//! END-TO-END mandate: real training through the full three-layer stack.
//!
//! L1 Pallas kernels → L2 JAX train_step → AOT HLO text → L3 rust PJRT
//! execution, with the communication layer simulated per transport. Trains
//! a GPT-2-style model on a synthetic bigram corpus for a few hundred
//! steps, logs the loss curve to `reports/`, and checks Fig 12's
//! claim: NCCL-vs-VCCL transport choice does NOT change convergence (the
//! loss curves are bit-identical; only simulated iteration time differs).
//!
//! Run (needs the AOT artifacts and a PJRT-enabled build):
//! `cd python && python -m compile.aot --out ../artifacts --presets e2e`,
//! then `cargo run --release --features xla --example train_e2e -- [steps] [preset]`

use std::path::Path;

use vccl::config::Config;
use vccl::train::{run_training, TrainOpts};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: u64 = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let preset = args.get(1).cloned().unwrap_or_else(|| "e2e".to_string());
    let dir = Path::new("artifacts");
    if !dir.join(format!("meta_{preset}.json")).exists() {
        eprintln!("artifacts for preset {preset:?} missing — run:");
        eprintln!("  cd python && python -m compile.aot --out ../artifacts --presets {preset}");
        std::process::exit(1);
    }

    let opts = TrainOpts { preset: preset.clone(), steps, log_every: 10, ..Default::default() };

    println!("=== VCCL (SM-free) transport ===");
    let vccl_rep = run_training(dir, Config::paper_defaults(), &opts, |r| {
        println!("step {:>5}  loss {:.4}  ({:.0} ms/step)", r.step, r.loss, r.wall_ms);
    })?;

    println!("\n=== NCCL (kernel) transport — loss must be identical (Fig 12) ===");
    let nccl_rep = run_training(dir, Config::nccl_baseline(), &opts, |_| {})?;

    // Fig 12 equivalence: identical losses, step for step.
    let mut max_diff = 0f32;
    for (a, b) in vccl_rep.steps.iter().zip(nccl_rep.steps.iter()) {
        max_diff = max_diff.max((a.loss - b.loss).abs());
    }
    println!("\nloss-curve max |Δ| across transports: {max_diff} (expected 0: the");
    println!("transport changes WHEN tensors move, never their values)");

    println!("\nsimulated 1F1B iteration time:");
    println!("  VCCL: {:.2} ms  ({:.0} TFLOPS/GPU at paper-scale compute)",
             vccl_rep.sim_iter_ns as f64 / 1e6, vccl_rep.sim_tflops_per_gpu);
    println!("  NCCL: {:.2} ms  ({:.0} TFLOPS/GPU)",
             nccl_rep.sim_iter_ns as f64 / 1e6, nccl_rep.sim_tflops_per_gpu);
    let gain = nccl_rep.sim_iter_ns as f64 / vccl_rep.sim_iter_ns as f64 - 1.0;
    println!("  SM-free gain: {:+.2}% (paper: up to +5.28%)", gain * 100.0);

    std::fs::create_dir_all("reports").ok();
    std::fs::write("reports/e2e_loss_vccl.csv", vccl_rep.to_csv())?;
    std::fs::write("reports/e2e_loss_nccl.csv", nccl_rep.to_csv())?;
    println!("\nloss curves -> reports/e2e_loss_{{vccl,nccl}}.csv");
    println!("initial loss {:.4} -> final loss {:.4} over {} steps",
             vccl_rep.initial_loss(), vccl_rep.final_loss(), steps);
    anyhow::ensure!(max_diff == 0.0, "transports must not change numerics");
    anyhow::ensure!(vccl_rep.final_loss() < vccl_rep.initial_loss(), "loss must descend");
    Ok(())
}
