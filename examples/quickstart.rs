//! Quickstart: build a simulated 2-node cluster, run collectives under
//! VCCL's SM-free transport, and print NCCL-Tests-style numbers.
//!
//! Run: `cargo run --release --example quickstart`

use vccl::ccl::{ClusterSim, CollKind};
use vccl::config::Config;
use vccl::topology::RankId;
use vccl::util::ByteSize;

fn main() {
    let mut cfg = Config::paper_defaults();
    cfg.vccl.channels = 4;
    println!("cluster: {} nodes × {} GPUs, {} Gbps rail-optimized CLOS",
             cfg.topo.num_nodes, cfg.topo.gpus_per_node, cfg.net.link_gbps);
    println!("transport: {}\n", cfg.vccl.transport.name());

    // Inter-node point-to-point (the paper's PP boundary traffic).
    let mut sim = ClusterSim::new(cfg.clone());
    let (t, op) = sim.run_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
    println!("SendRecv 64MB inter-node: {t}  algbw {:.1} GB/s",
             op.algbw_gbps().unwrap() / 8.0);

    // Ring AllReduce over all 16 ranks (DP traffic).
    let mut sim = ClusterSim::new(cfg.clone());
    let nranks = sim.topo.num_ranks();
    let (t, op) = sim.run_collective(CollKind::AllReduce, ByteSize::mb(64).0);
    println!("AllReduce 64MB ×{nranks}:   {t}  busbw {:.1} GB/s",
             op.busbw_gbps(nranks).unwrap() / 8.0);

    // AlltoAll (MoE dispatch traffic) — exercises PXN relays.
    let mut sim = ClusterSim::new(cfg.clone());
    let (t, op) = sim.run_collective(CollKind::AllToAll, ByteSize::mb(16).0);
    println!("AlltoAll  16MB ×{nranks}:   {t}  algbw {:.1} GB/s",
             op.algbw_gbps().unwrap() / 8.0);

    // SM accounting: the whole point of the SM-free design.
    println!("\ncomm kernel launches: {} (VCCL target: 0)", sim.stats.comm_kernel_launches);
    println!("proxy CPU time: {:.2} ms across {} ranks",
             sim.stats.proxy_cpu_ns.iter().sum::<u64>() as f64 / 1e6, nranks);
}
