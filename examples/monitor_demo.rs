//! Monitor demo (Fig 15): the window-based O(μs) monitor distinguishes
//! genuine network stragglers from GPU interference and task termination.
//!
//! Run: `cargo run --release --example monitor_demo`

use vccl::config::Config;
use vccl::coordinator::observability;

fn main() {
    let cfg = Config::paper_defaults();
    println!("{}", observability::fig15_pinpointing(&cfg));
    println!("{}", observability::fig19_window_sweep(&cfg));
}
