//! MoE-style AlltoAll workload (§6 "SM-free for other reduction-free
//! primitives"): token-dispatch traffic across 16 ranks, comparing the
//! SM-free transport against the kernel baseline, PXN relays included.
//!
//! Run: `cargo run --release --example alltoall_moe`

use vccl::ccl::{ClusterSim, CollKind};
use vccl::config::Config;
use vccl::util::ByteSize;

fn main() {
    println!("MoE token-dispatch AlltoAll, 2 nodes × 8 GPUs, per-rank buffer sweep\n");
    println!("{:>8} {:>14} {:>14} {:>8}", "size", "VCCL GB/s", "NCCL GB/s", "gain");
    for mb in [4u64, 16, 64] {
        let bytes = ByteSize::mb(mb).0;
        let run = |preset: Config| {
            let mut cfg = preset;
            cfg.vccl.channels = 4;
            let mut sim = ClusterSim::new(cfg);
            let (_, op) = sim.run_collective(CollKind::AllToAll, bytes);
            (op.algbw_gbps().unwrap() / 8.0, sim.stats.comm_kernel_launches, sim.stats.ce_ops)
        };
        let (v, v_kernels, v_ce) = run(Config::paper_defaults());
        let (n, n_kernels, _) = run(Config::nccl_baseline());
        println!("{:>7}M {v:>13.1} {n:>13.1} {:>+7.1}%", mb, (v / n - 1.0) * 100.0);
        if mb == 64 {
            println!("\nkernel launches: VCCL={v_kernels} NCCL={n_kernels}; VCCL copy-engine ops={v_ce}");
            println!("(dispatch/combine overlap potential = freed SMs; §6 discussion)");
        }
    }
}
