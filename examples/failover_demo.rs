//! Failover demo (Fig 13a): a SendRecv rides through an RNIC port-down via
//! the primary-backup QP mechanism, then fails back when the port heals.
//!
//! Run: `cargo run --release --example failover_demo`

use vccl::ccl::ClusterSim;
use vccl::config::Config;
use vccl::sim::SimTime;
use vccl::topology::RankId;
use vccl::util::ByteSize;

fn main() {
    let mut cfg = Config::paper_defaults();
    cfg.vccl.channels = 2;
    cfg.net.qp_warmup_ns = 2_000_000_000;
    let window_s = cfg.net.retry_window_ns() as f64 / 1e9;
    println!("retry window: {window_s:.1}s (IB_TIMEOUT={}, RETRY_CNT={})",
             cfg.net.ib_timeout_exp, cfg.net.ib_retry_cnt);

    let mut sim = ClusterSim::new(cfg);
    let port = sim.topo.primary_port(sim.topo.gpu_of_rank(RankId(0)));
    let backup = sim.conns.is_empty(); // (created lazily below)
    let _ = backup;
    println!("injecting: {port} DOWN at t=4s, UP at t=19s\n");
    sim.inject_port_down(port, SimTime::s(4));
    sim.inject_port_up(port, SimTime::s(19));

    let id = sim.submit_p2p(RankId(0), RankId(8), ByteSize::gb(1).0);
    sim.run_to_idle(400_000_000);
    let op = &sim.ops[id.0];

    println!("transfer done: {} at t={}", op.is_done(), op.finished_at.unwrap());
    println!("failovers: {}  failbacks: {}", sim.stats.failovers, sim.stats.failbacks);
    println!("\nbandwidth timeline (1s buckets, primary port):");
    for (t, gbps) in sim.port_bandwidth_series(port, SimTime::s(1)) {
        let bar = "#".repeat((gbps / 20.0) as usize);
        println!("  t={t:>4.0}s {gbps:>6.0} Gbps |{bar}");
    }
    let bport = sim.conns.iter().find_map(|c| c.backup_port).unwrap();
    println!("\nbandwidth timeline (backup port {bport}):");
    for (t, gbps) in sim.port_bandwidth_series(bport, SimTime::s(1)) {
        let bar = "#".repeat((gbps / 20.0) as usize);
        println!("  t={t:>4.0}s {gbps:>6.0} Gbps |{bar}");
    }
}
