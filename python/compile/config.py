"""Model-size presets for the L2 GPT-2-style workload.

The paper trains GPT-2 at 32B–314B on Hopper clusters; our compute substrate
is a single CPU core driving XLA-CPU through PJRT, so the end-to-end example
uses a scaled-down preset (documented in DESIGN.md's substitution table).
The architecture (decoder-only transformer, 1F1B-friendly uniform blocks) and
the full three-layer path (Pallas kernel -> JAX fwd/bwd -> HLO -> rust PJRT)
are identical across presets; only the dimensions change.
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch: int
    # Pallas tiling (see kernels/attention.py): rows per q-block.
    block_q: int = 32
    block_k: int = 32

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    def param_count(self) -> int:
        d, v, l = self.d_model, self.vocab, self.n_layers
        embed = v * d + self.seq_len * d
        per_layer = (
            2 * d            # ln1 scale/bias
            + d * 3 * d + 3 * d  # qkv
            + d * d + d      # proj
            + 2 * d          # ln2
            + d * self.d_ff + self.d_ff  # fc1
            + self.d_ff * d + d          # fc2
        )
        final_ln = 2 * d
        return embed + l * per_layer + final_ln

    def to_dict(self):
        d = asdict(self)
        d["param_count"] = self.param_count()
        d["d_head"] = self.d_head
        d["d_ff"] = self.d_ff
        return d


#: Unit-test scale: lowers + runs in well under a second.
TINY = ModelConfig(name="tiny", vocab=512, d_model=64, n_layers=2, n_heads=4,
                   seq_len=32, batch=2, block_q=16, block_k=16)

#: End-to-end training scale for the 1-core CPU substrate (~4M params).
E2E = ModelConfig(name="e2e", vocab=2048, d_model=256, n_layers=4, n_heads=8,
                  seq_len=128, batch=8)

#: GPT-2-class ~100M preset (the paper-shaped model); lowers fine, but a
#: few hundred CPU steps are not practical on one core — used for artifact
#: generation checks and as the documented "real" configuration.
GPT2_100M = ModelConfig(name="gpt2_100m", vocab=16384, d_model=768,
                        n_layers=12, n_heads=12, seq_len=256, batch=8)

PRESETS = {c.name: c for c in (TINY, E2E, GPT2_100M)}
