"""AOT lowering: JAX -> HLO text artifacts for the Rust PJRT runtime.

HLO **text** is the interchange format, NOT `lowered.compile()` /
serialized protos: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which the xla crate's xla_extension 0.5.1 rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Outputs (per preset):
  artifacts/train_step_<preset>.hlo.txt  (flat,m,v,step,tokens,targets) ->
                                         tuple(flat', m', v', loss)
  artifacts/loss_<preset>.hlo.txt        (flat,tokens,targets) -> tuple(loss)
  artifacts/meta_<preset>.json           shapes + param layout for rust
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

try:
    from . import config as cfgmod
    from . import model as M
except ImportError:
    from compile import config as cfgmod
    from compile import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_preset(preset: str, out_dir: str) -> dict:
    cfg = cfgmod.PRESETS[preset]
    P = M.layout_size(cfg)
    B, L = cfg.batch, cfg.seq_len
    f32 = jnp.float32
    i32 = jnp.int32
    flat = jax.ShapeDtypeStruct((P,), f32)
    mv = jax.ShapeDtypeStruct((P,), f32)
    step = jax.ShapeDtypeStruct((), f32)
    toks = jax.ShapeDtypeStruct((B, L), i32)

    step_fn = functools.partial(M.train_step, cfg=cfg)
    lowered_step = jax.jit(step_fn).lower(flat, mv, mv, step, toks, toks)
    loss_fn = functools.partial(M.loss_fn, cfg=cfg)
    lowered_loss = jax.jit(lambda a, b, c: (loss_fn(a, b, c),)).lower(flat, toks, toks)

    os.makedirs(out_dir, exist_ok=True)
    paths = {}
    for name, lowered in [("train_step", lowered_step), ("loss", lowered_loss)]:
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}_{preset}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        paths[name] = path

    meta = {
        "preset": preset,
        "model": cfg.to_dict(),
        "flat_len": P,
        "batch": B,
        "seq_len": L,
        "train_step": {
            "inputs": ["flat[P]", "m[P]", "v[P]", "step[]", "tokens[B,L]", "targets[B,L]"],
            "outputs": ["flat[P]", "m[P]", "v[P]", "loss[]"],
        },
        "artifacts": paths,
    }
    meta_path = os.path.join(out_dir, f"meta_{preset}.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)
    meta["meta_path"] = meta_path
    return meta


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument(
        "--presets",
        default="tiny,e2e",
        help="comma-separated preset names (tiny,e2e,gpt2_100m)",
    )
    args = ap.parse_args()
    for preset in args.presets.split(","):
        meta = lower_preset(preset.strip(), args.out)
        print(
            f"[aot] {preset}: {meta['flat_len']} params "
            f"({meta['model']['param_count']} logical) -> {meta['artifacts']}"
        )
    # Marker file the Makefile can depend on.
    with open(os.path.join(args.out, "model.hlo.txt"), "w") as f:
        f.write("# see per-preset artifacts: train_step_<preset>.hlo.txt\n")


if __name__ == "__main__":
    main()
