"""Fused MLP Pallas kernel: gelu(x @ w1 + b1) @ w2 + b2 in one pass.

Second L1 kernel: the transformer block's MLP fused end-to-end so the
[N, 4D] hidden activation never round-trips to HBM — it lives in VMEM for
the row-tile being processed (the TPU translation of kernel fusion that
CUDA would express with a persistent threadblock).

grid = (N / block_rows,): each program takes a row tile of x and both
weight matrices (weights fit VMEM at our model sizes; at larger D this
BlockSpec would tile F as well).

Like attention.py: `interpret=True` for CPU-PJRT execution, `custom_vjp`
with a pure-jnp backward (ref.fused_mlp_ref).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]          # [bn, D]
    w1 = w1_ref[...]        # [D, F]
    b1 = b1_ref[...]        # [F]
    w2 = w2_ref[...]        # [F, D]
    b2 = b2_ref[...]        # [D]
    h = x @ w1 + b1[None, :]
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    h = 0.5 * h * (1.0 + jnp.tanh(c * (h + 0.044715 * h**3)))
    o_ref[...] = h @ w2 + b2[None, :]


def _mlp_fwd_impl(x, w1, b1, w2, b2, *, block_rows: int):
    N, D = x.shape
    F = w1.shape[1]
    assert N % block_rows == 0, (N, block_rows)
    grid = (N // block_rows,)
    return pl.pallas_call(
        _mlp_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((D, F), lambda i: (0, 0)),
            pl.BlockSpec((F,), lambda i: (0,)),
            pl.BlockSpec((F, D), lambda i: (0, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, D), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def fused_mlp(x, w1, b1, w2, b2, block_rows=32):
    """Fused transformer MLP over [N, D] rows (Pallas forward)."""
    return _mlp_fwd_impl(x, w1, b1, w2, b2, block_rows=block_rows)


def _fwd(x, w1, b1, w2, b2, block_rows):
    out = _mlp_fwd_impl(x, w1, b1, w2, b2, block_rows=block_rows)
    return out, (x, w1, b1, w2, b2)


def _bwd(block_rows, res, g):
    x, w1, b1, w2, b2 = res
    _, vjp = jax.vjp(ref.fused_mlp_ref, x, w1, b1, w2, b2)
    return vjp(g)


fused_mlp.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(block_rows: int, d_model: int, d_ff: int,
                         dtype_bytes: int = 4) -> int:
    """VMEM working set per program (§Perf): x-tile + both weights + h."""
    return (
        block_rows * d_model      # x tile
        + d_model * d_ff + d_ff   # w1, b1
        + d_ff * d_model + d_model  # w2, b2
        + block_rows * d_ff       # hidden tile
        + block_rows * d_model    # out tile
    ) * dtype_bytes
