"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest suite asserts `assert_allclose`
against, and they double as the backward-pass math for the kernels'
`custom_vjp` (flash-attention-style recompute: the forward runs in Pallas,
the backward re-derives gradients from saved inputs with plain jnp).
"""

import jax.numpy as jnp


def causal_attention_ref(q, k, v):
    """Reference causal self-attention.

    q, k, v: [L, Dh] for one (batch, head). Returns [L, Dh].
    """
    L = q.shape[0]
    scale = (1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], dtype=q.dtype)))
    scores = (q @ k.T) * scale  # [L, L]
    mask = jnp.tril(jnp.ones((L, L), dtype=bool))
    scores = jnp.where(mask, scores, -1e30)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return p @ v


def causal_attention_ref_batched(q, k, v):
    """q, k, v: [BH, L, Dh] — vmapped reference."""
    import jax

    return jax.vmap(causal_attention_ref)(q, k, v)


def gelu(x):
    """tanh-approximation GELU (GPT-2's activation)."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, dtype=x.dtype))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def fused_mlp_ref(x, w1, b1, w2, b2):
    """Reference transformer MLP: gelu(x @ w1 + b1) @ w2 + b2.

    x: [N, D]; w1: [D, F]; b1: [F]; w2: [F, D]; b2: [D].
    """
    h = gelu(x @ w1 + b1)
    return h @ w2 + b2


def layer_norm_ref(x, scale, bias, eps=1e-5):
    """Reference LayerNorm over the last axis."""
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias
