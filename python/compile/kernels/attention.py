"""Flash-attention-style Pallas kernel (the L1 compute hot-spot).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's hot spot
is GEMM co-running with communication on Hopper SMs; on TPU the analogous
structure is MXU matmuls fed by an explicit HBM->VMEM schedule. This kernel
expresses that schedule with a Pallas grid:

  grid = (batch*heads, L/block_q)  -- one program per q-tile;
  each program streams K/V tiles through VMEM with an online-softmax
  carry (m, l, acc), so the S = Q K^T matrix is never materialized and
  the VMEM footprint is O(block_q * (d_head + block_k)) instead of O(L^2).

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO with identical numerics
(verified against kernels/ref.py by pytest + hypothesis).

Autodiff: pallas_call has no automatic VJP, so `flash_attention` is a
jax.custom_vjp -- forward through the kernel, backward recomputed with the
pure-jnp reference math (standard flash-attention practice: recompute
attention in the backward rather than saving S).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, q_block: int):
    """One q-tile: online softmax over causal k-tiles.

    Refs are VMEM tiles: q [bq, dh], k/v [L, dh] (full rows of this
    batch-head; the fori_loop below walks them in block_k strides, which is
    the HBM->VMEM streaming the BlockSpec would express on real hardware).
    """
    q = q_ref[...]  # [bq, dh]
    bq, dh = q.shape
    L = k_ref.shape[0]
    scale = (1.0 / jnp.sqrt(jnp.asarray(dh, dtype=q.dtype)))

    q_tile = pl.program_id(1)
    q_start = q_tile * q_block

    # Causal bound: this q-tile attends to keys < q_start + bq. We walk all
    # tiles up to that bound. (Static loop count = L/block_k; masking takes
    # care of the boundary.)
    n_kblocks = L // block_k

    def body(i, carry):
        m_prev, l_prev, acc = carry
        k_start = i * block_k
        k_blk = jax.lax.dynamic_slice_in_dim(k_ref[...], k_start, block_k, axis=0)
        v_blk = jax.lax.dynamic_slice_in_dim(v_ref[...], k_start, block_k, axis=0)
        s = (q @ k_blk.T) * scale  # [bq, block_k]
        # Causal mask: key position must be <= query position.
        q_pos = q_start + jnp.arange(bq)[:, None]
        k_pos = k_start + jnp.arange(block_k)[None, :]
        s = jnp.where(k_pos <= q_pos, s, -1e30)
        # Online softmax update.
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=-1)
        acc = acc * alpha[:, None] + p @ v_blk
        return m_new, l_new, acc

    m0 = jnp.full((bq,), -1e30, dtype=q.dtype)
    l0 = jnp.zeros((bq,), dtype=q.dtype)
    acc0 = jnp.zeros((bq, dh), dtype=q.dtype)
    m, l, acc = jax.lax.fori_loop(0, n_kblocks, body, (m0, l0, acc0))
    o_ref[...] = acc / l[:, None]


def _flash_fwd_impl(q, k, v, *, block_q: int, block_k: int):
    """q, k, v: [BH, L, Dh] -> [BH, L, Dh] via the Pallas kernel."""
    BH, L, Dh = q.shape
    assert L % block_q == 0 and L % block_k == 0, (L, block_q, block_k)
    grid = (BH, L // block_q)
    kernel = functools.partial(_flash_kernel, block_k=block_k, q_block=block_q)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, Dh), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, L, Dh), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, L, Dh), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, Dh), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, L, Dh), q.dtype),
        interpret=True,
    )(q, k, v)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention(q, k, v, block_q=32, block_k=32):
    """Causal flash attention over [BH, L, Dh] tensors (Pallas forward)."""
    return _flash_fwd_impl(q, k, v, block_q=block_q, block_k=block_k)


def _fwd(q, k, v, block_q, block_k):
    out = _flash_fwd_impl(q, k, v, block_q=block_q, block_k=block_k)
    return out, (q, k, v)


def _bwd(block_q, block_k, res, g):
    # Flash-style recompute: re-derive gradients from q, k, v with the
    # reference math (no S matrix was saved by the forward).
    q, k, v = res
    _, vjp = jax.vjp(ref.causal_attention_ref_batched, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)


def vmem_footprint_bytes(block_q: int, block_k: int, d_head: int, L: int,
                         dtype_bytes: int = 4) -> int:
    """Estimated VMEM working set per program (DESIGN.md §Perf):
    q-tile + k-tile + v-tile + acc + softmax carries."""
    q_tile = block_q * d_head
    kv_tiles = 2 * block_k * d_head
    acc = block_q * d_head
    carries = 2 * block_q
    return (q_tile + kv_tiles + acc + carries) * dtype_bytes


def mxu_utilization_estimate(block_q: int, block_k: int, d_head: int) -> float:
    """Fraction of a 128x128 MXU tile the kernel's matmuls fill (§Perf).

    Each inner matmul is [block_q, d_head] @ [d_head, block_k]; the MXU
    processes 128x128 systolic tiles, so utilization ~= product of the
    dimension fills (capped at 1).
    """
    fill = lambda n: min(n, 128) / 128.0
    return fill(block_q) * fill(block_k) * fill(d_head)
