"""L2: GPT-2-style decoder-only transformer in JAX, calling the L1 Pallas
kernels, with a flat-parameter Adam train step for the Rust PJRT runtime.

Everything the Rust coordinator needs is two jitted functions over plain
arrays (no pytrees cross the FFI):

  loss_fn(flat_params, tokens, targets)                 -> loss
  train_step(flat_params, m, v, step, tokens, targets)  -> (flat', m', v', loss)

Parameters live in ONE flat f32 vector; (un)packing happens inside JAX with
static offsets, so the Rust side passes exactly 3 big buffers + 1 scalar +
2 token arrays and receives 3 buffers + 1 scalar back. XLA fuses the
unpack/repack into the surrounding computation.
"""

import functools

import jax
import jax.numpy as jnp

try:  # package-relative when imported as compile.model
    from . import config as cfgmod
    from .kernels import ref
    from .kernels.attention import flash_attention
    from .kernels.fused_mlp import fused_mlp
except ImportError:  # script-style import from python/
    from compile import config as cfgmod
    from compile.kernels import ref
    from compile.kernels.attention import flash_attention
    from compile.kernels.fused_mlp import fused_mlp


# ----------------------------------------------------------------------
# Flat-parameter layout
# ----------------------------------------------------------------------

def param_layout(cfg):
    """Ordered (name, shape) list defining the flat vector layout."""
    d, v, L, f = cfg.d_model, cfg.vocab, cfg.seq_len, cfg.d_ff
    layout = [("embed", (v, d)), ("pos", (L, d))]
    for i in range(cfg.n_layers):
        layout += [
            (f"l{i}.ln1_s", (d,)), (f"l{i}.ln1_b", (d,)),
            (f"l{i}.qkv_w", (d, 3 * d)), (f"l{i}.qkv_b", (3 * d,)),
            (f"l{i}.proj_w", (d, d)), (f"l{i}.proj_b", (d,)),
            (f"l{i}.ln2_s", (d,)), (f"l{i}.ln2_b", (d,)),
            (f"l{i}.fc1_w", (d, f)), (f"l{i}.fc1_b", (f,)),
            (f"l{i}.fc2_w", (f, d)), (f"l{i}.fc2_b", (d,)),
        ]
    layout += [("lnf_s", (d,)), ("lnf_b", (d,))]
    return layout


def layout_size(cfg) -> int:
    return sum(int(jnp.prod(jnp.asarray(s))) for _, s in param_layout(cfg))


def unpack(flat, cfg):
    """Flat f32 vector -> dict of named arrays (static slices)."""
    params = {}
    off = 0
    for name, shape in param_layout(cfg):
        n = 1
        for s in shape:
            n *= s
        params[name] = jax.lax.dynamic_slice_in_dim(flat, off, n).reshape(shape)
        off += n
    return params


def init_params(cfg, seed: int = 0):
    """GPT-2-style init, returned as the flat vector."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in param_layout(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_b", "ln1_b", "ln2_b", "lnf_b")) and len(shape) == 1:
            chunks.append(jnp.zeros(shape, jnp.float32).ravel())
        elif name.endswith(("ln1_s", "ln2_s", "lnf_s")):
            chunks.append(jnp.ones(shape, jnp.float32).ravel())
        else:
            std = 0.02
            chunks.append((jax.random.normal(sub, shape, jnp.float32) * std).ravel())
    return jnp.concatenate(chunks)


# ----------------------------------------------------------------------
# Forward
# ----------------------------------------------------------------------

def forward_logits(flat, tokens, cfg):
    """tokens [B, L] int32 -> logits [B, L, V]."""
    p = unpack(flat, cfg)
    B, L = tokens.shape
    d, H = cfg.d_model, cfg.n_heads
    dh = cfg.d_head

    x = p["embed"][tokens] + p["pos"][None, :L, :]

    for i in range(cfg.n_layers):
        h = ref.layer_norm_ref(x, p[f"l{i}.ln1_s"], p[f"l{i}.ln1_b"])
        qkv = h @ p[f"l{i}.qkv_w"] + p[f"l{i}.qkv_b"]  # [B, L, 3d]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        # [B, L, d] -> [B*H, L, dh]
        to_heads = lambda t: t.reshape(B, L, H, dh).transpose(0, 2, 1, 3).reshape(B * H, L, dh)
        att = flash_attention(
            to_heads(q), to_heads(k), to_heads(v), cfg.block_q, cfg.block_k
        )
        att = att.reshape(B, H, L, dh).transpose(0, 2, 1, 3).reshape(B, L, d)
        x = x + att @ p[f"l{i}.proj_w"] + p[f"l{i}.proj_b"]

        h = ref.layer_norm_ref(x, p[f"l{i}.ln2_s"], p[f"l{i}.ln2_b"])
        mlp_out = fused_mlp(
            h.reshape(B * L, d),
            p[f"l{i}.fc1_w"], p[f"l{i}.fc1_b"],
            p[f"l{i}.fc2_w"], p[f"l{i}.fc2_b"],
            cfg.block_q,
        ).reshape(B, L, d)
        x = x + mlp_out

    x = ref.layer_norm_ref(x, p["lnf_s"], p["lnf_b"])
    return x @ p["embed"].T  # tied LM head


def loss_fn(flat, tokens, targets, cfg):
    """Mean next-token cross-entropy."""
    logits = forward_logits(flat, tokens, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -ll.mean()


# ----------------------------------------------------------------------
# Adam train step (flat-vector optimizer state)
# ----------------------------------------------------------------------

ADAM_B1, ADAM_B2, ADAM_EPS, LR = 0.9, 0.999, 1e-8, 1.5e-4


def train_step(flat, m, v, step, tokens, targets, cfg):
    """One Adam step. step: scalar f32 (1-based). Returns new state + loss."""
    loss, g = jax.value_and_grad(lambda f: loss_fn(f, tokens, targets, cfg))(flat)
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1**step)
    vhat = v / (1.0 - ADAM_B2**step)
    flat = flat - LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return flat, m, v, loss


def make_jitted(cfg):
    """(loss_jit, step_jit) with cfg closed over."""
    loss_jit = jax.jit(functools.partial(loss_fn, cfg=cfg))
    step_jit = jax.jit(functools.partial(train_step, cfg=cfg))
    return loss_jit, step_jit


def synthetic_batch(cfg, seed: int):
    """Deterministic synthetic corpus: Zipf-ish token stream with strong
    bigram structure, so the loss has something learnable to descend on."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    B, L, V = cfg.batch, cfg.seq_len, cfg.vocab
    # bigram "grammar": next token = (3*tok + noise) mod V
    start = jax.random.randint(k1, (B, 1), 0, V)
    noise = jax.random.randint(k2, (B, L), 0, 7)

    def step(tok, n):
        nxt = (3 * tok + n) % V
        return nxt, nxt

    def row(s, ns):
        _, toks = jax.lax.scan(step, s[0], ns)
        return toks

    seqs = jax.vmap(row)(start, noise)  # [B, L]
    tokens = seqs[:, :-1]
    targets = seqs[:, 1:]
    # pad back to L with wraparound so shapes stay [B, L]
    tokens = jnp.concatenate([start, tokens], axis=1)[:, : L]
    targets = seqs
    return tokens.astype(jnp.int32), targets.astype(jnp.int32)


def get_config(name: str):
    return cfgmod.PRESETS[name]
