"""L2 correctness: model shapes, training descent, determinism, and the
flat-parameter packing the Rust runtime depends on."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import config as C
from compile import model as M


CFG = C.TINY


class TestLayout:
    def test_flat_size_matches_layout(self):
        flat = M.init_params(CFG)
        assert flat.shape == (M.layout_size(CFG),)
        assert M.layout_size(CFG) == CFG.param_count()

    def test_unpack_shapes(self):
        flat = M.init_params(CFG)
        p = M.unpack(flat, CFG)
        assert p["embed"].shape == (CFG.vocab, CFG.d_model)
        assert p["l0.qkv_w"].shape == (CFG.d_model, 3 * CFG.d_model)
        assert p["l1.fc1_w"].shape == (CFG.d_model, CFG.d_ff)
        assert p["lnf_s"].shape == (CFG.d_model,)

    def test_unpack_roundtrip_values(self):
        flat = M.init_params(CFG)
        p = M.unpack(flat, CFG)
        # First layout entry is the embedding: its raveled values must be
        # the first vocab*d elements of the flat vector.
        np.testing.assert_array_equal(
            np.asarray(p["embed"]).ravel(),
            np.asarray(flat[: CFG.vocab * CFG.d_model]),
        )

    def test_presets_param_counts(self):
        assert C.GPT2_100M.param_count() > 95_000_000
        assert C.E2E.param_count() < 10_000_000
        assert C.TINY.param_count() < 300_000


class TestForward:
    def test_logits_shape(self):
        flat = M.init_params(CFG)
        toks, _ = M.synthetic_batch(CFG, 0)
        logits = M.forward_logits(flat, toks, CFG)
        assert logits.shape == (CFG.batch, CFG.seq_len, CFG.vocab)

    def test_initial_loss_near_uniform(self):
        flat = M.init_params(CFG)
        toks, tgts = M.synthetic_batch(CFG, 0)
        loss = float(M.loss_fn(flat, toks, tgts, CFG))
        uniform = float(np.log(CFG.vocab))
        assert abs(loss - uniform) < 0.5, (loss, uniform)

    def test_forward_deterministic(self):
        flat = M.init_params(CFG)
        toks, tgts = M.synthetic_batch(CFG, 0)
        l1 = float(M.loss_fn(flat, toks, tgts, CFG))
        l2 = float(M.loss_fn(flat, toks, tgts, CFG))
        assert l1 == l2


class TestTraining:
    def test_loss_descends(self):
        flat = M.init_params(CFG)
        m = jnp.zeros_like(flat)
        v = jnp.zeros_like(flat)
        _, step_jit = M.make_jitted(CFG)
        toks, tgts = M.synthetic_batch(CFG, 0)
        l_first = None
        for i in range(20):
            flat, m, v, loss = step_jit(flat, m, v, jnp.float32(i + 1), toks, tgts)
            if l_first is None:
                l_first = float(loss)
        assert float(loss) < l_first - 0.3, (l_first, float(loss))

    def test_grad_is_finite(self):
        flat = M.init_params(CFG)
        toks, tgts = M.synthetic_batch(CFG, 0)
        g = jax.grad(lambda f: M.loss_fn(f, toks, tgts, CFG))(flat)
        assert bool(jnp.isfinite(g).all())
        assert float(jnp.abs(g).max()) > 0.0

    def test_training_deterministic(self):
        def run():
            flat = M.init_params(CFG)
            m = jnp.zeros_like(flat)
            v = jnp.zeros_like(flat)
            _, step_jit = M.make_jitted(CFG)
            toks, tgts = M.synthetic_batch(CFG, 0)
            for i in range(3):
                flat, m, v, loss = step_jit(flat, m, v, jnp.float32(i + 1), toks, tgts)
            return float(loss)

        assert run() == run()

    def test_synthetic_batch_shapes_and_range(self):
        toks, tgts = M.synthetic_batch(CFG, 1)
        assert toks.shape == (CFG.batch, CFG.seq_len)
        assert tgts.shape == (CFG.batch, CFG.seq_len)
        assert toks.dtype == jnp.int32
        assert int(toks.min()) >= 0 and int(toks.max()) < CFG.vocab

    def test_synthetic_batches_differ_by_seed(self):
        t0, _ = M.synthetic_batch(CFG, 0)
        t1, _ = M.synthetic_batch(CFG, 1)
        assert not np.array_equal(np.asarray(t0), np.asarray(t1))
