"""L1 correctness: Pallas kernels vs pure-jnp oracles.

The hypothesis sweeps cover the shape/dtype space the model exercises
(power-of-two sequence lengths, head dims, block sizes); assert_allclose
against kernels/ref.py is THE correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import (
    flash_attention,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.fused_mlp import fused_mlp


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ----------------------------------------------------------------------
# Flash attention
# ----------------------------------------------------------------------

class TestFlashAttention:
    def test_matches_reference_basic(self):
        q, k, v = rand(0, 4, 64, 16), rand(1, 4, 64, 16), rand(2, 4, 64, 16)
        out = flash_attention(q, k, v, 32, 32)
        expect = ref.causal_attention_ref_batched(q, k, v)
        np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        bh=st.sampled_from([1, 2, 4]),
        L=st.sampled_from([16, 32, 64, 128]),
        dh=st.sampled_from([8, 16, 32]),
        blk=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_reference_sweep(self, bh, L, dh, blk, seed):
        if L % blk != 0:
            blk = L
        q = rand(seed, bh, L, dh)
        k = rand(seed + 1, bh, L, dh)
        v = rand(seed + 2, bh, L, dh)
        out = flash_attention(q, k, v, blk, blk)
        expect = ref.causal_attention_ref_batched(q, k, v)
        np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)

    def test_block_size_does_not_change_numerics(self):
        q, k, v = rand(7, 2, 64, 16), rand(8, 2, 64, 16), rand(9, 2, 64, 16)
        outs = [flash_attention(q, k, v, bq, bk) for bq, bk in [(16, 16), (32, 16), (64, 64)]]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=2e-5, atol=2e-5)

    def test_causality(self):
        # Changing a future key/value must not change earlier outputs.
        q, k, v = rand(3, 1, 32, 8), rand(4, 1, 32, 8), rand(5, 1, 32, 8)
        out1 = flash_attention(q, k, v, 16, 16)
        k2 = k.at[:, -1, :].set(99.0)
        v2 = v.at[:, -1, :].set(-99.0)
        out2 = flash_attention(q, k2, v2, 16, 16)
        np.testing.assert_allclose(out1[:, :-1], out2[:, :-1], rtol=1e-5, atol=1e-5)
        assert not np.allclose(out1[:, -1], out2[:, -1])

    def test_gradients_match_reference(self):
        q, k, v = rand(10, 2, 32, 8), rand(11, 2, 32, 8), rand(12, 2, 32, 8)

        def f_kernel(q, k, v):
            return flash_attention(q, k, v, 16, 16).sum()

        def f_ref(q, k, v):
            return ref.causal_attention_ref_batched(q, k, v).sum()

        gk = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)

    def test_jit_and_lower(self):
        q, k, v = rand(13, 2, 32, 8), rand(14, 2, 32, 8), rand(15, 2, 32, 8)
        jitted = jax.jit(lambda a, b, c: flash_attention(a, b, c, 16, 16))
        np.testing.assert_allclose(
            jitted(q, k, v), flash_attention(q, k, v, 16, 16), rtol=1e-6
        )

    def test_vmem_estimates_sane(self):
        # §Perf: the working set must fit Hopper/TPU-v4-class VMEM (16MB).
        fp = vmem_footprint_bytes(block_q=128, block_k=128, d_head=64, L=2048)
        assert fp < 16 * 1024 * 1024
        u = mxu_utilization_estimate(128, 128, 64)
        assert 0.0 < u <= 1.0
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(8, 8, 8) < 0.01


# ----------------------------------------------------------------------
# Fused MLP
# ----------------------------------------------------------------------

class TestFusedMlp:
    def test_matches_reference_basic(self):
        x = rand(20, 64, 32)
        w1, b1 = rand(21, 32, 128), rand(22, 128)
        w2, b2 = rand(23, 128, 32), rand(24, 32)
        out = fused_mlp(x, w1, b1, w2, b2, 32)
        expect = ref.fused_mlp_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(out, expect, rtol=2e-5, atol=2e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        n=st.sampled_from([16, 32, 64, 128]),
        d=st.sampled_from([8, 16, 32]),
        f=st.sampled_from([32, 64]),
        blk=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**16),
    )
    def test_matches_reference_sweep(self, n, d, f, blk, seed):
        if n % blk != 0:
            blk = n
        x = rand(seed, n, d)
        w1, b1 = rand(seed + 1, d, f), rand(seed + 2, f)
        w2, b2 = rand(seed + 3, f, d), rand(seed + 4, d)
        out = fused_mlp(x, w1, b1, w2, b2, blk)
        expect = ref.fused_mlp_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(out, expect, rtol=3e-5, atol=3e-5)

    def test_gradients_match_reference(self):
        x = rand(30, 32, 16)
        w1, b1 = rand(31, 16, 64), rand(32, 64)
        w2, b2 = rand(33, 64, 16), rand(34, 16)

        def f_kernel(*a):
            return fused_mlp(*a, 16).sum()

        def f_ref(*a):
            return ref.fused_mlp_ref(*a).sum()

        gk = jax.grad(f_kernel, argnums=tuple(range(5)))(x, w1, b1, w2, b2)
        gr = jax.grad(f_ref, argnums=tuple(range(5)))(x, w1, b1, w2, b2)
        for a, b in zip(gk, gr):
            np.testing.assert_allclose(a, b, rtol=3e-5, atol=3e-5)

    def test_block_rows_invariance(self):
        x = rand(40, 64, 16)
        w1, b1 = rand(41, 16, 64), rand(42, 64)
        w2, b2 = rand(43, 64, 16), rand(44, 16)
        outs = [fused_mlp(x, w1, b1, w2, b2, blk) for blk in (8, 16, 32, 64)]
        for o in outs[1:]:
            np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


class TestRefInternals:
    def test_gelu_known_values(self):
        # gelu(0)=0; gelu(large)≈large; gelu(-large)≈0.
        x = jnp.array([0.0, 10.0, -10.0])
        g = ref.gelu(x)
        assert abs(float(g[0])) < 1e-6
        assert abs(float(g[1]) - 10.0) < 1e-3
        assert abs(float(g[2])) < 1e-3

    def test_layer_norm_stats(self):
        x = rand(50, 8, 32)
        y = ref.layer_norm_ref(x, jnp.ones(32), jnp.zeros(32))
        np.testing.assert_allclose(np.asarray(y.mean(-1)), 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(y.std(-1)), 1.0, atol=1e-2)

    def test_attention_rows_sum_to_convex_combination(self):
        # Each output row is a convex combination of v rows: with v = const,
        # output = const.
        q, k = rand(51, 1, 16, 8), rand(52, 1, 16, 8)
        v = jnp.ones((1, 16, 8))
        out = ref.causal_attention_ref_batched(q, k, v)
        np.testing.assert_allclose(out, 1.0, rtol=1e-5)
