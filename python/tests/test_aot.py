"""AOT artifact checks: HLO text generates, has the right signature, and
matches what the Rust runtime expects (meta.json contract)."""

import json
import os

import pytest

from compile import aot
from compile import config as C


@pytest.fixture(scope="module")
def tiny_meta(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    return aot.lower_preset("tiny", str(out))


class TestAot:
    def test_artifacts_written(self, tiny_meta):
        for p in tiny_meta["artifacts"].values():
            assert os.path.exists(p)
            assert os.path.getsize(p) > 1000

    def test_hlo_is_text_with_entry_layout(self, tiny_meta):
        text = open(tiny_meta["artifacts"]["train_step"]).read()
        assert text.startswith("HloModule")
        assert "entry_computation_layout" in text
        # Interchange contract: text, not protobuf (see aot.py docstring).
        assert "\x00" not in text

    def test_train_step_signature(self, tiny_meta):
        text = open(tiny_meta["artifacts"]["train_step"]).read()
        P = tiny_meta["flat_len"]
        B, L = tiny_meta["batch"], tiny_meta["seq_len"]
        head = text.splitlines()[0]
        assert f"f32[{P}]" in head
        assert f"s32[{B},{L}]" in head
        # Output tuple: 3 buffers + scalar loss.
        assert head.count(f"f32[{P}]") >= 4  # 3 in + ≥1 out mentions

    def test_meta_contract(self, tiny_meta):
        meta = json.load(open(tiny_meta["meta_path"]))
        assert meta["flat_len"] == C.TINY.param_count()
        assert meta["train_step"]["outputs"][-1] == "loss[]"

    def test_loss_artifact_single_output(self, tiny_meta):
        text = open(tiny_meta["artifacts"]["loss"]).read()
        head = text.splitlines()[0]
        assert "->(f32[])" in head
