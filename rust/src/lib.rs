//! # VCCL — an efficient, reliable and observable collective communication
//! library, reproduced on a simulated GPU-cluster substrate.
//!
//! This crate reproduces the system described in *"An Efficient, Reliable and
//! Observable Collective Communication Library in Large-scale GPU Training
//! Clusters"* (VCCL). The paper's substrate — Hopper GPUs, ConnectX-7 RNICs,
//! a 400 Gbps rail-optimized CLOS fabric — is rebuilt here as a deterministic
//! discrete-event simulation, faithful to the abstractions the paper
//! manipulates (SMs / copy engines / CUDA streams on the GPU side, QP / WR /
//! WC / CQ verbs on the network side). The *real* compute path (the paper's
//! GPT-2 training workload) is JAX + Pallas, AOT-lowered to HLO and executed
//! from Rust through PJRT (`runtime`).
//!
//! Module map (see DESIGN.md for the full inventory):
//!
//! - [`util`] — deterministic RNG, byte/bandwidth units, formatting.
//! - [`config`] — layered configuration: paper defaults → config file →
//!   `ICCL_*`/`VCCL_*` env vars (every knob is in docs/CONFIG.md).
//! - [`sim`] — discrete-event engine: nanosecond clock, event queue.
//! - [`trace`] — flight recorder: bounded cross-layer event ring with
//!   Chrome-trace export and anomaly snapshots (`vccl trace <id>`).
//! - [`topology`] — servers, GPUs, RNICs, NVLink, two-tier rail-optimized CLOS.
//! - [`net`] — RDMA verbs simulation: QPs, WR/WC/CQ, retry-timeout, CTS
//!   credits, max-min fair link sharing, incast/PFC behaviour, port failures;
//!   hot paths are O(changed-entities), not O(cluster) (DESIGN.md §Perf L3/L4).
//! - [`gpu`] — SM pool + block scheduler, GEMM wave/straggler model
//!   (paper Appendix E), copy engines, CUDA streams and ordering primitives.
//! - [`ccl`] — the collective library itself: communicators, transports
//!   (kernel-based NCCL baseline, NCCLX-like, SM-free VCCL), primitives,
//!   zero-copy registration, dynamic memory pool.
//! - [`fault`] — primary-backup QP mechanism (§3.3): failure perception,
//!   state migration, breakpoint retransmission, failback.
//! - [`monitor`] — window-based O(μs) network monitor (§3.4) and the
//!   dual-threshold straggler pinpointer.
//! - [`rca`] — causal root-cause engine over the flight recorder: typed
//!   dependency graph, backward walk from symptoms to fault windows, and
//!   ground-truth-scored diagnosis (`vccl rca <id>`).
//! - [`pipeline`] — 1F1B pipeline-parallel schedule and the training
//!   iteration model used for the throughput experiments (Fig 11, 13b, 14).
//! - [`metrics`] — counters/gauges, report tables, and the `BENCH_*.json`
//!   emission behind `vccl bench`.
//! - [`runtime`] — PJRT (xla crate) wrapper that loads the AOT artifacts.
//! - [`train`] — real-compute training driver (loss curves, Fig 12 / e2e).
//! - [`soak`] — time-compressed soak harness: MTBF fault injection over
//!   simulated days with checkpoint/resume of the full sim state (§Soak).
//! - [`coordinator`] — leader/CLI: experiment drivers for every paper
//!   table and figure, plus the `bench` measurement loop.

pub mod util;
pub mod config;
pub mod sim;
pub mod trace;
pub mod topology;
pub mod net;
pub mod gpu;
pub mod ccl;
pub mod fault;
pub mod monitor;
pub mod rca;
pub mod pipeline;
pub mod metrics;
pub mod runtime;
pub mod train;
pub mod soak;
pub mod coordinator;
