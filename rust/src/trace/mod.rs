//! Flight recorder: an always-on, bounded, cross-layer trace subsystem.
//!
//! The paper's observability pillar (§3.4) stops at per-connection bandwidth
//! windows; diagnosing a real anomaly needs the *order* of events across
//! layers — which WR stalled, which flow was re-rated, which pointer
//! migrated. This module records exactly that:
//!
//! - a global, **bounded ring buffer** of typed [`TraceEvent`]s, recorded
//!   behind a zero-cost-when-disabled [`Tracer`] handle that is threaded
//!   through `net::{flow,rdma}`, `fault`, `monitor` and `ccl::cluster`;
//! - **anomaly snapshots**: when the pinpointer flags a non-healthy verdict
//!   (or a failover migrates pointers) the recorder freezes the trailing
//!   window of events into a named [`Incident`], so the cause survives ring
//!   eviction even on long runs;
//! - two exporters — Chrome trace-event JSON ([`chrome`], loadable in
//!   `chrome://tracing` / [Perfetto](https://ui.perfetto.dev)) and a
//!   fixed-width incident timeline ([`timeline`]).
//!
//! Design constraints, in order:
//!
//! 1. **Zero cost when disabled.** A disabled `Tracer` holds no sink — no
//!    ring is allocated, every `record` call is one branch on an `Option`.
//!    Simulation behaviour is *never* affected either way: the recorder
//!    observes, it does not schedule.
//! 2. **Bounded.** The ring holds at most `trace.ring_capacity` records;
//!    older records are dropped (and counted). Incidents are capped at
//!    [`MAX_INCIDENTS`] and throttled to one per snapshot window.
//! 3. **Deterministic.** Records carry simulated time only; same config +
//!    seed ⇒ byte-identical exports (the tie-break sorting in
//!    `net::flow::FlowNet::reallocate` exists for this).

pub mod chrome;
pub mod diff;
pub mod timeline;

use std::collections::VecDeque;
use std::fmt;
use std::sync::{Arc, Mutex};

use crate::sim::SimTime;

/// Hard cap on frozen incidents per recorder (bounded-memory guarantee).
pub const MAX_INCIDENTS: usize = 16;

/// One typed cross-layer event. Variants carry plain ids (flow, QP, port
/// ordinal, connection, op) so records stay `Copy` and the ring stays flat.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TraceEvent {
    /// A `ClusterSim` attached to this recorder (marks timeline epochs when
    /// one `vccl trace` run drives several back-to-back simulations).
    SimStarted { nodes: usize, ranks: usize },
    /// A fluid flow entered the network (`net::flow`).
    FlowStarted { flow: u64, bytes: u64 },
    /// Max-min re-rate changed a flow's bandwidth by more than 10 %.
    FlowRerated { flow: u64, gbps: f64 },
    /// A flow's path lost a link: rate dropped to zero with bytes left.
    /// `link` names the first down link on the flow's path at stall time
    /// (`None` when the stall came from contention rather than a dead
    /// link) — the `rca` causal graph derives its Flow→Link→Port edges
    /// from it.
    FlowStalled { flow: u64, link: Option<usize> },
    /// A stalled data stream is moving again. `scope` names the id
    /// namespace of `flow`: `"flow"` — a net-layer flow whose link came
    /// back within the retry window (`flow` = flow id); `"xfer"` — a
    /// transfer whose rolled-back window was re-posted on the backup QP
    /// after failover (`flow` = transfer id).
    FlowResumed { flow: u64, scope: &'static str },
    /// A flow drained its last byte.
    FlowFinished { flow: u64 },
    /// A flow was killed (failover flushes the primary QP's flows).
    FlowKilled { flow: u64 },
    /// A link's capacity was changed at runtime (fault injection /
    /// degradation). `was_gbps` is the capacity being replaced, so a
    /// degrade (`gbps < was_gbps`) and its restoration are distinguishable
    /// without external state — the `rca` graph opens/closes degrade fault
    /// windows from exactly this pair.
    LinkCapacity { link: usize, gbps: f64, was_gbps: f64 },
    /// One incremental allocation pass (§Perf L3): the connected component
    /// the max-min water-fill walked, in flows and links. The Chrome
    /// exporter turns these into a counter track plus a component-size
    /// histogram. Reports the work *actually done*, so reference-mode
    /// (force-global) runs record the full net here by design — the only
    /// event kind whose payload legitimately differs between allocation
    /// modes (everything simulation-affecting stays bit-identical).
    AllocPass { flows: usize, links: usize },
    /// The proxy posted a send WR on a QP (`net::rdma`).
    WrPosted { qp: u64, port: usize, bytes: u64 },
    /// A WC was delivered: `status` ∈ success / retry-exceeded / flushed.
    WrCompleted { qp: u64, port: usize, bytes: u64, status: &'static str },
    /// A stalled QP armed the hardware retransmission window.
    QpRetryArmed { qp: u64, port: usize, deadline_ns: u64 },
    /// The retransmission window expired: the QP entered the error state.
    QpError { qp: u64, port: usize },
    /// RESET→RTS begun (VCCL's proactive reset); warm after `warm_ns`.
    QpReset { qp: u64, port: usize, warm_ns: u64 },
    /// Fault injection / perception: a NIC port went down or came back.
    PortDown { port: usize },
    PortUp { port: usize },
    /// §Fault domains: a switch entity (leaf or spine plane) went down /
    /// came back, cascading to its member links. `switch` is the fabric's
    /// switch id (leaves first, then spine planes).
    SwitchDown { switch: usize },
    SwitchUp { switch: usize },
    /// §Elastic: a whole server node crashed / recovered, cascading to
    /// every NIC port it owns. `node` is the fabric's node index.
    NodeDown { node: usize },
    NodeUp { node: usize },
    /// §Elastic: the communicator's rings were rebuilt (shrink on node
    /// death, expand on rejoin). `ranks` is the surviving membership each
    /// of the `channels` rebuilt rings now visits.
    RingRebuilt { channels: usize, ranks: usize },
    /// §Elastic: an in-flight op step crossing a dead node was aborted and
    /// re-issued on the rebuilt ring.
    OpRequeued { op: usize, channel: usize },
    /// §Fault domains: a spine trunk lost capacity (degrade) or was fully
    /// downed (`gbps == 0`). `switch` is the owning leaf switch — the RCA
    /// graph opens its trunk fault windows on that switch node, which is
    /// what makes trunk symptoms attribute to the switch, not a bare link.
    TrunkDegraded { link: usize, switch: usize, gbps: f64, was_gbps: f64 },
    TrunkRestored { link: usize, switch: usize, gbps: f64 },
    /// §Fault domains: a connection migrated to its backup-plane QP because
    /// its *path* died (dead trunk / leaf) while the endpoint port stayed
    /// up — path-death perception, distinct from the port-death failovers
    /// `PointerMigrated` records alone. `link` is the first dead link on
    /// the primary path at migration time.
    PathMigrated { conn: usize, xfer: u64, link: usize },
    /// §3.3 failover migrated both sides' pointers to the breakpoint.
    /// `xfer` is the transfer whose window rolled back (the `Xfer.seq`
    /// creation ordinal, joining to `FlowResumed { scope: "xfer" }`);
    /// `port` is the failed primary port's ordinal when known, so
    /// incidents frozen on a failover join to ground truth without
    /// string parsing.
    PointerMigrated {
        conn: usize,
        xfer: u64,
        port: Option<usize>,
        breakpoint: u64,
        rolled_back: u64,
    },
    /// Traffic returned to the (healed, warm) primary QP.
    Failback { conn: usize },
    /// A collective was submitted / finished (`ccl::collectives`). The
    /// finish event carries the op's §Perf L5 roll-up totals (transfers
    /// finished, payload bytes) — the per-transfer records are recycled by
    /// then, so the trace reads the fold, never retired `Xfer`s.
    OpSubmitted { op: usize, kind: &'static str, bytes: u64 },
    OpFinished { op: usize, xfers: u64, bytes: u64 },
    /// A connection bound a QP to a port at setup (`ccl::cluster::conn`).
    /// Recorded once per QP (primary and backup), these static bindings
    /// are what lets the `rca` graph walk Conn → QP → Port without
    /// consulting live simulator state.
    ConnBound { conn: usize, qp: u64, port: usize, backup: bool },
    /// A per-channel ring step began / completed.
    StepBegin { op: usize, channel: usize, step: usize },
    StepEnd { op: usize, channel: usize, step: usize },
    /// The pinpointer classified a windowed sample as non-healthy
    /// (`verdict` ∈ network-anomaly / non-network).
    MonitorVerdict { port: usize, verdict: &'static str, gbps: f64 },
}

impl TraceEvent {
    /// Stable event-kind name (used as the Chrome event name and in the
    /// timeline's event column).
    pub fn kind(&self) -> &'static str {
        match self {
            TraceEvent::SimStarted { .. } => "SimStarted",
            TraceEvent::FlowStarted { .. } => "FlowStarted",
            TraceEvent::FlowRerated { .. } => "FlowRerated",
            TraceEvent::FlowStalled { .. } => "FlowStalled",
            TraceEvent::FlowResumed { .. } => "FlowResumed",
            TraceEvent::FlowFinished { .. } => "FlowFinished",
            TraceEvent::FlowKilled { .. } => "FlowKilled",
            TraceEvent::LinkCapacity { .. } => "LinkCapacity",
            TraceEvent::AllocPass { .. } => "AllocPass",
            TraceEvent::WrPosted { .. } => "WrPosted",
            TraceEvent::WrCompleted { .. } => "WrCompleted",
            TraceEvent::QpRetryArmed { .. } => "QpRetryArmed",
            TraceEvent::QpError { .. } => "QpError",
            TraceEvent::QpReset { .. } => "QpReset",
            TraceEvent::PortDown { .. } => "PortDown",
            TraceEvent::PortUp { .. } => "PortUp",
            TraceEvent::SwitchDown { .. } => "SwitchDown",
            TraceEvent::SwitchUp { .. } => "SwitchUp",
            TraceEvent::NodeDown { .. } => "NodeDown",
            TraceEvent::NodeUp { .. } => "NodeUp",
            TraceEvent::RingRebuilt { .. } => "RingRebuilt",
            TraceEvent::OpRequeued { .. } => "OpRequeued",
            TraceEvent::TrunkDegraded { .. } => "TrunkDegraded",
            TraceEvent::TrunkRestored { .. } => "TrunkRestored",
            TraceEvent::PathMigrated { .. } => "PathMigrated",
            TraceEvent::PointerMigrated { .. } => "PointerMigrated",
            TraceEvent::Failback { .. } => "Failback",
            TraceEvent::OpSubmitted { .. } => "OpSubmitted",
            TraceEvent::OpFinished { .. } => "OpFinished",
            TraceEvent::ConnBound { .. } => "ConnBound",
            TraceEvent::StepBegin { .. } => "StepBegin",
            TraceEvent::StepEnd { .. } => "StepEnd",
            TraceEvent::MonitorVerdict { .. } => "MonitorVerdict",
        }
    }

    /// The layer the event was recorded from (timeline's layer column).
    pub fn layer(&self) -> &'static str {
        match self {
            TraceEvent::SimStarted { .. } => "sim",
            TraceEvent::FlowStarted { .. }
            | TraceEvent::FlowRerated { .. }
            | TraceEvent::FlowStalled { .. }
            | TraceEvent::FlowResumed { .. }
            | TraceEvent::FlowFinished { .. }
            | TraceEvent::FlowKilled { .. }
            | TraceEvent::LinkCapacity { .. }
            | TraceEvent::AllocPass { .. } => "net.flow",
            TraceEvent::WrPosted { .. }
            | TraceEvent::WrCompleted { .. }
            | TraceEvent::QpRetryArmed { .. }
            | TraceEvent::QpError { .. }
            | TraceEvent::QpReset { .. } => "net.rdma",
            TraceEvent::PortDown { .. }
            | TraceEvent::PortUp { .. }
            | TraceEvent::SwitchDown { .. }
            | TraceEvent::SwitchUp { .. }
            | TraceEvent::NodeDown { .. }
            | TraceEvent::NodeUp { .. }
            | TraceEvent::TrunkDegraded { .. }
            | TraceEvent::TrunkRestored { .. } => "fabric",
            TraceEvent::PointerMigrated { .. }
            | TraceEvent::Failback { .. }
            | TraceEvent::PathMigrated { .. } => "fault",
            TraceEvent::OpSubmitted { .. }
            | TraceEvent::OpFinished { .. }
            | TraceEvent::ConnBound { .. }
            | TraceEvent::StepBegin { .. }
            | TraceEvent::StepEnd { .. }
            | TraceEvent::RingRebuilt { .. }
            | TraceEvent::OpRequeued { .. } => "ccl",
            TraceEvent::MonitorVerdict { .. } => "monitor",
        }
    }

    /// Is this one of the causal-chain kinds the incident timeline keeps?
    pub fn is_key_event(&self) -> bool {
        matches!(
            self,
            TraceEvent::SimStarted { .. }
                | TraceEvent::FlowStalled { .. }
                | TraceEvent::FlowResumed { .. }
                | TraceEvent::QpRetryArmed { .. }
                | TraceEvent::QpError { .. }
                | TraceEvent::QpReset { .. }
                | TraceEvent::PortDown { .. }
                | TraceEvent::PortUp { .. }
                | TraceEvent::SwitchDown { .. }
                | TraceEvent::SwitchUp { .. }
                | TraceEvent::NodeDown { .. }
                | TraceEvent::NodeUp { .. }
                | TraceEvent::RingRebuilt { .. }
                | TraceEvent::OpRequeued { .. }
                | TraceEvent::TrunkDegraded { .. }
                | TraceEvent::TrunkRestored { .. }
                | TraceEvent::LinkCapacity { .. }
                | TraceEvent::PointerMigrated { .. }
                | TraceEvent::Failback { .. }
                | TraceEvent::PathMigrated { .. }
                | TraceEvent::MonitorVerdict { .. }
        )
    }
}

/// One ring entry: simulated timestamp + monotone sequence + payload.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub at: SimTime,
    pub seq: u64,
    pub ev: TraceEvent,
}

/// Cap on the in-flight transfers named per incident (bounded-memory).
pub const MAX_LIVE_XFERS: usize = 32;

/// One in-flight transfer at incident-freeze time: the §Perf L5 slab's
/// live view, snapshotted so a frozen incident names exactly which
/// transfers were still moving when the anomaly fired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LiveXfer {
    /// Stable creation ordinal (`Xfer.seq` — the id trace events use).
    pub seq: u64,
    pub op: usize,
    pub channel: usize,
    pub conn: usize,
    pub bytes: u64,
    /// Wire chunks acknowledged / total (progress at freeze time).
    pub chunks_done: u64,
    pub chunks_total: u64,
}

/// A frozen snapshot of the trailing event window, named after the anomaly
/// that triggered it.
#[derive(Debug, Clone)]
pub struct Incident {
    pub name: String,
    /// When the anomaly was flagged.
    pub at: SimTime,
    /// The anomaly event that triggered the freeze — structured metadata
    /// (port, conn, …) so consumers join incidents to ground truth without
    /// parsing `name`.
    pub trigger: TraceEvent,
    /// The trailing `trace.snapshot_window_ns` of ring records at that time.
    pub events: Vec<TraceRecord>,
    /// Transfers still in flight at freeze time, capped at
    /// [`MAX_LIVE_XFERS`] in ascending slot order. Filled by the cluster
    /// layer immediately after the freeze (the recorder itself has no slab
    /// access); empty until then and for non-cluster recorders.
    pub live_xfers: Vec<LiveXfer>,
    /// Total live transfers at freeze time (may exceed `live_xfers.len()`).
    pub live_total: u64,
}

impl Incident {
    /// The port ordinal the triggering anomaly names, if it names one.
    pub fn port(&self) -> Option<usize> {
        match self.trigger {
            TraceEvent::MonitorVerdict { port, .. }
            | TraceEvent::QpError { port, .. }
            | TraceEvent::QpRetryArmed { port, .. }
            | TraceEvent::QpReset { port, .. }
            | TraceEvent::WrPosted { port, .. }
            | TraceEvent::WrCompleted { port, .. }
            | TraceEvent::PortDown { port }
            | TraceEvent::PortUp { port } => Some(port),
            TraceEvent::PointerMigrated { port, .. } => port,
            _ => None,
        }
    }

    /// The connection the triggering anomaly names, if it names one.
    pub fn conn(&self) -> Option<usize> {
        match self.trigger {
            TraceEvent::PointerMigrated { conn, .. }
            | TraceEvent::Failback { conn }
            | TraceEvent::PathMigrated { conn, .. }
            | TraceEvent::ConnBound { conn, .. } => Some(conn),
            _ => None,
        }
    }

    /// The switch entity the triggering anomaly names, if it names one.
    pub fn switch(&self) -> Option<usize> {
        match self.trigger {
            TraceEvent::SwitchDown { switch }
            | TraceEvent::SwitchUp { switch }
            | TraceEvent::TrunkDegraded { switch, .. }
            | TraceEvent::TrunkRestored { switch, .. } => Some(switch),
            _ => None,
        }
    }

    /// The server node the triggering anomaly names, if it names one
    /// (§Elastic crash incidents).
    pub fn node(&self) -> Option<usize> {
        match self.trigger {
            TraceEvent::NodeDown { node } | TraceEvent::NodeUp { node } => Some(node),
            _ => None,
        }
    }
}

/// The recorder state behind a sink: bounded ring + incidents.
#[derive(Debug)]
struct Recorder {
    capacity: usize,
    snapshot_window_ns: u64,
    ring: VecDeque<TraceRecord>,
    seq: u64,
    dropped: u64,
    incidents: Vec<Incident>,
    /// Simulation epoch: bumped on every `SimStarted` record. One `vccl
    /// trace` run can drive several back-to-back simulations into the same
    /// sink, and each restarts its clock at 0 — so both the freeze
    /// throttle and the trailing-window cutoff must never compare
    /// timestamps across epochs.
    epoch: u64,
    /// Sequence number of the current epoch's first record.
    epoch_start_seq: u64,
    /// (epoch, time) of the last frozen incident.
    last_freeze: Option<(u64, SimTime)>,
    /// Incidents `[0, enriched)` have had their live-transfer view filled
    /// in by the cluster layer (`TraceSink::enrich_incidents`).
    enriched: usize,
}

impl Recorder {
    fn new(capacity: usize, snapshot_window_ns: u64) -> Self {
        Recorder {
            capacity: capacity.max(1),
            snapshot_window_ns,
            // Grows on demand up to `capacity` — an idle enabled recorder
            // costs (almost) nothing until events arrive.
            ring: VecDeque::new(),
            seq: 0,
            dropped: 0,
            incidents: Vec::new(),
            epoch: 0,
            epoch_start_seq: 0,
            last_freeze: None,
            enriched: 0,
        }
    }

    fn record(&mut self, at: SimTime, ev: TraceEvent) {
        if matches!(ev, TraceEvent::SimStarted { .. }) {
            self.epoch += 1;
            self.epoch_start_seq = self.seq;
        }
        if self.ring.len() >= self.capacity {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(TraceRecord { at, seq: self.seq, ev });
        self.seq += 1;
    }

    /// Freeze the trailing window into a named incident. Throttled: at most
    /// one incident per snapshot window within one simulation epoch (an
    /// anomaly usually flags many consecutive samples), at most
    /// [`MAX_INCIDENTS`] total. The window never reaches across a
    /// `SimStarted` boundary into an earlier simulation's events.
    fn freeze(&mut self, at: SimTime, trigger: TraceEvent, name: &str) {
        if self.incidents.len() >= MAX_INCIDENTS {
            return;
        }
        if let Some((epoch, last)) = self.last_freeze {
            if epoch == self.epoch && at.since(last).as_ns() < self.snapshot_window_ns {
                return;
            }
        }
        self.last_freeze = Some((self.epoch, at));
        let cutoff = at.as_ns().saturating_sub(self.snapshot_window_ns);
        let events: Vec<TraceRecord> = self
            .ring
            .iter()
            .filter(|r| r.seq >= self.epoch_start_seq && r.at.as_ns() >= cutoff)
            .copied()
            .collect();
        self.incidents.push(Incident {
            name: name.to_string(),
            at,
            trigger,
            events,
            live_xfers: Vec::new(),
            live_total: 0,
        });
    }
}

/// Shared handle to one recorder. Cloning shares the ring — this is how one
/// `vccl trace` invocation collects events from every simulation the
/// experiment builds. Uses `Arc<Mutex<_>>` so `Config` stays `Send`; the
/// simulator is single-threaded, so the lock is never contended.
#[derive(Clone)]
pub struct TraceSink(Arc<Mutex<Recorder>>);

impl TraceSink {
    pub fn new(ring_capacity: usize, snapshot_window_ns: u64) -> Self {
        TraceSink(Arc::new(Mutex::new(Recorder::new(ring_capacity, snapshot_window_ns))))
    }

    /// Snapshot of the ring contents, oldest first.
    pub fn records(&self) -> Vec<TraceRecord> {
        self.0.lock().unwrap().ring.iter().copied().collect()
    }

    pub fn incidents(&self) -> Vec<Incident> {
        self.0.lock().unwrap().incidents.clone()
    }

    /// Records evicted from the ring so far.
    pub fn dropped(&self) -> u64 {
        self.0.lock().unwrap().dropped
    }

    pub fn len(&self) -> usize {
        self.0.lock().unwrap().ring.len()
    }

    /// Incidents frozen so far (cheap: one counter read under the lock).
    pub fn incident_count(&self) -> usize {
        self.0.lock().unwrap().incidents.len()
    }

    /// Fill the live-transfer view of every not-yet-enriched incident.
    /// Called by the cluster layer right after event dispatch whenever new
    /// incidents appeared, while the §Perf L5 slab still holds the
    /// freeze-time state (single-threaded simulator ⇒ same sim time, so
    /// this is deterministic). `xfers` is truncated to [`MAX_LIVE_XFERS`].
    pub fn enrich_incidents(&self, live_total: u64, xfers: &[LiveXfer]) {
        let mut r = self.0.lock().unwrap();
        let upto = r.incidents.len();
        for i in r.enriched..upto {
            let inc = &mut r.incidents[i];
            inc.live_total = live_total;
            inc.live_xfers = xfers.iter().copied().take(MAX_LIVE_XFERS).collect();
        }
        r.enriched = upto;
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let r = self.0.lock().unwrap();
        write!(
            f,
            "TraceSink {{ events: {}, dropped: {}, incidents: {} }}",
            r.ring.len(),
            r.dropped,
            r.incidents.len()
        )
    }
}

/// The handle threaded through the stack. Disabled = no sink = no ring
/// allocation; every record call is a single `Option` branch.
#[derive(Debug, Clone, Default)]
pub struct Tracer {
    sink: Option<TraceSink>,
}

impl Tracer {
    /// The no-op tracer (the default everywhere tracing is off).
    pub fn disabled() -> Self {
        Tracer { sink: None }
    }

    /// A tracer recording into `sink`.
    pub fn attached(sink: TraceSink) -> Self {
        Tracer { sink: Some(sink) }
    }

    /// Resolve from config: an installed shared sink wins (the `vccl trace`
    /// path), else a fresh private recorder when `trace.enabled`, else off.
    pub fn from_config(cfg: &crate::config::TraceConfig) -> Self {
        if let Some(sink) = &cfg.sink {
            Tracer::attached(sink.clone())
        } else if cfg.enabled {
            Tracer::attached(TraceSink::new(cfg.ring_capacity, cfg.snapshot_window_ns))
        } else {
            Tracer::disabled()
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    pub fn sink(&self) -> Option<&TraceSink> {
        self.sink.as_ref()
    }

    /// Record one event at simulated time `at`.
    #[inline]
    pub fn record(&self, at: SimTime, ev: TraceEvent) {
        if let Some(sink) = &self.sink {
            sink.0.lock().unwrap().record(at, ev);
        }
    }

    /// Record an anomaly event AND freeze the trailing window into a named
    /// incident (throttled: at most one incident per snapshot window, at
    /// most [`MAX_INCIDENTS`] total). Callers building the name with
    /// `format!` should gate on [`Tracer::enabled`] first.
    pub fn record_anomaly(&self, at: SimTime, ev: TraceEvent, name: &str) {
        if let Some(sink) = &self.sink {
            let mut r = sink.0.lock().unwrap();
            r.record(at, ev);
            r.freeze(at, ev, name);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing_and_holds_no_sink() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        assert!(t.sink().is_none());
        // Recording through a disabled tracer is a no-op (and must not
        // panic or allocate a ring).
        t.record(SimTime::ns(1), TraceEvent::PortDown { port: 0 });
        t.record_anomaly(SimTime::ns(2), TraceEvent::PortUp { port: 0 }, "x");
        assert!(t.sink().is_none());
    }

    #[test]
    fn ring_is_bounded_and_counts_drops() {
        let sink = TraceSink::new(4, 1_000);
        let t = Tracer::attached(sink.clone());
        for i in 0..10u64 {
            t.record(SimTime::ns(i), TraceEvent::FlowStarted { flow: i, bytes: 1 });
        }
        assert_eq!(sink.len(), 4);
        assert_eq!(sink.dropped(), 6);
        let recs = sink.records();
        // Oldest evicted: the survivors are the last four, seq monotone.
        assert_eq!(recs.len(), 4);
        assert!(recs.windows(2).all(|w| w[0].seq < w[1].seq));
        assert_eq!(recs[0].seq, 6);
        assert_eq!(recs[3].seq, 9);
    }

    #[test]
    fn incident_freezes_trailing_window_only() {
        let sink = TraceSink::new(1024, 100); // 100ns snapshot window
        let t = Tracer::attached(sink.clone());
        t.record(SimTime::ns(10), TraceEvent::PortDown { port: 3 });
        t.record(SimTime::ns(500), TraceEvent::FlowStalled { flow: 1, link: Some(6) });
        t.record_anomaly(
            SimTime::ns(550),
            TraceEvent::MonitorVerdict { port: 3, verdict: "network-anomaly", gbps: 12.0 },
            "verdict-port3",
        );
        let incs = sink.incidents();
        assert_eq!(incs.len(), 1);
        assert_eq!(incs[0].name, "verdict-port3");
        // The 10ns PortDown is outside the 100ns trailing window.
        assert_eq!(incs[0].events.len(), 2);
        assert!(incs[0].events.iter().all(|r| r.at.as_ns() >= 450));
        // Structured trigger metadata: the port joins without name parsing.
        assert_eq!(incs[0].port(), Some(3));
        assert_eq!(incs[0].conn(), None);
        assert_eq!(incs[0].trigger.kind(), "MonitorVerdict");
    }

    #[test]
    fn incident_enrichment_fills_live_xfers_once() {
        let sink = TraceSink::new(64, 100);
        let t = Tracer::attached(sink.clone());
        t.record_anomaly(
            SimTime::ns(100),
            TraceEvent::PointerMigrated {
                conn: 2,
                xfer: 7,
                port: Some(1),
                breakpoint: 3,
                rolled_back: 1,
            },
            "failover-conn2-port1",
        );
        assert_eq!(sink.incident_count(), 1);
        let lx = LiveXfer {
            seq: 7,
            op: 0,
            channel: 0,
            conn: 2,
            bytes: 1 << 20,
            chunks_done: 3,
            chunks_total: 8,
        };
        sink.enrich_incidents(5, &[lx]);
        let incs = sink.incidents();
        assert_eq!(incs[0].live_total, 5);
        assert_eq!(incs[0].live_xfers, vec![lx]);
        assert_eq!(incs[0].port(), Some(1));
        assert_eq!(incs[0].conn(), Some(2));
        // A second enrichment pass must not touch already-enriched ones.
        sink.enrich_incidents(0, &[]);
        assert_eq!(sink.incidents()[0].live_total, 5);
        // The per-incident list is bounded even if the slab holds more.
        let many: Vec<LiveXfer> =
            (0..2 * MAX_LIVE_XFERS as u64).map(|i| LiveXfer { seq: i, ..lx }).collect();
        t.record_anomaly(SimTime::ns(10_000), TraceEvent::PortDown { port: 0 }, "p0");
        sink.enrich_incidents(many.len() as u64, &many);
        let incs = sink.incidents();
        assert_eq!(incs[1].live_xfers.len(), MAX_LIVE_XFERS);
        assert_eq!(incs[1].live_total, 2 * MAX_LIVE_XFERS as u64);
    }

    #[test]
    fn incidents_throttled_and_capped() {
        let sink = TraceSink::new(64, 1_000);
        let t = Tracer::attached(sink.clone());
        // Two anomalies inside one window → one incident.
        t.record_anomaly(SimTime::ns(100), TraceEvent::PortDown { port: 0 }, "a");
        t.record_anomaly(SimTime::ns(200), TraceEvent::PortDown { port: 0 }, "b");
        assert_eq!(sink.incidents().len(), 1);
        // Far-apart anomalies accumulate, but never beyond MAX_INCIDENTS.
        for i in 0..(MAX_INCIDENTS as u64 + 8) {
            t.record_anomaly(
                SimTime::ns(10_000 + i * 10_000),
                TraceEvent::PortDown { port: 0 },
                "more",
            );
        }
        assert_eq!(sink.incidents().len(), MAX_INCIDENTS);
    }

    #[test]
    fn sim_epochs_isolate_throttle_and_window() {
        let sink = TraceSink::new(1024, 1_000_000);
        let t = Tracer::attached(sink.clone());
        // Sim 1: anomaly late in its timeline.
        t.record(SimTime::ZERO, TraceEvent::SimStarted { nodes: 1, ranks: 8 });
        t.record(SimTime::ms(11), TraceEvent::PortDown { port: 0 });
        t.record_anomaly(SimTime::ms(11), TraceEvent::QpError { qp: 0, port: 0 }, "sim1");
        assert_eq!(sink.incidents().len(), 1);
        // Sim 2 restarts the clock at 0: its anomaly must NOT be throttled
        // by sim 1's (clock went backwards), and its snapshot must not
        // reach back into sim 1's events.
        t.record(SimTime::ZERO, TraceEvent::SimStarted { nodes: 1, ranks: 8 });
        t.record(SimTime::us(10), TraceEvent::PortDown { port: 3 });
        t.record_anomaly(SimTime::us(20), TraceEvent::QpError { qp: 1, port: 3 }, "sim2");
        let incs = sink.incidents();
        assert_eq!(incs.len(), 2, "sim 2's incident must not be throttled away");
        assert_eq!(incs[1].name, "sim2");
        assert!(
            incs[1].events.iter().all(|r| !matches!(r.ev, TraceEvent::QpError { qp: 0, .. })),
            "sim 2's snapshot must not contain sim 1's events"
        );
        assert!(incs[1].events.iter().any(|r| matches!(r.ev, TraceEvent::PortDown { port: 3 })));
    }

    #[test]
    fn clones_share_one_ring() {
        let sink = TraceSink::new(16, 1_000);
        let a = Tracer::attached(sink.clone());
        let b = a.clone();
        a.record(SimTime::ns(1), TraceEvent::PortDown { port: 0 });
        b.record(SimTime::ns(2), TraceEvent::PortUp { port: 0 });
        assert_eq!(sink.len(), 2);
        let recs = sink.records();
        assert_eq!(recs[0].ev.kind(), "PortDown");
        assert_eq!(recs[1].ev.kind(), "PortUp");
    }

    #[test]
    fn kinds_and_layers_are_stable() {
        let ev = TraceEvent::PointerMigrated {
            conn: 1,
            xfer: 9,
            port: Some(0),
            breakpoint: 5,
            rolled_back: 3,
        };
        assert_eq!(ev.kind(), "PointerMigrated");
        assert_eq!(ev.layer(), "fault");
        assert!(ev.is_key_event());
        let ev = TraceEvent::WrPosted { qp: 0, port: 0, bytes: 1 };
        assert_eq!(ev.layer(), "net.rdma");
        assert!(!ev.is_key_event());
        let ev = TraceEvent::ConnBound { conn: 0, qp: 4, port: 2, backup: true };
        assert_eq!(ev.kind(), "ConnBound");
        assert_eq!(ev.layer(), "ccl");
        assert!(!ev.is_key_event());
        let ev = TraceEvent::LinkCapacity { link: 2, gbps: 50.0, was_gbps: 400.0 };
        assert_eq!(ev.kind(), "LinkCapacity");
        assert_eq!(ev.layer(), "net.flow");
        assert!(ev.is_key_event());
        let ev = TraceEvent::SwitchDown { switch: 5 };
        assert_eq!(ev.kind(), "SwitchDown");
        assert_eq!(ev.layer(), "fabric");
        assert!(ev.is_key_event());
        let ev = TraceEvent::TrunkDegraded { link: 70, switch: 3, gbps: 100.0, was_gbps: 800.0 };
        assert_eq!(ev.kind(), "TrunkDegraded");
        assert_eq!(ev.layer(), "fabric");
        assert!(ev.is_key_event());
        let ev = TraceEvent::TrunkRestored { link: 70, switch: 3, gbps: 800.0 };
        assert_eq!(ev.kind(), "TrunkRestored");
        assert_eq!(ev.layer(), "fabric");
        let ev = TraceEvent::PathMigrated { conn: 4, xfer: 11, link: 70 };
        assert_eq!(ev.kind(), "PathMigrated");
        assert_eq!(ev.layer(), "fault");
        assert!(ev.is_key_event());
    }

    #[test]
    fn elastic_kinds_and_node_metadata() {
        let ev = TraceEvent::NodeDown { node: 1 };
        assert_eq!(ev.kind(), "NodeDown");
        assert_eq!(ev.layer(), "fabric");
        assert!(ev.is_key_event());
        let ev = TraceEvent::RingRebuilt { channels: 2, ranks: 24 };
        assert_eq!(ev.kind(), "RingRebuilt");
        assert_eq!(ev.layer(), "ccl");
        assert!(ev.is_key_event());
        let ev = TraceEvent::OpRequeued { op: 0, channel: 1 };
        assert_eq!(ev.kind(), "OpRequeued");
        assert_eq!(ev.layer(), "ccl");
        assert!(ev.is_key_event());

        let sink = TraceSink::new(64, 1_000);
        let t = Tracer::attached(sink.clone());
        t.record_anomaly(SimTime::ns(100), TraceEvent::NodeDown { node: 1 }, "node1-crash");
        let incs = sink.incidents();
        assert_eq!(incs[0].node(), Some(1));
        assert_eq!(incs[0].port(), None);
        assert_eq!(incs[0].switch(), None);
    }

    #[test]
    fn incident_switch_metadata_joins_fault_domains() {
        let sink = TraceSink::new(64, 1_000);
        let t = Tracer::attached(sink.clone());
        t.record_anomaly(
            SimTime::ns(100),
            TraceEvent::TrunkDegraded { link: 70, switch: 3, gbps: 0.0, was_gbps: 800.0 },
            "trunk-link70",
        );
        t.record_anomaly(
            SimTime::ns(10_000),
            TraceEvent::PathMigrated { conn: 4, xfer: 11, link: 70 },
            "pathmig-conn4",
        );
        let incs = sink.incidents();
        assert_eq!(incs[0].switch(), Some(3));
        assert_eq!(incs[0].port(), None);
        assert_eq!(incs[1].conn(), Some(4));
        assert_eq!(incs[1].switch(), None);
    }
}
