//! Trace diffing: compare two trace exports / incident timelines.
//!
//! `vccl trace <id> --diff` runs an experiment twice into two fresh sinks
//! and renders the delta — the executable witness of the determinism
//! contract (same config + seed ⇒ identical event streams), and the tool
//! for comparing a healthy run against an incident snapshot. The
//! comparison is structural, not textual:
//!
//! - **event-set delta**: per-kind record counts on each side, with the
//!   first diverging record (by ring position) pinpointed;
//! - **`AllocPass` component histogram** comparison: the §Perf L3
//!   "how local are reallocations?" buckets, side by side with deltas;
//! - **incident-set delta**: frozen incidents by name/trigger/port.
//!
//! Everything here is a pure function over `&[TraceRecord]` — no sinks, no
//! locks — so the output is deterministic and bit-identity testable.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::metrics::Table;

use super::{Incident, TraceEvent, TraceRecord};

/// Per-component-size histogram of `AllocPass` records (§Perf L3): bucket
/// upper bounds 1, 2, 4, 8, 16, 32, 64, ∞ over the pass's flow count —
/// the same bucketing the Chrome exporter's summary event uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct AllocHistogram {
    pub passes: u64,
    pub buckets: [u64; 8],
}

/// Bucket labels, index-aligned with [`AllocHistogram::buckets`].
pub const ALLOC_BUCKET_LABELS: [&str; 8] =
    ["<=1", "<=2", "<=4", "<=8", "<=16", "<=32", "<=64", ">64"];

/// Fold every `AllocPass` in `records` into the component-size histogram.
pub fn alloc_histogram(records: &[TraceRecord]) -> AllocHistogram {
    let mut h = AllocHistogram::default();
    for r in records {
        if let TraceEvent::AllocPass { flows, .. } = r.ev {
            h.passes += 1;
            let b = match flows {
                0 | 1 => 0,
                2 => 1,
                3..=4 => 2,
                5..=8 => 3,
                9..=16 => 4,
                17..=32 => 5,
                33..=64 => 6,
                _ => 7,
            };
            h.buckets[b] += 1;
        }
    }
    h
}

/// The structural delta between two record streams.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceDiff {
    pub total_a: usize,
    pub total_b: usize,
    /// kind → (count in A, count in B); keys sorted (BTreeMap) for
    /// deterministic rendering.
    pub kinds: BTreeMap<&'static str, (u64, u64)>,
    /// Ring position and (kind_a, kind_b) of the first record where the
    /// two streams disagree on (time, event); `None` when one stream is a
    /// prefix of the other (or they are identical).
    pub first_divergence: Option<(usize, String, String)>,
    pub alloc_a: AllocHistogram,
    pub alloc_b: AllocHistogram,
}

impl TraceDiff {
    /// No difference at all (the determinism-witness verdict).
    pub fn identical(&self) -> bool {
        self.total_a == self.total_b
            && self.first_divergence.is_none()
            && self.kinds.values().all(|(a, b)| a == b)
    }
}

/// Compare two record streams (ring order). Timestamps and payloads both
/// count: two streams diverge at the first position where either differs.
/// `seq` is deliberately ignored — a resumed run restarts its counter, and
/// the contract is about *events*, not bookkeeping.
pub fn diff_records(a: &[TraceRecord], b: &[TraceRecord]) -> TraceDiff {
    let mut kinds: BTreeMap<&'static str, (u64, u64)> = BTreeMap::new();
    for r in a {
        kinds.entry(r.ev.kind()).or_default().0 += 1;
    }
    for r in b {
        kinds.entry(r.ev.kind()).or_default().1 += 1;
    }
    let first_divergence = a
        .iter()
        .zip(b.iter())
        .position(|(x, y)| x.at != y.at || x.ev != y.ev)
        .map(|i| (i, a[i].ev.kind().to_string(), b[i].ev.kind().to_string()));
    TraceDiff {
        total_a: a.len(),
        total_b: b.len(),
        kinds,
        first_divergence,
        alloc_a: alloc_histogram(a),
        alloc_b: alloc_histogram(b),
    }
}

/// Render the fixed-width diff report (the `vccl trace --diff` body).
pub fn render(d: &TraceDiff, label_a: &str, label_b: &str) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "trace diff — {label_a}: {} record(s), {label_b}: {} record(s)",
        d.total_a, d.total_b
    );
    if d.identical() {
        let _ = writeln!(
            out,
            "verdict: IDENTICAL event streams (determinism contract holds)\n"
        );
    } else {
        match &d.first_divergence {
            Some((i, ka, kb)) => {
                let _ = writeln!(
                    out,
                    "verdict: DIVERGED at record {i} ({label_a}: {ka}, {label_b}: {kb})\n"
                );
            }
            None => {
                let _ = writeln!(
                    out,
                    "verdict: one stream is a prefix of the other \
                     (lengths {} vs {})\n",
                    d.total_a, d.total_b
                );
            }
        }
    }
    let mut t = Table::new(vec!["event kind", label_a, label_b, "delta"]);
    for (kind, (na, nb)) in &d.kinds {
        let delta = *nb as i64 - *na as i64;
        t.row(vec![
            kind.to_string(),
            na.to_string(),
            nb.to_string(),
            if delta == 0 { "0".to_string() } else { format!("{delta:+}") },
        ]);
    }
    out.push_str(&t.render());
    // §Perf L3 component-size histogram, side by side.
    if d.alloc_a.passes > 0 || d.alloc_b.passes > 0 {
        let _ = writeln!(
            out,
            "\nAllocPass component histogram — {label_a}: {} pass(es), {label_b}: {} pass(es):\n",
            d.alloc_a.passes, d.alloc_b.passes
        );
        let mut t = Table::new(vec!["component flows", label_a, label_b, "delta"]);
        for (i, label) in ALLOC_BUCKET_LABELS.iter().enumerate() {
            let (na, nb) = (d.alloc_a.buckets[i], d.alloc_b.buckets[i]);
            let delta = nb as i64 - na as i64;
            t.row(vec![
                label.to_string(),
                na.to_string(),
                nb.to_string(),
                if delta == 0 { "0".to_string() } else { format!("{delta:+}") },
            ]);
        }
        out.push_str(&t.render());
    }
    out
}

/// Render the incident-set comparison: name, trigger kind, port and event
/// count per side, joined structurally via [`Incident::port`] — never by
/// parsing names.
pub fn render_incidents(a: &[Incident], b: &[Incident], label_a: &str, label_b: &str) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "incidents — {label_a}: {}, {label_b}: {}:\n",
        a.len(),
        b.len()
    );
    if a.is_empty() && b.is_empty() {
        let _ = writeln!(out, "(none on either side)");
        return out;
    }
    let mut t = Table::new(vec!["side", "incident", "trigger", "port", "events", "in flight"]);
    for (side, incs) in [(label_a, a), (label_b, b)] {
        for inc in incs {
            t.row(vec![
                side.to_string(),
                inc.name.clone(),
                inc.trigger.kind().to_string(),
                inc.port().map_or_else(|| "-".to_string(), |p| p.to_string()),
                inc.events.len().to_string(),
                inc.live_total.to_string(),
            ]);
        }
    }
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn rec(ns: u64, seq: u64, ev: TraceEvent) -> TraceRecord {
        TraceRecord { at: SimTime::ns(ns), seq, ev }
    }

    fn sample() -> Vec<TraceRecord> {
        vec![
            rec(0, 0, TraceEvent::SimStarted { nodes: 2, ranks: 16 }),
            rec(10, 1, TraceEvent::AllocPass { flows: 1, links: 2 }),
            rec(20, 2, TraceEvent::AllocPass { flows: 12, links: 8 }),
            rec(30, 3, TraceEvent::PortDown { port: 1 }),
            rec(40, 4, TraceEvent::FlowStalled { flow: 3, link: Some(2) }),
        ]
    }

    #[test]
    fn identical_streams_diff_to_zero() {
        let a = sample();
        let d = diff_records(&a, &a);
        assert!(d.identical());
        assert!(d.first_divergence.is_none());
        assert!(d.kinds.values().all(|(x, y)| x == y));
        let s = render(&d, "run A", "run B");
        assert!(s.contains("IDENTICAL"), "{s}");
        assert!(s.contains("AllocPass component histogram"), "{s}");
    }

    #[test]
    fn divergence_is_pinpointed() {
        let a = sample();
        let mut b = sample();
        // Same kind, different payload: still a divergence.
        b[3] = rec(30, 3, TraceEvent::PortDown { port: 5 });
        let d = diff_records(&a, &b);
        assert!(!d.identical());
        assert_eq!(
            d.first_divergence,
            Some((3, "PortDown".to_string(), "PortDown".to_string()))
        );
        let s = render(&d, "a", "b");
        assert!(s.contains("DIVERGED at record 3"), "{s}");
        // Counts per kind still match here (payload-only divergence).
        assert_eq!(d.kinds["PortDown"], (1, 1));
    }

    #[test]
    fn seq_numbers_do_not_count_as_divergence() {
        // A resumed run restarts its seq counter; events are what matter.
        let a = sample();
        let b: Vec<TraceRecord> =
            a.iter().map(|r| TraceRecord { seq: r.seq + 100, ..*r }).collect();
        assert!(diff_records(&a, &b).identical());
    }

    #[test]
    fn prefix_streams_report_missing_tail() {
        let a = sample();
        let b = a[..3].to_vec();
        let d = diff_records(&a, &b);
        assert!(!d.identical());
        assert!(d.first_divergence.is_none());
        assert_eq!(d.kinds["FlowStalled"], (1, 0));
        let s = render(&d, "a", "b");
        assert!(s.contains("prefix"), "{s}");
        assert!(s.contains("-1"), "{s}");
    }

    #[test]
    fn alloc_histograms_bucket_like_chrome() {
        let h = alloc_histogram(&sample());
        assert_eq!(h.passes, 2);
        assert_eq!(h.buckets[0], 1); // flows=1
        assert_eq!(h.buckets[4], 1); // flows=12 → ≤16
    }

    #[test]
    fn render_is_deterministic() {
        let a = sample();
        let mut b = sample();
        b.pop();
        let d = diff_records(&a, &b);
        assert_eq!(render(&d, "x", "y"), render(&d, "x", "y"));
    }

    #[test]
    fn incident_comparison_uses_structured_port() {
        let inc = Incident {
            name: "network-anomaly-port7".to_string(),
            at: SimTime::ms(4),
            trigger: TraceEvent::MonitorVerdict {
                port: 7,
                verdict: "network-anomaly",
                gbps: 11.0,
            },
            events: vec![rec(0, 0, TraceEvent::PortDown { port: 7 })],
            live_xfers: Vec::new(),
            live_total: 2,
        };
        let s = render_incidents(&[inc], &[], "a", "b");
        assert!(s.contains("MonitorVerdict"), "{s}");
        assert!(s.contains("| 7 "), "{s}");
        let s = render_incidents(&[], &[], "a", "b");
        assert!(s.contains("none on either side"), "{s}");
    }
}
