//! Chrome trace-event JSON exporter (plus a small JSON syntax checker the
//! tests and CI smoke use to validate the emitted file).
//!
//! The output is the ["JSON Object Format"] of the Trace Event spec:
//! `{"traceEvents": [...], "displayTimeUnit": "ms"}`. Load it in
//! `chrome://tracing` or drop it onto <https://ui.perfetto.dev>. Mapping:
//!
//! - **pid** = node (derived from the port ordinal) for port/QP/monitor
//!   events; pseudo-processes for the port-less layers (`net.flow`,
//!   `ccl`, `fault`, `sim`).
//! - **tid** = the lane inside the process: port ordinal, flow id, op id,
//!   connection id. Collective steps get a per-(op, channel) lane so their
//!   spans nest correctly.
//! - flow lifetimes and collective-step durations are **span pairs**
//!   (`"ph": "B"`/`"E"`: `FlowStarted` opens a `Flow` span that
//!   `FlowFinished`/`FlowKilled` closes; `StepBegin`/`StepEnd` bracket a
//!   `Step` span), so chrome://tracing renders them as bars with real
//!   durations. `AllocPass` records become a `"ph": "C"` counter track
//!   (component size over time) plus one summary histogram event. Every
//!   other record is an instant event (`"ph": "i"`, thread-scoped);
//!   `"ph": "M"` metadata events name the processes.
//!
//! Timestamps are simulated microseconds (the spec's unit), so exports are
//! byte-identical across runs at the same config + seed.
//!
//! ["JSON Object Format"]:
//! https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU
//!
//! The JSON itself reuses the hand-rolled emitter from [`crate::metrics`]
//! (`json_string` / `json_number`) — no serde in the offline build.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::metrics::{json_number, json_string};

use super::{TraceEvent, TraceRecord};

/// Pseudo-pids for layers that have no node: kept far above any real node
/// index so they never collide.
const PID_NET: usize = 9000;
const PID_CCL: usize = 9001;
const PID_FAULT: usize = 9002;
const PID_SIM: usize = 9003;
const PID_FABRIC: usize = 9004;

/// Topology facts the exporter needs to map a port ordinal to its node.
#[derive(Debug, Clone, Copy)]
pub struct ChromeMeta {
    /// NIC ports per node (`nics_per_node × ports_per_nic`).
    pub ports_per_node: usize,
}

/// The (pid, tid) lane of one event.
fn lane(ev: &TraceEvent, meta: &ChromeMeta) -> (usize, u64) {
    let node_of = |port: usize| port / meta.ports_per_node.max(1);
    match *ev {
        TraceEvent::SimStarted { .. } => (PID_SIM, 0),
        TraceEvent::FlowStarted { flow, .. }
        | TraceEvent::FlowRerated { flow, .. }
        | TraceEvent::FlowStalled { flow, .. }
        | TraceEvent::FlowFinished { flow }
        | TraceEvent::FlowKilled { flow } => (PID_NET, flow),
        // One counter lane for the whole allocator.
        TraceEvent::AllocPass { .. } => (PID_NET, 0),
        // Capacity changes live on the link's lane of the net process.
        TraceEvent::LinkCapacity { link, .. } => (PID_NET, link as u64),
        // A failover resume carries a TRANSFER id, not a net-flow id — it
        // belongs on the fault process next to the pointer migration, not
        // on some unrelated flow's lane.
        TraceEvent::FlowResumed { flow, scope } => {
            if scope == "xfer" { (PID_FAULT, flow) } else { (PID_NET, flow) }
        }
        TraceEvent::WrPosted { port, .. }
        | TraceEvent::WrCompleted { port, .. }
        | TraceEvent::QpRetryArmed { port, .. }
        | TraceEvent::QpError { port, .. }
        | TraceEvent::QpReset { port, .. }
        | TraceEvent::PortDown { port }
        | TraceEvent::PortUp { port }
        // A conn's QP↔port binding renders on the port's lane: reading a
        // port row shows which QPs it carries.
        | TraceEvent::ConnBound { port, .. }
        | TraceEvent::MonitorVerdict { port, .. } => (node_of(port), port as u64),
        TraceEvent::PointerMigrated { conn, .. }
        | TraceEvent::Failback { conn }
        | TraceEvent::PathMigrated { conn, .. } => (PID_FAULT, conn as u64),
        // Switch-entity lanes: one row per switch; trunk capacity events on
        // the trunk link's lane of the same process.
        TraceEvent::SwitchDown { switch } | TraceEvent::SwitchUp { switch } => {
            (PID_FABRIC, switch as u64)
        }
        TraceEvent::TrunkDegraded { link, .. } | TraceEvent::TrunkRestored { link, .. } => {
            (PID_FABRIC, link as u64)
        }
        // Node-entity lanes sit above the switch/link tid space so a node's
        // crash row never merges with switch 0's.
        TraceEvent::NodeDown { node } | TraceEvent::NodeUp { node } => {
            (PID_FABRIC, (1u64 << 32) | node as u64)
        }
        TraceEvent::RingRebuilt { .. } => (PID_CCL, u64::MAX),
        TraceEvent::OpRequeued { op, channel } => {
            (PID_CCL, ((op as u64) << 16) | channel as u64)
        }
        TraceEvent::OpSubmitted { op, .. } | TraceEvent::OpFinished { op, .. } => {
            (PID_CCL, op as u64)
        }
        // Steps of the same op run concurrently across channels; give each
        // (op, channel) its own lane so the B/E spans nest correctly
        // (within one channel, steps are strictly sequential).
        TraceEvent::StepBegin { op, channel, .. } | TraceEvent::StepEnd { op, channel, .. } => {
            (PID_CCL, ((op as u64) << 16) | channel as u64)
        }
    }
}

/// Trace-event phase of one record: span begin/end for flow lifetimes and
/// collective steps, a counter sample for allocator passes, instant else.
fn phase(ev: &TraceEvent) -> &'static str {
    match ev {
        TraceEvent::FlowStarted { .. } | TraceEvent::StepBegin { .. } => "B",
        TraceEvent::FlowFinished { .. }
        | TraceEvent::FlowKilled { .. }
        | TraceEvent::StepEnd { .. } => "E",
        TraceEvent::AllocPass { .. } => "C",
        _ => "i",
    }
}

/// Display name: span pairs must share one name per lane so the viewer
/// matches B to E; everything else keeps its event kind.
fn display_name(ev: &TraceEvent) -> &'static str {
    match ev {
        TraceEvent::FlowStarted { .. }
        | TraceEvent::FlowFinished { .. }
        | TraceEvent::FlowKilled { .. } => "Flow",
        TraceEvent::StepBegin { .. } | TraceEvent::StepEnd { .. } => "Step",
        TraceEvent::AllocPass { .. } => "alloc.component",
        other => other.kind(),
    }
}

/// The `"args"` object for one event.
fn args_json(ev: &TraceEvent) -> String {
    match *ev {
        TraceEvent::SimStarted { nodes, ranks } => {
            format!("{{\"nodes\": {nodes}, \"ranks\": {ranks}}}")
        }
        TraceEvent::FlowStarted { flow, bytes } => {
            format!("{{\"flow\": {flow}, \"bytes\": {bytes}}}")
        }
        TraceEvent::FlowRerated { flow, gbps } => {
            format!("{{\"flow\": {flow}, \"gbps\": {}}}", json_number(gbps))
        }
        TraceEvent::FlowStalled { flow, link } => match link {
            Some(l) => format!("{{\"flow\": {flow}, \"link\": {l}}}"),
            None => format!("{{\"flow\": {flow}, \"link\": null}}"),
        },
        TraceEvent::FlowFinished { flow } | TraceEvent::FlowKilled { flow } => {
            format!("{{\"flow\": {flow}}}")
        }
        TraceEvent::LinkCapacity { link, gbps, was_gbps } => format!(
            "{{\"link\": {link}, \"gbps\": {}, \"was_gbps\": {}}}",
            json_number(gbps),
            json_number(was_gbps)
        ),
        TraceEvent::AllocPass { flows, links } => {
            format!("{{\"flows\": {flows}, \"links\": {links}}}")
        }
        TraceEvent::FlowResumed { flow, scope } => {
            format!("{{\"flow\": {flow}, \"scope\": {}}}", json_string(scope))
        }
        TraceEvent::WrPosted { qp, port, bytes } => {
            format!("{{\"qp\": {qp}, \"port\": {port}, \"bytes\": {bytes}}}")
        }
        TraceEvent::WrCompleted { qp, port, bytes, status } => format!(
            "{{\"qp\": {qp}, \"port\": {port}, \"bytes\": {bytes}, \"status\": {}}}",
            json_string(status)
        ),
        TraceEvent::QpRetryArmed { qp, port, deadline_ns } => {
            format!("{{\"qp\": {qp}, \"port\": {port}, \"deadline_ns\": {deadline_ns}}}")
        }
        TraceEvent::QpError { qp, port } => format!("{{\"qp\": {qp}, \"port\": {port}}}"),
        TraceEvent::QpReset { qp, port, warm_ns } => {
            format!("{{\"qp\": {qp}, \"port\": {port}, \"warm_ns\": {warm_ns}}}")
        }
        TraceEvent::PortDown { port } | TraceEvent::PortUp { port } => {
            format!("{{\"port\": {port}}}")
        }
        TraceEvent::SwitchDown { switch } | TraceEvent::SwitchUp { switch } => {
            format!("{{\"switch\": {switch}}}")
        }
        TraceEvent::NodeDown { node } | TraceEvent::NodeUp { node } => {
            format!("{{\"node\": {node}}}")
        }
        TraceEvent::RingRebuilt { channels, ranks } => {
            format!("{{\"channels\": {channels}, \"ranks\": {ranks}}}")
        }
        TraceEvent::OpRequeued { op, channel } => {
            format!("{{\"op\": {op}, \"channel\": {channel}}}")
        }
        TraceEvent::TrunkDegraded { link, switch, gbps, was_gbps } => format!(
            "{{\"link\": {link}, \"switch\": {switch}, \"gbps\": {}, \"was_gbps\": {}}}",
            json_number(gbps),
            json_number(was_gbps)
        ),
        TraceEvent::TrunkRestored { link, switch, gbps } => format!(
            "{{\"link\": {link}, \"switch\": {switch}, \"gbps\": {}}}",
            json_number(gbps)
        ),
        TraceEvent::PathMigrated { conn, xfer, link } => {
            format!("{{\"conn\": {conn}, \"xfer\": {xfer}, \"link\": {link}}}")
        }
        TraceEvent::PointerMigrated { conn, xfer, port, breakpoint, rolled_back } => {
            let port = match port {
                Some(p) => p.to_string(),
                None => "null".to_string(),
            };
            format!(
                "{{\"conn\": {conn}, \"xfer\": {xfer}, \"port\": {port}, \
                 \"breakpoint\": {breakpoint}, \"rolled_back\": {rolled_back}}}"
            )
        }
        TraceEvent::Failback { conn } => format!("{{\"conn\": {conn}}}"),
        TraceEvent::OpSubmitted { op, kind, bytes } => {
            format!("{{\"op\": {op}, \"kind\": {}, \"bytes\": {bytes}}}", json_string(kind))
        }
        TraceEvent::OpFinished { op, xfers, bytes } => {
            format!("{{\"op\": {op}, \"xfers\": {xfers}, \"bytes\": {bytes}}}")
        }
        TraceEvent::ConnBound { conn, qp, port, backup } => {
            format!("{{\"conn\": {conn}, \"qp\": {qp}, \"port\": {port}, \"backup\": {backup}}}")
        }
        TraceEvent::StepBegin { op, channel, step } | TraceEvent::StepEnd { op, channel, step } => {
            format!("{{\"op\": {op}, \"channel\": {channel}, \"step\": {step}}}")
        }
        TraceEvent::MonitorVerdict { port, verdict, gbps } => format!(
            "{{\"port\": {port}, \"verdict\": {}, \"gbps\": {}}}",
            json_string(verdict),
            json_number(gbps)
        ),
    }
}

fn process_name(pid: usize) -> String {
    match pid {
        PID_NET => "net.flow".to_string(),
        PID_CCL => "ccl".to_string(),
        PID_FAULT => "fault".to_string(),
        PID_SIM => "sim".to_string(),
        PID_FABRIC => "fabric".to_string(),
        n => format!("node{n}"),
    }
}

/// Serialize records into Chrome trace-event JSON. Deterministic: records
/// keep ring order, metadata is sorted by pid.
pub fn export(records: &[TraceRecord], meta: &ChromeMeta) -> String {
    // Name every process that appears.
    let mut pids: BTreeMap<usize, String> = BTreeMap::new();
    for r in records {
        let (pid, _) = lane(&r.ev, meta);
        pids.entry(pid).or_insert_with(|| process_name(pid));
    }

    let mut out = String::with_capacity(64 + records.len() * 128);
    out.push_str("{\n  \"displayTimeUnit\": \"ms\",\n  \"traceEvents\": [\n");
    let mut first = true;
    let mut push_ev = |out: &mut String, line: &str| {
        if !first {
            out.push_str(",\n");
        }
        first = false;
        out.push_str("    ");
        out.push_str(line);
    };
    for (pid, name) in &pids {
        push_ev(
            &mut out,
            &format!(
                "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {pid}, \"tid\": 0, \
                 \"args\": {{\"name\": {}}}}}",
                json_string(name)
            ),
        );
    }
    for r in records {
        let (pid, tid) = lane(&r.ev, meta);
        let ph = phase(&r.ev);
        // The scope field is only meaningful on instant events.
        let scope = if ph == "i" { "\"s\": \"t\", " } else { "" };
        let mut line = String::with_capacity(128);
        let _ = write!(
            line,
            "{{\"name\": {}, \"cat\": {}, \"ph\": \"{ph}\", {scope}\"ts\": {}, \
             \"pid\": {pid}, \"tid\": {tid}, \"args\": {}}}",
            json_string(display_name(&r.ev)),
            json_string(r.ev.layer()),
            json_number(r.at.as_ns() as f64 / 1e3),
            args_json(&r.ev),
        );
        push_ev(&mut out, &line);
    }
    // §Perf L3 observability: fold every AllocPass into a component-size
    // histogram (power-of-two buckets over the flow count) appended as one
    // summary event, so the "how local are reallocations?" answer is one
    // click instead of a counter-track scrub.
    let mut hist = [0u64; 8]; // 1, 2, ≤4, ≤8, ≤16, ≤32, ≤64, >64
    let (mut passes, mut last_ts) = (0u64, 0.0f64);
    for r in records {
        if let TraceEvent::AllocPass { flows, .. } = r.ev {
            passes += 1;
            last_ts = r.at.as_ns() as f64 / 1e3;
            let b = match flows {
                0 | 1 => 0,
                2 => 1,
                3..=4 => 2,
                5..=8 => 3,
                9..=16 => 4,
                17..=32 => 5,
                33..=64 => 6,
                _ => 7,
            };
            hist[b] += 1;
        }
    }
    if passes > 0 {
        let labels = ["le_1", "le_2", "le_4", "le_8", "le_16", "le_32", "le_64", "gt_64"];
        let mut args = format!("{{\"passes\": {passes}");
        for (l, n) in labels.iter().zip(hist) {
            let _ = write!(args, ", \"flows_{l}\": {n}");
        }
        args.push('}');
        push_ev(
            &mut out,
            &format!(
                "{{\"name\": \"AllocComponentHistogram\", \"cat\": \"net.flow\", \
                 \"ph\": \"i\", \"s\": \"t\", \"ts\": {}, \"pid\": {PID_NET}, \"tid\": 0, \
                 \"args\": {args}}}",
                json_number(last_ts)
            ),
        );
    }
    out.push_str("\n  ]\n}\n");
    out
}

/// Export only records at or after `since_ns`, dropping the `SimStarted`
/// marker — the post-resume trace tail of a checkpointed run. Because the
/// filter is a pure time predicate over ring-ordered records, this tail is
/// byte-identical to `export_since` of the uninterrupted run over the same
/// window (§Soak determinism contract).
pub fn export_since(records: &[TraceRecord], meta: &ChromeMeta, since_ns: u64) -> String {
    let tail: Vec<TraceRecord> = records
        .iter()
        .filter(|r| r.at.as_ns() >= since_ns && !matches!(r.ev, TraceEvent::SimStarted { .. }))
        .copied()
        .collect();
    export(&tail, meta)
}

// ---------------------------------------------------------------------
// Minimal JSON syntax checker (no serde offline). Validates the full JSON
// grammar; used by tests and the CI trace smoke to prove the export parses.
// ---------------------------------------------------------------------

/// Validate that `s` is one well-formed JSON value. Returns the byte offset
/// and a message on the first error.
pub fn json_lint(s: &str) -> Result<(), String> {
    let mut p = Lint { b: s.as_bytes(), i: 0, depth: 0 };
    p.ws();
    p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(())
}

struct Lint<'a> {
    b: &'a [u8],
    i: usize,
    depth: usize,
}

impl Lint<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<(), String> {
        self.depth += 1;
        if self.depth > 512 {
            return Err(format!("nesting too deep at byte {}", self.i));
        }
        let r = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string(),
            Some(b't') => self.literal("true"),
            Some(b'f') => self.literal("false"),
            Some(b'n') => self.literal("null"),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("expected a JSON value at byte {}", self.i)),
        };
        self.depth -= 1;
        r
    }

    fn object(&mut self) -> Result<(), String> {
        self.expect(b'{')?;
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<(), String> {
        self.expect(b'[')?;
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(());
        }
        loop {
            self.ws();
            self.value()?;
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(());
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<(), String> {
        self.expect(b'"')?;
        while let Some(c) = self.peek() {
            self.i += 1;
            match c {
                b'"' => return Ok(()),
                b'\\' => {
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.i += 1;
                    match esc {
                        b'"' | b'\\' | b'/' | b'b' | b'f' | b'n' | b'r' | b't' => {}
                        b'u' => {
                            for _ in 0..4 {
                                let h = self.peek().ok_or("truncated \\u escape")?;
                                if !h.is_ascii_hexdigit() {
                                    return Err(format!("bad \\u escape at byte {}", self.i));
                                }
                                self.i += 1;
                            }
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                0x00..=0x1f => {
                    return Err(format!("raw control character in string at byte {}", self.i - 1))
                }
                _ => {}
            }
        }
        Err("unterminated string".to_string())
    }

    fn number(&mut self) -> Result<(), String> {
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let digits = |p: &mut Self| -> Result<(), String> {
            let start = p.i;
            while p.peek().is_some_and(|c| c.is_ascii_digit()) {
                p.i += 1;
            }
            if p.i == start {
                Err(format!("expected digits at byte {}", p.i))
            } else {
                Ok(())
            }
        };
        digits(self)?;
        if self.peek() == Some(b'.') {
            self.i += 1;
            digits(self)?;
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            digits(self)?;
        }
        Ok(())
    }

    fn literal(&mut self, lit: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn meta() -> ChromeMeta {
        ChromeMeta { ports_per_node: 8 }
    }

    fn rec(at_ns: u64, seq: u64, ev: TraceEvent) -> TraceRecord {
        TraceRecord { at: SimTime::ns(at_ns), seq, ev }
    }

    #[test]
    fn export_is_valid_json_with_lanes() {
        let records = vec![
            rec(0, 0, TraceEvent::SimStarted { nodes: 2, ranks: 16 }),
            rec(100, 1, TraceEvent::WrPosted { qp: 0, port: 9, bytes: 1 << 20 }),
            rec(4_000_000, 2, TraceEvent::PortDown { port: 0 }),
            rec(4_000_100, 3, TraceEvent::FlowStalled { flow: 7, link: Some(0) }),
            rec(
                5_000_000,
                4,
                TraceEvent::PointerMigrated {
                    conn: 0,
                    xfer: 7,
                    port: Some(0),
                    breakpoint: 3,
                    rolled_back: 2,
                },
            ),
            rec(5_000_500, 5, TraceEvent::MonitorVerdict { port: 9, verdict: "network-anomaly", gbps: 20.5 }),
        ];
        let json = export(&records, &meta());
        json_lint(&json).unwrap();
        // Port 9 lives on node 1 (8 ports per node).
        assert!(json.contains("\"name\": \"WrPosted\""));
        assert!(json.contains("\"pid\": 1, \"tid\": 9"));
        // Pseudo-processes get metadata names.
        assert!(json.contains("\"name\": \"net.flow\""));
        assert!(json.contains("\"name\": \"fault\""));
        // Timestamps are microseconds.
        assert!(json.contains("\"ts\": 4000"));
    }

    #[test]
    fn fabric_events_get_their_own_process() {
        let records = vec![
            rec(100, 0, TraceEvent::SwitchDown { switch: 7 }),
            rec(
                200,
                1,
                TraceEvent::TrunkDegraded { link: 70, switch: 7, gbps: 0.0, was_gbps: 800.0 },
            ),
            rec(50_000, 2, TraceEvent::PathMigrated { conn: 3, xfer: 9, link: 70 }),
            rec(90_000, 3, TraceEvent::SwitchUp { switch: 7 }),
        ];
        let json = export(&records, &meta());
        json_lint(&json).unwrap();
        assert!(json.contains("\"name\": \"fabric\""));
        assert!(json.contains(&format!("\"pid\": {PID_FABRIC}, \"tid\": 7")));
        assert!(json.contains(&format!("\"pid\": {PID_FABRIC}, \"tid\": 70")));
        // Path migration sits on the fault process next to PointerMigrated.
        assert!(json.contains(&format!("\"pid\": {PID_FAULT}, \"tid\": 3")));
        assert!(json.contains("\"switch\": 7"));
    }

    #[test]
    fn empty_export_is_valid() {
        let json = export(&[], &meta());
        json_lint(&json).unwrap();
        assert!(json.contains("\"traceEvents\": ["));
    }

    #[test]
    fn export_is_deterministic() {
        let records = vec![
            rec(1, 0, TraceEvent::FlowStarted { flow: 0, bytes: 123 }),
            rec(2, 1, TraceEvent::FlowRerated { flow: 0, gbps: 387.5 }),
            rec(3, 2, TraceEvent::FlowFinished { flow: 0 }),
        ];
        assert_eq!(export(&records, &meta()), export(&records, &meta()));
    }

    /// Flow lifetimes and collective steps export as B/E span pairs on
    /// stable lanes; allocator passes become a counter track plus one
    /// component-size histogram summary. The whole export stays valid JSON.
    #[test]
    fn spans_counters_and_histogram_export() {
        let records = vec![
            rec(0, 0, TraceEvent::FlowStarted { flow: 5, bytes: 1 << 20 }),
            rec(10, 1, TraceEvent::AllocPass { flows: 1, links: 2 }),
            rec(20, 2, TraceEvent::StepBegin { op: 2, channel: 1, step: 0 }),
            rec(700, 3, TraceEvent::AllocPass { flows: 9, links: 4 }),
            rec(900, 4, TraceEvent::StepEnd { op: 2, channel: 1, step: 0 }),
            rec(1_000, 5, TraceEvent::FlowFinished { flow: 5 }),
            rec(1_100, 6, TraceEvent::FlowKilled { flow: 6 }),
        ];
        let json = export(&records, &meta());
        json_lint(&json).unwrap();
        // Flow span pair on the flow's lane, matching names.
        assert!(json.contains("\"name\": \"Flow\", \"cat\": \"net.flow\", \"ph\": \"B\""));
        assert!(json.contains("\"name\": \"Flow\", \"cat\": \"net.flow\", \"ph\": \"E\""));
        // Step span pair on the (op, channel) lane: 2<<16 | 1.
        let step_tid = (2u64 << 16) | 1;
        assert!(json.contains(&format!("\"ph\": \"B\", \"ts\": 0.02, \"pid\": {PID_CCL}, \"tid\": {step_tid}")));
        assert!(json.contains(&format!("\"ph\": \"E\", \"ts\": 0.9, \"pid\": {PID_CCL}, \"tid\": {step_tid}")));
        // Allocator counter samples + the appended histogram.
        assert!(json.contains("\"name\": \"alloc.component\""));
        assert!(json.contains("\"ph\": \"C\""));
        assert!(json.contains("\"name\": \"AllocComponentHistogram\""));
        assert!(json.contains("\"passes\": 2"));
        assert!(json.contains("\"flows_le_1\": 1"));
        assert!(json.contains("\"flows_le_16\": 1"));
        // Instant events keep their thread scope; spans must not carry one.
        assert!(!json.contains("\"ph\": \"B\", \"s\""));
    }

    /// The resume-tail contract: exporting a full run's records from T
    /// equals exporting a resumed run's records from T, as long as the
    /// record sets agree past T — `SimStarted` (re-emitted by the resumed
    /// process at construction) is excluded from both sides.
    #[test]
    fn export_since_splices_resume_tails() {
        let full = vec![
            rec(0, 0, TraceEvent::SimStarted { nodes: 2, ranks: 16 }),
            rec(100, 1, TraceEvent::FlowStarted { flow: 0, bytes: 4096 }),
            rec(2_000, 2, TraceEvent::FlowFinished { flow: 0 }),
            rec(5_000, 3, TraceEvent::FlowStarted { flow: 1, bytes: 8192 }),
            rec(9_000, 4, TraceEvent::FlowFinished { flow: 1 }),
        ];
        // A resumed process re-emits SimStarted at its own construction and
        // then records the same post-boundary events.
        let resumed = vec![
            rec(5_000, 0, TraceEvent::SimStarted { nodes: 2, ranks: 16 }),
            rec(5_000, 1, TraceEvent::FlowStarted { flow: 1, bytes: 8192 }),
            rec(9_000, 2, TraceEvent::FlowFinished { flow: 1 }),
        ];
        let a = export_since(&full, &meta(), 5_000);
        let b = export_since(&resumed, &meta(), 5_000);
        json_lint(&a).unwrap();
        assert_eq!(a, b);
        assert!(!a.contains("SimStarted"));
        assert!(a.contains("\"ts\": 5"));
        // The pre-boundary flow is gone from the tail.
        assert!(!a.contains("\"bytes\": 4096"));
    }

    #[test]
    fn json_lint_accepts_and_rejects() {
        for good in [
            "null",
            "-12.5e-3",
            "[1, 2, 3]",
            "{\"a\": [true, false, {\"b\": \"c\\n\"}]}",
            "  {\"u\": \"\\u00e9\"}  ",
            "[]",
            "{}",
        ] {
            json_lint(good).unwrap_or_else(|e| panic!("{good:?}: {e}"));
        }
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\": }",
            "{\"a\": 1,}",
            "nul",
            "\"unterminated",
            "[1] extra",
            "{'single': 1}",
            "1.",
            "\"bad \\x escape\"",
        ] {
            assert!(json_lint(bad).is_err(), "{bad:?} should be rejected");
        }
    }

    #[test]
    fn all_event_args_are_valid_json() {
        let events = [
            TraceEvent::SimStarted { nodes: 1, ranks: 8 },
            TraceEvent::FlowStarted { flow: 1, bytes: 2 },
            TraceEvent::FlowRerated { flow: 1, gbps: 1.5 },
            TraceEvent::FlowStalled { flow: 1, link: None },
            TraceEvent::FlowStalled { flow: 1, link: Some(4) },
            TraceEvent::FlowResumed { flow: 1, scope: "flow" },
            TraceEvent::FlowResumed { flow: 1, scope: "xfer" },
            TraceEvent::FlowFinished { flow: 1 },
            TraceEvent::FlowKilled { flow: 1 },
            TraceEvent::AllocPass { flows: 3, links: 7 },
            TraceEvent::WrPosted { qp: 1, port: 2, bytes: 3 },
            TraceEvent::WrCompleted { qp: 1, port: 2, bytes: 3, status: "success" },
            TraceEvent::QpRetryArmed { qp: 1, port: 2, deadline_ns: 3 },
            TraceEvent::QpError { qp: 1, port: 2 },
            TraceEvent::QpReset { qp: 1, port: 2, warm_ns: 3 },
            TraceEvent::PortDown { port: 1 },
            TraceEvent::PortUp { port: 1 },
            TraceEvent::SwitchDown { switch: 2 },
            TraceEvent::SwitchUp { switch: 2 },
            TraceEvent::TrunkDegraded { link: 70, switch: 3, gbps: 100.0, was_gbps: 800.0 },
            TraceEvent::TrunkRestored { link: 70, switch: 3, gbps: 800.0 },
            TraceEvent::PathMigrated { conn: 1, xfer: 5, link: 70 },
            TraceEvent::PointerMigrated {
                conn: 1,
                xfer: 5,
                port: Some(2),
                breakpoint: 2,
                rolled_back: 3,
            },
            TraceEvent::PointerMigrated {
                conn: 1,
                xfer: 5,
                port: None,
                breakpoint: 2,
                rolled_back: 3,
            },
            TraceEvent::Failback { conn: 1 },
            TraceEvent::ConnBound { conn: 1, qp: 2, port: 3, backup: false },
            TraceEvent::LinkCapacity { link: 4, gbps: 50.0, was_gbps: 400.0 },
            TraceEvent::OpSubmitted { op: 1, kind: "AllReduce", bytes: 2 },
            TraceEvent::OpFinished { op: 1, xfers: 4, bytes: 32 },
            TraceEvent::StepBegin { op: 1, channel: 2, step: 3 },
            TraceEvent::StepEnd { op: 1, channel: 2, step: 3 },
            TraceEvent::MonitorVerdict { port: 1, verdict: "non-network", gbps: 0.5 },
        ];
        for ev in events {
            json_lint(&args_json(&ev)).unwrap_or_else(|e| panic!("{}: {e}", ev.kind()));
        }
    }
}
