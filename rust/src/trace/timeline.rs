//! Fixed-width incident timeline: the human-readable exporter.
//!
//! Where the Chrome export is for interactive digging, this one answers the
//! on-call question — *what happened, in what order?* — in plain text. It
//! keeps only the causal-chain event kinds ([`TraceEvent::is_key_event`]):
//! port flaps, stalls/resumes, retry windows, pointer migrations, failbacks
//! and monitor verdicts, one fixed-width row each.

use std::fmt::Write as _;

use crate::metrics::Table;

use super::{Incident, TraceEvent, TraceRecord};

/// One-line human description of an event's payload.
pub fn describe(ev: &TraceEvent) -> String {
    match *ev {
        TraceEvent::SimStarted { nodes, ranks } => format!("{nodes} nodes / {ranks} ranks"),
        TraceEvent::FlowStarted { flow, bytes } => format!("flow {flow}: {bytes} B"),
        TraceEvent::FlowRerated { flow, gbps } => format!("flow {flow} -> {gbps:.1} Gbps"),
        TraceEvent::FlowStalled { flow, link } => match link {
            Some(l) => format!("flow {flow} rate -> 0 (link {l} down)"),
            None => format!("flow {flow} rate -> 0"),
        },
        TraceEvent::FlowResumed { flow, scope } => {
            if scope == "xfer" {
                format!("xfer {flow} resumed on the backup QP")
            } else {
                format!("flow {flow} moving again")
            }
        }
        TraceEvent::FlowFinished { flow } => format!("flow {flow} drained"),
        TraceEvent::FlowKilled { flow } => format!("flow {flow} aborted"),
        TraceEvent::AllocPass { flows, links } => {
            format!("component: {flows} flow(s) / {links} link(s)")
        }
        TraceEvent::WrPosted { qp, bytes, .. } => format!("qp {qp}: {bytes} B"),
        TraceEvent::WrCompleted { qp, status, .. } => format!("qp {qp}: {status}"),
        TraceEvent::QpRetryArmed { qp, deadline_ns, .. } => {
            format!("qp {qp}: hw retransmission until {:.3} s", deadline_ns as f64 / 1e9)
        }
        TraceEvent::QpError { qp, .. } => format!("qp {qp}: retry window exhausted"),
        TraceEvent::QpReset { qp, warm_ns, .. } => {
            format!("qp {qp}: proactive RESET->RTS, warm in {:.2} s", warm_ns as f64 / 1e9)
        }
        TraceEvent::PortDown { port } => format!("port {port} down"),
        TraceEvent::PortUp { port } => format!("port {port} up"),
        TraceEvent::SwitchDown { switch } => format!("switch {switch} down (member links dead)"),
        TraceEvent::SwitchUp { switch } => format!("switch {switch} up"),
        TraceEvent::NodeDown { node } => format!("node {node} crashed (all NIC ports dead)"),
        TraceEvent::NodeUp { node } => format!("node {node} recovered"),
        TraceEvent::RingRebuilt { channels, ranks } => {
            format!("{channels} ring(s) rebuilt over {ranks} rank(s)")
        }
        TraceEvent::OpRequeued { op, channel } => {
            format!("op {op} ch {channel}: aborted and requeued on rebuilt ring")
        }
        TraceEvent::TrunkDegraded { link, switch, gbps, was_gbps } => {
            format!("trunk link {link} (switch {switch}): {was_gbps:.0} -> {gbps:.0} Gbps")
        }
        TraceEvent::TrunkRestored { link, switch, gbps } => {
            format!("trunk link {link} (switch {switch}): restored to {gbps:.0} Gbps")
        }
        TraceEvent::PathMigrated { conn, xfer, link } => format!(
            "conn {conn} xfer {xfer}: path dead (link {link}), migrated to backup plane"
        ),
        TraceEvent::LinkCapacity { link, gbps, was_gbps } => {
            format!("link {link}: {was_gbps:.0} -> {gbps:.0} Gbps")
        }
        TraceEvent::PointerMigrated { conn, xfer, breakpoint, rolled_back, .. } => format!(
            "conn {conn} xfer {xfer}: breakpoint chunk {breakpoint}, \
             {rolled_back} in-flight rolled back"
        ),
        TraceEvent::Failback { conn } => format!("conn {conn}: traffic back on primary"),
        TraceEvent::OpSubmitted { op, kind, bytes } => format!("op {op}: {kind} {bytes} B"),
        TraceEvent::OpFinished { op, xfers, bytes } => {
            format!("op {op} complete: {xfers} transfer(s), {bytes} B")
        }
        TraceEvent::ConnBound { conn, qp, port, backup } => {
            let role = if backup { "backup" } else { "primary" };
            format!("conn {conn}: {role} qp {qp} on port {port}")
        }
        TraceEvent::StepBegin { op, channel, step } => {
            format!("op {op} ch {channel} step {step}")
        }
        TraceEvent::StepEnd { op, channel, step } => format!("op {op} ch {channel} step {step}"),
        TraceEvent::MonitorVerdict { port, verdict, gbps } => {
            format!("port {port}: {verdict} at {gbps:.1} Gbps")
        }
    }
}

fn event_table(records: impl Iterator<Item = TraceRecord>) -> (Table, usize) {
    let mut t = Table::new(vec!["t (ms)", "layer", "event", "detail"]);
    let mut rows = 0;
    for r in records {
        t.row(vec![
            format!("{:.3}", r.at.as_ms_f64()),
            r.ev.layer().to_string(),
            r.ev.kind().to_string(),
            describe(&r.ev),
        ]);
        rows += 1;
    }
    (t, rows)
}

/// Timeline of the key (causal-chain) events in `records`, ring order.
pub fn key_event_timeline(records: &[TraceRecord]) -> String {
    let (t, rows) = event_table(records.iter().filter(|r| r.ev.is_key_event()).copied());
    if rows == 0 {
        return "timeline: no key events recorded (healthy run)\n".to_string();
    }
    let mut out = format!("timeline — {rows} key event(s):\n\n");
    out.push_str(&t.render());
    out
}

/// Rendering cap for one incident: a failover snapshot can hold thousands
/// of per-chunk events; the table shows the key events plus the LAST
/// `MAX_INCIDENT_ROWS` raw events leading into the anomaly. The full
/// window is always in the frozen [`Incident`] (and the Chrome export).
pub const MAX_INCIDENT_ROWS: usize = 40;

/// Render one frozen incident: header, its key events, and the tail of
/// the raw trailing window (capped at [`MAX_INCIDENT_ROWS`]).
pub fn incident_table(inc: &Incident) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "incident {:?} at {:.3} ms — {} event(s) in the trailing window:",
        inc.name,
        inc.at.as_ms_f64(),
        inc.events.len()
    );
    // Structured trigger metadata (satellite of the RCA layer): what froze
    // this snapshot, and which port/conn it names — no string parsing.
    let mut meta = format!("trigger: {}", inc.trigger.kind());
    if let Some(p) = inc.port() {
        let _ = write!(meta, " port {p}");
    }
    if let Some(c) = inc.conn() {
        let _ = write!(meta, " conn {c}");
    }
    if let Some(s) = inc.switch() {
        let _ = write!(meta, " switch {s}");
    }
    let _ = writeln!(out, "{meta}");
    // The §Perf L5 live view: which transfers were still in flight when
    // the anomaly froze this window.
    if inc.live_total > 0 {
        let shown: Vec<String> = inc
            .live_xfers
            .iter()
            .map(|x| {
                format!(
                    "xfer {} (op {} ch {} conn {}, {}/{} chunks)",
                    x.seq, x.op, x.channel, x.conn, x.chunks_done, x.chunks_total
                )
            })
            .collect();
        let more = if (inc.live_total as usize) > inc.live_xfers.len() {
            format!(" … +{} more", inc.live_total as usize - inc.live_xfers.len())
        } else {
            String::new()
        };
        let _ = writeln!(out, "in flight: {} transfer(s): {}{more}", inc.live_total, shown.join(", "));
    }
    out.push('\n');
    let key: Vec<TraceRecord> =
        inc.events.iter().filter(|r| r.ev.is_key_event()).copied().collect();
    let tail_from = inc.events.len().saturating_sub(MAX_INCIDENT_ROWS);
    // Key events first (the causal chain), then the raw tail; dedup by seq
    // so a key event inside the tail is not printed twice.
    let mut rows: Vec<TraceRecord> = key;
    for r in &inc.events[tail_from..] {
        if !rows.iter().any(|k| k.seq == r.seq) {
            rows.push(*r);
        }
    }
    rows.sort_by_key(|r| r.seq);
    if tail_from > 0 {
        let _ = writeln!(
            out,
            "(showing key events + the last {} of {}; the full window is in the trace JSON)\n",
            inc.events.len() - tail_from,
            inc.events.len()
        );
    }
    let (t, _) = event_table(rows.into_iter());
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::SimTime;

    fn rec(ns: u64, seq: u64, ev: TraceEvent) -> TraceRecord {
        TraceRecord { at: SimTime::ns(ns), seq, ev }
    }

    #[test]
    fn timeline_keeps_only_key_events() {
        let records = vec![
            rec(1_000_000, 0, TraceEvent::WrPosted { qp: 0, port: 0, bytes: 1 }),
            rec(4_000_000, 1, TraceEvent::PortDown { port: 0 }),
            rec(4_100_000, 2, TraceEvent::FlowStalled { flow: 3, link: Some(0) }),
            rec(
                9_000_000,
                3,
                TraceEvent::PointerMigrated {
                    conn: 0,
                    xfer: 3,
                    port: Some(0),
                    breakpoint: 2,
                    rolled_back: 1,
                },
            ),
        ];
        let s = key_event_timeline(&records);
        assert!(s.contains("PortDown"));
        assert!(s.contains("FlowStalled"));
        assert!(s.contains("PointerMigrated"));
        assert!(!s.contains("WrPosted"), "non-key events must be filtered:\n{s}");
        // Fixed width: all table lines equal length.
        let lines: Vec<&str> = s.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.len() >= 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn empty_timeline_says_healthy() {
        let records =
            vec![rec(1, 0, TraceEvent::FlowStarted { flow: 0, bytes: 8 })];
        assert!(key_event_timeline(&records).contains("healthy"));
    }

    #[test]
    fn incident_renders_full_window() {
        let inc = Incident {
            name: "failover-conn0-port0".to_string(),
            at: SimTime::ms(9),
            trigger: TraceEvent::PointerMigrated {
                conn: 0,
                xfer: 11,
                port: Some(0),
                breakpoint: 2,
                rolled_back: 1,
            },
            events: vec![
                rec(8_000_000, 0, TraceEvent::WrPosted { qp: 0, port: 0, bytes: 1 }),
                rec(9_000_000, 1, TraceEvent::QpError { qp: 0, port: 0 }),
            ],
            live_xfers: vec![crate::trace::LiveXfer {
                seq: 11,
                op: 0,
                channel: 1,
                conn: 0,
                bytes: 1 << 20,
                chunks_done: 2,
                chunks_total: 8,
            }],
            live_total: 3,
        };
        let s = incident_table(&inc);
        assert!(s.contains("failover-conn0-port0"));
        // Incidents keep every event, key or not.
        assert!(s.contains("WrPosted"));
        assert!(s.contains("QpError"));
        // Structured trigger + live-transfer surfacing.
        assert!(s.contains("trigger: PointerMigrated port 0 conn 0"), "{s}");
        assert!(s.contains("in flight: 3 transfer(s)"), "{s}");
        assert!(s.contains("xfer 11 (op 0 ch 1 conn 0, 2/8 chunks)"), "{s}");
    }
}
