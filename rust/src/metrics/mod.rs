//! Lightweight metrics: named counters/gauges plus the plain-text table
//! formatter every experiment report uses (no external deps — offline build).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A string-keyed metrics registry (BTreeMap so reports are ordered).
#[derive(Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            let _ = writeln!(out, "{k}: {v}");
        }
        for (k, v) in &self.gauges {
            let _ = writeln!(out, "{k}: {v:.4}");
        }
        out
    }
}

/// Fixed-width text table builder for experiment reports.
#[derive(Debug)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let _ = write!(line, " {:<w$} |", cells[i], w = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = Metrics::new();
        m.inc("wcs", 3);
        m.inc("wcs", 2);
        m.set("bw_gbps", 387.5);
        assert_eq!(m.counter("wcs"), 5);
        assert_eq!(m.gauge("bw_gbps"), Some(387.5));
        let r = m.render();
        assert!(r.contains("wcs: 5") && r.contains("bw_gbps: 387.5"));
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["short", "1"]);
        t.row(vec!["a-much-longer-name", "2"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines the same width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }
}
