//! Lightweight metrics: named counters/gauges, the plain-text table
//! formatter every experiment report uses, and the machine-readable
//! [`BenchReport`] JSON emitted by `vccl bench` (no external deps — offline
//! build, hand-rolled JSON writer).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A string-keyed metrics registry (BTreeMap so reports are ordered).
#[derive(Debug, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn inc(&mut self, name: &str, by: u64) {
        *self.counters.entry(name.to_string()).or_default() += by;
    }

    pub fn set(&mut self, name: &str, value: f64) {
        self.gauges.insert(name.to_string(), value);
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        for (k, v) in &self.counters {
            let _ = writeln!(out, "{k}: {v}");
        }
        for (k, v) in &self.gauges {
            let _ = writeln!(out, "{k}: {v:.4}");
        }
        out
    }
}

/// Fixed-width text table builder for experiment reports.
#[derive(Debug)]
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(headers: Vec<S>) -> Self {
        Table { headers: headers.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncols {
                let _ = write!(line, " {:<w$} |", cells[i], w = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }
}

/// One named measurement inside a [`BenchReport`].
#[derive(Debug, Clone, PartialEq)]
pub struct BenchMetric {
    /// Dotted metric name, e.g. `p2p.inter.vccl.64MB.algbw_gbps`.
    pub name: String,
    pub value: f64,
    /// Unit suffix (`gbps`, `us`, `ms`, `tflops`, `count`, `percent`, ...).
    pub unit: String,
}

/// A machine-readable benchmark report, serialized to `BENCH_<name>.json`
/// by `vccl bench` so the performance trajectory of the repo is tracked
/// from real, reproducible runs (same seed ⇒ same numbers).
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// Short suite name: `p2p`, `failover`, `monitor`, `train`.
    pub bench: String,
    /// What paper artifact this reproduces (e.g. "Fig 10 / Table 1").
    pub source: String,
    pub metrics: Vec<BenchMetric>,
}

impl BenchReport {
    pub fn new(bench: &str, source: &str) -> Self {
        BenchReport { bench: bench.to_string(), source: source.to_string(), metrics: Vec::new() }
    }

    /// Record one metric. Non-finite values are clamped to 0 so the emitted
    /// JSON is always valid (JSON has no NaN/Infinity).
    pub fn push(&mut self, name: impl Into<String>, value: f64, unit: &str) -> &mut Self {
        let value = if value.is_finite() { value } else { 0.0 };
        self.metrics.push(BenchMetric { name: name.into(), value, unit: unit.to_string() });
        self
    }

    /// Serialize to pretty-printed JSON.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        let _ = writeln!(out, "  \"bench\": {},", json_string(&self.bench));
        let _ = writeln!(out, "  \"source\": {},", json_string(&self.source));
        out.push_str("  \"metrics\": [\n");
        for (i, m) in self.metrics.iter().enumerate() {
            let comma = if i + 1 < self.metrics.len() { "," } else { "" };
            let _ = writeln!(
                out,
                "    {{\"name\": {}, \"value\": {}, \"unit\": {}}}{comma}",
                json_string(&m.name),
                json_number(m.value),
                json_string(&m.unit),
            );
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// JSON string literal with the mandatory escapes. Public because the
/// flight recorder's Chrome-trace exporter (`trace::chrome`) reuses this
/// emitter instead of growing a second hand-rolled JSON writer.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// JSON number: finite, shortest round-trip form, never `NaN`. Rust's f64
/// `Display` never emits scientific notation, so the output is always a
/// valid JSON number (`42`, `387.5`, `0.000000032`). Shared with
/// `trace::chrome` like [`json_string`].
pub fn json_number(v: f64) -> String {
    if !v.is_finite() {
        return "0".to_string();
    }
    format!("{v}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges() {
        let mut m = Metrics::new();
        m.inc("wcs", 3);
        m.inc("wcs", 2);
        m.set("bw_gbps", 387.5);
        assert_eq!(m.counter("wcs"), 5);
        assert_eq!(m.gauge("bw_gbps"), Some(387.5));
        let r = m.render();
        assert!(r.contains("wcs: 5") && r.contains("bw_gbps: 387.5"));
    }

    #[test]
    fn table_alignment() {
        let mut t = Table::new(vec!["name", "value"]);
        t.row(vec!["short", "1"]);
        t.row(vec!["a-much-longer-name", "2"]);
        let r = t.render();
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
        // All lines the same width.
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn row_arity_checked() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["only-one"]);
    }

    #[test]
    fn bench_report_json_shape() {
        let mut r = BenchReport::new("p2p", "Fig 10 / Table 1");
        r.push("p2p.inter.vccl.64MB.algbw_gbps", 387.5, "gbps");
        r.push("p2p.inter.vccl.64MB.latency_us", 1342.0, "us");
        let j = r.to_json();
        assert!(j.contains("\"bench\": \"p2p\""));
        assert!(j.contains("\"source\": \"Fig 10 / Table 1\""));
        assert!(j.contains("\"name\": \"p2p.inter.vccl.64MB.algbw_gbps\""));
        assert!(j.contains("\"value\": 387.5"));
        assert!(j.contains("\"unit\": \"gbps\""));
        // Exactly one comma between the two metric objects, none trailing.
        assert!(j.matches("\"name\"").count() == 2);
        assert!(!j.contains("},\n  ]"), "trailing comma before ]:\n{j}");
        assert!(j.trim_end().ends_with('}'));
    }

    #[test]
    fn bench_json_escapes_and_clamps() {
        let mut r = BenchReport::new("weird\"name", "line\nbreak");
        r.push("nan.metric", f64::NAN, "x");
        r.push("int.metric", 3.0, "count");
        let j = r.to_json();
        assert!(j.contains("weird\\\"name"));
        assert!(j.contains("line\\nbreak"));
        assert!(j.contains("\"value\": 0")); // NaN clamped
        assert!(j.contains("\"value\": 3")); // integral rendered without .0
        assert!(!j.contains("NaN"));
    }

    #[test]
    fn json_number_forms() {
        assert_eq!(json_number(42.0), "42");
        assert_eq!(json_number(-1.0), "-1");
        assert_eq!(json_number(f64::INFINITY), "0");
        assert!(json_number(1.5).starts_with("1.5"));
    }
}
