//! End-to-end training driver: REAL compute (the L2/L1 model through PJRT)
//! + the simulated cluster's communication timing.
//!
//! Division of labour, mirroring DESIGN.md's substitution table:
//!
//! - **loss curve** — real: every optimizer step executes the AOT-compiled
//!   JAX train_step (which runs the Pallas kernels' HLO) on actual data.
//!   Fig 12's claim ("SM-free does not change convergence") becomes: the
//!   transport choice changes only *when* tensors move, never their values,
//!   so the curve is bit-identical across transports — asserted by the
//!   `train_e2e` example by running both and diffing losses.
//! - **throughput** — simulated: the 1F1B pipeline model supplies iteration
//!   times for the configured transport, with per-stage compute times
//!   *measured* from the real PJRT step so the simulated overlap window is
//!   grounded in the real workload.

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use crate::ccl::ClusterSim;
use crate::config::Config;
use crate::pipeline::{PipelineCfg, PipelineSim};
use crate::runtime::{synthetic_batch, ModelRuntime};

/// One recorded training step.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: u64,
    pub loss: f32,
    pub wall_ms: f64,
}

/// Result of a training run.
#[derive(Debug)]
pub struct TrainReport {
    pub preset: String,
    pub steps: Vec<StepRecord>,
    /// Simulated per-iteration time for the configured transport (ns).
    pub sim_iter_ns: u64,
    /// Simulated achieved TFLOPS/GPU at paper-scale compute times.
    pub sim_tflops_per_gpu: f64,
    pub transport: &'static str,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.steps.last().map(|s| s.loss).unwrap_or(f32::NAN)
    }

    pub fn initial_loss(&self) -> f32 {
        self.steps.first().map(|s| s.loss).unwrap_or(f32::NAN)
    }

    /// CSV of the loss curve (the `train --out` flag and the `train_e2e`
    /// example write these under `reports/`).
    pub fn to_csv(&self) -> String {
        let mut out = String::from("step,loss,wall_ms\n");
        for s in &self.steps {
            out.push_str(&format!("{},{:.6},{:.2}\n", s.step, s.loss, s.wall_ms));
        }
        out
    }
}

/// Training configuration for the driver.
#[derive(Debug, Clone)]
pub struct TrainOpts {
    pub preset: String,
    pub steps: u64,
    pub seed: u64,
    pub log_every: u64,
    /// Pipeline shape used for the simulated-throughput half.
    pub pp_stages: usize,
    pub microbatches: usize,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            preset: "tiny".into(),
            steps: 50,
            seed: 0,
            log_every: 10,
            pp_stages: 4,
            microbatches: 8,
        }
    }
}

/// Run real training through PJRT; then run the pipeline sim with compute
/// times calibrated from the measured steps.
pub fn run_training(
    artifact_dir: &Path,
    cfg: Config,
    opts: &TrainOpts,
    mut on_log: impl FnMut(&StepRecord),
) -> Result<TrainReport> {
    let rt = ModelRuntime::load(artifact_dir, &opts.preset)?;
    let mut st = rt.init_state(opts.seed);
    let mut steps = Vec::with_capacity(opts.steps as usize);
    for i in 0..opts.steps {
        let (toks, tgts) =
            synthetic_batch(rt.meta.batch, rt.meta.seq_len, rt.meta.vocab, opts.seed + 1 + i);
        let t0 = Instant::now();
        let loss = rt.train_step(&mut st, &toks, &tgts)?;
        let rec = StepRecord {
            step: i + 1,
            loss,
            wall_ms: t0.elapsed().as_secs_f64() * 1e3,
        };
        if i == 0 || (i + 1) % opts.log_every == 0 || i + 1 == opts.steps {
            on_log(&rec);
        }
        steps.push(rec);
    }

    // Simulated throughput: compute time per microbatch per stage derived
    // from the measured wallclock (fwd:bwd ≈ 1:2), message sizes from the
    // real activation shape (B×L×H×4 bytes — Appendix C).
    let med_ms = median(steps.iter().map(|s| s.wall_ms));
    let per_micro_total_ns = (med_ms * 1e6) as u64 / opts.microbatches as u64;
    // Appendix C: S_PP = B × L × H × p. H (d_model) isn't in the meta, but
    // for the presets used here H·p ≈ 1 KiB per token is representative.
    let act_bytes = (rt.meta.batch * rt.meta.seq_len) as u64 * 1024;
    let transport = cfg.vccl.transport.name();
    let mut pcfg = PipelineCfg::spread(&cfg, opts.pp_stages, opts.microbatches);
    pcfg.fwd_ns = per_micro_total_ns / 3;
    pcfg.bwd_ns = per_micro_total_ns * 2 / 3;
    pcfg.msg_bytes = act_bytes.max(1 << 20);
    pcfg.flops_per_micro_stage =
        6.0 * rt.meta.param_count as f64 * (rt.meta.batch * rt.meta.seq_len) as f64
            / opts.pp_stages as f64
            / opts.microbatches as f64
            / 3.0;
    let mut pipe = PipelineSim::new(ClusterSim::new(cfg), pcfg);
    let r = pipe.run_iteration();

    Ok(TrainReport {
        preset: opts.preset.clone(),
        steps,
        sim_iter_ns: r.iter_ns,
        sim_tflops_per_gpu: r.tflops_per_gpu,
        transport,
    })
}

fn median(xs: impl Iterator<Item = f64>) -> f64 {
    let mut v: Vec<f64> = xs.collect();
    if v.is_empty() {
        return 0.0;
    }
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn median_works() {
        assert_eq!(median([3.0, 1.0, 2.0].into_iter()), 2.0);
        assert_eq!(median(std::iter::empty()), 0.0);
    }

    #[test]
    fn report_csv_format() {
        let r = TrainReport {
            preset: "tiny".into(),
            steps: vec![StepRecord { step: 1, loss: 6.25, wall_ms: 12.5 }],
            sim_iter_ns: 1,
            sim_tflops_per_gpu: 0.0,
            transport: "vccl-smfree",
        };
        let csv = r.to_csv();
        assert!(csv.starts_with("step,loss,wall_ms\n"));
        assert!(csv.contains("1,6.250000,12.50"));
        assert_eq!(r.final_loss(), 6.25);
    }

    /// Real-compute smoke test (needs the AOT artifacts and a PJRT-enabled
    /// build: `python -m compile.aot --out rust/artifacts --presets tiny`
    /// then `--features xla`).
    #[cfg(feature = "xla")]
    #[test]
    fn tiny_training_descends_and_sim_reports() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("meta_tiny.json").exists() {
            eprintln!("skipping: generate the AOT artifacts first");
            return;
        }
        let opts = TrainOpts { steps: 12, ..Default::default() };
        let rep = run_training(&dir, Config::paper_defaults(), &opts, |_| {}).unwrap();
        assert_eq!(rep.steps.len(), 12);
        assert!(rep.final_loss() < rep.initial_loss());
        assert!(rep.sim_iter_ns > 0);
    }
}
