//! Collective primitives over the cluster simulation.
//!
//! Every primitive decomposes into per-channel ring steps (or a direct
//! exchange for AlltoAll), each step a set of chunked point-to-point
//! transfers. The decomposition mirrors NCCL's Simple-protocol ring
//! algorithms; channels stripe over rails (see [`crate::topology::build_rings`]).
//!
//! | primitive      | steps      | per-step payload per rank        |
//! |----------------|------------|----------------------------------|
//! | SendRecv       | 1          | bytes / channels                 |
//! | AllReduce      | 2(N−1)     | bytes / (N · channels)           |
//! | AllGather      | N−1        | bytes / (N · channels)           |
//! | ReduceScatter  | N−1        | bytes / (N · channels)           |
//! | AlltoAll       | 1          | bytes / (N · channels) per peer  |
//!
//! Reduction steps (AllReduce's first N−1, all of ReduceScatter) add a
//! reduction-kernel delay between ring steps — reductions are *not*
//! SM-free in either system (§6: VCCL targets reduction-free primitives).

use crate::sim::SimTime;
use crate::topology::RankId;
use crate::trace::TraceEvent;

use super::cluster::{ChanRollup, ClusterSim, CollKind, Event, Op, OpId};

impl ClusterSim {
    /// Submit a collective over all ranks. Returns its id; drive with
    /// [`ClusterSim::run_until`] / [`ClusterSim::run_to_idle`].
    pub fn submit(&mut self, kind: CollKind, bytes: u64) -> OpId {
        assert_ne!(kind, CollKind::SendRecv, "use submit_p2p for SendRecv");
        self.submit_inner(kind, bytes, None)
    }

    /// Submit a point-to-point SendRecv.
    pub fn submit_p2p(&mut self, src: RankId, dst: RankId, bytes: u64) -> OpId {
        self.submit_inner(CollKind::SendRecv, bytes, Some((src, dst)))
    }

    fn submit_inner(&mut self, kind: CollKind, bytes: u64, p2p: Option<(RankId, RankId)>) -> OpId {
        let n = self.topo.num_ranks();
        let channels = self.cfg.vccl.channels.max(1);
        let steps_total = match kind {
            CollKind::SendRecv | CollKind::AllToAll => 1,
            CollKind::AllReduce => 2 * (n - 1),
            CollKind::AllGather | CollKind::ReduceScatter => n - 1,
        };
        let id = OpId(self.ops.len());
        self.ops.push(Op {
            id,
            kind,
            bytes,
            p2p,
            channels,
            steps_total,
            chan_step: vec![0; channels],
            chan_pending: vec![0; channels],
            chan_rollup: vec![ChanRollup::default(); channels],
            channels_done: 0,
            failed: false,
            started_at: self.now(),
            finished_at: None,
        });
        self.tracer.record(
            self.now(),
            TraceEvent::OpSubmitted { op: id.0, kind: kind.name(), bytes },
        );
        for c in 0..channels {
            let now = self.now();
            self.sched_at(now, Event::OpStep { op: id, channel: c });
        }
        id
    }

    /// Issue the current step of `op` on `channel` (OpStep event handler).
    pub(crate) fn issue_step(&mut self, op: OpId, channel: usize) {
        let (kind, bytes, p2p, channels, nranks) = {
            let o = &self.ops[op.0];
            if o.failed || o.is_done() {
                return;
            }
            self.tracer.record(
                self.engine.now(),
                TraceEvent::StepBegin { op: op.0, channel, step: o.chan_step[channel] },
            );
            (o.kind, o.bytes, o.p2p, o.channels, self.topo.num_ranks())
        };
        match kind {
            CollKind::SendRecv => {
                let (src, dst) = p2p.expect("SendRecv without endpoints");
                let per = (bytes / channels as u64).max(1);
                self.ops[op.0].chan_pending[channel] = 1;
                self.start_xfer(op, src, dst, channel, per);
            }
            CollKind::AllReduce | CollKind::AllGather | CollKind::ReduceScatter => {
                let seg = (bytes / (nranks as u64 * channels as u64)).max(1);
                // §Elastic: after a shrink the rings span the SURVIVING
                // ranks only — the step completes when every segment of the
                // (possibly shrunk) ring lands, so pend the ring's length,
                // not the full world size. Identical when nothing is dead.
                let ring = self.rings[channel % self.rings.len()].clone();
                self.ops[op.0].chan_pending[channel] = ring.order.len();
                for &r in &ring.order {
                    let next = ring.next(r);
                    self.start_xfer(op, r, next, channel, seg);
                }
            }
            CollKind::AllToAll => {
                let per = (bytes / (nranks as u64 * channels as u64)).max(1);
                // §Elastic: exchange among the survivors only. The filter
                // preserves rank order, so with no dead nodes this is
                // bit-identical to the plain 0..nranks double loop.
                let alive: Vec<usize> =
                    (0..nranks).filter(|&r| !self.rank_on_dead_node(r)).collect();
                let m = alive.len();
                self.ops[op.0].chan_pending[channel] = m * (m.saturating_sub(1));
                for &r in &alive {
                    for &s in &alive {
                        if r != s {
                            self.start_xfer(op, RankId(r), RankId(s), channel, per);
                        }
                    }
                }
            }
        }
    }

    /// A transfer of `op` on `channel` finished: advance the step machine.
    pub(crate) fn on_xfer_done(&mut self, op: OpId, channel: usize) {
        let now = self.now();
        let nranks = self.topo.num_ranks();
        let (advance, reduce_delay_ns) = {
            let o = &mut self.ops[op.0];
            debug_assert!(o.chan_pending[channel] > 0);
            o.chan_pending[channel] -= 1;
            if o.chan_pending[channel] > 0 {
                return;
            }
            self.tracer.record(
                now,
                TraceEvent::StepEnd { op: op.0, channel, step: o.chan_step[channel] },
            );
            o.chan_step[channel] += 1;
            if o.chan_step[channel] >= o.steps_total {
                o.channels_done += 1;
                if o.channels_done == o.channels {
                    o.finished_at = Some(now);
                    // §Perf L5: the completion event carries the op's
                    // roll-up totals — by now every transfer record may
                    // already be recycled, so the trace reads the fold.
                    let (xfers, bytes) = o
                        .chan_rollup
                        .iter()
                        .fold((0, 0), |(x, b), r| (x + r.xfers, b + r.bytes));
                    self.tracer.record(now, TraceEvent::OpFinished { op: op.0, xfers, bytes });
                }
                return;
            }
            // Reduction delay between ring steps where a reduce happens:
            // AllReduce's reduce-scatter phase (steps 1..N−1 consume data)
            // and every ReduceScatter step.
            let seg = (o.bytes / (nranks as u64 * o.channels as u64)).max(1);
            let reduces = match o.kind {
                CollKind::AllReduce => o.chan_step[channel] < nranks, // first N−1 steps
                CollKind::ReduceScatter => true,
                _ => false,
            };
            let delay = if reduces {
                (seg as f64 / (self.cfg.gpu.reduce_gbps * 0.125)) as u64
            } else {
                0
            };
            (true, delay)
        };
        if advance {
            self.sched_at(now + SimTime::ns(reduce_delay_ns), Event::OpStep { op, channel });
        }
    }

    /// Convenience: run one collective to completion and return (time, op).
    pub fn run_collective(&mut self, kind: CollKind, bytes: u64) -> (SimTime, &Op) {
        let id = self.submit(kind, bytes);
        self.run_to_idle(200_000_000);
        let op = &self.ops[id.0];
        let t = op.finished_at.expect("collective did not finish");
        (t.since(op.started_at), op)
    }

    /// Convenience: run one SendRecv to completion.
    pub fn run_p2p(&mut self, src: RankId, dst: RankId, bytes: u64) -> (SimTime, &Op) {
        let id = self.submit_p2p(src, dst, bytes);
        self.run_to_idle(200_000_000);
        let op = &self.ops[id.0];
        let t = op.finished_at.expect("p2p did not finish");
        (t.since(op.started_at), op)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::util::ByteSize;

    fn sim(mut cfg: Config) -> ClusterSim {
        cfg.vccl.channels = 2; // keep event counts small in unit tests
        ClusterSim::new(cfg)
    }

    #[test]
    fn inter_node_p2p_reaches_line_rate() {
        let mut s = sim(Config::paper_defaults());
        let (t, op) = s.run_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        let bw = op.algbw_gbps().unwrap();
        // One NIC pair at 400 Gbps × wire efficiency ≈ 388; expect > 350.
        assert!(bw > 350.0 && bw <= 400.0, "bw={bw} t={t}");
    }

    #[test]
    fn intra_node_p2p_beats_inter_node() {
        let mut s1 = sim(Config::paper_defaults());
        let (_, op1) = s1.run_p2p(RankId(0), RankId(1), ByteSize::mb(64).0);
        let intra = op1.algbw_gbps().unwrap();
        let mut s2 = sim(Config::paper_defaults());
        let (_, op2) = s2.run_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        let inter = op2.algbw_gbps().unwrap();
        assert!(intra > 4.0 * inter, "intra={intra} inter={inter}");
    }

    #[test]
    fn smfree_intra_large_message_faster_than_kernel() {
        // §4.1: copy engines saturate NVLink better (+7% large-message BW).
        let mut v = sim(Config::paper_defaults());
        let (_, opv) = v.run_p2p(RankId(0), RankId(1), ByteSize::mb(512).0);
        let vbw = opv.algbw_gbps().unwrap();
        let mut n = sim(Config::nccl_baseline());
        let (_, opn) = n.run_p2p(RankId(0), RankId(1), ByteSize::mb(512).0);
        let nbw = opn.algbw_gbps().unwrap();
        let gain = vbw / nbw;
        assert!((1.03..1.12).contains(&gain), "gain={gain} v={vbw} n={nbw}");
    }

    #[test]
    fn smfree_small_message_latency_lower_inter_node() {
        // §4.1: −18.9% small-message latency from removing GPU-CPU sync.
        let mut v = sim(Config::paper_defaults());
        let (tv, _) = v.run_p2p(RankId(0), RankId(8), ByteSize::kb(64).0);
        let mut n = sim(Config::nccl_baseline());
        let (tn, _) = n.run_p2p(RankId(0), RankId(8), ByteSize::kb(64).0);
        assert!(tv < tn, "vccl={tv} nccl={tn}");
    }

    #[test]
    fn kernel_transport_occupies_sms_smfree_does_not() {
        let mut n = sim(Config::nccl_baseline());
        n.submit_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        // Mid-transfer, the sender GPU must hold comm SMs.
        n.run_until(SimTime::us(50));
        assert!(n.gpus[0].compute.comm_sms() > 0);
        n.run_to_idle(10_000_000);
        assert_eq!(n.gpus[0].compute.comm_sms(), 0);

        let mut v = sim(Config::paper_defaults());
        v.submit_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        v.run_until(SimTime::us(50));
        assert_eq!(v.gpus[0].compute.comm_sms(), 0);
        v.run_to_idle(10_000_000);
    }

    #[test]
    fn ncclx_holds_exactly_one_sm_during_p2p() {
        let mut x = sim(Config::ncclx_like());
        x.submit_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        x.run_until(SimTime::us(50));
        assert_eq!(x.gpus[0].compute.comm_sms(), 1);
        x.run_to_idle(10_000_000);
    }

    #[test]
    fn allreduce_busbw_approaches_link_rate() {
        let mut s = sim(Config::paper_defaults());
        let nranks = s.topo.num_ranks();
        let (_, op) = s.run_collective(CollKind::AllReduce, ByteSize::mb(128).0);
        let busbw = op.busbw_gbps(nranks).unwrap();
        // Ring allreduce on 2×8 GPUs, inter-node bound: busbw should land
        // in the hundreds of Gbps (paper Fig 18 baseline: ~450 Gbps).
        assert!(busbw > 200.0, "busbw={busbw}");
    }

    #[test]
    fn allgather_and_reducescatter_complete() {
        let mut s = sim(Config::paper_defaults());
        let (_, op) = s.run_collective(CollKind::AllGather, ByteSize::mb(32).0);
        assert!(op.is_done());
        let mut s = sim(Config::paper_defaults());
        let (_, op) = s.run_collective(CollKind::ReduceScatter, ByteSize::mb(32).0);
        assert!(op.is_done());
    }

    #[test]
    fn reducescatter_slower_than_allgather_due_to_reduction() {
        let mut s1 = sim(Config::paper_defaults());
        let (t_ag, _) = s1.run_collective(CollKind::AllGather, ByteSize::mb(64).0);
        let mut s2 = sim(Config::paper_defaults());
        let (t_rs, _) = s2.run_collective(CollKind::ReduceScatter, ByteSize::mb(64).0);
        assert!(t_rs > t_ag, "rs={t_rs} ag={t_ag}");
    }

    #[test]
    fn alltoall_completes_with_pxn() {
        let mut s = sim(Config::paper_defaults());
        let (_, op) = s.run_collective(CollKind::AllToAll, ByteSize::mb(16).0);
        assert!(op.is_done());
        assert!(op.algbw_gbps().unwrap() > 0.0);
    }

    #[test]
    fn allreduce_deterministic_across_runs() {
        let run = || {
            let mut s = sim(Config::paper_defaults());
            let (t, _) = s.run_collective(CollKind::AllReduce, ByteSize::mb(16).0);
            t
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn bigger_message_takes_longer() {
        let mut a = sim(Config::paper_defaults());
        let (ta, _) = a.run_p2p(RankId(0), RankId(8), ByteSize::mb(8).0);
        let mut b = sim(Config::paper_defaults());
        let (tb, _) = b.run_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        assert!(tb > ta);
    }
}
