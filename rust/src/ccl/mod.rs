//! The collective communication library core.
//!
//! - [`cluster`] — the simulation: event loop, connections, chunked
//!   transfers, failover/failback, monitor feeding.
//! - [`collectives`] — SendRecv / AllReduce / AllGather / ReduceScatter /
//!   AlltoAll as per-channel ring-step machines over the cluster.
//! - [`transport`] — the three P2P implementations' cost profiles
//!   (NCCL kernel baseline, NCCLX-like, VCCL SM-free).
//! - [`mempool`] — eager vs lazy (2MB pool) buffer accounting (§4.4).

pub mod cluster;
pub mod collectives;
pub mod mempool;
pub mod transport;

pub use cluster::{
    ActiveSide, ChanRollup, ClusterSim, CollKind, Conn, ConnId, Event, FfStats, Op, OpId, Stats,
    Xfer, XferId, XferMemStats, XferSlab,
};
pub use mempool::{AllocPolicy, MemPool};
pub use transport::{locality_of, DataPath, Locality, TransportProfile};
