//! The cluster simulation: one event loop tying together the RDMA network,
//! the GPUs, the transports, the fault-tolerance machinery and the monitor.
//!
//! `ClusterSim` is the L3 runtime's *model* of the world. Collective
//! operations decompose into chunked point-to-point transfers ([`Xfer`]),
//! each following its transport's cost profile (§3.2): staging copies and
//! GPU↔CPU flag polling for the kernel baseline, copy-engine admission for
//! the SM-free path, zero-copy GDR when eligible. Chunk payloads become
//! flows in [`crate::net::FlowNet`]; Work Completions drive the chunk
//! pointers (the same pointers §3.3's migration retreats on failover).
//!
//! Everything is deterministic: same config + seed ⇒ identical event trace.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::config::{Config, Transport};
use crate::fault::{migrate_to_breakpoint_traced, DeltaProbe, ProbeVerdict, RecvPointers,
    SendPointers, SyncFifo};
use crate::gpu::{CopyEngines, GpuCompute, TaskId};
use crate::monitor::MonitorSet;
use crate::net::{CompletionStatus, FlowId, QpId, QpState, RdmaNet, WorkCompletion};
use crate::sim::{Engine, EngineState, SimTime};
use crate::topology::{build_rings, build_rings_excluding, Cluster, LinkId, NicId, NodeId,
    PortId, RankId, Ring};
use crate::trace::{TraceEvent, Tracer};
use crate::util::{fingerprint, CkptReader, CkptWriter, Rng};

use super::mempool::{AllocPolicy, MemPool};
use super::transport::{locality_of, DataPath, Locality, TransportProfile};

/// Index newtypes into the cluster's tables.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ConnId(pub usize);
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct OpId(pub usize);

/// Generation-stamped handle into the transfer slab (§Perf L5). `slot`
/// indexes [`XferSlab`]; `gen` must match the slot's current generation.
/// Completed transfers are recycled, so an event queued against a transfer
/// that has since finished (a late `ChunkReady`, a failover re-post) can
/// fire after its slot holds a *different* transfer — the generation
/// mismatch detects that staleness and the event is ignored instead of
/// misrouted to the new occupant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct XferId {
    pub slot: u32,
    pub gen: u32,
}

/// The one event type of the simulation.
#[derive(Debug, Clone, Copy)]
pub enum Event {
    /// Flow-completion check (network payloads, NVLink copies).
    Flow { flow: FlowId, gen: u32 },
    /// Hardware retransmission window expired for a QP.
    QpRetry { qp: QpId, epoch: u32 },
    /// QP warm-up finished; release queued WRs.
    QpWarm { qp: QpId },
    /// GPU compute task completion check.
    GpuTask { gpu: usize, task: TaskId, gen: u32 },
    /// A staged chunk of a transfer is ready to go on the wire.
    ChunkReady { xfer: XferId },
    /// Fault injection.
    PortDown { port: PortId },
    PortUp { port: PortId },
    /// Fabric fault injection: a trunk link dies/heals with both endpoint
    /// ports still up (path death, §Fault domains), or a whole switch
    /// cascades to every member link.
    TrunkDown { link: LinkId },
    TrunkUp { link: LinkId },
    SwitchDown { switch: usize },
    SwitchUp { switch: usize },
    /// Node fault injection (§Elastic): a whole server crashes — every
    /// NIC port it owns goes dark at once — or recovers.
    NodeDown { node: usize },
    NodeUp { node: usize },
    /// Receiver-side δ-timeout double check (§3.3 case 2).
    DeltaCheck { conn: ConnId, epoch: u32 },
    /// Advance a collective to its next ring step on one channel.
    OpStep { op: OpId, channel: usize },
}

/// Which QP a connection currently uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActiveSide {
    Primary,
    Backup,
}

/// A (src GPU, dst GPU, channel) connection. Inter-node connections own
/// QPs (primary + optional backup); intra-node connections move chunks
/// over NVLink flows directly.
#[derive(Debug)]
pub struct Conn {
    pub id: ConnId,
    pub src: RankId,
    pub dst: RankId,
    pub channel: usize,
    pub locality: Locality,
    pub primary: Option<QpId>,
    pub primary_port: Option<PortId>,
    pub backup: Option<QpId>,
    pub backup_port: Option<PortId>,
    pub active: ActiveSide,
    /// Transfers queued on this connection. Only the FRONT transfer is
    /// active (NCCL's per-channel FIFO serializes sends between a pair);
    /// the rest start when their predecessors finish.
    pub pending: std::collections::VecDeque<XferId>,
    /// Case-2 receiver-side probe.
    pub probe: Option<DeltaProbe>,
    /// Failovers seen (stats / Fig 14).
    pub failovers: u32,
    /// Waiting for primary port to heal + QP to warm.
    pub awaiting_failback: bool,
    /// First use seen (lazy mempool accounting).
    pub used: bool,
}

impl Conn {
    /// The transfer currently on the wire for this connection.
    pub fn cur_xfer(&self) -> Option<XferId> {
        self.pending.front().copied()
    }

    pub fn active_qp(&self) -> Option<QpId> {
        match self.active {
            ActiveSide::Primary => self.primary,
            ActiveSide::Backup => self.backup,
        }
    }

    pub fn active_port(&self) -> Option<PortId> {
        match self.active {
            ActiveSide::Primary => self.primary_port,
            ActiveSide::Backup => self.backup_port,
        }
    }
}

/// One chunked point-to-point transfer.
#[derive(Debug)]
pub struct Xfer {
    pub id: XferId,
    /// Stable creation ordinal (how many transfers existed before this
    /// one). Slot indices are recycled, so trace events and intra-node
    /// flow metadata carry this id instead: it is unique for the
    /// simulation's lifetime and identical whether recycling is on or the
    /// retain-everything reference path is (§Perf L5 equivalence).
    pub seq: u64,
    pub op: OpId,
    pub channel: usize,
    pub conn: ConnId,
    pub bytes: u64,
    pub chunk_bytes: u64,
    pub chunks_total: u64,
    pub send: SendPointers,
    pub recv: RecvPointers,
    pub fifo: SyncFifo,
    pub profile: TransportProfile,
    pub locality: Locality,
    /// Sender staging pipeline: next time the staging resource is free.
    stage_free_at: SimTime,
    /// Per-side SMs we actually acquired (released on completion).
    sms_src: u32,
    sms_dst: u32,
    /// Failover stalls ridden by this transfer: one hardware retry window
    /// per pointer migration (folded into the roll-up's `stall_ns`).
    pub stall_ns: u64,
    /// Chunks put on the wire, monotone — unlike `send.transmitted`, this
    /// is never rolled back by pointer migration, so it exceeds
    /// `chunks_total` by exactly the retransmitted window(s) after a
    /// failover and equals it otherwise (the falsifiable conservation
    /// witness the roll-up carries as `chunks_wire`).
    pub wire_chunks: u64,
    pub done: bool,
    pub started_at: SimTime,
    pub finished_at: Option<SimTime>,
}

impl Xfer {
    fn inflight(&self) -> u64 {
        self.send.posted - self.send.acked
    }
}

/// §Perf L5 memory counters — the witnesses of the O(active) bookkeeping
/// guarantee, surfaced as `simcore.mem.*` in `BENCH_simcore.json`. All of
/// `created`/`retired`/`live`/`high_water` are mode-independent (retaining
/// a finished record does not make it live); only `slots_resident` differs
/// between recycling and the retain-everything reference.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct XferMemStats {
    /// Transfers ever created.
    pub created: u64,
    /// Transfers finished and folded into their op's roll-up.
    pub retired: u64,
    /// Transfers currently in flight (`created − retired`).
    pub live: u64,
    /// Peak of `live` — what the ≥100× memory gate compares `created` to.
    pub high_water: u64,
    /// Slab slots actually allocated. Equals `high_water` when recycling
    /// (slots grow only when no freed slot exists) and `created` in
    /// retain-everything reference mode.
    pub slots_resident: u64,
}

/// §Perf L5: the transfer table, recycled through a free list so memory is
/// O(active transfers), not O(transfers ever created). Before this, the
/// plain `Vec<Xfer>` grew one record per chunked transfer forever (~8.4M
/// per `scale256` AllReduce) and was the 256-node memory ceiling.
///
/// Slots are generation-stamped: [`XferSlab::retire`] bumps the slot's
/// generation, so a stale [`XferId`] held by a queued event resolves to
/// `None` instead of the slot's new occupant. The free list is LIFO —
/// deterministic reuse order, and the hottest slots stay cache-resident.
///
/// The pre-L5 retain-everything behaviour survives as a reference mode
/// (`set_retain_all`, gated like the §Perf L3/L4 reference paths): retired
/// records are kept and slots never reused. Outputs are identical in both
/// modes by contract — `randomized_equivalence_with_retained_reference`
/// pins completion timers, roll-ups, BENCH JSON and trace exports, and
/// debug builds cross-check every roll-up fold against the retained
/// records while they are cheap to rescan.
#[derive(Debug, Default)]
pub struct XferSlab {
    slots: Vec<XferSlot>,
    /// Freed slot indices, reused LIFO.
    free: Vec<u32>,
    created: u64,
    retired: u64,
    high_water: u64,
    /// Reference mode: keep retired records, never reuse slots.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    retain_all: bool,
}

#[derive(Debug, Default)]
struct XferSlot {
    gen: u32,
    x: Option<Xfer>,
}

impl XferSlab {
    pub fn new() -> Self {
        Self::default()
    }

    /// Allocate a slot (recycling the most recently freed one) and insert
    /// the transfer `make` builds from its id and stable creation ordinal.
    pub(crate) fn insert(&mut self, make: impl FnOnce(XferId, u64) -> Xfer) -> XferId {
        let seq = self.created;
        self.created += 1;
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                self.slots.push(XferSlot::default());
                (self.slots.len() - 1) as u32
            }
        };
        let s = &mut self.slots[slot as usize];
        debug_assert!(s.x.is_none(), "allocated an occupied slot");
        let id = XferId { slot, gen: s.gen };
        s.x = Some(make(id, seq));
        self.high_water = self.high_water.max(self.live());
        id
    }

    /// The transfer behind `id`, if the slot still holds that generation.
    /// Stale ids (slot recycled) resolve to `None`; in retain-everything
    /// mode the finished record is returned instead — callers' `done`
    /// checks make both read as the same no-op.
    pub fn get(&self, id: XferId) -> Option<&Xfer> {
        let s = self.slots.get(id.slot as usize)?;
        if s.gen != id.gen {
            return None;
        }
        s.x.as_ref()
    }

    pub fn get_mut(&mut self, id: XferId) -> Option<&mut Xfer> {
        let s = self.slots.get_mut(id.slot as usize)?;
        if s.gen != id.gen {
            return None;
        }
        s.x.as_mut()
    }

    /// Retire a finished transfer: drop the record and put the slot on the
    /// free list with a bumped generation, so ids queued before the finish
    /// now mismatch. The retain-everything reference keeps the record and
    /// never reuses the slot.
    pub(crate) fn retire(&mut self, id: XferId) {
        self.retired += 1;
        let s = &mut self.slots[id.slot as usize];
        debug_assert_eq!(s.gen, id.gen, "retiring a stale XferId");
        debug_assert!(
            s.x.as_ref().is_some_and(|x| x.done),
            "retiring an unfinished transfer"
        );
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        if self.retain_all {
            return;
        }
        s.x = None;
        s.gen = s.gen.wrapping_add(1);
        self.free.push(id.slot);
    }

    /// §Elastic: drop an UNFINISHED transfer — aborted by a node-death
    /// shrink, to be re-issued on the rebuilt ring. Unlike
    /// [`XferSlab::retire`] nothing was folded into a roll-up, and the
    /// record is dropped even in retain-everything mode: an aborted
    /// transfer delivered nothing, and a retained not-done record would
    /// leak into `iter_live` and keep stale events alive. The generation
    /// bumps either way so queued `ChunkReady`s against it go stale.
    pub(crate) fn abort(&mut self, id: XferId) {
        self.retired += 1;
        let s = &mut self.slots[id.slot as usize];
        debug_assert_eq!(s.gen, id.gen, "aborting a stale XferId");
        debug_assert!(
            s.x.as_ref().is_some_and(|x| !x.done),
            "aborting a finished transfer"
        );
        s.x = None;
        s.gen = s.gen.wrapping_add(1);
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        if self.retain_all {
            return; // never reuse slots in the reference mode
        }
        self.free.push(id.slot);
    }

    /// Transfers currently in flight.
    pub fn live(&self) -> u64 {
        self.created - self.retired
    }

    /// Live (not yet finished) transfers, ascending slot order.
    pub fn iter_live(&self) -> impl Iterator<Item = &Xfer> {
        self.slots.iter().filter_map(|s| s.x.as_ref()).filter(|x| !x.done)
    }

    /// Every retained record, live and finished — meaningful in
    /// retain-everything mode (recycling drops finished records).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn iter_retained(&self) -> impl Iterator<Item = &Xfer> {
        self.slots.iter().filter_map(|s| s.x.as_ref())
    }

    /// Switch to the retain-everything reference mode (before any
    /// transfer exists — mixing modes mid-run would corrupt the free
    /// list's invariants).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn set_retain_all(&mut self, on: bool) {
        assert_eq!(self.created, 0, "switch slab modes before the first transfer");
        self.retain_all = on;
    }

    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn retain_all(&self) -> bool {
        self.retain_all
    }

    /// §Perf L5 memory counters (see [`XferMemStats`]).
    pub fn mem_stats(&self) -> XferMemStats {
        XferMemStats {
            created: self.created,
            retired: self.retired,
            live: self.live(),
            high_water: self.high_water,
            slots_resident: self.slots.len() as u64,
        }
    }

    /// Serialize the slab bookkeeping (§Soak checkpointing). Requires an
    /// op-quiescent boundary — no live transfers — and the recycling mode
    /// (the retained-history slab is a test-only reference, not durable
    /// state), so only slot generations and the free list survive.
    pub fn save(&self, w: &mut CkptWriter) {
        assert_eq!(self.live(), 0, "XferSlab checkpoint requires quiescence (live transfers)");
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        assert!(!self.retain_all, "checkpoint the recycling slab, not the retained reference");
        w.usize("nslots", self.slots.len());
        for s in &self.slots {
            debug_assert!(s.x.is_none(), "quiescent slab holds a record");
            w.u32("g", s.gen);
        }
        w.usize("nfree", self.free.len());
        for f in &self.free {
            w.u32("fr", *f);
        }
        w.u64("created", self.created);
        w.u64("retired", self.retired);
        w.u64("hw", self.high_water);
    }

    /// Restore the bookkeeping into a fresh slab — slot generations and the
    /// LIFO free-list order are bit-exact, so post-resume allocations reuse
    /// the same slots with the same generations as the uninterrupted run.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        self.slots.clear();
        for _ in 0..r.usize("nslots")? {
            self.slots.push(XferSlot { gen: r.u32("g")?, x: None });
        }
        self.free.clear();
        for _ in 0..r.usize("nfree")? {
            self.free.push(r.u32("fr")?);
        }
        self.created = r.u64("created")?;
        self.retired = r.u64("retired")?;
        self.high_water = r.u64("hw")?;
        Ok(())
    }
}

/// §Perf L5: per-(op, channel) roll-up, folded at `finish_xfer` so every
/// figure the reports and benches read survives the transfer record being
/// recycled. Readers (trace `OpFinished`, benches, tests) consume this —
/// never retired `Xfer`s.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct ChanRollup {
    /// Transfers finished on this (op, channel).
    pub xfers: u64,
    /// Chunks delivered, exactly once each: the sum of the finished
    /// transfers' `chunks_total` (a transfer finishes precisely when its
    /// acked pointer reaches that count).
    pub chunks: u64,
    /// Chunks put on the wire (monotone across failover rollbacks, from
    /// [`Xfer::wire_chunks`]): equals `chunks` exactly on a failover-free
    /// channel and exceeds it by the retransmitted window(s) otherwise.
    /// Divergence without a failover is a real bug — a stale event drove
    /// a recycled slot, or a chunk was pumped twice — which is what makes
    /// this pair a falsifiable conservation witness.
    pub chunks_wire: u64,
    /// Payload bytes of the finished transfers.
    pub bytes: u64,
    /// Earliest transfer start on the channel (ns).
    pub first_start_ns: Option<u64>,
    /// Latest transfer finish on the channel (ns).
    pub last_finish_ns: Option<u64>,
    /// Failover stall ridden by the channel's transfers: one hardware
    /// retry window per pointer migration (§3.3).
    pub stall_ns: u64,
}

impl ChanRollup {
    /// Fold one finished transfer into the roll-up.
    fn fold(&mut self, x: &Xfer, finished_at: SimTime) {
        self.xfers += 1;
        self.chunks += x.chunks_total;
        self.chunks_wire += x.wire_chunks;
        self.bytes += x.bytes;
        self.stall_ns += x.stall_ns;
        let (s, f) = (x.started_at.as_ns(), finished_at.as_ns());
        self.first_start_ns = Some(self.first_start_ns.map_or(s, |v| v.min(s)));
        self.last_finish_ns = Some(self.last_finish_ns.map_or(f, |v| v.max(f)));
    }
}

/// Collective kinds (NCCL-Tests semantics for `bytes`: per-rank buffer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CollKind {
    /// Point-to-point between a (src, dst) pair.
    SendRecv,
    /// Ring allreduce: 2(N−1) steps (reduce-scatter + allgather phases).
    AllReduce,
    /// Ring allgather: N−1 steps.
    AllGather,
    /// Ring reduce-scatter: N−1 steps (with reduction).
    ReduceScatter,
    /// Direct alltoall: every rank sends bytes/N to every peer.
    AllToAll,
}

impl CollKind {
    /// Stable name (trace events, reports).
    pub fn name(&self) -> &'static str {
        match self {
            CollKind::SendRecv => "SendRecv",
            CollKind::AllReduce => "AllReduce",
            CollKind::AllGather => "AllGather",
            CollKind::ReduceScatter => "ReduceScatter",
            CollKind::AllToAll => "AllToAll",
        }
    }
}

/// A running collective operation.
#[derive(Debug)]
pub struct Op {
    pub id: OpId,
    pub kind: CollKind,
    pub bytes: u64,
    pub p2p: Option<(RankId, RankId)>,
    pub channels: usize,
    pub steps_total: usize,
    pub chan_step: Vec<usize>,
    pub chan_pending: Vec<usize>,
    /// §Perf L5: per-channel transfer roll-up (counts, bytes, start/finish
    /// instants, failover stall) — folded as transfers finish, so the op's
    /// figures outlive the recycled transfer records.
    pub chan_rollup: Vec<ChanRollup>,
    pub channels_done: usize,
    pub failed: bool,
    pub started_at: SimTime,
    pub finished_at: Option<SimTime>,
}

impl Op {
    pub fn is_done(&self) -> bool {
        self.finished_at.is_some()
    }

    /// Algorithm bandwidth in Gbps (NCCL-Tests `algbw`): bytes / time.
    pub fn algbw_gbps(&self) -> Option<f64> {
        let end = self.finished_at?;
        let ns = end.since(self.started_at).as_ns().max(1);
        Some(self.bytes as f64 * 8.0 / ns as f64)
    }

    /// Bus bandwidth (NCCL-Tests `busbw`): algbw × correction factor.
    pub fn busbw_gbps(&self, nranks: usize) -> Option<f64> {
        let alg = self.algbw_gbps()?;
        let n = nranks as f64;
        let factor = match self.kind {
            CollKind::SendRecv => 1.0,
            CollKind::AllReduce => 2.0 * (n - 1.0) / n,
            CollKind::AllGather | CollKind::ReduceScatter => (n - 1.0) / n,
            CollKind::AllToAll => (n - 1.0) / n,
        };
        Some(alg * factor)
    }
}

/// Aggregate counters (Fig 17 / Table 4/5 inputs).
#[derive(Debug, Default, Clone)]
pub struct Stats {
    /// Kernel launches per transport op (Table 4: VCCL launches none).
    pub comm_kernel_launches: u64,
    /// CPU-proxy busy nanoseconds per rank.
    pub proxy_cpu_ns: Vec<u64>,
    /// Copy-engine operations issued.
    pub ce_ops: u64,
    /// Total payload bytes completed on the wire.
    pub wire_bytes: u64,
    /// Per-port completion traffic, aggregated into monitor-window-sized
    /// buckets (§Perf L4: O(ports × windows) memory, not one entry per
    /// chunk). Feeds the bandwidth-timeline figures (13a, 18) and the
    /// §3.3 recovery-gap metric.
    pub port_traffic: crate::monitor::PortTraffic,
    /// Failovers and failbacks executed.
    pub failovers: u64,
    pub failbacks: u64,
    /// Ops that hung (no fault tolerance) — Fig 13b/14 GPU-waste input.
    pub hung_ops: u64,
    /// δ-probe verdicts observed (case-2 machinery).
    pub probe_benign: u64,
    pub probe_dead: u64,
    /// §Elastic: node-death shrinks and node-recovery rejoins executed.
    pub elastic_shrinks: u64,
    pub elastic_rejoins: u64,
    /// §Elastic: (op, channel) steps aborted by a shrink and requeued on
    /// the rebuilt rings.
    pub ops_requeued: u64,
}

/// §Perf L6 fast-forward counters: windows opened (one per event popped
/// from the global queue while the tier is on), events elided from the
/// global queue into the local buffer, and how many of those were
/// dispatched locally. `elided - local_dispatched` events were flushed
/// back to the engine at a run-loop exit.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FfStats {
    pub windows: u64,
    pub elided: u64,
    pub local_dispatched: u64,
}

/// A locally buffered event in the fast-forward tier. Ordered by
/// `(at, lseq)` — `lseq` increments per buffered event, reproducing the
/// engine's schedule-order FIFO tie-break for simultaneous events.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct LocalEv {
    at: SimTime,
    lseq: u64,
    ev: Event,
}

impl Ord for LocalEv {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.lseq).cmp(&(other.at, other.lseq))
    }
}

impl PartialOrd for LocalEv {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// §Perf L6: the flow-level fast-forward tier. While the run loop drains
/// the window between two *global-queue* events, every event a handler
/// schedules strictly inside that window is buffered here and dispatched
/// locally — skipping the calendar/heap round-trip entirely. The horizon
/// (the engine's next pending event) bounds the window, so fault
/// injections, monitor boundaries and anything scheduled at or beyond it
/// still serialize through the global queue. The local buffer replays
/// `(at, lseq)` order, which equals the engine's `(at, seq)` order for
/// the same events, so dispatch order — and therefore every observable
/// trajectory — is bit-identical to the fully-evented run (pinned by
/// `randomized_equivalence_fast_forward_vs_evented`).
#[derive(Debug)]
struct FastForward {
    /// Tier switch (`engine.fast_forward`). Off: every call passes
    /// straight through to the engine.
    enabled: bool,
    /// True while a run loop is draining a window; always false outside
    /// `next_event`/`ff_flush`, so external schedulers (fault injection
    /// between runs, the soak/rca harnesses, pipeline's own loop) always
    /// talk to the real engine.
    draining: bool,
    /// The engine's next pending event when the window opened. Events at
    /// or beyond it are never buffered.
    horizon: SimTime,
    /// Run-loop deadline (`run_until`): events beyond it must outlive the
    /// loop, so they go to the engine even when inside the horizon.
    bound: Option<SimTime>,
    lseq: u64,
    buf: BinaryHeap<Reverse<LocalEv>>,
    windows: u64,
    elided: u64,
    local_dispatched: u64,
}

impl FastForward {
    fn new(enabled: bool) -> Self {
        FastForward {
            enabled,
            draining: false,
            horizon: SimTime::ZERO,
            bound: None,
            lseq: 0,
            buf: BinaryHeap::new(),
            windows: 0,
            elided: 0,
            local_dispatched: 0,
        }
    }

    fn stats(&self) -> FfStats {
        FfStats {
            windows: self.windows,
            elided: self.elided,
            local_dispatched: self.local_dispatched,
        }
    }
}

/// The simulation.
pub struct ClusterSim {
    pub cfg: Config,
    pub topo: Cluster,
    pub engine: Engine<Event>,
    /// §Perf L6 fast-forward tier. Pure scheduling shortcut: holds no
    /// durable state between run loops (the buffer is flushed back into
    /// `engine` at every loop exit), so checkpoints never see it.
    ff: FastForward,
    pub rdma: RdmaNet,
    pub gpus: Vec<GpuUnit>,
    pub conns: Vec<Conn>,
    conn_by_key: HashMap<(usize, usize, usize), ConnId>,
    /// §Perf L5: completed transfers are recycled through this slab —
    /// bookkeeping is O(active transfers), not O(history).
    pub xfers: XferSlab,
    pub ops: Vec<Op>,
    qp_conn: HashMap<QpId, ConnId>,
    intra_flows: HashMap<FlowId, XferId>,
    pub monitor: Option<MonitorSet>,
    pub rings: Vec<Ring>,
    /// §Elastic: nodes currently perceived dead (every NIC port dark).
    /// Rings are built excluding these; connections touching them swallow
    /// failure completions instead of running a §3.3 failover that cannot
    /// help (the backup port sits on the same dead server).
    pub dead_nodes: Vec<bool>,
    pub mempools: Vec<MemPool>,
    pub stats: Stats,
    pub rng: Rng,
    /// Flight recorder handle (disabled unless `trace.enabled` or a shared
    /// sink is installed — see `rust/src/trace/`). Cloned into the RDMA
    /// and monitor layers at construction.
    pub tracer: Tracer,
    /// Op-level SM residency: one communication kernel per (op, GPU), not
    /// one per channel-transfer (Table 1's 2-SM inter-host default is per
    /// operation). (op, gpu) → (sms held, live transfer refcount).
    op_sms: HashMap<(usize, usize), (u32, u32)>,
    /// Incidents in the sink already carrying their live-transfer view
    /// (see [`ClusterSim::enrich_new_incidents`]). Pure trace-side state:
    /// excluded from checkpoints like everything else behind `tracer`.
    incidents_enriched: usize,
}

/// Per-GPU execution resources.
pub struct GpuUnit {
    pub compute: GpuCompute,
    pub ce: CopyEngines,
}

impl ClusterSim {
    pub fn new(cfg: Config) -> Self {
        // The fabric is built from the CONFIGURED rates — `net.link_gbps`
        // and `gpu.nvlink_gbps` flow through to link capacities (and the
        // 1:1 spine trunks derived from them) instead of hard-coded 400 /
        // 3600 build rates.
        let topo = Cluster::with_rates(cfg.topo.clone(), cfg.net.link_gbps, cfg.gpu.nvlink_gbps);
        let fabric = &topo.fabric;
        let tracer = Tracer::from_config(&cfg.trace);
        let mut rdma = RdmaNet::new(fabric, cfg.net.clone());
        rdma.set_tracer(tracer.clone());
        let n_ranks = topo.num_ranks();
        let gpus = (0..n_ranks)
            .map(|_| GpuUnit {
                compute: GpuCompute::new(cfg.gpu.clone()),
                ce: CopyEngines::new(cfg.gpu.num_copy_engines, cfg.gpu.copy_engine_setup_ns),
            })
            .collect();
        let rings = build_rings(&topo, cfg.vccl.channels.max(1));
        let policy = if cfg.vccl.lazy_mempool { AllocPolicy::LazyPool } else { AllocPolicy::Eager };
        let mempools = (0..n_ranks)
            .map(|_| {
                let mut m = MemPool::new(policy, cfg.vccl.zero_copy, cfg.vccl.chunk_bytes * 8);
                m.on_init(n_ranks - 1, cfg.vccl.channels);
                m
            })
            .collect();
        let monitor = if cfg.vccl.monitor {
            let mut m = MonitorSet::new(&cfg.vccl);
            m.set_tracer(tracer.clone());
            Some(m)
        } else {
            None
        };
        let seed = cfg.seed;
        let n_nodes = cfg.topo.num_nodes;
        let trailing_ns = cfg.vccl.trailing_ns.max(1);
        let bucket_ns = cfg.engine.bucket_ns;
        let fast_forward = cfg.engine.fast_forward;
        tracer.record(
            SimTime::ZERO,
            TraceEvent::SimStarted { nodes: cfg.topo.num_nodes, ranks: n_ranks },
        );
        ClusterSim {
            cfg,
            topo,
            engine: Engine::with_bucket_ns(bucket_ns),
            ff: FastForward::new(fast_forward),
            rdma,
            gpus,
            conns: Vec::new(),
            conn_by_key: HashMap::new(),
            xfers: XferSlab::new(),
            ops: Vec::new(),
            qp_conn: HashMap::new(),
            intra_flows: HashMap::new(),
            monitor,
            rings,
            dead_nodes: vec![false; n_nodes],
            mempools,
            stats: Stats {
                proxy_cpu_ns: vec![0; n_ranks],
                // Bucket the per-port completion traffic at the monitor's
                // trailing-window granularity (§Perf L4 bounded stats).
                port_traffic: crate::monitor::PortTraffic::new(trailing_ns),
                ..Default::default()
            },
            rng: Rng::new(seed),
            tracer,
            op_sms: HashMap::new(),
            incidents_enriched: 0,
        }
    }

    pub fn now(&self) -> SimTime {
        self.engine.now()
    }

    // ------------------------------------------------------------------
    // Connections
    // ------------------------------------------------------------------

    /// Get or create the connection (src → dst, channel). QPs for
    /// inter-node connections are created on first need (bootstrap).
    pub fn conn(&mut self, src: RankId, dst: RankId, channel: usize) -> ConnId {
        let key = (src.0, dst.0, channel);
        if let Some(&id) = self.conn_by_key.get(&key) {
            return id;
        }
        let locality = locality_of(&self.topo, src, dst);
        let id = ConnId(self.conns.len());
        let (primary, primary_port, backup, backup_port) = match locality {
            Locality::IntraNode => (None, None, None, None),
            _ => {
                // PXN: the payload leaves from the NIC rail-matched to the
                // destination's local index (relay GPU's NIC).
                let src_gpu = self.topo.gpu_of_rank(src);
                let dst_gpu = self.topo.gpu_of_rank(dst);
                let eff_src_gpu = if locality == Locality::InterPxn {
                    crate::topology::GpuId { node: src_gpu.node, local: dst_gpu.local }
                } else {
                    src_gpu
                };
                let p_port = self.topo.primary_port(eff_src_gpu);
                let d_port = self.topo.primary_port(dst_gpu);
                let p_qp = self.rdma.create_qp(&self.topo.fabric, p_port, d_port);
                self.qp_conn.insert(p_qp, id);
                // Static conn → QP → port bindings in the ring: the RCA
                // causal graph joins entities through these without
                // consulting live simulator state.
                self.tracer.record(
                    self.engine.now(),
                    TraceEvent::ConnBound {
                        conn: id.0,
                        qp: p_qp.0,
                        port: self.topo.fabric.port_ordinal(p_port),
                        backup: false,
                    },
                );
                let (b_qp, b_port) = if self.cfg.vccl.fault_tolerance {
                    let bp = self.topo.backup_port(eff_src_gpu);
                    let bd = self.topo.backup_port(dst_gpu);
                    let q = self.rdma.create_qp(&self.topo.fabric, bp, bd);
                    self.qp_conn.insert(q, id);
                    self.tracer.record(
                        self.engine.now(),
                        TraceEvent::ConnBound {
                            conn: id.0,
                            qp: q.0,
                            port: self.topo.fabric.port_ordinal(bp),
                            backup: true,
                        },
                    );
                    (Some(q), Some(bp))
                } else {
                    (None, None)
                };
                (Some(p_qp), Some(p_port), b_qp, b_port)
            }
        };
        let probe = if self.cfg.vccl.fault_tolerance && locality != Locality::IntraNode {
            Some(DeltaProbe::new(self.cfg.net.retry_window_ns(), self.cfg.vccl.delta_margin))
        } else {
            None
        };
        self.conns.push(Conn {
            id,
            src,
            dst,
            channel,
            locality,
            primary,
            primary_port,
            backup,
            backup_port,
            active: ActiveSide::Primary,
            pending: std::collections::VecDeque::new(),
            probe,
            failovers: 0,
            awaiting_failback: false,
            used: false,
        });
        self.conn_by_key.insert(key, id);
        id
    }

    // ------------------------------------------------------------------
    // Transfers
    // ------------------------------------------------------------------

    /// Create a transfer on (src→dst, channel) and start pumping chunks.
    pub fn start_xfer(&mut self, op: OpId, src: RankId, dst: RankId, channel: usize, bytes: u64)
        -> XferId {
        let conn_id = self.conn(src, dst, channel);
        let locality = self.conns[conn_id.0].locality;
        let profile = TransportProfile::resolve(&self.cfg, locality);
        let now = self.now();
        let chunk = self.cfg.vccl.chunk_bytes.min(bytes.max(1));
        let chunks_total = bytes.div_ceil(chunk).max(1);

        // Lazy-mempool first-use accounting.
        if !self.conns[conn_id.0].used {
            self.conns[conn_id.0].used = true;
            self.mempools[src.0].on_first_use(dst.0, channel);
        }

        // Acquire the transport's SM residency: one comm kernel per
        // (op, GPU) — channel transfers of the same op share it.
        let (sms_src, sms_dst) = (profile.src_sms, profile.dst_sms);
        self.op_sm_acquire(op, src.0, sms_src, now);
        self.op_sm_acquire(op, dst.0, sms_dst, now);

        let setup = profile.setup_ns;
        let xid = self.xfers.insert(|id, seq| Xfer {
            id,
            seq,
            op,
            channel,
            conn: conn_id,
            bytes,
            chunk_bytes: chunk,
            chunks_total,
            send: SendPointers::default(),
            recv: RecvPointers::default(),
            fifo: SyncFifo::default(),
            profile,
            locality,
            stage_free_at: now + SimTime::ns(setup),
            sms_src,
            sms_dst,
            stall_ns: 0,
            wire_chunks: 0,
            done: false,
            started_at: now,
            finished_at: None,
        });
        self.conns[conn_id.0].pending.push_back(xid);
        // Only the queue head transmits; followers wait their turn.
        if self.conns[conn_id.0].pending.len() == 1 {
            self.pump_xfer(xid);
        }
        xid
    }

    /// Sender-side pipeline: stage (copy/launch/sync) the next chunks into
    /// flight, respecting the CTS slot window.
    fn pump_xfer(&mut self, xid: XferId) {
        const SLOTS: u64 = 8; // NCCL FIFO depth / CTS credits
        let now = self.now();
        loop {
            let Some(x) = self.xfers.get(xid) else { return };
            if x.done || x.send.posted >= x.chunks_total || x.inflight() >= SLOTS {
                return;
            }
            let chunk = x
                .chunk_bytes
                .min(x.bytes.saturating_sub(x.send.posted * x.chunk_bytes))
                .max(1);
            let src = self.conns[x.conn.0].src;
            let base = now.max(x.stage_free_at);
            // When the chunk becomes postable, per data path.
            let ready_at = if x.locality == Locality::IntraNode {
                match x.profile.intra_path {
                    // cudaMemcpy through a copy engine: admission queueing
                    // + setup latency; the byte movement itself is the
                    // NVLink flow started at ChunkReady.
                    DataPath::CopyEngine => {
                        let busy = (chunk as f64
                            / (self.cfg.gpu.nvlink_gbps * 0.125 * x.profile.intra_efficiency))
                            as u64;
                        let grant = self.gpus[src.0].ce.admit(base, busy);
                        self.stats.ce_ops += 1;
                        grant.start_at
                    }
                    // SM copy kernel streams chunks back-to-back.
                    _ => base,
                }
            } else {
                let stage_ns = match x.profile.stage {
                    None | Some(DataPath::ZeroCopy) => 0,
                    Some(DataPath::SmStaged) => {
                        // SM copy app→chunk buffer at HBM rate.
                        (chunk as f64 / (self.cfg.gpu.hbm_gbps * 0.125)) as u64
                    }
                    Some(DataPath::CopyEngine) => {
                        // PXN relay: NVLink-rate CE copy to the rail GPU.
                        let busy = (chunk as f64
                            / (self.cfg.gpu.nvlink_gbps * 0.125 * x.profile.intra_efficiency))
                            as u64;
                        let grant = self.gpus[src.0].ce.admit(base, busy);
                        self.stats.ce_ops += 1;
                        (grant.start_at + SimTime::ns(busy)).since(base).as_ns()
                    }
                };
                base + SimTime::ns(stage_ns + x.profile.per_chunk_sync_ns)
            };
            let x = self.xfers.get_mut(xid).expect("pumped transfer is live");
            x.stage_free_at = ready_at;
            x.send.posted += 1;
            // Proxy CPU cost per chunk (Fig 17: SM-free shifts work to CPU).
            let proxy_ns = match self.cfg.vccl.transport {
                Transport::SmFree => 1_200,
                Transport::NcclxLike => 900,
                Transport::Kernel => 700,
            };
            self.stats.proxy_cpu_ns[src.0] += proxy_ns;
            self.sched_at(ready_at, Event::ChunkReady { xfer: xid });
        }
    }

    /// A staged chunk is ready: put it on the wire (QP or NVLink flow).
    fn on_chunk_ready(&mut self, xid: XferId) {
        let now = self.now();
        // §Perf L5 stale-id gate: a ChunkReady queued before the transfer
        // finished can fire after its slot was recycled — the generation
        // mismatch (or, in retain-everything mode, the `done` record)
        // makes it the same no-op instead of driving the new occupant.
        let (conn_id, op, chunk, seq, intra_efficiency, recv_copy) = {
            let Some(x) = self.xfers.get(xid) else { return };
            if x.done || x.send.transmitted >= x.chunks_total {
                return;
            }
            let chunk = x
                .chunk_bytes
                .min(x.bytes.saturating_sub(x.send.transmitted * x.chunk_bytes))
                .max(1);
            (x.conn, x.op, chunk, x.seq, x.profile.intra_efficiency, x.profile.recv_copy)
        };
        let conn = &self.conns[conn_id.0];
        match conn.locality {
            Locality::IntraNode => {
                let src_gpu = self.topo.gpu_of_rank(conn.src);
                let dst_gpu = self.topo.gpu_of_rank(conn.dst);
                let path = self.topo.fabric.path_nvlink(src_gpu, dst_gpu);
                // SM copies move fewer bytes/s on the same link: inflate the
                // byte count by 1/efficiency (time-equivalent).
                let eff_bytes = (chunk as f64 / intra_efficiency) as u64;
                // Handshake tail: device-side flag for the copy kernel,
                // shared-memory P2pRegInfo flags for the CE path (§3.2-1).
                let tail = match self.cfg.vccl.transport {
                    Transport::Kernel => 500,
                    _ => 300,
                };
                // Flow metadata carries the transfer's stable `seq`, not
                // its recyclable slot index (§Perf L5 identity).
                let (flow, timers) = self.rdma.flows.start(
                    now,
                    path,
                    eff_bytes,
                    tail,
                    crate::net::FlowMeta(seq),
                );
                self.intra_flows.insert(flow, xid);
                for t in timers {
                    self.sched_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
                }
                let x = self.xfers.get_mut(xid).expect("transfer is live");
                x.send.transmitted += 1;
                x.wire_chunks += 1;
            }
            _ => {
                let Some(mut qp) = conn.active_qp() else { return };
                // Posting to an errored QP would silently flush: perceive
                // the failure NOW and post on the freshly-activated backup.
                if self.rdma.qp_state(qp) == QpState::Error {
                    self.on_conn_failure(conn_id, qp);
                    match self.conns[conn_id.0].active_qp() {
                        Some(q) if self.rdma.qp_state(q) == QpState::Rts => qp = q,
                        _ => {
                            // Both paths dead (§6 limitation): the op hangs.
                            if !self.ops[op.0].failed {
                                self.ops[op.0].failed = true;
                                self.stats.hung_ops += 1;
                            }
                            return;
                        }
                    }
                }
                let extra_tail = if recv_copy {
                    // Receiver chunk→app copy + its poll.
                    (chunk as f64 / (self.cfg.gpu.hbm_gbps * 0.125)) as u64
                        + self.cfg.gpu.gpu_cpu_poll_ns
                } else {
                    0
                };
                let (_wr, out) = self.rdma.post_send(qp, chunk, now, extra_tail);
                {
                    let x = self.xfers.get_mut(xid).expect("transfer is live");
                    x.send.transmitted += 1;
                    x.wire_chunks += 1;
                }
                // Arm the receiver's δ-probe (case 2) on first outstanding.
                let deadline = self.conns[conn_id.0]
                    .probe
                    .as_mut()
                    .and_then(|p| p.arm(now));
                if let Some((at, epoch)) = deadline {
                    self.sched_at(at, Event::DeltaCheck { conn: conn_id, epoch });
                }
                self.absorb(out);
            }
        }
    }

    /// Schedule NetOutput items into the engine and route WCs.
    fn absorb(&mut self, out: crate::net::rdma::NetOutput) {
        for t in out.timers {
            self.sched_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
        }
        for (qp, epoch, at) in out.retry_deadlines {
            self.sched_at(at, Event::QpRetry { qp, epoch });
        }
        for (qp, at) in out.warmups {
            self.sched_at(at, Event::QpWarm { qp });
        }
        for wc in out.wcs {
            self.on_wc(wc);
        }
    }

    // ------------------------------------------------------------------
    // Completions
    // ------------------------------------------------------------------

    fn on_wc(&mut self, wc: WorkCompletion) {
        let Some(&conn_id) = self.qp_conn.get(&wc.qp) else { return };
        let conn = &self.conns[conn_id.0];
        match wc.status {
            CompletionStatus::Success => {
                // Successful chunks count whichever QP carried them: after
                // failback the backup QP drains its in-flight window while
                // new chunks already flow on the primary.
                let port = self.rdma.qp_src(wc.qp);
                let ordinal = self.topo.fabric.port_ordinal(port);
                if let Some(mon) = &mut self.monitor {
                    // §Perf L4: the remaining-to-send signal (§3.4 cond ii)
                    // is an O(1) counter read, and only the monitor needs it.
                    let backlog = self.rdma.port_backlog_bytes(port);
                    let _ = mon.on_wc(ordinal, wc.posted_at, wc.completed_at, wc.bytes, backlog);
                }
                self.stats.port_traffic.record(wc.completed_at.as_ns(), ordinal, wc.bytes);
                self.stats.wire_bytes += wc.bytes;
                let Some(xid) = conn.cur_xfer() else { return };
                self.on_chunk_complete(xid, conn_id);
            }
            CompletionStatus::RetryExceeded => {
                // Case 1 (§3.3): the sender's own WC error. `probe_dead`
                // deliberately does NOT move here — it counts only case-2
                // δ-probe LinkDead verdicts (see `on_delta_check`); case-1
                // failovers are visible as `stats.failovers`.
                self.on_conn_failure(conn_id, wc.qp);
            }
            CompletionStatus::WrFlushed => {
                // Flushed WRs of a failed-over QP: already rolled back by
                // pointer migration — ignore.
            }
        }
    }

    fn on_chunk_complete(&mut self, xid: XferId, conn_id: ConnId) {
        let now = self.now();
        let more = {
            let Some(x) = self.xfers.get_mut(xid) else { return };
            if x.done {
                return;
            }
            x.send.acked += 1;
            x.recv.received += 1;
            x.recv.done += 1;
            x.recv.posted = x.recv.posted.max(x.recv.done);
            x.send.acked < x.chunks_total
        };
        // Progress the δ-probe.
        let redeadline = self.conns[conn_id.0]
            .probe
            .as_mut()
            .and_then(|p| p.on_progress(now, more));
        if let Some((at, epoch)) = redeadline {
            self.sched_at(at, Event::DeltaCheck { conn: conn_id, epoch });
        }
        if more {
            self.pump_xfer(xid);
        } else {
            self.finish_xfer(xid);
        }
    }

    fn finish_xfer(&mut self, xid: XferId) {
        let now = self.now();
        let (conn_id, op, channel, sms_src, sms_dst) = {
            let x = self.xfers.get_mut(xid).expect("finishing a live transfer");
            x.done = true;
            x.finished_at = Some(now);
            (x.conn, x.op, x.channel, x.sms_src, x.sms_dst)
        };
        // §Perf L5: fold the completed transfer into its op's per-channel
        // roll-up BEFORE the record is recycled — reports, benches and the
        // OpFinished trace event read these, never retired `Xfer`s.
        {
            let x = self.xfers.get(xid).expect("just finished");
            self.ops[op.0].chan_rollup[channel].fold(x, now);
        }
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        self.debug_check_rollup(op, channel);
        let (src, dst, next) = {
            let c = &mut self.conns[conn_id.0];
            debug_assert_eq!(c.pending.front(), Some(&xid));
            c.pending.pop_front();
            if let Some(p) = c.probe.as_mut() {
                p.disarm();
            }
            (c.src, c.dst, c.pending.front().copied())
        };
        // Wake the next queued transfer on this connection.
        if let Some(n) = next {
            self.pump_xfer(n);
        }
        self.op_sm_release(op, src.0, sms_src, now);
        self.op_sm_release(op, dst.0, sms_dst, now);
        // §Perf L5: the figures are folded — recycle the slot (bumping its
        // generation so queued stale ids are detected). The next step's
        // transfers reuse it, which is what keeps bookkeeping O(active).
        self.xfers.retire(xid);
        self.on_xfer_done(op, channel);
    }

    /// Debug cross-check (§Perf L5): in retain-everything reference mode,
    /// the incremental roll-up must equal a recomputation over the
    /// retained records at every fold. Bounded — rescanning is skipped
    /// once the retained set outgrows a cheap cap (the randomized
    /// equivalence test pins large runs end-to-end instead).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    fn debug_check_rollup(&self, op: OpId, channel: usize) {
        if !self.xfers.retain_all() || self.xfers.mem_stats().retired > 4_096 {
            return;
        }
        let mut reference = ChanRollup::default();
        for x in self
            .xfers
            .iter_retained()
            .filter(|x| x.done && x.op == op && x.channel == channel)
        {
            reference.fold(x, x.finished_at.expect("done transfers carry a finish time"));
        }
        assert_eq!(
            reference, self.ops[op.0].chan_rollup[channel],
            "roll-up diverged from the retained records for op {} channel {}",
            op.0, channel
        );
    }

    /// Refcounted op-level comm-kernel SM acquisition.
    fn op_sm_acquire(&mut self, op: OpId, gpu: usize, sms: u32, now: SimTime) {
        if sms == 0 {
            return;
        }
        let entry = self.op_sms.entry((op.0, gpu)).or_insert((0, 0));
        if entry.1 == 0 {
            entry.0 = sms;
            entry.1 = 1;
            self.stats.comm_kernel_launches += 1;
            for t in self.gpus[gpu].compute.acquire_comm_sms(sms, now) {
                self.sched_at(t.at, Event::GpuTask { gpu, task: t.task, gen: t.gen });
            }
        } else {
            entry.1 += 1;
        }
    }

    fn op_sm_release(&mut self, op: OpId, gpu: usize, sms: u32, now: SimTime) {
        if sms == 0 {
            return;
        }
        let Some(entry) = self.op_sms.get_mut(&(op.0, gpu)) else { return };
        entry.1 -= 1;
        if entry.1 == 0 {
            let held = entry.0;
            self.op_sms.remove(&(op.0, gpu));
            for t in self.gpus[gpu].compute.release_comm_sms(held, now) {
                self.sched_at(t.at, Event::GpuTask { gpu, task: t.task, gen: t.gen });
            }
        }
    }

    // ------------------------------------------------------------------
    // Fault tolerance (§3.3)
    // ------------------------------------------------------------------

    /// A QP surfaced a retry-exceeded error: fail over to the backup QP (if
    /// any), or mark the op as hung (the NCCL baseline behaviour).
    fn on_conn_failure(&mut self, conn_id: ConnId, failed_qp: QpId) {
        let now = self.now();
        // §Elastic: a connection with an endpoint on a crashed node is
        // past saving — its backup port sits on the same dead server, so
        // a §3.3 failover cannot help. Ring transfers were aborted and
        // requeued by the shrink; a straggler surfacing here is a P2P
        // aimed at the dead node, which has nowhere to requeue (§6
        // limitation) and fails like the baseline hang.
        if self.conn_on_dead_node(conn_id) {
            if let Some(xid) = self.conns[conn_id.0].cur_xfer() {
                self.abort_xfer_record(xid);
            }
            return;
        }
        let conn = &self.conns[conn_id.0];
        let error_port = if Some(failed_qp) == conn.primary {
            conn.primary_port
        } else {
            conn.backup_port
        };
        let has_backup = conn.backup.is_some() && Some(failed_qp) == conn.primary;
        let cur = conn.cur_xfer();
        if cur.is_none() {
            // Idle connection: switch to the backup right away so the next
            // transfer posts on a live QP, and start warming the primary.
            if has_backup {
                let c = &mut self.conns[conn_id.0];
                c.active = ActiveSide::Backup;
                c.awaiting_failback = true;
                c.failovers += 1;
                self.stats.failovers += 1;
                let out = self.rdma.reset_to_rts(failed_qp, now);
                self.absorb(out);
            }
            return;
        }
        let xid = cur.unwrap();
        if !has_backup {
            // No backup (NCCL baseline, or the backup itself died): the
            // collective hangs — the failure mode Fig 13b shows for NCCL.
            let op = self.xfers.get(xid).expect("current transfer is live").op;
            if !self.ops[op.0].failed {
                self.ops[op.0].failed = true;
                self.stats.hung_ops += 1;
            }
            return;
        }

        // --- VCCL failover ---
        // 1. Migrate pointers to the breakpoint (Fig 8). The traced variant
        //    also freezes a `failover-conn<N>-port<P>` incident snapshot,
        //    so the PortDown → FlowStalled → QpError chain leading here
        //    survives ring eviction on long runs (the port suffix + the
        //    event's xfer/port payload are what RCA joins on).
        let window_ns = self.cfg.net.retry_window_ns();
        let error_ordinal = error_port.map(|p| self.topo.fabric.port_ordinal(p));
        let (rolled_back, xfer_seq) = {
            let x = self.xfers.get_mut(xid).expect("current transfer is live");
            let seq = x.seq;
            let lost = migrate_to_breakpoint_traced(
                &mut x.send,
                &mut x.recv,
                &mut x.fifo,
                &self.tracer,
                now,
                conn_id.0,
                seq,
                error_ordinal,
            );
            x.fifo.error_port = error_port;
            // The transfer rode out one hardware retransmission window
            // before this failover fired — folded into the roll-up's
            // `stall_ns` at finish.
            x.stall_ns += window_ns;
            (lost, x.seq)
        };
        // 2. Switch to the backup QP.
        {
            let c = &mut self.conns[conn_id.0];
            c.active = ActiveSide::Backup;
            c.awaiting_failback = true;
            c.failovers += 1;
            if let Some(p) = c.probe.as_mut() {
                p.disarm();
            }
        }
        self.stats.failovers += 1;
        // 3. Proactively reset the dead primary so its warm-up overlaps the
        //    failover period (§3.3 "recovery of normal QPs").
        let out = self.rdma.reset_to_rts(failed_qp, now);
        self.absorb(out);
        // 4. Re-post the rolled-back window on the backup QP (breakpoint
        //    retransmission). The chunks were already staged — only the
        //    proxy's ibv_post_send needs to re-run, so a small CPU delay.
        for i in 0..rolled_back {
            self.sched_at(
                now + SimTime::ns(2_000 + i * 500),
                Event::ChunkReady { xfer: xid },
            );
        }
        // The transfer's data flow resumes on the backup QP (breakpoint
        // retransmission): the "resume" leg of the failover causal chain.
        // Scope "xfer": the id is a transfer's stable creation ordinal
        // (§Perf L5) — slot indices are recycled, seqs never are.
        self.tracer
            .record(now, TraceEvent::FlowResumed { flow: xfer_seq, scope: "xfer" });
        // Path death distinct from port death (§Fault domains): the error
        // port never flapped, but a trunk or switch on the primary path is
        // dead. Name the killing link so RCA can join this migration to
        // the TrunkDegraded/SwitchDown fault window.
        if error_port.is_some_and(|p| self.topo.fabric.port_up(p)) {
            if let Some(l) = self.rdma.qp_first_dead_link(failed_qp, &self.topo.fabric) {
                self.tracer.record(
                    now,
                    TraceEvent::PathMigrated { conn: conn_id.0, xfer: xfer_seq, link: l.0 },
                );
            }
        }
        // 5. Resume normal pumping for not-yet-staged chunks.
        self.pump_xfer(xid);
    }

    /// δ-timeout double-check (case 2).
    fn on_delta_check(&mut self, conn_id: ConnId, epoch: u32) {
        let now = self.now();
        let conn = &self.conns[conn_id.0];
        if conn.cur_xfer().is_none() {
            // Nothing in flight: the probe must not keep re-arming.
            if let Some(p) = self.conns[conn_id.0].probe.as_mut() {
                p.disarm();
            }
            return;
        }
        let conn = &self.conns[conn_id.0];
        let Some(qp) = conn.active_qp() else { return };
        let link_alive = {
            let path = self.rdma.qp_path_up(qp, &self.topo.fabric);
            path
        };
        let Some(probe) = self.conns[conn_id.0].probe.as_mut() else { return };
        match probe.check(epoch, now, link_alive) {
            ProbeVerdict::NotDue => {}
            ProbeVerdict::SenderStalled => {
                self.stats.probe_benign += 1;
                if let Some((at, e)) = self.conns[conn_id.0].probe.as_ref().unwrap().next_deadline()
                {
                    self.sched_at(at, Event::DeltaCheck { conn: conn_id, epoch: e });
                }
            }
            ProbeVerdict::LinkDead => {
                self.stats.probe_dead += 1;
                // Receiver generates a local WC error → same failover path.
                self.on_conn_failure(conn_id, qp);
            }
        }
    }

    /// Port state change entry points (failure injection).
    pub fn inject_port_down(&mut self, port: PortId, at: SimTime) {
        self.sched_at(at, Event::PortDown { port });
    }

    pub fn inject_port_up(&mut self, port: PortId, at: SimTime) {
        self.sched_at(at, Event::PortUp { port });
    }

    /// Fabric fault entry points (§Fault domains): a trunk link dying with
    /// both endpoint ports still up, or a whole switch cascading to every
    /// member link.
    pub fn inject_trunk_down(&mut self, link: LinkId, at: SimTime) {
        self.sched_at(at, Event::TrunkDown { link });
    }

    pub fn inject_trunk_up(&mut self, link: LinkId, at: SimTime) {
        self.sched_at(at, Event::TrunkUp { link });
    }

    pub fn inject_switch_down(&mut self, switch: usize, at: SimTime) {
        self.sched_at(at, Event::SwitchDown { switch });
    }

    pub fn inject_switch_up(&mut self, switch: usize, at: SimTime) {
        self.sched_at(at, Event::SwitchUp { switch });
    }

    /// Node fault entry points (§Elastic): a whole server crashes — every
    /// NIC port it owns goes dark at once — or recovers.
    pub fn inject_node_down(&mut self, node: usize, at: SimTime) {
        self.sched_at(at, Event::NodeDown { node });
    }

    pub fn inject_node_up(&mut self, node: usize, at: SimTime) {
        self.sched_at(at, Event::NodeUp { node });
    }

    fn on_port_state(&mut self, port: PortId, up: bool) {
        let now = self.now();
        let ordinal = self.topo.fabric.port_ordinal(port);
        self.tracer.record(
            now,
            if up { TraceEvent::PortUp { port: ordinal } } else { TraceEvent::PortDown { port: ordinal } },
        );
        self.topo.fabric.set_port_up(port, up);
        let out = self.rdma.set_port_up(&self.topo.fabric, port, up, now);
        self.absorb(out);
        if up {
            self.failback_sweep();
        }
    }

    /// A trunk link died or healed while both endpoint NIC ports stayed up:
    /// path death, perceived through the retry windows `set_links_up` arms
    /// on every crossing QP (case 1) or the δ-probe's whole-path CTS check
    /// (case 2) — never through a port flap.
    fn on_trunk_state(&mut self, link: LinkId, up: bool) {
        let now = self.now();
        let switch = self.topo.fabric.switch_of_link(link).unwrap_or(usize::MAX);
        let gbps = self.rdma.flows.link_capacity_bpns(link) * 8.0;
        self.tracer.record(
            now,
            if up {
                TraceEvent::TrunkRestored { link: link.0, switch, gbps }
            } else {
                TraceEvent::TrunkDegraded { link: link.0, switch, gbps: 0.0, was_gbps: gbps }
            },
        );
        self.topo.fabric.set_link_up(link, up);
        let out = self.rdma.set_links_up(&[link], up, now);
        self.absorb(out);
        if up {
            self.failback_sweep();
        }
    }

    /// A whole switch (leaf or spine plane) died or healed: cascade to its
    /// member links in one shot, then let the same path-death machinery
    /// fail every crossing connection over to the backup plane.
    fn on_switch_state(&mut self, switch: usize, up: bool) {
        let now = self.now();
        self.tracer.record(
            now,
            if up { TraceEvent::SwitchUp { switch } } else { TraceEvent::SwitchDown { switch } },
        );
        let members = self.topo.fabric.set_switch_up(switch, up);
        let out = self.rdma.set_links_up(&members, up, now);
        self.absorb(out);
        if up {
            self.failback_sweep();
        }
    }

    // ------------------------------------------------------------------
    // Elastic node fault tolerance (§Elastic)
    // ------------------------------------------------------------------

    /// A whole node crashed or recovered. Down: cascade every NIC port the
    /// node owns dark (peers escalate per-QP path death to node-death
    /// perception — every port of the peer is gone, so no backup plane can
    /// help), then shrink the world: abort and requeue in-flight ring
    /// steps and rebuild the rings without the victim. Up: restore the
    /// ports, re-warm the flushed QPs (deferred re-entry, §3.3-style), and
    /// rebuild full-membership rings. With `elastic.enabled = false` the
    /// cascade still happens but nothing shrinks — crossing ops hang, the
    /// non-elastic baseline.
    fn on_node_state(&mut self, node: usize, up: bool) {
        let now = self.now();
        self.tracer.record(
            now,
            if up { TraceEvent::NodeUp { node } } else { TraceEvent::NodeDown { node } },
        );
        let was_dead = self.dead_nodes.get(node).copied().unwrap_or(false);
        let elastic = self.cfg.elastic.enabled && node < self.dead_nodes.len();
        let members = self.topo.fabric.set_node_up(node, up);
        if !up && elastic {
            // Mark BEFORE the link teardown: any completion surfacing from
            // it must already hit the dead-node guard in `on_conn_failure`.
            self.dead_nodes[node] = true;
        }
        if up {
            if let Some(d) = self.dead_nodes.get_mut(node) {
                *d = false;
            }
        }
        let out = self.rdma.set_links_up(&members, up, now);
        self.absorb(out);
        if up {
            if elastic && was_dead {
                self.elastic_rejoin(node);
            }
            self.failback_sweep();
        } else if elastic && !was_dead {
            self.elastic_shrink(node);
        }
    }

    /// Does this rank sit on a node currently perceived dead?
    pub(super) fn rank_on_dead_node(&self, rank: usize) -> bool {
        let per = self.cfg.topo.gpus_per_node.max(1);
        self.dead_nodes.get(rank / per).copied().unwrap_or(false)
    }

    /// Does either endpoint of the connection sit on a dead node?
    fn conn_on_dead_node(&self, conn_id: ConnId) -> bool {
        let c = &self.conns[conn_id.0];
        self.rank_on_dead_node(c.src.0) || self.rank_on_dead_node(c.dst.0)
    }

    /// Absorb a `NetOutput` DROPPING its completions: the elastic shrink
    /// owns the aborted transfers' fate, so the teardown's
    /// RetryExceeded/flush completions must not re-enter the §3.3 failover
    /// path. Re-rate timers, retry deadlines and warm-ups still schedule.
    fn absorb_sans_wcs(&mut self, out: crate::net::rdma::NetOutput) {
        for t in out.timers {
            self.sched_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
        }
        for (qp, epoch, at) in out.retry_deadlines {
            self.sched_at(at, Event::QpRetry { qp, epoch });
        }
        for (qp, at) in out.warmups {
            self.sched_at(at, Event::QpWarm { qp });
        }
    }

    /// Drop one unfinished transfer (§Elastic): detach it from its
    /// connection's FIFO, release the op's SM residency, fail a stranded
    /// P2P, and recycle the slab slot without folding a roll-up.
    fn abort_xfer_record(&mut self, xid: XferId) {
        let now = self.now();
        let Some(x) = self.xfers.get(xid) else { return };
        let (conn_id, op, sms_src, sms_dst) = (x.conn, x.op, x.sms_src, x.sms_dst);
        let (src, dst) = (self.conns[conn_id.0].src, self.conns[conn_id.0].dst);
        {
            let c = &mut self.conns[conn_id.0];
            c.pending.retain(|&q| q != xid);
            if let Some(p) = c.probe.as_mut() {
                p.disarm();
            }
        }
        self.op_sm_release(op, src.0, sms_src, now);
        self.op_sm_release(op, dst.0, sms_dst, now);
        if self.ops[op.0].p2p.is_some() && !self.ops[op.0].failed {
            self.ops[op.0].failed = true;
            self.stats.hung_ops += 1;
        }
        self.xfers.abort(xid);
    }

    /// §Elastic shrink: abort every in-flight transfer stranded by the
    /// dead node — ring-collective steps (a ring spans every node, so
    /// every channel crosses the victim) and P2P transfers with an
    /// endpoint on it — then rebuild the rings without the node and
    /// requeue the aborted steps on them. Transfers not crossing the
    /// victim (P2P between survivors) keep running untouched.
    fn elastic_shrink(&mut self, node: usize) {
        let now = self.now();
        let per = self.cfg.topo.gpus_per_node.max(1);
        // 1. Classify live transfers (ascending slot order: deterministic).
        let mut abort: Vec<XferId> = Vec::new();
        let mut requeue: Vec<(OpId, usize)> = Vec::new();
        for x in self.xfers.iter_live() {
            if self.ops[x.op.0].p2p.is_some() {
                let c = &self.conns[x.conn.0];
                if c.src.0 / per != node && c.dst.0 / per != node {
                    continue; // non-crossing P2P: untouched (pinned by test)
                }
            } else if !requeue.contains(&(x.op, x.channel)) {
                requeue.push((x.op, x.channel));
            }
            abort.push(x.id);
        }
        // 2. Kill the NVLink flows of aborted transfers. The map iterates
        //    in hash order, so sort the doomed flows before killing them —
        //    re-rate passes must run in a reproducible order.
        let doomed: std::collections::HashSet<XferId> = abort.iter().copied().collect();
        let mut dead_flows: Vec<FlowId> = self
            .intra_flows
            .iter()
            .filter(|(_, x)| doomed.contains(x))
            .map(|(&f, _)| f)
            .collect();
        dead_flows.sort_unstable_by_key(|f| f.0);
        for f in dead_flows {
            self.intra_flows.remove(&f);
            for t in self.rdma.flows.kill(f, now) {
                self.sched_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
            }
        }
        // 3. Detach the aborted transfers, remembering the connections
        //    whose ACTIVE transfer went away: their wire state must flush
        //    and a surviving queued follower must be woken.
        let mut repump: Vec<ConnId> = Vec::new();
        for &xid in &abort {
            let conn_id = self.xfers.get(xid).expect("aborting a live transfer").conn;
            if self.conns[conn_id.0].cur_xfer() == Some(xid) && !repump.contains(&conn_id) {
                repump.push(conn_id);
            }
            self.abort_xfer_record(xid);
        }
        // 4. Flush wire state on the interrupted connections: drive the
        //    active QP to the error state (dropping its teardown
        //    completions — the shrink owns these transfers), then restart
        //    it toward RTS unless it sits on the dead node (those re-warm
        //    at rejoin instead), and wake the new FIFO front.
        for conn_id in repump {
            if self.conns[conn_id.0].locality != Locality::IntraNode {
                if let Some(qp) = self.conns[conn_id.0].active_qp() {
                    let out = self.rdma.force_error(qp, now);
                    self.absorb_sans_wcs(out);
                    if !self.conn_on_dead_node(conn_id) {
                        let out = self.rdma.reset_to_rts(qp, now);
                        self.absorb(out);
                    }
                }
            }
            if let Some(next) = self.conns[conn_id.0].cur_xfer() {
                self.pump_xfer(next);
            }
        }
        // 5. Rebuild the rings over the survivors and requeue the aborted
        //    steps on them. The step index is untouched: the interrupted
        //    step re-runs from its start on the shrunk ring.
        self.rebuild_rings();
        let delay = SimTime::ns(self.cfg.elastic.requeue_delay_ns.max(1));
        for (op, channel) in requeue {
            self.tracer.record(now, TraceEvent::OpRequeued { op: op.0, channel });
            self.stats.ops_requeued += 1;
            self.sched_at(now + delay, Event::OpStep { op, channel });
        }
        self.stats.elastic_shrinks += 1;
    }

    /// §Elastic rejoin: the node's ports are back. Re-warm every QP the
    /// crash teardown flushed (traffic re-enters only at full-rate
    /// hardware — the same QpWarm gating failback uses) and rebuild the
    /// rings to full membership. In-flight steps on the shrunk rings keep
    /// running; the next `OpStep` of each channel picks up the full ring.
    fn elastic_rejoin(&mut self, node: usize) {
        let now = self.now();
        let per = self.cfg.topo.gpus_per_node.max(1);
        let resets: Vec<QpId> = self
            .conns
            .iter()
            .filter(|c| c.src.0 / per == node || c.dst.0 / per == node)
            .flat_map(|c| [c.primary, c.backup])
            .flatten()
            .filter(|&qp| self.rdma.qp_state(qp) == QpState::Error)
            .collect();
        for qp in resets {
            let out = self.rdma.reset_to_rts(qp, now);
            self.absorb(out);
        }
        self.rebuild_rings();
        self.stats.elastic_rejoins += 1;
    }

    /// Rebuild the channel rings over the current (surviving) membership
    /// and record the new world size.
    fn rebuild_rings(&mut self) {
        self.rings =
            build_rings_excluding(&self.topo, self.cfg.vccl.channels.max(1), &self.dead_nodes);
        let ranks = self.rings.first().map_or(0, |r| r.order.len());
        self.tracer.record(
            self.now(),
            TraceEvent::RingRebuilt { channels: self.rings.len(), ranks },
        );
    }

    /// Failback check over every connection waiting on a healed path: any
    /// of them may return once its (proactively reset) primary QP is warm.
    fn failback_sweep(&mut self) {
        let candidates: Vec<ConnId> =
            self.conns.iter().filter(|c| c.awaiting_failback).map(|c| c.id).collect();
        for cid in candidates {
            self.try_failback(cid);
        }
    }

    fn try_failback(&mut self, conn_id: ConnId) {
        let now = self.now();
        let c = &self.conns[conn_id.0];
        let (Some(pqp), Some(_pport)) = (c.primary, c.primary_port) else { return };
        // The WHOLE primary path must be healthy — the failed port may be
        // on either end (or a trunk), not just the local NIC.
        if self.rdma.qp_state(pqp) != QpState::Rts
            || !self.rdma.qp_path_up(pqp, &self.topo.fabric)
        {
            return;
        }
        if !self.rdma.is_warm(pqp, now) {
            // Will fire again on the QpWarm event.
            return;
        }
        let c = &mut self.conns[conn_id.0];
        c.active = ActiveSide::Primary;
        c.awaiting_failback = false;
        self.stats.failbacks += 1;
        self.tracer.record(now, TraceEvent::Failback { conn: conn_id.0 });
        // New chunks flow on the primary from here on; re-pump in case the
        // transfer throttled down on the backup.
        if let Some(xid) = self.conns[conn_id.0].cur_xfer() {
            self.pump_xfer(xid);
        }
    }

    // ------------------------------------------------------------------
    // Event loop
    // ------------------------------------------------------------------

    /// The one scheduling entry point for all simulation events. With the
    /// fast-forward tier off (or outside a drain window) this is exactly
    /// `engine.schedule_at`. Inside a window, events strictly before the
    /// horizon (and within the run deadline) are buffered locally instead
    /// of round-tripping through the global queue — the steady-state
    /// chunk/flow/WC chatter that dominates large presets.
    pub(crate) fn sched_at(&mut self, at: SimTime, ev: Event) {
        if self.ff.draining
            && at < self.ff.horizon
            && self.ff.bound.map_or(true, |d| at <= d)
        {
            let lseq = self.ff.lseq;
            self.ff.lseq += 1;
            self.ff.elided += 1;
            self.ff.buf.push(Reverse(LocalEv { at, lseq, ev }));
        } else {
            self.engine.schedule_at(at, ev);
        }
    }

    /// Pop the next event to dispatch, in global time order. Drains the
    /// fast-forward buffer first (every buffered event precedes the
    /// horizon, i.e. the engine's next pending event); once it is empty,
    /// pops the engine and — if the tier is enabled — opens the next
    /// window at the new engine head. `deadline` leaves later events
    /// pending (the `run_until` contract).
    fn next_event(&mut self, deadline: Option<SimTime>) -> Option<(SimTime, Event)> {
        if self.ff.draining {
            if let Some(Reverse(l)) = self.ff.buf.pop() {
                // Keep the engine clock in lock-step with locally
                // dispatched events: handlers read `now()` from it, and
                // `l.at` precedes every engine-pending event by
                // construction, so this can never skip one.
                self.engine.advance_to(l.at);
                self.ff.local_dispatched += 1;
                return Some((l.at, l.ev));
            }
            self.ff.draining = false;
        }
        let t = self.engine.peek_time()?;
        if deadline.is_some_and(|d| t > d) {
            return None;
        }
        let (at, ev) = self.engine.pop().expect("peeked event must pop");
        if self.ff.enabled {
            self.ff.horizon = self.engine.peek_time().unwrap_or(SimTime::ns(u64::MAX));
            self.ff.bound = deadline;
            self.ff.draining = true;
            self.ff.windows += 1;
        }
        Some((at, ev))
    }

    /// Return buffered fast-forward events to the engine. Called at every
    /// run-loop exit so external drivers — fault injection between runs,
    /// checkpointing, the soak/rca/pipeline harnesses poking the engine
    /// directly — always see the full pending set in the global queue.
    /// Ascending `(at, lseq)` replay assigns engine seqs in scheduling
    /// order, preserving equal-time FIFO for a later run loop.
    fn ff_flush(&mut self) {
        while let Some(Reverse(l)) = self.ff.buf.pop() {
            self.engine.schedule_at(l.at, l.ev);
        }
        self.ff.draining = false;
    }

    /// Total events dispatched, both through the global queue and locally
    /// by the fast-forward tier. This — not `engine.dispatched()` — is
    /// the mode-independent work count of a run.
    pub fn events_processed(&self) -> u64 {
        self.engine.dispatched() + self.ff.local_dispatched
    }

    /// §Perf L6 fast-forward tier counters (all zero when disabled).
    pub fn ff_stats(&self) -> FfStats {
        self.ff.stats()
    }

    pub fn dispatch(&mut self, ev: Event) {
        let now = self.now();
        match ev {
            Event::Flow { flow, gen } => {
                if let Some(&xid) = self.intra_flows.get(&flow) {
                    let (meta, timers) = self.rdma.flows.try_finish(flow, gen, now);
                    for t in timers {
                        self.sched_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
                    }
                    if meta.is_some() {
                        self.intra_flows.remove(&flow);
                        // An intra-flow entry pins its transfer live: the
                        // transfer cannot finish before this chunk acks.
                        let (conn_id, chunk_bytes) = {
                            let x = self.xfers.get(xid).expect("intra flow's transfer is live");
                            (x.conn, x.chunk_bytes)
                        };
                        self.stats.wire_bytes += chunk_bytes;
                        self.on_chunk_complete(xid, conn_id);
                    }
                } else {
                    let out = self.rdma.on_flow_timer(flow, gen, now);
                    self.absorb(out);
                }
            }
            Event::QpRetry { qp, epoch } => {
                let out = self.rdma.on_retry_deadline(qp, epoch, now);
                self.absorb(out);
            }
            Event::QpWarm { qp } => {
                let out = self.rdma.on_warm(qp, now);
                self.absorb(out);
                // A freshly warm primary may enable failback.
                if let Some(&cid) = self.qp_conn.get(&qp) {
                    if self.conns[cid.0].awaiting_failback && self.conns[cid.0].primary == Some(qp)
                    {
                        self.try_failback(cid);
                    }
                }
            }
            Event::GpuTask { gpu, task, gen } => {
                let _ = self.gpus[gpu].compute.try_finish(task, gen, now);
            }
            Event::ChunkReady { xfer } => self.on_chunk_ready(xfer),
            Event::PortDown { port } => self.on_port_state(port, false),
            Event::PortUp { port } => self.on_port_state(port, true),
            Event::TrunkDown { link } => self.on_trunk_state(link, false),
            Event::TrunkUp { link } => self.on_trunk_state(link, true),
            Event::SwitchDown { switch } => self.on_switch_state(switch, false),
            Event::SwitchUp { switch } => self.on_switch_state(switch, true),
            Event::NodeDown { node } => self.on_node_state(node, false),
            Event::NodeUp { node } => self.on_node_state(node, true),
            Event::DeltaCheck { conn, epoch } => self.on_delta_check(conn, epoch),
            Event::OpStep { op, channel } => self.issue_step(op, channel),
        }
        // Incident enrichment (§Perf L5 live view): freezes happen deep in
        // the monitor/fault layers with no slab access, so right after the
        // event that froze them — same sim time, single-threaded, hence
        // deterministic — fill in which transfers were still in flight.
        if self.tracer.enabled() {
            self.enrich_new_incidents();
        }
    }

    /// Fill `live_xfers`/`live_total` on incidents frozen by the event just
    /// dispatched. `iter_live()` walks ascending slot order, so the listed
    /// transfers (capped at [`crate::trace::MAX_LIVE_XFERS`]) are stable
    /// across runs at a seed.
    fn enrich_new_incidents(&mut self) {
        let Some(sink) = self.tracer.sink() else { return };
        if sink.incident_count() == self.incidents_enriched {
            return;
        }
        let live: Vec<crate::trace::LiveXfer> = self
            .xfers
            .iter_live()
            .take(crate::trace::MAX_LIVE_XFERS)
            .map(|x| crate::trace::LiveXfer {
                seq: x.seq,
                op: x.op.0,
                channel: x.channel,
                conn: x.conn.0,
                bytes: x.bytes,
                chunks_done: x.send.acked,
                chunks_total: x.chunks_total,
            })
            .collect();
        sink.enrich_incidents(self.xfers.live() as u64, &live);
        self.incidents_enriched = sink.incident_count();
    }

    /// Run until the engine drains or `deadline` passes. Returns the time.
    pub fn run_until(&mut self, deadline: SimTime) -> SimTime {
        while let Some((_, ev)) = self.next_event(Some(deadline)) {
            self.dispatch(ev);
        }
        self.ff_flush();
        self.engine.now()
    }

    /// Run to quiescence (panics after `max_events` as a hang backstop).
    pub fn run_to_idle(&mut self, max_events: u64) -> SimTime {
        let debug = std::env::var("VCCL_DEBUG_EVENTS").is_ok();
        let mut n: u64 = 0;
        let mut counts = [0u64; 10];
        while let Some((_, ev)) = self.next_event(None) {
            if debug {
                let k = match ev {
                    Event::Flow { .. } => 0,
                    Event::QpRetry { .. } => 1,
                    Event::QpWarm { .. } => 2,
                    Event::GpuTask { .. } => 3,
                    Event::ChunkReady { .. } => 4,
                    Event::PortDown { .. } => 5,
                    Event::PortUp { .. } => 6,
                    Event::DeltaCheck { .. } => 7,
                    Event::OpStep { .. } => 8,
                    Event::TrunkDown { .. }
                    | Event::TrunkUp { .. }
                    | Event::SwitchDown { .. }
                    | Event::SwitchUp { .. }
                    | Event::NodeDown { .. }
                    | Event::NodeUp { .. } => 9,
                };
                counts[k] += 1;
                if n % 10_000_000 == 0 && n > 0 {
                    eprintln!("[debug] n={n} now={} counts(flow,retry,warm,gpu,chunk,down,up,delta,step,fabric)={counts:?}", self.engine.now());
                }
            }
            self.dispatch(ev);
            n += 1;
            assert!(n < max_events, "simulation did not quiesce in {max_events} events");
        }
        self.ff_flush();
        self.engine.now()
    }

    /// Run until the given op completes (or fails / the engine drains).
    /// Unlike [`Self::run_to_idle`] this leaves future events (warm-ups,
    /// scheduled port flaps) pending, so back-to-back ops see a continuous
    /// clock. Returns true if the op finished.
    pub fn run_until_op(&mut self, op: OpId, max_events: u64) -> bool {
        let mut n: u64 = 0;
        while !self.ops[op.0].is_done() && !self.ops[op.0].failed {
            let Some((_, ev)) = self.next_event(None) else { break };
            self.dispatch(ev);
            n += 1;
            assert!(n < max_events, "op did not finish in {max_events} events");
        }
        // The op can finish mid-window: hand the un-dispatched remainder
        // back to the engine so the next caller sees a coherent queue.
        self.ff_flush();
        self.ops[op.0].is_done()
    }

    /// Bandwidth timeline of a port: bucketed Gbps series from the windowed
    /// per-port traffic aggregation (§Perf L4). Exact whenever `bucket` is
    /// a multiple of the aggregation granularity (the monitor trailing
    /// window — figures plot 1 s bins over the default 10 ms buckets).
    pub fn port_bandwidth_series(&self, port: PortId, bucket: SimTime) -> Vec<(f64, f64)> {
        let ordinal = self.topo.fabric.port_ordinal(port);
        self.stats.port_traffic.series_gbps(ordinal, bucket.as_ns())
    }

    /// §Perf L5 reference mode: retain every finished transfer record and
    /// never recycle a slot (the pre-L5 behaviour). Outputs are identical
    /// by contract; only memory differs. Must be called before the first
    /// transfer starts. Gated like the §Perf L3/L4 reference paths.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn set_xfer_retain_all(&mut self, on: bool) {
        self.xfers.set_retain_all(on);
    }

    /// Live NVLink-flow → transfer entries. Drains to zero when no
    /// intra-node chunk is on the wire (§Perf L5: nothing pins a dead
    /// transfer).
    pub fn intra_flow_count(&self) -> usize {
        self.intra_flows.len()
    }

    /// QP → connection routing entries. O(connections) — two per
    /// fault-tolerant inter-node connection — never O(transfers).
    pub fn qp_conn_count(&self) -> usize {
        self.qp_conn.len()
    }

    // ------------------------------------------------------------------
    // Checkpoint / resume (§Soak)
    // ------------------------------------------------------------------

    /// Hash of everything behaviour-relevant in the config. The trace
    /// section is excluded — the flight recorder is diagnostics, not
    /// simulation state, and does not survive a restart.
    pub fn config_fingerprint(cfg: &Config) -> u64 {
        fingerprint(&format!(
            "{:?}|{:?}|{:?}|{:?}|seed={}",
            cfg.gpu, cfg.net, cfg.topo, cfg.vccl, cfg.seed
        ))
    }

    /// Serialize the complete durable simulation state at an
    /// **op-quiescent boundary**: no live transfers or flows, no
    /// outstanding WRs, no armed δ-probes, no resident comm kernels.
    /// Future events (QP warm-ups, scheduled port flaps, stale
    /// generation-guarded checks) MAY be pending — the engine queue is
    /// serialized verbatim, cancelled entries included, so the `seq` /
    /// `dispatched` bookkeeping and every future pop are bit-identical
    /// after resume. See DESIGN.md §Soak for the layout contract.
    pub fn checkpoint(&self) -> String {
        assert_eq!(self.xfers.live(), 0, "checkpoint requires quiescence (live transfers)");
        assert!(self.intra_flows.is_empty(), "checkpoint requires quiescence (intra-node flows)");
        assert!(self.op_sms.is_empty(), "checkpoint requires quiescence (comm kernels resident)");
        for c in &self.conns {
            assert!(c.pending.is_empty(), "checkpoint requires quiescence (queued transfers)");
            if let Some(p) = &c.probe {
                assert!(!p.is_armed(), "checkpoint requires quiescence (armed δ-probe)");
            }
        }
        let mut w = CkptWriter::new("VCCLCKPT", 2);
        w.section("config");
        w.u64("cfgfp", Self::config_fingerprint(&self.cfg));
        // Connection bootstrap replay list: re-running `conn()` in creation
        // order reproduces ids, QP numbering and the link→QP index exactly.
        w.section("conns");
        w.usize("nconns", self.conns.len());
        for c in &self.conns {
            w.usize("src", c.src.0);
            w.usize("dst", c.dst.0);
            w.usize("ch", c.channel);
        }
        for c in &self.conns {
            w.bool("actb", matches!(c.active, ActiveSide::Backup));
            w.bool("afb", c.awaiting_failback);
            w.u32("cfo", c.failovers);
            w.bool("used", c.used);
            w.opt_u64("pep", c.probe.as_ref().map(|p| u64::from(p.epoch)));
        }
        w.section("fabric");
        self.topo.fabric.save(&mut w);
        w.section("elastic");
        w.usize("ndn", self.dead_nodes.len());
        for d in &self.dead_nodes {
            w.bool("dn", *d);
        }
        w.section("rdma");
        self.rdma.save(&mut w);
        w.section("engine");
        // The fast-forward buffer is flushed at every run-loop exit, so a
        // quiescent boundary always has the complete pending set in the
        // engine — the checkpoint layout is identical in both modes.
        assert!(self.ff.buf.is_empty(), "checkpoint requires a flushed fast-forward buffer");
        let st = self.engine.checkpoint_state();
        w.u64("enow", st.now.as_ns());
        w.u64("eseq", st.seq);
        w.u64("edisp", st.dispatched);
        w.usize("ncanc", st.cancelled.len());
        for c in &st.cancelled {
            w.u64("cs", *c);
        }
        w.usize("npend", st.pending.len());
        for (at, seq, ev) in &st.pending {
            w.u64("at", at.as_ns());
            w.u64("sq", *seq);
            save_event(&mut w, ev);
        }
        w.section("xfers");
        self.xfers.save(&mut w);
        w.section("ops");
        w.usize("nops", self.ops.len());
        for o in &self.ops {
            w.u64("kind", coll_ordinal(o.kind));
            w.u64("bytes", o.bytes);
            w.bool("p2p", o.p2p.is_some());
            if let Some((s, d)) = o.p2p {
                w.usize("ps", s.0);
                w.usize("pd", d.0);
            }
            w.usize("chans", o.channels);
            w.usize("steps", o.steps_total);
            for &s in &o.chan_step {
                w.usize("cs", s);
            }
            for &p in &o.chan_pending {
                w.usize("cp", p);
            }
            for ru in &o.chan_rollup {
                save_rollup(&mut w, ru);
            }
            w.usize("cdone", o.channels_done);
            w.bool("fail", o.failed);
            w.u64("start", o.started_at.as_ns());
            w.opt_u64("fin", o.finished_at.map(|t| t.as_ns()));
        }
        w.section("stats");
        w.u64("kls", self.stats.comm_kernel_launches);
        w.usize("nproxy", self.stats.proxy_cpu_ns.len());
        for v in &self.stats.proxy_cpu_ns {
            w.u64("px", *v);
        }
        w.u64("ceops", self.stats.ce_ops);
        w.u64("wireb", self.stats.wire_bytes);
        w.u64("sfo", self.stats.failovers);
        w.u64("sfb", self.stats.failbacks);
        w.u64("hung", self.stats.hung_ops);
        w.u64("pben", self.stats.probe_benign);
        w.u64("pdead", self.stats.probe_dead);
        w.u64("eshr", self.stats.elastic_shrinks);
        w.u64("erej", self.stats.elastic_rejoins);
        w.u64("oreq", self.stats.ops_requeued);
        self.stats.port_traffic.save(&mut w);
        w.section("monitor");
        w.bool("hasmon", self.monitor.is_some());
        if let Some(m) = &self.monitor {
            m.save(&mut w);
        }
        w.section("mempools");
        w.usize("nmp", self.mempools.len());
        for m in &self.mempools {
            m.save(&mut w);
        }
        w.section("gpus");
        w.usize("ngpu", self.gpus.len());
        for g in &self.gpus {
            g.compute.save(&mut w);
            g.ce.save(&mut w);
        }
        w.section("rng");
        let rs = self.rng.state();
        w.u64("r0", rs[0]);
        w.u64("r1", rs[1]);
        w.u64("r2", rs[2]);
        w.u64("r3", rs[3]);
        w.finish()
    }

    /// Rebuild a simulation from a [`Self::checkpoint`] stream and the SAME
    /// config it was taken under (enforced by fingerprint). The fresh
    /// instance replays connection bootstrap, then patches every mutable
    /// field from the stream — after this, driving the pair (resumed vs
    /// never-stopped) produces bit-identical events, timers, roll-ups and
    /// reports. The flight-recorder ring is NOT restored (diagnostics only;
    /// `trace::export_since` splices post-resume trace tails instead).
    pub fn restore(cfg: Config, text: &str) -> Result<ClusterSim, String> {
        let mut r = CkptReader::new(text, "VCCLCKPT", 2)?;
        let mut sim = ClusterSim::new(cfg);
        r.expect("config")?;
        if r.u64("cfgfp")? != Self::config_fingerprint(&sim.cfg) {
            return Err("checkpoint was taken under a different config".to_string());
        }
        r.expect("conns")?;
        let nconns = r.usize("nconns")?;
        let mut replay = Vec::with_capacity(nconns);
        for _ in 0..nconns {
            let src = r.usize("src")?;
            let dst = r.usize("dst")?;
            let ch = r.usize("ch")?;
            replay.push((src, dst, ch));
        }
        for (i, (src, dst, ch)) in replay.into_iter().enumerate() {
            let id = sim.conn(RankId(src), RankId(dst), ch);
            if id.0 != i {
                return Err(format!("connection replay produced id {} for entry {i}", id.0));
            }
        }
        for c in sim.conns.iter_mut() {
            c.active = if r.bool("actb")? { ActiveSide::Backup } else { ActiveSide::Primary };
            c.awaiting_failback = r.bool("afb")?;
            c.failovers = r.u32("cfo")?;
            c.used = r.bool("used")?;
            match (&mut c.probe, r.opt_u64("pep")?) {
                (Some(p), Some(e)) => {
                    p.epoch =
                        u32::try_from(e).map_err(|_| "probe epoch overflow".to_string())?;
                }
                (None, None) => {}
                _ => return Err("probe presence mismatch vs config".to_string()),
            }
        }
        r.expect("fabric")?;
        sim.topo.fabric.load(&mut r)?;
        r.expect("elastic")?;
        let ndn = r.usize("ndn")?;
        if ndn != sim.dead_nodes.len() {
            return Err(format!(
                "dead-node table mismatch: ckpt {ndn} vs config {}",
                sim.dead_nodes.len()
            ));
        }
        for d in sim.dead_nodes.iter_mut() {
            *d = r.bool("dn")?;
        }
        if sim.dead_nodes.iter().any(|&d| d) {
            // Mid-shrink checkpoint: rebuild the shrunk rings silently (the
            // RingRebuilt trace fired in the original timeline already).
            sim.rings = build_rings_excluding(
                &sim.topo,
                sim.cfg.vccl.channels.max(1),
                &sim.dead_nodes,
            );
        }
        r.expect("rdma")?;
        sim.rdma.load(&mut r)?;
        r.expect("engine")?;
        let now = SimTime::ns(r.u64("enow")?);
        let seq = r.u64("eseq")?;
        let dispatched = r.u64("edisp")?;
        let mut cancelled = Vec::new();
        for _ in 0..r.usize("ncanc")? {
            cancelled.push(r.u64("cs")?);
        }
        let mut pending = Vec::new();
        for _ in 0..r.usize("npend")? {
            let at = SimTime::ns(r.u64("at")?);
            let sq = r.u64("sq")?;
            pending.push((at, sq, load_event(&mut r)?));
        }
        sim.engine = Engine::from_state_with(
            EngineState { now, seq, dispatched, cancelled, pending },
            sim.cfg.engine.bucket_ns,
        );
        r.expect("xfers")?;
        sim.xfers.load(&mut r)?;
        r.expect("ops")?;
        sim.ops.clear();
        for i in 0..r.usize("nops")? {
            let kind = coll_from_ordinal(r.u64("kind")?)?;
            let bytes = r.u64("bytes")?;
            let p2p = if r.bool("p2p")? {
                Some((RankId(r.usize("ps")?), RankId(r.usize("pd")?)))
            } else {
                None
            };
            let channels = r.usize("chans")?;
            let steps_total = r.usize("steps")?;
            let mut chan_step = Vec::with_capacity(channels);
            for _ in 0..channels {
                chan_step.push(r.usize("cs")?);
            }
            let mut chan_pending = Vec::with_capacity(channels);
            for _ in 0..channels {
                chan_pending.push(r.usize("cp")?);
            }
            let mut chan_rollup = Vec::with_capacity(channels);
            for _ in 0..channels {
                chan_rollup.push(load_rollup(&mut r)?);
            }
            sim.ops.push(Op {
                id: OpId(i),
                kind,
                bytes,
                p2p,
                channels,
                steps_total,
                chan_step,
                chan_pending,
                chan_rollup,
                channels_done: r.usize("cdone")?,
                failed: r.bool("fail")?,
                started_at: SimTime::ns(r.u64("start")?),
                finished_at: r.opt_u64("fin")?.map(SimTime::ns),
            });
        }
        r.expect("stats")?;
        sim.stats.comm_kernel_launches = r.u64("kls")?;
        let nproxy = r.usize("nproxy")?;
        if nproxy != sim.stats.proxy_cpu_ns.len() {
            return Err(format!(
                "checkpoint has {nproxy} proxy counters, config built {}",
                sim.stats.proxy_cpu_ns.len()
            ));
        }
        for v in sim.stats.proxy_cpu_ns.iter_mut() {
            *v = r.u64("px")?;
        }
        sim.stats.ce_ops = r.u64("ceops")?;
        sim.stats.wire_bytes = r.u64("wireb")?;
        sim.stats.failovers = r.u64("sfo")?;
        sim.stats.failbacks = r.u64("sfb")?;
        sim.stats.hung_ops = r.u64("hung")?;
        sim.stats.probe_benign = r.u64("pben")?;
        sim.stats.probe_dead = r.u64("pdead")?;
        sim.stats.elastic_shrinks = r.u64("eshr")?;
        sim.stats.elastic_rejoins = r.u64("erej")?;
        sim.stats.ops_requeued = r.u64("oreq")?;
        sim.stats.port_traffic.load(&mut r)?;
        r.expect("monitor")?;
        if r.bool("hasmon")? != sim.monitor.is_some() {
            return Err("monitor presence mismatch vs config".to_string());
        }
        if let Some(m) = sim.monitor.as_mut() {
            m.load(&mut r)?;
        }
        r.expect("mempools")?;
        let nmp = r.usize("nmp")?;
        if nmp != sim.mempools.len() {
            return Err(format!(
                "checkpoint has {nmp} mempools, config built {}",
                sim.mempools.len()
            ));
        }
        for m in sim.mempools.iter_mut() {
            m.load(&mut r)?;
        }
        r.expect("gpus")?;
        let ngpu = r.usize("ngpu")?;
        if ngpu != sim.gpus.len() {
            return Err(format!("checkpoint has {ngpu} GPUs, config built {}", sim.gpus.len()));
        }
        for g in sim.gpus.iter_mut() {
            g.compute.load(&mut r)?;
            g.ce.load(&mut r)?;
        }
        r.expect("rng")?;
        let rs = [r.u64("r0")?, r.u64("r1")?, r.u64("r2")?, r.u64("r3")?];
        sim.rng = Rng::from_state(rs);
        r.finish()?;
        Ok(sim)
    }
}

fn coll_ordinal(k: CollKind) -> u64 {
    match k {
        CollKind::SendRecv => 0,
        CollKind::AllReduce => 1,
        CollKind::AllGather => 2,
        CollKind::ReduceScatter => 3,
        CollKind::AllToAll => 4,
    }
}

fn coll_from_ordinal(v: u64) -> Result<CollKind, String> {
    Ok(match v {
        0 => CollKind::SendRecv,
        1 => CollKind::AllReduce,
        2 => CollKind::AllGather,
        3 => CollKind::ReduceScatter,
        4 => CollKind::AllToAll,
        other => return Err(format!("bad collective ordinal {other}")),
    })
}

fn save_rollup(w: &mut CkptWriter, ru: &ChanRollup) {
    w.u64("rx", ru.xfers);
    w.u64("rc", ru.chunks);
    w.u64("rw", ru.chunks_wire);
    w.u64("rb", ru.bytes);
    w.opt_u64("rf", ru.first_start_ns);
    w.opt_u64("rl", ru.last_finish_ns);
    w.u64("rs", ru.stall_ns);
}

fn load_rollup(r: &mut CkptReader) -> Result<ChanRollup, String> {
    Ok(ChanRollup {
        xfers: r.u64("rx")?,
        chunks: r.u64("rc")?,
        chunks_wire: r.u64("rw")?,
        bytes: r.u64("rb")?,
        first_start_ns: r.opt_u64("rf")?,
        last_finish_ns: r.opt_u64("rl")?,
        stall_ns: r.u64("rs")?,
    })
}

fn save_port(w: &mut CkptWriter, p: PortId) {
    w.usize("pn", p.nic.node.0);
    w.usize("pl", p.nic.local);
    w.u64("pp", u64::from(p.port));
}

fn load_port(r: &mut CkptReader) -> Result<PortId, String> {
    let node = r.usize("pn")?;
    let local = r.usize("pl")?;
    let port = u8::try_from(r.u64("pp")?).map_err(|_| "port index overflow".to_string())?;
    Ok(PortId { nic: NicId { node: NodeId(node), local }, port })
}

/// Event codec: every one of the fifteen kinds serializes faithfully — a
/// pending event whose target is gone by resume time (a stale `ChunkReady`
/// against a recycled slot, a `GpuTask` for a finished task) fires as the
/// same no-op it would have been in the uninterrupted run, because the
/// generation counters it is checked against are restored too.
fn save_event(w: &mut CkptWriter, ev: &Event) {
    match ev {
        Event::Flow { flow, gen } => {
            w.token("evF");
            w.u64("f", flow.0);
            w.u32("g", *gen);
        }
        Event::QpRetry { qp, epoch } => {
            w.token("evR");
            w.u64("q", qp.0);
            w.u32("e", *epoch);
        }
        Event::QpWarm { qp } => {
            w.token("evW");
            w.u64("q", qp.0);
        }
        Event::GpuTask { gpu, task, gen } => {
            w.token("evG");
            w.usize("u", *gpu);
            w.u64("t", task.0);
            w.u32("g", *gen);
        }
        Event::ChunkReady { xfer } => {
            w.token("evC");
            w.u32("s", xfer.slot);
            w.u32("g", xfer.gen);
        }
        Event::PortDown { port } => {
            w.token("evD");
            save_port(w, *port);
        }
        Event::PortUp { port } => {
            w.token("evU");
            save_port(w, *port);
        }
        Event::TrunkDown { link } => {
            w.token("evT");
            w.usize("l", link.0);
        }
        Event::TrunkUp { link } => {
            w.token("evV");
            w.usize("l", link.0);
        }
        Event::SwitchDown { switch } => {
            w.token("evL");
            w.usize("s", *switch);
        }
        Event::SwitchUp { switch } => {
            w.token("evM");
            w.usize("s", *switch);
        }
        Event::NodeDown { node } => {
            w.token("evN");
            w.usize("n", *node);
        }
        Event::NodeUp { node } => {
            w.token("evO");
            w.usize("n", *node);
        }
        Event::DeltaCheck { conn, epoch } => {
            w.token("evX");
            w.usize("c", conn.0);
            w.u32("e", *epoch);
        }
        Event::OpStep { op, channel } => {
            w.token("evS");
            w.usize("o", op.0);
            w.usize("c", *channel);
        }
    }
}

fn load_event(r: &mut CkptReader) -> Result<Event, String> {
    Ok(match r.token()? {
        "evF" => Event::Flow { flow: FlowId(r.u64("f")?), gen: r.u32("g")? },
        "evR" => Event::QpRetry { qp: QpId(r.u64("q")?), epoch: r.u32("e")? },
        "evW" => Event::QpWarm { qp: QpId(r.u64("q")?) },
        "evG" => Event::GpuTask { gpu: r.usize("u")?, task: TaskId(r.u64("t")?), gen: r.u32("g")? },
        "evC" => Event::ChunkReady { xfer: XferId { slot: r.u32("s")?, gen: r.u32("g")? } },
        "evD" => Event::PortDown { port: load_port(r)? },
        "evU" => Event::PortUp { port: load_port(r)? },
        "evT" => Event::TrunkDown { link: LinkId(r.usize("l")?) },
        "evV" => Event::TrunkUp { link: LinkId(r.usize("l")?) },
        "evL" => Event::SwitchDown { switch: r.usize("s")? },
        "evM" => Event::SwitchUp { switch: r.usize("s")? },
        "evN" => Event::NodeDown { node: r.usize("n")? },
        "evO" => Event::NodeUp { node: r.usize("n")? },
        "evX" => Event::DeltaCheck { conn: ConnId(r.usize("c")?), epoch: r.u32("e")? },
        "evS" => Event::OpStep { op: OpId(r.usize("o")?), channel: r.usize("c")? },
        other => return Err(format!("unknown event tag {other:?}")),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::monitor::Verdict;
    use crate::util::ByteSize;

    /// Fast-failing config so failover tests run in bounded sim time:
    /// retry window ≈ 8.4 ms, warm-up 100 ms.
    fn fast_ft_cfg() -> Config {
        let mut cfg = Config::paper_defaults();
        cfg.vccl.channels = 1;
        cfg.net.ib_timeout_exp = 10;
        cfg.net.ib_retry_cnt = 2;
        cfg.net.qp_warmup_ns = 100_000_000;
        cfg
    }

    #[test]
    fn failover_completes_transfer_through_backup() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        // 256MB takes ~5.5s at 388Gbps; kill the port at 2ms, never restore.
        s.inject_port_down(port, SimTime::ms(2));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(50_000_000);
        let op = &s.ops[id.0];
        assert!(op.is_done(), "transfer must complete on the backup QP");
        assert!(!op.failed);
        assert_eq!(s.stats.failovers, 1);
        // The stall costs ≈ the retry window before failover kicks in.
        let t = op.finished_at.unwrap().since(op.started_at);
        let window = s.cfg.net.retry_window_ns();
        assert!(t.as_ns() > window, "t={t} must include the retry window");
    }

    /// Counter-semantics pin: `probe_dead` counts ONLY case-2 δ-probe
    /// LinkDead verdicts. A case-1 failover — the sender's own
    /// `RetryExceeded` WC — must leave it untouched (it used to carry a
    /// dead `probe_dead += 0` statement on that path) and be visible as
    /// `failovers` instead.
    #[test]
    fn retry_exceeded_failover_does_not_count_as_probe_death() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(2));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(50_000_000);
        assert!(s.ops[id.0].is_done());
        assert_eq!(s.stats.failovers, 1, "case 1 must fail over");
        assert_eq!(s.stats.probe_dead, 0, "case 1 is not a probe death");
    }

    /// §Perf L4 regression: the failed primary port's running backlog
    /// drops to zero the moment its WRs are flushed and the pointers
    /// migrate, and the re-posted window shows up on the backup port.
    #[test]
    fn pointer_migration_rollback_drops_primary_backlog() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        let down_at = SimTime::ms(2);
        s.inject_port_down(port, down_at);
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        // Mid-transfer, pre-failure: the primary carries a live window.
        s.run_until(SimTime::ms(1));
        assert!(s.rdma.port_backlog_bytes(port) > 0, "window must be outstanding");
        // Ride just past the retry window: QP errors, WRs flush, pointers
        // migrate, the rolled-back window re-posts on the backup (1 ms in —
        // well before the ~5 ms the remaining 246 MB needs to drain).
        let window = SimTime::ns(s.cfg.net.retry_window_ns());
        s.run_until(down_at + window + SimTime::ms(1));
        assert_eq!(s.stats.failovers, 1, "failover must have happened");
        assert!(!s.ops[id.0].is_done(), "transfer still in flight on the backup");
        assert_eq!(
            s.rdma.port_backlog_bytes(port),
            0,
            "rollback must drop the dead primary port's backlog"
        );
        let bport = s.conns.iter().find_map(|c| c.backup_port).unwrap();
        assert!(
            s.rdma.port_backlog_bytes(bport) > 0,
            "re-posted window must be outstanding on the backup port"
        );
        s.run_to_idle(50_000_000);
        assert!(s.ops[id.0].is_done());
        assert_eq!(s.rdma.port_backlog_bytes(bport), 0);
    }

    #[test]
    fn flap_within_retry_window_needs_no_failover() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(2));
        s.inject_port_up(port, SimTime::ms(4)); // back before ~10.4ms deadline
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        s.run_to_idle(50_000_000);
        assert!(s.ops[id.0].is_done());
        assert_eq!(s.stats.failovers, 0, "short flap must ride out the retry window");
    }

    #[test]
    fn failback_returns_to_primary_after_port_up() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(2));
        // Port heals at 300ms — after failover (≈10ms) and after the
        // proactively-started warm-up (100ms) has finished.
        s.inject_port_up(port, SimTime::ms(300));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::gb(1).0);
        s.run_to_idle(100_000_000);
        assert!(s.ops[id.0].is_done());
        assert_eq!(s.stats.failovers, 1);
        assert_eq!(s.stats.failbacks, 1, "traffic must return to the primary QP");
    }

    /// The flight recorder captures the §3.3 causal chain in order:
    /// PortDown → FlowStalled → PointerMigrated → FlowResumed, and the
    /// failover freezes an incident snapshot.
    #[test]
    fn traced_failover_records_causal_chain() {
        let mut cfg = fast_ft_cfg();
        cfg.trace.enabled = true;
        let mut s = ClusterSim::new(cfg);
        assert!(s.tracer.enabled());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(2));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(50_000_000);
        assert!(s.ops[id.0].is_done());
        let sink = s.tracer.sink().unwrap();
        let recs = sink.records();
        let pos = |k: &str| {
            recs.iter()
                .position(|r| r.ev.kind() == k)
                .unwrap_or_else(|| panic!("no {k} event recorded"))
        };
        let (down, stalled, migrated, resumed) = (
            pos("PortDown"),
            pos("FlowStalled"),
            pos("PointerMigrated"),
            pos("FlowResumed"),
        );
        assert!(down < stalled && stalled < migrated && migrated < resumed);
        assert!(recs[down].at <= recs[stalled].at);
        assert!(recs[stalled].at <= recs[migrated].at);
        assert!(recs[migrated].at <= recs[resumed].at);
        assert!(
            sink.incidents().iter().any(|i| i.name.starts_with("failover-conn")),
            "failover must freeze an incident snapshot"
        );
    }

    /// The recorder observes, never schedules: the same scenario with
    /// tracing on and off must produce the identical simulation.
    #[test]
    fn tracing_never_perturbs_the_simulation() {
        let run = |traced: bool| {
            let mut cfg = fast_ft_cfg();
            cfg.trace.enabled = traced;
            let mut s = ClusterSim::new(cfg);
            let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
            // 256MB (~5.5s at line rate) so the 2ms port-down lands
            // mid-transfer and the full failover path runs.
            s.inject_port_down(port, SimTime::ms(2));
            let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
            s.run_to_idle(50_000_000);
            (
                s.ops[id.0].finished_at.expect("op finishes").as_ns(),
                s.engine.dispatched(),
                s.stats.failovers,
            )
        };
        let traced = run(true);
        assert_eq!(traced, run(false));
        assert_eq!(traced.2, 1, "the scenario must actually fail over");
    }

    /// Trace streams are reproducible: two runs at the same seed record
    /// the identical (kind, timestamp) sequence.
    #[test]
    fn trace_stream_is_deterministic_across_runs() {
        let run = || {
            let mut cfg = fast_ft_cfg();
            cfg.trace.enabled = true;
            let mut s = ClusterSim::new(cfg);
            let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
            s.inject_port_down(port, SimTime::ms(2));
            let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
            s.run_to_idle(50_000_000);
            assert!(s.ops[id.0].is_done());
            s.tracer
                .sink()
                .unwrap()
                .records()
                .iter()
                .map(|r| (r.at.as_ns(), r.ev.kind()))
                .collect::<Vec<_>>()
        };
        let a = run();
        assert_eq!(a, run());
        assert!(!a.is_empty());
    }

    #[test]
    fn nccl_baseline_hangs_on_port_failure() {
        let mut cfg = Config::nccl_baseline();
        cfg.vccl.channels = 1;
        cfg.net.ib_timeout_exp = 10;
        cfg.net.ib_retry_cnt = 2;
        let mut s = ClusterSim::new(cfg);
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(2));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(50_000_000);
        let op = &s.ops[id.0];
        assert!(op.failed, "NCCL baseline must hang (Fig 13b)");
        assert!(!op.is_done());
        assert_eq!(s.stats.hung_ops, 1);
        assert_eq!(s.stats.failovers, 0);
    }

    #[test]
    fn backup_qp_uses_second_closest_nic() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        let cid = s.conn(RankId(0), RankId(8), 0);
        let c = &s.conns[cid.0];
        let p = c.primary_port.unwrap();
        let b = c.backup_port.unwrap();
        assert_ne!(p, b);
        assert_eq!(p.nic.local, 0);
        assert_eq!(b.nic.local, 1); // neighbouring RNIC (§3.3)
    }

    #[test]
    fn dual_port_backup_on_same_nic() {
        let mut cfg = fast_ft_cfg();
        cfg.topo.dual_port_nics = true;
        let mut s = ClusterSim::new(cfg);
        let cid = s.conn(RankId(0), RankId(8), 0);
        let c = &s.conns[cid.0];
        let p = c.primary_port.unwrap();
        let b = c.backup_port.unwrap();
        assert_eq!(p.nic, b.nic, "dual-port: backup lives on the other port");
        assert_ne!(p.port, b.port);
    }

    /// §Fault domains tentpole property: a single trunk-down on a
    /// dual-plane fabric loses zero collectives. Both endpoint ports stay
    /// up the whole time — the failure is perceived as PATH death via the
    /// retry window — yet the crossing connection fails over exactly once
    /// to the backup plane, and fails back after the trunk heals.
    #[test]
    fn trunk_down_migrates_to_backup_plane_without_port_flap() {
        let mut cfg = fast_ft_cfg();
        cfg.topo.dual_port_nics = true;
        cfg.trace.enabled = true;
        let mut s = ClusterSim::new(cfg);
        let cid = s.conn(RankId(0), RankId(8), 0);
        let pport = s.conns[cid.0].primary_port.unwrap();
        // rank 0's plane-0 primary path rides trunk (rail 0, plane 0).
        let trunk = s.topo.fabric.trunk_up(0, 0);
        s.inject_trunk_down(trunk, SimTime::ms(2));
        s.inject_trunk_up(trunk, SimTime::ms(3_000));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(50_000_000);
        let op = &s.ops[id.0];
        assert!(op.is_done() && !op.failed, "zero lost collectives");
        assert_eq!(s.stats.hung_ops, 0);
        assert_eq!(s.stats.failovers, 1, "exactly one failover");
        assert_eq!(s.stats.failbacks, 1, "traffic returns after the heal");
        // The endpoint port NEVER flapped: this was path death.
        assert!(s.topo.fabric.port_up(pport));
        let recs = s.tracer.sink().unwrap().records();
        assert!(!recs.iter().any(|r| r.ev.kind() == "PortDown"), "no port flap");
        let degr = recs
            .iter()
            .find_map(|r| match r.ev {
                TraceEvent::TrunkDegraded { link, switch, .. } => Some((link, switch)),
                _ => None,
            })
            .expect("TrunkDegraded recorded");
        assert_eq!(degr.0, trunk.0);
        assert_eq!(Some(degr.1), s.topo.fabric.switch_of_link(trunk));
        let migr = recs
            .iter()
            .find_map(|r| match r.ev {
                TraceEvent::PathMigrated { conn, link, .. } => Some((conn, link)),
                _ => None,
            })
            .expect("PathMigrated recorded");
        assert_eq!(migr, (cid.0, trunk.0), "migration names the killing trunk");
        assert!(recs.iter().any(|r| r.ev.kind() == "TrunkRestored"));
        assert!(recs.iter().any(|r| r.ev.kind() == "Failback"));
    }

    /// Killing a whole spine plane cascades to every trunk in the plane:
    /// every inter-node connection riding plane 0 migrates to the other
    /// plane (at most once each) and the collective still completes.
    #[test]
    fn spine_plane_down_migrates_every_crossing_conn() {
        let mut cfg = fast_ft_cfg();
        cfg.topo.dual_port_nics = true;
        cfg.trace.enabled = true;
        let mut s = ClusterSim::new(cfg);
        let spine0 = s.topo.fabric.num_leaf_switches(); // plane-0 spine
        s.inject_switch_down(spine0, SimTime::ms(2));
        let id = s.submit(CollKind::AllGather, ByteSize::mb(64).0);
        s.run_to_idle(200_000_000);
        let op = &s.ops[id.0];
        assert!(op.is_done() && !op.failed, "zero lost collectives");
        assert_eq!(s.stats.hung_ops, 0);
        assert!(s.stats.failovers >= 1, "the plane loss must be perceived");
        for c in s.conns.iter().filter(|c| c.primary.is_some()) {
            assert!(
                c.failovers <= 1,
                "conn {} failed over {} times (must be at most once)",
                c.id.0,
                c.failovers
            );
        }
        let recs = s.tracer.sink().unwrap().records();
        assert!(recs
            .iter()
            .any(|r| matches!(r.ev, TraceEvent::SwitchDown { switch } if switch == spine0)));
    }

    /// The four fabric fault events survive the checkpoint event codec.
    #[test]
    fn fabric_events_round_trip_through_the_checkpoint_codec() {
        let evs = [
            Event::TrunkDown { link: LinkId(7) },
            Event::TrunkUp { link: LinkId(7) },
            Event::SwitchDown { switch: 3 },
            Event::SwitchUp { switch: 3 },
            Event::NodeDown { node: 5 },
            Event::NodeUp { node: 5 },
        ];
        for ev in evs {
            let mut w = CkptWriter::new("T", 1);
            save_event(&mut w, &ev);
            let blob = w.finish();
            let mut r = CkptReader::new(&blob, "T", 1).unwrap();
            let back = load_event(&mut r).unwrap();
            assert_eq!(format!("{ev:?}"), format!("{back:?}"));
        }
    }

    // ------------------------------------------------------------------
    // §Elastic: node crash, ring shrink, rejoin
    // ------------------------------------------------------------------

    #[test]
    fn node_crash_shrinks_ring_and_allreduce_completes() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        // 256 MB AllReduce takes ~10 ms over 2×8 ranks; node 1 dies at
        // 2 ms, mid-flight. The world shrinks to node 0's 8 ranks and the
        // collective completes on the rebuilt (NVLink-only) ring.
        s.inject_node_down(1, SimTime::ms(2));
        let id = s.submit(CollKind::AllReduce, ByteSize::mb(256).0);
        s.run_to_idle(100_000_000);
        let op = &s.ops[id.0];
        assert!(op.is_done(), "AllReduce must complete on the shrunk ring");
        assert!(!op.failed);
        assert_eq!(s.stats.elastic_shrinks, 1, "exactly one shrink");
        assert_eq!(s.stats.elastic_rejoins, 0);
        assert!(s.stats.ops_requeued >= 1, "the interrupted step must requeue");
        assert_eq!(s.rings[0].order.len(), 8, "rings span the survivors");
        let recs = s.tracer.sink().unwrap().records();
        assert!(recs.iter().any(|r| matches!(r.ev, TraceEvent::NodeDown { node: 1 })));
        assert!(recs
            .iter()
            .any(|r| matches!(r.ev, TraceEvent::RingRebuilt { ranks: 8, .. })));
        assert!(recs.iter().any(|r| matches!(r.ev, TraceEvent::OpRequeued { .. })));
    }

    #[test]
    fn node_recovery_rejoins_and_full_ring_returns() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        s.inject_node_down(1, SimTime::ms(2));
        s.inject_node_up(1, SimTime::ms(400));
        let id = s.submit(CollKind::AllReduce, ByteSize::mb(256).0);
        s.run_to_idle(200_000_000);
        assert!(s.ops[id.0].is_done() && !s.ops[id.0].failed);
        assert_eq!(s.stats.elastic_shrinks, 1);
        assert_eq!(s.stats.elastic_rejoins, 1, "exactly one rejoin");
        assert_eq!(
            s.rings[0].order.len(),
            s.topo.num_ranks(),
            "full membership restored after the heal"
        );
        // A post-rejoin collective must complete over ALL ranks again
        // (rejoin completeness): the healed node's QPs re-warmed.
        let id2 = s.submit(CollKind::AllReduce, ByteSize::mb(16).0);
        s.run_to_idle(100_000_000);
        assert!(s.ops[id2.0].is_done() && !s.ops[id2.0].failed);
        let recs = s.tracer.sink().unwrap().records();
        assert!(recs.iter().any(|r| matches!(r.ev, TraceEvent::NodeUp { node: 1 })));
        let full = s.topo.num_ranks();
        assert!(recs
            .iter()
            .any(|r| matches!(r.ev, TraceEvent::RingRebuilt { ranks, .. } if ranks == full)));
    }

    #[test]
    fn non_crossing_p2p_is_bit_identical_under_remote_node_crash() {
        // A P2P between nodes 0 and 1 must be untouched — timing and
        // roll-up — by node 2 crashing (the elastic guarantee: only ops
        // crossing the victim are perturbed).
        let mut cfg = fast_ft_cfg();
        cfg.topo.num_nodes = 3;
        let run = |crash: bool| {
            let mut s = ClusterSim::new(cfg.clone());
            if crash {
                s.inject_node_down(2, SimTime::ms(1));
            }
            let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(32).0);
            s.run_to_idle(50_000_000);
            let op = &s.ops[id.0];
            assert!(op.is_done() && !op.failed);
            (op.started_at, op.finished_at, format!("{:?}", op.chan_rollup))
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn elastic_disabled_crash_strands_the_collective() {
        // The non-elastic baseline: the crash cascades to the ports, §3.3
        // failover cannot help (the backup plane died with the node), and
        // the crossing channel hangs/fails instead of shrinking.
        let mut cfg = fast_ft_cfg();
        cfg.elastic.enabled = false;
        let mut s = ClusterSim::new(cfg);
        s.inject_node_down(1, SimTime::ms(2));
        let id = s.submit(CollKind::AllReduce, ByteSize::mb(256).0);
        s.run_to_idle(100_000_000);
        let op = &s.ops[id.0];
        assert!(op.failed || !op.is_done(), "baseline must NOT complete");
        assert_eq!(s.stats.elastic_shrinks, 0);
        assert_eq!(s.stats.ops_requeued, 0);
    }

    #[test]
    fn checkpoint_round_trips_a_dead_node_and_resumes_identically() {
        // Crash, finish the shrunk collective, checkpoint with node 1
        // still dead. The restored sim must carry the dead-node view and
        // the shrunk rings, then evolve bit-identically through the heal.
        let mut s = ClusterSim::new(fast_ft_cfg());
        s.inject_node_down(1, SimTime::ms(2));
        let id = s.submit(CollKind::AllReduce, ByteSize::mb(64).0);
        s.run_to_idle(100_000_000);
        assert!(s.ops[id.0].is_done());
        let blob = s.checkpoint();
        let mut r = ClusterSim::restore(fast_ft_cfg(), &blob).unwrap();
        assert_eq!(r.dead_nodes, vec![false, true]);
        assert_eq!(r.rings[0].order.len(), 8, "restore rebuilds shrunk rings");
        for sim in [&mut s, &mut r] {
            let now = sim.now();
            sim.inject_node_up(1, now + SimTime::ms(1));
            sim.submit(CollKind::AllReduce, ByteSize::mb(16).0);
            sim.run_to_idle(100_000_000);
        }
        assert_eq!(s.stats.elastic_rejoins, 1);
        assert_eq!(s.checkpoint(), r.checkpoint(), "divergence after resume");
    }

    #[test]
    fn monitor_sees_traffic_and_stays_healthy() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        s.run_to_idle(20_000_000);
        assert!(s.ops[id.0].is_done());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        let ordinal = s.topo.fabric.port_ordinal(port);
        let mon = s.monitor.as_ref().unwrap();
        assert!(!mon.samples(ordinal).is_empty(), "monitor must emit samples");
        assert!(mon
            .verdicts(ordinal)
            .iter()
            .all(|(_, v)| *v == Verdict::Healthy));
    }

    /// §Perf L5: a `ChunkReady` queued against a transfer that finished
    /// and whose slot was recycled must be ignored (generation mismatch),
    /// never misrouted to the slot's new occupant — whether it fires
    /// before the slot is reused or mid-flight of the new transfer.
    #[test]
    fn stale_chunk_ready_after_recycle_is_ignored() {
        // Clean reference: two back-to-back transfers, no stale events.
        let clean_second_op_ns = {
            let mut s = ClusterSim::new(fast_ft_cfg());
            let a = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(4).0);
            s.run_to_idle(20_000_000);
            let t1 = s.ops[a.0].finished_at.unwrap();
            let b = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(4).0);
            s.run_to_idle(20_000_000);
            s.ops[b.0].finished_at.unwrap().since(t1).as_ns()
        };

        let mut s = ClusterSim::new(fast_ft_cfg());
        let a = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(4).0);
        // Capture the transfer's id mid-flight, then let it finish.
        s.run_until(SimTime::us(20));
        let stale = s.conns.iter().find_map(|c| c.cur_xfer()).expect("transfer in flight");
        s.run_to_idle(20_000_000);
        let t1 = s.ops[a.0].finished_at.unwrap();
        let m = s.xfers.mem_stats();
        assert_eq!((m.created, m.retired, m.live), (1, 1, 0));
        assert!(s.xfers.get(stale).is_none(), "retired id must resolve to nothing");

        // Stale event #1 fires before the slot is reused; #2 fires while
        // the new occupant is mid-flight.
        let now = s.now();
        s.engine.schedule_at(now, Event::ChunkReady { xfer: stale });
        s.engine.schedule_at(now + SimTime::us(20), Event::ChunkReady { xfer: stale });
        let b = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(4).0);
        s.run_until(now + SimTime::us(30));
        let reused = s.conns.iter().find_map(|c| c.cur_xfer()).expect("second transfer live");
        assert_eq!(reused.slot, stale.slot, "the freed slot must be recycled");
        assert_ne!(reused.gen, stale.gen, "the recycled slot must carry a new generation");
        s.run_to_idle(20_000_000);
        assert!(s.ops[b.0].is_done());
        // No failover ran, so a single phantom transmission from either
        // stale event would surface as chunks_wire > chunks here.
        let r = &s.ops[b.0].chan_rollup;
        let wire: u64 = r.iter().map(|c| c.chunks_wire).sum();
        let delivered: u64 = r.iter().map(|c| c.chunks).sum();
        assert_eq!(wire, delivered, "stale events must not inject chunks into the new occupant");
        // And the new occupant's timing is bit-identical to the clean run.
        assert_eq!(
            s.ops[b.0].finished_at.unwrap().since(t1).as_ns(),
            clean_second_op_ns,
            "stale events must not perturb the simulation"
        );
        assert_eq!(s.xfers.mem_stats().created, 2);
    }

    /// §Perf L5: no per-transfer map may pin completed work — the
    /// flow→transfer and flow→WR maps drain to zero after every op, and
    /// the QP routing map is O(connections), never O(transfers).
    #[test]
    fn per_transfer_maps_shrink_after_completion() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        let a = s.submit_p2p(RankId(0), RankId(1), ByteSize::mb(8).0); // NVLink flows
        let b = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(8).0); // QP traffic
        s.run_to_idle(20_000_000);
        assert!(s.ops[a.0].is_done() && s.ops[b.0].is_done());
        assert_eq!(s.intra_flow_count(), 0, "intra-flow map must drain");
        assert_eq!(s.rdma.flow_owner_count(), 0, "flow→WR owner map must drain");
        assert_eq!(s.xfers.live(), 0, "no live transfers at quiescence");
        assert_eq!(s.xfers.iter_live().count(), 0, "live iteration agrees with the counter");
        let inter_conns = s.conns.iter().filter(|c| c.primary.is_some()).count();
        let qps = s.qp_conn_count();
        assert_eq!(qps, 2 * inter_conns, "one primary + one backup QP per inter-node conn");
        // A follow-up op reuses the connections: zero map growth.
        let c2 = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(8).0);
        s.run_to_idle(20_000_000);
        assert!(s.ops[c2.0].is_done());
        assert_eq!(s.qp_conn_count(), qps, "QP map is per-connection, not per-transfer");
        assert_eq!(s.intra_flow_count(), 0);
        assert_eq!(s.rdma.flow_owner_count(), 0);
    }

    /// §Perf L5 acceptance (the archetype headline): a seeded randomized
    /// ~1k-op workload — mixed collectives and P2P, random sizes, port
    /// flaps straddling transfers — driven once with slot recycling and
    /// once in retain-everything reference mode must be bit-identical:
    /// per-op completion timers, per-op roll-ups, stats distilled into
    /// BENCH-style JSON, and the full flight-recorder (Chrome) export.
    /// Mirrors the §Perf L3 allocator-equivalence test shape.
    #[test]
    fn randomized_equivalence_with_retained_reference() {
        let run = |retain: bool| {
            let mut cfg = fast_ft_cfg();
            cfg.trace.enabled = true;
            cfg.trace.ring_capacity = 1 << 15;
            let mut s = ClusterSim::new(cfg);
            if retain {
                s.set_xfer_retain_all(true);
            }
            let mut rng = crate::util::Rng::new(0x55AB5);
            let ops_n = if cfg!(debug_assertions) { 200 } else { 1000 };
            // Flap only even-rail primary ports: backup QPs live on the
            // next (odd) rail, so a flap can never kill both paths of a
            // connection and hang an op mid-sweep.
            let flap_ranks = [0usize, 2, 4, 6, 8, 10, 12, 14];
            let mut finished = Vec::with_capacity(ops_n);
            for i in 0..ops_n {
                if rng.below(100) < 7 {
                    let g = flap_ranks[rng.below(flap_ranks.len() as u64) as usize];
                    let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(g)));
                    let at = s.now() + SimTime::ns(rng.range(1_000, 2_000_000));
                    s.inject_port_down(port, at);
                    s.inject_port_up(port, at + SimTime::ns(rng.range(100_000, 20_000_000)));
                }
                let id = match rng.below(10) {
                    0..=5 => {
                        let n = s.topo.num_ranks();
                        let src = RankId(rng.below(n as u64) as usize);
                        let mut dst = RankId(rng.below(n as u64) as usize);
                        if dst == src {
                            dst = RankId((src.0 + 1) % n);
                        }
                        s.submit_p2p(src, dst, rng.range(1, 4 << 20))
                    }
                    6 => s.submit(CollKind::AllReduce, rng.range(1 << 16, 2 << 20)),
                    7 => s.submit(CollKind::AllGather, rng.range(1 << 16, 2 << 20)),
                    8 => s.submit(CollKind::ReduceScatter, rng.range(1 << 16, 2 << 20)),
                    _ => s.submit(CollKind::AllToAll, rng.range(1 << 16, 1 << 20)),
                };
                assert!(s.run_until_op(id, 100_000_000), "op {i} must finish");
                finished.push(s.ops[id.0].finished_at.unwrap().as_ns());
            }
            s.run_to_idle(100_000_000);
            let m = s.xfers.mem_stats();
            // BENCH-style JSON distilled from the run: bit-identity here is
            // what "recycling keeps BENCH_*.json byte-identical" means.
            let mut rep = crate::metrics::BenchReport::new(
                "xfer-equivalence",
                "§Perf L5 recycling vs retain-everything reference",
            );
            rep.push("ops", finished.len() as f64, "count");
            rep.push("last_finish_ns", *finished.last().unwrap() as f64, "ns");
            rep.push("events_dispatched", s.engine.dispatched() as f64, "count");
            rep.push("failovers", s.stats.failovers as f64, "count");
            rep.push("failbacks", s.stats.failbacks as f64, "count");
            rep.push("wire_bytes", s.stats.wire_bytes as f64, "bytes");
            rep.push("xfers_created", m.created as f64, "count");
            rep.push("xfers_peak_live", m.high_water as f64, "count");
            let rollups: Vec<Vec<ChanRollup>> =
                s.ops.iter().map(|o| o.chan_rollup.clone()).collect();
            let meta = crate::trace::chrome::ChromeMeta { ports_per_node: 8 };
            let records = s.tracer.sink().expect("tracing on").records();
            let trace_json = crate::trace::chrome::export(&records, &meta);
            (finished, rep.to_json(), rollups, trace_json, m)
        };
        let rec = run(false);
        let refr = run(true);
        assert_eq!(rec.0, refr.0, "completion timers diverged");
        assert_eq!(rec.1, refr.1, "BENCH JSON diverged");
        assert_eq!(rec.2, refr.2, "per-op roll-ups diverged");
        assert_eq!(rec.3, refr.3, "trace exports diverged");
        // Live accounting is mode-independent; only residency differs.
        let (m, rm) = (rec.4, refr.4);
        assert_eq!(
            (m.created, m.retired, m.live, m.high_water),
            (rm.created, rm.retired, rm.live, rm.high_water),
            "mem counters must be mode-independent"
        );
        assert_eq!(rm.slots_resident, rm.created, "the reference retains every record");
        assert!(
            m.slots_resident <= m.high_water,
            "recycling must cap resident slots at the live peak: {m:?}"
        );
        assert!(m.created > 1_000, "sweep too small: {m:?}");
        assert!(m.high_water * 4 < m.created, "recycling must bound live slots: {m:?}");
        assert!(rec.0.len() as u64 >= 200);
    }

    /// §Soak tentpole: checkpoint at an op-quiescent boundary while events
    /// are still pending (a PortUp scheduled 30 s out), restore into a
    /// fresh instance, and drive both through an identical follow-up
    /// workload. Completion timers, dispatch counts, failover/failback
    /// stats, wire bytes and the RNG stream must be bit-identical — and
    /// re-checkpointing the restored sim must reproduce the original
    /// stream byte-for-byte (restore is a fixed point).
    #[test]
    fn checkpoint_restore_round_trip_is_bit_identical() {
        let cfg = fast_ft_cfg();
        let mut s = ClusterSim::new(cfg.clone());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(2));
        // Heals long after the checkpoint: the PortUp event must survive
        // serialization and fire identically post-resume.
        s.inject_port_up(port, SimTime::s(30));
        let a = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        assert!(s.run_until_op(a, 50_000_000));
        assert_eq!(s.stats.failovers, 1, "the flap must land mid-transfer");
        // Op-quiescent boundary: transfers drained, PortUp still queued.
        let boundary = s.now() + SimTime::ms(1);
        s.run_until(boundary - SimTime::ns(1));
        s.engine.advance_to(boundary);
        let text = s.checkpoint();

        let mut t = ClusterSim::restore(cfg, &text).expect("restore");
        assert_eq!(t.checkpoint(), text, "restore must be a checkpoint fixed point");

        let drive = |s: &mut ClusterSim| {
            // New traffic rides the backup QP, then the pending PortUp
            // fires and failback returns it to the primary.
            let b = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(32).0);
            assert!(s.run_until_op(b, 50_000_000));
            let c = s.submit(CollKind::AllGather, 1 << 20);
            assert!(s.run_until_op(c, 100_000_000));
            s.run_to_idle(100_000_000);
            (
                s.ops.iter().map(|o| o.finished_at.map(|t| t.as_ns())).collect::<Vec<_>>(),
                s.engine.dispatched(),
                s.stats.failovers,
                s.stats.failbacks,
                s.stats.wire_bytes,
                s.xfers.mem_stats(),
                s.rng.next_u64(),
            )
        };
        let orig = drive(&mut s);
        let resumed = drive(&mut t);
        assert_eq!(orig, resumed, "resumed run diverged from the uninterrupted one");
        assert_eq!(orig.3, 1, "the pending PortUp must drive exactly one failback");
    }

    /// Restoring under a different config (or a corrupted stream) must
    /// fail loudly, never silently misparse.
    #[test]
    fn restore_rejects_config_skew_and_corruption() {
        let cfg = fast_ft_cfg();
        let mut s = ClusterSim::new(cfg.clone());
        let a = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(4).0);
        assert!(s.run_until_op(a, 20_000_000));
        s.run_to_idle(20_000_000);
        let text = s.checkpoint();
        let mut other = cfg.clone();
        other.seed ^= 1;
        assert!(
            ClusterSim::restore(other, &text).unwrap_err().contains("different config"),
            "seed skew must be rejected"
        );
        let truncated = &text[..text.len() / 2];
        assert!(ClusterSim::restore(cfg, truncated).is_err(), "truncation must be rejected");
    }

    #[test]
    fn mempool_lazy_vs_eager_footprint() {
        let mut v = ClusterSim::new(fast_ft_cfg());
        let _ = v.run_p2p(RankId(0), RankId(8), ByteSize::mb(8).0);
        let lazy_peak: u64 = v.mempools.iter().map(|m| m.peak_bytes()).sum();
        let mut cfg = Config::nccl_baseline();
        cfg.vccl.channels = 1;
        let mut n = ClusterSim::new(cfg);
        let _ = n.run_p2p(RankId(0), RankId(8), ByteSize::mb(8).0);
        let eager_peak: u64 = n.mempools.iter().map(|m| m.peak_bytes()).sum();
        assert!(lazy_peak * 4 < eager_peak, "lazy={lazy_peak} eager={eager_peak}");
    }

    #[test]
    fn proxy_cpu_higher_for_smfree() {
        // Fig 17: SM-free shifts ~2% utilization to the CPU proxies.
        let mut v = ClusterSim::new(fast_ft_cfg());
        let _ = v.run_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        let v_cpu: u64 = v.stats.proxy_cpu_ns.iter().sum();
        let mut cfg = Config::nccl_baseline();
        cfg.vccl.channels = 1;
        let mut n = ClusterSim::new(cfg);
        let _ = n.run_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        let n_cpu: u64 = n.stats.proxy_cpu_ns.iter().sum();
        assert!(v_cpu > n_cpu, "vccl={v_cpu} nccl={n_cpu}");
    }

    /// §Perf L6 tentpole property: the fast-forward tier is a scheduling
    /// shortcut, never a model change. A seeded randomized workload —
    /// mixed collectives and P2P through all three run-loop entry points,
    /// port flaps straddling transfers, a mid-run checkpoint/restore cut —
    /// driven once fully evented and once fast-forwarded must agree on
    /// every observable: completion timers, per-op roll-ups, failover
    /// stats, wire bytes, trace streams, the final clock and the RNG
    /// stream. Only the *scheduling* counters (engine dispatch vs local
    /// dispatch split) may differ; their sum — `events_processed()` — is
    /// pinned equal too.
    #[test]
    fn randomized_equivalence_fast_forward_vs_evented() {
        let run = |fast_forward: bool| {
            let mut cfg = fast_ft_cfg();
            cfg.trace.enabled = true;
            cfg.engine.fast_forward = fast_forward;
            let mut s = ClusterSim::new(cfg.clone());
            let mut rng = crate::util::Rng::new(0x1F6);
            let ops_n = if cfg!(debug_assertions) { 60 } else { 300 };
            let flap_ranks = [0usize, 2, 4, 6, 8, 10, 12, 14];
            let mut finished = Vec::with_capacity(ops_n);
            for i in 0..ops_n {
                if rng.below(100) < 7 {
                    let g = flap_ranks[rng.below(flap_ranks.len() as u64) as usize];
                    let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(g)));
                    let at = s.now() + SimTime::ns(rng.range(1_000, 2_000_000));
                    s.inject_port_down(port, at);
                    s.inject_port_up(port, at + SimTime::ns(rng.range(100_000, 20_000_000)));
                }
                let id = match rng.below(10) {
                    0..=6 => {
                        let n = s.topo.num_ranks();
                        let src = RankId(rng.below(n as u64) as usize);
                        let mut dst = RankId(rng.below(n as u64) as usize);
                        if dst == src {
                            dst = RankId((src.0 + 1) % n);
                        }
                        s.submit_p2p(src, dst, rng.range(1, 4 << 20))
                    }
                    7 => s.submit(CollKind::AllReduce, rng.range(1 << 16, 2 << 20)),
                    8 => s.submit(CollKind::AllGather, rng.range(1 << 16, 2 << 20)),
                    _ => s.submit(CollKind::ReduceScatter, rng.range(1 << 16, 2 << 20)),
                };
                // Exercise every run-loop shape: the op-bounded loop, a
                // deadline loop that cuts windows short, and full drains.
                match rng.below(4) {
                    0 => {
                        let step = SimTime::ns(rng.range(10_000, 3_000_000));
                        s.run_until(s.now() + step);
                        assert!(s.run_until_op(id, 100_000_000), "op {i} must finish");
                    }
                    1 => {
                        s.run_to_idle(100_000_000);
                    }
                    _ => {
                        assert!(s.run_until_op(id, 100_000_000), "op {i} must finish");
                    }
                }
                // Mid-run checkpoint/resume cut at an op-quiescent
                // boundary: the restored sim replaces the original and
                // must carry the identical trajectory forward.
                if i == ops_n / 2 {
                    s.run_to_idle(100_000_000);
                    let boundary = s.now() + SimTime::ms(1);
                    s.run_until(boundary - SimTime::ns(1));
                    s.engine.advance_to(boundary);
                    let blob = s.checkpoint();
                    let tracer = s.tracer.clone();
                    let ffs = s.ff_stats();
                    s = ClusterSim::restore(cfg.clone(), &blob).expect("restore");
                    // The recorder ring and the fast-forward counters are
                    // diagnostics, not sim state: carry both across the
                    // cut so streams and work totals stay comparable.
                    s.tracer = tracer;
                    s.rdma.set_tracer(s.tracer.clone());
                    if let Some(m) = s.monitor.as_mut() {
                        m.set_tracer(s.tracer.clone());
                    }
                    s.ff.windows = ffs.windows;
                    s.ff.elided = ffs.elided;
                    s.ff.local_dispatched = ffs.local_dispatched;
                }
                finished.push(s.ops[id.0].finished_at.map(|t| t.as_ns()));
            }
            s.run_to_idle(100_000_000);
            let records: Vec<_> = s
                .tracer
                .sink()
                .expect("tracing on")
                .records()
                .iter()
                .map(|r| (r.at.as_ns(), r.ev.kind()))
                .collect();
            (
                finished,
                s.ops.iter().map(|o| format!("{:?}", o.chan_rollup)).collect::<Vec<_>>(),
                s.stats.failovers,
                s.stats.failbacks,
                s.stats.wire_bytes,
                s.now().as_ns(),
                s.rng.next_u64(),
                records,
                s.events_processed(),
            )
        };
        let evented = run(false);
        let fast = run(true);
        assert_eq!(evented, fast, "fast-forward trajectory diverged from evented");
        // And the tier must actually have engaged — elision is the point.
        let probe = {
            let mut cfg = fast_ft_cfg();
            cfg.engine.fast_forward = true;
            let mut s = ClusterSim::new(cfg);
            let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(8).0);
            s.run_to_idle(20_000_000);
            assert!(s.ops[id.0].is_done());
            s.ff_stats()
        };
        assert!(probe.windows > 0, "no fast-forward window opened: {probe:?}");
        assert!(probe.local_dispatched > 0, "nothing dispatched locally: {probe:?}");
    }

    /// With the tier disabled (the default), the counters stay zero and
    /// the engine sees every event — the pre-L6 behaviour, bit for bit.
    #[test]
    fn fast_forward_off_by_default_and_counters_stay_zero() {
        let mut s = ClusterSim::new(fast_ft_cfg());
        assert!(!s.cfg.engine.fast_forward);
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(8).0);
        s.run_to_idle(20_000_000);
        assert!(s.ops[id.0].is_done());
        assert_eq!(s.ff_stats(), FfStats::default());
        assert_eq!(s.events_processed(), s.engine.dispatched());
    }
}
