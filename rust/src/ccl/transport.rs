//! Transport cost profiles: what each P2P implementation pays per transfer
//! and per chunk, and which execution resources it holds (§3.2, Fig 1/4).
//!
//! | aspect                  | NCCL kernel      | NCCLX-like      | VCCL SM-free    |
//! |-------------------------|------------------|-----------------|-----------------|
//! | SMs held (inter-node)   | 2                | 1 (ordering)    | 0               |
//! | SMs held (intra-node)   | 32               | 1               | 0               |
//! | data movement intra     | SM copy kernel   | copy engine     | copy engine     |
//! | staging copies inter    | app↔chunk bufs   | zero-copy       | zero-copy       |
//! | GPU↔CPU sync per chunk  | flag polling     | none            | none            |
//! | stream ordering         | the kernel itself| 1-SM kernel     | writeValue ops  |
//!
//! (The NCCL baseline here is configured *with* zero-copy when the paper's
//! comparison does so — Fig 10 "we explicitly implement the zero-copy
//! mechanism for the NCCL baseline"; staging costs remain for intra-node
//! and for the chunk-FIFO handshake.)

use crate::config::{Config, StreamOrdering, Transport};
use crate::gpu::OrderingCost;

/// Whether a transfer crosses nodes, and how.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Locality {
    /// NVLink within one server.
    IntraNode,
    /// RDMA between servers, NIC-local GPU (same local index) — eligible
    /// for zero-copy GDR.
    InterSameRail,
    /// RDMA between servers with different local indices: PXN relays the
    /// payload over NVLink to the rail-local GPU first (§3.2-1).
    InterPxn,
}

/// How chunk payloads move on the sending side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataPath {
    /// GDR straight from the (registered) application buffer.
    ZeroCopy,
    /// Staged through the chunk FIFO by an SM copy kernel.
    SmStaged,
    /// Moved by a GPU copy engine (cudaMemcpy, async).
    CopyEngine,
}

/// Resolved per-transfer cost profile.
#[derive(Debug, Clone, Copy)]
pub struct TransportProfile {
    /// SMs held on the *sender* GPU for the whole transfer.
    pub src_sms: u32,
    /// SMs held on the *receiver* GPU for the whole transfer.
    pub dst_sms: u32,
    /// One-time setup on the critical path before the first chunk
    /// (kernel launch / proxy wake / ordering sync).
    pub setup_ns: u64,
    /// Added latency per chunk from GPU↔CPU synchronization (flag polling
    /// in the kernel transport; ~0 for the CPU-driven paths).
    pub per_chunk_sync_ns: u64,
    /// Sender-side staging before a chunk can be posted. `None` = no
    /// staging copy (zero-copy).
    pub stage: Option<DataPath>,
    /// Data path for the wire movement of intra-node chunks.
    pub intra_path: DataPath,
    /// Efficiency factor applied to intra-node link bandwidth
    /// (SM copies issue narrower transactions: §4.1's 7 %).
    pub intra_efficiency: f64,
    /// Receiver-side per-chunk delivery copy cost exists (chunk buf → app
    /// buf). Zero-copy transports skip it.
    pub recv_copy: bool,
}

impl TransportProfile {
    /// Resolve the profile for a transport × locality pair.
    pub fn resolve(cfg: &Config, locality: Locality) -> TransportProfile {
        let t = cfg.vccl.transport;
        let zero_copy = cfg.vccl.zero_copy;
        let ord = OrderingCost::of(match t {
            Transport::Kernel => StreamOrdering::WriteValue, // unused: kernel orders itself
            _ => cfg.vccl.ordering,
        });
        match t {
            Transport::Kernel => {
                let (src_sms, dst_sms) = match locality {
                    Locality::IntraNode => (32, 0), // sender-driven kernel copy
                    _ => (2, 2),                    // send + recv kernels
                };
                TransportProfile {
                    src_sms,
                    dst_sms,
                    setup_ns: cfg.gpu.kernel_launch_ns,
                    // GPU↔CPU flag polling gates each chunk the proxy posts;
                    // intra-node kernel copies never involve the proxy.
                    per_chunk_sync_ns: if locality == Locality::IntraNode {
                        0
                    } else {
                        cfg.gpu.gpu_cpu_poll_ns
                    },
                    stage: match locality {
                        Locality::IntraNode => None, // kernel writes peer directly
                        _ => {
                            if zero_copy {
                                None
                            } else {
                                Some(DataPath::SmStaged)
                            }
                        }
                    },
                    intra_path: DataPath::SmStaged,
                    intra_efficiency: cfg.gpu.sm_copy_efficiency,
                    recv_copy: !zero_copy && locality != Locality::IntraNode,
                }
            }
            Transport::NcclxLike => TransportProfile {
                // SM-free data path, but a persistent 1-SM ordering kernel
                // pinned on both parties for the op duration.
                src_sms: 1,
                dst_sms: if locality == Locality::IntraNode { 0 } else { 1 },
                setup_ns: cfg.gpu.kernel_launch_ns,
                per_chunk_sync_ns: 0,
                stage: None,
                intra_path: DataPath::CopyEngine,
                intra_efficiency: cfg.gpu.ce_copy_efficiency,
                recv_copy: false,
            },
            Transport::SmFree => TransportProfile {
                src_sms: 0,
                dst_sms: 0,
                setup_ns: ord.sync_ns,
                per_chunk_sync_ns: 0,
                stage: match locality {
                    // PXN still needs the NVLink relay copy; done by CE.
                    Locality::InterPxn => Some(DataPath::CopyEngine),
                    _ => None,
                },
                intra_path: DataPath::CopyEngine,
                intra_efficiency: cfg.gpu.ce_copy_efficiency,
                recv_copy: false,
            },
        }
    }
}

/// Classify a (src, dst) rank pair.
pub fn locality_of(
    cluster: &crate::topology::Cluster,
    src: crate::topology::RankId,
    dst: crate::topology::RankId,
) -> Locality {
    if cluster.same_node(src, dst) {
        Locality::IntraNode
    } else if cluster.gpu_of_rank(src).local == cluster.gpu_of_rank(dst).local {
        Locality::InterSameRail
    } else {
        Locality::InterPxn
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;
    use crate::topology::{Cluster, RankId};

    #[test]
    fn kernel_transport_holds_sms() {
        let cfg = Config::nccl_baseline();
        let inter = TransportProfile::resolve(&cfg, Locality::InterSameRail);
        assert_eq!((inter.src_sms, inter.dst_sms), (2, 2));
        let intra = TransportProfile::resolve(&cfg, Locality::IntraNode);
        assert_eq!(intra.src_sms, 32);
        assert!(intra.intra_efficiency < 0.9);
        assert_eq!(inter.per_chunk_sync_ns, cfg.gpu.gpu_cpu_poll_ns);
    }

    #[test]
    fn smfree_holds_none() {
        let cfg = Config::paper_defaults();
        for loc in [Locality::IntraNode, Locality::InterSameRail, Locality::InterPxn] {
            let p = TransportProfile::resolve(&cfg, loc);
            assert_eq!((p.src_sms, p.dst_sms), (0, 0), "{loc:?}");
            assert_eq!(p.per_chunk_sync_ns, 0);
            assert!(!p.recv_copy);
        }
        // Zero-copy except the PXN relay.
        assert!(TransportProfile::resolve(&cfg, Locality::InterSameRail).stage.is_none());
        assert_eq!(
            TransportProfile::resolve(&cfg, Locality::InterPxn).stage,
            Some(DataPath::CopyEngine)
        );
    }

    #[test]
    fn ncclx_holds_exactly_one_sm() {
        let cfg = Config::ncclx_like();
        let p = TransportProfile::resolve(&cfg, Locality::InterSameRail);
        assert_eq!((p.src_sms, p.dst_sms), (1, 1));
        assert!(p.stage.is_none());
    }

    #[test]
    fn ce_beats_sm_copy_efficiency() {
        // The §4.1 +7% intra-node bandwidth claim reduces to this ordering.
        let v = TransportProfile::resolve(&Config::paper_defaults(), Locality::IntraNode);
        let n = TransportProfile::resolve(&Config::nccl_baseline(), Locality::IntraNode);
        assert!(v.intra_efficiency > n.intra_efficiency);
        let gain = v.intra_efficiency / n.intra_efficiency;
        assert!((1.05..1.10).contains(&gain), "gain={gain}");
    }

    #[test]
    fn locality_classification() {
        let c = Cluster::new(TopologyConfig { num_nodes: 2, ..Default::default() });
        assert_eq!(locality_of(&c, RankId(0), RankId(3)), Locality::IntraNode);
        assert_eq!(locality_of(&c, RankId(0), RankId(8)), Locality::InterSameRail);
        assert_eq!(locality_of(&c, RankId(0), RankId(9)), Locality::InterPxn);
    }
}
