//! GPU memory accounting: NCCL-style eager pre-allocation vs VCCL's dynamic
//! memory pool (§4.4 "Optimizing memory usage", Appendix J / Fig 21).
//!
//! NCCL's default behaviour pre-allocates chunk buffers for **every**
//! (peer, channel, protocol) triple at communicator init; with complex
//! parallelism (MoE: big TP×EP×PP communicator sets) that reaches ~10 GB of
//! HBM. VCCL changes two things:
//!
//!  1. **Lazy allocation** — a connection's buffers are carved out of a
//!     2 MB-aligned pool on *first use*, so channels/protocols/peers that a
//!     model never exercises cost nothing;
//!  2. **Zero-copy** — registered user buffers replace intermediate chunk
//!     buffers for P2P, removing the allocation entirely.
//!
//! This module is pure accounting (no DES involvement): the communicator
//! calls it during setup and on first use, experiments read the footprint.

use std::collections::HashMap;

use crate::util::{CkptReader, CkptWriter};

/// NCCL protocol variants that each get buffer space in eager mode.
pub const PROTOCOLS: usize = 3; // LL, LL128, Simple

/// 2MB alignment quantum of the pool (cuMem granularity).
pub const POOL_ALIGN: u64 = 2 << 20;

/// Allocation policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AllocPolicy {
    /// NCCL default: all (peer, channel, protocol) buffers at init.
    Eager,
    /// VCCL: 2MB-aligned pool, connections served on first use.
    LazyPool,
}

/// Per-rank memory accounting.
#[derive(Debug)]
pub struct MemPool {
    policy: AllocPolicy,
    zero_copy: bool,
    buffer_bytes: u64, // chunk buffer size per (peer, channel, protocol)
    /// Pool bytes actually reserved (lazy) or total eager reservation.
    reserved: u64,
    /// Bytes handed out of the reservation (lazy only).
    used: u64,
    /// Which (peer, channel) pairs already have buffers (lazy only).
    live: HashMap<(usize, usize), u64>,
    /// Peak reservation observed (the Fig 21 metric).
    peak: u64,
}

impl MemPool {
    pub fn new(policy: AllocPolicy, zero_copy: bool, buffer_bytes: u64) -> Self {
        MemPool {
            policy,
            zero_copy,
            buffer_bytes,
            reserved: 0,
            used: 0,
            live: HashMap::new(),
            peak: 0,
        }
    }

    /// Communicator init: eager mode reserves everything up front.
    pub fn on_init(&mut self, peers: usize, channels: usize) {
        if self.policy == AllocPolicy::Eager {
            // Every peer × channel × protocol gets a buffer, plus the same
            // again for receive-side staging when zero-copy is off.
            let per_conn = self.buffer_bytes * PROTOCOLS as u64;
            let sides = if self.zero_copy { 1 } else { 2 };
            self.reserved = per_conn * peers as u64 * channels as u64 * sides;
        }
        self.peak = self.peak.max(self.reserved);
    }

    /// A connection's first transfer: lazy mode allocates from the pool.
    /// Returns the bytes newly reserved (0 if already live / zero-copy).
    pub fn on_first_use(&mut self, peer: usize, channel: usize) -> u64 {
        if self.policy == AllocPolicy::Eager {
            return 0; // already paid at init
        }
        if self.live.contains_key(&(peer, channel)) {
            return 0;
        }
        // Zero-copy removes the data buffers; a small control FIFO remains.
        let need = if self.zero_copy {
            self.buffer_bytes / 16 // CTS fifo + flags, not payload staging
        } else {
            self.buffer_bytes // Simple-protocol staging only, on demand
        };
        self.live.insert((peer, channel), need);
        self.used += need;
        let before = self.reserved;
        while self.reserved < self.used {
            self.reserved += POOL_ALIGN;
        }
        self.peak = self.peak.max(self.reserved);
        self.reserved - before
    }

    /// Current HBM reservation attributable to the CCL.
    pub fn reserved_bytes(&self) -> u64 {
        self.reserved
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak
    }

    pub fn live_connections(&self) -> usize {
        self.live.len()
    }

    /// Serialize the accounting state (§Soak checkpointing). Policy and
    /// buffer sizing come from config at restore, not the stream.
    pub fn save(&self, w: &mut CkptWriter) {
        w.u64("rsv", self.reserved);
        w.u64("used", self.used);
        w.u64("peak", self.peak);
        let mut live: Vec<(&(usize, usize), &u64)> = self.live.iter().collect();
        live.sort_unstable_by_key(|(k, _)| **k);
        w.usize("nlive", live.len());
        for ((peer, channel), bytes) in live {
            w.usize("p", *peer);
            w.usize("c", *channel);
            w.u64("b", *bytes);
        }
    }

    /// Restore accounting into a freshly constructed pool.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        self.reserved = r.u64("rsv")?;
        self.used = r.u64("used")?;
        self.peak = r.u64("peak")?;
        self.live.clear();
        for _ in 0..r.usize("nlive")? {
            let peer = r.usize("p")?;
            let channel = r.usize("c")?;
            let bytes = r.u64("b")?;
            self.live.insert((peer, channel), bytes);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUF: u64 = 8 << 20; // 8MB per buffer, NCCL-Simple-ish

    #[test]
    fn eager_pays_everything_up_front() {
        let mut m = MemPool::new(AllocPolicy::Eager, false, BUF);
        m.on_init(15, 16); // 16-rank communicator, 16 channels
        let expect = BUF * PROTOCOLS as u64 * 15 * 16 * 2;
        assert_eq!(m.reserved_bytes(), expect);
        // First use adds nothing.
        assert_eq!(m.on_first_use(3, 0), 0);
        assert_eq!(m.reserved_bytes(), expect);
    }

    #[test]
    fn lazy_grows_with_use_only() {
        let mut m = MemPool::new(AllocPolicy::LazyPool, false, BUF);
        m.on_init(15, 16);
        assert_eq!(m.reserved_bytes(), 0);
        m.on_first_use(0, 0);
        let r1 = m.reserved_bytes();
        assert!(r1 >= BUF && r1 % POOL_ALIGN == 0);
        // Re-use is free.
        assert_eq!(m.on_first_use(0, 0), 0);
        m.on_first_use(0, 1);
        assert!(m.reserved_bytes() >= 2 * BUF);
        assert_eq!(m.live_connections(), 2);
    }

    #[test]
    fn zero_copy_shrinks_lazy_footprint() {
        let mut with_zc = MemPool::new(AllocPolicy::LazyPool, true, BUF);
        let mut without = MemPool::new(AllocPolicy::LazyPool, false, BUF);
        for m in [&mut with_zc, &mut without] {
            m.on_init(15, 16);
            for p in 0..4 {
                for c in 0..16 {
                    m.on_first_use(p, c);
                }
            }
        }
        assert!(with_zc.reserved_bytes() < without.reserved_bytes() / 4);
    }

    #[test]
    fn pool_alignment_respected() {
        let mut m = MemPool::new(AllocPolicy::LazyPool, true, BUF);
        m.on_init(7, 2);
        m.on_first_use(1, 0);
        assert_eq!(m.reserved_bytes() % POOL_ALIGN, 0);
    }

    #[test]
    fn fig21_shape_lazy_plus_zerocopy_saves_vs_eager() {
        // A "complex parallelism" communicator: many peers and channels but
        // a sparse usage pattern (each rank talks to few peers in practice).
        let peers = 31;
        let channels = 16;
        let mut nccl = MemPool::new(AllocPolicy::Eager, false, BUF);
        nccl.on_init(peers, channels);
        let mut vccl = MemPool::new(AllocPolicy::LazyPool, true, BUF);
        vccl.on_init(peers, channels);
        for p in 0..6 {
            // PP neighbours + a few DP peers actually used
            for c in 0..channels {
                vccl.on_first_use(p, c);
            }
        }
        let saving = 1.0 - vccl.peak_bytes() as f64 / nccl.peak_bytes() as f64;
        // Paper reports up to 26.7% of *total model HBM*; relative to CCL
        // buffers alone the saving is far larger.
        assert!(saving > 0.9, "saving={saving}");
    }
}
