//! Dependency-driven 1F1B execution over the cluster simulation.

use std::collections::HashMap;

use crate::ccl::{ClusterSim, Event};
use crate::config::{Config, StreamOrdering, Transport};
use crate::gpu::{BrokerOutcome, EventFlag, HostCallback, HostFuncBroker};
use crate::sim::SimTime;
use crate::topology::RankId;

/// One work item of the schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Item {
    F(usize), // forward of microbatch j
    B(usize), // backward of microbatch j
}

/// Pipeline configuration (Table 3 defaults: PP=4, microbatches from the
/// global batch, 1F1B).
#[derive(Debug, Clone)]
pub struct PipelineCfg {
    /// Pipeline stages (each mapped to one GPU rank).
    pub stages: usize,
    /// Microbatches per iteration.
    pub microbatches: usize,
    /// Forward compute per microbatch per stage at full rate (ns).
    pub fwd_ns: u64,
    /// Backward compute per microbatch per stage (ns); ≈ 2× forward.
    pub bwd_ns: u64,
    /// Activation/gradient message size between stages (Appendix C:
    /// B × L × H × p bytes, typically ≥ 32 MB).
    pub msg_bytes: u64,
    /// Which ranks host the stages (must be `stages` long).
    pub stage_ranks: Vec<RankId>,
    /// Model FLOPs per microbatch per stage (for the TFLOPS report).
    pub flops_per_micro_stage: f64,
}

impl PipelineCfg {
    /// Spread `stages` across the cluster: consecutive stages land on
    /// consecutive GPUs, wrapping across nodes (mixes NVLink and RDMA
    /// boundaries like a real Megatron placement).
    pub fn spread(cfg: &Config, stages: usize, microbatches: usize) -> PipelineCfg {
        let n = cfg.topo.num_nodes * cfg.topo.gpus_per_node;
        assert!(stages <= n, "more stages than GPUs");
        let stride = n / stages;
        let stage_ranks = (0..stages).map(|s| RankId(s * stride)).collect();
        // Defaults sized like a GPT block stack per stage at BF16:
        // fwd ≈ 4 ms, bwd ≈ 8 ms, 64 MB boundary tensors.
        PipelineCfg {
            stages,
            microbatches,
            fwd_ns: 4_000_000,
            bwd_ns: 8_000_000,
            msg_bytes: 64 << 20,
            stage_ranks,
            flops_per_micro_stage: 0.0,
        }
    }
}

/// Outcome of one iteration.
#[derive(Debug, Clone)]
pub struct PipelineResult {
    pub iter_ns: u64,
    /// Per-GPU achieved TFLOPS (0 if flops_per_micro_stage unset).
    pub tflops_per_gpu: f64,
    /// hostFunc ordering deadlocked the bidirectional exchange (Fig 5).
    pub deadlocked: bool,
    /// The iteration hung on an unrecovered link failure (NCCL + port down).
    pub hung: bool,
    /// Communication-kernel SM utilisation over the iteration (Table 1-ish).
    pub comm_sm_utilization: f64,
}

/// Dependency-driven 1F1B executor.
pub struct PipelineSim {
    pub sim: ClusterSim,
    pub cfg: PipelineCfg,
    /// Per-stage item sequence (1F1B order) and progress cursor.
    seq: Vec<Vec<Item>>,
    cursor: Vec<usize>,
    running: Vec<Option<Item>>,
    /// Arrived activations / gradients: (stage, microbatch).
    acts: Vec<Vec<bool>>,
    grads: Vec<Vec<bool>>,
    /// Outstanding sends: op → (kind_is_fwd, dst_stage, microbatch).
    pending_sends: HashMap<usize, (bool, usize, usize)>,
    finished_ops: usize,
}

impl PipelineSim {
    pub fn new(mut sim: ClusterSim, cfg: PipelineCfg) -> Self {
        assert_eq!(cfg.stage_ranks.len(), cfg.stages);
        // Keep channel counts modest: PP messages are few and large.
        sim.cfg.vccl.channels = sim.cfg.vccl.channels.min(4).max(1);
        let p = cfg.stages;
        let m = cfg.microbatches;
        let seq = (0..p).map(|s| one_f1b_sequence(p, m, s)).collect();
        PipelineSim {
            sim,
            cfg,
            seq,
            cursor: vec![0; p],
            running: vec![None; p],
            acts: vec![vec![false; m]; p],
            grads: vec![vec![false; m]; p],
            pending_sends: HashMap::new(),
            finished_ops: 0,
        }
    }

    /// The Fig 5 check: with hostFunc ordering and *unmerged* bidirectional
    /// P2P groups, the steady-state F/B exchange between adjacent stages
    /// deadlocks the host-callback threads.
    fn hostfunc_deadlocks(&self) -> bool {
        if self.sim.cfg.vccl.transport != Transport::SmFree
            || self.sim.cfg.vccl.ordering != StreamOrdering::HostFunc
            || self.cfg.stages < 2
            || self.cfg.microbatches < 2
        {
            return false;
        }
        // Reconstruct the steady-state callback queues of an adjacent pair.
        let mut broker = HostFuncBroker::new();
        const FWD: EventFlag = EventFlag(1);
        const BWD: EventFlag = EventFlag(2);
        broker.enqueue(0, HostCallback { waits: Some(BWD), signals: vec![], label: "s0.wait_bwd" });
        broker.enqueue(0, HostCallback { waits: None, signals: vec![FWD], label: "s0.ready_fwd" });
        broker.enqueue(1, HostCallback { waits: Some(FWD), signals: vec![], label: "s1.wait_fwd" });
        broker.enqueue(1, HostCallback { waits: None, signals: vec![BWD], label: "s1.ready_bwd" });
        matches!(broker.run(&[]), BrokerOutcome::Deadlock(_))
    }

    fn deps_ready(&self, stage: usize, item: Item) -> bool {
        match item {
            Item::F(j) => stage == 0 || self.acts[stage][j],
            Item::B(j) => {
                if stage == self.cfg.stages - 1 {
                    // Last stage: backward follows its own forward, which
                    // sequence order already guarantees.
                    true
                } else {
                    self.grads[stage][j]
                }
            }
        }
    }

    /// Start any stage whose head item is ready.
    fn schedule_ready(&mut self) {
        let now = self.sim.now();
        for s in 0..self.cfg.stages {
            if self.running[s].is_some() || self.cursor[s] >= self.seq[s].len() {
                continue;
            }
            let item = self.seq[s][self.cursor[s]];
            if !self.deps_ready(s, item) {
                continue;
            }
            let work = match item {
                Item::F(_) => self.cfg.fwd_ns,
                Item::B(_) => self.cfg.bwd_ns,
            };
            let gpu = self.cfg.stage_ranks[s].0;
            let tag = encode_tag(s, item);
            let (_, timer) = self.sim.gpus[gpu].compute.start_task(work, tag, now);
            self.sim
                .engine
                .schedule_at(timer.at, Event::GpuTask { gpu, task: timer.task, gen: timer.gen });
            self.running[s] = Some(item);
        }
    }

    fn on_compute_done(&mut self, stage: usize, item: Item) {
        debug_assert_eq!(self.running[stage], Some(item));
        self.running[stage] = None;
        self.cursor[stage] += 1;
        // Emit the boundary communication; it overlaps with whatever the
        // stage runs next (the transport decides what that overlap costs).
        match item {
            Item::F(j) => {
                if stage + 1 < self.cfg.stages {
                    let op = self.sim.submit_p2p(
                        self.cfg.stage_ranks[stage],
                        self.cfg.stage_ranks[stage + 1],
                        self.cfg.msg_bytes,
                    );
                    self.pending_sends.insert(op.0, (true, stage + 1, j));
                }
            }
            Item::B(j) => {
                if stage > 0 {
                    let op = self.sim.submit_p2p(
                        self.cfg.stage_ranks[stage],
                        self.cfg.stage_ranks[stage - 1],
                        self.cfg.msg_bytes,
                    );
                    self.pending_sends.insert(op.0, (false, stage - 1, j));
                }
            }
        }
    }

    fn poll_ops(&mut self) -> bool {
        let mut hung = false;
        let done: Vec<usize> = self
            .pending_sends
            .keys()
            .copied()
            .filter(|&o| self.sim.ops[o].is_done() || self.sim.ops[o].failed)
            .collect();
        for o in done {
            let (is_fwd, dst, j) = self.pending_sends.remove(&o).unwrap();
            if self.sim.ops[o].failed {
                hung = true;
                continue;
            }
            self.finished_ops += 1;
            if is_fwd {
                self.acts[dst][j] = true;
            } else {
                self.grads[dst][j] = true;
            }
        }
        hung
    }

    fn all_done(&self) -> bool {
        (0..self.cfg.stages).all(|s| self.cursor[s] >= self.seq[s].len())
            && self.pending_sends.is_empty()
    }

    /// Run one training iteration (all microbatches through all stages).
    pub fn run_iteration(&mut self) -> PipelineResult {
        if self.hostfunc_deadlocks() {
            return PipelineResult {
                iter_ns: 0,
                tflops_per_gpu: 0.0,
                deadlocked: true,
                hung: false,
                comm_sm_utilization: 0.0,
            };
        }
        let start = self.sim.now();
        // Reset per-iteration state.
        for s in 0..self.cfg.stages {
            self.cursor[s] = 0;
            self.running[s] = None;
            for j in 0..self.cfg.microbatches {
                self.acts[s][j] = false;
                self.grads[s][j] = false;
            }
        }
        self.schedule_ready();
        let mut hung = false;
        let hang_budget = SimTime::s(3_000);
        while !self.all_done() {
            let Some((_, ev)) = self.sim.engine.pop() else {
                // Engine drained but the schedule isn't finished: a send
                // hung without fault tolerance.
                hung = true;
                break;
            };
            match ev {
                Event::GpuTask { gpu, task, gen } => {
                    let now = self.sim.now();
                    if let Some(tag) = self.sim.gpus[gpu].compute.try_finish(task, gen, now) {
                        let (stage, item) = decode_tag(tag);
                        self.on_compute_done(stage, item);
                    }
                }
                other => self.sim.dispatch(other),
            }
            hung |= self.poll_ops();
            if hung {
                break;
            }
            self.schedule_ready();
            if self.sim.now().since(start) > hang_budget {
                hung = true;
                break;
            }
        }
        let iter_ns = self.sim.now().since(start).as_ns();
        let p = self.cfg.stages;
        let total_flops = self.cfg.flops_per_micro_stage
            * self.cfg.microbatches as f64
            * 3.0 // fwd + 2×bwd
            * p as f64;
        let tflops_per_gpu = if iter_ns > 0 && !hung {
            total_flops / (iter_ns as f64) / p as f64 * 1e9 / 1e12
        } else {
            0.0
        };
        let now = self.sim.now();
        let util: f64 = (0..p)
            .map(|s| self.sim.gpus[self.cfg.stage_ranks[s].0].compute.comm_sm_utilization(now))
            .sum::<f64>()
            / p as f64;
        PipelineResult {
            iter_ns,
            tflops_per_gpu,
            deadlocked: false,
            hung,
            comm_sm_utilization: util,
        }
    }
}

fn encode_tag(stage: usize, item: Item) -> u64 {
    let (kind, j) = match item {
        Item::F(j) => (0u64, j as u64),
        Item::B(j) => (1u64, j as u64),
    };
    (stage as u64) << 32 | kind << 31 | j
}

fn decode_tag(tag: u64) -> (usize, Item) {
    let stage = (tag >> 32) as usize;
    let j = (tag & 0x7FFF_FFFF) as usize;
    let item = if (tag >> 31) & 1 == 1 { Item::B(j) } else { Item::F(j) };
    (stage, item)
}

/// The canonical 1F1B order for stage `s` of `p` with `m` microbatches:
/// `w = min(m, p−s−1)` warm-up forwards, steady 1F1B, backward drain.
fn one_f1b_sequence(p: usize, m: usize, s: usize) -> Vec<Item> {
    let w = (p - s - 1).min(m);
    let mut seq = Vec::with_capacity(2 * m);
    for j in 0..w {
        seq.push(Item::F(j));
    }
    let mut next_f = w;
    let mut next_b = 0;
    while next_f < m {
        seq.push(Item::F(next_f));
        next_f += 1;
        seq.push(Item::B(next_b));
        next_b += 1;
    }
    while next_b < m {
        seq.push(Item::B(next_b));
        next_b += 1;
    }
    seq
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn pipe(cfg: Config, stages: usize, m: usize) -> PipelineSim {
        let pcfg = PipelineCfg::spread(&cfg, stages, m);
        PipelineSim::new(ClusterSim::new(cfg), pcfg)
    }

    #[test]
    fn sequence_shape_is_1f1b() {
        // p=4, m=8, stage 0: 3 warmups then alternating, ending in Bs.
        let seq = one_f1b_sequence(4, 8, 0);
        assert_eq!(seq.len(), 16);
        assert_eq!(&seq[..3], &[Item::F(0), Item::F(1), Item::F(2)]);
        assert_eq!(seq[3], Item::F(3));
        assert_eq!(seq[4], Item::B(0));
        assert_eq!(*seq.last().unwrap(), Item::B(7));
        // Last stage: strict F,B alternation.
        let last = one_f1b_sequence(4, 8, 3);
        assert_eq!(&last[..4], &[Item::F(0), Item::B(0), Item::F(1), Item::B(1)]);
    }

    #[test]
    fn every_microbatch_appears_once_each_direction() {
        for s in 0..4 {
            let seq = one_f1b_sequence(4, 6, s);
            let fs: Vec<usize> = seq.iter().filter_map(|i| match i { Item::F(j) => Some(*j), _ => None }).collect();
            let bs: Vec<usize> = seq.iter().filter_map(|i| match i { Item::B(j) => Some(*j), _ => None }).collect();
            assert_eq!(fs, (0..6).collect::<Vec<_>>());
            assert_eq!(bs, (0..6).collect::<Vec<_>>());
        }
    }

    #[test]
    fn iteration_completes_and_is_bounded_below() {
        let mut p = pipe(Config::paper_defaults(), 4, 8);
        let r = p.run_iteration();
        assert!(!r.hung && !r.deadlocked);
        // Lower bound: (m + p − 1) × (tf + tb) critical path on the last
        // stage ≈ (8+3) × 12ms = 132 ms... actually (p−1)(tf+tb) bubble +
        // m×(tf+tb) steady = 11 × 12 ms = 132 ms.
        let lower = (8 + 3) as u64 * 12_000_000;
        assert!(r.iter_ns >= lower, "iter={} lower={lower}", r.iter_ns);
        // And not absurdly above it (comm must overlap).
        assert!(r.iter_ns < lower * 13 / 10, "iter={}", r.iter_ns);
    }

    #[test]
    fn vccl_beats_nccl_by_paper_margin() {
        // Fig 11: SM-free overlap buys ~4–5.3% iteration time.
        let mut v = pipe(Config::paper_defaults(), 4, 8);
        let rv = v.run_iteration();
        let mut n = pipe(Config::nccl_baseline(), 4, 8);
        let rn = n.run_iteration();
        let gain = rn.iter_ns as f64 / rv.iter_ns as f64 - 1.0;
        assert!(gain > 0.005, "gain={gain}");
        assert!(gain < 0.12, "gain={gain}");
    }

    #[test]
    fn ncclx_sits_between_nccl_and_vccl() {
        let mut v = pipe(Config::paper_defaults(), 4, 8);
        let rv = v.run_iteration().iter_ns;
        let mut x = pipe(Config::ncclx_like(), 4, 8);
        let rx = x.run_iteration().iter_ns;
        let mut n = pipe(Config::nccl_baseline(), 4, 8);
        let rn = n.run_iteration().iter_ns;
        assert!(rv <= rx && rx <= rn, "v={rv} x={rx} n={rn}");
        assert!(rx > rv, "the 1-SM ordering kernel must cost something");
    }

    #[test]
    fn hostfunc_ordering_deadlocks_unmerged_groups() {
        let mut cfg = Config::paper_defaults();
        cfg.vccl.ordering = crate::config::StreamOrdering::HostFunc;
        let mut p = pipe(cfg, 4, 8);
        let r = p.run_iteration();
        assert!(r.deadlocked, "Fig 5: hostFunc must deadlock bidirectional 1F1B");
    }

    #[test]
    fn comm_sm_utilization_orders_by_transport() {
        let mut v = pipe(Config::paper_defaults(), 4, 8);
        let uv = v.run_iteration().comm_sm_utilization;
        let mut x = pipe(Config::ncclx_like(), 4, 8);
        let ux = x.run_iteration().comm_sm_utilization;
        let mut n = pipe(Config::nccl_baseline(), 4, 8);
        let un = n.run_iteration().comm_sm_utilization;
        assert_eq!(uv, 0.0, "SM-free must not consume SMs");
        assert!(ux > 0.0 && un > ux, "v={uv} x={ux} n={un}");
    }

    #[test]
    fn link_failure_hangs_nccl_but_not_vccl() {
        // Fast retry window for test speed.
        let mk = |mut cfg: Config| {
            cfg.net.ib_timeout_exp = 10;
            cfg.net.ib_retry_cnt = 2;
            cfg.net.qp_warmup_ns = 50_000_000;
            cfg
        };
        let mut v = pipe(mk(Config::paper_defaults()), 4, 8);
        // Stage 1→2 boundary crosses nodes (ranks 4 → 8). Kill rank 4's NIC.
        let port = v.sim.topo.primary_port(v.sim.topo.gpu_of_rank(RankId(4)));
        v.sim.inject_port_down(port, SimTime::ms(30));
        let rv = v.run_iteration();
        assert!(!rv.hung, "VCCL must ride through the failure");
        assert!(v.sim.stats.failovers >= 1);

        let mut n = pipe(mk(Config::nccl_baseline()), 4, 8);
        let port = n.sim.topo.primary_port(n.sim.topo.gpu_of_rank(RankId(4)));
        n.sim.inject_port_down(port, SimTime::ms(30));
        let rn = n.run_iteration();
        assert!(rn.hung, "NCCL baseline must hang (Fig 13b)");
    }
}
