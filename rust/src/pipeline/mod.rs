//! Pipeline-parallel training model: the 1F1B schedule (Fig 6) executed
//! over the cluster simulation ([`crate::ccl::ClusterSim`]).
//!
//! This is where the paper's headline number comes from: in 1F1B the P2P
//! activation/gradient exchanges overlap with forward/backward compute, and
//! the *only* difference between NCCL and VCCL is what the communication
//! costs the compute — kernel-based P2P parks 2 (inter) / 32 (intra) SMs on
//! the GPU and tail-straggles the co-resident GEMMs (Appendix E); the
//! NCCLX-like design parks 1; SM-free parks none. The schedule below runs
//! real dependency-driven 1F1B over [`crate::ccl::ClusterSim`], so compute slowdowns
//! and communication times interact exactly as they do on hardware.
//!
//! [`scaling`] adds the §5 analytic model `I = (Tn − Tv)/(Tv + α)` for the
//! gain-vs-cluster-size trend.

pub mod schedule;
pub mod scaling;

pub use scaling::{dp_overhead_ns, relative_gain};
pub use schedule::{PipelineCfg, PipelineResult, PipelineSim};
