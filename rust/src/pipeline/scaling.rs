//! The §5 analytic scaling model.
//!
//! > "The performance benefit can be modeled as I = (Tn − Tv)/(Tv + α),
//! >  where Tv and Tn denote per-iteration compute time under VCCL and
//! >  NCCL, and α represents DP communication overhead. Since the
//! >  communication pattern within the DP group follows the ring algorithm
//! >  over a single-rail interconnect, AllReduce overhead exhibits linear
//! >  scaling, causing I to decrease with cluster size."

/// DP AllReduce overhead for a ring over `dp` ranks moving `bytes` of
/// gradients at `link_gbps` per rail: t = 2(n−1)/n × bytes / rate — the
/// linear-in-n trend the paper describes (the n-dependent factor grows
/// toward 2 and, more importantly, per-rail serialization adds latency
/// terms linear in n).
pub fn dp_overhead_ns(dp: usize, grad_bytes: u64, link_gbps: f64, hop_ns: u64) -> u64 {
    if dp <= 1 {
        return 0;
    }
    let n = dp as f64;
    let volume = 2.0 * (n - 1.0) / n * grad_bytes as f64;
    let bw_ns = volume / (link_gbps * 0.125);
    // 2(n−1) ring steps each paying per-hop latency.
    let lat_ns = 2.0 * (n - 1.0) * hop_ns as f64;
    (bw_ns + lat_ns) as u64
}

/// The paper's relative-gain formula.
pub fn relative_gain(t_nccl_ns: u64, t_vccl_ns: u64, alpha_ns: u64) -> f64 {
    (t_nccl_ns as f64 - t_vccl_ns as f64) / (t_vccl_ns as f64 + alpha_ns as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gain_decreases_with_dp_scale() {
        // Fixed compute times; α grows with DP width → I shrinks (§5).
        let (tn, tv) = (105_000_000u64, 100_000_000u64);
        let grad = 4u64 << 30; // 4GB of gradients
        let gains: Vec<f64> = [2usize, 8, 32, 128]
            .iter()
            .map(|&dp| relative_gain(tn, tv, dp_overhead_ns(dp, grad, 400.0, 1200)))
            .collect();
        for w in gains.windows(2) {
            assert!(w[1] < w[0], "gain must shrink with scale: {gains:?}");
        }
        // But absolute GPU-time savings stay positive at any scale.
        assert!(gains.iter().all(|g| *g > 0.0));
    }

    #[test]
    fn no_dp_no_alpha() {
        assert_eq!(dp_overhead_ns(1, 1 << 30, 400.0, 1000), 0);
        let g = relative_gain(105, 100, 0);
        assert!((g - 0.05).abs() < 1e-9);
    }

    #[test]
    fn alpha_linear_trend() {
        let a8 = dp_overhead_ns(8, 1 << 30, 400.0, 1000);
        let a16 = dp_overhead_ns(16, 1 << 30, 400.0, 1000);
        let a32 = dp_overhead_ns(32, 1 << 30, 400.0, 1000);
        // Monotone increasing, sublinear-to-linear in n.
        assert!(a16 > a8 && a32 > a16);
    }
}
