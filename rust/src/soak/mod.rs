//! Time-compressed soak harness (§Soak): simulated days of mixed training
//! traffic under an MTBF/MTTR-driven fault schedule, with periodic
//! checkpoint/resume of the full simulation state.
//!
//! Simulated time is divided into fixed-period **bursts** (one "training
//! step" each: a DP AllReduce followed by a wave of pipeline P2Ps, then
//! idle until the next period boundary — the time compression). Between
//! bursts the simulation is **op-quiescent**: no live transfers, flows,
//! outstanding WRs or armed δ-probes — exactly the state
//! [`ClusterSim::checkpoint`] requires. Future events (a port heal, a QP
//! warm-up) may be pending at a boundary; they serialize with the engine.
//!
//! Four fault classes, drawn from one Poisson process ([`FaultClock`],
//! exponential inter-arrivals at the configured MTBF), mixed by the
//! `soak.{flap,degrade,trunk,switch}` weights:
//!
//! - **port flaps** — `inject_port_down` at the fault time, `inject_port_up`
//!   MTTR later, both as engine events. Exercises the §3.3 failover /
//!   failback machinery; graded against `stats.failovers`/`failbacks`.
//! - **link degrades** (straggler NIC / slow switch) — the port's TX link
//!   capacity is cut ÷[`DEGRADE_FACTOR`] at the burst boundary and restored
//!   `ceil(MTTR/period)` bursts later. The port keeps completing WCs at the
//!   collapsed rate, which is what the §3.4 window monitor exists to catch;
//!   graded as a per-(port, burst) confusion matrix against the monitor's
//!   non-`Healthy` verdict deltas.
//! - **trunk degrades** (`soak.trunk_weight`, §Fault domains) — the trunk
//!   link of the victim's rail is cut ÷[`DEGRADE_FACTOR`] instead of its
//!   NIC uplink. Both endpoint ports stay pristine; the collapse is only
//!   visible end-to-end, and RCA must attribute it to the owning switch.
//!   Victim exclusion is keyed on the resolved [`LinkId`] — two victims on
//!   the same rail resolve to the SAME trunk, and a second booking would
//!   record the already-cut capacity as "original".
//! - **switch downs** (`soak.switch_weight`) — the victim rail's leaf
//!   switch dies whole (`inject_switch_down`), cascading to every member
//!   link; heals MTTR later. Per victim this grades exactly like a flap
//!   (one failover to the backup plane/rail, one failback), but the
//!   perception path is path-death, never a port flap.
//! - **node crashes** (`soak.node_weight`, §Elastic) — a whole peer node
//!   (never node 0, which hosts every graded port) dies
//!   (`inject_node_down`), the cluster shrinks around it, and it rejoins
//!   MTTR later. Graded as zero lost ops and exactly one elastic
//!   shrink + rejoin per crash. While the victim is dead the pipeline
//!   wave routes to the next alive peer node (skipped when none exists).
//!   Dedup is two-way across fault domains: a crash on a node with an
//!   in-force port fault is suppressed (the flap's heal would revive one
//!   port of a dead server), and port-keyed faults on a crashed node's
//!   ports are suppressed — both counted via `faults_suppressed`,
//!   mirroring the LinkId-keyed trunk dedup.
//!
//! Every injection is appended to the **fault tape** ([`TapeEntry`], the
//! soak's ground truth) so `vccl rca` can diagnose a soak's trace ring and
//! grade precision/recall against the injected schedule.
//!
//! Fault targets are ranks `1..=gpus_per_node-2` of node 0: their primary
//! ports carry exactly one steady P2P flow per burst (never a ring-crossing
//! edge), so one flap maps to one failover and a fault-free graded port has
//! no bandwidth-collapse excuse. Burst 0 is always fault-free so every
//! graded port establishes a trailing-average baseline first. Ports with an
//! active flap are excluded from confusion cells for that burst (their
//! traffic legitimately failed over to the backup port).
//!
//! ## Checkpoint format
//!
//! `SoakHarness::checkpoint` emits a `VCCLSOAK v3` header (harness
//! counters, both RNG streams, the fault clock, active faults, the fault
//! tape, the per-port verdict baseline) followed by the embedded `VCCLCKPT` stream
//! of the simulation. A version bump is REQUIRED whenever any serialized
//! structure changes shape. On resume, `sim_days` and `checkpoint_every`
//! may differ from the checkpointed run (extend a soak, change cadence);
//! the clocks that shape behaviour — period, MTBF, MTTR, fault mix — are
//! validated and refused on mismatch. Everything the report derives from
//! is serialized, so an interrupted-and-resumed soak produces a
//! `BENCH_soak.json` byte-identical to the uninterrupted run.

use std::collections::BTreeMap;

use crate::ccl::{ClusterSim, CollKind, Event, OpId};
use crate::config::Config;
use crate::metrics::BenchReport;
use crate::sim::SimTime;
use crate::topology::{LinkId, RankId};
use crate::util::{CkptReader, CkptWriter, Rng};

/// Simulated length of one burst period (one "training step" slot).
pub const BURST_PERIOD_NS: u64 = 60_000_000_000;

/// Capacity divisor of a degrade fault (a NIC negotiating down / a
/// congested switch: bandwidth collapses well past the pinpointer's 50 %
/// drop threshold but the link stays up).
pub const DEGRADE_FACTOR: f64 = 8.0;

/// Hang backstop per driven op.
const MAX_EVENTS_PER_OP: u64 = 200_000_000;

/// RNG stream salts: traffic sizes and the fault schedule are independent
/// streams so tests can pin one without replaying the other.
const TRAFFIC_SALT: u64 = 0x7EA5_0C0F_FEE0_50AC;
const FAULT_SALT: u64 = 0xFA17_C10C_0000_50AC;

/// Poisson fault-arrival clock: exponential inter-arrivals at the MTBF
/// mean, on the *nominal* burst clock (`burst × period`) so the schedule
/// is independent of traffic-induced boundary drift. Same seed ⇒ identical
/// schedule; the empirical inter-arrival mean converges to the MTBF.
#[derive(Debug)]
pub struct FaultClock {
    rng: Rng,
    mtbf_ns: f64,
    next_at_ns: u64,
}

impl FaultClock {
    /// Arrivals start after `start_ns` (the soak leaves burst 0 fault-free
    /// so monitored ports establish a baseline).
    pub fn new(seed: u64, mtbf_ns: f64, start_ns: u64) -> Self {
        let mut c = FaultClock { rng: Rng::new(seed), mtbf_ns, next_at_ns: start_ns };
        c.next_at_ns += c.draw();
        c
    }

    fn draw(&mut self) -> u64 {
        self.rng.exp(self.mtbf_ns).max(1.0) as u64
    }

    /// Next arrival time (nominal ns).
    pub fn next_at_ns(&self) -> u64 {
        self.next_at_ns
    }

    /// Consume the pending arrival and schedule the next one.
    pub fn advance(&mut self) -> u64 {
        let at = self.next_at_ns;
        let step = self.draw();
        self.next_at_ns += step;
        at
    }

    /// The clock's RNG also decides fault kind / target / jitter, so the
    /// whole fault schedule lives in one serializable stream.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Derived soak driver parameters (see `soak.*` in docs/CONFIG.md).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SoakParams {
    /// Nominal burst period (ns of simulated time).
    pub period_ns: u64,
    /// Mean time between faults (ns, exponential inter-arrivals).
    pub mtbf_ns: u64,
    /// Fault duration (ns; degrades round up to whole bursts).
    pub mttr_ns: u64,
    /// Total bursts to run (`ceil(sim_days / period)`).
    pub bursts_total: u64,
    /// Checkpoint cadence in bursts (0 = never).
    pub checkpoint_every: u64,
    /// Relative weights of the four fault kinds. The trunk/switch weights
    /// default to 0 so the pre-fabric fault mix (and its RNG stream) is
    /// unchanged unless explicitly opted into.
    pub flap_weight: u32,
    pub degrade_weight: u32,
    pub trunk_weight: u32,
    pub switch_weight: u32,
    /// Relative weight of whole-node crashes (§Elastic). Defaults to 0 so
    /// the pre-elastic fault mix (and its RNG stream) is unchanged unless
    /// explicitly opted into.
    pub node_weight: u32,
    /// Run the per-burst DP AllReduce (off = pure P2P soak).
    pub allreduce: bool,
}

impl SoakParams {
    pub fn from_config(cfg: &Config) -> Self {
        let day_ns = 86_400_000_000_000f64;
        let total_ns = (cfg.soak.sim_days.max(0.0) * day_ns).ceil() as u64;
        SoakParams {
            period_ns: BURST_PERIOD_NS,
            mtbf_ns: (cfg.soak.mtbf_hours.max(1e-6) * 3.6e12) as u64,
            mttr_ns: (cfg.soak.mttr_s.max(0.0) * 1e9) as u64,
            bursts_total: total_ns.div_ceil(BURST_PERIOD_NS).max(1),
            checkpoint_every: cfg.soak.checkpoint_every,
            flap_weight: 1,
            degrade_weight: 1,
            trunk_weight: cfg.soak.trunk_weight,
            switch_weight: cfg.soak.switch_weight,
            node_weight: cfg.soak.node_weight,
            allreduce: true,
        }
    }
}

/// What kind of fault a [`TapeEntry`] records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TapeKind {
    /// Port flap — `id` is the victim port ordinal.
    Flap,
    /// NIC-uplink capacity degrade — `id` is the victim port ordinal.
    Degrade,
    /// Trunk-link capacity degrade — `id` is the owning leaf switch.
    TrunkDegrade,
    /// Whole-switch outage — `id` is the leaf switch.
    SwitchDown,
    /// Whole-node crash (§Elastic) — `id` is the victim node.
    NodeCrash,
}

impl TapeKind {
    fn to_usize(self) -> usize {
        match self {
            TapeKind::Flap => 0,
            TapeKind::Degrade => 1,
            TapeKind::TrunkDegrade => 2,
            TapeKind::SwitchDown => 3,
            TapeKind::NodeCrash => 4,
        }
    }

    fn from_usize(v: usize) -> Result<TapeKind, String> {
        Ok(match v {
            0 => TapeKind::Flap,
            1 => TapeKind::Degrade,
            2 => TapeKind::TrunkDegrade,
            3 => TapeKind::SwitchDown,
            4 => TapeKind::NodeCrash,
            _ => return Err(format!("unknown soak tape kind {v}")),
        })
    }
}

/// One injected fault on the soak's ground-truth tape: what, where, when.
/// `vccl rca` grades its diagnosis of a soak's trace ring against this.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TapeEntry {
    pub kind: TapeKind,
    /// Victim port ordinal (Flap/Degrade) or leaf switch id (TrunkDegrade/
    /// SwitchDown) — the node RCA is expected to attribute the symptoms to.
    pub id: usize,
    /// Simulated time the fault took effect.
    pub at_ns: u64,
}

/// An in-force capacity degrade (ground truth for monitor grading).
#[derive(Debug, Clone)]
struct Degrade {
    ordinal: usize,
    link: usize,
    orig_bits: u64,
    heal_burst: u64,
    detected: bool,
}

/// An in-force port flap (excludes its port from confusion grading).
#[derive(Debug, Clone)]
struct Flap {
    ordinal: usize,
    up_ns: u64,
}

/// An in-force node crash (§Elastic: dedups overlapping fault domains and
/// routes the pipeline wave off the dead node).
#[derive(Debug, Clone)]
struct Crash {
    node: usize,
    up_ns: u64,
}

/// Final soak roll-up — everything `BENCH_soak.json` reports.
#[derive(Debug, Clone)]
pub struct SoakReport {
    pub bursts: u64,
    pub sim_seconds: f64,
    pub ops_submitted: u64,
    pub ops_completed: u64,
    /// Completed / submitted ops — 1.0 when fault tolerance recovers every
    /// burst, < 1.0 when ops hang (e.g. a baseline-transport soak).
    pub availability: f64,
    pub flaps_injected: u64,
    pub degrades_injected: u64,
    pub trunk_degrades_injected: u64,
    pub switches_injected: u64,
    /// §Elastic: whole-node crashes injected, and the shrink/rejoin/
    /// requeue work the elastic layer did in response (from sim stats —
    /// graded as exactly one shrink + rejoin per crash).
    pub node_crashes_injected: u64,
    pub elastic_shrinks: u64,
    pub elastic_rejoins: u64,
    pub ops_requeued: u64,
    /// Degrades (NIC + trunk) the window monitor caught while in force.
    pub degrades_detected: u64,
    pub faults_suppressed: u64,
    pub failovers: u64,
    pub failbacks: u64,
    /// Monitor confusion matrix over (graded port, burst) cells.
    pub tp: u64,
    pub fp: u64,
    pub fn_: u64,
    pub tn: u64,
    pub goodput_bytes: u64,
    pub wire_bytes: u64,
}

impl SoakReport {
    /// tp/(tp+fp); 1.0 when the monitor never fired (nothing to be wrong
    /// about).
    pub fn precision(&self) -> f64 {
        if self.tp + self.fp == 0 { 1.0 } else { self.tp as f64 / (self.tp + self.fp) as f64 }
    }

    /// tp/(tp+fn); 1.0 when no degrade was ever in force.
    pub fn recall(&self) -> f64 {
        if self.tp + self.fn_ == 0 { 1.0 } else { self.tp as f64 / (self.tp + self.fn_) as f64 }
    }

    /// Machine-readable roll-up (`BENCH_soak.json`). Deterministic: every
    /// value derives from simulated state.
    pub fn to_bench(&self) -> BenchReport {
        let mut b = BenchReport::new("soak", "vccl soak — time-compressed MTBF fault soak");
        b.push("bursts", self.bursts as f64, "count")
            .push("sim_time", self.sim_seconds, "s")
            .push("ops_submitted", self.ops_submitted as f64, "count")
            .push("ops_completed", self.ops_completed as f64, "count")
            .push("availability", self.availability, "fraction")
            .push("flaps_injected", self.flaps_injected as f64, "count")
            .push("degrades_injected", self.degrades_injected as f64, "count")
            .push("trunk_degrades_injected", self.trunk_degrades_injected as f64, "count")
            .push("switches_injected", self.switches_injected as f64, "count")
            .push("node_crashes_injected", self.node_crashes_injected as f64, "count")
            .push("elastic_shrinks", self.elastic_shrinks as f64, "count")
            .push("elastic_rejoins", self.elastic_rejoins as f64, "count")
            .push("ops_requeued", self.ops_requeued as f64, "count")
            .push("degrades_detected", self.degrades_detected as f64, "count")
            .push("faults_suppressed", self.faults_suppressed as f64, "count")
            .push("failovers", self.failovers as f64, "count")
            .push("failbacks", self.failbacks as f64, "count")
            .push("monitor_tp", self.tp as f64, "cells")
            .push("monitor_fp", self.fp as f64, "cells")
            .push("monitor_fn", self.fn_ as f64, "cells")
            .push("monitor_tn", self.tn as f64, "cells")
            .push("monitor_precision", self.precision(), "fraction")
            .push("monitor_recall", self.recall(), "fraction")
            .push("goodput", self.goodput_bytes as f64 / 1e9, "GB")
            .push("goodput_vs_wallclock", self.goodput_bytes as f64 * 8.0 / self.sim_seconds.max(1e-9) / 1e9, "Gbps")
            .push("wire", self.wire_bytes as f64 / 1e9, "GB");
        b
    }
}

/// The soak driver: owns the simulation, the traffic generator, the fault
/// clock and the grading state. One [`Self::run_burst`] call = one period.
pub struct SoakHarness {
    cfg: Config,
    pub params: SoakParams,
    pub sim: ClusterSim,
    traffic_rng: Rng,
    faults: FaultClock,
    burst: u64,
    ops_submitted: u64,
    ops_completed: u64,
    goodput_bytes: u64,
    flaps_injected: u64,
    degrades_injected: u64,
    trunk_degrades_injected: u64,
    switches_injected: u64,
    node_crashes_injected: u64,
    degrades_detected: u64,
    suppressed: u64,
    tp: u64,
    fp: u64,
    fn_: u64,
    tn: u64,
    active_degrades: Vec<Degrade>,
    active_flaps: Vec<Flap>,
    active_crashes: Vec<Crash>,
    /// Ground-truth tape of every injected fault, in injection order.
    tape: Vec<TapeEntry>,
    /// Last seen non-Healthy verdict total per graded port ordinal.
    prev_anomalies: BTreeMap<usize, u64>,
    /// An op failed to complete: the sim holds live state forever, so
    /// checkpointing is off and availability < 1.
    hung: bool,
}

impl SoakHarness {
    pub fn new(cfg: Config) -> Self {
        let params = SoakParams::from_config(&cfg);
        Self::with_params(cfg, params)
    }

    /// Tests inject custom params (fault mix, period, burst count) here.
    pub fn with_params(cfg: Config, params: SoakParams) -> Self {
        assert!(cfg.topo.num_nodes >= 2, "soak needs cross-node P2P traffic");
        assert!(cfg.topo.gpus_per_node >= 4, "soak needs fault-target ranks 1..=n-2");
        let sim = ClusterSim::new(cfg.clone());
        let faults = FaultClock::new(cfg.seed ^ FAULT_SALT, params.mtbf_ns as f64, params.period_ns);
        let traffic_rng = Rng::new(cfg.seed ^ TRAFFIC_SALT);
        SoakHarness {
            cfg,
            params,
            sim,
            traffic_rng,
            faults,
            burst: 0,
            ops_submitted: 0,
            ops_completed: 0,
            goodput_bytes: 0,
            flaps_injected: 0,
            degrades_injected: 0,
            trunk_degrades_injected: 0,
            switches_injected: 0,
            node_crashes_injected: 0,
            degrades_detected: 0,
            suppressed: 0,
            tp: 0,
            fp: 0,
            fn_: 0,
            tn: 0,
            active_degrades: Vec::new(),
            active_flaps: Vec::new(),
            active_crashes: Vec::new(),
            tape: Vec::new(),
            prev_anomalies: BTreeMap::new(),
            hung: false,
        }
    }

    /// Ground-truth fault tape (injection order) — what `vccl rca` is
    /// graded against when diagnosing this soak's trace ring.
    pub fn fault_tape(&self) -> &[TapeEntry] {
        &self.tape
    }

    pub fn burst_index(&self) -> u64 {
        self.burst
    }

    pub fn done(&self) -> bool {
        self.burst >= self.params.bursts_total
    }

    pub fn hung(&self) -> bool {
        self.hung
    }

    fn graded_port(&self, rank: usize) -> (crate::topology::PortId, usize) {
        let port = self.sim.topo.primary_port(self.sim.topo.gpu_of_rank(RankId(rank)));
        (port, self.sim.topo.fabric.port_ordinal(port))
    }

    /// Run one burst: heal due degrades, draw this period's faults, drive
    /// the traffic, grade the monitor, then advance to the next boundary.
    pub fn run_burst(&mut self) {
        assert!(!self.done(), "soak already finished");
        let t0 = self.sim.now();
        let gpn = self.cfg.topo.gpus_per_node;

        // 1. Heal degrades that reached their MTTR (boundary-applied: the
        //    sim is op-quiescent here, so no flow re-rate is in flight).
        let burst = self.burst;
        let due: Vec<Degrade> =
            self.active_degrades.iter().filter(|d| d.heal_burst <= burst).cloned().collect();
        self.active_degrades.retain(|d| d.heal_burst > burst);
        for d in due {
            let timers =
                self.sim.rdma.flows.set_link_capacity(LinkId(d.link), f64::from_bits(d.orig_bits), t0);
            for t in timers {
                self.sim.engine.schedule_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
            }
            self.degrades_detected += d.detected as u64;
        }
        self.active_flaps.retain(|f| f.up_ns > t0.as_ns());
        self.active_crashes.retain(|c| c.up_ns > t0.as_ns());

        // 2. Draw faults whose nominal arrival falls in this period.
        let window_end = (self.burst + 1).saturating_mul(self.params.period_ns);
        while self.faults.next_at_ns() < window_end {
            let _nominal = self.faults.advance();
            let (wf, wd, wt, ws) = (
                self.params.flap_weight as u64,
                self.params.degrade_weight as u64,
                self.params.trunk_weight as u64,
                self.params.switch_weight as u64,
            );
            let wsum = (wf + wd + wt + ws + self.params.node_weight as u64).max(1);
            let draw = self.faults.rng().below(wsum);
            let kind = if draw < wf {
                TapeKind::Flap
            } else if draw < wf + wd {
                TapeKind::Degrade
            } else if draw < wf + wd + wt {
                TapeKind::TrunkDegrade
            } else if draw < wf + wd + wt + ws {
                TapeKind::SwitchDown
            } else {
                TapeKind::NodeCrash
            };
            let rank = 1 + self.faults.rng().below((gpn - 2) as u64) as usize;
            // Flap jitter stays below the burst's minimum traffic time
            // (smallest AllReduce + smallest P2P ≈ 280 µs of transfers), so
            // a down-event always lands while the target's flow is pending
            // or in flight — one flap ⇒ exactly one failover.
            let jitter = self.faults.rng().range(10_000, 100_000);
            let (port, ordinal) = self.graded_port(rank);
            // Degrade exclusion is keyed on the RESOLVED LinkId, not the
            // victim's port ordinal: two victims on the same rail resolve
            // to the SAME trunk link, and a second booking would record the
            // already-cut capacity as "original", wedging the heal.
            let victim_link = match kind {
                TapeKind::Degrade => Some(self.sim.topo.fabric.port_tx(port)),
                TapeKind::TrunkDegrade => Some(
                    self.sim
                        .topo
                        .fabric
                        .trunk_up(port.nic.local % self.cfg.topo.rails, usize::from(port.port)),
                ),
                TapeKind::Flap | TapeKind::SwitchDown | TapeKind::NodeCrash => None,
            };
            // Port-keyed dedup (NodeCrash dedups on the node domain in its
            // own arm below — the drawn rank/port is not its victim). The
            // crashed-node arm mirrors it the other way: a port fault on a
            // dead server's port would book a heal against hardware the
            // node cascade already owns.
            let victim_node = self.sim.topo.fabric.node_of_port_ordinal(ordinal);
            if kind != TapeKind::NodeCrash
                && (self.active_flaps.iter().any(|f| f.ordinal == ordinal)
                    || self.active_degrades.iter().any(|d| d.ordinal == ordinal)
                    || victim_link
                        .is_some_and(|l| self.active_degrades.iter().any(|d| d.link == l.0))
                    || self.active_crashes.iter().any(|c| c.node == victim_node))
            {
                // One fault at a time per victim; the arrival is consumed so
                // both sides of a resume agree on the schedule.
                self.suppressed += 1;
                continue;
            }
            match kind {
                TapeKind::Flap => {
                    let down = t0 + SimTime::ns(jitter);
                    let up = down + SimTime::ns(self.params.mttr_ns);
                    self.sim.inject_port_down(port, down);
                    self.sim.inject_port_up(port, up);
                    self.active_flaps.push(Flap { ordinal, up_ns: up.as_ns() });
                    self.flaps_injected += 1;
                    self.tape.push(TapeEntry { kind, id: ordinal, at_ns: down.as_ns() });
                }
                TapeKind::Degrade | TapeKind::TrunkDegrade => {
                    let link = victim_link.expect("degrade kinds resolve a victim link");
                    let orig = self.sim.rdma.flows.link_capacity_bpns(link);
                    let timers =
                        self.sim.rdma.flows.set_link_capacity(link, orig / DEGRADE_FACTOR, t0);
                    for t in timers {
                        self.sim.engine.schedule_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
                    }
                    let heal_after = self.params.mttr_ns.div_ceil(self.params.period_ns).max(1);
                    self.active_degrades.push(Degrade {
                        ordinal,
                        link: link.0,
                        orig_bits: orig.to_bits(),
                        heal_burst: self.burst + heal_after,
                        detected: false,
                    });
                    if kind == TapeKind::Degrade {
                        self.degrades_injected += 1;
                        self.tape.push(TapeEntry { kind, id: ordinal, at_ns: t0.as_ns() });
                    } else {
                        self.trunk_degrades_injected += 1;
                        let leaf =
                            self.sim.topo.fabric.switch_of_link(link).unwrap_or(usize::MAX);
                        self.tape.push(TapeEntry { kind, id: leaf, at_ns: t0.as_ns() });
                    }
                }
                TapeKind::SwitchDown => {
                    let leaf = self
                        .sim
                        .topo
                        .fabric
                        .switch_of_link(self.sim.topo.fabric.port_tx(port))
                        .expect("graded ports hang off a leaf switch");
                    let down = t0 + SimTime::ns(jitter);
                    let up = down + SimTime::ns(self.params.mttr_ns);
                    self.sim.inject_switch_down(leaf, down);
                    self.sim.inject_switch_up(leaf, up);
                    // A dead leaf mutes the victim's primary port exactly
                    // like a flap (traffic fails over to the backup plane),
                    // so reuse the flap list for grading exclusion and
                    // MTTR-based retention.
                    self.active_flaps.push(Flap { ordinal, up_ns: up.as_ns() });
                    self.switches_injected += 1;
                    self.tape.push(TapeEntry { kind, id: leaf, at_ns: down.as_ns() });
                }
                TapeKind::NodeCrash => {
                    // Victim: any node but node 0 (it hosts every graded
                    // port and the traffic sources — crashing it would
                    // grade the traffic generator, not the elastic layer).
                    let nodes = self.cfg.topo.num_nodes;
                    let victim = 1 + self.faults.rng().below((nodes - 1) as u64) as usize;
                    // Node-domain dedup: a crash on an already-dead node,
                    // or on a node with an in-force port fault, would
                    // double-book the cascade (the earlier fault's heal
                    // would revive one port of a dead server). The arrival
                    // is consumed either way so resumes agree.
                    let fab = &self.sim.topo.fabric;
                    if self.active_crashes.iter().any(|c| c.node == victim)
                        || self
                            .active_flaps
                            .iter()
                            .any(|f| fab.node_of_port_ordinal(f.ordinal) == victim)
                        || self
                            .active_degrades
                            .iter()
                            .any(|d| fab.node_of_port_ordinal(d.ordinal) == victim)
                    {
                        self.suppressed += 1;
                        continue;
                    }
                    // Boundary-applied (down at t0, before this burst's
                    // traffic events): the crash is in force for the whole
                    // burst, so the wave reroutes around it and no P2P is
                    // ever in flight toward a dying node — mid-flight
                    // aborts are the cluster tests' and the elastic
                    // experiment's job; the soak grades long-run shrink/
                    // rejoin accounting. The jitter draw was consumed
                    // above so resumes agree on the schedule.
                    let up = t0 + SimTime::ns(self.params.mttr_ns);
                    self.sim.inject_node_down(victim, t0);
                    self.sim.inject_node_up(victim, up);
                    self.active_crashes.push(Crash { node: victim, up_ns: up.as_ns() });
                    self.node_crashes_injected += 1;
                    self.tape.push(TapeEntry { kind, id: victim, at_ns: t0.as_ns() });
                }
            }
        }

        // 3. Traffic: the DP AllReduce first (alone, so ring edges see full
        //    rate), then the pipeline P2P wave on disjoint ports. Partial
        //    bandwidth windows are flushed first — a window straddling the
        //    ~60 s inter-burst gap would alias to ~0 Gbps and read as a
        //    collapse on a healthy port.
        if let Some(mon) = self.sim.monitor.as_mut() {
            mon.flush_windows();
        }
        let mut burst_ops: Vec<OpId> = Vec::new();
        if self.params.allreduce {
            let bytes = self.traffic_rng.range(1 << 20, 4 << 20);
            let id = self.sim.submit(CollKind::AllReduce, bytes);
            self.ops_submitted += 1;
            if !self.sim.run_until_op(id, MAX_EVENTS_PER_OP) {
                self.hung = true;
            }
            burst_ops.push(id);
        }
        let mut wave = Vec::new();
        for g in 0..gpn {
            // ≥ 12 MB ⇒ ≥ 12 chunk WCs per port per burst — enough to fill
            // the monitor's 8-message window and emit several samples even
            // at the smallest draw (the window was just flushed). The size
            // is drawn before any elastic rerouting so the traffic stream
            // is identical whether or not a crash is in force.
            let bytes = self.traffic_rng.range(12 << 20, 32 << 20);
            // §Elastic: route the pipeline target off crashed nodes — the
            // first alive peer node, same rail. Keyed on the crash
            // schedule (not live sim state): a boundary-applied NodeDown
            // event may not have been dispatched yet when the wave is
            // submitted. With every peer dead the wave has no target and
            // is skipped (goodput dips for the burst; nothing is
            // submitted, so nothing is lost).
            let Some(dst) = (1..self.cfg.topo.num_nodes)
                .find(|&n| !self.active_crashes.iter().any(|c| c.node == n))
            else {
                continue;
            };
            wave.push(self.sim.submit_p2p(RankId(g), RankId(dst * gpn + g), bytes));
            self.ops_submitted += 1;
        }
        for &id in &wave {
            if !self.sim.run_until_op(id, MAX_EVENTS_PER_OP) {
                self.hung = true;
            }
        }
        burst_ops.extend(wave);
        for &id in &burst_ops {
            let op = &self.sim.ops[id.0];
            if op.is_done() {
                self.ops_completed += 1;
                self.goodput_bytes += op.chan_rollup.iter().map(|c| c.bytes).sum::<u64>();
            }
        }

        // 4. Grade the monitor: one confusion cell per (graded port, burst).
        if let Some(mon) = self.sim.monitor.as_ref() {
            for rank in 1..=gpn - 2 {
                let port = self.sim.topo.primary_port(self.sim.topo.gpu_of_rank(RankId(rank)));
                let ord = self.sim.topo.fabric.port_ordinal(port);
                if self.active_flaps.iter().any(|f| f.ordinal == ord) {
                    continue; // traffic failed over: the port is mute, not judged
                }
                let c = mon.verdict_counts(ord);
                let anomalies = c[1] + c[2];
                let prev = self.prev_anomalies.get(&ord).copied().unwrap_or(0);
                let flagged = anomalies > prev;
                self.prev_anomalies.insert(ord, anomalies);
                match (self.active_degrades.iter().position(|d| d.ordinal == ord), flagged) {
                    (Some(i), true) => {
                        self.tp += 1;
                        self.active_degrades[i].detected = true;
                    }
                    (Some(_), false) => self.fn_ += 1,
                    (None, true) => self.fp += 1,
                    (None, false) => self.tn += 1,
                }
            }
        }

        // 5. Advance to the next boundary (draining heals/warm-ups due
        //    before it) and stop exactly ON it — the op-quiescent protocol
        //    ClusterSim::checkpoint requires.
        let end = self.sim.now();
        let nominal = t0 + SimTime::ns(self.params.period_ns);
        let boundary =
            if nominal > end + SimTime::ns(1_000_000) { nominal } else { end + SimTime::ns(1_000_000) };
        self.sim.run_until(boundary - SimTime::ns(1));
        self.sim.engine.advance_to(boundary);
        self.burst += 1;
    }

    /// Drive bursts to completion, checkpointing every
    /// `params.checkpoint_every` bursts through `sink(burst, text)`.
    /// `stop_after_ckpts` aborts right after the N-th checkpoint (CI uses
    /// it to simulate a kill mid-soak). Returns checkpoints written.
    pub fn run(&mut self, stop_after_ckpts: Option<u64>, sink: &mut dyn FnMut(u64, &str)) -> u64 {
        let mut written = 0u64;
        while !self.done() {
            self.run_burst();
            let every = self.params.checkpoint_every;
            if every > 0 && self.burst % every == 0 && !self.done() && !self.hung {
                sink(self.burst, &self.checkpoint());
                written += 1;
                if stop_after_ckpts.is_some_and(|n| written >= n) {
                    return written;
                }
            }
        }
        written
    }

    /// Serialize the harness + embedded simulation. Panics if an op hung
    /// (the sim is not op-quiescent and never will be).
    pub fn checkpoint(&self) -> String {
        assert!(!self.hung, "cannot checkpoint a soak with a hung op");
        let mut w = CkptWriter::new("VCCLSOAK", 3);
        w.u64("burst", self.burst);
        w.u64("period", self.params.period_ns);
        w.u64("mtbf", self.params.mtbf_ns);
        w.u64("mttr", self.params.mttr_ns);
        w.u64("wflap", self.params.flap_weight as u64);
        w.u64("wdeg", self.params.degrade_weight as u64);
        w.u64("wtrunk", self.params.trunk_weight as u64);
        w.u64("wswitch", self.params.switch_weight as u64);
        w.u64("wnode", self.params.node_weight as u64);
        w.bool("ar", self.params.allreduce);
        w.u64("nfat", self.faults.next_at_ns);
        let fs = self.faults.rng.state();
        let ts = self.traffic_rng.state();
        for (i, v) in fs.iter().enumerate() {
            w.u64(&format!("f{i}"), *v);
        }
        for (i, v) in ts.iter().enumerate() {
            w.u64(&format!("t{i}"), *v);
        }
        w.u64("sub", self.ops_submitted);
        w.u64("cmp", self.ops_completed);
        w.u64("good", self.goodput_bytes);
        w.u64("flp", self.flaps_injected);
        w.u64("deg", self.degrades_injected);
        w.u64("tdi", self.trunk_degrades_injected);
        w.u64("swi", self.switches_injected);
        w.u64("ncr", self.node_crashes_injected);
        w.u64("ddet", self.degrades_detected);
        w.u64("sup", self.suppressed);
        w.u64("tp", self.tp);
        w.u64("fp", self.fp);
        w.u64("fnn", self.fn_);
        w.u64("tn", self.tn);
        w.usize("nact", self.active_degrades.len());
        for d in &self.active_degrades {
            w.usize("ord", d.ordinal);
            w.usize("lnk", d.link);
            w.u64("cap", d.orig_bits);
            w.u64("heal", d.heal_burst);
            w.bool("det", d.detected);
        }
        w.usize("nflp", self.active_flaps.len());
        for f in &self.active_flaps {
            w.usize("ord", f.ordinal);
            w.u64("up", f.up_ns);
        }
        w.usize("ncra", self.active_crashes.len());
        for c in &self.active_crashes {
            w.usize("cn", c.node);
            w.u64("cup", c.up_ns);
        }
        w.usize("nprev", self.prev_anomalies.len());
        for (ord, v) in &self.prev_anomalies {
            w.usize("ord", *ord);
            w.u64("anom", *v);
        }
        w.usize("ntape", self.tape.len());
        for e in &self.tape {
            w.usize("tk", e.kind.to_usize());
            w.usize("tid", e.id);
            w.u64("tat", e.at_ns);
        }
        let header = w.finish();
        format!("{header}{}", self.sim.checkpoint())
    }

    /// Resume from [`Self::checkpoint`] output under the given config.
    pub fn restore(cfg: Config, text: &str) -> Result<SoakHarness, String> {
        let params = SoakParams::from_config(&cfg);
        Self::restore_with_params(cfg, params, text)
    }

    pub fn restore_with_params(
        cfg: Config,
        params: SoakParams,
        text: &str,
    ) -> Result<SoakHarness, String> {
        let pos = text
            .find("VCCLCKPT")
            .ok_or_else(|| "soak checkpoint lacks an embedded sim stream".to_string())?;
        let (head, simtext) = text.split_at(pos);
        let mut r = CkptReader::new(head, "VCCLSOAK", 3)?;
        let burst = r.u64("burst")?;
        for (tag, want) in [
            ("period", params.period_ns),
            ("mtbf", params.mtbf_ns),
            ("mttr", params.mttr_ns),
            ("wflap", params.flap_weight as u64),
            ("wdeg", params.degrade_weight as u64),
            ("wtrunk", params.trunk_weight as u64),
            ("wswitch", params.switch_weight as u64),
            ("wnode", params.node_weight as u64),
        ] {
            let got = r.u64(tag)?;
            if got != want {
                return Err(format!(
                    "soak param {tag} changed: checkpoint {got}, config {want} \
                     (only sim_days / checkpoint_every may change across resume)"
                ));
            }
        }
        if r.bool("ar")? != params.allreduce {
            return Err("soak traffic mix (allreduce) changed across resume".to_string());
        }
        let next_at = r.u64("nfat")?;
        let mut fs = [0u64; 4];
        for (i, v) in fs.iter_mut().enumerate() {
            *v = r.u64(&format!("f{i}"))?;
        }
        let mut ts = [0u64; 4];
        for (i, v) in ts.iter_mut().enumerate() {
            *v = r.u64(&format!("t{i}"))?;
        }
        let ops_submitted = r.u64("sub")?;
        let ops_completed = r.u64("cmp")?;
        let goodput_bytes = r.u64("good")?;
        let flaps_injected = r.u64("flp")?;
        let degrades_injected = r.u64("deg")?;
        let trunk_degrades_injected = r.u64("tdi")?;
        let switches_injected = r.u64("swi")?;
        let node_crashes_injected = r.u64("ncr")?;
        let degrades_detected = r.u64("ddet")?;
        let suppressed = r.u64("sup")?;
        let tp = r.u64("tp")?;
        let fp = r.u64("fp")?;
        let fn_ = r.u64("fnn")?;
        let tn = r.u64("tn")?;
        let nact = r.usize("nact")?;
        let mut active_degrades = Vec::with_capacity(nact);
        for _ in 0..nact {
            active_degrades.push(Degrade {
                ordinal: r.usize("ord")?,
                link: r.usize("lnk")?,
                orig_bits: r.u64("cap")?,
                heal_burst: r.u64("heal")?,
                detected: r.bool("det")?,
            });
        }
        let nflp = r.usize("nflp")?;
        let mut active_flaps = Vec::with_capacity(nflp);
        for _ in 0..nflp {
            active_flaps.push(Flap { ordinal: r.usize("ord")?, up_ns: r.u64("up")? });
        }
        let ncra = r.usize("ncra")?;
        let mut active_crashes = Vec::with_capacity(ncra);
        for _ in 0..ncra {
            active_crashes.push(Crash { node: r.usize("cn")?, up_ns: r.u64("cup")? });
        }
        let nprev = r.usize("nprev")?;
        let mut prev_anomalies = BTreeMap::new();
        for _ in 0..nprev {
            let ord = r.usize("ord")?;
            let v = r.u64("anom")?;
            prev_anomalies.insert(ord, v);
        }
        let ntape = r.usize("ntape")?;
        let mut tape = Vec::with_capacity(ntape);
        for _ in 0..ntape {
            tape.push(TapeEntry {
                kind: TapeKind::from_usize(r.usize("tk")?)?,
                id: r.usize("tid")?,
                at_ns: r.u64("tat")?,
            });
        }
        r.finish()?;
        let sim = ClusterSim::restore(cfg.clone(), simtext)?;
        Ok(SoakHarness {
            cfg,
            params,
            sim,
            traffic_rng: Rng::from_state(ts),
            faults: FaultClock { rng: Rng::from_state(fs), mtbf_ns: params.mtbf_ns as f64, next_at_ns: next_at },
            burst,
            ops_submitted,
            ops_completed,
            goodput_bytes,
            flaps_injected,
            degrades_injected,
            trunk_degrades_injected,
            switches_injected,
            node_crashes_injected,
            degrades_detected,
            suppressed,
            tp,
            fp,
            fn_,
            tn,
            active_degrades,
            active_flaps,
            active_crashes,
            tape,
            prev_anomalies,
            hung: false,
        })
    }

    /// Roll up the soak so far (callable at any boundary).
    pub fn report(&self) -> SoakReport {
        // In-force degrades count as detected-so-far for the roll-up; their
        // `detected` flag is otherwise folded in at heal time.
        let in_force_detected =
            self.active_degrades.iter().filter(|d| d.detected).count() as u64;
        SoakReport {
            bursts: self.burst,
            sim_seconds: self.sim.now().as_ns() as f64 / 1e9,
            ops_submitted: self.ops_submitted,
            ops_completed: self.ops_completed,
            availability: if self.ops_submitted == 0 {
                1.0
            } else {
                self.ops_completed as f64 / self.ops_submitted as f64
            },
            flaps_injected: self.flaps_injected,
            degrades_injected: self.degrades_injected,
            trunk_degrades_injected: self.trunk_degrades_injected,
            switches_injected: self.switches_injected,
            node_crashes_injected: self.node_crashes_injected,
            elastic_shrinks: self.sim.stats.elastic_shrinks,
            elastic_rejoins: self.sim.stats.elastic_rejoins,
            ops_requeued: self.sim.stats.ops_requeued,
            degrades_detected: self.degrades_detected + in_force_detected,
            faults_suppressed: self.suppressed,
            failovers: self.sim.stats.failovers,
            failbacks: self.sim.stats.failbacks,
            tp: self.tp,
            fp: self.fp,
            fn_: self.fn_,
            tn: self.tn,
            goodput_bytes: self.goodput_bytes,
            wire_bytes: self.sim.stats.wire_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_params(bursts: u64) -> SoakParams {
        SoakParams {
            period_ns: BURST_PERIOD_NS,
            mtbf_ns: 90_000_000_000, // 1.5 simulated minutes: ~2 faults / 3 bursts
            mttr_ns: 30_000_000_000,
            bursts_total: bursts,
            checkpoint_every: 2,
            flap_weight: 1,
            degrade_weight: 1,
            trunk_weight: 0,
            switch_weight: 0,
            node_weight: 0,
            allreduce: true,
        }
    }

    /// quick_params with the classic kinds off and the fabric kinds on.
    fn fabric_params(bursts: u64, trunk_w: u32, switch_w: u32) -> SoakParams {
        SoakParams {
            flap_weight: 0,
            degrade_weight: 0,
            trunk_weight: trunk_w,
            switch_weight: switch_w,
            ..quick_params(bursts)
        }
    }

    #[test]
    fn fault_clock_same_seed_same_schedule() {
        let mut a = FaultClock::new(7, 1e9, 0);
        let mut b = FaultClock::new(7, 1e9, 0);
        for _ in 0..100 {
            assert_eq!(a.advance(), b.advance());
        }
        let mut c = FaultClock::new(8, 1e9, 0);
        let sa: Vec<u64> = (0..16).map(|_| FaultClock::new(7, 1e9, 0).advance()).collect();
        assert!(sa.iter().all(|&x| x == sa[0]));
        assert_ne!(a.advance(), c.advance());
    }

    #[test]
    fn fault_clock_mean_matches_mtbf() {
        let mtbf = 3_600_000_000_000f64; // 1 simulated hour
        let mut c = FaultClock::new(0x5CC1, mtbf, 0);
        let n = 20_000u64;
        let mut prev = 0u64;
        let mut sum = 0u64;
        for _ in 0..n {
            let at = c.advance();
            sum += at - prev;
            prev = at;
        }
        let mean = sum as f64 / n as f64;
        let err = (mean - mtbf).abs() / mtbf;
        assert!(err < 0.05, "empirical inter-arrival mean {mean:.3e} vs MTBF {mtbf:.3e}");
    }

    #[test]
    fn a_short_soak_runs_detects_and_recovers() {
        let cfg = Config::soak_defaults();
        let mut h = SoakHarness::with_params(cfg, quick_params(6));
        while !h.done() {
            h.run_burst();
        }
        let r = h.report();
        assert!(!h.hung());
        assert_eq!(r.bursts, 6);
        assert_eq!(r.availability, 1.0, "fault tolerance must complete every op");
        assert!(r.ops_submitted == 6 * 9, "1 allreduce + 8 p2p per burst");
        assert!(r.flaps_injected + r.degrades_injected >= 1, "MTBF of 1.5 bursts must fault");
        // Flap accounting: every flap failed over exactly once and (MTTR +
        // warm-up < period) failed back before the next boundary.
        assert_eq!(r.failovers, r.flaps_injected);
        assert_eq!(r.failbacks, r.flaps_injected);
        // Monitor grading: perfect on this controlled traffic.
        assert_eq!(r.precision(), 1.0, "fp={}", r.fp);
        assert_eq!(r.recall(), 1.0, "fn={}", r.fn_);
        assert_eq!(r.degrades_detected, r.degrades_injected);
        // Goodput conservation: harness accumulation == per-op roll-ups.
        let rollup: u64 = h
            .sim
            .ops
            .iter()
            .map(|o| o.chan_rollup.iter().map(|c| c.bytes).sum::<u64>())
            .sum();
        assert_eq!(r.goodput_bytes, rollup);
        assert!(r.wire_bytes >= r.goodput_bytes, "wire carries goodput + retransmits");
    }

    #[test]
    fn soak_checkpoint_resume_is_bit_identical() {
        let cfg = Config::soak_defaults();
        // Uninterrupted reference.
        let mut a = SoakHarness::with_params(cfg.clone(), quick_params(5));
        while !a.done() {
            a.run_burst();
        }
        let bench_a = a.report().to_bench().to_json();

        // Interrupted at burst 2, resumed fresh.
        let mut b = SoakHarness::with_params(cfg.clone(), quick_params(5));
        b.run_burst();
        b.run_burst();
        let ckpt = b.checkpoint();
        drop(b);
        let mut c = SoakHarness::restore_with_params(cfg, quick_params(5), &ckpt)
            .expect("soak restore");
        assert_eq!(c.burst_index(), 2);
        // Re-checkpointing the restored harness is a fixed point.
        assert_eq!(c.checkpoint(), ckpt);
        while !c.done() {
            c.run_burst();
        }
        assert_eq!(c.report().to_bench().to_json(), bench_a);
        assert_eq!(c.sim.now(), a.sim.now());
        assert_eq!(c.sim.stats.failovers, a.sim.stats.failovers);
        assert_eq!(c.traffic_rng.state(), a.traffic_rng.state());
        assert_eq!(c.faults.rng.state(), a.faults.rng.state());
    }

    #[test]
    fn soak_restore_rejects_param_drift() {
        let cfg = Config::soak_defaults();
        let mut h = SoakHarness::with_params(cfg.clone(), quick_params(4));
        h.run_burst();
        let ckpt = h.checkpoint();
        let mut skewed = quick_params(4);
        skewed.mtbf_ns += 1;
        let err = SoakHarness::restore_with_params(cfg.clone(), skewed, &ckpt).unwrap_err();
        assert!(err.contains("mtbf"), "{err}");
        // sim_days (bursts_total) may legitimately change across resume.
        let extended = SoakParams { bursts_total: 9, ..quick_params(4) };
        let h2 = SoakHarness::restore_with_params(cfg, extended, &ckpt).unwrap();
        assert!(!h2.done());
    }

    #[test]
    fn run_loop_checkpoints_on_cadence_and_stops_on_request() {
        let cfg = Config::soak_defaults();
        let mut h = SoakHarness::with_params(cfg, quick_params(6));
        let mut seen: Vec<u64> = Vec::new();
        let written = h.run(Some(1), &mut |b, text| {
            seen.push(b);
            assert!(text.starts_with("VCCLSOAK v3"));
        });
        assert_eq!((written, seen.as_slice()), (1, &[2u64][..]));
        assert_eq!(h.burst_index(), 2, "stop-after-ckpt aborts mid-soak");
        let written = h.run(None, &mut |b, _| seen.push(b));
        // Bursts 4 fires the cadence; burst 6 is the end (no checkpoint).
        assert_eq!((written, seen.as_slice()), (1, &[2u64, 4][..]));
        assert!(h.done());
    }

    /// §Fault domains: trunk degrades collapse the victim's end-to-end
    /// bandwidth with both endpoint ports pristine, the port-level monitor
    /// still catches every one, and healed trunks return to full capacity.
    #[test]
    fn trunk_weighted_soak_degrades_only_trunks_and_recovers() {
        let cfg = Config::soak_defaults();
        let mut h = SoakHarness::with_params(cfg.clone(), fabric_params(6, 1, 0));
        while !h.done() {
            h.run_burst();
        }
        let r = h.report();
        assert!(!h.hung());
        assert_eq!(r.availability, 1.0, "a slow trunk must never lose an op");
        assert!(r.trunk_degrades_injected >= 1, "MTBF of 1.5 bursts must fault");
        assert_eq!(r.flaps_injected + r.degrades_injected + r.switches_injected, 0);
        assert_eq!(r.failovers, 0, "a degraded trunk is slow, not dead");
        assert_eq!(r.precision(), 1.0, "fp={}", r.fp);
        assert_eq!(r.recall(), 1.0, "fn={}", r.fn_);
        assert_eq!(r.degrades_detected, r.trunk_degrades_injected);
        // Ground-truth tape: every entry is a trunk fault on a real leaf.
        assert_eq!(h.fault_tape().len(), r.trunk_degrades_injected as usize);
        let leaves = h.sim.topo.fabric.num_leaf_switches();
        assert!(h
            .fault_tape()
            .iter()
            .all(|e| e.kind == TapeKind::TrunkDegrade && e.id < leaves));
        assert!(h.active_degrades.iter().all(|d| h.sim.topo.fabric.is_trunk(LinkId(d.link))));
        // Every link without an in-force degrade is back at built capacity.
        let fresh = ClusterSim::new(cfg);
        for l in 0..h.sim.topo.fabric.num_links() {
            if h.active_degrades.iter().any(|d| d.link == l) {
                continue;
            }
            assert_eq!(
                h.sim.rdma.flows.link_capacity_bpns(LinkId(l)).to_bits(),
                fresh.rdma.flows.link_capacity_bpns(LinkId(l)).to_bits(),
                "link {l} capacity restored after heal"
            );
        }
    }

    /// §Fault domains: a leaf-switch outage grades exactly like a flap —
    /// one failover to the backup plane, one failback on heal — but the
    /// victim's port never flapped.
    #[test]
    fn switch_weighted_soak_fails_over_and_back_per_outage() {
        let cfg = Config::soak_defaults();
        let mut h = SoakHarness::with_params(cfg, fabric_params(6, 0, 1));
        while !h.done() {
            h.run_burst();
        }
        let r = h.report();
        assert!(!h.hung());
        assert_eq!(r.availability, 1.0, "leaf outages must not lose ops");
        assert!(r.switches_injected >= 1, "MTBF of 1.5 bursts must fault");
        assert_eq!(r.flaps_injected + r.degrades_injected + r.trunk_degrades_injected, 0);
        assert_eq!(r.failovers, r.switches_injected, "one plane failover per outage");
        assert_eq!(r.failbacks, r.switches_injected, "heal brings traffic home");
        assert_eq!(r.precision(), 1.0, "fp={}", r.fp);
        let leaves = h.sim.topo.fabric.num_leaf_switches();
        assert!(h
            .fault_tape()
            .iter()
            .all(|e| e.kind == TapeKind::SwitchDown && e.id < leaves));
    }

    /// The dedup satellite: with two NICs per rail, distinct victim ports
    /// resolve to the SAME trunk link. Exclusion keyed on the resolved
    /// LinkId must suppress the second booking — a double-booked trunk
    /// would record the already-cut capacity as "original" and wedge the
    /// heal at 1/8th rate forever.
    #[test]
    fn shared_rail_trunk_is_never_double_booked() {
        let mut cfg = Config::soak_defaults();
        cfg.topo.rails = 4; // 8 NICs on 4 rails: NIC r and NIC r+4 share a trunk
        let mut p = fabric_params(8, 1, 0);
        p.mtbf_ns = 20_000_000_000; // ~3 arrivals per burst: force collisions
        let mut h = SoakHarness::with_params(cfg, p);
        while !h.done() {
            h.run_burst();
            let mut links: Vec<usize> = h.active_degrades.iter().map(|d| d.link).collect();
            let n = links.len();
            links.sort_unstable();
            links.dedup();
            assert_eq!(links.len(), n, "a trunk link was double-booked");
        }
        let r = h.report();
        assert!(!h.hung());
        assert_eq!(r.availability, 1.0);
        assert!(r.trunk_degrades_injected >= 2);
        assert!(r.faults_suppressed >= 1, "same-trunk collisions must be suppressed");
    }

    /// Satellite: kill + resume in the middle of an in-force trunk
    /// degrade. The resumed run must heal the trunk to the checkpointed
    /// original capacity and produce a byte-identical BENCH_soak.json.
    #[test]
    fn soak_resume_mid_trunk_degrade_is_bit_identical() {
        let cfg = Config::soak_defaults();
        let mut p = fabric_params(5, 1, 0);
        p.mtbf_ns = 15_000_000_000; // ~4 arrivals per burst
        p.mttr_ns = 90_000_000_000; // degrades span two burst boundaries
        let mut a = SoakHarness::with_params(cfg.clone(), p.clone());
        while !a.done() {
            a.run_burst();
        }
        let bench_a = a.report().to_bench().to_json();

        let mut b = SoakHarness::with_params(cfg.clone(), p.clone());
        b.run_burst();
        b.run_burst();
        assert!(!b.active_degrades.is_empty(), "checkpoint must land mid-degrade");
        assert!(b.active_degrades.iter().all(|d| b.sim.topo.fabric.is_trunk(LinkId(d.link))));
        let ckpt = b.checkpoint();
        drop(b);
        let mut c = SoakHarness::restore_with_params(cfg, p, &ckpt).expect("soak restore");
        assert_eq!(c.checkpoint(), ckpt, "re-checkpoint is a fixed point");
        while !c.done() {
            c.run_burst();
        }
        assert_eq!(c.report().to_bench().to_json(), bench_a);
        assert_eq!(c.fault_tape(), a.fault_tape());
        assert_eq!(c.sim.now(), a.sim.now());
        // Healed capacities match the uninterrupted run bit-for-bit.
        let caps = |h: &SoakHarness| -> Vec<u64> {
            (0..h.sim.topo.fabric.num_links())
                .map(|l| h.sim.rdma.flows.link_capacity_bpns(LinkId(l)).to_bits())
                .collect()
        };
        assert_eq!(caps(&c), caps(&a));
    }

    /// §Elastic: a node-weighted soak grades the shrink/rejoin machinery —
    /// zero lost ops, exactly one shrink and one rejoin per crash, and the
    /// full ring back at the end. Crashes are boundary-applied so nothing
    /// is in flight toward the victim; the P2P wave reroutes (here, with
    /// one peer node, it is skipped outright while the peer is down).
    #[test]
    fn node_weighted_soak_shrinks_and_rejoins_per_crash() {
        let cfg = Config::soak_defaults();
        let p = SoakParams {
            flap_weight: 0,
            degrade_weight: 0,
            node_weight: 1,
            ..quick_params(6)
        };
        let mut h = SoakHarness::with_params(cfg, p);
        while !h.done() {
            h.run_burst();
        }
        let r = h.report();
        assert!(!h.hung());
        assert_eq!(r.availability, 1.0, "a node crash must never lose an op");
        assert!(r.node_crashes_injected >= 1, "MTBF of 1.5 bursts must fault");
        assert_eq!(
            r.flaps_injected + r.degrades_injected + r.trunk_degrades_injected
                + r.switches_injected,
            0
        );
        assert_eq!(r.elastic_shrinks, r.node_crashes_injected, "one shrink per crash");
        assert_eq!(r.elastic_rejoins, r.node_crashes_injected, "one rejoin per heal");
        assert_eq!(r.ops_requeued, 0, "boundary-applied crashes abort nothing");
        assert_eq!(r.precision(), 1.0, "fp={}", r.fp);
        // Ground-truth tape: every entry names the only crashable node.
        assert_eq!(h.fault_tape().len(), r.node_crashes_injected as usize);
        assert!(h.fault_tape().iter().all(|e| e.kind == TapeKind::NodeCrash && e.id == 1));
        // All crashes healed within their burst (mttr < period): full ring.
        assert!(h.sim.dead_nodes.iter().all(|d| !d), "every victim rejoined");
        let full = h.cfg.topo.num_nodes * h.cfg.topo.gpus_per_node;
        assert_eq!(h.sim.rings[0].order.len(), full, "final ring spans all ranks");
    }

    /// The overlap-dedup satellite: with MTTR spanning burst boundaries, a
    /// second crash drawn while the victim is still down must be
    /// suppressed (not double-booked) — a double booking would schedule a
    /// second NodeUp cascade that revives ports the first heal already
    /// owns. Counted via `faults_suppressed`, like the trunk dedup.
    #[test]
    fn node_crash_on_crashed_node_is_suppressed() {
        let cfg = Config::soak_defaults();
        let mut p = SoakParams {
            flap_weight: 0,
            degrade_weight: 0,
            node_weight: 1,
            ..quick_params(6)
        };
        p.mtbf_ns = 20_000_000_000; // ~3 arrivals per burst: force collisions
        p.mttr_ns = 90_000_000_000; // crashes span burst boundaries
        let mut h = SoakHarness::with_params(cfg, p);
        while !h.done() {
            h.run_burst();
            // One crash at a time per node — no duplicates in force.
            let mut nodes: Vec<usize> = h.active_crashes.iter().map(|c| c.node).collect();
            let n = nodes.len();
            nodes.sort_unstable();
            nodes.dedup();
            assert_eq!(nodes.len(), n, "a node crash was double-booked");
        }
        let r = h.report();
        assert!(!h.hung());
        assert_eq!(r.availability, 1.0);
        assert!(r.node_crashes_injected >= 2, "heals must re-arm the victim");
        assert!(r.faults_suppressed >= 1, "same-node collisions must be suppressed");
        assert_eq!(r.elastic_shrinks, r.node_crashes_injected);
    }
}
