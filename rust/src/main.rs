//! `vccl` — CLI entry point. See `vccl help` / coordinator module docs.

use vccl::coordinator::{self, Command};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (cmd, cfg) = match coordinator::parse_args(&args) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("error: {e}\n\n{}", coordinator::help_text());
            std::process::exit(2);
        }
    };
    let result = match cmd {
        Command::Help => {
            println!("{}", coordinator::help_text());
            Ok(())
        }
        Command::Info => {
            println!("{cfg:#?}");
            Ok(())
        }
        Command::Exp { id } => coordinator::run_experiment(&id, &cfg).map(|r| println!("{r}")),
        Command::Trace { id, out, diff } => {
            if diff {
                coordinator::trace::run_traced_diff(&id, &cfg).and_then(|(text, identical)| {
                    println!("{text}");
                    if identical {
                        Ok(())
                    } else {
                        Err(anyhow::anyhow!("trace diff: runs of {id} diverged"))
                    }
                })
            } else {
                coordinator::trace::run_traced(&id, &cfg, out.as_deref()).map(|run| {
                    println!("{}", run.report);
                    println!("{}", run.summary);
                    println!(
                        "trace: {} event(s) ({} dropped from the ring), {} incident(s) -> {}",
                        run.records.len(),
                        run.dropped,
                        run.incidents.len(),
                        run.json_path.display()
                    );
                })
            }
        }
        Command::Rca { id, symptom, out } => {
            coordinator::rca::run_rca(&id, &cfg, symptom.as_deref()).and_then(|(text, bench)| {
                println!("{text}");
                if let Some(path) = out {
                    if let Some(dir) = path.parent() {
                        if !dir.as_os_str().is_empty() {
                            std::fs::create_dir_all(dir)?;
                        }
                    }
                    std::fs::write(&path, bench.to_json())?;
                    println!("wrote {}", path.display());
                }
                Ok(())
            })
        }
        Command::Bench { out_dir, quick, suite } => {
            coordinator::bench::run_bench(
                &cfg,
                &out_dir,
                &coordinator::bench::BenchOpts { quick, suite },
            )
                .map(|paths| {
                    for p in paths {
                        println!("wrote {}", p.display());
                    }
                })
        }
        Command::Soak { out_dir, opts } => {
            coordinator::soak::run_soak(&cfg, &out_dir, &opts).map(|summary| println!("{summary}"))
        }
        Command::Train { preset, steps, out } => {
            let opts = vccl::train::TrainOpts { preset, steps, ..Default::default() };
            vccl::train::run_training(std::path::Path::new("artifacts"), cfg, &opts, |rec| {
                println!("step {:>5}  loss {:.4}  ({:.0} ms)", rec.step, rec.loss, rec.wall_ms);
            })
            .map(|rep| {
                println!(
                    "transport={} sim_iter={:.1}ms sim_tflops/gpu={:.0} final_loss={:.4}",
                    rep.transport,
                    rep.sim_iter_ns as f64 / 1e6,
                    rep.sim_tflops_per_gpu,
                    rep.final_loss()
                );
                if let Some(path) = out {
                    std::fs::write(&path, rep.to_csv()).expect("write csv");
                    println!("loss curve -> {}", path.display());
                }
            })
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
