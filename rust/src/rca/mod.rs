//! Causal root-cause analysis over the flight recorder (§3.4, `vccl rca`).
//!
//! The monitor answers *"is something wrong on this port?"*; the recorder
//! answers *"what happened around the anomaly?"*. This module closes the
//! loop and answers *"why did this symptom happen?"* — Mycroft-style
//! causal diagnosis, but over the deterministic event stream the simulator
//! already records, so every verdict is replayable bit-for-bit.
//!
//! The pipeline is three pure stages over `&[TraceRecord]`:
//!
//! 1. **Graph build** ([`build`]): one pass over the ring derives a typed
//!    dependency graph. Nodes are the stable recorder ids (port ordinals,
//!    QP ids, flow ids, transfer creation ordinals, conn ids, op ids);
//!    edges point *effect → cause* and come from event semantics, never
//!    from live simulator state:
//!
//!    | event                        | edges derived                          |
//!    |------------------------------|----------------------------------------|
//!    | `ConnBound`                  | Conn→Qp, Qp→Port                       |
//!    | `WrPosted`/`WrCompleted`/`QpReset` | Qp→Port                          |
//!    | `QpRetryArmed`/`QpError`     | Qp→Port (+ symptom)                    |
//!    | `FlowStalled { link: Some }` | Flow→Link, Link→Port, Link→Switch      |
//!    | `PointerMigrated`            | Xfer→Conn, Conn→Port (+ symptom)       |
//!    | `PathMigrated`               | Xfer→Conn, Conn→Link, Link→Switch      |
//!    | `TrunkDegraded`/`TrunkRestored` | Link→Switch (window on the switch)  |
//!    | `OpSubmitted` w/o `OpFinished` | Op→each in-interval symptom entity   |
//!
//!    The same pass opens **fault windows** — `PortDown`..`PortUp`,
//!    `SwitchDown`..`SwitchUp`, `TrunkDegraded`..`TrunkRestored` and
//!    `LinkCapacity` degrade..restore pairs — and collects **symptoms**
//!    (stalls, armed/expired retry windows, failovers, non-healthy monitor
//!    verdicts, ops unfinished at trace end), folded by (kind, entity) so
//!    the first occurrence carries the time-to-attribution clock.
//!
//! 2. **Backward walk** ([`CausalGraph::walk`]): BFS from the symptom node
//!    along effect→cause edges. Every reached node with a fault window
//!    active at symptom time is a candidate root cause, scored by hop
//!    distance and fault-to-symptom delay. With no fault evidence in
//!    reach, the nearest infrastructure node is reported *unattributed* —
//!    rendered for the operator, excluded from grading.
//!
//! 3. **Grading** ([`grade`]): scenario runners know the injected faults
//!    (ground truth), so precision / recall / time-to-attribution are
//!    computed per scenario and asserted in tests and CI.
//!
//! Everything is deterministic: `BTreeMap` adjacency, first-occurrence
//! symptom order, and rational score arithmetic with a total tie-break on
//! node identity. Same ring ⇒ byte-identical report.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::fmt::Write as _;

use crate::config::{Config, RcaConfig};
use crate::metrics::Table;
use crate::sim::SimTime;
use crate::trace::{TraceEvent, TraceRecord};

/// The slice of static topology the graph needs: which links are NIC
/// uplinks, and which port each belongs to. Mirrors the fabric layout
/// contract (NIC tx/rx pairs interleaved at the front of the link table;
/// trunk links after), so it can be derived from config alone and applied
/// to a recorded trace long after the simulator is gone.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RcaTopo {
    /// Links `0..nic_links` are NIC uplinks; link `l` serves port `l / 2`.
    pub nic_links: usize,
    /// Ports per NIC (planes): 2 on dual-port RNICs, else 1.
    pub ports_per_nic: usize,
    pub nics_per_node: usize,
    /// Rail count (leaf switches per plane). Zero when the switch layout
    /// is unknown — fault windows then stay on bare link nodes and no
    /// Link→Switch edges are derived.
    pub rails: usize,
}

impl RcaTopo {
    pub fn from_config(cfg: &Config) -> Self {
        let ports_per_nic = if cfg.topo.dual_port_nics { 2 } else { 1 };
        RcaTopo {
            nic_links: cfg.topo.num_nodes * cfg.topo.nics_per_node * ports_per_nic * 2,
            ports_per_nic,
            nics_per_node: cfg.topo.nics_per_node,
            rails: cfg.topo.rails,
        }
    }

    /// Leaf switches (rails × planes); trunk pair `i` belongs to leaf `i`.
    pub fn leaf_switches(&self) -> usize {
        self.rails * self.ports_per_nic
    }

    /// The port ordinal a NIC uplink belongs to; `None` for trunk links.
    pub fn link_port(&self, link: usize) -> Option<usize> {
        (link < self.nic_links).then_some(link / 2)
    }

    /// The host node (server) a port ordinal belongs to: ports are laid
    /// out node-major, `nics_per_node × ports_per_nic` per node.
    pub fn port_node(&self, port: usize) -> usize {
        port / (self.nics_per_node * self.ports_per_nic).max(1)
    }

    /// The leaf switch that owns a link (fabric layout contract): a NIC
    /// uplink belongs to the leaf of its (rail, plane); trunk pairs follow
    /// the NIC uplinks in the table, one up/down pair per leaf. `None`
    /// past the trunk region (NVLink) or when the switch layout is
    /// unknown (`rails == 0`).
    pub fn link_switch(&self, link: usize) -> Option<usize> {
        if self.rails == 0 || self.ports_per_nic == 0 {
            return None;
        }
        if let Some(t) = link.checked_sub(self.nic_links) {
            return (t / 2 < self.leaf_switches()).then_some(t / 2);
        }
        let port_idx = link / 2;
        let local = (port_idx / self.ports_per_nic) % self.nics_per_node.max(1);
        let plane = port_idx % self.ports_per_nic;
        Some((local % self.rails) * self.ports_per_nic + plane)
    }
}

/// A vertex in the causal graph, keyed by the recorder's stable ids.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Node {
    Port(usize),
    Link(usize),
    Switch(usize),
    /// A host server (§Elastic): the fault domain a node crash opens.
    Host(usize),
    Qp(u64),
    Conn(usize),
    Flow(u64),
    Xfer(u64),
    Op(usize),
}

impl Node {
    pub fn render(&self) -> String {
        match self {
            Node::Port(p) => format!("port {p}"),
            Node::Link(l) => format!("link {l}"),
            Node::Switch(s) => format!("switch {s}"),
            Node::Host(h) => format!("host {h}"),
            Node::Qp(q) => format!("qp {q}"),
            Node::Conn(c) => format!("conn {c}"),
            Node::Flow(f) => format!("flow {f}"),
            Node::Xfer(x) => format!("xfer {x}"),
            Node::Op(o) => format!("op {o}"),
        }
    }
}

/// Why an effect→cause edge exists (one per deriving event semantic).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EdgeKind {
    /// QP → the port its work requests cross.
    QpOnPort,
    /// Conn → a QP it bound at setup (`ConnBound`).
    ConnOwnsQp,
    /// Conn → the port a failover identified as failed (`PointerMigrated`).
    ConnOnPort,
    /// Flow → the first down link on its path at stall time.
    FlowOnLink,
    /// NIC uplink → its port (static layout, via [`RcaTopo`]).
    LinkOnPort,
    /// Trunk link → the switch that owns it (fault-domain hierarchy).
    LinkOnSwitch,
    /// NIC port → the host server it is plugged into (static layout):
    /// a crashed node emits no per-port `PortDown`, so symptoms on its
    /// ports walk up to the node-down window through this edge.
    PortOnNode,
    /// Conn → the dead link a path migration named (`PathMigrated`).
    ConnOnLink,
    /// Xfer → the connection whose pointers migrated.
    XferOnConn,
    /// Op → an entity symptomatic inside the op's open interval.
    OpOverlap,
}

impl EdgeKind {
    /// Human phrasing for chain rendering: "<effect> <describe> <cause>".
    pub fn describe(&self) -> &'static str {
        match self {
            EdgeKind::QpOnPort => "posts on",
            EdgeKind::ConnOwnsQp => "bound qp",
            EdgeKind::ConnOnPort => "failed over from",
            EdgeKind::FlowOnLink => "stalled on",
            EdgeKind::LinkOnPort => "uplink of",
            EdgeKind::LinkOnSwitch => "member of",
            EdgeKind::PortOnNode => "hosted by",
            EdgeKind::ConnOnLink => "migrated off",
            EdgeKind::XferOnConn => "carried by",
            EdgeKind::OpOverlap => "overlaps",
        }
    }
}

/// Observable badness the walk starts from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SymptomKind {
    FlowStall,
    QpRetry,
    QpError,
    Failover,
    Verdict,
    OpDeadlineMiss,
}

impl SymptomKind {
    /// Stable name; `--symptom <substr>` filters against it.
    pub fn name(&self) -> &'static str {
        match self {
            SymptomKind::FlowStall => "stall",
            SymptomKind::QpRetry => "qp-retry",
            SymptomKind::QpError => "qp-error",
            SymptomKind::Failover => "failover",
            SymptomKind::Verdict => "verdict",
            SymptomKind::OpDeadlineMiss => "op-deadline",
        }
    }
}

/// One folded symptom: first occurrence of (kind, entity), with the number
/// of repeats. The first-occurrence time is what time-to-attribution is
/// measured from.
#[derive(Debug, Clone, PartialEq)]
pub struct Symptom {
    pub kind: SymptomKind,
    pub node: Node,
    pub at: SimTime,
    pub count: u64,
    pub detail: String,
}

/// An interval during which a piece of infrastructure was observably at
/// fault: `PortDown`..`PortUp`, or a `LinkCapacity` degrade..restore pair.
/// NIC-uplink degrades hang off the *port* node (where the symptom walks
/// converge); trunk degrades stay on the link node.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultWindow {
    pub node: Node,
    pub kind: &'static str,
    pub from: SimTime,
    pub until: Option<SimTime>,
}

impl FaultWindow {
    /// Active at `t`, with `grace_ns` of slack after close so symptoms
    /// that lag the recovery (retry expiries, trailing verdicts) still
    /// attribute to the fault that caused them.
    fn active_at(&self, t: SimTime, grace_ns: u64) -> bool {
        self.from <= t && self.until.map_or(true, |u| t.as_ns() <= u.as_ns() + grace_ns)
    }
}

/// The typed dependency graph plus everything the walk needs.
#[derive(Debug, Clone)]
pub struct CausalGraph {
    pub topo: RcaTopo,
    /// effect → (cause, kind); `Vec` deduped, insertion-ordered.
    edges: BTreeMap<Node, Vec<(Node, EdgeKind)>>,
    edge_count: usize,
    pub symptoms: Vec<Symptom>,
    pub faults: Vec<FaultWindow>,
    /// Timestamp of the last record — the "now" for deadline-miss symptoms.
    pub end: SimTime,
}

/// One pass over the ring: derive edges, fault windows and symptoms.
pub fn build(records: &[TraceRecord], topo: RcaTopo) -> CausalGraph {
    let mut g = CausalGraph {
        topo,
        edges: BTreeMap::new(),
        edge_count: 0,
        symptoms: Vec::new(),
        faults: Vec::new(),
        end: SimTime::ZERO,
    };
    let mut seen: BTreeMap<(SymptomKind, Node), usize> = BTreeMap::new();
    let mut open_ops: BTreeMap<usize, (SimTime, &'static str, u64)> = BTreeMap::new();
    for r in records {
        if r.at > g.end {
            g.end = r.at;
        }
        match r.ev {
            TraceEvent::ConnBound { conn, qp, port, .. } => {
                g.add_edge(Node::Conn(conn), Node::Qp(qp), EdgeKind::ConnOwnsQp);
                g.add_edge(Node::Qp(qp), Node::Port(port), EdgeKind::QpOnPort);
            }
            TraceEvent::WrPosted { qp, port, .. }
            | TraceEvent::WrCompleted { qp, port, .. }
            | TraceEvent::QpReset { qp, port, .. } => {
                g.add_edge(Node::Qp(qp), Node::Port(port), EdgeKind::QpOnPort);
            }
            TraceEvent::QpRetryArmed { qp, port, .. } => {
                g.add_edge(Node::Qp(qp), Node::Port(port), EdgeKind::QpOnPort);
                g.symptom(
                    &mut seen,
                    SymptomKind::QpRetry,
                    Node::Qp(qp),
                    r.at,
                    format!("retry window armed on port {port}"),
                );
            }
            TraceEvent::QpError { qp, port } => {
                g.add_edge(Node::Qp(qp), Node::Port(port), EdgeKind::QpOnPort);
                g.symptom(
                    &mut seen,
                    SymptomKind::QpError,
                    Node::Qp(qp),
                    r.at,
                    format!("retry window expired on port {port}"),
                );
            }
            TraceEvent::FlowStalled { flow, link } => {
                if let Some(l) = link {
                    g.add_edge(Node::Flow(flow), Node::Link(l), EdgeKind::FlowOnLink);
                    if let Some(p) = topo.link_port(l) {
                        g.add_edge(Node::Link(l), Node::Port(p), EdgeKind::LinkOnPort);
                    }
                    // A leaf-switch outage kills NIC uplinks without a
                    // PortDown: the stall must be able to walk up to the
                    // owning switch's fault window.
                    if let Some(s) = topo.link_switch(l) {
                        g.add_edge(Node::Link(l), Node::Switch(s), EdgeKind::LinkOnSwitch);
                    }
                }
                let detail = match link {
                    Some(l) => format!("rate -> 0 (link {l} down)"),
                    None => "rate -> 0 (contention)".to_string(),
                };
                g.symptom(&mut seen, SymptomKind::FlowStall, Node::Flow(flow), r.at, detail);
            }
            TraceEvent::PointerMigrated { conn, xfer, port, rolled_back, .. } => {
                g.add_edge(Node::Xfer(xfer), Node::Conn(conn), EdgeKind::XferOnConn);
                if let Some(p) = port {
                    g.add_edge(Node::Conn(conn), Node::Port(p), EdgeKind::ConnOnPort);
                }
                g.symptom(
                    &mut seen,
                    SymptomKind::Failover,
                    Node::Conn(conn),
                    r.at,
                    format!("xfer {xfer}: {rolled_back} chunk(s) rolled back"),
                );
            }
            TraceEvent::MonitorVerdict { port, verdict, gbps } => {
                // Only non-healthy verdicts are ever recorded.
                g.symptom(
                    &mut seen,
                    SymptomKind::Verdict,
                    Node::Port(port),
                    r.at,
                    format!("{verdict} at {gbps:.1} Gbps"),
                );
            }
            TraceEvent::PortDown { port } => {
                g.open_fault(Node::Port(port), "port-down", r.at);
            }
            TraceEvent::PortUp { port } => {
                g.close_fault(Node::Port(port), r.at);
            }
            TraceEvent::LinkCapacity { link, gbps, was_gbps } => {
                // NIC-uplink degrades hang off the port; trunk degrades
                // off the owning leaf switch (with a Link→Switch edge so
                // flow stalls on the trunk walk up to it); bare link only
                // when the switch layout is unknown.
                let node = match (topo.link_port(link), topo.link_switch(link)) {
                    (Some(p), _) => Node::Port(p),
                    (None, Some(s)) => {
                        g.add_edge(Node::Link(link), Node::Switch(s), EdgeKind::LinkOnSwitch);
                        Node::Switch(s)
                    }
                    (None, None) => Node::Link(link),
                };
                if gbps < was_gbps {
                    g.open_fault(node, "degraded", r.at);
                } else {
                    g.close_fault(node, r.at);
                }
            }
            TraceEvent::SwitchDown { switch } => {
                g.open_fault(Node::Switch(switch), "switch-down", r.at);
            }
            TraceEvent::SwitchUp { switch } => {
                g.close_fault(Node::Switch(switch), r.at);
            }
            TraceEvent::NodeDown { node } => {
                g.open_fault(Node::Host(node), "node-down", r.at);
            }
            TraceEvent::NodeUp { node } => {
                g.close_fault(Node::Host(node), r.at);
            }
            TraceEvent::TrunkDegraded { link, switch, .. } => {
                g.add_edge(Node::Link(link), Node::Switch(switch), EdgeKind::LinkOnSwitch);
                g.open_fault(Node::Switch(switch), "trunk-down", r.at);
            }
            TraceEvent::TrunkRestored { link, switch, .. } => {
                g.add_edge(Node::Link(link), Node::Switch(switch), EdgeKind::LinkOnSwitch);
                g.close_fault(Node::Switch(switch), r.at);
            }
            TraceEvent::PathMigrated { conn, xfer, link } => {
                g.add_edge(Node::Xfer(xfer), Node::Conn(conn), EdgeKind::XferOnConn);
                g.add_edge(Node::Conn(conn), Node::Link(link), EdgeKind::ConnOnLink);
                if let Some(s) = topo.link_switch(link) {
                    g.add_edge(Node::Link(link), Node::Switch(s), EdgeKind::LinkOnSwitch);
                }
            }
            TraceEvent::OpSubmitted { op, kind, bytes } => {
                open_ops.insert(op, (r.at, kind, bytes));
            }
            TraceEvent::OpFinished { op, .. } => {
                open_ops.remove(&op);
            }
            _ => {}
        }
    }
    // Every port in the graph hangs off its host server (static layout,
    // like Link→Port): a node crash kills every NIC port of the victim
    // WITHOUT per-port PortDown events, so symptoms on those ports need
    // the Port→Host edge to reach the node-down fault window.
    let mut ports: BTreeSet<usize> = BTreeSet::new();
    for (n, v) in &g.edges {
        if let Node::Port(p) = n {
            ports.insert(*p);
        }
        for (c, _) in v {
            if let Node::Port(p) = c {
                ports.insert(*p);
            }
        }
    }
    for s in &g.symptoms {
        if let Node::Port(p) = s.node {
            ports.insert(p);
        }
    }
    for p in ports {
        g.add_edge(Node::Port(p), Node::Host(topo.port_node(p)), EdgeKind::PortOnNode);
    }
    // Ops still open when the trace ends are hung. Each becomes a symptom
    // with temporal edges to every entity that showed a symptom inside the
    // op's interval — the bridge from "op 3 never finished" down to the
    // stalled flows / errored QPs that explain it.
    for (op, (at, kind, bytes)) in open_ops {
        let targets: Vec<Node> = g
            .symptoms
            .iter()
            .filter(|s| s.at >= at && s.node != Node::Op(op))
            .map(|s| s.node)
            .collect();
        for n in targets {
            g.add_edge(Node::Op(op), n, EdgeKind::OpOverlap);
        }
        let end = g.end;
        g.symptom(
            &mut seen,
            SymptomKind::OpDeadlineMiss,
            Node::Op(op),
            end,
            format!("{kind} ({bytes} B) unfinished at trace end"),
        );
    }
    g
}

/// A ranked root-cause candidate for one symptom.
#[derive(Debug, Clone, PartialEq)]
pub struct RankedCause {
    pub node: Node,
    /// The attributed port ordinal (direct for `Port` nodes, via the NIC
    /// uplink layout for `Link` nodes). Grading keys on this.
    pub port: Option<usize>,
    pub hops: usize,
    /// Fault-window kind, or `"unattributed"` for the fallback candidate.
    pub kind: &'static str,
    pub fault_at: SimTime,
    /// Backed by a fault window active at symptom time. Only confident
    /// causes are graded; fallbacks are rendered for the operator only.
    pub confident: bool,
    pub score: f64,
    /// Walk path, symptom-exclusive, cause-inclusive: each entry is the
    /// node stepped *to* and the edge kind that justified the step.
    pub path: Vec<(Node, EdgeKind)>,
}

/// One symptom with its ranked causes (best first).
#[derive(Debug, Clone, PartialEq)]
pub struct Attribution {
    pub symptom: Symptom,
    pub causes: Vec<RankedCause>,
}

impl Attribution {
    /// The port the top confident cause names — what grading counts.
    pub fn attributed_port(&self) -> Option<usize> {
        self.causes.iter().find(|c| c.confident).and_then(|c| c.port)
    }

    /// The switch the top confident cause names — what fabric-level
    /// grading ([`grade_switches`]) counts.
    pub fn attributed_switch(&self) -> Option<usize> {
        self.causes.iter().find(|c| c.confident).and_then(|c| match c.node {
            Node::Switch(s) => Some(s),
            _ => None,
        })
    }

    /// The host the top confident cause names — what node-level grading
    /// ([`grade_nodes`]) counts.
    pub fn attributed_node(&self) -> Option<usize> {
        self.causes.iter().find(|c| c.confident).and_then(|c| match c.node {
            Node::Host(h) => Some(h),
            _ => None,
        })
    }
}

impl CausalGraph {
    fn add_edge(&mut self, effect: Node, cause: Node, kind: EdgeKind) {
        let v = self.edges.entry(effect).or_default();
        if !v.contains(&(cause, kind)) {
            v.push((cause, kind));
            self.edge_count += 1;
        }
    }

    fn symptom(
        &mut self,
        seen: &mut BTreeMap<(SymptomKind, Node), usize>,
        kind: SymptomKind,
        node: Node,
        at: SimTime,
        detail: String,
    ) {
        match seen.get(&(kind, node)) {
            Some(&i) => self.symptoms[i].count += 1,
            None => {
                seen.insert((kind, node), self.symptoms.len());
                self.symptoms.push(Symptom { kind, node, at, count: 1, detail });
            }
        }
    }

    fn open_fault(&mut self, node: Node, kind: &'static str, at: SimTime) {
        // Re-opening an already-open window folds (repeated degrades).
        if self.faults.iter().any(|f| f.node == node && f.until.is_none()) {
            return;
        }
        self.faults.push(FaultWindow { node, kind, from: at, until: None });
    }

    fn close_fault(&mut self, node: Node, at: SimTime) {
        if let Some(f) =
            self.faults.iter_mut().rev().find(|f| f.node == node && f.until.is_none())
        {
            f.until = Some(at);
        }
    }

    pub fn edge_count(&self) -> usize {
        self.edge_count
    }

    pub fn node_count(&self) -> usize {
        let mut set: BTreeSet<Node> = BTreeSet::new();
        for (n, v) in &self.edges {
            set.insert(*n);
            for (c, _) in v {
                set.insert(*c);
            }
        }
        for s in &self.symptoms {
            set.insert(s.node);
        }
        for f in &self.faults {
            set.insert(f.node);
        }
        set.len()
    }

    /// Backward BFS from `symptom` along effect→cause edges; rank every
    /// fault-backed node reached. Deterministic: `BTreeMap` adjacency is
    /// insertion-ordered per node, scores are rational, ties break on node
    /// identity.
    pub fn walk(&self, symptom: &Symptom, cfg: &RcaConfig) -> Vec<RankedCause> {
        let grace_ns = (cfg.grace_ms * 1e6) as u64;
        let mut dist: BTreeMap<Node, usize> = BTreeMap::new();
        let mut parent: BTreeMap<Node, (Node, EdgeKind)> = BTreeMap::new();
        let mut queue: VecDeque<Node> = VecDeque::new();
        dist.insert(symptom.node, 0);
        queue.push_back(symptom.node);
        let mut causes: Vec<RankedCause> = Vec::new();
        while let Some(n) = queue.pop_front() {
            let hops = dist[&n];
            for f in &self.faults {
                if f.node == n && f.active_at(symptom.at, grace_ns) {
                    let dt_ms =
                        symptom.at.as_ns().saturating_sub(f.from.as_ns()) as f64 / 1e6;
                    let score = cfg.hop_weight / (1.0 + hops as f64)
                        + cfg.time_weight / (1.0 + dt_ms / cfg.time_decay_ms);
                    causes.push(RankedCause {
                        node: n,
                        port: self.port_of(n),
                        hops,
                        kind: f.kind,
                        fault_at: f.from,
                        confident: true,
                        score,
                        path: Self::path_to(symptom.node, n, &parent),
                    });
                }
            }
            if let Some(adj) = self.edges.get(&n) {
                for &(c, k) in adj {
                    if let std::collections::btree_map::Entry::Vacant(e) = dist.entry(c) {
                        e.insert(hops + 1);
                        parent.insert(c, (n, k));
                        queue.push_back(c);
                    }
                }
            }
        }
        if causes.is_empty() {
            // No fault evidence in reach: fall back to the nearest
            // infrastructure node so the operator still gets a pointer.
            let nearest = dist
                .iter()
                .filter(|(n, _)| matches!(n, Node::Port(_) | Node::Link(_) | Node::Switch(_)))
                .map(|(n, h)| (*h, *n))
                .min();
            if let Some((hops, n)) = nearest {
                causes.push(RankedCause {
                    node: n,
                    port: self.port_of(n),
                    hops,
                    kind: "unattributed",
                    fault_at: symptom.at,
                    confident: false,
                    score: cfg.hop_weight / (1.0 + hops as f64),
                    path: Self::path_to(symptom.node, n, &parent),
                });
            }
        }
        causes.sort_by(|a, b| {
            b.score
                .partial_cmp(&a.score)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.node.cmp(&b.node))
        });
        let mut kept = BTreeSet::new();
        causes.retain(|c| kept.insert(c.node));
        causes.truncate(cfg.max_candidates.max(1));
        causes
    }

    fn port_of(&self, n: Node) -> Option<usize> {
        match n {
            Node::Port(p) => Some(p),
            Node::Link(l) => self.topo.link_port(l),
            _ => None,
        }
    }

    fn path_to(
        from: Node,
        to: Node,
        parent: &BTreeMap<Node, (Node, EdgeKind)>,
    ) -> Vec<(Node, EdgeKind)> {
        let mut path = Vec::new();
        let mut cur = to;
        while cur != from {
            let Some(&(prev, kind)) = parent.get(&cur) else { break };
            path.push((cur, kind));
            cur = prev;
        }
        path.reverse();
        path
    }
}

/// The full analysis result for one trace.
#[derive(Debug, Clone)]
pub struct RcaReport {
    /// All symptoms found, pre-filter.
    pub symptoms_total: usize,
    pub attributions: Vec<Attribution>,
    pub nodes: usize,
    pub edges: usize,
    pub faults: usize,
    pub end: SimTime,
}

/// Walk every symptom (optionally filtered by `--symptom` substring match
/// on [`SymptomKind::name`]) and rank its causes.
pub fn analyze(g: &CausalGraph, cfg: &RcaConfig, symptom_filter: Option<&str>) -> RcaReport {
    let mut attributions = Vec::new();
    for s in &g.symptoms {
        if let Some(f) = symptom_filter {
            if !s.kind.name().contains(f) {
                continue;
            }
        }
        attributions.push(Attribution { symptom: s.clone(), causes: g.walk(s, cfg) });
    }
    RcaReport {
        symptoms_total: g.symptoms.len(),
        attributions,
        nodes: g.node_count(),
        edges: g.edge_count(),
        faults: g.faults.len(),
        end: g.end,
    }
}

/// Ground truth: one injected fault the scenario runner knows about.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    pub port: usize,
    pub at: SimTime,
}

/// Scenario score: how the report's confident attributions line up with
/// the injected fault set.
#[derive(Debug, Clone, PartialEq)]
pub struct Grade {
    /// Distinct injected victim ports.
    pub injected: usize,
    /// Attributions with a confident top cause naming a port.
    pub attributed: usize,
    /// Of those, how many named an injected port.
    pub correct: usize,
    /// Distinct injected ports named by at least one attribution.
    pub recalled: usize,
    pub precision: f64,
    pub recall: f64,
    /// Per recalled port: earliest (symptom time − latest injection ≤ it),
    /// i.e. how quickly after the fault a walkable symptom existed.
    pub tta_ns: Vec<(usize, u64)>,
}

impl Grade {
    pub fn mean_tta_ms(&self) -> f64 {
        if self.tta_ns.is_empty() {
            return 0.0;
        }
        self.tta_ns.iter().map(|(_, d)| *d as f64 / 1e6).sum::<f64>()
            / self.tta_ns.len() as f64
    }
}

/// Score a report against the injected fault set.
pub fn grade(report: &RcaReport, injected: &[InjectedFault]) -> Grade {
    let ports: BTreeSet<usize> = injected.iter().map(|f| f.port).collect();
    let mut attributed = 0usize;
    let mut correct = 0usize;
    let mut tta: BTreeMap<usize, u64> = BTreeMap::new();
    for a in &report.attributions {
        let Some(p) = a.attributed_port() else { continue };
        attributed += 1;
        if ports.contains(&p) {
            correct += 1;
            if let Some(f) = injected
                .iter()
                .filter(|f| f.port == p && f.at <= a.symptom.at)
                .max_by_key(|f| f.at.as_ns())
            {
                let d = a.symptom.at.as_ns() - f.at.as_ns();
                tta.entry(p).and_modify(|e| *e = (*e).min(d)).or_insert(d);
            }
        }
    }
    Grade {
        injected: ports.len(),
        attributed,
        correct,
        recalled: tta.len(),
        precision: if attributed == 0 { 1.0 } else { correct as f64 / attributed as f64 },
        recall: if ports.is_empty() { 1.0 } else { tta.len() as f64 / ports.len() as f64 },
        tta_ns: tta.into_iter().collect(),
    }
}

/// Ground truth for a fabric-level fault: the owning switch of the downed
/// trunk (or the downed switch itself).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedSwitchFault {
    pub switch: usize,
    pub at: SimTime,
}

/// Score a report against injected fabric faults: same shape as [`grade`]
/// but keyed on the switch the top confident cause names. `tta_ns` entries
/// are keyed by switch id.
pub fn grade_switches(report: &RcaReport, injected: &[InjectedSwitchFault]) -> Grade {
    let switches: BTreeSet<usize> = injected.iter().map(|f| f.switch).collect();
    let mut attributed = 0usize;
    let mut correct = 0usize;
    let mut tta: BTreeMap<usize, u64> = BTreeMap::new();
    for a in &report.attributions {
        let Some(s) = a.attributed_switch() else { continue };
        attributed += 1;
        if switches.contains(&s) {
            correct += 1;
            if let Some(f) = injected
                .iter()
                .filter(|f| f.switch == s && f.at <= a.symptom.at)
                .max_by_key(|f| f.at.as_ns())
            {
                let d = a.symptom.at.as_ns() - f.at.as_ns();
                tta.entry(s).and_modify(|e| *e = (*e).min(d)).or_insert(d);
            }
        }
    }
    Grade {
        injected: switches.len(),
        attributed,
        correct,
        recalled: tta.len(),
        precision: if attributed == 0 { 1.0 } else { correct as f64 / attributed as f64 },
        recall: if switches.is_empty() { 1.0 } else { tta.len() as f64 / switches.len() as f64 },
        tta_ns: tta.into_iter().collect(),
    }
}

/// Ground truth for a node-level fault: the crashed host server.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedNodeFault {
    pub node: usize,
    pub at: SimTime,
}

/// Score a report against injected node crashes: same shape as [`grade`]
/// but keyed on the host the top confident cause names. `tta_ns` entries
/// are keyed by host id.
pub fn grade_nodes(report: &RcaReport, injected: &[InjectedNodeFault]) -> Grade {
    let hosts: BTreeSet<usize> = injected.iter().map(|f| f.node).collect();
    let mut attributed = 0usize;
    let mut correct = 0usize;
    let mut tta: BTreeMap<usize, u64> = BTreeMap::new();
    for a in &report.attributions {
        let Some(h) = a.attributed_node() else { continue };
        attributed += 1;
        if hosts.contains(&h) {
            correct += 1;
            if let Some(f) = injected
                .iter()
                .filter(|f| f.node == h && f.at <= a.symptom.at)
                .max_by_key(|f| f.at.as_ns())
            {
                let d = a.symptom.at.as_ns() - f.at.as_ns();
                tta.entry(h).and_modify(|e| *e = (*e).min(d)).or_insert(d);
            }
        }
    }
    Grade {
        injected: hosts.len(),
        attributed,
        correct,
        recalled: tta.len(),
        precision: if attributed == 0 { 1.0 } else { correct as f64 / attributed as f64 },
        recall: if hosts.is_empty() { 1.0 } else { tta.len() as f64 / hosts.len() as f64 },
        tta_ns: tta.into_iter().collect(),
    }
}

/// Multi-fault disambiguation score: with several victims at fault
/// simultaneously, does each symptom name *its own* victim — the one its
/// causal walk actually reaches — rather than a fresher or closer fault
/// elsewhere in the fabric?
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Disambiguation {
    /// Symptoms whose candidate list reaches exactly one injected victim.
    pub scored: usize,
    /// Of those, the top confident cause named that victim.
    pub correct: usize,
    /// Symptoms reaching two or more victims (op-overlap bridges): they
    /// are ambiguous by construction, not mis-attributed, so they are
    /// counted but not scored.
    pub ambiguous: usize,
    /// `correct / scored`; vacuously 1.0 with nothing to score.
    pub score: f64,
}

/// Score how well the report disambiguates between the given victim
/// entities (injected ports as [`Node::Port`], switches as
/// [`Node::Switch`], crashed hosts as [`Node::Host`]). A symptom is
/// "scored" when exactly one victim is reachable in its candidate list;
/// it is "correct" when the top confident cause is that victim.
pub fn disambiguate(report: &RcaReport, victims: &[Node]) -> Disambiguation {
    let vs: BTreeSet<Node> = victims.iter().copied().collect();
    let mut scored = 0usize;
    let mut correct = 0usize;
    let mut ambiguous = 0usize;
    for a in &report.attributions {
        let reachable: BTreeSet<Node> = a
            .causes
            .iter()
            .filter(|c| c.confident)
            .map(|c| c.node)
            .filter(|n| vs.contains(n))
            .collect();
        match reachable.len() {
            0 => {}
            1 => {
                scored += 1;
                let own = *reachable.iter().next().expect("len == 1");
                let top = a.causes.iter().find(|c| c.confident).map(|c| c.node);
                if top == Some(own) {
                    correct += 1;
                }
            }
            _ => ambiguous += 1,
        }
    }
    Disambiguation {
        scored,
        correct,
        ambiguous,
        score: if scored == 0 { 1.0 } else { correct as f64 / scored as f64 },
    }
}

/// How many causal chains [`render_report`] prints in full.
const MAX_CHAINS: usize = 3;

/// Fixed-width report body (the `vccl rca` stdout), timeline-style.
pub fn render_report(r: &RcaReport, title: &str) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "rca — {title}: {} symptom(s) ({} shown), {} node(s), {} edge(s), \
         {} fault window(s), trace end {:.3} ms",
        r.symptoms_total,
        r.attributions.len(),
        r.nodes,
        r.edges,
        r.faults,
        r.end.as_ms_f64(),
    );
    out.push('\n');
    if r.attributions.is_empty() {
        let _ = writeln!(out, "(no symptoms — nothing to diagnose)");
        return out;
    }
    let mut t = Table::new(vec![
        "symptom",
        "entity",
        "t (ms)",
        "n",
        "root cause",
        "kind",
        "hops",
        "score",
        "fault t (ms)",
    ]);
    for a in &r.attributions {
        let s = &a.symptom;
        match a.causes.first() {
            Some(c) => t.row(vec![
                s.kind.name().to_string(),
                s.node.render(),
                format!("{:.3}", s.at.as_ms_f64()),
                s.count.to_string(),
                c.node.render(),
                c.kind.to_string(),
                c.hops.to_string(),
                format!("{:.2}", c.score),
                if c.confident { format!("{:.3}", c.fault_at.as_ms_f64()) } else { "-".to_string() },
            ]),
            None => t.row(vec![
                s.kind.name().to_string(),
                s.node.render(),
                format!("{:.3}", s.at.as_ms_f64()),
                s.count.to_string(),
                "-".to_string(),
                "unreachable".to_string(),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
            ]),
        };
    }
    out.push_str(&t.render());
    // Full causal chains for the first few confident attributions.
    let mut shown = 0usize;
    for a in &r.attributions {
        let Some(c) = a.causes.first() else { continue };
        if !c.confident || shown == MAX_CHAINS {
            continue;
        }
        shown += 1;
        let _ = writeln!(
            out,
            "\ncausal chain — {} on {} at {:.3} ms:\n",
            a.symptom.kind.name(),
            a.symptom.node.render(),
            a.symptom.at.as_ms_f64(),
        );
        let mut t = Table::new(vec!["hop", "entity", "via", "evidence"]);
        t.row(vec![
            "0".to_string(),
            a.symptom.node.render(),
            "-".to_string(),
            a.symptom.detail.clone(),
        ]);
        let last = c.path.len();
        for (i, (node, kind)) in c.path.iter().enumerate() {
            let evidence = if i + 1 == last {
                format!("fault window {} open since {:.3} ms", c.kind, c.fault_at.as_ms_f64())
            } else {
                String::new()
            };
            t.row(vec![
                (i + 1).to_string(),
                node.render(),
                kind.describe().to_string(),
                evidence,
            ]);
        }
        out.push_str(&t.render());
    }
    out
}

/// Render a grade as a fixed-width block (appended per scenario).
pub fn render_grade(g: &Grade, scenario: &str) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "\nground truth — {scenario}: {} injected port(s), {} attribution(s), \
         precision {:.2}, recall {:.2}",
        g.injected, g.attributed, g.precision, g.recall,
    );
    if !g.tta_ns.is_empty() {
        let mut t = Table::new(vec!["victim port", "time to attribution (ms)"]);
        for (p, d) in &g.tta_ns {
            t.row(vec![p.to_string(), format!("{:.3}", *d as f64 / 1e6)]);
        }
        out.push('\n');
        out.push_str(&t.render());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rcfg() -> RcaConfig {
        RcaConfig::default()
    }

    /// paper_defaults shape: 2 nodes × 8 NICs single-port, 8 leaves.
    fn topo32() -> RcaTopo {
        RcaTopo { nic_links: 32, ports_per_nic: 1, nics_per_node: 8, rails: 8 }
    }

    fn rec(ns: u64, seq: u64, ev: TraceEvent) -> TraceRecord {
        TraceRecord { at: SimTime::ns(ns), seq, ev }
    }

    /// The hand-built incident: conn 0 (qp 1 on port 2, backup qp 9 on
    /// port 3) loses port 2 mid-transfer; the full symptom ladder fires.
    fn incident_records() -> Vec<TraceRecord> {
        vec![
            rec(0, 0, TraceEvent::SimStarted { nodes: 2, ranks: 16 }),
            rec(
                100,
                1,
                TraceEvent::ConnBound { conn: 0, qp: 1, port: 2, backup: false },
            ),
            rec(
                110,
                2,
                TraceEvent::ConnBound { conn: 0, qp: 9, port: 3, backup: true },
            ),
            rec(
                500_000,
                3,
                TraceEvent::OpSubmitted { op: 0, kind: "AllReduce", bytes: 1 << 20 },
            ),
            rec(1_000_000, 4, TraceEvent::PortDown { port: 2 }),
            rec(1_100_000, 5, TraceEvent::WrPosted { qp: 1, port: 2, bytes: 4096 }),
            rec(
                1_200_000,
                6,
                TraceEvent::QpRetryArmed { qp: 1, port: 2, deadline_ns: 50_000_000 },
            ),
            // Link 4 is port 2's tx uplink (4 / 2 == 2).
            rec(1_300_000, 7, TraceEvent::FlowStalled { flow: 5, link: Some(4) }),
            rec(50_000_000, 8, TraceEvent::QpError { qp: 1, port: 2 }),
            rec(
                50_100_000,
                9,
                TraceEvent::PointerMigrated {
                    conn: 0,
                    xfer: 7,
                    port: Some(2),
                    breakpoint: 10,
                    rolled_back: 5,
                },
            ),
            rec(
                55_000_000,
                10,
                TraceEvent::MonitorVerdict {
                    port: 2,
                    verdict: "network-anomaly",
                    gbps: 11.0,
                },
            ),
            rec(60_000_000, 11, TraceEvent::PortUp { port: 2 }),
        ]
    }

    #[test]
    fn topo_maps_nic_links_to_ports() {
        let cfg = Config::paper_defaults(); // 2 nodes x 8 NICs, single-port
        let t = RcaTopo::from_config(&cfg);
        assert_eq!(t.nic_links, 32);
        assert_eq!(t.leaf_switches(), 8);
        assert_eq!(t.link_port(0), Some(0));
        assert_eq!(t.link_port(1), Some(0));
        assert_eq!(t.link_port(7), Some(3));
        assert_eq!(t.link_port(31), Some(15));
        assert_eq!(t.link_port(32), None);
        // NIC uplinks map to the leaf of their (rail, plane): node 1's
        // NIC 7 (links 30/31) hangs off leaf 7 just like node 0's NIC 7.
        assert_eq!(t.link_switch(4), Some(2));
        assert_eq!(t.link_switch(31), Some(7));
        // Trunk pairs map to their owning leaf; NVLink links to nothing.
        assert_eq!(t.link_switch(32), Some(0));
        assert_eq!(t.link_switch(33), Some(0));
        assert_eq!(t.link_switch(40), Some(4));
        assert_eq!(t.link_switch(47), Some(7));
        assert_eq!(t.link_switch(48), None); // past the trunk region
        let mut cfg = Config::paper_defaults();
        cfg.topo.dual_port_nics = true;
        let t = RcaTopo::from_config(&cfg);
        assert_eq!(t.nic_links, 64);
        assert_eq!(t.leaf_switches(), 16);
        // Dual-plane: NIC 2's plane-1 uplink belongs to leaf (rail 2, plane 1).
        assert_eq!(t.link_switch(2 * 4 + 2), Some(2 * 2 + 1));
    }

    #[test]
    fn hand_built_sequence_walks_to_injected_port() {
        let g = build(&incident_records(), topo32());
        // One fault window: port 2, [1 ms, 60 ms].
        assert_eq!(g.faults.len(), 1);
        assert_eq!(g.faults[0].node, Node::Port(2));
        assert_eq!(g.faults[0].kind, "port-down");
        assert_eq!(g.faults[0].until, Some(SimTime::ms(60)));
        // The full symptom ladder, plus the hung op.
        let kinds: Vec<SymptomKind> = g.symptoms.iter().map(|s| s.kind).collect();
        assert_eq!(
            kinds,
            vec![
                SymptomKind::QpRetry,
                SymptomKind::FlowStall,
                SymptomKind::QpError,
                SymptomKind::Failover,
                SymptomKind::Verdict,
                SymptomKind::OpDeadlineMiss,
            ]
        );
        // Every symptom's top cause is the injected port, confidently.
        for s in &g.symptoms {
            let causes = g.walk(s, &rcfg());
            let top = causes.first().unwrap_or_else(|| panic!("no cause for {s:?}"));
            assert!(top.confident, "{s:?} -> {top:?}");
            assert_eq!(top.node, Node::Port(2), "{s:?}");
            assert_eq!(top.port, Some(2));
            assert_eq!(top.kind, "port-down");
        }
        // Hop distances reflect the graph shape.
        let hop_of = |kind: SymptomKind| {
            let s = g.symptoms.iter().find(|s| s.kind == kind).unwrap();
            g.walk(s, &rcfg())[0].hops
        };
        assert_eq!(hop_of(SymptomKind::Verdict), 0); // Port(2) itself
        assert_eq!(hop_of(SymptomKind::QpError), 1); // Qp -> Port
        assert_eq!(hop_of(SymptomKind::FlowStall), 2); // Flow -> Link -> Port
        assert_eq!(hop_of(SymptomKind::Failover), 1); // Conn -> Port (ConnOnPort)
        // Grade: one injected fault, fully recalled, perfect precision.
        let report = analyze(&g, &rcfg(), None);
        let gr = grade(&report, &[InjectedFault { port: 2, at: SimTime::ms(1) }]);
        assert_eq!(gr.injected, 1);
        assert_eq!(gr.recalled, 1);
        assert_eq!(gr.precision, 1.0);
        assert_eq!(gr.recall, 1.0);
        // Earliest attributing symptom is the retry arm at 1.2 ms.
        assert_eq!(gr.tta_ns, vec![(2, 200_000)]);
    }

    #[test]
    fn symptoms_fold_by_kind_and_entity() {
        let recs = vec![
            rec(10, 0, TraceEvent::FlowStalled { flow: 5, link: Some(4) }),
            rec(20, 1, TraceEvent::FlowStalled { flow: 5, link: Some(4) }),
            rec(30, 2, TraceEvent::FlowStalled { flow: 6, link: None }),
        ];
        let g = build(&recs, topo32());
        assert_eq!(g.symptoms.len(), 2);
        assert_eq!(g.symptoms[0].count, 2);
        assert_eq!(g.symptoms[0].at, SimTime::ns(10));
        assert_eq!(g.symptoms[1].node, Node::Flow(6));
    }

    #[test]
    fn degrade_window_opens_and_closes_from_link_capacity() {
        let recs = vec![
            // NIC uplink 4 -> port 2: degrade at 2 ms, restore at 9 ms.
            rec(
                2_000_000,
                0,
                TraceEvent::LinkCapacity { link: 4, gbps: 50.0, was_gbps: 400.0 },
            ),
            rec(
                5_000_000,
                1,
                TraceEvent::MonitorVerdict {
                    port: 2,
                    verdict: "network-anomaly",
                    gbps: 48.0,
                },
            ),
            rec(
                9_000_000,
                2,
                TraceEvent::LinkCapacity { link: 4, gbps: 400.0, was_gbps: 50.0 },
            ),
        ];
        let g = build(&recs, topo32());
        assert_eq!(g.faults.len(), 1);
        assert_eq!(g.faults[0].node, Node::Port(2));
        assert_eq!(g.faults[0].kind, "degraded");
        assert_eq!(g.faults[0].from, SimTime::ms(2));
        assert_eq!(g.faults[0].until, Some(SimTime::ms(9)));
        let causes = g.walk(&g.symptoms[0], &rcfg());
        assert_eq!(causes[0].node, Node::Port(2));
        assert_eq!(causes[0].kind, "degraded");
        assert!(causes[0].confident);
        // Trunk degrades attribute to the owning leaf switch (link 40 ->
        // trunk pair 4) with the Link→Switch edge in place.
        let recs = vec![rec(
            0,
            0,
            TraceEvent::LinkCapacity { link: 40, gbps: 50.0, was_gbps: 400.0 },
        )];
        let g = build(&recs, topo32());
        assert_eq!(g.faults[0].node, Node::Switch(4));
        // Unknown switch layout: the window stays on the bare link node.
        let g = build(
            &recs,
            RcaTopo { nic_links: 32, ports_per_nic: 1, nics_per_node: 8, rails: 0 },
        );
        assert_eq!(g.faults[0].node, Node::Link(40));
    }

    /// §Fault domains: a trunk capacity degrade plus the stalls it causes
    /// walk Flow → Link → Switch, and fabric-level grading scores the
    /// switch attribution.
    #[test]
    fn trunk_symptoms_attribute_to_owning_switch() {
        let recs = vec![
            // Trunk link 40 (leaf 4) dies at 2 ms; the event names its
            // owning switch, the stalled flow names only the link.
            rec(
                2_000_000,
                0,
                TraceEvent::TrunkDegraded { link: 40, switch: 4, gbps: 0.0, was_gbps: 400.0 },
            ),
            rec(2_100_000, 1, TraceEvent::FlowStalled { flow: 5, link: Some(40) }),
            rec(
                10_000_000,
                2,
                TraceEvent::PathMigrated { conn: 0, xfer: 7, link: 40 },
            ),
            rec(3_000_000_000, 3, TraceEvent::TrunkRestored { link: 40, switch: 4, gbps: 400.0 }),
        ];
        let g = build(&recs, topo32());
        assert_eq!(g.faults.len(), 1);
        assert_eq!(g.faults[0].node, Node::Switch(4));
        assert_eq!(g.faults[0].kind, "trunk-down");
        assert_eq!(g.faults[0].until, Some(SimTime::ns(3_000_000_000)));
        let stall = g.symptoms.iter().find(|s| s.kind == SymptomKind::FlowStall).unwrap();
        let causes = g.walk(stall, &rcfg());
        assert!(causes[0].confident);
        assert_eq!(causes[0].node, Node::Switch(4));
        assert_eq!(causes[0].hops, 2); // Flow -> Link -> Switch
        let report = analyze(&g, &rcfg(), None);
        let gr = grade_switches(
            &report,
            &[InjectedSwitchFault { switch: 4, at: SimTime::ms(2) }],
        );
        assert_eq!(gr.injected, 1);
        assert_eq!(gr.recalled, 1);
        assert_eq!(gr.precision, 1.0);
        assert_eq!(gr.recall, 1.0);
        // No PORT is ever blamed for a trunk death.
        let pgr = grade(&report, &[]);
        assert_eq!(pgr.attributed, 0, "switch attributions must not count as ports");
    }

    #[test]
    fn closed_window_past_grace_is_not_a_candidate() {
        let recs = vec![
            rec(1_000_000, 0, TraceEvent::PortDown { port: 2 }),
            rec(2_000_000, 1, TraceEvent::PortUp { port: 2 }),
            // A verdict 10 s later: far past grace, must not attribute.
            rec(
                10_000_000_000,
                2,
                TraceEvent::MonitorVerdict {
                    port: 2,
                    verdict: "non-network",
                    gbps: 300.0,
                },
            ),
        ];
        let g = build(&recs, topo32());
        let causes = g.walk(&g.symptoms[0], &rcfg());
        assert_eq!(causes.len(), 1);
        assert!(!causes[0].confident);
        assert_eq!(causes[0].kind, "unattributed");
        let report = analyze(&g, &rcfg(), None);
        assert_eq!(report.attributions[0].attributed_port(), None);
        let gr = grade(&report, &[InjectedFault { port: 2, at: SimTime::ms(1) }]);
        assert_eq!(gr.attributed, 0);
        assert_eq!(gr.recalled, 0);
        assert_eq!(gr.precision, 1.0); // vacuous, nothing attributed
        assert_eq!(gr.recall, 0.0);
    }

    #[test]
    fn scoring_prefers_recent_fault_and_breaks_ties_on_node() {
        // Flow 5 stalled on two different uplinks across its life; both
        // ports are down, port 2 much longer than port 9.
        let recs = vec![
            rec(1_000, 0, TraceEvent::PortDown { port: 2 }),
            rec(400_000_000, 1, TraceEvent::PortDown { port: 9 }),
            rec(400_100_000, 2, TraceEvent::FlowStalled { flow: 5, link: Some(4) }),
            rec(400_200_000, 3, TraceEvent::FlowStalled { flow: 5, link: Some(18) }),
        ];
        let g = build(&recs, topo32());
        let s = &g.symptoms[0]; // the folded flow-5 stall (first at 400.1 ms)
        let causes = g.walk(s, &rcfg());
        assert_eq!(causes.len(), 2);
        // Same hop count; port 9's fault is 0.1 ms old vs 400 ms: the
        // fresher fault wins on the time term.
        assert_eq!(causes[0].node, Node::Port(9));
        assert_eq!(causes[1].node, Node::Port(2));
        assert!(causes[0].score > causes[1].score);
        // Exact tie (same fault time, same hops): node order decides.
        let recs = vec![
            rec(1_000, 0, TraceEvent::PortDown { port: 2 }),
            rec(1_000, 1, TraceEvent::PortDown { port: 9 }),
            rec(2_000, 2, TraceEvent::FlowStalled { flow: 5, link: Some(4) }),
            rec(2_000, 3, TraceEvent::FlowStalled { flow: 5, link: Some(18) }),
        ];
        let g = build(&recs, topo32());
        let causes = g.walk(&g.symptoms[0], &rcfg());
        assert_eq!(causes[0].node, Node::Port(2));
        assert_eq!(causes[1].node, Node::Port(9));
    }

    #[test]
    fn hung_op_bridges_to_symptomatic_entities() {
        let recs = vec![
            rec(0, 0, TraceEvent::OpSubmitted { op: 3, kind: "AllReduce", bytes: 64 }),
            rec(1_000_000, 1, TraceEvent::PortDown { port: 2 }),
            rec(1_100_000, 2, TraceEvent::FlowStalled { flow: 5, link: Some(4) }),
        ];
        let g = build(&recs, topo32());
        let miss = g
            .symptoms
            .iter()
            .find(|s| s.kind == SymptomKind::OpDeadlineMiss)
            .expect("hung op symptom");
        assert_eq!(miss.node, Node::Op(3));
        assert_eq!(miss.at, g.end);
        let causes = g.walk(miss, &rcfg());
        assert_eq!(causes[0].node, Node::Port(2));
        assert!(causes[0].confident);
        // Op -> Flow (overlap) -> Link -> Port.
        assert_eq!(causes[0].hops, 3);
        // A finished op leaves no symptom.
        let recs = vec![
            rec(0, 0, TraceEvent::OpSubmitted { op: 3, kind: "AllReduce", bytes: 64 }),
            rec(5, 1, TraceEvent::OpFinished { op: 3, xfers: 2, bytes: 64 }),
        ];
        let g = build(&recs, topo32());
        assert!(g.symptoms.is_empty());
    }

    #[test]
    fn symptom_filter_selects_kinds() {
        let g = build(&incident_records(), topo32());
        let r = analyze(&g, &rcfg(), Some("qp"));
        assert_eq!(r.attributions.len(), 2); // qp-retry, qp-error
        assert_eq!(r.symptoms_total, 6);
        let r = analyze(&g, &rcfg(), Some("failover"));
        assert_eq!(r.attributions.len(), 1);
        let r = analyze(&g, &rcfg(), Some("nope"));
        assert!(r.attributions.is_empty());
    }

    #[test]
    fn report_renders_deterministically() {
        let g = build(&incident_records(), topo32());
        let r = analyze(&g, &rcfg(), None);
        let a = render_report(&r, "unit");
        let b = render_report(&r, "unit");
        assert_eq!(a, b);
        assert!(a.contains("root cause"), "{a}");
        assert!(a.contains("port 2"), "{a}");
        assert!(a.contains("causal chain"), "{a}");
        assert!(a.contains("fault window port-down open since 1.000 ms"), "{a}");
        let gr = grade(&r, &[InjectedFault { port: 2, at: SimTime::ms(1) }]);
        let s = render_grade(&gr, "unit");
        assert!(s.contains("precision 1.00, recall 1.00"), "{s}");
        assert!(s.contains("victim port"), "{s}");
    }

    #[test]
    fn walk_terminates_on_cyclic_graphs() {
        // Op overlap edges can point at entities whose own walks reach
        // back near the op; the visited set must keep BFS finite.
        let recs = vec![
            rec(0, 0, TraceEvent::OpSubmitted { op: 0, kind: "AllReduce", bytes: 1 }),
            rec(10, 1, TraceEvent::ConnBound { conn: 0, qp: 1, port: 2, backup: false }),
            rec(20, 2, TraceEvent::QpError { qp: 1, port: 2 }),
            rec(30, 3, TraceEvent::QpError { qp: 1, port: 2 }),
        ];
        let g = build(&recs, topo32());
        for s in &g.symptoms {
            let _ = g.walk(s, &rcfg()); // must not hang
        }
    }

    /// §Elastic: a node crash opens a fault window on the host, and a
    /// stall on one of the victim's uplinks walks Flow → Link → Port →
    /// Host into it — with no per-port PortDown ever recorded.
    #[test]
    fn node_crash_symptoms_attribute_to_host() {
        let recs = vec![
            rec(2_000_000, 0, TraceEvent::NodeDown { node: 1 }),
            // Link 18 is port 9's tx uplink; port 9 lives on node 1.
            rec(2_100_000, 1, TraceEvent::FlowStalled { flow: 5, link: Some(18) }),
            rec(400_000_000, 2, TraceEvent::NodeUp { node: 1 }),
        ];
        let g = build(&recs, topo32());
        assert_eq!(g.faults.len(), 1);
        assert_eq!(g.faults[0].node, Node::Host(1));
        assert_eq!(g.faults[0].kind, "node-down");
        assert_eq!(g.faults[0].until, Some(SimTime::ms(400)));
        let causes = g.walk(&g.symptoms[0], &rcfg());
        assert!(causes[0].confident);
        assert_eq!(causes[0].node, Node::Host(1));
        assert_eq!(causes[0].hops, 3); // Flow -> Link -> Port -> Host
        let report = analyze(&g, &rcfg(), None);
        let gr = grade_nodes(
            &report,
            &[InjectedNodeFault { node: 1, at: SimTime::ms(2) }],
        );
        assert_eq!(gr.injected, 1);
        assert_eq!(gr.recalled, 1);
        assert_eq!(gr.precision, 1.0);
        assert_eq!(gr.recall, 1.0);
        assert_eq!(gr.tta_ns, vec![(1, 100_000)]);
        // No PORT is blamed for a node death (there is no port window).
        let pgr = grade(&report, &[]);
        assert_eq!(pgr.attributed, 0, "host attributions must not count as ports");
    }

    /// The disambiguation satellite: two simultaneous victims, one stall
    /// each. Each stall reaches exactly its own victim (scored, correct);
    /// the hung op overlaps both and is counted ambiguous, not wrong.
    #[test]
    fn concurrent_victims_disambiguate_per_symptom() {
        let recs = vec![
            rec(0, 0, TraceEvent::OpSubmitted { op: 0, kind: "AllReduce", bytes: 1 << 20 }),
            rec(1_000_000, 1, TraceEvent::PortDown { port: 2 }),
            rec(1_000_000, 2, TraceEvent::PortDown { port: 9 }),
            // Link 4 -> port 2, link 18 -> port 9: disjoint walks.
            rec(1_100_000, 3, TraceEvent::FlowStalled { flow: 5, link: Some(4) }),
            rec(1_200_000, 4, TraceEvent::FlowStalled { flow: 6, link: Some(18) }),
        ];
        let g = build(&recs, topo32());
        let report = analyze(&g, &rcfg(), None);
        let victims = [Node::Port(2), Node::Port(9)];
        let d = disambiguate(&report, &victims);
        assert_eq!(d.scored, 2, "each stall reaches exactly one victim");
        assert_eq!(d.correct, 2, "each stall names its own victim");
        assert_eq!(d.ambiguous, 1, "the hung op overlaps both victims");
        assert_eq!(d.score, 1.0);
        // And the per-stall attributions really are distinct ports.
        let stall_ports: Vec<Option<usize>> = report
            .attributions
            .iter()
            .filter(|a| a.symptom.kind == SymptomKind::FlowStall)
            .map(|a| a.attributed_port())
            .collect();
        assert_eq!(stall_ports, vec![Some(2), Some(9)]);
    }
}
