//! GPU execution model: SM pool, compute kernels with the co-residency
//! tail-straggler effect (paper Appendix E), copy engines, and the CUDA
//! stream-ordering primitives the SM-free design replaces kernels with.
//!
//! What matters for this paper is not cycle-accurate SM simulation but the
//! *resource interference* structure:
//!
//!  - a communication kernel occupies `n` SMs for its full duration
//!    (Table 1: 32 SMs intra-node P2P, 2 inter-node, 28/4 alltoall);
//!  - a GEMM whose blocks land on those SMs is extended by a tail-straggler
//!    factor (Appendix E: the kernel cannot finish until its slowest block
//!    does, and blocks co-resident with 20 communication warps run slower);
//!  - copy engines move data without touching SMs but pay a setup latency
//!    and are a contended, countable resource (§4.1: higher small-message
//!    intra-node latency under VCCL).

pub mod compute;
pub mod copy_engine;
pub mod stream;

pub use compute::{ComputeTask, GpuCompute, TaskId, TaskTimer};
pub use copy_engine::{CopyEngines, CopyGrant};
pub use stream::{BrokerOutcome, EventFlag, HostCallback, HostFuncBroker, OrderingCost};
