//! Copy engines: the DMA units VCCL's SM-free intra-node path uses instead
//! of SM copy kernels (§3.2-1).
//!
//! Copy engines are a small, contended pool (Hopper exposes a handful of
//! async DMA engines). A `cudaMemcpy` issued through an engine:
//!  - pays a fixed setup latency (`copy_engine_setup_ns`) — the §4.1
//!    small-message latency penalty of the SM-free design;
//!  - queues behind earlier copies when all engines are busy;
//!  - but moves the bytes at higher efficiency than an SM copy kernel
//!    ("wider transactions that better saturate NVLink", §4.1 +7 %).
//!
//! The engine pool only does *admission*: the byte movement itself is a
//! flow in the [`crate::net::FlowNet`] (NVLink links) or a fixed-time HBM
//! staging copy, started by the caller when the grant begins.

use crate::sim::SimTime;
use crate::util::{CkptReader, CkptWriter};

/// A granted slot on a copy engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CopyGrant {
    /// When the engine starts serving this copy (≥ request time).
    pub start_at: SimTime,
    /// Which engine serves it (for traces).
    pub engine: u32,
}

/// FIFO admission over `n` engines: each request declares its expected
/// busy time; the earliest-free engine serves it.
#[derive(Debug)]
pub struct CopyEngines {
    free_at: Vec<SimTime>,
    setup_ns: u64,
}

impl CopyEngines {
    pub fn new(n: u32, setup_ns: u64) -> Self {
        CopyEngines { free_at: vec![SimTime::ZERO; n.max(1) as usize], setup_ns }
    }

    pub fn setup_ns(&self) -> u64 {
        self.setup_ns
    }

    /// Request an engine at `now` for a copy expected to occupy it for
    /// `busy_ns` (setup included by this call). Returns when the copy may
    /// begin (post-setup) and marks the engine busy until start + busy.
    pub fn admit(&mut self, now: SimTime, busy_ns: u64) -> CopyGrant {
        let (idx, &free) = self
            .free_at
            .iter()
            .enumerate()
            .min_by_key(|(_, &t)| t)
            .expect("at least one engine");
        let begin = now.max(free) + SimTime::ns(self.setup_ns);
        self.free_at[idx] = begin + SimTime::ns(busy_ns);
        CopyGrant { start_at: begin, engine: idx as u32 }
    }

    /// Earliest time any engine is free (diagnostics).
    pub fn next_free(&self) -> SimTime {
        *self.free_at.iter().min().unwrap()
    }

    /// Serialize the admission state (§Soak checkpointing). `free_at` can
    /// point into the future at an op-quiescent boundary (an engine granted
    /// right before the last copy of a burst), so it must survive.
    pub fn save(&self, w: &mut CkptWriter) {
        w.usize("nce", self.free_at.len());
        for t in &self.free_at {
            w.u64("free", t.as_ns());
        }
    }

    /// Restore into a freshly constructed pool of the same size.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        let n = r.usize("nce")?;
        if n != self.free_at.len() {
            return Err(format!("checkpoint has {n} copy engines, config built {}", self.free_at.len()));
        }
        for t in self.free_at.iter_mut() {
            *t = SimTime::ns(r.u64("free")?);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grant_includes_setup_latency() {
        let mut ce = CopyEngines::new(3, 4_000);
        let g = ce.admit(SimTime::us(10), 1_000);
        assert_eq!(g.start_at, SimTime::ns(14_000));
    }

    #[test]
    fn engines_round_robin_when_free() {
        let mut ce = CopyEngines::new(2, 0);
        let a = ce.admit(SimTime::ZERO, 100);
        let b = ce.admit(SimTime::ZERO, 100);
        // Two engines → both start immediately on different engines.
        assert_eq!(a.start_at, SimTime::ZERO);
        assert_eq!(b.start_at, SimTime::ZERO);
        assert_ne!(a.engine, b.engine);
    }

    #[test]
    fn queueing_when_all_busy() {
        let mut ce = CopyEngines::new(1, 1_000);
        let a = ce.admit(SimTime::ZERO, 10_000);
        // Engine busy until 1_000 + 10_000; next admit waits.
        let b = ce.admit(SimTime::ZERO, 5_000);
        assert_eq!(a.start_at, SimTime::ns(1_000));
        assert_eq!(b.start_at, SimTime::ns(12_000)); // 11_000 free + 1_000 setup
    }

    #[test]
    fn contention_is_the_small_message_penalty() {
        // Many small copies through few engines: per-copy latency grows —
        // the §4.1 intra-node small-message observation.
        let mut ce = CopyEngines::new(3, 4_000);
        let mut last = SimTime::ZERO;
        for _ in 0..12 {
            let g = ce.admit(SimTime::ZERO, 500);
            last = last.max(g.start_at);
        }
        // 12 copies / 3 engines = 4 rounds; round i starts after i×(4.5us).
        assert!(last.as_ns() >= 3 * 4_500, "last={last}");
    }
}
