//! CUDA-stream ordering without kernels (§3.2-3).
//!
//! When the P2P data path no longer puts a kernel on the stream, something
//! else must (a) hold back the communication until prerequisite compute
//! finishes and (b) block dependent compute until the communication is done.
//! The paper tries `cudaLaunchHostFunc` first and hits the Fig 5 deadlock:
//! host callbacks from *independent streams* are serialized on CUDA's
//! internal host-execution thread, so a callback that blocks (waiting for a
//! peer's ready flag) starves the very callback that would set it.
//!
//! [`HostFuncBroker`] reproduces that semantics: per-process FIFO callback
//! queues with blocking waits — and a detector for the circular-wait state.
//! `cuStreamWriteValue`/`cuStreamWaitValue` (the fix) are stream-native and
//! modelled as plain nanosecond-scale stream ops with no shared thread.

use std::collections::{HashMap, HashSet, VecDeque};

use crate::config::StreamOrdering;

/// Cost model for the ordering primitive on each P2P op.
#[derive(Debug, Clone, Copy)]
pub struct OrderingCost {
    /// Latency added on the critical path per synchronization point (ns).
    pub sync_ns: u64,
    /// SMs held for the duration of the op (0 except the NCCLX-like
    /// ordering kernel, which is accounted in the transport instead).
    pub sms: u32,
}

impl OrderingCost {
    pub fn of(mode: StreamOrdering) -> OrderingCost {
        match mode {
            // Host callback dispatch: μs-scale (CUDA internal thread hop).
            StreamOrdering::HostFunc => OrderingCost { sync_ns: 6_000, sms: 0 },
            // Stream memory op: sub-μs (device-side poll on a mapped word).
            StreamOrdering::WriteValue => OrderingCost { sync_ns: 400, sms: 0 },
        }
    }
}

/// An event that callbacks wait on / signal (e.g. `proxyReadyEvent` of the
/// forward or backward P2P group between a GPU pair).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventFlag(pub u64);

/// One host-function callback: optionally blocks until `waits` is signalled,
/// then signals `signals`.
#[derive(Debug, Clone)]
pub struct HostCallback {
    pub waits: Option<EventFlag>,
    pub signals: Vec<EventFlag>,
    /// Label for diagnostics ("gpu0.bwd_recv").
    pub label: &'static str,
}

/// Result of executing the queued callbacks.
#[derive(Debug, PartialEq, Eq)]
pub enum BrokerOutcome {
    /// All callbacks ran; order of completion labels.
    Completed(Vec<&'static str>),
    /// Circular wait: the listed callbacks can never run.
    Deadlock(Vec<&'static str>),
}

/// Per-process host-callback execution: each process (≈ one training rank)
/// has ONE internal CUDA host thread running its callbacks strictly FIFO.
#[derive(Debug, Default)]
pub struct HostFuncBroker {
    queues: HashMap<usize, VecDeque<HostCallback>>,
}

impl HostFuncBroker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Enqueue a callback on `process`'s host thread.
    pub fn enqueue(&mut self, process: usize, cb: HostCallback) {
        self.queues.entry(process).or_default().push_back(cb);
    }

    /// Execute until done or stuck. The semantics being tested: a *blocked
    /// head* callback blocks its whole thread — callbacks behind it cannot
    /// run even if their own waits are satisfied (single-thread limitation
    /// of `cudaLaunchHostFunc`).
    pub fn run(&mut self, pre_signalled: &[EventFlag]) -> BrokerOutcome {
        let mut signalled: HashSet<EventFlag> = pre_signalled.iter().copied().collect();
        let mut completed = Vec::new();
        loop {
            let mut progressed = false;
            let pids: Vec<usize> = {
                let mut v: Vec<usize> = self.queues.keys().copied().collect();
                v.sort();
                v
            };
            for pid in pids {
                let runnable = {
                    let q = &self.queues[&pid];
                    match q.front() {
                        None => false,
                        Some(cb) => cb.waits.map_or(true, |w| signalled.contains(&w)),
                    }
                };
                if runnable {
                    let cb = self.queues.get_mut(&pid).unwrap().pop_front().unwrap();
                    for s in cb.signals {
                        signalled.insert(s);
                    }
                    completed.push(cb.label);
                    progressed = true;
                }
            }
            if !progressed {
                break;
            }
        }
        let stuck: Vec<&'static str> = self
            .queues
            .values()
            .flat_map(|q| q.iter().map(|cb| cb.label))
            .collect();
        if stuck.is_empty() {
            BrokerOutcome::Completed(completed)
        } else {
            BrokerOutcome::Deadlock(stuck)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FWD_0_TO_1: EventFlag = EventFlag(1);
    const BWD_1_TO_0: EventFlag = EventFlag(2);

    /// The Fig 5 scenario. GPU0's host thread first enqueues a callback
    /// that blocks on the *backward* communication from GPU1; the callback
    /// that would signal GPU0's forward send sits behind it. GPU1 is the
    /// mirror image. Neither head can run → deadlock.
    #[test]
    fn fig5_bidirectional_hostfunc_deadlocks() {
        let mut b = HostFuncBroker::new();
        b.enqueue(
            0,
            HostCallback { waits: Some(BWD_1_TO_0), signals: vec![], label: "gpu0.wait_bwd" },
        );
        b.enqueue(
            0,
            HostCallback { waits: None, signals: vec![FWD_0_TO_1], label: "gpu0.signal_fwd" },
        );
        b.enqueue(
            1,
            HostCallback { waits: Some(FWD_0_TO_1), signals: vec![], label: "gpu1.wait_fwd" },
        );
        b.enqueue(
            1,
            HostCallback { waits: None, signals: vec![BWD_1_TO_0], label: "gpu1.signal_bwd" },
        );
        match b.run(&[]) {
            BrokerOutcome::Deadlock(stuck) => {
                assert_eq!(stuck.len(), 4);
            }
            other => panic!("expected deadlock, got {other:?}"),
        }
    }

    /// The paper's first workaround: merging the bidirectional P2P groups
    /// reorders the queues so the signal precedes the blocking wait.
    #[test]
    fn merged_groups_complete() {
        let mut b = HostFuncBroker::new();
        b.enqueue(
            0,
            HostCallback { waits: None, signals: vec![FWD_0_TO_1], label: "gpu0.signal_fwd" },
        );
        b.enqueue(
            0,
            HostCallback { waits: Some(BWD_1_TO_0), signals: vec![], label: "gpu0.wait_bwd" },
        );
        b.enqueue(
            1,
            HostCallback { waits: None, signals: vec![BWD_1_TO_0], label: "gpu1.signal_bwd" },
        );
        b.enqueue(
            1,
            HostCallback { waits: Some(FWD_0_TO_1), signals: vec![], label: "gpu1.wait_fwd" },
        );
        match b.run(&[]) {
            BrokerOutcome::Completed(order) => assert_eq!(order.len(), 4),
            other => panic!("expected completion, got {other:?}"),
        }
    }

    #[test]
    fn pre_signalled_event_unblocks() {
        let mut b = HostFuncBroker::new();
        b.enqueue(0, HostCallback { waits: Some(EventFlag(9)), signals: vec![], label: "w" });
        assert_eq!(b.run(&[EventFlag(9)]), BrokerOutcome::Completed(vec!["w"]));
    }

    #[test]
    fn blocked_head_starves_runnable_tail() {
        // The single-thread limitation itself: the tail callback has no
        // dependency at all but can never run.
        let mut b = HostFuncBroker::new();
        b.enqueue(0, HostCallback { waits: Some(EventFlag(5)), signals: vec![], label: "head" });
        b.enqueue(0, HostCallback { waits: None, signals: vec![EventFlag(5)], label: "tail" });
        match b.run(&[]) {
            BrokerOutcome::Deadlock(stuck) => assert_eq!(stuck, vec!["head", "tail"]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn ordering_costs_differ() {
        let hf = OrderingCost::of(StreamOrdering::HostFunc);
        let wv = OrderingCost::of(StreamOrdering::WriteValue);
        assert!(hf.sync_ns > 10 * wv.sync_ns);
        assert_eq!(wv.sms, 0);
    }
}
