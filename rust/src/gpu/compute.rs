//! Compute-side model: kernels over the SM pool with the Appendix E
//! co-residency tail-straggler effect.
//!
//! A compute task (a GEMM, a fused fwd/bwd step) is `work_ns` of execution
//! at full rate. While one or more communication kernels are resident on the
//! GPU, the task's *rate* drops by the tail factor
//!
//! ```text
//!   tail(n) = 1 + (slowdown − 1) · n² / (n² + k)        (n = comm SMs)
//! ```
//!
//! The quadratic ramp is a calibration of Appendix E's mechanism: with more
//! comm SMs resident, the probability that the GEMM's critical-path wave has
//! a block co-scheduled with communication warps rises steeply, and then
//! saturates at the full per-SM `slowdown`. With the default `k = 8` this
//! lands the paper's measured points: a 2-SM NCCL SendRecv costs ≈4–5 % of
//! end-to-end TFLOPS in 1F1B, the 1-SM NCCLX ordering kernel ≈⅓ of that
//! (Fig 11), and VCCL's 0 SMs cost nothing.
//!
//! Progress accounting uses the same generation-counter pattern as the flow
//! network: rate changes invalidate outstanding completion timers.

use std::collections::HashMap;

use crate::config::GpuConfig;
use crate::sim::SimTime;
use crate::util::{CkptReader, CkptWriter};

/// Identifier of an in-flight compute task on one GPU.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TaskId(pub u64);

/// Completion-check timer the owner must schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TaskTimer {
    pub task: TaskId,
    pub gen: u32,
    pub at: SimTime,
}

#[derive(Debug, Clone)]
pub struct ComputeTask {
    remaining_ns: f64, // at full rate
    rate: f64,         // 1.0 = full speed
    last_update: SimTime,
    gen: u32,
    pub tag: u64,
}

/// Per-GPU compute state: resident communication SMs + running tasks.
#[derive(Debug)]
pub struct GpuCompute {
    cfg: GpuConfig,
    comm_sms: u32,
    tasks: HashMap<TaskId, ComputeTask>,
    next_id: u64,
    /// Σ (comm SMs × ns) — the numerator of the Table 1 SM-utilization
    /// metric. Updated lazily on occupancy changes.
    comm_sm_ns: f64,
    busy_sm_ns: f64,
    last_occupancy_update: SimTime,
    /// Quadratic saturation constant `k` of the tail factor.
    quad_k: f64,
}

impl GpuCompute {
    pub fn new(cfg: GpuConfig) -> Self {
        GpuCompute {
            cfg,
            comm_sms: 0,
            tasks: HashMap::new(),
            next_id: 0,
            comm_sm_ns: 0.0,
            busy_sm_ns: 0.0,
            last_occupancy_update: SimTime::ZERO,
            quad_k: 8.0,
        }
    }

    pub fn cfg(&self) -> &GpuConfig {
        &self.cfg
    }

    /// The Appendix E tail-straggler factor at `n` resident comm SMs.
    pub fn tail_factor(&self, n: u32) -> f64 {
        if n == 0 {
            return 1.0;
        }
        let n2 = (n as f64) * (n as f64);
        1.0 + (self.cfg.coresidency_slowdown - 1.0) * n2 / (n2 + self.quad_k)
    }

    fn current_rate(&self) -> f64 {
        1.0 / self.tail_factor(self.comm_sms)
    }

    fn account_occupancy(&mut self, now: SimTime) {
        let dt = now.since(self.last_occupancy_update).as_ns() as f64;
        self.comm_sm_ns += dt * self.comm_sms as f64;
        if !self.tasks.is_empty() {
            // Compute tasks are modelled as full-GPU waves (the paper's
            // nvjet GEMM launches 132 blocks on 132 SMs).
            self.busy_sm_ns += dt * (self.cfg.num_sms - self.comm_sms) as f64;
        }
        self.last_occupancy_update = now;
    }

    /// Communication kernel takes `n` SMs (NCCL-style P2P / alltoall, or
    /// the 1-SM NCCLX ordering kernel). Returns fresh timers for running
    /// tasks (their rate just dropped).
    pub fn acquire_comm_sms(&mut self, n: u32, now: SimTime) -> Vec<TaskTimer> {
        self.account_occupancy(now);
        self.comm_sms += n;
        assert!(
            self.comm_sms <= self.cfg.num_sms,
            "comm SMs {} exceed pool {}",
            self.comm_sms,
            self.cfg.num_sms
        );
        self.rerate(now)
    }

    /// Release `n` communication SMs.
    pub fn release_comm_sms(&mut self, n: u32, now: SimTime) -> Vec<TaskTimer> {
        self.account_occupancy(now);
        assert!(self.comm_sms >= n, "releasing {} of {} comm SMs", n, self.comm_sms);
        self.comm_sms -= n;
        self.rerate(now)
    }

    pub fn comm_sms(&self) -> u32 {
        self.comm_sms
    }

    /// Start a compute task of `work_ns` full-rate nanoseconds.
    pub fn start_task(&mut self, work_ns: u64, tag: u64, now: SimTime) -> (TaskId, TaskTimer) {
        self.account_occupancy(now);
        let id = TaskId(self.next_id);
        self.next_id += 1;
        let rate = self.current_rate();
        self.tasks.insert(
            id,
            ComputeTask { remaining_ns: work_ns as f64, rate, last_update: now, gen: 0, tag },
        );
        let eta = (work_ns as f64 / rate).ceil() as u64;
        (id, TaskTimer { task: id, gen: 0, at: now + SimTime::ns(eta) })
    }

    /// Completion-timer dispatch. Returns the task's tag if done.
    pub fn try_finish(&mut self, id: TaskId, gen: u32, now: SimTime) -> Option<u64> {
        let t = self.tasks.get_mut(&id)?;
        if t.gen != gen {
            return None;
        }
        let dt = now.since(t.last_update).as_ns() as f64;
        t.remaining_ns -= dt * t.rate;
        t.last_update = now;
        if t.remaining_ns > 0.5 {
            return None;
        }
        let tag = t.tag;
        self.account_occupancy(now);
        self.tasks.remove(&id);
        Some(tag)
    }

    /// How long a task of `work_ns` would take if launched now and the
    /// occupancy never changed (analytic helper for the pipeline model).
    pub fn projected_ns(&self, work_ns: u64) -> u64 {
        (work_ns as f64 * self.tail_factor(self.comm_sms)).ceil() as u64
    }

    fn rerate(&mut self, now: SimTime) -> Vec<TaskTimer> {
        let rate = self.current_rate();
        let mut timers = Vec::with_capacity(self.tasks.len());
        for (&id, t) in self.tasks.iter_mut() {
            let dt = now.since(t.last_update).as_ns() as f64;
            t.remaining_ns = (t.remaining_ns - dt * t.rate).max(0.0);
            t.last_update = now;
            t.rate = rate;
            t.gen += 1;
            let eta = (t.remaining_ns / rate).ceil() as u64;
            timers.push(TaskTimer { task: id, gen: t.gen, at: now + SimTime::ns(eta) });
        }
        timers
    }

    /// SM-utilization fraction attributable to communication kernels over
    /// `[0, now]` — the Table 1 metric.
    pub fn comm_sm_utilization(&mut self, now: SimTime) -> f64 {
        self.account_occupancy(now);
        let total = self.cfg.num_sms as f64 * now.as_ns() as f64;
        if total == 0.0 {
            0.0
        } else {
            self.comm_sm_ns / total
        }
    }

    /// Serialize the durable state (§Soak checkpointing). Requires
    /// quiescence: no running tasks, no resident comm kernels.
    pub fn save(&self, w: &mut CkptWriter) {
        assert!(self.tasks.is_empty(), "GpuCompute checkpoint requires quiescence (tasks running)");
        assert!(self.comm_sms == 0, "GpuCompute checkpoint requires quiescence (comm SMs resident)");
        w.u64("nexttask", self.next_id);
        w.f64("commsm", self.comm_sm_ns);
        w.f64("busysm", self.busy_sm_ns);
        w.u64("occat", self.last_occupancy_update.as_ns());
    }

    /// Restore into a freshly constructed instance.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        self.next_id = r.u64("nexttask")?;
        self.comm_sm_ns = r.f64("commsm")?;
        self.busy_sm_ns = r.f64("busysm")?;
        self.last_occupancy_update = SimTime::ns(r.u64("occat")?);
        Ok(())
    }

    /// GEMM (FLOPs) → full-rate execution time at the configured peak,
    /// assuming the given achieved-fraction-of-peak.
    pub fn gemm_work_ns(&self, flops: f64, efficiency: f64) -> u64 {
        let per_ns = self.cfg.peak_tflops * efficiency * 1e3; // FLOP per ns
        (flops / per_ns).ceil() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpu() -> GpuCompute {
        GpuCompute::new(GpuConfig::default())
    }

    #[test]
    fn tail_factor_shape() {
        let g = gpu();
        assert_eq!(g.tail_factor(0), 1.0);
        let t1 = g.tail_factor(1);
        let t2 = g.tail_factor(2);
        let t32 = g.tail_factor(32);
        assert!(t1 > 1.0 && t2 > t1 && t32 > t2);
        // Saturates at the full slowdown.
        assert!(t32 < 1.6 && t32 > 1.55);
        // 1-SM penalty is roughly a third of the 2-SM penalty (NCCLX vs
        // NCCL calibration, Fig 11).
        let r = (t1 - 1.0) / (t2 - 1.0);
        assert!((0.25..0.45).contains(&r), "ratio={r}");
    }

    #[test]
    fn task_runs_at_full_rate_when_alone() {
        let mut g = gpu();
        let (id, timer) = g.start_task(1_000_000, 42, SimTime::ZERO);
        assert_eq!(timer.at, SimTime::ms(1));
        assert_eq!(g.try_finish(id, timer.gen, timer.at), Some(42));
    }

    #[test]
    fn comm_kernel_extends_running_task() {
        let mut g = gpu();
        let (id, t0) = g.start_task(1_000_000, 1, SimTime::ZERO);
        // Comm kernel lands at 50% progress with 2 SMs.
        let timers = g.acquire_comm_sms(2, SimTime::us(500));
        assert_eq!(timers.len(), 1);
        assert!(timers[0].at > t0.at, "completion must move out");
        // Old timer is stale.
        assert_eq!(g.try_finish(id, t0.gen, t0.at), None);
        // New timer: 500us left at rate 1/tail(2).
        let tail = g.tail_factor(2);
        let expect = 500_000.0 + 500_000.0 * tail;
        assert!((timers[0].at.as_ns() as f64 - expect).abs() < 2.0);
        assert_eq!(g.try_finish(id, timers[0].gen, timers[0].at), Some(1));
    }

    #[test]
    fn release_restores_full_rate() {
        let mut g = gpu();
        let _ = g.acquire_comm_sms(2, SimTime::ZERO);
        let (id, t0) = g.start_task(1_000_000, 7, SimTime::ZERO);
        let tail = g.tail_factor(2);
        // Release at 20% of the slowed schedule.
        let rel_at = SimTime::ns((1_000_000.0 * tail * 0.2) as u64);
        let timers = g.release_comm_sms(2, rel_at);
        assert_eq!(timers.len(), 1);
        assert!(timers[0].at < t0.at);
        assert_eq!(g.try_finish(id, timers[0].gen, timers[0].at), Some(7));
    }

    #[test]
    fn sm_utilization_accounting() {
        let mut g = gpu();
        let _ = g.acquire_comm_sms(2, SimTime::ZERO);
        let _ = g.release_comm_sms(2, SimTime::ms(10));
        // 2 SMs for 10ms out of 132 SMs × 20ms.
        let u = g.comm_sm_utilization(SimTime::ms(20));
        let expect = (2.0 * 10.0) / (132.0 * 20.0);
        assert!((u - expect).abs() < 1e-9, "u={u} expect={expect}");
    }

    #[test]
    fn nested_acquire_release() {
        let mut g = gpu();
        let _ = g.acquire_comm_sms(2, SimTime::ZERO);
        let _ = g.acquire_comm_sms(1, SimTime::us(1));
        assert_eq!(g.comm_sms(), 3);
        let _ = g.release_comm_sms(2, SimTime::us(2));
        assert_eq!(g.comm_sms(), 1);
        let _ = g.release_comm_sms(1, SimTime::us(3));
        assert_eq!(g.comm_sms(), 0);
    }

    #[test]
    fn gemm_work_matches_peak() {
        let g = gpu();
        // 989 TFLOPS peak, 50% efficiency → 1e12 FLOP ≈ 2.022 ms.
        let ns = g.gemm_work_ns(1e12, 0.5);
        assert!((ns as f64 / 1e6 - 2.022).abs() < 0.01, "ns={ns}");
    }

    #[test]
    fn projected_matches_tail() {
        let mut g = gpu();
        assert_eq!(g.projected_ns(1000), 1000);
        let _ = g.acquire_comm_sms(2, SimTime::ZERO);
        let t = g.tail_factor(2);
        assert_eq!(g.projected_ns(1000), (1000.0 * t).ceil() as u64);
    }
}
