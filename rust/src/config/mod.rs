//! Configuration system.
//!
//! Three layers, later wins:
//!   1. built-in defaults matching the paper's cluster (Table 3 + §4 setup),
//!   2. a JSON config file (`--config cluster.json`),
//!   3. `VCCL_*` / `ICCL_*` environment variables — the paper's knobs
//!      (`ICCL_IB_TIMEOUT`, `ICCL_IB_RETRY_CNT`, ...) are honoured verbatim.
//!
//! The env-var layer exists because the paper's §5 lessons are mostly about
//! env-var misconfiguration; the experiment harness exercises the same
//! surface (`vccl exp hostfunc` flips `VCCL_ORDERING=hostfunc`, etc).
//!
//! Every key, its default and the paper knob it maps to is documented in
//! docs/CONFIG.md.

mod env;

pub use env::apply_env;


use crate::util::Gbps;

/// Which transport implements P2P primitives (§3.2 and baselines).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transport {
    /// NCCL baseline: kernel-based P2P occupying SMs for the op duration,
    /// GPU↔CPU shared-flag polling, staged copies through chunk buffers.
    Kernel,
    /// NCCLX-like ablation: SM-free data path but a persistent 1-SM ordering
    /// kernel held while the op is in flight (Fig 11's −1.73 % baseline).
    NcclxLike,
    /// VCCL: fully SM-free — zero-copy / copy-engine data movement, CPU
    /// proxy control, writeValue/waitValue stream ordering.
    SmFree,
}

impl Transport {
    pub fn name(&self) -> &'static str {
        match self {
            Transport::Kernel => "nccl-kernel",
            Transport::NcclxLike => "ncclx-like",
            Transport::SmFree => "vccl-smfree",
        }
    }
}

/// How CUDA-stream ordering is enforced when no kernel is on the stream
/// (§3.2-3): hostFunc callbacks (can deadlock — Fig 5) vs stream memory ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamOrdering {
    /// `cudaLaunchHostFunc`: callbacks from independent streams may be
    /// serialized on one host thread → bidirectional 1F1B deadlock.
    HostFunc,
    /// `cuStreamWriteValue`/`cuStreamWaitValue`: stream-native, no host
    /// callback thread, no serialization-induced deadlock.
    WriteValue,
}

/// GPU model parameters (Hopper-class defaults; Appendix A/E numbers).
#[derive(Debug, Clone)]
pub struct GpuConfig {
    /// SMs per GPU (H800/H100: 132).
    pub num_sms: u32,
    /// Dense BF16 throughput per GPU at 100 % MXU/TensorCore utilization
    /// (TFLOPS). Used by the GEMM wave model.
    pub peak_tflops: f64,
    /// Copy engines per GPU.
    pub num_copy_engines: u32,
    /// NVLink per-direction bandwidth per GPU (Gbps). Hopper: 900 GB/s
    /// aggregate bidirectional NVLink ≈ 3600 Gbps per direction.
    pub nvlink_gbps: f64,
    /// Efficiency of SM-driven intra-node copies relative to link peak.
    /// Copy engines issue wider transactions (§4.1: +7 % large-message BW).
    pub sm_copy_efficiency: f64,
    /// Efficiency of copy-engine-driven copies relative to link peak.
    pub ce_copy_efficiency: f64,
    /// Fixed cost to launch a kernel (ns).
    pub kernel_launch_ns: u64,
    /// Copy-engine request setup latency (ns) — the reason small-message
    /// intra-node latency is *worse* under VCCL (§4.1).
    pub copy_engine_setup_ns: u64,
    /// GPU↔CPU shared-flag polling interval for the NCCL-baseline proxy (ns).
    pub gpu_cpu_poll_ns: u64,
    /// Per-SM slowdown of co-resident GEMM blocks when a communication
    /// kernel shares the SM (Appendix E: 20 comm warps vs 12 GEMM warps
    /// compete for issue slots).
    pub coresidency_slowdown: f64,
    /// HBM bandwidth used by staging copies between application and chunk
    /// buffers (Gbps). H800-class: ~3.3 TB/s.
    pub hbm_gbps: f64,
    /// Effective throughput of the SM reduction kernel in ring collectives
    /// (Gbps) — HBM-bound, well below peak.
    pub reduce_gbps: f64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        GpuConfig {
            num_sms: 132,
            peak_tflops: 989.0,
            num_copy_engines: 3,
            nvlink_gbps: 3600.0,
            sm_copy_efficiency: 0.87,
            ce_copy_efficiency: 0.93,
            kernel_launch_ns: 1_500,
            copy_engine_setup_ns: 4_000,
            gpu_cpu_poll_ns: 1_200,
            coresidency_slowdown: 1.6,
            hbm_gbps: 26_400.0,
            reduce_gbps: 4_800.0,
        }
    }
}

/// Network / RDMA parameters (ConnectX-7-class defaults, §4 cluster).
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Per-port line rate (Gbps).
    pub link_gbps: f64,
    /// One-way propagation + switching latency per hop (ns).
    pub hop_latency_ns: u64,
    /// NIC processing latency per WR (doorbell → wire) (ns).
    pub nic_latency_ns: u64,
    /// RDMA_READ/WRITE payload efficiency on the wire (headers, DCQCN).
    pub wire_efficiency: f64,
    /// IB transport retry timeout exponent: timeout = 4.096 μs × 2^N
    /// (Table 3: ICCL_IB_TIMEOUT=18 → ≈1.07 s per retry).
    pub ib_timeout_exp: u32,
    /// Retry count before the QP enters error state (Table 3: 7).
    pub ib_retry_cnt: u32,
    /// PCIe host↔device bandwidth per GPU (Gbps) — bounds GDR when the
    /// buffer is not NIC-local (PXN motivation).
    pub pcie_gbps: f64,
    /// Incast degradation: when >1 flows converge on one egress port the
    /// effective goodput is scaled by this factor per extra flow (models
    /// the PFC backpressure / congestion collapse of Fig 18 phase 2).
    pub incast_penalty: f64,
    /// QP hardware warm-up time after RESET→RTS before full-rate service
    /// (§3.3 recovery: "often on the order of seconds").
    pub qp_warmup_ns: u64,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            link_gbps: 400.0,
            hop_latency_ns: 1_000,
            nic_latency_ns: 2_500,
            wire_efficiency: 0.97,
            ib_timeout_exp: 18,
            ib_retry_cnt: 7,
            pcie_gbps: 512.0,
            incast_penalty: 0.35,
            qp_warmup_ns: 1_500_000_000,
        }
    }
}

impl NetConfig {
    /// The per-attempt retransmission timeout: 4.096 μs × 2^exp.
    pub fn retry_timeout_ns(&self) -> u64 {
        (4_096.0 * 2f64.powi(self.ib_timeout_exp as i32)) as u64
    }

    /// Total time the hardware retries before reporting a WC error
    /// (retry_cnt attempts). The paper's Fig 13a shows ~10 s of silence
    /// with TIMEOUT=18, RETRY=7 — but notes about half of flaps recover
    /// within the window, so the window is intentional.
    pub fn retry_window_ns(&self) -> u64 {
        self.retry_timeout_ns() * self.ib_retry_cnt as u64
    }

    pub fn link(&self) -> Gbps {
        Gbps(self.link_gbps)
    }
}

/// Cluster shape (§4: 8 GPUs + 8 rail NICs (+1 mgmt) per server, two-tier
/// rail-optimized CLOS, 1:1 oversubscription).
#[derive(Debug, Clone)]
pub struct TopologyConfig {
    pub num_nodes: usize,
    pub gpus_per_node: usize,
    pub nics_per_node: usize,
    /// NICs with two physical ports (backup QP placement uses the second
    /// port of the same NIC when available — §3.3).
    pub dual_port_nics: bool,
    /// Leaf switches per rail group; spine count derives from 1:1
    /// oversubscription.
    pub rails: usize,
}

impl Default for TopologyConfig {
    fn default() -> Self {
        TopologyConfig {
            num_nodes: 2,
            gpus_per_node: 8,
            nics_per_node: 8,
            dual_port_nics: false,
            rails: 8,
        }
    }
}

/// VCCL feature switches + tunables (the paper's Table 3 "VCCL settings").
#[derive(Debug, Clone)]
pub struct VcclConfig {
    pub transport: Transport,
    pub ordering: StreamOrdering,
    /// Primary-backup QP fault tolerance (§3.3).
    pub fault_tolerance: bool,
    /// Window-based monitor (§3.4).
    pub monitor: bool,
    /// Monitor sliding-window size in messages (Table 3: 8).
    pub window_size: usize,
    /// Anomaly heuristic: bandwidth drop threshold vs trailing average.
    pub bw_drop_ratio: f64,
    /// Anomaly heuristic: remaining-to-send multiple of historical max.
    pub rts_multiple: f64,
    /// Trailing-average horizon for the pinpointer (ns; paper: ~10 ms).
    pub trailing_ns: u64,
    /// Case-2 double-check δ: slightly larger than the retry timeout.
    pub delta_margin: f64,
    /// Channels per communicator (Table 3 CC traffic generator: 32; the
    /// 1024-GPU accounting in §4.2 uses 16).
    pub channels: usize,
    /// Chunk size per channel slot.
    pub chunk_bytes: u64,
    /// Lazy 2 MB-aligned memory pool instead of eager pre-allocation (§4.4).
    pub lazy_mempool: bool,
    /// Zero-copy user-buffer registration for P2P (§3.2, §4.4).
    pub zero_copy: bool,
}

impl Default for VcclConfig {
    fn default() -> Self {
        VcclConfig {
            transport: Transport::SmFree,
            ordering: StreamOrdering::WriteValue,
            fault_tolerance: true,
            monitor: true,
            window_size: 8,
            bw_drop_ratio: 0.5,
            rts_multiple: 2.0,
            trailing_ns: 10_000_000,
            delta_margin: 1.25,
            channels: 16,
            chunk_bytes: 1 << 20,
            lazy_mempool: true,
            zero_copy: true,
        }
    }
}

/// Root-cause analysis settings (`rca.*`, see `rust/src/rca/`). These
/// shape the diagnosis (candidate ranking), never the simulation.
#[derive(Debug, Clone)]
pub struct RcaConfig {
    /// Ranked root-cause candidates kept per symptom.
    pub max_candidates: usize,
    /// Score weight of causal proximity: `hop_weight / (1 + hops)`.
    pub hop_weight: f64,
    /// Score weight of temporal proximity to the fault-window open.
    pub time_weight: f64,
    /// Half-weight point of the temporal term, in ms of fault→symptom lag.
    pub time_decay_ms: f64,
    /// Slack after a fault window closes during which lagging symptoms
    /// (retry expiries, trailing verdicts) still attribute to it.
    pub grace_ms: f64,
}

impl Default for RcaConfig {
    fn default() -> Self {
        RcaConfig {
            max_candidates: 3,
            hop_weight: 100.0,
            time_weight: 50.0,
            time_decay_ms: 250.0,
            grace_ms: 100.0,
        }
    }
}

/// Flight-recorder settings (`trace.*`, see `rust/src/trace/`).
#[derive(Debug, Clone)]
pub struct TraceConfig {
    /// Record cross-layer trace events. Off by default: a disabled tracer
    /// allocates nothing and costs one branch per would-be event.
    pub enabled: bool,
    /// Bounded ring capacity in events; older events are dropped (counted).
    pub ring_capacity: usize,
    /// Trailing window frozen into an incident snapshot when an anomaly is
    /// flagged (pinpointer non-healthy verdict, failover migration).
    pub snapshot_window_ns: u64,
    /// Shared recorder installed by `vccl trace` so every simulation built
    /// from this config records into one ring. Not settable from config
    /// files or env vars; `Config::clone` shares it by design.
    pub sink: Option<crate::trace::TraceSink>,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            enabled: false,
            ring_capacity: 1 << 16,
            snapshot_window_ns: 2_000_000_000,
            sink: None,
        }
    }
}

/// Time-compressed soak harness settings (`soak.*`, see `rust/src/soak/`).
///
/// Deliberately excluded from the checkpoint config fingerprint: soak knobs
/// shape the *driver* (how long to run, when to checkpoint), not the
/// simulated cluster, so a resumed soak may change its slice length or
/// checkpoint cadence without invalidating the saved sim state.
#[derive(Debug, Clone)]
pub struct SoakConfig {
    /// Mean time between injected faults, in simulated hours. Fault
    /// inter-arrivals are exponential (Poisson process) at this mean.
    pub mtbf_hours: f64,
    /// Mean time to repair, in simulated seconds: how long an injected
    /// fault persists before the harness heals it.
    pub mttr_s: f64,
    /// Simulated duration of the whole soak, in days.
    pub sim_days: f64,
    /// Checkpoint the full sim state every N traffic bursts (0 = never).
    pub checkpoint_every: u64,
    /// Relative weight of trunk-capacity degrades in the fault mix
    /// (§Fault domains). 0 (the default) keeps the pre-fabric mix of port
    /// flaps and NIC-uplink degrades only.
    pub trunk_weight: u32,
    /// Relative weight of whole-switch (leaf) outages in the fault mix.
    pub switch_weight: u32,
    /// Relative weight of whole-node crashes in the fault mix (§Elastic).
    /// 0 (the default) keeps the PR-8 mix; a crash downs every port of a
    /// victim node for one MTTR and the cluster shrinks around it.
    pub node_weight: u32,
    /// Topology preset the soak drives: "burst" (the default 2-node
    /// paper cluster) or "scale64" (the 64-node scaling preset with the
    /// soak's shortened failure time constants). Like the other soak
    /// knobs this shapes the driver, not a running sim, so it is
    /// excluded from the checkpoint config fingerprint — but a resumed
    /// soak still validates it against the saved topology.
    pub preset: String,
}

impl Default for SoakConfig {
    fn default() -> Self {
        SoakConfig {
            mtbf_hours: 4.0,
            mttr_s: 30.0,
            sim_days: 1.0,
            checkpoint_every: 8,
            trunk_weight: 0,
            switch_weight: 0,
            node_weight: 0,
            preset: String::from("burst"),
        }
    }
}

/// Elastic membership settings (`elastic.*`, §Elastic): node-crash
/// detection escalation and communicator shrink/rejoin.
#[derive(Debug, Clone)]
pub struct ElasticConfig {
    /// Escalate all-ports-down peers to a node-dead perception and
    /// shrink the communicator around them. Off = a node crash strands
    /// its rings exactly like pre-elastic builds (ops hang).
    pub enabled: bool,
    /// Delay between aborting a crossing op's in-flight step and
    /// re-issuing it on the rebuilt ring (models the bootstrap
    /// re-rendezvous round of a communicator shrink).
    pub requeue_delay_ns: u64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig { enabled: true, requeue_delay_ns: 1_000_000 }
    }
}

/// Event-engine settings (`engine.*`, §Perf L6). These tune the scheduler,
/// never the modeled physics: any combination produces the same trajectory
/// (the randomized equivalence tests pin it), only at different speeds.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Calendar-queue bucket width in nanoseconds (clamped to [64, 1 MiB]
    /// and rounded up to a power of two). ~4 µs matches the cluster sim's
    /// per-chunk event spacing; widen it for sparser workloads.
    pub bucket_ns: u64,
    /// Flow-level fast-forward tier: between two engine events, locally
    /// generated follow-up events (chunk completions, WCs, GPU tasks) are
    /// drained from a small local buffer instead of round-tripping through
    /// the global queue. Observable output is bit-identical either way
    /// (`randomized_equivalence_fast_forward_vs_evented` pins it); only
    /// engine work counters differ. Off by default; the `scale4k` preset
    /// turns it on.
    pub fast_forward: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { bucket_ns: crate::sim::DEFAULT_BUCKET_NS, fast_forward: false }
    }
}

/// Top-level configuration.
#[derive(Debug, Clone, Default)]
pub struct Config {
    pub gpu: GpuConfig,
    pub net: NetConfig,
    pub topo: TopologyConfig,
    pub vccl: VcclConfig,
    pub trace: TraceConfig,
    pub rca: RcaConfig,
    pub soak: SoakConfig,
    pub elastic: ElasticConfig,
    pub engine: EngineConfig,
    /// RNG seed for all stochastic elements.
    pub seed: u64,
}

impl Config {
    /// Paper-default configuration (Table 3 + §4 cluster description).
    pub fn paper_defaults() -> Self {
        Config { seed: 0x5CC1, ..Default::default() }
    }

    /// NCCL-baseline configuration: kernel transport, no backup QPs, eager
    /// buffers, monitor off.
    pub fn nccl_baseline() -> Self {
        let mut c = Self::paper_defaults();
        c.vccl.transport = Transport::Kernel;
        c.vccl.fault_tolerance = false;
        c.vccl.monitor = false;
        c.vccl.lazy_mempool = false;
        c.vccl.zero_copy = false;
        c
    }

    /// 64-node scaling preset (§Perf L3, the `scale64` experiment): the
    /// paper cluster widened to 64 nodes (512 GPUs), one channel, monitor
    /// off, and a shortened retry window + warm-up so the failover sweep
    /// completes in bounded sim time. Only tractable with the incremental
    /// component-scoped flow allocator — the global O(links × flows)
    /// reference re-rates every flow on each of the ~10⁶ network changes.
    pub fn scale64() -> Self {
        let mut c = Self::paper_defaults();
        c.topo.num_nodes = 64;
        c.vccl.channels = 1;
        c.vccl.monitor = false;
        c.net.ib_timeout_exp = 10;
        c.net.ib_retry_cnt = 2;
        c.net.qp_warmup_ns = 100_000_000;
        c
    }

    /// 256-node scaling preset (§Perf L4, the `scale256` experiment):
    /// `scale64` widened to 256 nodes (2048 GPUs) — and, unlike `scale64`,
    /// the in-band monitor stays ON: its per-WC remaining-to-send read is
    /// an O(1) counter lookup now (`RdmaNet::port_backlog_bytes`), so the
    /// §3.4 observability pillar is affordable at the scale the paper's
    /// reliability results actually live in. Only tractable with both the
    /// incremental flow allocator (§Perf L3) and the O(1) RDMA accounting
    /// (§Perf L4) — the pre-L4 scans cost O(QPs) per WC and per flap.
    pub fn scale256() -> Self {
        let mut c = Self::scale64();
        c.topo.num_nodes = 256;
        c.vccl.monitor = true;
        c
    }

    /// 512-node scaling preset (§Perf L5, the `scale512` experiment):
    /// `scale256` widened to 512 nodes (4096 GPUs), monitor still ON. A
    /// scale512 ring AllReduce creates ~33.5M chunked transfers; before
    /// §Perf L5 every one stayed resident in `ClusterSim::xfers` forever
    /// (memory was the post-L4 256-node ceiling — ~8.4M records per
    /// scale256 AllReduce), so this preset is only tractable with the
    /// recycling transfer slab holding O(active) ≈ one record per rank.
    pub fn scale512() -> Self {
        let mut c = Self::scale256();
        c.topo.num_nodes = 512;
        c
    }

    /// 4096-node scaling preset (§Perf L6, the `scale4k` experiment): a
    /// *rail slice* of a 4096-node cluster — one GPU + one dual-port NIC
    /// per node (rail 0 of the paper's 8-rail fabric), 4096 ranks in one
    /// ring. Unlike scale512's 8-GPU nodes (7/8 of ring hops intra-node),
    /// every hop here is inter-node RDMA, so this is the densest network
    /// workload per rank the sim runs. Only tractable with the §Perf L6
    /// calendar-queue engine + fast-forward tier; the full 8-rail 32768-
    /// rank cluster (ring transfers grow with ranks²) stays future work
    /// for the sharded-engine stretch goal.
    pub fn scale4k() -> Self {
        let mut c = Self::scale512();
        c.topo.num_nodes = 4096;
        c.topo.gpus_per_node = 1;
        c.topo.nics_per_node = 1;
        c.topo.rails = 1;
        // Backup QPs ride the second port of the same NIC (§3.3).
        c.topo.dual_port_nics = true;
        c.engine.fast_forward = true;
        c
    }

    /// Soak preset (§Soak, the `vccl soak` harness): the paper cluster with
    /// one channel and the `scale64` shortened failure time constants, so an
    /// MTBF-driven flap schedule detects, fails over and fails back well
    /// within a simulated-minutes traffic burst. Monitor stays ON — the soak
    /// report grades its verdicts against injected ground truth. NICs are
    /// dual-port so a failed-over connection rides the *same* NIC's second
    /// port instead of a neighbouring GPU's NIC: the neighbour's port would
    /// then carry two flows at half rate each, which the pinpointer would
    /// (correctly, but unhelpfully for grading) flag on a fault-free port.
    pub fn soak_defaults() -> Self {
        let mut c = Self::paper_defaults();
        c.vccl.channels = 1;
        c.net.ib_timeout_exp = 10;
        c.net.ib_retry_cnt = 2;
        c.net.qp_warmup_ns = 100_000_000;
        c.topo.dual_port_nics = true;
        // The pinpointer's trailing baseline must span the ~60 s idle gap
        // between bursts (two periods), or every burst would start from a
        // cold baseline and a degraded link would read as "normal".
        c.vccl.trailing_ns = 120_000_000_000;
        c
    }

    /// NCCLX-like configuration (SM-free data path + 1-SM ordering kernel).
    pub fn ncclx_like() -> Self {
        let mut c = Self::paper_defaults();
        c.vccl.transport = Transport::NcclxLike;
        c.vccl.fault_tolerance = false;
        c.vccl.monitor = false;
        c
    }

    /// Load from a `key = value` config file (dotted keys, `#` comments),
    /// then apply environment overrides.
    pub fn load(path: Option<&str>) -> anyhow::Result<Self> {
        let mut cfg = Config::paper_defaults();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow::anyhow!("reading config {p}: {e}"))?;
            cfg.apply_kv_text(&text)?;
        }
        apply_env(&mut cfg, |k| std::env::var(k).ok());
        Ok(cfg)
    }

    /// Apply `section.key = value` lines. Unknown keys are an error — a
    /// silently ignored typo is exactly the §5 failure mode we refuse.
    pub fn apply_kv_text(&mut self, text: &str) -> anyhow::Result<()> {
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("line {}: expected key = value", lineno + 1))?;
            self.set_key(k.trim(), v.trim())
                .map_err(|e| anyhow::anyhow!("line {}: {}", lineno + 1, e))?;
        }
        Ok(())
    }

    /// Set one dotted key. Public so the CLI's `--set k=v` flag reuses it.
    pub fn set_key(&mut self, key: &str, val: &str) -> anyhow::Result<()> {
        fn p<T: std::str::FromStr>(v: &str) -> anyhow::Result<T>
        where
            T::Err: std::fmt::Display,
        {
            v.parse::<T>().map_err(|e| anyhow::anyhow!("bad value {v:?}: {e}"))
        }
        fn pb(v: &str) -> anyhow::Result<bool> {
            match v.to_ascii_lowercase().as_str() {
                "1" | "true" | "yes" | "on" => Ok(true),
                "0" | "false" | "no" | "off" => Ok(false),
                other => Err(anyhow::anyhow!("bad bool {other:?}")),
            }
        }
        match key {
            "seed" => self.seed = p(val)?,
            "gpu.num_sms" => self.gpu.num_sms = p(val)?,
            "gpu.peak_tflops" => self.gpu.peak_tflops = p(val)?,
            "gpu.num_copy_engines" => self.gpu.num_copy_engines = p(val)?,
            "gpu.nvlink_gbps" => self.gpu.nvlink_gbps = p(val)?,
            "gpu.sm_copy_efficiency" => self.gpu.sm_copy_efficiency = p(val)?,
            "gpu.ce_copy_efficiency" => self.gpu.ce_copy_efficiency = p(val)?,
            "gpu.kernel_launch_ns" => self.gpu.kernel_launch_ns = p(val)?,
            "gpu.copy_engine_setup_ns" => self.gpu.copy_engine_setup_ns = p(val)?,
            "gpu.gpu_cpu_poll_ns" => self.gpu.gpu_cpu_poll_ns = p(val)?,
            "gpu.coresidency_slowdown" => self.gpu.coresidency_slowdown = p(val)?,
            "gpu.hbm_gbps" => self.gpu.hbm_gbps = p(val)?,
            "gpu.reduce_gbps" => self.gpu.reduce_gbps = p(val)?,
            "net.link_gbps" => self.net.link_gbps = p(val)?,
            "net.hop_latency_ns" => self.net.hop_latency_ns = p(val)?,
            "net.nic_latency_ns" => self.net.nic_latency_ns = p(val)?,
            "net.wire_efficiency" => self.net.wire_efficiency = p(val)?,
            "net.ib_timeout_exp" => self.net.ib_timeout_exp = p(val)?,
            "net.ib_retry_cnt" => self.net.ib_retry_cnt = p(val)?,
            "net.pcie_gbps" => self.net.pcie_gbps = p(val)?,
            "net.incast_penalty" => self.net.incast_penalty = p(val)?,
            "net.qp_warmup_ns" => self.net.qp_warmup_ns = p(val)?,
            "topo.num_nodes" => self.topo.num_nodes = p(val)?,
            "topo.gpus_per_node" => self.topo.gpus_per_node = p(val)?,
            "topo.nics_per_node" => self.topo.nics_per_node = p(val)?,
            "topo.dual_port_nics" => self.topo.dual_port_nics = pb(val)?,
            "topo.rails" => self.topo.rails = p(val)?,
            "vccl.transport" => {
                self.vccl.transport = match val {
                    "kernel" | "nccl" => Transport::Kernel,
                    "ncclx" => Transport::NcclxLike,
                    "smfree" | "vccl" => Transport::SmFree,
                    other => anyhow::bail!("unknown transport {other:?}"),
                }
            }
            "vccl.ordering" => {
                self.vccl.ordering = match val {
                    "hostfunc" => StreamOrdering::HostFunc,
                    "writevalue" | "waitvalue" => StreamOrdering::WriteValue,
                    other => anyhow::bail!("unknown ordering {other:?}"),
                }
            }
            "vccl.fault_tolerance" => self.vccl.fault_tolerance = pb(val)?,
            "vccl.monitor" => self.vccl.monitor = pb(val)?,
            "vccl.window_size" => self.vccl.window_size = p(val)?,
            "vccl.bw_drop_ratio" => self.vccl.bw_drop_ratio = p(val)?,
            "vccl.rts_multiple" => self.vccl.rts_multiple = p(val)?,
            "vccl.trailing_ns" => self.vccl.trailing_ns = p(val)?,
            "vccl.delta_margin" => self.vccl.delta_margin = p(val)?,
            "vccl.channels" => self.vccl.channels = p(val)?,
            "vccl.chunk_bytes" => self.vccl.chunk_bytes = p(val)?,
            "vccl.lazy_mempool" => self.vccl.lazy_mempool = pb(val)?,
            "vccl.zero_copy" => self.vccl.zero_copy = pb(val)?,
            "soak.mtbf_hours" => self.soak.mtbf_hours = p(val)?,
            "soak.mttr_s" => self.soak.mttr_s = p(val)?,
            "soak.sim_days" => self.soak.sim_days = p(val)?,
            "soak.checkpoint_every" => self.soak.checkpoint_every = p(val)?,
            "soak.trunk_weight" => self.soak.trunk_weight = p(val)?,
            "soak.switch_weight" => self.soak.switch_weight = p(val)?,
            "soak.node_weight" => self.soak.node_weight = p(val)?,
            "soak.preset" => match val {
                "burst" | "scale64" => self.soak.preset = val.to_string(),
                other => anyhow::bail!("unknown soak preset {other:?}"),
            },
            "elastic.enabled" => self.elastic.enabled = pb(val)?,
            "elastic.requeue_delay_ns" => self.elastic.requeue_delay_ns = p(val)?,
            "engine.bucket_ns" => self.engine.bucket_ns = p(val)?,
            "engine.fast_forward" => self.engine.fast_forward = pb(val)?,
            "trace.enabled" => self.trace.enabled = pb(val)?,
            "trace.ring_capacity" => self.trace.ring_capacity = p(val)?,
            "trace.snapshot_window_ns" => self.trace.snapshot_window_ns = p(val)?,
            "rca.max_candidates" => self.rca.max_candidates = p(val)?,
            "rca.hop_weight" => self.rca.hop_weight = p(val)?,
            "rca.time_weight" => self.rca.time_weight = p(val)?,
            "rca.time_decay_ms" => self.rca.time_decay_ms = p(val)?,
            "rca.grace_ms" => self.rca.grace_ms = p(val)?,
            other => anyhow::bail!("unknown config key {other:?}"),
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn retry_window_matches_paper_order_of_magnitude() {
        // TIMEOUT=18, RETRY_CNT=7 → per-attempt ≈ 1.07 s, window ≈ 7.5 s.
        // Fig 13a narrates "about 10 s" of silence before failover.
        let net = NetConfig::default();
        let w = net.retry_window_ns() as f64 / 1e9;
        assert!((6.0..12.0).contains(&w), "window={w}s");
    }

    #[test]
    fn presets_differ_as_expected() {
        let v = Config::paper_defaults();
        let n = Config::nccl_baseline();
        let x = Config::ncclx_like();
        assert_eq!(v.vccl.transport, Transport::SmFree);
        assert_eq!(n.vccl.transport, Transport::Kernel);
        assert_eq!(x.vccl.transport, Transport::NcclxLike);
        assert!(v.vccl.fault_tolerance && !n.vccl.fault_tolerance);
        assert!(v.vccl.zero_copy && !n.vccl.zero_copy);
    }

    #[test]
    fn scale_presets_widen_the_cluster() {
        let s64 = Config::scale64();
        let s256 = Config::scale256();
        let s512 = Config::scale512();
        assert_eq!(s64.topo.num_nodes, 64);
        assert_eq!(s256.topo.num_nodes, 256);
        assert_eq!(s512.topo.num_nodes, 512);
        assert_eq!(s256.topo.gpus_per_node * s256.topo.num_nodes, 2048);
        assert_eq!(s512.topo.gpus_per_node * s512.topo.num_nodes, 4096);
        // scale64 predates the O(1) backlog counter and turns the monitor
        // off; scale256 exists to show the monitor is affordable at scale,
        // and scale512 keeps it on while §Perf L5 recycles the transfers.
        assert!(!s64.vccl.monitor && s256.vccl.monitor && s512.vccl.monitor);
        // All shrink the failure machinery's time constants identically.
        assert_eq!(s64.net.ib_timeout_exp, s256.net.ib_timeout_exp);
        assert_eq!(s64.net.ib_timeout_exp, s512.net.ib_timeout_exp);
        assert_eq!(s64.net.qp_warmup_ns, s256.net.qp_warmup_ns);
        assert_eq!(s64.net.qp_warmup_ns, s512.net.qp_warmup_ns);
        assert_eq!(s64.vccl.channels, 1);
        assert_eq!(s256.vccl.channels, 1);
        assert_eq!(s512.vccl.channels, 1);

        // scale4k is a rail slice: 4096 single-GPU nodes, all-RDMA ring,
        // backup QPs on the second port of each node's only NIC, and the
        // §Perf L6 fast-forward tier on.
        let s4k = Config::scale4k();
        assert_eq!(s4k.topo.num_nodes, 4096);
        assert_eq!(s4k.topo.gpus_per_node, 1);
        assert_eq!(s4k.topo.nics_per_node, 1);
        assert_eq!(s4k.topo.rails, 1);
        assert!(s4k.topo.dual_port_nics);
        assert!(s4k.vccl.monitor, "the monitor stays on at 4096 nodes");
        assert!(s4k.engine.fast_forward);
        assert_eq!(s4k.net.ib_timeout_exp, s64.net.ib_timeout_exp);
        assert_eq!(s4k.net.qp_warmup_ns, s64.net.qp_warmup_ns);
    }

    #[test]
    fn kv_text_applies_and_rejects_unknown() {
        let mut c = Config::paper_defaults();
        c.apply_kv_text(
            "# comment\n\
             net.link_gbps = 200\n\
             vccl.window_size = 16  # inline comment\n\
             vccl.transport = kernel\n\
             topo.dual_port_nics = true\n",
        )
        .unwrap();
        assert_eq!(c.net.link_gbps, 200.0);
        assert_eq!(c.vccl.window_size, 16);
        assert_eq!(c.vccl.transport, Transport::Kernel);
        assert!(c.topo.dual_port_nics);
        // Typos are hard errors (§5 lesson: silent misconfig is fatal).
        assert!(c.apply_kv_text("vccl.windowsize = 8").is_err());
        assert!(c.apply_kv_text("vccl.transport = quantum").is_err());
        assert!(c.apply_kv_text("not a kv line").is_err());
    }

    #[test]
    fn engine_keys_parse_and_default_to_evented() {
        let mut c = Config::paper_defaults();
        assert_eq!(c.engine.bucket_ns, crate::sim::DEFAULT_BUCKET_NS);
        assert!(!c.engine.fast_forward, "fast-forward is opt-in (scale4k turns it on)");
        c.apply_kv_text(
            "engine.bucket_ns = 8192\n\
             engine.fast_forward = on\n",
        )
        .unwrap();
        assert_eq!(c.engine.bucket_ns, 8192);
        assert!(c.engine.fast_forward);
        assert!(c.apply_kv_text("engine.bogus = 1").is_err());
    }

    #[test]
    fn set_key_parses_all_sections() {
        let mut c = Config::paper_defaults();
        c.set_key("gpu.num_sms", "78").unwrap();
        c.set_key("net.ib_timeout_exp", "14").unwrap();
        c.set_key("topo.num_nodes", "4").unwrap();
        c.set_key("seed", "99").unwrap();
        assert_eq!((c.gpu.num_sms, c.net.ib_timeout_exp, c.topo.num_nodes, c.seed), (78, 14, 4, 99));
    }

    #[test]
    fn soak_keys_parse_and_preset_shrinks_time_constants() {
        let mut c = Config::paper_defaults();
        c.apply_kv_text(
            "soak.mtbf_hours = 0.5\n\
             soak.mttr_s = 10\n\
             soak.sim_days = 2.5\n\
             soak.checkpoint_every = 4\n\
             soak.trunk_weight = 2\n\
             soak.switch_weight = 3\n",
        )
        .unwrap();
        assert_eq!(c.soak.mtbf_hours, 0.5);
        assert_eq!(c.soak.mttr_s, 10.0);
        assert_eq!(c.soak.sim_days, 2.5);
        assert_eq!(c.soak.checkpoint_every, 4);
        assert_eq!(c.soak.trunk_weight, 2);
        assert_eq!(c.soak.switch_weight, 3);
        assert!(c.apply_kv_text("soak.bogus = 1").is_err());

        let s = Config::soak_defaults();
        assert_eq!(s.vccl.channels, 1);
        assert!(s.vccl.monitor, "soak grades the monitor: it must be on");
        assert!(s.topo.dual_port_nics, "failover must not share a neighbour's port");
        // Same shortened failure machinery as the scaling presets.
        let s64 = Config::scale64();
        assert_eq!(s.net.ib_timeout_exp, s64.net.ib_timeout_exp);
        assert_eq!(s.net.ib_retry_cnt, s64.net.ib_retry_cnt);
        assert_eq!(s.net.qp_warmup_ns, s64.net.qp_warmup_ns);
    }

    #[test]
    fn elastic_keys_parse_and_node_soak_knobs_default_off() {
        let mut c = Config::paper_defaults();
        assert!(c.elastic.enabled, "elastic shrink must be on by default");
        assert_eq!(c.soak.node_weight, 0, "node crashes are opt-in");
        assert_eq!(c.soak.preset, "burst");
        c.apply_kv_text(
            "soak.node_weight = 2\n\
             soak.preset = scale64\n\
             elastic.enabled = off\n\
             elastic.requeue_delay_ns = 5000000\n",
        )
        .unwrap();
        assert_eq!(c.soak.node_weight, 2);
        assert_eq!(c.soak.preset, "scale64");
        assert!(!c.elastic.enabled);
        assert_eq!(c.elastic.requeue_delay_ns, 5_000_000);
        assert!(c.apply_kv_text("soak.preset = mesh").is_err());
        assert!(c.apply_kv_text("elastic.bogus = 1").is_err());
    }

    #[test]
    fn trace_keys_parse_and_default_off() {
        let mut c = Config::paper_defaults();
        assert!(!c.trace.enabled, "tracing must be opt-in");
        assert!(c.trace.sink.is_none());
        c.apply_kv_text(
            "trace.enabled = true\n\
             trace.ring_capacity = 1024\n\
             trace.snapshot_window_ns = 5000000\n",
        )
        .unwrap();
        assert!(c.trace.enabled);
        assert_eq!(c.trace.ring_capacity, 1024);
        assert_eq!(c.trace.snapshot_window_ns, 5_000_000);
        assert!(c.apply_kv_text("trace.bogus = 1").is_err());
    }

    #[test]
    fn rca_keys_parse_and_have_sane_defaults() {
        let mut c = Config::paper_defaults();
        assert_eq!(c.rca.max_candidates, 3);
        assert!(c.rca.hop_weight > 0.0 && c.rca.time_weight > 0.0);
        c.apply_kv_text(
            "rca.max_candidates = 5\n\
             rca.hop_weight = 80\n\
             rca.time_weight = 40\n\
             rca.time_decay_ms = 500\n\
             rca.grace_ms = 250\n",
        )
        .unwrap();
        assert_eq!(c.rca.max_candidates, 5);
        assert_eq!(c.rca.hop_weight, 80.0);
        assert_eq!(c.rca.time_weight, 40.0);
        assert_eq!(c.rca.time_decay_ms, 500.0);
        assert_eq!(c.rca.grace_ms, 250.0);
        assert!(c.apply_kv_text("rca.bogus = 1").is_err());
    }
}
