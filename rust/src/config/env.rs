//! Environment-variable override layer (full table: docs/CONFIG.md).
//!
//! Honours both the paper's `ICCL_*` spelling and a `VCCL_*` alias. The
//! lookup function is injected so tests can drive overrides without touching
//! the process environment (std::env is process-global and test-parallel
//! unsafe).

use super::{Config, StreamOrdering, Transport};

/// Apply recognised environment variables onto `cfg`.
///
/// `get` abstracts `std::env::var` for testability. For each knob the
/// `ICCL_` spelling wins over `VCCL_` (the paper's §5 lesson 1 is precisely
/// about `ICCL_NET_PLUGIN` being set by accident — we at least make the
/// precedence deterministic and *log* unknown ICCL_ variables).
pub fn apply_env(cfg: &mut Config, get: impl Fn(&str) -> Option<String>) -> Vec<String> {
    let mut applied = Vec::new();
    let lookup = |name: &str| -> Option<String> {
        get(&format!("ICCL_{name}")).or_else(|| get(&format!("VCCL_{name}")))
    };

    if let Some(v) = lookup("IB_TIMEOUT").and_then(|s| s.parse().ok()) {
        cfg.net.ib_timeout_exp = v;
        applied.push(format!("IB_TIMEOUT={v}"));
    }
    if let Some(v) = lookup("IB_RETRY_CNT").and_then(|s| s.parse().ok()) {
        cfg.net.ib_retry_cnt = v;
        applied.push(format!("IB_RETRY_CNT={v}"));
    }
    if let Some(v) = lookup("WINDOW_SIZE").and_then(|s| s.parse().ok()) {
        cfg.vccl.window_size = v;
        applied.push(format!("WINDOW_SIZE={v}"));
    }
    if let Some(v) = lookup("CHANNELS").and_then(|s| s.parse().ok()) {
        cfg.vccl.channels = v;
        applied.push(format!("CHANNELS={v}"));
    }
    if let Some(v) = lookup("CHUNK_BYTES").and_then(|s| s.parse().ok()) {
        cfg.vccl.chunk_bytes = v;
        applied.push(format!("CHUNK_BYTES={v}"));
    }
    if let Some(v) = lookup("TRANSPORT") {
        match v.as_str() {
            "kernel" | "nccl" => cfg.vccl.transport = Transport::Kernel,
            "ncclx" => cfg.vccl.transport = Transport::NcclxLike,
            "smfree" | "vccl" => cfg.vccl.transport = Transport::SmFree,
            other => applied.push(format!("TRANSPORT={other} (unrecognised, ignored)")),
        }
        applied.push(format!("TRANSPORT={}", cfg.vccl.transport.name()));
    }
    if let Some(v) = lookup("ORDERING") {
        match v.as_str() {
            "hostfunc" => cfg.vccl.ordering = StreamOrdering::HostFunc,
            "writevalue" | "waitvalue" => cfg.vccl.ordering = StreamOrdering::WriteValue,
            other => applied.push(format!("ORDERING={other} (unrecognised, ignored)")),
        }
    }
    if let Some(v) = lookup("FAULT_TOLERANCE").and_then(|s| parse_bool(&s)) {
        cfg.vccl.fault_tolerance = v;
        applied.push(format!("FAULT_TOLERANCE={v}"));
    }
    if let Some(v) = lookup("MONITOR").and_then(|s| parse_bool(&s)) {
        cfg.vccl.monitor = v;
        applied.push(format!("MONITOR={v}"));
    }
    if let Some(v) = lookup("ZERO_COPY").and_then(|s| parse_bool(&s)) {
        cfg.vccl.zero_copy = v;
        applied.push(format!("ZERO_COPY={v}"));
    }
    if let Some(v) = lookup("LAZY_MEMPOOL").and_then(|s| parse_bool(&s)) {
        cfg.vccl.lazy_mempool = v;
        applied.push(format!("LAZY_MEMPOOL={v}"));
    }
    if let Some(v) = lookup("TRACE").and_then(|s| parse_bool(&s)) {
        cfg.trace.enabled = v;
        applied.push(format!("TRACE={v}"));
    }
    if let Some(v) = lookup("SEED").and_then(|s| s.parse().ok()) {
        cfg.seed = v;
        applied.push(format!("SEED={v}"));
    }
    // §5 lesson 1: loading a foreign net plugin corrupts internal structs.
    // We refuse rather than UB.
    if let Some(v) = lookup("NET_PLUGIN") {
        applied.push(format!(
            "NET_PLUGIN={v} — refusing to load foreign plugins (see §5 lesson 1); ignored"
        ));
    }
    applied
}

fn parse_bool(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "1" | "true" | "yes" | "on" => Some(true),
        "0" | "false" | "no" | "off" => Some(false),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn env_of(pairs: &[(&str, &str)]) -> HashMap<String, String> {
        pairs.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect()
    }

    #[test]
    fn iccl_vars_override_defaults() {
        let mut cfg = Config::paper_defaults();
        let env = env_of(&[("ICCL_IB_TIMEOUT", "14"), ("ICCL_IB_RETRY_CNT", "3")]);
        apply_env(&mut cfg, |k| env.get(k).cloned());
        assert_eq!(cfg.net.ib_timeout_exp, 14);
        assert_eq!(cfg.net.ib_retry_cnt, 3);
    }

    #[test]
    fn iccl_wins_over_vccl() {
        let mut cfg = Config::paper_defaults();
        let env = env_of(&[("ICCL_WINDOW_SIZE", "4"), ("VCCL_WINDOW_SIZE", "64")]);
        apply_env(&mut cfg, |k| env.get(k).cloned());
        assert_eq!(cfg.vccl.window_size, 4);
    }

    #[test]
    fn transport_and_ordering_parse() {
        let mut cfg = Config::paper_defaults();
        let env = env_of(&[("VCCL_TRANSPORT", "kernel"), ("VCCL_ORDERING", "hostfunc")]);
        apply_env(&mut cfg, |k| env.get(k).cloned());
        assert_eq!(cfg.vccl.transport, Transport::Kernel);
        assert_eq!(cfg.vccl.ordering, StreamOrdering::HostFunc);
    }

    #[test]
    fn trace_env_toggles_recorder() {
        let mut cfg = Config::paper_defaults();
        let env = env_of(&[("VCCL_TRACE", "1")]);
        apply_env(&mut cfg, |k| env.get(k).cloned());
        assert!(cfg.trace.enabled);
    }

    #[test]
    fn bool_forms() {
        for (s, want) in [("1", true), ("true", true), ("ON", true), ("0", false), ("off", false)]
        {
            assert_eq!(parse_bool(s), Some(want));
        }
        assert_eq!(parse_bool("maybe"), None);
    }

    #[test]
    fn net_plugin_refused_not_loaded() {
        let mut cfg = Config::paper_defaults();
        let env = env_of(&[("ICCL_NET_PLUGIN", "libnccl-net.so")]);
        let applied = apply_env(&mut cfg, |k| env.get(k).cloned());
        assert!(applied.iter().any(|l| l.contains("refusing")));
    }

    #[test]
    fn unknown_values_ignored() {
        let mut cfg = Config::paper_defaults();
        let env = env_of(&[("VCCL_TRANSPORT", "quantum")]);
        apply_env(&mut cfg, |k| env.get(k).cloned());
        assert_eq!(cfg.vccl.transport, Transport::SmFree); // unchanged
    }
}
