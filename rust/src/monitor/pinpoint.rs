//! The dual-threshold network-straggler pinpointer (§3.4, Fig 15).
//!
//! Inputs per windowed sample: estimated bandwidth + the NIC's
//! remaining-to-send (RTS, un-ACKed bytes tracked via the WR/WC lifecycle).
//! Output verdicts reproduce the four Fig 15 cases:
//!
//! | case                      | bandwidth        | RTS            | verdict        |
//! |---------------------------|------------------|----------------|----------------|
//! | 1 normal                  | stable           | stable         | Healthy        |
//! | 2 task termination        | declines to 0    | drains to 0    | Healthy        |
//! | 3 network interference    | drops > 50 %     | accumulates 2× | NetworkAnomaly |
//! | 4 GPU interference        | drops > 50 %     | no build-up    | NonNetwork     |
//!
//! §Soak bounding: the verdict log used to be an unbounded `Vec` —
//! O(windows elapsed) per port. It is now **exact per-verdict counters** +
//! a capped per-bucket roll-up ring + a capped raw tail, with the retain-all
//! log kept under the reference-mode cfg and cross-checked per push —
//! exactly the `WindowEstimator`/`PortTraffic` pattern. Per-port memory is
//! O(window capacity), not O(windows elapsed).

use crate::sim::SimTime;
use crate::util::{CkptReader, CkptWriter};
use std::collections::VecDeque;

/// Hard cap on retained per-bucket verdict roll-ups per pinpointer.
pub const VERDICT_BUCKET_CAP: usize = 128;
/// Hard cap on the raw recent-verdict tail per pinpointer.
pub const VERDICT_TAIL_CAP: usize = 64;

/// Classification of one monitored sample.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Bandwidth within range, or decline explained by buffer drain.
    Healthy,
    /// Condition (i) + (ii): the link itself is degraded — isolate it.
    NetworkAnomaly,
    /// Bandwidth dropped but the NIC is starved: upstream (GPU/compute)
    /// problem, NOT the network ("network innocence" proof).
    NonNetwork,
}

impl Verdict {
    /// Stable index into per-verdict count arrays.
    pub fn ordinal(self) -> usize {
        match self {
            Verdict::Healthy => 0,
            Verdict::NetworkAnomaly => 1,
            Verdict::NonNetwork => 2,
        }
    }

    fn from_ordinal(i: u64) -> Result<Verdict, String> {
        match i {
            0 => Ok(Verdict::Healthy),
            1 => Ok(Verdict::NetworkAnomaly),
            2 => Ok(Verdict::NonNetwork),
            other => Err(format!("bad verdict ordinal {other}")),
        }
    }
}

/// Roll-up of the verdicts issued inside one time bucket.
#[derive(Debug, Clone, Copy)]
pub struct VerdictBucket {
    /// Bucket index (`at_ns / trailing_ns`).
    pub idx: u64,
    /// Per-verdict counts, indexed by [`Verdict::ordinal`].
    pub counts: [u64; 3],
}

/// Streaming pinpointer with a trailing-average baseline.
#[derive(Debug)]
pub struct Pinpointer {
    trailing_ns: u64,
    bw_drop_ratio: f64,
    rts_multiple: f64,
    /// (t, gbps) history inside the trailing horizon.
    trail: VecDeque<(SimTime, f64)>,
    trail_sum: f64,
    /// Historical max of RTS (condition ii baseline).
    rts_hist_max: u64,
    /// Exact count of every verdict ever issued, by [`Verdict::ordinal`].
    counts: [u64; 3],
    last: Option<(SimTime, Verdict)>,
    /// Per-bucket roll-ups, ascending by `idx`, at most
    /// [`VERDICT_BUCKET_CAP`]. Bucket width = `trailing_ns`.
    buckets: Vec<VerdictBucket>,
    /// Most recent raw verdicts, at most [`VERDICT_TAIL_CAP`].
    tail: Vec<(SimTime, Verdict)>,
    /// Reference mode: the full unbounded verdict log.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    retained: Option<Vec<(SimTime, Verdict)>>,
    /// Total verdicts at the instant retention was switched on.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    retain_offset: u64,
}

impl Pinpointer {
    pub fn new(trailing_ns: u64, bw_drop_ratio: f64, rts_multiple: f64) -> Self {
        Pinpointer {
            trailing_ns: trailing_ns.max(1),
            bw_drop_ratio,
            rts_multiple,
            trail: VecDeque::new(),
            trail_sum: 0.0,
            rts_hist_max: 0,
            counts: [0; 3],
            last: None,
            buckets: Vec::new(),
            tail: Vec::new(),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            retained: None,
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            retain_offset: 0,
        }
    }

    /// Observe one windowed sample. Returns (and logs) the verdict.
    pub fn observe(&mut self, at: SimTime, gbps: f64, rts_bytes: u64) -> Verdict {
        // Evict history outside the trailing horizon.
        while let Some(&(t, g)) = self.trail.front() {
            if at.since(t).as_ns() > self.trailing_ns {
                self.trail.pop_front();
                self.trail_sum -= g;
            } else {
                break;
            }
        }
        let baseline = if self.trail.is_empty() {
            gbps
        } else {
            self.trail_sum / self.trail.len() as f64
        };

        let bw_collapsed = gbps < baseline * self.bw_drop_ratio;
        // Condition (ii) against the max observed *before* this sample.
        let rts_piled = self.rts_hist_max > 0
            && rts_bytes as f64 > self.rts_hist_max as f64 * self.rts_multiple;

        let verdict = if bw_collapsed && rts_piled {
            Verdict::NetworkAnomaly
        } else if bw_collapsed {
            // Includes both case 2 (termination: RTS drained to ~0) and
            // case 4 (GPU interference: NIC starved). Either way: not the
            // network's fault.
            if rts_bytes == 0 {
                Verdict::Healthy // terminal drain — case 2
            } else {
                Verdict::NonNetwork
            }
        } else {
            Verdict::Healthy
        };

        // Update baselines AFTER judging (anomalous samples shouldn't
        // poison the history — only healthy ones establish "normal").
        // The RTS baseline additionally adapts at most 20% per healthy
        // sample: a window straddling the onset of an anomaly reads as
        // "healthy" (mixed bandwidth) but must not teach the detector that
        // a piled-up NIC is normal.
        if verdict == Verdict::Healthy {
            self.trail.push_back((at, gbps));
            self.trail_sum += gbps;
            self.rts_hist_max = if self.rts_hist_max == 0 {
                rts_bytes
            } else {
                self.rts_hist_max
                    .max(rts_bytes.min((self.rts_hist_max as f64 * 1.2) as u64))
            };
        }
        self.log_verdict(at, verdict);
        verdict
    }

    /// Fold one verdict into the bounded aggregates. Sample times may step
    /// backwards (the window max slides over out-of-order completions), so
    /// bucket insertion has the `PortTraffic::record` fast-path/fallback
    /// shape.
    fn log_verdict(&mut self, at: SimTime, v: Verdict) {
        self.counts[v.ordinal()] += 1;
        self.last = Some((at, v));
        let idx = at.as_ns() / self.trailing_ns;
        match self.buckets.last_mut() {
            Some(b) if b.idx == idx => b.counts[v.ordinal()] += 1,
            Some(b) if b.idx > idx => {
                match self.buckets.binary_search_by_key(&idx, |b| b.idx) {
                    Ok(pos) => self.buckets[pos].counts[v.ordinal()] += 1,
                    // Before the oldest retained bucket: detail evicted;
                    // the exact global counters still see it.
                    Err(0) => {}
                    Err(pos) => {
                        let mut counts = [0u64; 3];
                        counts[v.ordinal()] = 1;
                        self.buckets.insert(pos, VerdictBucket { idx, counts });
                        if self.buckets.len() > VERDICT_BUCKET_CAP {
                            self.buckets.remove(0);
                        }
                    }
                }
            }
            _ => {
                let mut counts = [0u64; 3];
                counts[v.ordinal()] = 1;
                self.buckets.push(VerdictBucket { idx, counts });
                if self.buckets.len() > VERDICT_BUCKET_CAP {
                    self.buckets.remove(0);
                }
            }
        }
        self.tail.push((at, v));
        if self.tail.len() > VERDICT_TAIL_CAP {
            self.tail.remove(0);
        }
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        {
            if let Some(r) = &mut self.retained {
                r.push((at, v));
            }
            self.debug_check();
        }
    }

    /// Reference-mode cross-check: bounded views must agree with the
    /// retain-all log on every overlapping element.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    fn debug_check(&self) {
        let Some(r) = &self.retained else { return };
        debug_assert_eq!(
            self.counts.iter().sum::<u64>(),
            self.retain_offset + r.len() as u64,
            "verdict count skew vs retained log"
        );
        debug_assert_eq!(self.last, r.last().copied(), "last verdict skew vs retained log");
        let n = self.tail.len().min(r.len());
        debug_assert_eq!(
            &self.tail[self.tail.len() - n..],
            &r[r.len() - n..],
            "bounded tail diverged from retained log"
        );
    }

    /// Switch the reference retain-all log on/off. Seeds the log from the
    /// current tail so the per-push cross-check invariants hold mid-stream.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn set_retain_all(&mut self, on: bool) {
        if on {
            self.retain_offset = self.counts.iter().sum::<u64>() - self.tail.len() as u64;
            self.retained = Some(self.tail.clone());
        } else {
            self.retained = None;
        }
    }

    /// The full retain-all verdict log (reference mode only).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn retained_log(&self) -> Option<&[(SimTime, Verdict)]> {
        self.retained.as_deref()
    }

    /// The bounded tail of recent verdicts (at most [`VERDICT_TAIL_CAP`]).
    /// Exact global counts live in [`Pinpointer::verdict_counts`].
    pub fn log(&self) -> &[(SimTime, Verdict)] {
        &self.tail
    }

    /// Exact per-verdict counts over the whole stream, indexed by
    /// [`Verdict::ordinal`].
    pub fn verdict_counts(&self) -> [u64; 3] {
        self.counts
    }

    /// Bounded per-bucket roll-ups (ascending, at most
    /// [`VERDICT_BUCKET_CAP`]).
    pub fn buckets(&self) -> &[VerdictBucket] {
        &self.buckets
    }

    pub fn last(&self) -> Option<(SimTime, Verdict)> {
        self.last
    }

    /// Resident size of the *bounded* state (the reference-mode retain-all
    /// log is deliberately excluded — it exists to test this bound).
    pub fn memory_bytes(&self) -> usize {
        self.trail.capacity() * std::mem::size_of::<(SimTime, f64)>()
            + self.buckets.capacity() * std::mem::size_of::<VerdictBucket>()
            + self.tail.capacity() * std::mem::size_of::<(SimTime, Verdict)>()
    }

    /// Serialize the mutable state (§Soak checkpointing). The constructor
    /// parameters (thresholds, trailing window) come from config.
    pub fn save(&self, w: &mut CkptWriter) {
        w.usize("trail", self.trail.len());
        for &(t, g) in &self.trail {
            w.u64("t", t.as_ns());
            w.f64("g", g);
        }
        w.f64("tsum", self.trail_sum);
        w.u64("rtsmax", self.rts_hist_max);
        for (i, c) in self.counts.iter().enumerate() {
            w.u64(&format!("v{i}"), *c);
        }
        w.bool("haslast", self.last.is_some());
        if let Some((t, v)) = self.last {
            w.u64("at", t.as_ns());
            w.u64("vd", v.ordinal() as u64);
        }
        w.usize("nbuckets", self.buckets.len());
        for b in &self.buckets {
            w.u64("i", b.idx);
            for (i, c) in b.counts.iter().enumerate() {
                w.u64(&format!("c{i}"), *c);
            }
        }
        w.usize("ntail", self.tail.len());
        for &(t, v) in &self.tail {
            w.u64("at", t.as_ns());
            w.u64("vd", v.ordinal() as u64);
        }
    }

    /// Restore the mutable state saved by [`Pinpointer::save`] into a
    /// freshly constructed pinpointer (same thresholds).
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        let nt = r.usize("trail")?;
        self.trail.clear();
        for _ in 0..nt {
            self.trail.push_back((SimTime::ns(r.u64("t")?), r.f64("g")?));
        }
        self.trail_sum = r.f64("tsum")?;
        self.rts_hist_max = r.u64("rtsmax")?;
        for i in 0..3 {
            self.counts[i] = r.u64(&format!("v{i}"))?;
        }
        self.last = if r.bool("haslast")? {
            Some((SimTime::ns(r.u64("at")?), Verdict::from_ordinal(r.u64("vd")?)?))
        } else {
            None
        };
        let nb = r.usize("nbuckets")?;
        self.buckets.clear();
        for _ in 0..nb {
            let idx = r.u64("i")?;
            let mut counts = [0u64; 3];
            for (i, c) in counts.iter_mut().enumerate() {
                *c = r.u64(&format!("c{i}"))?;
            }
            self.buckets.push(VerdictBucket { idx, counts });
        }
        let ntl = r.usize("ntail")?;
        self.tail.clear();
        for _ in 0..ntl {
            self.tail.push((SimTime::ns(r.u64("at")?), Verdict::from_ordinal(r.u64("vd")?)?));
        }
        // A restored pinpointer starts reference retention from its tail.
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        if self.retained.is_some() {
            self.set_retain_all(true);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pin() -> Pinpointer {
        // 10ms trail, 50% drop, 2× RTS — the paper's thresholds.
        Pinpointer::new(10_000_000, 0.5, 2.0)
    }

    /// Case 1: stable bandwidth + stable RTS → healthy throughout.
    #[test]
    fn case1_normal_traffic() {
        let mut p = pin();
        for i in 0..100u64 {
            let v = p.observe(SimTime::us(10 * i), 390.0 + (i % 7) as f64, 4 << 20);
            assert_eq!(v, Verdict::Healthy, "sample {i}");
        }
        assert_eq!(p.verdict_counts(), [100, 0, 0]);
    }

    /// Case 2: task termination — bandwidth falls because the NIC buffer
    /// drains; RTS → 0 explains it.
    #[test]
    fn case2_termination_not_flagged() {
        let mut p = pin();
        for i in 0..50u64 {
            p.observe(SimTime::us(10 * i), 400.0, 4 << 20);
        }
        // Tail-off with empty NIC.
        for i in 50..60u64 {
            let v = p.observe(SimTime::us(10 * i), 30.0, 0);
            assert_eq!(v, Verdict::Healthy, "terminal sample {i}");
        }
    }

    /// Case 3: network interference — bandwidth halves AND un-sent data
    /// piles up on the NIC → network anomaly.
    #[test]
    fn case3_network_interference_flagged() {
        let mut p = pin();
        for i in 0..50u64 {
            p.observe(SimTime::us(10 * i), 400.0, 4 << 20);
        }
        let mut flagged = 0;
        for i in 50..70u64 {
            let rts = (4u64 << 20) * (2 + (i - 50)); // accumulating
            if p.observe(SimTime::us(10 * i), 120.0, rts) == Verdict::NetworkAnomaly {
                flagged += 1;
            }
        }
        assert!(flagged >= 15, "flagged={flagged}");
        assert_eq!(p.verdict_counts()[Verdict::NetworkAnomaly.ordinal()], flagged);
    }

    /// Case 4: GPU interference — bandwidth collapses but the NIC is
    /// starved (no accumulation) → NOT a network anomaly.
    #[test]
    fn case4_gpu_interference_not_network() {
        let mut p = pin();
        for i in 0..50u64 {
            p.observe(SimTime::us(10 * i), 400.0, 4 << 20);
        }
        for i in 50..70u64 {
            let v = p.observe(SimTime::us(10 * i), 100.0, 1 << 20);
            assert_eq!(v, Verdict::NonNetwork, "sample {i}");
        }
    }

    #[test]
    fn anomalies_do_not_poison_baseline() {
        let mut p = pin();
        for i in 0..50u64 {
            p.observe(SimTime::us(10 * i), 400.0, 4 << 20);
        }
        // Long anomaly, then recovery: recovery must read as healthy and
        // the anomaly must KEEP being flagged (baseline not dragged down).
        for i in 50..90u64 {
            let v = p.observe(SimTime::us(10 * i), 100.0, 40 << 20);
            assert_eq!(v, Verdict::NetworkAnomaly, "sample {i}");
        }
        let v = p.observe(SimTime::us(900), 395.0, 4 << 20);
        assert_eq!(v, Verdict::Healthy);
    }

    #[test]
    fn cold_start_is_healthy() {
        let mut p = pin();
        assert_eq!(p.observe(SimTime::ZERO, 5.0, 0), Verdict::Healthy);
    }

    /// §Soak: verdict-log memory is O(window capacity), not O(windows
    /// elapsed) — a soak-length verdict stream must not grow the pinpointer.
    #[test]
    fn memory_is_capacity_bounded_over_soak_lengths() {
        let mut p = pin();
        // 200k verdicts across ~33 simulated minutes of 10ms buckets.
        for i in 0..200_000u64 {
            p.observe(SimTime::us(10 * i), 390.0, 4 << 20);
        }
        assert_eq!(p.verdict_counts().iter().sum::<u64>(), 200_000);
        assert!(p.buckets().len() <= VERDICT_BUCKET_CAP, "buckets={}", p.buckets().len());
        assert!(p.log().len() <= VERDICT_TAIL_CAP, "tail={}", p.log().len());
        let cap_bound = (VERDICT_BUCKET_CAP * 2) * std::mem::size_of::<VerdictBucket>()
            + (VERDICT_TAIL_CAP * 2) * std::mem::size_of::<(SimTime, Verdict)>()
            + 4096 * std::mem::size_of::<(SimTime, f64)>();
        assert!(p.memory_bytes() <= cap_bound, "mem={} bound={cap_bound}", p.memory_bytes());
    }

    /// Reference-mode equivalence: the bounded tail and exact counters must
    /// track the retain-all log (enforced per push by debug_check too).
    #[test]
    fn bounded_views_match_retained_log() {
        let mut p = pin();
        p.set_retain_all(true);
        for i in 0..10_000u64 {
            let (g, rts) = match i % 97 {
                0..=79 => (400.0, 4 << 20),
                80..=89 => (100.0, 64 << 20), // anomaly spell
                _ => (100.0, 1 << 20),        // gpu-ish spell
            };
            p.observe(SimTime::us(10 * i), g, rts);
        }
        let r = p.retained_log().unwrap();
        assert_eq!(p.verdict_counts().iter().sum::<u64>(), r.len() as u64);
        let tail = p.log();
        assert_eq!(tail, &r[r.len() - tail.len()..]);
        // Per-verdict global counts equal the retained histogram.
        let mut hist = [0u64; 3];
        for &(_, v) in r {
            hist[v.ordinal()] += 1;
        }
        assert_eq!(hist, p.verdict_counts());
    }

    /// Checkpoint round-trip: a restored pinpointer issues the identical
    /// verdict stream (trail baseline, RTS max and counters all survive).
    #[test]
    fn save_load_round_trip_continues_identically() {
        let mut a = pin();
        for i in 0..500u64 {
            let (g, rts) = if i % 50 < 40 { (400.0, 4 << 20) } else { (100.0, 64 << 20) };
            a.observe(SimTime::us(10 * i), g, rts);
        }
        let mut w = crate::util::CkptWriter::new("T", 1);
        a.save(&mut w);
        let text = w.finish();
        let mut b = pin();
        let mut r = crate::util::CkptReader::new(&text, "T", 1).unwrap();
        b.load(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(a.verdict_counts(), b.verdict_counts());
        for i in 500..700u64 {
            let (g, rts) = if i % 50 < 40 { (400.0, 4 << 20) } else { (100.0, 64 << 20) };
            let va = a.observe(SimTime::us(10 * i), g, rts);
            let vb = b.observe(SimTime::us(10 * i), g, rts);
            assert_eq!(va, vb, "diverged at {i}");
        }
        assert_eq!(a.verdict_counts(), b.verdict_counts());
        assert_eq!(a.log(), b.log());
    }
}
