//! The dual-threshold network-straggler pinpointer (§3.4, Fig 15).
//!
//! Inputs per windowed sample: estimated bandwidth + the NIC's
//! remaining-to-send (RTS, un-ACKed bytes tracked via the WR/WC lifecycle).
//! Output verdicts reproduce the four Fig 15 cases:
//!
//! | case                      | bandwidth        | RTS            | verdict        |
//! |---------------------------|------------------|----------------|----------------|
//! | 1 normal                  | stable           | stable         | Healthy        |
//! | 2 task termination        | declines to 0    | drains to 0    | Healthy        |
//! | 3 network interference    | drops > 50 %     | accumulates 2× | NetworkAnomaly |
//! | 4 GPU interference        | drops > 50 %     | no build-up    | NonNetwork     |

use crate::sim::SimTime;
use std::collections::VecDeque;

/// Classification of one monitored sample.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Bandwidth within range, or decline explained by buffer drain.
    Healthy,
    /// Condition (i) + (ii): the link itself is degraded — isolate it.
    NetworkAnomaly,
    /// Bandwidth dropped but the NIC is starved: upstream (GPU/compute)
    /// problem, NOT the network ("network innocence" proof).
    NonNetwork,
}

/// Streaming pinpointer with a trailing-average baseline.
#[derive(Debug)]
pub struct Pinpointer {
    trailing_ns: u64,
    bw_drop_ratio: f64,
    rts_multiple: f64,
    /// (t, gbps) history inside the trailing horizon.
    trail: VecDeque<(SimTime, f64)>,
    trail_sum: f64,
    /// Historical max of RTS (condition ii baseline).
    rts_hist_max: u64,
    log: Vec<(SimTime, Verdict)>,
}

impl Pinpointer {
    pub fn new(trailing_ns: u64, bw_drop_ratio: f64, rts_multiple: f64) -> Self {
        Pinpointer {
            trailing_ns,
            bw_drop_ratio,
            rts_multiple,
            trail: VecDeque::new(),
            trail_sum: 0.0,
            rts_hist_max: 0,
            log: Vec::new(),
        }
    }

    /// Observe one windowed sample. Returns (and logs) the verdict.
    pub fn observe(&mut self, at: SimTime, gbps: f64, rts_bytes: u64) -> Verdict {
        // Evict history outside the trailing horizon.
        while let Some(&(t, g)) = self.trail.front() {
            if at.since(t).as_ns() > self.trailing_ns {
                self.trail.pop_front();
                self.trail_sum -= g;
            } else {
                break;
            }
        }
        let baseline = if self.trail.is_empty() {
            gbps
        } else {
            self.trail_sum / self.trail.len() as f64
        };

        let bw_collapsed = gbps < baseline * self.bw_drop_ratio;
        // Condition (ii) against the max observed *before* this sample.
        let rts_piled = self.rts_hist_max > 0
            && rts_bytes as f64 > self.rts_hist_max as f64 * self.rts_multiple;

        let verdict = if bw_collapsed && rts_piled {
            Verdict::NetworkAnomaly
        } else if bw_collapsed {
            // Includes both case 2 (termination: RTS drained to ~0) and
            // case 4 (GPU interference: NIC starved). Either way: not the
            // network's fault.
            if rts_bytes == 0 {
                Verdict::Healthy // terminal drain — case 2
            } else {
                Verdict::NonNetwork
            }
        } else {
            Verdict::Healthy
        };

        // Update baselines AFTER judging (anomalous samples shouldn't
        // poison the history — only healthy ones establish "normal").
        // The RTS baseline additionally adapts at most 20% per healthy
        // sample: a window straddling the onset of an anomaly reads as
        // "healthy" (mixed bandwidth) but must not teach the detector that
        // a piled-up NIC is normal.
        if verdict == Verdict::Healthy {
            self.trail.push_back((at, gbps));
            self.trail_sum += gbps;
            self.rts_hist_max = if self.rts_hist_max == 0 {
                rts_bytes
            } else {
                self.rts_hist_max
                    .max(rts_bytes.min((self.rts_hist_max as f64 * 1.2) as u64))
            };
        }
        self.log.push((at, verdict));
        verdict
    }

    pub fn log(&self) -> &[(SimTime, Verdict)] {
        &self.log
    }

    pub fn memory_bytes(&self) -> usize {
        self.trail.capacity() * std::mem::size_of::<(SimTime, f64)>()
            + self.log.capacity() * std::mem::size_of::<(SimTime, Verdict)>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pin() -> Pinpointer {
        // 10ms trail, 50% drop, 2× RTS — the paper's thresholds.
        Pinpointer::new(10_000_000, 0.5, 2.0)
    }

    /// Case 1: stable bandwidth + stable RTS → healthy throughout.
    #[test]
    fn case1_normal_traffic() {
        let mut p = pin();
        for i in 0..100u64 {
            let v = p.observe(SimTime::us(10 * i), 390.0 + (i % 7) as f64, 4 << 20);
            assert_eq!(v, Verdict::Healthy, "sample {i}");
        }
    }

    /// Case 2: task termination — bandwidth falls because the NIC buffer
    /// drains; RTS → 0 explains it.
    #[test]
    fn case2_termination_not_flagged() {
        let mut p = pin();
        for i in 0..50u64 {
            p.observe(SimTime::us(10 * i), 400.0, 4 << 20);
        }
        // Tail-off with empty NIC.
        for i in 50..60u64 {
            let v = p.observe(SimTime::us(10 * i), 30.0, 0);
            assert_eq!(v, Verdict::Healthy, "terminal sample {i}");
        }
    }

    /// Case 3: network interference — bandwidth halves AND un-sent data
    /// piles up on the NIC → network anomaly.
    #[test]
    fn case3_network_interference_flagged() {
        let mut p = pin();
        for i in 0..50u64 {
            p.observe(SimTime::us(10 * i), 400.0, 4 << 20);
        }
        let mut flagged = 0;
        for i in 50..70u64 {
            let rts = (4u64 << 20) * (2 + (i - 50)); // accumulating
            if p.observe(SimTime::us(10 * i), 120.0, rts) == Verdict::NetworkAnomaly {
                flagged += 1;
            }
        }
        assert!(flagged >= 15, "flagged={flagged}");
    }

    /// Case 4: GPU interference — bandwidth collapses but the NIC is
    /// starved (no accumulation) → NOT a network anomaly.
    #[test]
    fn case4_gpu_interference_not_network() {
        let mut p = pin();
        for i in 0..50u64 {
            p.observe(SimTime::us(10 * i), 400.0, 4 << 20);
        }
        for i in 50..70u64 {
            let v = p.observe(SimTime::us(10 * i), 100.0, 1 << 20);
            assert_eq!(v, Verdict::NonNetwork, "sample {i}");
        }
    }

    #[test]
    fn anomalies_do_not_poison_baseline() {
        let mut p = pin();
        for i in 0..50u64 {
            p.observe(SimTime::us(10 * i), 400.0, 4 << 20);
        }
        // Long anomaly, then recovery: recovery must read as healthy and
        // the anomaly must KEEP being flagged (baseline not dragged down).
        for i in 50..90u64 {
            let v = p.observe(SimTime::us(10 * i), 100.0, 40 << 20);
            assert_eq!(v, Verdict::NetworkAnomaly, "sample {i}");
        }
        let v = p.observe(SimTime::us(900), 395.0, 4 << 20);
        assert_eq!(v, Verdict::Healthy);
    }

    #[test]
    fn cold_start_is_healthy() {
        let mut p = pin();
        assert_eq!(p.observe(SimTime::ZERO, 5.0, 0), Verdict::Healthy);
    }
}
