//! Bandwidth estimators: per-message and sliding-window (Fig 9).
//!
//! §Soak bounding: the estimator used to keep every emitted [`BwSample`] in
//! an unbounded `Vec` — O(windows elapsed) per port, which makes a
//! months-long soak impossible before it starts. It now keeps, exactly like
//! `monitor::PortTraffic` but with a *hard cap*:
//!
//! - **exact global aggregates** (`samples_total`, `last`) — never dropped;
//! - a **capped ring of per-bucket roll-ups** (bucket width = the monitor's
//!   trailing window; at most [`SAMPLE_BUCKET_CAP`] buckets, oldest detail
//!   evicted — the globals stay exact);
//! - a **capped raw tail** of the most recent samples ([`SAMPLE_TAIL_CAP`]),
//!   so slice-shaped consumers keep working;
//! - the old retain-all `Vec` survives only under the reference-mode cfg
//!   (`test`/`debug_assertions`/`ref-alloc`) with a per-push cross-check,
//!   mirroring the `XferSlab`/`PortTraffic` pattern from PRs 4–5.
//!
//! Per-port memory is therefore O(window capacity), not O(windows elapsed).

use std::collections::VecDeque;

use crate::sim::SimTime;
use crate::util::{CkptReader, CkptWriter};

/// Hard cap on retained per-bucket roll-ups per estimator.
pub const SAMPLE_BUCKET_CAP: usize = 128;
/// Hard cap on the raw recent-sample tail per estimator.
pub const SAMPLE_TAIL_CAP: usize = 64;

/// One completed message observed at the verbs layer.
#[derive(Debug, Clone, Copy)]
pub struct MsgRecord {
    pub posted_at: SimTime,
    pub completed_at: SimTime,
    pub bytes: u64,
}

/// One throughput sample emitted by the estimator.
#[derive(Debug, Clone, Copy)]
pub struct BwSample {
    /// Timestamp of the sample (completion of the window's last WC).
    pub at: SimTime,
    /// Estimated throughput in Gbps.
    pub gbps: f64,
    /// Span the estimate covers (t₂ − t₁), ns.
    pub span_ns: u64,
}

impl BwSample {
    /// Bit-exact equality (f64 compared by bits — NaN-safe, −0.0 ≠ +0.0).
    pub fn bits_eq(&self, other: &BwSample) -> bool {
        self.at == other.at
            && self.gbps.to_bits() == other.gbps.to_bits()
            && self.span_ns == other.span_ns
    }
}

/// Roll-up of the samples emitted inside one time bucket.
#[derive(Debug, Clone, Copy)]
pub struct SampleBucket {
    /// Bucket index (`at_ns / bucket_ns`).
    pub idx: u64,
    pub count: u64,
    pub sum_gbps: f64,
    pub min_gbps: f64,
    pub max_gbps: f64,
}

/// Sliding-window estimator. `window == 1` is exactly the paper's naive
/// per-message scheme.
#[derive(Debug)]
pub struct WindowEstimator {
    window: usize,
    bucket_ns: u64,
    ring: VecDeque<MsgRecord>,
    /// Exact count of every sample ever emitted (survives all eviction).
    samples_total: u64,
    last: Option<BwSample>,
    /// Per-bucket roll-ups, ascending by `idx`, at most [`SAMPLE_BUCKET_CAP`].
    buckets: Vec<SampleBucket>,
    /// Most recent raw samples, at most [`SAMPLE_TAIL_CAP`].
    tail: Vec<BwSample>,
    /// Reference mode: the full unbounded sample log, for equivalence tests.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    retained: Option<Vec<BwSample>>,
    /// `samples_total` at the instant retention was switched on.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    retain_offset: u64,
}

impl WindowEstimator {
    /// Default bucket width = the monitor's default trailing window — read
    /// off the config default so the two can never silently diverge (the
    /// same convention as `PortTraffic::default`).
    pub fn new(window: usize) -> Self {
        Self::with_bucket(window, crate::config::VcclConfig::default().trailing_ns)
    }

    /// Estimator with an explicit roll-up bucket width.
    pub fn with_bucket(window: usize, bucket_ns: u64) -> Self {
        assert!(window >= 1, "window must be ≥ 1");
        WindowEstimator {
            window,
            bucket_ns: bucket_ns.max(1),
            ring: VecDeque::with_capacity(window),
            samples_total: 0,
            last: None,
            buckets: Vec::new(),
            tail: Vec::new(),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            retained: None,
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            retain_offset: 0,
        }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Roll-up granularity in nanoseconds.
    pub fn bucket_ns(&self) -> u64 {
        self.bucket_ns
    }

    /// Push a completed message; emits a sample once the ring holds a full
    /// window (then slides by one per message).
    pub fn push(&mut self, rec: MsgRecord) -> Option<BwSample> {
        self.ring.push_back(rec);
        if self.ring.len() > self.window {
            self.ring.pop_front();
        }
        if self.ring.len() < self.window {
            return None;
        }
        // t₁ = post of the first WR in the window; the WCs may complete out
        // of post order under multi-QP striping, so take min/max defensively.
        let t1 = self.ring.iter().map(|r| r.posted_at).min().unwrap();
        let t2 = self.ring.iter().map(|r| r.completed_at).max().unwrap();
        let span = t2.since(t1).as_ns().max(1);
        let total: u64 = self.ring.iter().map(|r| r.bytes).sum();
        let gbps = total as f64 / span as f64 / 0.125;
        let s = BwSample { at: t2, gbps, span_ns: span };
        self.emit(s);
        Some(s)
    }

    /// Drop the partial message window so the next traffic epoch starts
    /// fresh. Bursty workloads (§Soak: ~ms of traffic per simulated minute)
    /// need this at epoch boundaries — a window straddling a long idle gap
    /// spans the gap and aliases to ~0 Gbps, which would read as a
    /// bandwidth collapse on a healthy port. Emitted samples, counts and
    /// roll-ups are untouched.
    pub fn flush_window(&mut self) {
        self.ring.clear();
    }

    /// Fold one emitted sample into the bounded aggregates. `s.at` may go
    /// *backwards* between consecutive samples (the window max slides over
    /// out-of-order completions), so bucket insertion has the same
    /// fast-path/fallback shape as `PortTraffic::record`.
    fn emit(&mut self, s: BwSample) {
        self.samples_total += 1;
        self.last = Some(s);
        let idx = s.at.as_ns() / self.bucket_ns;
        match self.buckets.last_mut() {
            Some(b) if b.idx == idx => fold_sample(b, &s),
            Some(b) if b.idx > idx => {
                match self.buckets.binary_search_by_key(&idx, |b| b.idx) {
                    Ok(pos) => fold_sample(&mut self.buckets[pos], &s),
                    // Before the oldest retained bucket: that detail has
                    // been evicted — the sample only reaches the exact
                    // globals and the tail.
                    Err(0) => {}
                    Err(pos) => {
                        self.buckets.insert(pos, new_bucket(idx, &s));
                        if self.buckets.len() > SAMPLE_BUCKET_CAP {
                            self.buckets.remove(0);
                        }
                    }
                }
            }
            _ => {
                self.buckets.push(new_bucket(idx, &s));
                if self.buckets.len() > SAMPLE_BUCKET_CAP {
                    self.buckets.remove(0);
                }
            }
        }
        self.tail.push(s);
        if self.tail.len() > SAMPLE_TAIL_CAP {
            self.tail.remove(0);
        }
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        {
            if let Some(r) = &mut self.retained {
                r.push(s);
            }
            self.debug_check();
        }
    }

    /// Reference-mode cross-check: the bounded views must agree with the
    /// retain-all log on every overlapping element.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    fn debug_check(&self) {
        let Some(r) = &self.retained else { return };
        debug_assert_eq!(
            self.samples_total,
            self.retain_offset + r.len() as u64,
            "sample count skew vs retained log"
        );
        if let (Some(a), Some(b)) = (self.last, r.last()) {
            debug_assert!(a.bits_eq(b), "last sample skew vs retained log");
        }
        let n = self.tail.len().min(r.len());
        let ts = &self.tail[self.tail.len() - n..];
        let rs = &r[r.len() - n..];
        debug_assert!(
            ts.iter().zip(rs).all(|(a, b)| a.bits_eq(b)),
            "bounded tail diverged from retained log"
        );
    }

    /// Switch the reference retain-all log on/off. Seeds the log from the
    /// current tail so the per-push cross-check invariants hold mid-stream.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn set_retain_all(&mut self, on: bool) {
        if on {
            self.retain_offset = self.samples_total - self.tail.len() as u64;
            self.retained = Some(self.tail.clone());
        } else {
            self.retained = None;
        }
    }

    /// The full retain-all sample log (reference mode only).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn retained_samples(&self) -> Option<&[BwSample]> {
        self.retained.as_deref()
    }

    /// The bounded tail of recent samples (at most [`SAMPLE_TAIL_CAP`]).
    /// Exact global counts live in [`WindowEstimator::samples_total`].
    pub fn samples(&self) -> &[BwSample] {
        &self.tail
    }

    /// Exact count of every sample ever emitted.
    pub fn samples_total(&self) -> u64 {
        self.samples_total
    }

    /// Bounded per-bucket roll-ups (ascending, at most
    /// [`SAMPLE_BUCKET_CAP`]).
    pub fn buckets(&self) -> &[SampleBucket] {
        &self.buckets
    }

    pub fn last(&self) -> Option<BwSample> {
        self.last
    }

    /// Resident size of the *bounded* state (the reference-mode retain-all
    /// log is deliberately excluded — it exists to test this bound).
    pub fn memory_bytes(&self) -> usize {
        self.ring.capacity() * std::mem::size_of::<MsgRecord>()
            + self.buckets.capacity() * std::mem::size_of::<SampleBucket>()
            + self.tail.capacity() * std::mem::size_of::<BwSample>()
    }

    /// Serialize the mutable state (§Soak checkpointing). The constructor
    /// parameters (`window`, `bucket_ns`) come from config, not the stream.
    pub fn save(&self, w: &mut CkptWriter) {
        w.usize("ring", self.ring.len());
        for r in &self.ring {
            w.u64("p", r.posted_at.as_ns());
            w.u64("c", r.completed_at.as_ns());
            w.u64("b", r.bytes);
        }
        w.u64("stotal", self.samples_total);
        w.bool("haslast", self.last.is_some());
        if let Some(s) = self.last {
            save_sample(w, &s);
        }
        w.usize("nbuckets", self.buckets.len());
        for b in &self.buckets {
            w.u64("i", b.idx);
            w.u64("n", b.count);
            w.f64("sum", b.sum_gbps);
            w.f64("min", b.min_gbps);
            w.f64("max", b.max_gbps);
        }
        w.usize("ntail", self.tail.len());
        for s in &self.tail {
            save_sample(w, s);
        }
    }

    /// Restore the mutable state saved by [`WindowEstimator::save`] into a
    /// freshly constructed estimator (same `window`/`bucket_ns`).
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        let nring = r.usize("ring")?;
        self.ring.clear();
        for _ in 0..nring {
            self.ring.push_back(MsgRecord {
                posted_at: SimTime::ns(r.u64("p")?),
                completed_at: SimTime::ns(r.u64("c")?),
                bytes: r.u64("b")?,
            });
        }
        self.samples_total = r.u64("stotal")?;
        self.last = if r.bool("haslast")? { Some(load_sample(r)?) } else { None };
        let nb = r.usize("nbuckets")?;
        self.buckets.clear();
        for _ in 0..nb {
            self.buckets.push(SampleBucket {
                idx: r.u64("i")?,
                count: r.u64("n")?,
                sum_gbps: r.f64("sum")?,
                min_gbps: r.f64("min")?,
                max_gbps: r.f64("max")?,
            });
        }
        let nt = r.usize("ntail")?;
        self.tail.clear();
        for _ in 0..nt {
            self.tail.push(load_sample(r)?);
        }
        // A restored estimator starts reference retention from its tail —
        // the pre-checkpoint history beyond it is gone by design.
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        if self.retained.is_some() {
            self.set_retain_all(true);
        }
        Ok(())
    }
}

fn new_bucket(idx: u64, s: &BwSample) -> SampleBucket {
    SampleBucket { idx, count: 1, sum_gbps: s.gbps, min_gbps: s.gbps, max_gbps: s.gbps }
}

fn fold_sample(b: &mut SampleBucket, s: &BwSample) {
    b.count += 1;
    b.sum_gbps += s.gbps;
    b.min_gbps = b.min_gbps.min(s.gbps);
    b.max_gbps = b.max_gbps.max(s.gbps);
}

fn save_sample(w: &mut CkptWriter, s: &BwSample) {
    w.u64("at", s.at.as_ns());
    w.f64("g", s.gbps);
    w.u64("sp", s.span_ns);
}

fn load_sample(r: &mut CkptReader) -> Result<BwSample, String> {
    Ok(BwSample { at: SimTime::ns(r.u64("at")?), gbps: r.f64("g")?, span_ns: r.u64("sp")? })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(post_us: u64, done_us: u64, bytes: u64) -> MsgRecord {
        MsgRecord {
            posted_at: SimTime::us(post_us),
            completed_at: SimTime::us(done_us),
            bytes,
        }
    }

    #[test]
    fn per_message_equals_window_one() {
        let mut e = WindowEstimator::new(1);
        // 1MB in 20.97us ≈ 400 Gbps.
        let s = e.push(msg(0, 21, 1 << 20)).unwrap();
        assert!((s.gbps - 399.5).abs() < 5.0, "gbps={}", s.gbps);
    }

    #[test]
    fn window_needs_w_messages() {
        let mut e = WindowEstimator::new(4);
        assert!(e.push(msg(0, 10, 1000)).is_none());
        assert!(e.push(msg(10, 20, 1000)).is_none());
        assert!(e.push(msg(20, 30, 1000)).is_none());
        assert!(e.push(msg(30, 40, 1000)).is_some());
        // Slides by one afterwards.
        assert!(e.push(msg(40, 50, 1000)).is_some());
        assert_eq!(e.samples().len(), 2);
        assert_eq!(e.samples_total(), 2);
    }

    #[test]
    fn window_amortizes_queuing_noise() {
        // Two interleaved messages share the link: each takes 2× the solo
        // time (queuing), but the window over both spans the same wall time
        // as their combined bytes → correct aggregate estimate.
        // Solo: 1MB @ 400Gbps = ~21us. Interleaved pair: both complete at 42us.
        let mut naive = WindowEstimator::new(1);
        let mut windowed = WindowEstimator::new(2);
        let a = msg(0, 42, 1 << 20);
        let b = msg(0, 42, 1 << 20);
        let na = naive.push(a).unwrap();
        let _ = naive.push(b).unwrap();
        windowed.push(a);
        let w = windowed.push(b).unwrap();
        // Naive halves the estimate (each message "sees" 2MB-worth of time).
        assert!((na.gbps - 200.0).abs() < 5.0, "naive={}", na.gbps);
        // Windowed recovers the true link rate.
        assert!((w.gbps - 400.0).abs() < 5.0, "windowed={}", w.gbps);
    }

    #[test]
    fn larger_window_smooths_more() {
        // A single slow outlier among fast messages: W=8 dampens it more
        // than W=2 (Appendix H's fluctuation story).
        let make = |w: usize| {
            let mut e = WindowEstimator::new(w);
            let mut t = 0;
            let mut minmax: (f64, f64) = (f64::MAX, 0.0);
            for i in 0..64u64 {
                let dur = if i == 32 { 200 } else { 20 }; // outlier
                if let Some(s) = e.push(msg(t, t + dur, 1 << 20)) {
                    minmax.0 = minmax.0.min(s.gbps);
                    minmax.1 = minmax.1.max(s.gbps);
                }
                t += dur;
            }
            minmax.1 / minmax.0 // fluctuation ratio
        };
        let f2 = make(2);
        let f8 = make(8);
        let f32_ = make(32);
        assert!(f2 > f8 && f8 > f32_, "f2={f2} f8={f8} f32={f32_}");
    }

    #[test]
    fn out_of_order_completion_safe() {
        let mut e = WindowEstimator::new(2);
        e.push(msg(0, 30, 1000));
        // Completes before the earlier message (multi-QP striping).
        let s = e.push(msg(5, 25, 1000)).unwrap();
        assert_eq!(s.span_ns, 30_000 - 0);
    }

    #[test]
    fn zero_span_guard() {
        let mut e = WindowEstimator::new(1);
        let s = e.push(msg(10, 10, 1000)).unwrap();
        assert!(s.gbps.is_finite());
    }

    /// §Soak: per-port memory is O(window capacity), not O(windows elapsed)
    /// — a soak-length stream of samples must not grow the estimator.
    #[test]
    fn memory_is_capacity_bounded_over_soak_lengths() {
        let mut e = WindowEstimator::with_bucket(1, 10_000_000); // 10ms buckets
        // 200k samples spread across 100k distinct buckets (~17 simulated
        // minutes): orders of magnitude beyond any cap.
        for i in 0..200_000u64 {
            e.push(msg(i * 5_000, i * 5_000 + 20, 1 << 20));
        }
        assert_eq!(e.samples_total(), 200_000);
        assert!(e.buckets().len() <= SAMPLE_BUCKET_CAP, "buckets={}", e.buckets().len());
        assert!(e.samples().len() <= SAMPLE_TAIL_CAP, "tail={}", e.samples().len());
        let cap_bound = (SAMPLE_BUCKET_CAP * 2) * std::mem::size_of::<SampleBucket>()
            + (SAMPLE_TAIL_CAP * 2) * std::mem::size_of::<BwSample>()
            + 8 * std::mem::size_of::<MsgRecord>();
        assert!(e.memory_bytes() <= cap_bound, "mem={} bound={cap_bound}", e.memory_bytes());
        // The globals stay exact across all that eviction.
        let sum: u64 = e.buckets().iter().map(|b| b.count).sum();
        assert!(sum <= 200_000);
        assert!(e.last().is_some());
    }

    /// Reference-mode equivalence: the bounded tail and counters must track
    /// the retain-all log exactly (the per-push debug_check enforces it on
    /// every sample; this exercises it over an out-of-order-rich stream).
    #[test]
    fn bounded_views_match_retained_log() {
        let mut e = WindowEstimator::with_bucket(4, 1_000);
        e.set_retain_all(true);
        for i in 0..5_000u64 {
            // Alternate forward/backward completion times so the window max
            // occasionally steps backwards (bucket fallback path).
            let done = if i % 3 == 0 { 40 + i * 7 } else { 10 + i * 7 };
            e.push(MsgRecord {
                posted_at: SimTime::ns(i * 7),
                completed_at: SimTime::ns(done),
                bytes: 1 << 16,
            });
        }
        let r = e.retained_samples().unwrap();
        assert_eq!(e.samples_total(), r.len() as u64);
        let tail = e.samples();
        let suffix = &r[r.len() - tail.len()..];
        assert!(tail.iter().zip(suffix).all(|(a, b)| a.bits_eq(b)));
    }

    /// Checkpoint round-trip: a restored estimator continues the identical
    /// sample stream, including the half-full message ring.
    #[test]
    fn save_load_round_trip_continues_identically() {
        let mut a = WindowEstimator::with_bucket(4, 10_000);
        for i in 0..103u64 {
            a.push(msg(i * 10, i * 10 + 25, 1 << 18));
        }
        let mut w = crate::util::CkptWriter::new("T", 1);
        a.save(&mut w);
        let text = w.finish();
        let mut b = WindowEstimator::with_bucket(4, 10_000);
        let mut r = crate::util::CkptReader::new(&text, "T", 1).unwrap();
        b.load(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(a.samples_total(), b.samples_total());
        for i in 103..140u64 {
            let sa = a.push(msg(i * 10, i * 10 + 25, 1 << 18));
            let sb = b.push(msg(i * 10, i * 10 + 25, 1 << 18));
            match (sa, sb) {
                (Some(x), Some(y)) => assert!(x.bits_eq(&y), "diverged at {i}"),
                (None, None) => {}
                _ => panic!("emission skew at {i}"),
            }
        }
        assert_eq!(a.samples_total(), b.samples_total());
        assert_eq!(a.buckets().len(), b.buckets().len());
    }
}
