//! Bandwidth estimators: per-message and sliding-window (Fig 9).

use std::collections::VecDeque;

use crate::sim::SimTime;

/// One completed message observed at the verbs layer.
#[derive(Debug, Clone, Copy)]
pub struct MsgRecord {
    pub posted_at: SimTime,
    pub completed_at: SimTime,
    pub bytes: u64,
}

/// One throughput sample emitted by the estimator.
#[derive(Debug, Clone, Copy)]
pub struct BwSample {
    /// Timestamp of the sample (completion of the window's last WC).
    pub at: SimTime,
    /// Estimated throughput in Gbps.
    pub gbps: f64,
    /// Span the estimate covers (t₂ − t₁), ns.
    pub span_ns: u64,
}

/// Sliding-window estimator. `window == 1` is exactly the paper's naive
/// per-message scheme.
#[derive(Debug)]
pub struct WindowEstimator {
    window: usize,
    ring: VecDeque<MsgRecord>,
    samples: Vec<BwSample>,
}

impl WindowEstimator {
    pub fn new(window: usize) -> Self {
        assert!(window >= 1, "window must be ≥ 1");
        WindowEstimator { window, ring: VecDeque::with_capacity(window), samples: Vec::new() }
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Push a completed message; emits a sample once the ring holds a full
    /// window (then slides by one per message).
    pub fn push(&mut self, rec: MsgRecord) -> Option<BwSample> {
        self.ring.push_back(rec);
        if self.ring.len() > self.window {
            self.ring.pop_front();
        }
        if self.ring.len() < self.window {
            return None;
        }
        // t₁ = post of the first WR in the window; the WCs may complete out
        // of post order under multi-QP striping, so take min/max defensively.
        let t1 = self.ring.iter().map(|r| r.posted_at).min().unwrap();
        let t2 = self.ring.iter().map(|r| r.completed_at).max().unwrap();
        let span = t2.since(t1).as_ns().max(1);
        let total: u64 = self.ring.iter().map(|r| r.bytes).sum();
        let gbps = total as f64 / span as f64 / 0.125;
        let s = BwSample { at: t2, gbps, span_ns: span };
        self.samples.push(s);
        Some(s)
    }

    pub fn samples(&self) -> &[BwSample] {
        &self.samples
    }

    pub fn last(&self) -> Option<BwSample> {
        self.samples.last().copied()
    }

    pub fn memory_bytes(&self) -> usize {
        self.ring.capacity() * std::mem::size_of::<MsgRecord>()
            + self.samples.capacity() * std::mem::size_of::<BwSample>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn msg(post_us: u64, done_us: u64, bytes: u64) -> MsgRecord {
        MsgRecord {
            posted_at: SimTime::us(post_us),
            completed_at: SimTime::us(done_us),
            bytes,
        }
    }

    #[test]
    fn per_message_equals_window_one() {
        let mut e = WindowEstimator::new(1);
        // 1MB in 20.97us ≈ 400 Gbps.
        let s = e.push(msg(0, 21, 1 << 20)).unwrap();
        assert!((s.gbps - 399.5).abs() < 5.0, "gbps={}", s.gbps);
    }

    #[test]
    fn window_needs_w_messages() {
        let mut e = WindowEstimator::new(4);
        assert!(e.push(msg(0, 10, 1000)).is_none());
        assert!(e.push(msg(10, 20, 1000)).is_none());
        assert!(e.push(msg(20, 30, 1000)).is_none());
        assert!(e.push(msg(30, 40, 1000)).is_some());
        // Slides by one afterwards.
        assert!(e.push(msg(40, 50, 1000)).is_some());
        assert_eq!(e.samples().len(), 2);
    }

    #[test]
    fn window_amortizes_queuing_noise() {
        // Two interleaved messages share the link: each takes 2× the solo
        // time (queuing), but the window over both spans the same wall time
        // as their combined bytes → correct aggregate estimate.
        // Solo: 1MB @ 400Gbps = ~21us. Interleaved pair: both complete at 42us.
        let mut naive = WindowEstimator::new(1);
        let mut windowed = WindowEstimator::new(2);
        let a = msg(0, 42, 1 << 20);
        let b = msg(0, 42, 1 << 20);
        let na = naive.push(a).unwrap();
        let _ = naive.push(b).unwrap();
        windowed.push(a);
        let w = windowed.push(b).unwrap();
        // Naive halves the estimate (each message "sees" 2MB-worth of time).
        assert!((na.gbps - 200.0).abs() < 5.0, "naive={}", na.gbps);
        // Windowed recovers the true link rate.
        assert!((w.gbps - 400.0).abs() < 5.0, "windowed={}", w.gbps);
    }

    #[test]
    fn larger_window_smooths_more() {
        // A single slow outlier among fast messages: W=8 dampens it more
        // than W=2 (Appendix H's fluctuation story).
        let make = |w: usize| {
            let mut e = WindowEstimator::new(w);
            let mut t = 0;
            let mut minmax: (f64, f64) = (f64::MAX, 0.0);
            for i in 0..64u64 {
                let dur = if i == 32 { 200 } else { 20 }; // outlier
                if let Some(s) = e.push(msg(t, t + dur, 1 << 20)) {
                    minmax.0 = minmax.0.min(s.gbps);
                    minmax.1 = minmax.1.max(s.gbps);
                }
                t += dur;
            }
            minmax.1 / minmax.0 // fluctuation ratio
        };
        let f2 = make(2);
        let f8 = make(8);
        let f32_ = make(32);
        assert!(f2 > f8 && f8 > f32_, "f2={f2} f8={f8} f32={f32_}");
    }

    #[test]
    fn out_of_order_completion_safe() {
        let mut e = WindowEstimator::new(2);
        e.push(msg(0, 30, 1000));
        // Completes before the earlier message (multi-QP striping).
        let s = e.push(msg(5, 25, 1000)).unwrap();
        assert_eq!(s.span_ns, 30_000 - 0);
    }

    #[test]
    fn zero_span_guard() {
        let mut e = WindowEstimator::new(1);
        let s = e.push(msg(10, 10, 1000)).unwrap();
        assert!(s.gbps.is_finite());
    }
}
