//! Online network performance monitor (§3.4): O(μs) per-NIC throughput from
//! WR/WC timestamps, window-smoothed, plus the dual-threshold straggler
//! pinpointer.
//!
//! Two estimators, exactly as the paper frames them (Fig 9):
//!
//! - **per-message**: `B = ω(M) / (t₂ − t₁)` — captures transient dynamics
//!   but is hopelessly noisy under concurrent traffic (queuing delay and
//!   bandwidth interleaving pollute `t₂ − t₁`);
//! - **per-window**: over the last `W` messages, `B̄ = Σω(Mᵢ) / (t₂ − t₁)`
//!   with `t₁` = post time of the window's first WR and `t₂` = completion
//!   of its last WC — amortizes queuing noise while staying responsive.
//!   `W = 1` degenerates to per-message; Table 3 uses `W = 8`; Appendix H
//!   shows `W = 32` over-smoothing.
//!
//! The pinpointer (Fig 15) flags a *network* anomaly only when BOTH hold:
//!  (i) windowed bandwidth drops > 50 % below the trailing (~10 ms) average
//!      of the same primitive, and
//! (ii) remaining-to-send (un-ACKed bytes on the NIC) exceeds 2× its
//!      historical max — bandwidth collapse *with* data piling up is a
//!      network problem; collapse with an empty NIC is the upstream
//!      (compute) starving the NIC (GPU interference / normal completion).

pub mod estimator;
pub mod pinpoint;

pub use estimator::{BwSample, MsgRecord, WindowEstimator};
pub use pinpoint::{Pinpointer, Verdict};

use crate::sim::SimTime;
use crate::trace::{TraceEvent, Tracer};
use std::collections::HashMap;

/// Per-port monitor bundle: one estimator + one pinpointer per RNIC port,
/// keyed by an opaque port index (the cluster maps `PortId` → index).
#[derive(Debug)]
pub struct MonitorSet {
    window: usize,
    trailing_ns: u64,
    bw_drop_ratio: f64,
    rts_multiple: f64,
    ports: HashMap<usize, PortMonitor>,
    /// Overhead accounting: CPU-ns charged per processed WC (Table 5).
    pub wc_cost_ns: u64,
    pub processed_wcs: u64,
    /// Flight recorder: non-healthy verdicts become trace events and
    /// freeze anomaly snapshots (disabled by default).
    tracer: Tracer,
}

#[derive(Debug)]
pub struct PortMonitor {
    pub estimator: WindowEstimator,
    pub pinpointer: Pinpointer,
}

impl MonitorSet {
    pub fn new(cfg: &crate::config::VcclConfig) -> Self {
        MonitorSet {
            window: cfg.window_size,
            trailing_ns: cfg.trailing_ns,
            bw_drop_ratio: cfg.bw_drop_ratio,
            rts_multiple: cfg.rts_multiple,
            ports: HashMap::new(),
            wc_cost_ns: 150, // ~pair of timestamps + ring push per WC
            processed_wcs: 0,
            tracer: Tracer::disabled(),
        }
    }

    /// Install a flight-recorder handle.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    fn port(&mut self, port: usize) -> &mut PortMonitor {
        let (w, t, b, r) = (self.window, self.trailing_ns, self.bw_drop_ratio, self.rts_multiple);
        self.ports.entry(port).or_insert_with(|| PortMonitor {
            estimator: WindowEstimator::new(w),
            pinpointer: Pinpointer::new(t, b, r),
        })
    }

    /// Feed one completed message (WR post time, WC completion time, bytes)
    /// plus the port's current backlog. Returns a verdict when the sample
    /// completes a window.
    pub fn on_wc(
        &mut self,
        port: usize,
        posted_at: SimTime,
        completed_at: SimTime,
        bytes: u64,
        backlog_bytes: u64,
    ) -> Option<Verdict> {
        self.processed_wcs += 1;
        let pm = self.port(port);
        let sample = pm.estimator.push(MsgRecord { posted_at, completed_at, bytes })?;
        let verdict = pm.pinpointer.observe(sample.at, sample.gbps, backlog_bytes);
        // Non-healthy verdicts are exactly the "why" moments the flight
        // recorder exists for: record them and freeze the trailing window.
        if verdict != Verdict::Healthy && self.tracer.enabled() {
            let label = match verdict {
                Verdict::NetworkAnomaly => "network-anomaly",
                Verdict::NonNetwork => "non-network",
                Verdict::Healthy => unreachable!(),
            };
            self.tracer.record_anomaly(
                sample.at,
                TraceEvent::MonitorVerdict { port, verdict: label, gbps: sample.gbps },
                &format!("{label}-port{port}"),
            );
        }
        Some(verdict)
    }

    /// All samples a port has produced (for the figure outputs).
    pub fn samples(&self, port: usize) -> &[BwSample] {
        self.ports.get(&port).map(|p| p.estimator.samples()).unwrap_or(&[])
    }

    pub fn verdicts(&self, port: usize) -> &[(SimTime, Verdict)] {
        self.ports.get(&port).map(|p| p.pinpointer.log()).unwrap_or(&[])
    }

    /// Total monitor CPU time charged (ns) — the Table 5 overhead metric.
    pub fn cpu_overhead_ns(&self) -> u64 {
        self.processed_wcs * self.wc_cost_ns
    }

    /// Approximate resident memory of the monitor state in bytes
    /// (ring buffers + sample logs) — Table 5's memory column.
    pub fn memory_bytes(&self) -> usize {
        self.ports
            .values()
            .map(|p| p.estimator.memory_bytes() + p.pinpointer.memory_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VcclConfig;
    use crate::trace::{TraceSink, Tracer};

    #[test]
    fn non_healthy_verdicts_reach_the_flight_recorder() {
        let mut mon = MonitorSet::new(&VcclConfig::default());
        let sink = TraceSink::new(256, 1_000_000_000);
        mon.set_tracer(Tracer::attached(sink.clone()));
        let msg = 1u64 << 20;
        let mut t = 0u64;
        let mut push = |mon: &mut MonitorSet, gbps: f64, backlog: u64, t: &mut u64| {
            let dur = (msg as f64 / (gbps * 0.125)) as u64;
            let v = mon.on_wc(0, SimTime::ns(*t), SimTime::ns(*t + dur), msg, backlog);
            *t += dur;
            v
        };
        // Steady 390 Gbps with a steady backlog: all-healthy, no records.
        for _ in 0..100 {
            push(&mut mon, 390.0, 4 << 20, &mut t);
        }
        assert!(sink.is_empty(), "healthy traffic must record nothing");
        // Bandwidth collapse WITH pile-up: network anomaly → trace events
        // plus one (throttled) incident snapshot.
        for _ in 0..40 {
            push(&mut mon, 100.0, 64 << 20, &mut t);
        }
        let recs = sink.records();
        assert!(!recs.is_empty(), "anomalous verdicts must be recorded");
        assert!(recs.iter().all(|r| r.ev.kind() == "MonitorVerdict"));
        let incs = sink.incidents();
        assert_eq!(incs.len(), 1, "snapshots throttle to one per window");
        assert!(incs[0].name.contains("network-anomaly"), "{}", incs[0].name);
    }
}
