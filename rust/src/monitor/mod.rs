//! Online network performance monitor (§3.4): O(μs) per-NIC throughput from
//! WR/WC timestamps, window-smoothed, plus the dual-threshold straggler
//! pinpointer.
//!
//! Two estimators, exactly as the paper frames them (Fig 9):
//!
//! - **per-message**: `B = ω(M) / (t₂ − t₁)` — captures transient dynamics
//!   but is hopelessly noisy under concurrent traffic (queuing delay and
//!   bandwidth interleaving pollute `t₂ − t₁`);
//! - **per-window**: over the last `W` messages, `B̄ = Σω(Mᵢ) / (t₂ − t₁)`
//!   with `t₁` = post time of the window's first WR and `t₂` = completion
//!   of its last WC — amortizes queuing noise while staying responsive.
//!   `W = 1` degenerates to per-message; Table 3 uses `W = 8`; Appendix H
//!   shows `W = 32` over-smoothing.
//!
//! The pinpointer (Fig 15) flags a *network* anomaly only when BOTH hold:
//!  (i) windowed bandwidth drops > 50 % below the trailing (~10 ms) average
//!      of the same primitive, and
//! (ii) remaining-to-send (un-ACKed bytes on the NIC) exceeds 2× its
//!      historical max — bandwidth collapse *with* data piling up is a
//!      network problem; collapse with an empty NIC is the upstream
//!      (compute) starving the NIC (GPU interference / normal completion).

pub mod estimator;
pub mod pinpoint;

pub use estimator::{BwSample, MsgRecord, SampleBucket, WindowEstimator};
pub use pinpoint::{Pinpointer, Verdict, VerdictBucket};

use crate::sim::SimTime;
use crate::trace::{TraceEvent, Tracer};
use crate::util::{CkptReader, CkptWriter};
use std::collections::HashMap;

/// §Perf L4: bounded per-port completion-traffic aggregation.
///
/// Replaces the unbounded per-WC `(ns, port, bytes)` trace the cluster kept
/// for the bandwidth-timeline figures (13a, 18): completions are folded
/// into fixed-width time buckets sized to the monitor's trailing window
/// (`vccl.trailing_ns`), so memory is **O(ports × elapsed windows)** instead
/// of O(total chunks). Exact per-port first/last completion instants are
/// retained for gap measurements (the §3.3 recovery-gap metric).
#[derive(Debug, Clone)]
pub struct PortTraffic {
    bucket_ns: u64,
    ports: HashMap<usize, PortBuckets>,
}

/// One port's aggregated completion traffic.
#[derive(Debug, Clone)]
pub struct PortBuckets {
    /// Exact instant of the port's first recorded completion.
    pub first_ns: u64,
    /// Exact instant of the port's latest recorded completion.
    pub last_ns: u64,
    /// Total completed bytes on the port.
    pub total_bytes: u64,
    /// `(bucket index, bytes)`, ascending. Per-port completion times are
    /// nondecreasing (the event loop's clock is monotone), so appends keep
    /// the vec sorted; an out-of-order record falls back to insertion.
    pub buckets: Vec<(u64, u64)>,
}

impl Default for PortTraffic {
    fn default() -> Self {
        // The monitor's default trailing window — read off the config
        // default so the two can never silently diverge.
        PortTraffic::new(crate::config::VcclConfig::default().trailing_ns)
    }
}

impl PortTraffic {
    pub fn new(bucket_ns: u64) -> Self {
        PortTraffic { bucket_ns: bucket_ns.max(1), ports: HashMap::new() }
    }

    /// Aggregation granularity in nanoseconds.
    pub fn bucket_ns(&self) -> u64 {
        self.bucket_ns
    }

    /// Fold one completion into its port's current bucket. O(1) amortized.
    pub fn record(&mut self, at_ns: u64, port: usize, bytes: u64) {
        let idx = at_ns / self.bucket_ns;
        let p = self.ports.entry(port).or_insert_with(|| PortBuckets {
            first_ns: at_ns,
            last_ns: at_ns,
            total_bytes: 0,
            buckets: Vec::new(),
        });
        p.first_ns = p.first_ns.min(at_ns);
        p.last_ns = p.last_ns.max(at_ns);
        p.total_bytes += bytes;
        match p.buckets.last_mut() {
            Some((i, b)) if *i == idx => *b += bytes,
            Some((i, _)) if *i > idx => match p.buckets.binary_search_by_key(&idx, |e| e.0) {
                Ok(pos) => p.buckets[pos].1 += bytes,
                Err(pos) => p.buckets.insert(pos, (idx, bytes)),
            },
            _ => p.buckets.push((idx, bytes)),
        }
    }

    /// A port's aggregated record, if it saw any traffic.
    pub fn port(&self, port: usize) -> Option<&PortBuckets> {
        self.ports.get(&port)
    }

    /// Bandwidth series of a port re-bucketed to `bucket_ns`-wide bins:
    /// `(bin start in seconds, Gbps)`, ascending. Exact when `bucket_ns`
    /// is a multiple of the aggregation granularity (the usual case — the
    /// figures plot 1 s bins over 10 ms buckets); otherwise bytes are
    /// attributed by fine-bucket start. A request finer than the
    /// aggregation granularity is clamped up to it — the per-completion
    /// times are gone, and dividing a whole fine bucket's bytes by a
    /// smaller bin width would inflate the Gbps values.
    pub fn series_gbps(&self, port: usize, bucket_ns: u64) -> Vec<(f64, f64)> {
        let b = bucket_ns.max(self.bucket_ns).max(1);
        let Some(p) = self.ports.get(&port) else { return Vec::new() };
        let mut coarse: Vec<(u64, u64)> = Vec::new();
        for &(idx, bytes) in &p.buckets {
            let c = idx * self.bucket_ns / b;
            match coarse.last_mut() {
                Some((ci, cb)) if *ci == c => *cb += bytes,
                _ => coarse.push((c, bytes)),
            }
        }
        coarse
            .into_iter()
            .map(|(c, bytes)| ((c * b) as f64 / 1e9, bytes as f64 * 8.0 / b as f64))
            .collect()
    }

    /// First completion at or after `ns` on a port. Exact when the port's
    /// very first completion qualifies (the §3.3 recovery-gap case: a
    /// backup port is silent until failover). Otherwise a **lower bound**:
    /// the first bucket that could still contain qualifying completions is
    /// reported, clamped to the cutoff. A bucket straddling the cutoff is
    /// attributed conservatively (its per-completion times are gone), so
    /// the answer never skips past real traffic, is within one bucket
    /// width of the truth when that bucket holds a qualifying completion —
    /// and can be earlier than the truth when it doesn't. Derived metrics
    /// (the recovery gap) inherit the lower-bound reading in that case.
    pub fn first_completion_at_or_after(&self, port: usize, ns: u64) -> Option<u64> {
        let p = self.ports.get(&port)?;
        if p.first_ns >= ns {
            return Some(p.first_ns);
        }
        if p.last_ns < ns {
            return None;
        }
        p.buckets
            .iter()
            .map(|&(i, _)| i * self.bucket_ns)
            .find(|&t| t + self.bucket_ns > ns)
            .map(|t| t.max(ns))
    }

    /// Total completed bytes across ALL ports in `[from_ns, to_ns)`,
    /// attributed at aggregation-bucket granularity (a bucket belongs to
    /// the window containing its start). Exact when both bounds are
    /// multiples of `bucket_ns` — the fig18-style per-phase goodput reads
    /// (§Perf L5 resilience sweep) align their phases to the buckets.
    pub fn bytes_between(&self, from_ns: u64, to_ns: u64) -> u64 {
        self.ports
            .values()
            .map(|p| {
                p.buckets
                    .iter()
                    .filter(|(i, _)| {
                        let t = i * self.bucket_ns;
                        t >= from_ns && t < to_ns
                    })
                    .map(|&(_, b)| b)
                    .sum::<u64>()
            })
            .sum()
    }

    /// Approximate resident size (the bounded-memory guarantee's witness).
    pub fn memory_bytes(&self) -> usize {
        self.ports
            .values()
            .map(|p| std::mem::size_of::<PortBuckets>() + p.buckets.len() * 16)
            .sum::<usize>()
            + std::mem::size_of::<Self>()
    }

    /// Serialize the aggregated traffic (§Soak checkpointing). `bucket_ns`
    /// is a constructor parameter (from config), not part of the stream.
    pub fn save(&self, w: &mut CkptWriter) {
        let mut ports: Vec<_> = self.ports.iter().collect();
        ports.sort_by_key(|(port, _)| **port);
        w.usize("nports", ports.len());
        for (port, p) in ports {
            w.usize("port", *port);
            w.u64("first", p.first_ns);
            w.u64("last", p.last_ns);
            w.u64("total", p.total_bytes);
            w.usize("nbuckets", p.buckets.len());
            for &(i, b) in &p.buckets {
                w.u64("i", i);
                w.u64("b", b);
            }
        }
    }

    /// Restore the state saved by [`PortTraffic::save`] into a freshly
    /// constructed instance (same `bucket_ns`).
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        self.ports.clear();
        let n = r.usize("nports")?;
        for _ in 0..n {
            let port = r.usize("port")?;
            let first_ns = r.u64("first")?;
            let last_ns = r.u64("last")?;
            let total_bytes = r.u64("total")?;
            let nb = r.usize("nbuckets")?;
            let mut buckets = Vec::with_capacity(nb);
            for _ in 0..nb {
                buckets.push((r.u64("i")?, r.u64("b")?));
            }
            self.ports.insert(port, PortBuckets { first_ns, last_ns, total_bytes, buckets });
        }
        Ok(())
    }
}

/// Per-port monitor bundle: one estimator + one pinpointer per RNIC port,
/// keyed by an opaque port index (the cluster maps `PortId` → index).
#[derive(Debug)]
pub struct MonitorSet {
    window: usize,
    trailing_ns: u64,
    bw_drop_ratio: f64,
    rts_multiple: f64,
    ports: HashMap<usize, PortMonitor>,
    /// Overhead accounting: CPU-ns charged per processed WC (Table 5).
    pub wc_cost_ns: u64,
    pub processed_wcs: u64,
    /// Flight recorder: non-healthy verdicts become trace events and
    /// freeze anomaly snapshots (disabled by default).
    tracer: Tracer,
    /// Reference mode: newly created port monitors keep their full
    /// retain-all logs for equivalence tests.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    retain_all: bool,
}

#[derive(Debug)]
pub struct PortMonitor {
    pub estimator: WindowEstimator,
    pub pinpointer: Pinpointer,
}

impl MonitorSet {
    pub fn new(cfg: &crate::config::VcclConfig) -> Self {
        MonitorSet {
            window: cfg.window_size,
            trailing_ns: cfg.trailing_ns,
            bw_drop_ratio: cfg.bw_drop_ratio,
            rts_multiple: cfg.rts_multiple,
            ports: HashMap::new(),
            wc_cost_ns: 150, // ~pair of timestamps + ring push per WC
            processed_wcs: 0,
            tracer: Tracer::disabled(),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            retain_all: false,
        }
    }

    /// Install a flight-recorder handle.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Reference mode: make every port monitor keep its full retain-all
    /// sample/verdict logs (the bounded-vs-reference equivalence witness).
    /// Must be set before any traffic creates port monitors.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn set_retain_all(&mut self, on: bool) {
        assert!(self.ports.is_empty(), "set_retain_all after ports exist");
        self.retain_all = on;
    }

    fn port(&mut self, port: usize) -> &mut PortMonitor {
        let (w, t, b, r) = (self.window, self.trailing_ns, self.bw_drop_ratio, self.rts_multiple);
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        let retain = self.retain_all;
        self.ports.entry(port).or_insert_with(|| {
            #[allow(unused_mut)] // mutated only under the reference cfg
            let mut pm = PortMonitor {
                estimator: WindowEstimator::with_bucket(w, t),
                pinpointer: Pinpointer::new(t, b, r),
            };
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            if retain {
                pm.estimator.set_retain_all(true);
                pm.pinpointer.set_retain_all(true);
            }
            pm
        })
    }

    /// Feed one completed message (WR post time, WC completion time, bytes)
    /// plus the port's current backlog. Returns a verdict when the sample
    /// completes a window.
    pub fn on_wc(
        &mut self,
        port: usize,
        posted_at: SimTime,
        completed_at: SimTime,
        bytes: u64,
        backlog_bytes: u64,
    ) -> Option<Verdict> {
        self.processed_wcs += 1;
        let pm = self.port(port);
        let sample = pm.estimator.push(MsgRecord { posted_at, completed_at, bytes })?;
        let verdict = pm.pinpointer.observe(sample.at, sample.gbps, backlog_bytes);
        // Non-healthy verdicts are exactly the "why" moments the flight
        // recorder exists for: record them and freeze the trailing window.
        if verdict != Verdict::Healthy && self.tracer.enabled() {
            let label = match verdict {
                Verdict::NetworkAnomaly => "network-anomaly",
                Verdict::NonNetwork => "non-network",
                Verdict::Healthy => unreachable!(),
            };
            self.tracer.record_anomaly(
                sample.at,
                TraceEvent::MonitorVerdict { port, verdict: label, gbps: sample.gbps },
                &format!("{label}-port{port}"),
            );
        }
        Some(verdict)
    }

    /// Drop every port's partial message window (see
    /// [`WindowEstimator::flush_window`]). The soak harness calls this at
    /// each burst boundary so no bandwidth window straddles the inter-burst
    /// idle gap.
    pub fn flush_windows(&mut self) {
        for pm in self.ports.values_mut() {
            pm.estimator.flush_window();
        }
    }

    /// A port's bounded tail of recent samples (§Soak bounding: the full
    /// log is no longer retained — exact counts via
    /// [`MonitorSet::samples_total`]).
    pub fn samples(&self, port: usize) -> &[BwSample] {
        self.ports.get(&port).map(|p| p.estimator.samples()).unwrap_or(&[])
    }

    /// Exact count of every sample a port has ever produced.
    pub fn samples_total(&self, port: usize) -> u64 {
        self.ports.get(&port).map(|p| p.estimator.samples_total()).unwrap_or(0)
    }

    /// A port's bounded tail of recent verdicts (§Soak bounding: exact
    /// counts via [`MonitorSet::verdict_counts`]).
    pub fn verdicts(&self, port: usize) -> &[(SimTime, Verdict)] {
        self.ports.get(&port).map(|p| p.pinpointer.log()).unwrap_or(&[])
    }

    /// Exact per-verdict counts for a port, indexed by [`Verdict::ordinal`].
    pub fn verdict_counts(&self, port: usize) -> [u64; 3] {
        self.ports.get(&port).map(|p| p.pinpointer.verdict_counts()).unwrap_or([0; 3])
    }

    /// Ports that have produced at least one sample, ascending.
    pub fn active_ports(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.ports.keys().copied().collect();
        v.sort_unstable();
        v
    }

    /// Total monitor CPU time charged (ns) — the Table 5 overhead metric.
    pub fn cpu_overhead_ns(&self) -> u64 {
        self.processed_wcs * self.wc_cost_ns
    }

    /// Approximate resident memory of the monitor state in bytes
    /// (ring buffers + bounded roll-ups/tails) — Table 5's memory column.
    pub fn memory_bytes(&self) -> usize {
        self.ports
            .values()
            .map(|p| p.estimator.memory_bytes() + p.pinpointer.memory_bytes())
            .sum()
    }

    /// Serialize all per-port monitor state (§Soak checkpointing). The
    /// thresholds/window are constructor parameters from config.
    pub fn save(&self, w: &mut CkptWriter) {
        w.u64("wcs", self.processed_wcs);
        let mut ports: Vec<_> = self.ports.iter().collect();
        ports.sort_by_key(|(port, _)| **port);
        w.usize("nports", ports.len());
        for (port, pm) in ports {
            w.usize("port", *port);
            pm.estimator.save(w);
            pm.pinpointer.save(w);
        }
    }

    /// Restore the state saved by [`MonitorSet::save`] into a freshly
    /// constructed set (same config). Existing port monitors are replaced.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        self.processed_wcs = r.u64("wcs")?;
        self.ports.clear();
        let n = r.usize("nports")?;
        for _ in 0..n {
            let port = r.usize("port")?;
            let pm = self.port(port);
            pm.estimator.load(r)?;
            pm.pinpointer.load(r)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::VcclConfig;
    use crate::trace::{TraceSink, Tracer};

    /// §Perf L4: memory is bounded by elapsed windows, not completions —
    /// 100k completions inside one window collapse into one bucket.
    #[test]
    fn port_traffic_memory_is_window_bounded() {
        let mut t = PortTraffic::new(10_000_000); // 10ms buckets
        for i in 0..100_000u64 {
            t.record(i * 50, 3, 1 << 20); // all inside the first 5ms
        }
        let p = t.port(3).unwrap();
        assert_eq!(p.buckets.len(), 1, "one window → one bucket");
        assert_eq!(p.total_bytes, 100_000 << 20);
        assert_eq!(p.first_ns, 0);
        assert_eq!(p.last_ns, 99_999 * 50);
        // Spread over 50 windows → at most 50 buckets.
        let mut t = PortTraffic::new(10_000_000);
        for i in 0..100_000u64 {
            t.record(i * 5_000, 3, 1);
        }
        assert_eq!(t.port(3).unwrap().buckets.len(), 50);
    }

    /// Re-bucketing to a coarser series is exact when the coarse bin is a
    /// multiple of the aggregation granularity.
    #[test]
    fn port_traffic_series_rebuckets_exactly() {
        let mut t = PortTraffic::new(10_000_000);
        // 1 GB in second 0, 2 GB in second 2, nothing in second 1.
        t.record(400_000_000, 7, 1 << 30);
        t.record(2_100_000_000, 7, 1 << 30);
        t.record(2_900_000_000, 7, 1 << 30);
        let s = t.series_gbps(7, 1_000_000_000);
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].0, 0.0);
        assert!((s[0].1 - (1u64 << 30) as f64 * 8.0 / 1e9).abs() < 1e-9);
        assert_eq!(s[1].0, 2.0);
        assert!((s[1].1 - 2.0 * (1u64 << 30) as f64 * 8.0 / 1e9).abs() < 1e-9);
        assert!(t.series_gbps(8, 1_000_000_000).is_empty(), "silent port → empty series");
    }

    /// Cluster-wide per-phase goodput (§Perf L5 fig18-style sweeps): bytes
    /// across all ports inside a window, exact on bucket-aligned bounds.
    #[test]
    fn port_traffic_bytes_between_windows() {
        let mut t = PortTraffic::new(10_000_000); // 10ms buckets
        t.record(5_000_000, 0, 100); // bucket 0, port 0
        t.record(15_000_000, 1, 200); // bucket 1, port 1
        t.record(25_000_000, 0, 400); // bucket 2, port 0
        assert_eq!(t.bytes_between(0, 30_000_000), 700);
        assert_eq!(t.bytes_between(0, 10_000_000), 100);
        assert_eq!(t.bytes_between(10_000_000, 20_000_000), 200);
        assert_eq!(t.bytes_between(10_000_000, 30_000_000), 600);
        assert_eq!(t.bytes_between(30_000_000, 60_000_000), 0);
    }

    /// The recovery-gap query: exact for a port whose first completion is
    /// past the cutoff (the backup-port case), bucket-granular otherwise —
    /// and a bucket straddling the cutoff must not be skipped past.
    #[test]
    fn port_traffic_first_completion_query() {
        let mut t = PortTraffic::new(1_000);
        t.record(12_345, 0, 1);
        t.record(12_900, 0, 1);
        t.record(20_000, 0, 1);
        assert_eq!(t.first_completion_at_or_after(0, 1_000), Some(12_345), "exact first");
        assert_eq!(t.first_completion_at_or_after(0, 15_000), Some(20_000), "bucket start");
        // Cutoff inside a bucket that holds qualifying traffic (12_900):
        // the straddling bucket is reported (clamped), never skipped.
        assert_eq!(t.first_completion_at_or_after(0, 12_500), Some(12_500), "straddle");
        assert_eq!(t.first_completion_at_or_after(0, 25_000), None, "past all traffic");
        assert_eq!(t.first_completion_at_or_after(9, 0), None, "unknown port");
    }

    /// §Soak: the whole monitor set stays O(window capacity) per port over
    /// a soak-length WC stream — the acceptance-criteria growth witness.
    #[test]
    fn monitor_set_memory_bounded_over_soak_length_stream() {
        let mut mon = MonitorSet::new(&VcclConfig::default());
        let msg = 1u64 << 20;
        let mut mem_at_100k = 0usize;
        for i in 0..400_000u64 {
            // ~21us per message → ~8.4 simulated seconds ≫ the 10ms window.
            let t = i * 21_000;
            mon.on_wc(i as usize % 4, SimTime::ns(t), SimTime::ns(t + 21_000), msg, 4 << 20);
            if i == 100_000 {
                mem_at_100k = mon.memory_bytes();
            }
        }
        // Memory after 4× the traffic must not have grown past small
        // allocator slack (capacity rounding), let alone linearly.
        let end = mon.memory_bytes();
        assert!(
            end <= mem_at_100k + mem_at_100k / 2,
            "monitor memory grew with elapsed windows: {mem_at_100k} → {end}"
        );
        // And the exact aggregates kept counting.
        let total: u64 = (0..4).map(|p| mon.samples_total(p)).sum();
        assert_eq!(total, 400_000 - 4 * (VcclConfig::default().window_size as u64 - 1));
        for p in 0..4 {
            assert_eq!(mon.verdict_counts(p).iter().sum::<u64>(), mon.samples_total(p));
        }
        assert_eq!(mon.active_ports(), vec![0, 1, 2, 3]);
    }

    /// Checkpoint round-trip of the full monitor set: a restored set
    /// continues the identical sample/verdict streams on every port.
    #[test]
    fn monitor_set_save_load_round_trip() {
        let cfg = VcclConfig::default();
        let mut a = MonitorSet::new(&cfg);
        let msg = 1u64 << 20;
        for i in 0..5_000u64 {
            let t = i * 21_000;
            let backlog = if i % 40 < 30 { 4 << 20 } else { 64 << 20 };
            a.on_wc(i as usize % 3, SimTime::ns(t), SimTime::ns(t + 21_000), msg, backlog);
        }
        let mut w = crate::util::CkptWriter::new("T", 1);
        a.save(&mut w);
        let text = w.finish();
        let mut b = MonitorSet::new(&cfg);
        let mut r = crate::util::CkptReader::new(&text, "T", 1).unwrap();
        b.load(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(a.processed_wcs, b.processed_wcs);
        for i in 5_000..6_000u64 {
            let t = i * 21_000;
            let backlog = if i % 40 < 30 { 4 << 20 } else { 64 << 20 };
            let va = a.on_wc(i as usize % 3, SimTime::ns(t), SimTime::ns(t + 21_000), msg, backlog);
            let vb = b.on_wc(i as usize % 3, SimTime::ns(t), SimTime::ns(t + 21_000), msg, backlog);
            assert_eq!(va, vb, "verdict diverged at {i}");
        }
        for p in a.active_ports() {
            assert_eq!(a.verdict_counts(p), b.verdict_counts(p));
            assert_eq!(a.samples_total(p), b.samples_total(p));
            assert_eq!(a.verdicts(p), b.verdicts(p));
        }
    }

    /// PortTraffic checkpoint round-trip preserves every aggregate exactly.
    #[test]
    fn port_traffic_save_load_round_trip() {
        let mut a = PortTraffic::new(10_000_000);
        for i in 0..10_000u64 {
            a.record(i * 7_919, (i % 5) as usize, 1 + i % 1000);
        }
        let mut w = crate::util::CkptWriter::new("T", 1);
        a.save(&mut w);
        let text = w.finish();
        let mut b = PortTraffic::new(10_000_000);
        let mut r = crate::util::CkptReader::new(&text, "T", 1).unwrap();
        b.load(&mut r).unwrap();
        r.finish().unwrap();
        for p in 0..5usize {
            let (pa, pb) = (a.port(p).unwrap(), b.port(p).unwrap());
            assert_eq!(pa.first_ns, pb.first_ns);
            assert_eq!(pa.last_ns, pb.last_ns);
            assert_eq!(pa.total_bytes, pb.total_bytes);
            assert_eq!(pa.buckets, pb.buckets);
        }
        assert_eq!(a.bytes_between(0, u64::MAX), b.bytes_between(0, u64::MAX));
    }

    #[test]
    fn non_healthy_verdicts_reach_the_flight_recorder() {
        let mut mon = MonitorSet::new(&VcclConfig::default());
        let sink = TraceSink::new(256, 1_000_000_000);
        mon.set_tracer(Tracer::attached(sink.clone()));
        let msg = 1u64 << 20;
        let mut t = 0u64;
        let mut push = |mon: &mut MonitorSet, gbps: f64, backlog: u64, t: &mut u64| {
            let dur = (msg as f64 / (gbps * 0.125)) as u64;
            let v = mon.on_wc(0, SimTime::ns(*t), SimTime::ns(*t + dur), msg, backlog);
            *t += dur;
            v
        };
        // Steady 390 Gbps with a steady backlog: all-healthy, no records.
        for _ in 0..100 {
            push(&mut mon, 390.0, 4 << 20, &mut t);
        }
        assert!(sink.is_empty(), "healthy traffic must record nothing");
        // Bandwidth collapse WITH pile-up: network anomaly → trace events
        // plus one (throttled) incident snapshot.
        for _ in 0..40 {
            push(&mut mon, 100.0, 64 << 20, &mut t);
        }
        let recs = sink.records();
        assert!(!recs.is_empty(), "anomalous verdicts must be recorded");
        assert!(recs.iter().all(|r| r.ev.kind() == "MonitorVerdict"));
        let incs = sink.incidents();
        assert_eq!(incs.len(), 1, "snapshots throttle to one per window");
        assert!(incs[0].name.contains("network-anomaly"), "{}", incs[0].name);
    }
}
