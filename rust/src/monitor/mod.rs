//! Online network performance monitor (§3.4): O(μs) per-NIC throughput from
//! WR/WC timestamps, window-smoothed, plus the dual-threshold straggler
//! pinpointer.
//!
//! Two estimators, exactly as the paper frames them (Fig 9):
//!
//! - **per-message**: `B = ω(M) / (t₂ − t₁)` — captures transient dynamics
//!   but is hopelessly noisy under concurrent traffic (queuing delay and
//!   bandwidth interleaving pollute `t₂ − t₁`);
//! - **per-window**: over the last `W` messages, `B̄ = Σω(Mᵢ) / (t₂ − t₁)`
//!   with `t₁` = post time of the window's first WR and `t₂` = completion
//!   of its last WC — amortizes queuing noise while staying responsive.
//!   `W = 1` degenerates to per-message; Table 3 uses `W = 8`; Appendix H
//!   shows `W = 32` over-smoothing.
//!
//! The pinpointer (Fig 15) flags a *network* anomaly only when BOTH hold:
//!  (i) windowed bandwidth drops > 50 % below the trailing (~10 ms) average
//!      of the same primitive, and
//! (ii) remaining-to-send (un-ACKed bytes on the NIC) exceeds 2× its
//!      historical max — bandwidth collapse *with* data piling up is a
//!      network problem; collapse with an empty NIC is the upstream
//!      (compute) starving the NIC (GPU interference / normal completion).

pub mod estimator;
pub mod pinpoint;

pub use estimator::{BwSample, MsgRecord, WindowEstimator};
pub use pinpoint::{Pinpointer, Verdict};

use crate::sim::SimTime;
use std::collections::HashMap;

/// Per-port monitor bundle: one estimator + one pinpointer per RNIC port,
/// keyed by an opaque port index (the cluster maps `PortId` → index).
#[derive(Debug)]
pub struct MonitorSet {
    window: usize,
    trailing_ns: u64,
    bw_drop_ratio: f64,
    rts_multiple: f64,
    ports: HashMap<usize, PortMonitor>,
    /// Overhead accounting: CPU-ns charged per processed WC (Table 5).
    pub wc_cost_ns: u64,
    pub processed_wcs: u64,
}

#[derive(Debug)]
pub struct PortMonitor {
    pub estimator: WindowEstimator,
    pub pinpointer: Pinpointer,
}

impl MonitorSet {
    pub fn new(cfg: &crate::config::VcclConfig) -> Self {
        MonitorSet {
            window: cfg.window_size,
            trailing_ns: cfg.trailing_ns,
            bw_drop_ratio: cfg.bw_drop_ratio,
            rts_multiple: cfg.rts_multiple,
            ports: HashMap::new(),
            wc_cost_ns: 150, // ~pair of timestamps + ring push per WC
            processed_wcs: 0,
        }
    }

    fn port(&mut self, port: usize) -> &mut PortMonitor {
        let (w, t, b, r) = (self.window, self.trailing_ns, self.bw_drop_ratio, self.rts_multiple);
        self.ports.entry(port).or_insert_with(|| PortMonitor {
            estimator: WindowEstimator::new(w),
            pinpointer: Pinpointer::new(t, b, r),
        })
    }

    /// Feed one completed message (WR post time, WC completion time, bytes)
    /// plus the port's current backlog. Returns a verdict when the sample
    /// completes a window.
    pub fn on_wc(
        &mut self,
        port: usize,
        posted_at: SimTime,
        completed_at: SimTime,
        bytes: u64,
        backlog_bytes: u64,
    ) -> Option<Verdict> {
        self.processed_wcs += 1;
        let pm = self.port(port);
        let sample = pm.estimator.push(MsgRecord { posted_at, completed_at, bytes })?;
        Some(pm.pinpointer.observe(sample.at, sample.gbps, backlog_bytes))
    }

    /// All samples a port has produced (for the figure outputs).
    pub fn samples(&self, port: usize) -> &[BwSample] {
        self.ports.get(&port).map(|p| p.estimator.samples()).unwrap_or(&[])
    }

    pub fn verdicts(&self, port: usize) -> &[(SimTime, Verdict)] {
        self.ports.get(&port).map(|p| p.pinpointer.log()).unwrap_or(&[])
    }

    /// Total monitor CPU time charged (ns) — the Table 5 overhead metric.
    pub fn cpu_overhead_ns(&self) -> u64 {
        self.processed_wcs * self.wc_cost_ns
    }

    /// Approximate resident memory of the monitor state in bytes
    /// (ring buffers + sample logs) — Table 5's memory column.
    pub fn memory_bytes(&self) -> usize {
        self.ports
            .values()
            .map(|p| p.estimator.memory_bytes() + p.pinpointer.memory_bytes())
            .sum()
    }
}
