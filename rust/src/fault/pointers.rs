//! Transmission/reception pointers and the SyncFifo (§3.3 Fig 8, Table 2).
//!
//! Chunks of a transfer are numbered 0..n. Each side tracks three monotonic
//! pointers over that sequence:
//!
//! ```text
//! sender:    acked ≤ transmitted ≤ posted
//! receiver:  done  ≤ received    ≤ posted
//! ```
//!
//! `done` is synchronized back to the sender as `acked` on every chunk
//! completion, which is what makes the breakpoint well-defined on both
//! sides: everything `< done` is committed to the receiver's application
//! buffer and must NOT be retransmitted; everything in `[done, posted)` is
//! reproducible from the sender's (still-registered) application buffer.

use crate::topology::PortId;

/// Sender-side pointers (Fig 8 left).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SendPointers {
    /// Chunks prepared by the GPU (ready in the application/chunk buffer).
    pub posted: u64,
    /// Chunks for which the proxy invoked `ibv_post_send`.
    pub transmitted: u64,
    /// Chunks whose receipt the receiver acknowledged.
    pub acked: u64,
}

/// Receiver-side pointers (Fig 8 right).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct RecvPointers {
    /// Chunks with a posted receive buffer (CTS granted).
    pub posted: u64,
    /// Chunks for which `ibv_post_recv` consumed data from the wire.
    pub received: u64,
    /// Chunks committed to the application buffer.
    pub done: u64,
}

/// The sender-side synchronization FIFO (Table 2).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SyncFifo {
    /// Offset synchronization for CTS messages.
    pub fifo_head: u64,
    /// The retransmission chunk (== receiver `done` after migration).
    pub restart_pos: u64,
    /// The faulty port, so the sender knows which link to avoid/monitor.
    pub error_port: Option<PortId>,
}

impl SendPointers {
    pub fn invariant_ok(&self) -> bool {
        self.acked <= self.transmitted && self.transmitted <= self.posted
    }
}

impl RecvPointers {
    pub fn invariant_ok(&self) -> bool {
        self.done <= self.received && self.received <= self.posted
    }
}

/// Migrate both sides to the breakpoint (§3.3 "state synchronization and
/// migration"): the receiver retreats `received` to `done`, pushes the
/// agreed restart position into the sender's SyncFifo, and the sender
/// retreats `acked`/`transmitted` to it. Returns how many in-flight chunks
/// were rolled back (these are re-posted on the backup QP).
pub fn migrate_to_breakpoint(
    send: &mut SendPointers,
    recv: &mut RecvPointers,
    fifo: &mut SyncFifo,
) -> u64 {
    debug_assert!(send.invariant_ok() && recv.invariant_ok());
    // The receiver's `done` is the authoritative breakpoint; the sender's
    // `acked` can lag it by the in-flight ACK window, never lead it.
    debug_assert!(send.acked <= recv.done);
    let breakpoint = recv.done;
    let rolled_back = send.transmitted.saturating_sub(breakpoint);
    recv.received = breakpoint;
    fifo.restart_pos = breakpoint;
    fifo.fifo_head = breakpoint;
    send.acked = breakpoint;
    send.transmitted = breakpoint;
    debug_assert!(send.invariant_ok() && recv.invariant_ok());
    rolled_back
}

/// [`migrate_to_breakpoint`] plus flight-recorder instrumentation: records
/// a `PointerMigrated` event and freezes the trailing window into a
/// `failover-conn<N>-port<P>` incident (failovers are exactly the moments
/// the recorder exists for — the port suffix joins the incident to ground
/// truth, and [`crate::trace::Incident::port`] exposes it structurally).
/// `xfer` is the migrating transfer's stable creation ordinal. The
/// untraced function stays the pure state transform; call this one from
/// failover paths that hold a [`crate::trace::Tracer`].
pub fn migrate_to_breakpoint_traced(
    send: &mut SendPointers,
    recv: &mut RecvPointers,
    fifo: &mut SyncFifo,
    tracer: &crate::trace::Tracer,
    at: crate::sim::SimTime,
    conn: usize,
    xfer: u64,
    port: Option<usize>,
) -> u64 {
    let rolled_back = migrate_to_breakpoint(send, recv, fifo);
    if tracer.enabled() {
        let name = match port {
            Some(p) => format!("failover-conn{conn}-port{p}"),
            None => format!("failover-conn{conn}"),
        };
        tracer.record_anomaly(
            at,
            crate::trace::TraceEvent::PointerMigrated {
                conn,
                xfer,
                port,
                breakpoint: fifo.restart_pos,
                rolled_back,
            },
            &name,
        );
    }
    rolled_back
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn migration_rolls_back_exactly_the_inflight_window() {
        let mut s = SendPointers { posted: 20, transmitted: 15, acked: 9 };
        let mut r = RecvPointers { posted: 20, received: 14, done: 10 };
        let mut f = SyncFifo::default();
        let lost = migrate_to_breakpoint(&mut s, &mut r, &mut f);
        assert_eq!(lost, 5); // 10..15 must be retransmitted
        assert_eq!(s.transmitted, 10);
        assert_eq!(s.acked, 10);
        assert_eq!(s.posted, 20); // prepared data is untouched
        assert_eq!(r.received, 10);
        assert_eq!(r.done, 10);
        assert_eq!(f.restart_pos, 10);
    }

    #[test]
    fn traced_migration_records_event_and_freezes_incident() {
        use crate::sim::SimTime;
        use crate::trace::{TraceEvent, TraceSink, Tracer};
        let sink = TraceSink::new(64, 1_000_000);
        let tracer = Tracer::attached(sink.clone());
        let mut s = SendPointers { posted: 20, transmitted: 15, acked: 9 };
        let mut r = RecvPointers { posted: 20, received: 14, done: 10 };
        let mut f = SyncFifo::default();
        let lost = migrate_to_breakpoint_traced(
            &mut s,
            &mut r,
            &mut f,
            &tracer,
            SimTime::ms(5),
            3,
            42,
            Some(6),
        );
        assert_eq!(lost, 5);
        let recs = sink.records();
        assert_eq!(recs.len(), 1);
        assert_eq!(
            recs[0].ev,
            TraceEvent::PointerMigrated {
                conn: 3,
                xfer: 42,
                port: Some(6),
                breakpoint: 10,
                rolled_back: 5
            }
        );
        let incs = sink.incidents();
        assert_eq!(incs.len(), 1);
        assert_eq!(incs[0].name, "failover-conn3-port6");
        assert_eq!(incs[0].port(), Some(6));
        assert_eq!(incs[0].conn(), Some(3));
        // The disabled tracer is a pure pass-through.
        let mut s2 = SendPointers { posted: 20, transmitted: 15, acked: 9 };
        let mut r2 = RecvPointers { posted: 20, received: 14, done: 10 };
        let mut f2 = SyncFifo::default();
        let lost2 = migrate_to_breakpoint_traced(
            &mut s2,
            &mut r2,
            &mut f2,
            &Tracer::disabled(),
            SimTime::ms(5),
            3,
            42,
            None,
        );
        assert_eq!(lost2, 5);
        assert_eq!((s2, r2), (s, r));
    }

    #[test]
    fn migration_is_idempotent_at_breakpoint() {
        let mut s = SendPointers { posted: 7, transmitted: 7, acked: 7 };
        let mut r = RecvPointers { posted: 7, received: 7, done: 7 };
        let mut f = SyncFifo::default();
        assert_eq!(migrate_to_breakpoint(&mut s, &mut r, &mut f), 0);
        assert_eq!(s.transmitted, 7);
    }

    /// Property: for random consistent pointer states, migration never
    /// loses a committed chunk, never duplicates one, and restores all
    /// invariants. (proptest is unavailable offline; this is an RNG-driven
    /// equivalent with 10k cases.)
    #[test]
    fn migration_property_no_loss_no_duplicate() {
        let mut rng = Rng::new(0xFA01);
        for _ in 0..10_000 {
            let posted = rng.below(100) + 1;
            let transmitted = rng.below(posted + 1);
            // acked ≤ transmitted; receiver done ∈ [acked, received]
            let acked = rng.below(transmitted + 1);
            let received = rng.range(transmitted.saturating_sub(2).max(acked), transmitted);
            let done = rng.range(acked, received);
            let mut s = SendPointers { posted, transmitted, acked };
            let mut r = RecvPointers { posted, received, done };
            assert!(s.invariant_ok() && r.invariant_ok());
            let mut f = SyncFifo::default();
            let lost = migrate_to_breakpoint(&mut s, &mut r, &mut f);
            // No committed chunk rolled back:
            assert_eq!(r.done, done);
            assert!(s.transmitted == done && s.acked == done);
            // Rolled-back count is exactly the un-committed transmitted window:
            assert_eq!(lost, transmitted - done);
            // Retransmission resumes at the breakpoint — no duplicates below it:
            assert_eq!(f.restart_pos, done);
            assert!(s.invariant_ok() && r.invariant_ok());
        }
    }
}
