//! Case-2 failure perception: the δ-timeout "double-check" (§3.3, Fig 7b).
//!
//! Scenario: the receiver sent CTS, the port died before the data landed.
//! The *sender* will eventually see a WC retry error, but the *receiver*
//! has no local error — it would wait forever. VCCL's fix: when a WR is
//! issued, the receiver records its timestamp and watches for the WC. If
//! none arrives within δ (slightly larger than the hardware retry window,
//! to absorb queuing/propagation), the receiver re-probes the link with a
//! fresh CTS:
//!
//! - probe path dead  → generate a local WC error → failover (case 1 path);
//! - probe path alive → the sender is merely stalled on upstream
//!   dependencies (common in collectives) → benign, re-arm.

use crate::sim::SimTime;

/// Verdict of a δ-probe.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ProbeVerdict {
    /// No probe was due (progress happened, or nothing outstanding).
    NotDue,
    /// Probe ran, the link answered: sender stalled upstream — benign.
    SenderStalled,
    /// Probe ran, the link is dead: declare failure.
    LinkDead,
}

/// Per-connection receiver-side δ-timer.
#[derive(Debug, Clone)]
pub struct DeltaProbe {
    delta_ns: u64,
    /// Time the oldest outstanding expected chunk was CTS'd; None = idle.
    waiting_since: Option<SimTime>,
    /// Epoch guard for scheduled checks.
    pub epoch: u32,
}

impl DeltaProbe {
    /// δ = margin × hardware retry window (margin > 1, Table 3 semantics:
    /// "slightly larger than the retry-timeout threshold").
    pub fn new(retry_window_ns: u64, margin: f64) -> Self {
        DeltaProbe {
            delta_ns: (retry_window_ns as f64 * margin) as u64,
            waiting_since: None,
            epoch: 0,
        }
    }

    pub fn delta_ns(&self) -> u64 {
        self.delta_ns
    }

    /// Receiver granted CTS / expects data: arm if idle. Returns the
    /// deadline to schedule a check at (with the current epoch), if newly
    /// armed.
    pub fn arm(&mut self, now: SimTime) -> Option<(SimTime, u32)> {
        if self.waiting_since.is_some() {
            return None;
        }
        self.waiting_since = Some(now);
        self.epoch += 1;
        Some((now + SimTime::ns(self.delta_ns), self.epoch))
    }

    /// A chunk WC arrived: progress. Re-arms if more are outstanding.
    /// Returns a fresh deadline when re-armed.
    pub fn on_progress(&mut self, now: SimTime, more_outstanding: bool) -> Option<(SimTime, u32)> {
        self.waiting_since = None;
        self.epoch += 1;
        if more_outstanding {
            self.arm(now)
        } else {
            None
        }
    }

    /// Transfer finished / failed over: disarm.
    pub fn disarm(&mut self) {
        self.waiting_since = None;
        self.epoch += 1;
    }

    /// The scheduled check fired. `link_alive` is the result of the CTS
    /// re-probe (is the active QP's path up?).
    pub fn check(&mut self, epoch: u32, now: SimTime, link_alive: bool) -> ProbeVerdict {
        if epoch != self.epoch {
            return ProbeVerdict::NotDue;
        }
        let Some(since) = self.waiting_since else { return ProbeVerdict::NotDue };
        if now.since(since).as_ns() < self.delta_ns {
            return ProbeVerdict::NotDue;
        }
        if link_alive {
            // Benign: sender blocked on upstream compute/comm dependency.
            // Stay armed from now (fresh window).
            self.waiting_since = Some(now);
            self.epoch += 1;
            ProbeVerdict::SenderStalled
        } else {
            self.disarm();
            ProbeVerdict::LinkDead
        }
    }

    /// Next check deadline if armed (for re-scheduling after SenderStalled).
    pub fn next_deadline(&self) -> Option<(SimTime, u32)> {
        self.waiting_since.map(|s| (s + SimTime::ns(self.delta_ns), self.epoch))
    }

    /// Whether a δ-window is currently running (§Soak checkpointing asserts
    /// probes are disarmed at an op-quiescent boundary).
    pub fn is_armed(&self) -> bool {
        self.waiting_since.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn probe() -> DeltaProbe {
        DeltaProbe::new(1_000_000, 1.25) // δ = 1.25ms
    }

    #[test]
    fn delta_exceeds_retry_window() {
        let p = probe();
        assert!(p.delta_ns() > 1_000_000);
    }

    #[test]
    fn dead_link_detected_only_after_delta() {
        let mut p = probe();
        let (deadline, epoch) = p.arm(SimTime::ZERO).unwrap();
        assert_eq!(deadline.as_ns(), 1_250_000);
        // Early check (stale epoch path not taken — same epoch, early time).
        assert_eq!(p.check(epoch, SimTime::us(100), false), ProbeVerdict::NotDue);
        assert_eq!(p.check(epoch, deadline, false), ProbeVerdict::LinkDead);
    }

    #[test]
    fn live_link_is_benign_and_rearms() {
        let mut p = probe();
        let (deadline, epoch) = p.arm(SimTime::ZERO).unwrap();
        assert_eq!(p.check(epoch, deadline, true), ProbeVerdict::SenderStalled);
        // Re-armed with a fresh window from `deadline`.
        let (next, e2) = p.next_deadline().unwrap();
        assert_eq!(next, deadline + SimTime::ns(p.delta_ns()));
        // The old epoch is dead.
        assert_eq!(p.check(epoch, next, false), ProbeVerdict::NotDue);
        assert_eq!(p.check(e2, next, false), ProbeVerdict::LinkDead);
    }

    #[test]
    fn progress_cancels_pending_check() {
        let mut p = probe();
        let (deadline, epoch) = p.arm(SimTime::ZERO).unwrap();
        let _ = p.on_progress(SimTime::us(500), false);
        assert_eq!(p.check(epoch, deadline, false), ProbeVerdict::NotDue);
    }

    #[test]
    fn progress_with_more_outstanding_rearms() {
        let mut p = probe();
        let _ = p.arm(SimTime::ZERO).unwrap();
        let next = p.on_progress(SimTime::us(500), true);
        let (at, _) = next.unwrap();
        assert_eq!(at.as_ns(), 500_000 + 1_250_000);
    }

    #[test]
    fn double_arm_is_noop() {
        let mut p = probe();
        assert!(p.arm(SimTime::ZERO).is_some());
        assert!(p.arm(SimTime::us(1)).is_none());
    }
}
