//! Primary-backup QP fault tolerance (§3.3).
//!
//! The mechanism has four parts, all reproduced here:
//!
//! 1. **Backup QP creation** — at bootstrap every inter-node connection gets
//!    a backup QP on the *second-closest* RNIC (or the other port of a
//!    dual-port RNIC, same hardware distance). Placement comes from
//!    [`crate::topology::Cluster::backup_port`].
//! 2. **Failure perception** — receiver-driven, two triggers:
//!    *Case 1* (Fig 7a): the hardware exhausts IB_RETRY_CNT×timeout and the
//!    RNIC surfaces a `RetryExceeded` WC to the proxy.
//!    *Case 2* (Fig 7b): the port dies after CTS was delivered; the sender
//!    sees the WC error but the receiver does not. The receiver arms a
//!    δ-timer per expected chunk; on expiry it re-probes the link (CTS
//!    resend) and only declares failure if the probe path is dead — the
//!    "double-check" that avoids misclassifying a stalled upstream sender.
//! 3. **State synchronization & migration** — three pointers per side
//!    (posted/transmitted/acked ⇄ posted/received/done) plus the
//!    [`SyncFifo`] (Table 2). Migration retreats both sides to the agreed
//!    breakpoint so the backup QP resumes exactly at the first un-committed
//!    chunk: no loss, no duplicate delivery.
//! 4. **Failback** — on port recovery the primary QP is already mid-warm-up
//!    (VCCL resets it *proactively at failure perception* to mask the
//!    seconds-scale hardware warm-up), so traffic returns as soon as it is
//!    warm and the port is up.

pub mod pointers;
pub mod perception;

pub use perception::{DeltaProbe, ProbeVerdict};
pub use pointers::{
    migrate_to_breakpoint, migrate_to_breakpoint_traced, RecvPointers, SendPointers, SyncFifo,
};

use crate::net::QpId;
use crate::topology::PortId;

/// Which QP a connection currently transmits on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActiveQp {
    Primary,
    Backup,
}

/// Fault-tolerance state attached to one inter-node connection.
#[derive(Debug)]
pub struct ConnFt {
    pub primary: QpId,
    pub backup: QpId,
    pub primary_port: PortId,
    pub backup_port: PortId,
    pub active: ActiveQp,
    pub send: SendPointers,
    pub recv: RecvPointers,
    pub fifo: SyncFifo,
    /// Bumped on every failover/failback so stale WCs are discarded.
    pub epoch: u32,
    /// Set while the primary is erroring/warming and we wait to fail back.
    pub awaiting_failback: bool,
}

impl ConnFt {
    pub fn new(primary: QpId, backup: QpId, primary_port: PortId, backup_port: PortId) -> Self {
        ConnFt {
            primary,
            backup,
            primary_port,
            backup_port,
            active: ActiveQp::Primary,
            send: SendPointers::default(),
            recv: RecvPointers::default(),
            fifo: SyncFifo::default(),
            epoch: 0,
            awaiting_failback: false,
        }
    }

    pub fn active_qp(&self) -> QpId {
        match self.active {
            ActiveQp::Primary => self.primary,
            ActiveQp::Backup => self.backup,
        }
    }

    pub fn active_port(&self) -> PortId {
        match self.active {
            ActiveQp::Primary => self.primary_port,
            ActiveQp::Backup => self.backup_port,
        }
    }

    /// Failover: migrate state to the breakpoint and switch to the backup.
    /// Returns the number of chunks that must be re-posted (the in-flight
    /// window that was lost with the primary).
    pub fn failover(&mut self, error_port: PortId) -> u64 {
        let lost = migrate_to_breakpoint(&mut self.send, &mut self.recv, &mut self.fifo);
        self.fifo.error_port = Some(error_port);
        self.active = ActiveQp::Backup;
        self.awaiting_failback = true;
        self.epoch += 1;
        lost
    }

    /// Failback: primary port is healthy again and its QP is warm.
    pub fn failback(&mut self) {
        debug_assert_eq!(self.active, ActiveQp::Backup);
        self.active = ActiveQp::Primary;
        self.awaiting_failback = false;
        self.fifo.error_port = None;
        self.epoch += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{NicId, NodeId};

    fn port(n: usize, nic: usize) -> PortId {
        PortId { nic: NicId { node: NodeId(n), local: nic }, port: 0 }
    }

    fn conn() -> ConnFt {
        ConnFt::new(QpId(0), QpId(1), port(0, 0), port(0, 1))
    }

    #[test]
    fn failover_switches_and_counts_lost_window() {
        let mut c = conn();
        // 10 chunks posted, 8 transmitted, 5 acked; receiver committed 5.
        c.send.posted = 10;
        c.send.transmitted = 8;
        c.send.acked = 5;
        c.recv.posted = 10;
        c.recv.received = 8;
        c.recv.done = 5;
        let lost = c.failover(port(0, 0));
        assert_eq!(lost, 3); // chunks 5..8 were in flight
        assert_eq!(c.active, ActiveQp::Backup);
        assert_eq!(c.active_qp(), QpId(1));
        assert_eq!(c.send.transmitted, 5);
        assert_eq!(c.recv.received, 5);
        assert_eq!(c.fifo.restart_pos, 5);
        assert_eq!(c.fifo.error_port, Some(port(0, 0)));
        assert!(c.awaiting_failback);
    }

    #[test]
    fn failback_restores_primary() {
        let mut c = conn();
        c.failover(port(0, 0));
        let e = c.epoch;
        c.failback();
        assert_eq!(c.active, ActiveQp::Primary);
        assert_eq!(c.active_qp(), QpId(0));
        assert!(!c.awaiting_failback);
        assert_eq!(c.epoch, e + 1);
        assert_eq!(c.fifo.error_port, None);
    }

    #[test]
    fn epoch_bumps_invalidate_stale_wcs() {
        let mut c = conn();
        let e0 = c.epoch;
        c.failover(port(0, 0));
        assert!(c.epoch > e0);
    }
}
