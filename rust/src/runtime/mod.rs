//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! This is the boundary of the three-layer architecture: Python/JAX runs
//! ONCE at build time (`python -m compile.aot --out artifacts`) and never on
//! the training path; from here on the rust binary is self-contained. The
//! interchange format is HLO **text** rather than a serialized
//! `HloModuleProto`: pinned xla_extension builds (0.5.x) reject the 64-bit
//! instruction ids that jax≥0.5 emits in its protos, while the HLO text
//! parser reassigns ids cleanly on load — so text is the only format that is
//! stable across the Python and Rust sides of the pipeline. See DESIGN.md,
//! "PJRT runtime and the HLO text fallback", for the full rationale and the
//! artifact layout.
//!
//! The actual `xla` crate (PJRT bindings over xla_extension) is optional:
//! builds without the `xla` cargo feature get a stub [`ModelRuntime`] whose
//! `load` fails with a clear message, keeping every simulation-side
//! experiment — the entire `vccl exp` / `vccl bench` surface — fully
//! functional offline.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

/// Minimal metadata mirror of `artifacts/meta_<preset>.json`.
#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub preset: String,
    pub flat_len: usize,
    pub batch: usize,
    pub seq_len: usize,
    pub param_count: usize,
    pub vocab: i32,
}

impl ArtifactMeta {
    /// Parse the (small, flat) JSON without a serde dependency.
    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let grab = |key: &str| -> Result<u64> {
            json_number(&text, key).ok_or_else(|| anyhow!("missing {key} in {}", path.display()))
        };
        let preset = json_string(&text, "preset")
            .ok_or_else(|| anyhow!("missing preset in {}", path.display()))?;
        Ok(ArtifactMeta {
            preset,
            flat_len: grab("flat_len")? as usize,
            batch: grab("batch")? as usize,
            seq_len: grab("seq_len")? as usize,
            param_count: grab("param_count")? as usize,
            vocab: grab("vocab")? as i32,
        })
    }
}

/// Extract the first `"key": <number>` occurrence.
fn json_number(text: &str, key: &str) -> Option<u64> {
    let pat = format!("\"{key}\":");
    let at = text.find(&pat)? + pat.len();
    let rest = text[at..].trim_start();
    let end = rest.find(|c: char| !c.is_ascii_digit()).unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Extract the first `"key": "value"` occurrence.
fn json_string(text: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let at = text.find(&pat)? + pat.len();
    let rest = text[at..].trim_start().strip_prefix('"')?;
    Some(rest[..rest.find('"')?].to_string())
}

/// A compiled model runtime: the PJRT CPU client plus the train-step and
/// loss executables for one preset. Without the `xla` feature this is a
/// stub that can never be constructed (`load` always errors), which keeps
/// the [`crate::train`] driver compiling and lets it surface a precise
/// "built without PJRT" error at runtime instead of a build failure.
pub struct ModelRuntime {
    pub meta: ArtifactMeta,
    #[cfg(feature = "xla")]
    client: xla::PjRtClient,
    #[cfg(feature = "xla")]
    train_step: xla::PjRtLoadedExecutable,
    #[cfg(feature = "xla")]
    loss: xla::PjRtLoadedExecutable,
}

/// Full training state living on the Rust side (no Python at runtime).
pub struct TrainState {
    pub flat: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: u64,
}

impl ModelRuntime {
    /// Deterministic initial state (GPT-2-style N(0, 0.02) weights). The
    /// loss-curve experiments compare transports with the SAME Rust init,
    /// so curves are directly comparable (Fig 12's point: identical
    /// numerics whichever CCL moves the tensors).
    pub fn init_state(&self, seed: u64) -> TrainState {
        let n = self.meta.flat_len;
        let mut rng = crate::util::Rng::new(seed);
        let mut flat = Vec::with_capacity(n);
        for _ in 0..n {
            flat.push((rng.normal(0.0, 0.02)) as f32);
        }
        TrainState { flat, m: vec![0.0; n], v: vec![0.0; n], step: 0 }
    }
}

#[cfg(feature = "xla")]
impl ModelRuntime {
    /// Load artifacts for `preset` from `artifact_dir`.
    pub fn load(artifact_dir: &Path, preset: &str) -> Result<Self> {
        let meta = ArtifactMeta::load(&artifact_dir.join(format!("meta_{preset}.json")))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = artifact_dir.join(format!("{name}_{preset}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e:?}"))
        };
        let train_step = compile("train_step")?;
        let loss = compile("loss")?;
        Ok(ModelRuntime { meta, client, train_step, loss })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn tokens_literal(&self, toks: &[i32]) -> Result<xla::Literal> {
        let (b, l) = (self.meta.batch as i64, self.meta.seq_len as i64);
        anyhow::ensure!(toks.len() == (b * l) as usize, "token buffer shape");
        xla::Literal::vec1(toks)
            .reshape(&[b, l])
            .map_err(|e| anyhow!("tokens reshape: {e:?}"))
    }

    /// One optimizer step on (tokens, targets); returns the loss.
    pub fn train_step(&self, st: &mut TrainState, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        st.step += 1;
        let inputs = [
            xla::Literal::vec1(st.flat.as_slice()),
            xla::Literal::vec1(st.m.as_slice()),
            xla::Literal::vec1(st.v.as_slice()),
            xla::Literal::scalar(st.step as f32),
            self.tokens_literal(tokens)?,
            self.tokens_literal(targets)?,
        ];
        let result = self
            .train_step
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute train_step: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        anyhow::ensure!(parts.len() == 4, "expected 4 outputs, got {}", parts.len());
        let mut it = parts.into_iter();
        st.flat = it.next().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        st.m = it.next().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        st.v = it.next().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?;
        let loss = it.next().unwrap().to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0];
        Ok(loss)
    }

    /// Evaluate the loss without updating state.
    pub fn eval_loss(&self, st: &TrainState, tokens: &[i32], targets: &[i32]) -> Result<f32> {
        let inputs = [
            xla::Literal::vec1(st.flat.as_slice()),
            self.tokens_literal(tokens)?,
            self.tokens_literal(targets)?,
        ];
        let result = self
            .loss
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute loss: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch loss: {e:?}"))?;
        let l = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        Ok(l.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?[0])
    }
}

#[cfg(not(feature = "xla"))]
impl ModelRuntime {
    /// Stub: validate the artifact metadata (so missing AOT artifacts
    /// still produce the familiar error), then report that PJRT execution
    /// is not compiled in.
    pub fn load(artifact_dir: &Path, preset: &str) -> Result<Self> {
        let meta = ArtifactMeta::load(&artifact_dir.join(format!("meta_{preset}.json")))?;
        Err(anyhow!(
            "artifacts for preset {:?} found, but this binary was built without the \
             `xla` cargo feature, so PJRT execution is unavailable; rebuild with \
             `--features xla` after vendoring the xla crate (DESIGN.md, \"PJRT \
             runtime and the HLO text fallback\"). Simulation experiments \
             (`vccl exp`, `vccl bench`) do not need it.",
            meta.preset
        ))
    }

    pub fn platform(&self) -> String {
        "unavailable (built without the xla feature)".to_string()
    }

    /// Stub: unreachable in practice — `load` never constructs the stub.
    pub fn train_step(
        &self,
        _st: &mut TrainState,
        _tokens: &[i32],
        _targets: &[i32],
    ) -> Result<f32> {
        Err(anyhow!("PJRT unavailable: built without the `xla` feature"))
    }

    /// Stub: unreachable in practice — `load` never constructs the stub.
    pub fn eval_loss(&self, _st: &TrainState, _tokens: &[i32], _targets: &[i32]) -> Result<f32> {
        Err(anyhow!("PJRT unavailable: built without the `xla` feature"))
    }
}

/// Synthetic corpus matching `model.synthetic_batch`'s bigram grammar:
/// next = (3·tok + noise) mod V. Gives the model real structure to learn.
pub fn synthetic_batch(batch: usize, seq: usize, vocab: i32, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = crate::util::Rng::new(seed);
    let mut tokens = Vec::with_capacity(batch * seq);
    let mut targets = Vec::with_capacity(batch * seq);
    for _ in 0..batch {
        let mut tok = rng.below(vocab as u64) as i32;
        for _ in 0..seq {
            tokens.push(tok);
            let noise = rng.below(7) as i32;
            tok = (3 * tok + noise).rem_euclid(vocab);
            targets.push(tok);
        }
    }
    (tokens, targets)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_helpers() {
        let text = r#"{"preset": "tiny", "flat_len": 134912, "batch": 2, "nested": {"x": 1}}"#;
        assert_eq!(json_number(text, "flat_len"), Some(134912));
        assert_eq!(json_number(text, "batch"), Some(2));
        assert_eq!(json_string(text, "preset").as_deref(), Some("tiny"));
        assert_eq!(json_number(text, "missing"), None);
    }

    #[test]
    fn synthetic_batch_in_range_and_deterministic() {
        let (t1, g1) = synthetic_batch(2, 16, 512, 42);
        let (t2, _) = synthetic_batch(2, 16, 512, 42);
        assert_eq!(t1, t2);
        assert_eq!(t1.len(), 32);
        assert!(t1.iter().chain(g1.iter()).all(|&x| (0..512).contains(&x)));
        // Bigram structure: target[i] derives from token[i].
        for i in 0..16 {
            let d = (g1[i] - 3 * t1[i]).rem_euclid(512);
            assert!(d < 7, "grammar violated at {i}");
        }
    }

    #[test]
    fn meta_parse_roundtrip() {
        let dir = std::env::temp_dir().join("vccl_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("meta_x.json");
        std::fs::write(
            &p,
            r#"{"preset": "x", "model": {"vocab": 512, "param_count": 99}, "flat_len": 5, "batch": 2, "seq_len": 8}"#,
        )
        .unwrap();
        let m = ArtifactMeta::load(&p).unwrap();
        assert_eq!((m.flat_len, m.batch, m.seq_len, m.param_count), (5, 2, 8, 99));
        assert_eq!(m.vocab, 512);
        assert_eq!(m.preset, "x");
    }

    #[test]
    fn missing_artifacts_error_is_actionable() {
        let dir = std::env::temp_dir().join("vccl_no_artifacts_here");
        let e = match ModelRuntime::load(&dir, "tiny") {
            Err(e) => e,
            Ok(_) => panic!("load must fail without artifacts"),
        };
        assert!(e.to_string().contains("meta_tiny.json"), "{e}");
    }

    /// Full PJRT round trip — only compiled with the `xla` feature and only
    /// runs when the tiny artifacts exist
    /// (`python -m compile.aot --out rust/artifacts --presets tiny`). Kept
    /// as a test so PJRT-enabled builds exercise the Python→HLO→rust path
    /// end to end.
    #[cfg(feature = "xla")]
    #[test]
    fn pjrt_train_step_descends_loss() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("meta_tiny.json").exists() {
            eprintln!("skipping: generate the AOT artifacts first");
            return;
        }
        let rt = ModelRuntime::load(&dir, "tiny").expect("load artifacts");
        assert_eq!(rt.platform().to_lowercase(), "cpu");
        let mut st = rt.init_state(7);
        let (toks, tgts) =
            synthetic_batch(rt.meta.batch, rt.meta.seq_len, rt.meta.vocab, 1);
        let l0 = rt.eval_loss(&st, &toks, &tgts).unwrap();
        let mut last = l0;
        for _ in 0..10 {
            last = rt.train_step(&mut st, &toks, &tgts).unwrap();
        }
        assert!(last.is_finite() && l0.is_finite());
        assert!(last < l0, "loss must descend: {l0} -> {last}");
    }
}
