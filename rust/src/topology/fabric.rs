//! The link fabric: NVLink inside servers, a two-tier rail-optimized CLOS
//! between them.
//!
//! Links are *unidirectional* capacity units; a flow's path is an ordered
//! list of link ids. Modelling directions separately matters: the paper's
//! CTS credit messages travel receiver→sender while the payload goes
//! sender→receiver, and a port-down kills both at once.
//!
//! Rail-optimized wiring (the §4 cluster): NIC *i* of every server connects
//! to leaf switch *i* ("rail *i*"). Same-rail traffic crosses one leaf;
//! cross-rail traffic transits the spine trunk. 1:1 oversubscription means
//! the spine trunk never bottlenecks before the NIC uplinks do, but it
//! *shares* — which is how incast shows up.



use super::{GpuId, NicId, PortId};
use crate::config::TopologyConfig;
use crate::util::{CkptReader, CkptWriter};

/// Index into the fabric's link table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// NIC port → leaf (tx) or leaf → NIC port (rx). Capacity = line rate.
    NicUplinkTx,
    NicUplinkRx,
    /// Aggregated leaf↔spine trunk (1:1 oversubscription → capacity =
    /// nodes × line rate per direction).
    SpineTrunkUp,
    SpineTrunkDown,
    /// Per-GPU NVLink egress / ingress.
    NvlinkTx,
    NvlinkRx,
}

/// One unidirectional link.
#[derive(Debug, Clone)]
pub struct Link {
    pub kind: LinkKind,
    pub capacity_gbps: f64,
    pub up: bool,
}

/// An ordered list of links a flow traverses, plus the hop count used for
/// the propagation-latency part of the flow model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Path {
    pub links: Vec<LinkId>,
    pub hops: u32,
}

impl Path {
    pub fn empty() -> Self {
        Path { links: Vec::new(), hops: 0 }
    }
}

/// The complete link table with id arithmetic for addressing.
#[derive(Debug, Clone)]
pub struct Fabric {
    links: Vec<Link>,
    nodes: usize,
    nics_per_node: usize,
    ports_per_nic: usize,
    gpus_per_node: usize,
    rails: usize,
    // Layout offsets into `links`:
    // [0 .. n_ports*2)                    NIC uplinks (tx, rx interleaved)
    // [uplinks .. +rails*planes*2)        spine trunks (up, down per leaf)
    // [trunks .. +n_gpus*2)               NVLink (tx, rx per GPU)
    trunk_base: usize,
    nvlink_base: usize,
    link_gbps: f64,
    nvlink_gbps: f64,
}

impl Fabric {
    pub fn build(cfg: &TopologyConfig) -> Self {
        Self::build_with_rates(cfg, 400.0, 3600.0)
    }

    pub fn build_with_rates(cfg: &TopologyConfig, link_gbps: f64, nvlink_gbps: f64) -> Self {
        let ports_per_nic = if cfg.dual_port_nics { 2 } else { 1 };
        let n_ports = cfg.num_nodes * cfg.nics_per_node * ports_per_nic;
        let planes = ports_per_nic; // dual-port → dual-plane deployment (§4.2)
        let n_leaves = cfg.rails * planes;
        let n_gpus = cfg.num_nodes * cfg.gpus_per_node;

        let mut links = Vec::with_capacity(n_ports * 2 + n_leaves * 2 + n_gpus * 2);
        for _ in 0..n_ports {
            links.push(Link { kind: LinkKind::NicUplinkTx, capacity_gbps: link_gbps, up: true });
            links.push(Link { kind: LinkKind::NicUplinkRx, capacity_gbps: link_gbps, up: true });
        }
        let trunk_base = links.len();
        let trunk_cap = cfg.num_nodes as f64 * link_gbps; // 1:1 oversubscription
        for _ in 0..n_leaves {
            links.push(Link { kind: LinkKind::SpineTrunkUp, capacity_gbps: trunk_cap, up: true });
            links.push(Link {
                kind: LinkKind::SpineTrunkDown,
                capacity_gbps: trunk_cap,
                up: true,
            });
        }
        let nvlink_base = links.len();
        for _ in 0..n_gpus {
            links.push(Link { kind: LinkKind::NvlinkTx, capacity_gbps: nvlink_gbps, up: true });
            links.push(Link { kind: LinkKind::NvlinkRx, capacity_gbps: nvlink_gbps, up: true });
        }

        Fabric {
            links,
            nodes: cfg.num_nodes,
            nics_per_node: cfg.nics_per_node,
            ports_per_nic,
            gpus_per_node: cfg.gpus_per_node,
            rails: cfg.rails,
            trunk_base,
            nvlink_base,
            link_gbps,
            nvlink_gbps,
        }
    }

    /// Stable ordinal of a port (dense, 0-based) — used as the monitor's
    /// per-port key and for trace labelling.
    pub fn port_ordinal(&self, p: PortId) -> usize {
        self.port_index(p)
    }

    fn port_index(&self, p: PortId) -> usize {
        debug_assert!((p.port as usize) < self.ports_per_nic, "port {} out of range", p);
        (p.nic.node.0 * self.nics_per_node + p.nic.local) * self.ports_per_nic + p.port as usize
    }

    /// Transmit-direction uplink of a NIC port.
    pub fn port_tx(&self, p: PortId) -> LinkId {
        LinkId(self.port_index(p) * 2)
    }

    /// Receive-direction downlink of a NIC port.
    pub fn port_rx(&self, p: PortId) -> LinkId {
        LinkId(self.port_index(p) * 2 + 1)
    }

    /// Both unidirectional links of a NIC port `(tx, rx)` — the unit a
    /// physical port flap touches, and the seed set for one batched
    /// component recompute in the fluid allocator.
    ///
    /// Link ids are dense, stable and never reused for the lifetime of the
    /// fabric (the layout offsets above are fixed at build time). That
    /// stability is load-bearing: `net::FlowNet` keeps `Vec`-indexed
    /// per-link state (reverse flow index, incast sender counts, component
    /// stamps) keyed directly by `LinkId` and walks adjacency through it.
    pub fn port_links(&self, p: PortId) -> [LinkId; 2] {
        [self.port_tx(p), self.port_rx(p)]
    }

    fn leaf_index(&self, rail: usize, plane: usize) -> usize {
        rail * self.ports_per_nic + plane
    }

    pub fn trunk_up(&self, rail: usize, plane: usize) -> LinkId {
        LinkId(self.trunk_base + self.leaf_index(rail, plane) * 2)
    }

    pub fn trunk_down(&self, rail: usize, plane: usize) -> LinkId {
        LinkId(self.trunk_base + self.leaf_index(rail, plane) * 2 + 1)
    }

    fn gpu_index(&self, g: GpuId) -> usize {
        g.node.0 * self.gpus_per_node + g.local
    }

    pub fn nvlink_tx(&self, g: GpuId) -> LinkId {
        LinkId(self.nvlink_base + self.gpu_index(g) * 2)
    }

    pub fn nvlink_rx(&self, g: GpuId) -> LinkId {
        LinkId(self.nvlink_base + self.gpu_index(g) * 2 + 1)
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    pub fn line_rate_gbps(&self) -> f64 {
        self.link_gbps
    }

    pub fn nvlink_gbps(&self) -> f64 {
        self.nvlink_gbps
    }

    /// Bring a NIC port up/down (both directions at once — an optical-module
    /// failure kills the physical port).
    pub fn set_port_up(&mut self, p: PortId, up: bool) {
        let tx = self.port_tx(p);
        let rx = self.port_rx(p);
        self.links[tx.0].up = up;
        self.links[rx.0].up = up;
    }

    pub fn port_up(&self, p: PortId) -> bool {
        self.links[self.port_tx(p).0].up
    }

    /// Bring a single unidirectional link up/down. This is the trunk-level
    /// fault primitive: a dead trunk kills *paths* while both endpoint
    /// ports stay up — the fault class the §Fault-domains machinery
    /// perceives as path-death rather than port-death.
    pub fn set_link_up(&mut self, l: LinkId, up: bool) {
        self.links[l.0].up = up;
    }

    pub fn link_up(&self, l: LinkId) -> bool {
        self.links[l.0].up
    }

    /// Whether every link on the path is up.
    pub fn path_up(&self, path: &Path) -> bool {
        path.links.iter().all(|&l| self.links[l.0].up)
    }

    /// First dead link on a path, if any — names the fault-domain member
    /// that killed the path (trace labelling for `PathMigrated`).
    pub fn first_dead_link(&self, path: &Path) -> Option<LinkId> {
        path.links.iter().copied().find(|&l| !self.links[l.0].up)
    }

    /// Is this link a spine trunk (either direction)?
    pub fn is_trunk(&self, l: LinkId) -> bool {
        (self.trunk_base..self.nvlink_base).contains(&l.0)
    }

    /// Number of fabric planes (dual-port NICs ⇒ dual-plane deployment).
    pub fn planes(&self) -> usize {
        self.ports_per_nic
    }

    // ------------------------------------------------------------------
    // Switch entities (§Fault domains)
    //
    // The fabric's switches are first-class fault domains that *own* their
    // member links: leaf switch `leaf_index(rail, plane)` owns every NIC
    // uplink pair on that (rail, plane) plus its trunk pair; spine plane
    // `num_leaf_switches() + plane` owns every trunk pair in the plane.
    // Killing a switch cascades to its members, which is what makes
    // switch-level faults expressible on the existing link table.
    // ------------------------------------------------------------------

    /// Leaf switches: one per (rail, plane), id = `rail * planes + plane`.
    pub fn num_leaf_switches(&self) -> usize {
        self.rails * self.ports_per_nic
    }

    /// All switch entities: leaves first, then one spine plane per plane.
    pub fn num_switches(&self) -> usize {
        self.num_leaf_switches() + self.ports_per_nic
    }

    /// Member links of a switch (leaf: uplinks of its rail+plane + its
    /// trunks; spine plane: every trunk pair in the plane). Sorted by id.
    pub fn switch_links(&self, s: usize) -> Vec<LinkId> {
        let n_leaves = self.num_leaf_switches();
        let mut out = Vec::new();
        if s < n_leaves {
            let (rail, plane) = (s / self.ports_per_nic, s % self.ports_per_nic);
            for node in 0..self.nodes {
                for local in 0..self.nics_per_node {
                    if local % self.rails != rail {
                        continue;
                    }
                    let p = PortId {
                        nic: NicId { node: super::NodeId(node), local },
                        port: plane as u8,
                    };
                    out.push(self.port_tx(p));
                    out.push(self.port_rx(p));
                }
            }
            out.push(self.trunk_up(rail, plane));
            out.push(self.trunk_down(rail, plane));
        } else {
            let plane = s - n_leaves;
            for rail in 0..self.rails {
                out.push(self.trunk_up(rail, plane));
                out.push(self.trunk_down(rail, plane));
            }
        }
        out
    }

    /// Cascade a switch state change to its member links; returns the
    /// member set so callers can re-rate flows / arm crossing QPs.
    pub fn set_switch_up(&mut self, s: usize, up: bool) -> Vec<LinkId> {
        let members = self.switch_links(s);
        for &l in &members {
            self.links[l.0].up = up;
        }
        members
    }

    /// The leaf switch that owns a link: NIC uplinks belong to the leaf of
    /// their (rail, plane); trunks to the leaf they hang off. NVLink is not
    /// switched. This is the RCA attribution edge (trunk symptom → owning
    /// switch).
    pub fn switch_of_link(&self, l: LinkId) -> Option<usize> {
        if l.0 < self.trunk_base {
            let port_idx = l.0 / 2;
            let local = (port_idx / self.ports_per_nic) % self.nics_per_node;
            let plane = port_idx % self.ports_per_nic;
            Some((local % self.rails) * self.ports_per_nic + plane)
        } else if l.0 < self.nvlink_base {
            Some((l.0 - self.trunk_base) / 2)
        } else {
            None
        }
    }

    /// The rail (leaf) a NIC belongs to.
    pub fn rail_of(&self, nic: NicId) -> usize {
        nic.local % self.rails
    }

    // ------------------------------------------------------------------
    // Node entities (§Elastic)
    //
    // A server node is a fault domain too: a kernel panic / power loss
    // takes every NIC port of the node down at once. Node entities own
    // their NIC uplink pairs exactly like switches own member links, so
    // node-crash faults cascade on the existing link table. NVLinks are
    // deliberately *not* members — a dead node's intra-node traffic dies
    // with its ops (the elastic shrink aborts them), whereas the NIC
    // uplinks are what the *peers* observe going dark, which is the
    // all-ports-down perception the escalation keys on.
    // ------------------------------------------------------------------

    /// Number of server nodes in the fabric.
    pub fn num_fabric_nodes(&self) -> usize {
        self.nodes
    }

    /// All NIC ports of a node, sorted by (nic, port).
    pub fn node_ports(&self, n: usize) -> Vec<PortId> {
        let mut out = Vec::with_capacity(self.nics_per_node * self.ports_per_nic);
        for local in 0..self.nics_per_node {
            for port in 0..self.ports_per_nic {
                out.push(PortId {
                    nic: NicId { node: super::NodeId(n), local },
                    port: port as u8,
                });
            }
        }
        out
    }

    /// Member links of a node: every tx/rx uplink of its NIC ports.
    /// Sorted by id (the port layout is contiguous per node).
    pub fn node_links(&self, n: usize) -> Vec<LinkId> {
        let mut out = Vec::with_capacity(self.nics_per_node * self.ports_per_nic * 2);
        for p in self.node_ports(n) {
            out.push(self.port_tx(p));
            out.push(self.port_rx(p));
        }
        out
    }

    /// Cascade a node state change to its member links; returns the member
    /// set so callers can re-rate flows / arm crossing QPs, mirroring
    /// `set_switch_up`.
    pub fn set_node_up(&mut self, n: usize, up: bool) -> Vec<LinkId> {
        let members = self.node_links(n);
        for &l in &members {
            self.links[l.0].up = up;
        }
        members
    }

    /// The node that owns a NIC uplink. Trunks and NVLinks belong to no
    /// node entity (trunks are switch members; NVLink faults are not
    /// modeled). This is the RCA attribution edge (port symptom → node).
    pub fn node_of_link(&self, l: LinkId) -> Option<usize> {
        (l.0 < self.trunk_base)
            .then(|| (l.0 / 2) / (self.nics_per_node * self.ports_per_nic))
    }

    /// The node owning a dense port ordinal (`port_ordinal` inverse, node
    /// part only).
    pub fn node_of_port_ordinal(&self, ordinal: usize) -> usize {
        ordinal / (self.nics_per_node * self.ports_per_nic)
    }

    /// Node-dead perception (§Elastic): *every* NIC port of the node is
    /// down. Distinct from path-death — a switch outage on one plane
    /// leaves the other plane's ports up, so this stays false.
    pub fn node_dead(&self, n: usize) -> bool {
        self.node_ports(n).iter().all(|&p| !self.port_up(p))
    }

    /// Inter-node path between two NIC ports.
    ///
    /// Every inter-node flow transits its leaf's spine-plane trunk pair:
    /// the leaves are line cards whose node-facing ports switch through
    /// the plane, which is why trunk capacity is `nodes × line rate` —
    /// 1:1, never a bottleneck until a trunk fault cuts it. Same rail +
    /// same plane stays `hops: 2` (the intra-plane hairpin is cut-through
    /// and adds no modeled latency); what the trunk contributes there is
    /// capacity coupling and a shared fault domain (§Fault domains).
    /// Cross-rail / cross-plane traffic is a genuine 4-hop spine transit;
    /// PXN exists to avoid it.
    pub fn path_inter(&self, src: PortId, dst: PortId) -> Path {
        assert_ne!(src.nic.node, dst.nic.node, "use path_nvlink for intra-node");
        let (sr, sp) = (self.rail_of(src.nic), src.port as usize);
        let (dr, dp) = (self.rail_of(dst.nic), dst.port as usize);
        let links = vec![
            self.port_tx(src),
            self.trunk_up(sr, sp),
            self.trunk_down(dr, dp),
            self.port_rx(dst),
        ];
        let hops = if sr == dr && sp == dp { 2 } else { 4 };
        Path { links, hops }
    }

    /// Serialize the mutable fabric state — per-link up flags only
    /// (§Soak checkpointing). Layout and capacities are config-derived and
    /// rebuilt at restore.
    pub fn save(&self, w: &mut CkptWriter) {
        w.usize("nfab", self.links.len());
        for l in &self.links {
            w.bool("up", l.up);
        }
    }

    /// Restore the up flags into a freshly built fabric of the same shape.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        let n = r.usize("nfab")?;
        if n != self.links.len() {
            return Err(format!("checkpoint has {n} fabric links, config built {}", self.links.len()));
        }
        for l in self.links.iter_mut() {
            l.up = r.bool("up")?;
        }
        Ok(())
    }

    /// Intra-node NVLink path between two GPUs.
    pub fn path_nvlink(&self, src: GpuId, dst: GpuId) -> Path {
        assert_eq!(src.node, dst.node, "NVLink is intra-node only");
        assert_ne!(src.local, dst.local, "self-copy has no path");
        Path { links: vec![self.nvlink_tx(src), self.nvlink_rx(dst)], hops: 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::NodeId;

    fn topo(nodes: usize, dual: bool) -> TopologyConfig {
        TopologyConfig { num_nodes: nodes, dual_port_nics: dual, ..Default::default() }
    }

    fn port(node: usize, nic: usize, p: u8) -> PortId {
        PortId { nic: NicId { node: NodeId(node), local: nic }, port: p }
    }

    #[test]
    fn same_rail_path_hairpins_through_its_own_trunk_pair() {
        let f = Fabric::build(&topo(2, false));
        let p = f.path_inter(port(0, 3, 0), port(1, 3, 0));
        assert_eq!(p.links.len(), 4);
        assert_eq!(p.hops, 2, "the intra-plane hairpin adds no latency hop");
        assert_eq!(f.link(p.links[0]).kind, LinkKind::NicUplinkTx);
        assert_eq!(p.links[1], f.trunk_up(3, 0));
        assert_eq!(p.links[2], f.trunk_down(3, 0));
        assert_eq!(f.link(p.links[3]).kind, LinkKind::NicUplinkRx);
    }

    #[test]
    fn cross_rail_path_transits_spine() {
        let f = Fabric::build(&topo(2, false));
        let p = f.path_inter(port(0, 3, 0), port(1, 5, 0));
        assert_eq!(p.links.len(), 4);
        assert_eq!(p.hops, 4);
        assert_eq!(f.link(p.links[1]).kind, LinkKind::SpineTrunkUp);
        assert_eq!(f.link(p.links[2]).kind, LinkKind::SpineTrunkDown);
        assert_eq!(p.links[1], f.trunk_up(3, 0));
        assert_eq!(p.links[2], f.trunk_down(5, 0));
    }

    #[test]
    fn dual_plane_cross_plane_goes_through_spine() {
        let f = Fabric::build(&topo(2, true));
        // Same rail but different plane (port 0 vs port 1) — separate leaves.
        let p = f.path_inter(port(0, 3, 0), port(1, 3, 1));
        assert_eq!(p.links.len(), 4);
    }

    #[test]
    fn port_down_breaks_path() {
        let mut f = Fabric::build(&topo(2, false));
        let path = f.path_inter(port(0, 2, 0), port(1, 2, 0));
        assert!(f.path_up(&path));
        f.set_port_up(port(0, 2, 0), false);
        assert!(!f.path_up(&path));
        assert!(!f.port_up(port(0, 2, 0)));
        // Other ports unaffected.
        assert!(f.port_up(port(0, 3, 0)));
        f.set_port_up(port(0, 2, 0), true);
        assert!(f.path_up(&path));
    }

    #[test]
    fn nvlink_path_is_one_hop() {
        let f = Fabric::build(&topo(1, false));
        let a = GpuId { node: NodeId(0), local: 0 };
        let b = GpuId { node: NodeId(0), local: 5 };
        let p = f.path_nvlink(a, b);
        assert_eq!(p.links.len(), 2);
        assert_eq!(p.hops, 1);
        assert_eq!(f.link(p.links[0]).capacity_gbps, 3600.0);
    }

    #[test]
    fn trunk_capacity_is_1to1_oversubscribed() {
        let f = Fabric::build(&topo(4, false));
        let t = f.trunk_up(0, 0);
        assert_eq!(f.link(t).capacity_gbps, 4.0 * 400.0);
    }

    #[test]
    fn trunk_down_breaks_paths_but_not_ports() {
        let mut f = Fabric::build(&topo(2, false));
        let cross = f.path_inter(port(0, 3, 0), port(1, 5, 0));
        let same = f.path_inter(port(0, 3, 0), port(1, 3, 0));
        let other = f.path_inter(port(0, 4, 0), port(1, 4, 0));
        let t = f.trunk_up(3, 0);
        assert!(f.is_trunk(t) && !f.is_trunk(f.port_tx(port(0, 3, 0))));
        f.set_link_up(t, false);
        // Path-death without port-death: the endpoints never flapped.
        assert!(!f.path_up(&cross));
        assert!(!f.path_up(&same), "rail-matched traffic rides its own trunk");
        assert!(f.port_up(port(0, 3, 0)) && f.port_up(port(1, 5, 0)));
        assert_eq!(f.first_dead_link(&cross), Some(t));
        assert_eq!(f.first_dead_link(&same), Some(t));
        assert!(f.path_up(&other), "other rails' trunks are untouched");
        f.set_link_up(t, true);
        assert!(f.path_up(&cross));
        assert!(f.path_up(&same));
        assert_eq!(f.first_dead_link(&cross), None);
    }

    #[test]
    fn switch_cascade_owns_member_links() {
        let mut f = Fabric::build(&topo(2, true));
        assert_eq!(f.num_leaf_switches(), 16);
        assert_eq!(f.num_switches(), 18);
        // Leaf (rail 3, plane 1): both nodes' NIC-3 port-1 uplinks + trunks.
        let s = 3 * 2 + 1;
        let members = f.switch_links(s);
        assert_eq!(members.len(), 2 * 2 + 2);
        assert!(members.contains(&f.port_tx(port(0, 3, 1))));
        assert!(members.contains(&f.port_rx(port(1, 3, 1))));
        assert!(members.contains(&f.trunk_up(3, 1)));
        assert!(members.contains(&f.trunk_down(3, 1)));
        let downed = f.set_switch_up(s, false);
        assert_eq!(downed, members);
        assert!(!f.port_up(port(0, 3, 1)));
        assert!(!f.link_up(f.trunk_up(3, 1)));
        // Plane 0 of the same rail is untouched — that's the backup plane.
        assert!(f.port_up(port(0, 3, 0)));
        assert!(f.link_up(f.trunk_up(3, 0)));
        f.set_switch_up(s, true);
        assert!(f.port_up(port(0, 3, 1)));
    }

    #[test]
    fn spine_plane_switch_owns_every_trunk_in_plane() {
        let mut f = Fabric::build(&topo(2, true));
        let spine1 = f.num_leaf_switches() + 1;
        let members = f.switch_links(spine1);
        assert_eq!(members.len(), 8 * 2); // 8 rails × (up, down)
        assert!(members.iter().all(|&l| f.is_trunk(l)));
        f.set_switch_up(spine1, false);
        for rail in 0..8 {
            assert!(!f.link_up(f.trunk_up(rail, 1)));
            assert!(f.link_up(f.trunk_up(rail, 0)), "plane 0 spine survives");
        }
    }

    #[test]
    fn switch_of_link_inverts_membership() {
        let f = Fabric::build(&topo(2, true));
        for s in 0..f.num_leaf_switches() {
            for l in f.switch_links(s) {
                assert_eq!(f.switch_of_link(l), Some(s), "link {l:?} of leaf {s}");
            }
        }
        // Trunks attribute to their leaf, not the spine plane entity.
        assert_eq!(f.switch_of_link(f.trunk_up(5, 1)), Some(5 * 2 + 1));
        let g = GpuId { node: NodeId(0), local: 2 };
        assert_eq!(f.switch_of_link(f.nvlink_tx(g)), None);
    }

    #[test]
    fn node_cascade_owns_every_nic_port() {
        let mut f = Fabric::build(&topo(2, true));
        assert_eq!(f.num_fabric_nodes(), 2);
        let members = f.node_links(1);
        assert_eq!(members.len(), 8 * 2 * 2); // 8 NICs × 2 ports × (tx, rx)
        assert!(members.contains(&f.port_tx(port(1, 0, 0))));
        assert!(members.contains(&f.port_rx(port(1, 7, 1))));
        assert!(!members.contains(&f.port_tx(port(0, 0, 0))));
        assert!(members.iter().all(|&l| !f.is_trunk(l)));
        assert!(!f.node_dead(1));
        let downed = f.set_node_up(1, false);
        assert_eq!(downed, members);
        assert!(f.node_dead(1), "every port down ⇒ node-dead perception");
        assert!(!f.node_dead(0), "the surviving node is unaffected");
        assert!(f.port_up(port(0, 3, 0)));
        assert!(f.link_up(f.trunk_up(3, 0)), "trunks are switch members, not node members");
        f.set_node_up(1, true);
        assert!(!f.node_dead(1));
        assert!(f.port_up(port(1, 3, 1)));
    }

    #[test]
    fn node_dead_is_distinct_from_switch_outage() {
        let mut f = Fabric::build(&topo(2, true));
        // Kill every *leaf* plane-1 switch: all plane-1 ports of both nodes
        // go down, yet no node is dead — plane 0 still serves them.
        for rail in 0..8 {
            f.set_switch_up(rail * 2 + 1, false);
        }
        assert!(!f.node_dead(0) && !f.node_dead(1));
        // Downing the remaining plane-0 ports of node 1 crosses the line.
        for nic in 0..8 {
            f.set_port_up(port(1, nic, 0), false);
        }
        assert!(f.node_dead(1) && !f.node_dead(0));
    }

    #[test]
    fn node_of_link_inverts_membership() {
        let f = Fabric::build(&topo(2, true));
        for n in 0..f.num_fabric_nodes() {
            for l in f.node_links(n) {
                assert_eq!(f.node_of_link(l), Some(n), "link {l:?} of node {n}");
            }
        }
        assert_eq!(f.node_of_link(f.trunk_up(3, 0)), None);
        let g = GpuId { node: NodeId(0), local: 2 };
        assert_eq!(f.node_of_link(f.nvlink_tx(g)), None);
        // Ordinal inverse agrees with the link-based attribution.
        let p = port(1, 5, 1);
        assert_eq!(f.node_of_port_ordinal(f.port_ordinal(p)), 1);
    }

    #[test]
    fn link_ids_distinct() {
        let f = Fabric::build(&topo(2, true));
        let mut seen = std::collections::HashSet::new();
        for n in 0..2 {
            for nic in 0..8 {
                for p in 0..2u8 {
                    assert!(seen.insert(f.port_tx(port(n, nic, p))));
                    assert!(seen.insert(f.port_rx(port(n, nic, p))));
                }
            }
        }
        assert_eq!(seen.len(), 2 * 8 * 2 * 2);
    }
}
