//! The link fabric: NVLink inside servers, a two-tier rail-optimized CLOS
//! between them.
//!
//! Links are *unidirectional* capacity units; a flow's path is an ordered
//! list of link ids. Modelling directions separately matters: the paper's
//! CTS credit messages travel receiver→sender while the payload goes
//! sender→receiver, and a port-down kills both at once.
//!
//! Rail-optimized wiring (the §4 cluster): NIC *i* of every server connects
//! to leaf switch *i* ("rail *i*"). Same-rail traffic crosses one leaf;
//! cross-rail traffic transits the spine trunk. 1:1 oversubscription means
//! the spine trunk never bottlenecks before the NIC uplinks do, but it
//! *shares* — which is how incast shows up.



use super::{GpuId, NicId, PortId};
use crate::config::TopologyConfig;
use crate::util::{CkptReader, CkptWriter};

/// Index into the fabric's link table.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct LinkId(pub usize);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// NIC port → leaf (tx) or leaf → NIC port (rx). Capacity = line rate.
    NicUplinkTx,
    NicUplinkRx,
    /// Aggregated leaf↔spine trunk (1:1 oversubscription → capacity =
    /// nodes × line rate per direction).
    SpineTrunkUp,
    SpineTrunkDown,
    /// Per-GPU NVLink egress / ingress.
    NvlinkTx,
    NvlinkRx,
}

/// One unidirectional link.
#[derive(Debug, Clone)]
pub struct Link {
    pub kind: LinkKind,
    pub capacity_gbps: f64,
    pub up: bool,
}

/// An ordered list of links a flow traverses, plus the hop count used for
/// the propagation-latency part of the flow model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Path {
    pub links: Vec<LinkId>,
    pub hops: u32,
}

impl Path {
    pub fn empty() -> Self {
        Path { links: Vec::new(), hops: 0 }
    }
}

/// The complete link table with id arithmetic for addressing.
#[derive(Debug, Clone)]
pub struct Fabric {
    links: Vec<Link>,
    nodes: usize,
    nics_per_node: usize,
    ports_per_nic: usize,
    gpus_per_node: usize,
    rails: usize,
    // Layout offsets into `links`:
    // [0 .. n_ports*2)                    NIC uplinks (tx, rx interleaved)
    // [uplinks .. +rails*planes*2)        spine trunks (up, down per leaf)
    // [trunks .. +n_gpus*2)               NVLink (tx, rx per GPU)
    trunk_base: usize,
    nvlink_base: usize,
    link_gbps: f64,
    nvlink_gbps: f64,
}

impl Fabric {
    pub fn build(cfg: &TopologyConfig) -> Self {
        Self::build_with_rates(cfg, 400.0, 3600.0)
    }

    pub fn build_with_rates(cfg: &TopologyConfig, link_gbps: f64, nvlink_gbps: f64) -> Self {
        let ports_per_nic = if cfg.dual_port_nics { 2 } else { 1 };
        let n_ports = cfg.num_nodes * cfg.nics_per_node * ports_per_nic;
        let planes = ports_per_nic; // dual-port → dual-plane deployment (§4.2)
        let n_leaves = cfg.rails * planes;
        let n_gpus = cfg.num_nodes * cfg.gpus_per_node;

        let mut links = Vec::with_capacity(n_ports * 2 + n_leaves * 2 + n_gpus * 2);
        for _ in 0..n_ports {
            links.push(Link { kind: LinkKind::NicUplinkTx, capacity_gbps: link_gbps, up: true });
            links.push(Link { kind: LinkKind::NicUplinkRx, capacity_gbps: link_gbps, up: true });
        }
        let trunk_base = links.len();
        let trunk_cap = cfg.num_nodes as f64 * link_gbps; // 1:1 oversubscription
        for _ in 0..n_leaves {
            links.push(Link { kind: LinkKind::SpineTrunkUp, capacity_gbps: trunk_cap, up: true });
            links.push(Link {
                kind: LinkKind::SpineTrunkDown,
                capacity_gbps: trunk_cap,
                up: true,
            });
        }
        let nvlink_base = links.len();
        for _ in 0..n_gpus {
            links.push(Link { kind: LinkKind::NvlinkTx, capacity_gbps: nvlink_gbps, up: true });
            links.push(Link { kind: LinkKind::NvlinkRx, capacity_gbps: nvlink_gbps, up: true });
        }

        Fabric {
            links,
            nodes: cfg.num_nodes,
            nics_per_node: cfg.nics_per_node,
            ports_per_nic,
            gpus_per_node: cfg.gpus_per_node,
            rails: cfg.rails,
            trunk_base,
            nvlink_base,
            link_gbps,
            nvlink_gbps,
        }
    }

    /// Stable ordinal of a port (dense, 0-based) — used as the monitor's
    /// per-port key and for trace labelling.
    pub fn port_ordinal(&self, p: PortId) -> usize {
        self.port_index(p)
    }

    fn port_index(&self, p: PortId) -> usize {
        debug_assert!((p.port as usize) < self.ports_per_nic, "port {} out of range", p);
        (p.nic.node.0 * self.nics_per_node + p.nic.local) * self.ports_per_nic + p.port as usize
    }

    /// Transmit-direction uplink of a NIC port.
    pub fn port_tx(&self, p: PortId) -> LinkId {
        LinkId(self.port_index(p) * 2)
    }

    /// Receive-direction downlink of a NIC port.
    pub fn port_rx(&self, p: PortId) -> LinkId {
        LinkId(self.port_index(p) * 2 + 1)
    }

    /// Both unidirectional links of a NIC port `(tx, rx)` — the unit a
    /// physical port flap touches, and the seed set for one batched
    /// component recompute in the fluid allocator.
    ///
    /// Link ids are dense, stable and never reused for the lifetime of the
    /// fabric (the layout offsets above are fixed at build time). That
    /// stability is load-bearing: `net::FlowNet` keeps `Vec`-indexed
    /// per-link state (reverse flow index, incast sender counts, component
    /// stamps) keyed directly by `LinkId` and walks adjacency through it.
    pub fn port_links(&self, p: PortId) -> [LinkId; 2] {
        [self.port_tx(p), self.port_rx(p)]
    }

    fn leaf_index(&self, rail: usize, plane: usize) -> usize {
        rail * self.ports_per_nic + plane
    }

    pub fn trunk_up(&self, rail: usize, plane: usize) -> LinkId {
        LinkId(self.trunk_base + self.leaf_index(rail, plane) * 2)
    }

    pub fn trunk_down(&self, rail: usize, plane: usize) -> LinkId {
        LinkId(self.trunk_base + self.leaf_index(rail, plane) * 2 + 1)
    }

    fn gpu_index(&self, g: GpuId) -> usize {
        g.node.0 * self.gpus_per_node + g.local
    }

    pub fn nvlink_tx(&self, g: GpuId) -> LinkId {
        LinkId(self.nvlink_base + self.gpu_index(g) * 2)
    }

    pub fn nvlink_rx(&self, g: GpuId) -> LinkId {
        LinkId(self.nvlink_base + self.gpu_index(g) * 2 + 1)
    }

    pub fn link(&self, id: LinkId) -> &Link {
        &self.links[id.0]
    }

    pub fn num_links(&self) -> usize {
        self.links.len()
    }

    pub fn line_rate_gbps(&self) -> f64 {
        self.link_gbps
    }

    pub fn nvlink_gbps(&self) -> f64 {
        self.nvlink_gbps
    }

    /// Bring a NIC port up/down (both directions at once — an optical-module
    /// failure kills the physical port).
    pub fn set_port_up(&mut self, p: PortId, up: bool) {
        let tx = self.port_tx(p);
        let rx = self.port_rx(p);
        self.links[tx.0].up = up;
        self.links[rx.0].up = up;
    }

    pub fn port_up(&self, p: PortId) -> bool {
        self.links[self.port_tx(p).0].up
    }

    /// Whether every link on the path is up.
    pub fn path_up(&self, path: &Path) -> bool {
        path.links.iter().all(|&l| self.links[l.0].up)
    }

    /// The rail (leaf) a NIC belongs to.
    pub fn rail_of(&self, nic: NicId) -> usize {
        nic.local % self.rails
    }

    /// Inter-node path between two NIC ports.
    ///
    /// Same rail + same plane → one leaf: `src.tx → dst.rx` (2 hops).
    /// Otherwise the flow transits spine trunks (4 hops). Rail-optimized
    /// collectives keep traffic on the first form; PXN exists to avoid the
    /// second.
    pub fn path_inter(&self, src: PortId, dst: PortId) -> Path {
        assert_ne!(src.nic.node, dst.nic.node, "use path_nvlink for intra-node");
        let (sr, sp) = (self.rail_of(src.nic), src.port as usize);
        let (dr, dp) = (self.rail_of(dst.nic), dst.port as usize);
        if sr == dr && sp == dp {
            Path { links: vec![self.port_tx(src), self.port_rx(dst)], hops: 2 }
        } else {
            Path {
                links: vec![
                    self.port_tx(src),
                    self.trunk_up(sr, sp),
                    self.trunk_down(dr, dp),
                    self.port_rx(dst),
                ],
                hops: 4,
            }
        }
    }

    /// Serialize the mutable fabric state — per-link up flags only
    /// (§Soak checkpointing). Layout and capacities are config-derived and
    /// rebuilt at restore.
    pub fn save(&self, w: &mut CkptWriter) {
        w.usize("nfab", self.links.len());
        for l in &self.links {
            w.bool("up", l.up);
        }
    }

    /// Restore the up flags into a freshly built fabric of the same shape.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        let n = r.usize("nfab")?;
        if n != self.links.len() {
            return Err(format!("checkpoint has {n} fabric links, config built {}", self.links.len()));
        }
        for l in self.links.iter_mut() {
            l.up = r.bool("up")?;
        }
        Ok(())
    }

    /// Intra-node NVLink path between two GPUs.
    pub fn path_nvlink(&self, src: GpuId, dst: GpuId) -> Path {
        assert_eq!(src.node, dst.node, "NVLink is intra-node only");
        assert_ne!(src.local, dst.local, "self-copy has no path");
        Path { links: vec![self.nvlink_tx(src), self.nvlink_rx(dst)], hops: 1 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::NodeId;

    fn topo(nodes: usize, dual: bool) -> TopologyConfig {
        TopologyConfig { num_nodes: nodes, dual_port_nics: dual, ..Default::default() }
    }

    fn port(node: usize, nic: usize, p: u8) -> PortId {
        PortId { nic: NicId { node: NodeId(node), local: nic }, port: p }
    }

    #[test]
    fn same_rail_path_skips_spine() {
        let f = Fabric::build(&topo(2, false));
        let p = f.path_inter(port(0, 3, 0), port(1, 3, 0));
        assert_eq!(p.links.len(), 2);
        assert_eq!(p.hops, 2);
        assert_eq!(f.link(p.links[0]).kind, LinkKind::NicUplinkTx);
        assert_eq!(f.link(p.links[1]).kind, LinkKind::NicUplinkRx);
    }

    #[test]
    fn cross_rail_path_transits_spine() {
        let f = Fabric::build(&topo(2, false));
        let p = f.path_inter(port(0, 3, 0), port(1, 5, 0));
        assert_eq!(p.links.len(), 4);
        assert_eq!(f.link(p.links[1]).kind, LinkKind::SpineTrunkUp);
        assert_eq!(f.link(p.links[2]).kind, LinkKind::SpineTrunkDown);
    }

    #[test]
    fn dual_plane_cross_plane_goes_through_spine() {
        let f = Fabric::build(&topo(2, true));
        // Same rail but different plane (port 0 vs port 1) — separate leaves.
        let p = f.path_inter(port(0, 3, 0), port(1, 3, 1));
        assert_eq!(p.links.len(), 4);
    }

    #[test]
    fn port_down_breaks_path() {
        let mut f = Fabric::build(&topo(2, false));
        let path = f.path_inter(port(0, 2, 0), port(1, 2, 0));
        assert!(f.path_up(&path));
        f.set_port_up(port(0, 2, 0), false);
        assert!(!f.path_up(&path));
        assert!(!f.port_up(port(0, 2, 0)));
        // Other ports unaffected.
        assert!(f.port_up(port(0, 3, 0)));
        f.set_port_up(port(0, 2, 0), true);
        assert!(f.path_up(&path));
    }

    #[test]
    fn nvlink_path_is_one_hop() {
        let f = Fabric::build(&topo(1, false));
        let a = GpuId { node: NodeId(0), local: 0 };
        let b = GpuId { node: NodeId(0), local: 5 };
        let p = f.path_nvlink(a, b);
        assert_eq!(p.links.len(), 2);
        assert_eq!(p.hops, 1);
        assert_eq!(f.link(p.links[0]).capacity_gbps, 3600.0);
    }

    #[test]
    fn trunk_capacity_is_1to1_oversubscribed() {
        let f = Fabric::build(&topo(4, false));
        let t = f.trunk_up(0, 0);
        assert_eq!(f.link(t).capacity_gbps, 4.0 * 400.0);
    }

    #[test]
    fn link_ids_distinct() {
        let f = Fabric::build(&topo(2, true));
        let mut seen = std::collections::HashSet::new();
        for n in 0..2 {
            for nic in 0..8 {
                for p in 0..2u8 {
                    assert!(seen.insert(f.port_tx(port(n, nic, p))));
                    assert!(seen.insert(f.port_rx(port(n, nic, p))));
                }
            }
        }
        assert_eq!(seen.len(), 2 * 8 * 2 * 2);
    }
}
