//! Ring construction for ring collectives.
//!
//! NCCL builds one logical ring per channel; in a rail-optimized fabric the
//! efficient ring visits every GPU of a node before hopping to the next node
//! over the rail of the *channel's* NIC, so inter-node traffic stays on one
//! rail per channel (§2.1 "topology search & graph construction" — we
//! reproduce the production-relevant subset: rail-aligned rings).

use super::{Cluster, RankId};

/// One logical ring: an ordering of all ranks, plus the rail its inter-node
/// hops use.
#[derive(Debug, Clone)]
pub struct Ring {
    pub order: Vec<RankId>,
    pub rail: usize,
    /// rank → index into `order`. Collectives call `next`/`prev` for every
    /// rank of every step; a linear scan here made step issue O(ranks²),
    /// which the 64-node (512-rank) experiments cannot afford.
    pos_of: Vec<usize>,
}

impl Ring {
    fn new(order: Vec<RankId>, rail: usize) -> Self {
        Self::with_total_ranks(order, rail, 0)
    }

    /// Build a ring whose `pos_of` table is sized for `total` ranks even
    /// when `order` excludes some (§Elastic shrink). Excluded ranks keep a
    /// zero entry that `next`/`prev` must never consult — collectives only
    /// iterate `order`, so a dead rank is simply never asked.
    fn with_total_ranks(order: Vec<RankId>, rail: usize, total: usize) -> Self {
        let mut pos_of = vec![0; order.len().max(total)];
        for (i, r) in order.iter().enumerate() {
            pos_of[r.0] = i;
        }
        Ring { order, rail, pos_of }
    }

    /// Does the ring include `r`? O(1); false for ranks excluded by an
    /// elastic shrink (and trivially true on full rings).
    pub fn contains(&self, r: RankId) -> bool {
        r.0 < self.pos_of.len() && self.order.get(self.pos_of[r.0]) == Some(&r)
    }

    /// Successor of `r` on the ring.
    pub fn next(&self, r: RankId) -> RankId {
        let i = self.pos(r);
        self.order[(i + 1) % self.order.len()]
    }

    /// Predecessor of `r` on the ring.
    pub fn prev(&self, r: RankId) -> RankId {
        let i = self.pos(r);
        self.order[(i + self.order.len() - 1) % self.order.len()]
    }

    fn pos(&self, r: RankId) -> usize {
        self.pos_of[r.0]
    }
}

/// Build `channels` rail-aligned rings over the whole cluster.
///
/// Channel `c` uses rail `c % rails`; within each node the visit order is
/// rotated by the rail so that the node's *boundary* GPUs (the ones doing the
/// inter-node send/recv) sit on the channel's rail-local NIC.
pub fn build_rings(cluster: &Cluster, channels: usize) -> Vec<Ring> {
    build_rings_excluding(cluster, channels, &[])
}

/// Build `channels` rail-aligned rings over the surviving nodes only
/// (§Elastic shrink): nodes with `dead[node] == true` contribute no segment,
/// everything else keeps the exact `build_rings` layout. With no dead nodes
/// this is bit-identical to `build_rings` — the determinism contract the
/// elastic tests pin.
pub fn build_rings_excluding(cluster: &Cluster, channels: usize, dead: &[bool]) -> Vec<Ring> {
    let n_nodes = cluster.cfg.num_nodes;
    let per = cluster.cfg.gpus_per_node;
    let rails = cluster.cfg.rails.max(1);
    (0..channels)
        .map(|c| {
            let rail = c % rails;
            let mut order = Vec::with_capacity(n_nodes * per);
            for node in 0..n_nodes {
                if dead.get(node).copied().unwrap_or(false) {
                    continue;
                }
                // Start the node's segment at the rail-local GPU so that the
                // inter-node hop (last GPU of this node → first of next)
                // leaves from / arrives at the rail's NIC.
                for k in 0..per {
                    let local = (rail + k) % per;
                    order.push(RankId(node * per + local));
                }
            }
            Ring::with_total_ranks(order, rail, n_nodes * per)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;

    fn cluster(nodes: usize) -> Cluster {
        Cluster::new(TopologyConfig { num_nodes: nodes, ..Default::default() })
    }

    #[test]
    fn ring_visits_every_rank_once() {
        let c = cluster(3);
        for ring in build_rings(&c, 8) {
            let mut sorted: Vec<usize> = ring.order.iter().map(|r| r.0).collect();
            sorted.sort();
            assert_eq!(sorted, (0..c.num_ranks()).collect::<Vec<_>>());
        }
    }

    #[test]
    fn channels_spread_over_rails() {
        let c = cluster(2);
        let rings = build_rings(&c, 16);
        let rails: std::collections::HashSet<usize> = rings.iter().map(|r| r.rail).collect();
        assert_eq!(rails.len(), 8); // 16 channels over 8 rails → all used
    }

    #[test]
    fn node_segment_starts_on_rail_gpu() {
        let c = cluster(2);
        let rings = build_rings(&c, 8);
        for ring in &rings {
            // First rank of each node segment must be the rail-local GPU.
            for node in 0..2 {
                let first = ring.order[node * 8];
                let gpu = c.gpu_of_rank(first);
                assert_eq!(gpu.local, ring.rail);
            }
        }
    }

    #[test]
    fn next_prev_inverse() {
        let c = cluster(2);
        let ring = &build_rings(&c, 1)[0];
        for &r in &ring.order {
            assert_eq!(ring.prev(ring.next(r)), r);
        }
    }

    #[test]
    fn excluding_dead_node_drops_its_segment_only() {
        let c = cluster(4);
        let full = build_rings(&c, 8);
        let shrunk = build_rings_excluding(&c, 8, &[false, false, true, false]);
        for (f, s) in full.iter().zip(&shrunk) {
            assert_eq!(s.rail, f.rail);
            assert_eq!(s.order.len(), 3 * 8);
            // Surviving segments keep the full ring's layout and order.
            let expect: Vec<RankId> =
                f.order.iter().copied().filter(|r| r.0 / 8 != 2).collect();
            assert_eq!(s.order, expect);
            for &r in &s.order {
                assert!(s.contains(r));
                assert_eq!(s.prev(s.next(r)), r);
            }
            for dead in 16..24 {
                assert!(!s.contains(RankId(dead)));
            }
        }
    }

    #[test]
    fn excluding_nothing_matches_build_rings() {
        let c = cluster(3);
        let a = build_rings(&c, 8);
        let b = build_rings_excluding(&c, 8, &[false; 3]);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.order, y.order);
            assert_eq!(x.rail, y.rail);
            for &r in &x.order {
                assert_eq!(x.next(r), y.next(r));
                assert_eq!(x.prev(r), y.prev(r));
            }
        }
    }

    #[test]
    fn inter_node_hop_count_is_nodes() {
        // Each ring should cross node boundaries exactly `n_nodes` times
        // (wrapping hop included) — the property that makes it rail-friendly.
        let c = cluster(4);
        let ring = &build_rings(&c, 1)[0];
        let crossings = ring
            .order
            .iter()
            .zip(ring.order.iter().cycle().skip(1))
            .filter(|(a, b)| !c.same_node(**a, **b))
            .take(ring.order.len())
            .count();
        assert_eq!(crossings, 4);
    }
}
