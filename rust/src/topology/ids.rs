//! Typed identifiers for cluster entities.
//!
//! Newtypes keep rank / GPU / NIC / port index spaces from mixing — the kind
//! of bug the paper's §5 "misleading cases" section shows is expensive to
//! chase in production.


use std::fmt;

/// A server in the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NodeId(pub usize);

/// A GPU, addressed as (node, local index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct GpuId {
    pub node: NodeId,
    pub local: usize,
}

/// An RDMA NIC, addressed as (node, local index).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct NicId {
    pub node: NodeId,
    pub local: usize,
}

/// A physical NIC port (dual-port RNICs have port 0 and 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct PortId {
    pub nic: NicId,
    pub port: u8,
}

/// A flat communicator rank (node-major order, like NCCL's global rank).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct RankId(pub usize);

impl fmt::Display for NodeId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "node{}", self.0)
    }
}
impl fmt::Display for GpuId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/gpu{}", self.node, self.local)
    }
}
impl fmt::Display for NicId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/nic{}", self.node, self.local)
    }
}
impl fmt::Display for PortId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}p{}", self.nic, self.port)
    }
}
impl fmt::Display for RankId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "rank{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let p = PortId { nic: NicId { node: NodeId(2), local: 3 }, port: 1 };
        assert_eq!(p.to_string(), "node2/nic3p1");
        assert_eq!(RankId(17).to_string(), "rank17");
        assert_eq!(GpuId { node: NodeId(0), local: 4 }.to_string(), "node0/gpu4");
    }

    #[test]
    fn ids_hash_and_order() {
        use std::collections::HashSet;
        let mut s = HashSet::new();
        s.insert(RankId(1));
        s.insert(RankId(1));
        s.insert(RankId(2));
        assert_eq!(s.len(), 2);
        assert!(RankId(1) < RankId(2));
    }
}
