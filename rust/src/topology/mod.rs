//! Cluster topology: servers, GPUs, RNICs, NVLink, and the two-tier
//! rail-optimized CLOS fabric of the paper's production cluster (§4:
//! 8 GPUs + 8 rail RNICs per server, 400 Gbps, 1:1 oversubscription).
//!
//! The topology layer answers three questions for the rest of the stack:
//!  1. *Placement* — which RNIC is closest / second-closest to a GPU
//!     (primary vs backup QP placement, §3.3).
//!  2. *Paths* — the ordered list of links a flow traverses between two
//!     NIC ports (feeds the max-min fair bandwidth allocator in `net`).
//!  3. *Rings* — rail-aligned ring orderings for ring collectives.

mod ids;
mod fabric;
mod rings;

pub use fabric::{Fabric, LinkId, LinkKind, Path};
pub use ids::{GpuId, NicId, NodeId, PortId, RankId};
pub use rings::{build_rings, build_rings_excluding, Ring};

use crate::config::TopologyConfig;

/// A fully-resolved cluster: node/GPU/NIC inventory plus the link fabric.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub cfg: TopologyConfig,
    pub fabric: Fabric,
}

impl Cluster {
    /// Build with the paper's default rates (400 Gbps NICs, 3600 Gbps
    /// NVLink). Prefer [`Cluster::with_rates`] when a `Config` is in hand —
    /// that is what makes `net.link_gbps` / `gpu.nvlink_gbps` take effect.
    pub fn new(cfg: TopologyConfig) -> Self {
        let fabric = Fabric::build(&cfg);
        Cluster { cfg, fabric }
    }

    /// Build with explicit line rates: NIC uplinks (and the 1:1 spine
    /// trunks derived from them) at `link_gbps`, NVLink at `nvlink_gbps`.
    pub fn with_rates(cfg: TopologyConfig, link_gbps: f64, nvlink_gbps: f64) -> Self {
        let fabric = Fabric::build_with_rates(&cfg, link_gbps, nvlink_gbps);
        Cluster { cfg, fabric }
    }

    pub fn num_ranks(&self) -> usize {
        self.cfg.num_nodes * self.cfg.gpus_per_node
    }

    /// Map a flat rank to its (node, local GPU) coordinates.
    pub fn gpu_of_rank(&self, rank: RankId) -> GpuId {
        let node = rank.0 / self.cfg.gpus_per_node;
        let local = rank.0 % self.cfg.gpus_per_node;
        GpuId { node: NodeId(node), local }
    }

    pub fn rank_of_gpu(&self, gpu: GpuId) -> RankId {
        RankId(gpu.node.0 * self.cfg.gpus_per_node + gpu.local)
    }

    /// The rail-local (closest) RNIC for a GPU: in a rail-optimized server
    /// GPU *i* sits under the same PCIe switch as RNIC *i*.
    pub fn primary_nic(&self, gpu: GpuId) -> NicId {
        NicId { node: gpu.node, local: gpu.local % self.cfg.nics_per_node }
    }

    /// The backup placement (§3.3): the other port of the same RNIC when
    /// dual-port, otherwise the second-closest RNIC (same PCIe complex,
    /// neighbouring index).
    pub fn backup_port(&self, gpu: GpuId) -> PortId {
        let primary = self.primary_nic(gpu);
        if self.cfg.dual_port_nics {
            PortId { nic: primary, port: 1 }
        } else {
            let second = NicId {
                node: gpu.node,
                local: (primary.local + 1) % self.cfg.nics_per_node,
            };
            PortId { nic: second, port: 0 }
        }
    }

    pub fn primary_port(&self, gpu: GpuId) -> PortId {
        PortId { nic: self.primary_nic(gpu), port: 0 }
    }

    /// True if two ranks are on the same server (NVLink-reachable).
    pub fn same_node(&self, a: RankId, b: RankId) -> bool {
        self.gpu_of_rank(a).node == self.gpu_of_rank(b).node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster(nodes: usize) -> Cluster {
        Cluster::new(TopologyConfig { num_nodes: nodes, ..Default::default() })
    }

    #[test]
    fn rank_gpu_round_trip() {
        let c = cluster(4);
        for r in 0..c.num_ranks() {
            let g = c.gpu_of_rank(RankId(r));
            assert_eq!(c.rank_of_gpu(g), RankId(r));
        }
    }

    #[test]
    fn primary_nic_is_rail_local() {
        let c = cluster(2);
        let g = GpuId { node: NodeId(1), local: 5 };
        assert_eq!(c.primary_nic(g), NicId { node: NodeId(1), local: 5 });
    }

    #[test]
    fn backup_is_second_closest_single_port() {
        let c = cluster(2);
        let g = GpuId { node: NodeId(0), local: 7 };
        let b = c.backup_port(g);
        assert_eq!(b.nic.local, 0); // wraps 7+1 → 0
        assert_eq!(b.port, 0);
    }

    #[test]
    fn backup_is_other_port_when_dual() {
        let c = Cluster::new(TopologyConfig { dual_port_nics: true, ..Default::default() });
        let g = GpuId { node: NodeId(0), local: 3 };
        let b = c.backup_port(g);
        assert_eq!(b.nic, c.primary_nic(g)); // same NIC, same hardware distance
        assert_eq!(b.port, 1);
    }

    #[test]
    fn with_rates_propagates_to_fabric() {
        let c = Cluster::with_rates(TopologyConfig::default(), 200.0, 1800.0);
        assert_eq!(c.fabric.line_rate_gbps(), 200.0);
        assert_eq!(c.fabric.nvlink_gbps(), 1800.0);
        let p = c.primary_port(GpuId { node: NodeId(0), local: 0 });
        assert_eq!(c.fabric.link(c.fabric.port_tx(p)).capacity_gbps, 200.0);
        // Spine trunks scale with the line rate (1:1 oversubscription).
        assert_eq!(c.fabric.link(c.fabric.trunk_up(0, 0)).capacity_gbps, 2.0 * 200.0);
    }

    #[test]
    fn same_node_detection() {
        let c = cluster(2);
        assert!(c.same_node(RankId(0), RankId(7)));
        assert!(!c.same_node(RankId(0), RankId(8)));
    }
}
