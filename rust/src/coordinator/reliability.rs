//! Reliability experiments: Fig 2, Fig 13a/b, Fig 14, Fig 18, the
//! retry-window ablation and the §Fault domains fabric preset.

use std::fmt::Write as _;

use crate::ccl::{ClusterSim, CollKind, OpId};
use crate::config::Config;
use crate::metrics::Table;
use crate::pipeline::{PipelineCfg, PipelineSim};
use crate::rca::{self, InjectedNodeFault, InjectedSwitchFault, RcaTopo};
use crate::sim::SimTime;
use crate::topology::RankId;
use crate::trace::TraceSink;
use crate::util::{ByteSize, Rng};

use super::experiments;

/// Fast-failover variant of the config so the timelines fit in seconds of
/// simulated time (the paper's TIMEOUT=18 window is ~7.5s; we keep the
/// default for fig13a which reproduces the ~10s gap, and shrink elsewhere).
fn fast(cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.net.ib_timeout_exp = 12;
    c.net.ib_retry_cnt = 3;
    c.net.qp_warmup_ns = 400_000_000;
    c
}

/// Fig 2: failure-type statistics over 10 months (synthetic trace drawn
/// from the paper's reported mix: link failures dominate).
pub fn fig2_failure_stats(cfg: &Config) -> String {
    let mut rng = Rng::new(cfg.seed ^ 0xF16_2);
    // Monthly event rate for a ~24k-GPU fleet; category mix per Fig 2.
    let mix = [
        ("optical module", 0.42),
        ("RNIC hardware", 0.23),
        ("GPU", 0.21),
        ("miscellaneous", 0.14),
    ];
    let mut counts = [0u32; 4];
    let mut monthly = vec![[0u32; 4]; 10];
    for month in 0..10 {
        let events = 60 + rng.below(40);
        for _ in 0..events {
            let x = rng.f64();
            let mut acc = 0.0;
            for (i, (_, p)) in mix.iter().enumerate() {
                acc += p;
                if x < acc {
                    counts[i] += 1;
                    monthly[month][i] += 1;
                    break;
                }
            }
        }
    }
    let total: u32 = counts.iter().sum();
    let mut t = Table::new(vec!["failure type", "count (10 months)", "share %"]);
    for (i, (name, _)) in mix.iter().enumerate() {
        t.row(vec![
            name.to_string(),
            counts[i].to_string(),
            format!("{:.1}", counts[i] as f64 / total as f64 * 100.0),
        ]);
    }
    let mut out = String::from(
        "Fig 2 — failure statistics (synthetic trace, paper's category mix):\n\
         link failures (optical + RNIC) contribute the most failures.\n\n",
    );
    out.push_str(&t.render());
    let link_share =
        (counts[0] + counts[1]) as f64 / total as f64 * 100.0;
    let _ = writeln!(out, "\nlink-failure share: {link_share:.1}% (> GPU + misc)");
    out
}

/// Fig 13a: SendRecv bandwidth timeline across a port down/up cycle, with
/// the paper's default retry window (~7.5s at TIMEOUT=18 RETRY=7).
pub fn fig13a_failover_timeline(cfg: &Config) -> String {
    let mut c = cfg.clone();
    c.vccl.channels = 2;
    // Terabyte-scale transfer: use 16MB chunks to keep the event count sane.
    c.vccl.chunk_bytes = 16 << 20;
    // Scale the warm-up so failback is visible shortly after port-up.
    c.net.qp_warmup_ns = 2_000_000_000;
    let mut s = ClusterSim::new(c);
    let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
    // Paper timeline: down at 4s, up at 19s.
    s.inject_port_down(port, SimTime::s(4));
    s.inject_port_up(port, SimTime::s(19));
    // Enough traffic to span ~25s at ~390Gbps ≈ 1.2TB.
    let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::gb(1100).0);
    s.run_to_idle(400_000_000);
    let op = &s.ops[id.0];

    // Aggregate bandwidth per 1s bucket from the backup+primary ports.
    let bucket = SimTime::s(1);
    let prim = s.port_bandwidth_series(port, bucket);
    let bport = s.conns.iter().find_map(|cn| cn.backup_port).unwrap();
    let back = s.port_bandwidth_series(bport, bucket);
    let mut t = Table::new(vec!["t (s)", "primary Gbps", "backup Gbps", "phase"]);
    let lookup = |series: &[(f64, f64)], sec: f64| {
        series
            .iter()
            .find(|(ts, _)| (*ts - sec).abs() < 0.5)
            .map(|(_, g)| *g)
            .unwrap_or(0.0)
    };
    let window_s = s.cfg.net.retry_window_ns() as f64 / 1e9;
    for sec in 0..26 {
        let p = lookup(&prim, sec as f64);
        let b = lookup(&back, sec as f64);
        let phase = if (sec as f64) < 4.0 {
            "normal (primary)"
        } else if (sec as f64) < 4.0 + window_s {
            "RETRY window (0 Gbps)"
        } else if (sec as f64) < 19.0 {
            "backup QP"
        } else if p > 1.0 {
            "failback (primary)"
        } else {
            "primary warm-up"
        };
        t.row(vec![sec.to_string(), format!("{p:.0}"), format!("{b:.0}"), phase.into()]);
    }
    let mut out = String::from("Fig 13a — SendRecv bandwidth under a RNIC port down (4s) / up (19s)\n\n");
    out.push_str(&t.render());
    let _ = writeln!(
        out,
        "\nretry window ≈ {window_s:.1}s (IB_TIMEOUT={} RETRY_CNT={}); failovers={} failbacks={} op_done={}",
        s.cfg.net.ib_timeout_exp,
        s.cfg.net.ib_retry_cnt,
        s.stats.failovers,
        s.stats.failbacks,
        op.is_done(),
    );
    out
}

/// Fig 13b: per-iteration training TFLOPS across a severe link failure.
pub fn fig13b_training_under_failure(cfg: &Config) -> String {
    let mut out = String::from("Fig 13b — 70B-shape training across a severe link failure\n\n");
    let mut t = Table::new(vec!["iter", "VCCL TFLOPS/GPU", "NCCL TFLOPS/GPU"]);
    let run = |transport: &str| -> Vec<f64> {
        let mut c = fast(cfg);
        c.set_key("vccl.transport", transport).unwrap();
        let mut pcfg = PipelineCfg::spread(&c, 4, 8);
        pcfg.fwd_ns = 6_000_000;
        pcfg.bwd_ns = 12_000_000;
        pcfg.msg_bytes = 96 << 20;
        pcfg.flops_per_micro_stage = pcfg.fwd_ns as f64 * 1e-9 * (989e12 * 0.55);
        let mut p = PipelineSim::new(ClusterSim::new(c), pcfg);
        // Kill a stage-boundary NIC during iteration 3; never restore (a
        // "severe" failure needing manual intervention).
        let port = p.sim.topo.primary_port(p.sim.topo.gpu_of_rank(RankId(4)));
        p.sim.inject_port_down(port, SimTime::ms(450));
        let mut res = Vec::new();
        let mut hung = false;
        for _ in 0..8 {
            if hung {
                res.push(0.0);
                continue;
            }
            let r = p.run_iteration();
            hung = r.hung;
            res.push(if r.hung { 0.0 } else { r.tflops_per_gpu });
        }
        res
    };
    let v = run("vccl");
    let n = run("kernel");
    for i in 0..8 {
        t.row(vec![(i + 1).to_string(), format!("{:.0}", v[i]), format!("{:.0}", n[i])]);
    }
    out.push_str(&t.render());
    out.push_str(
        "\nNCCL hangs when the failure outlives hardware retransmission; VCCL's\n\
         primary-backup QP keeps TFLOPS ~constant after a one-iteration dip.\n",
    );
    out
}

/// Fig 14: failure-induced idle GPU time across deployments.
pub fn fig14_idle_gpu_time(cfg: &Config) -> String {
    let mut rng = Rng::new(cfg.seed ^ 0xF14);
    // Monte-carlo a month of a 24k-GPU fleet partitioned into 3k-GPU jobs.
    let jobs = 8usize;
    let gpus_per_job = 3_000u64;
    let link_failures_per_job_month = 14.0;
    let mut idle = [0f64; 3]; // single-plane, dual-plane, VCCL (GPU-hours)
    for _ in 0..jobs {
        let failures = rng.normal(link_failures_per_job_month, 3.0).max(0.0).round() as u32;
        for _ in 0..failures {
            // Restart cost: detect + drain + relaunch + warmup, 20–50 min.
            let restart_h = rng.uniform(20.0, 50.0) / 60.0;
            idle[0] += restart_h * gpus_per_job as f64;
            // Dual-plane bonding absorbs a fraction of port-down events
            // (paper: −29.6% idle time overall).
            if rng.chance(0.30) {
                // absorbed by the second plane
            } else {
                idle[1] += restart_h * gpus_per_job as f64;
            }
            // VCCL: the retry window + failover, seconds — only failures of
            // BOTH primary and backup paths (≈never) need a restart.
            let failover_h = (cfg.net.retry_window_ns() as f64 / 1e9 + 5.0) / 3600.0;
            idle[2] += failover_h * gpus_per_job as f64;
        }
    }
    let mut t = Table::new(vec!["deployment", "idle GPU-hours / month", "vs single-plane"]);
    let labels = ["single-plane (NCCL)", "dual-plane bonding", "VCCL fault tolerance"];
    for i in 0..3 {
        t.row(vec![
            labels[i].to_string(),
            format!("{:.0}", idle[i]),
            format!("{:+.1}%", (idle[i] / idle[0] - 1.0) * 100.0),
        ]);
    }
    let mut out = String::from("Fig 14 — GPU idle time caused by link failures (monthly, 24k fleet)\n\n");
    out.push_str(&t.render());
    let _ = writeln!(
        out,
        "\npaper: dual-plane −29.6%, VCCL ≈ −90%; measured: {:.1}% / {:.1}%",
        (idle[1] / idle[0] - 1.0) * 100.0,
        (idle[2] / idle[0] - 1.0) * 100.0
    );
    out
}

/// Fig 18 / Appendix G: AllReduce under progressive multi-port failures.
pub fn fig18_multiport_stress(cfg: &Config) -> String {
    let mut c = fast(cfg);
    c.vccl.channels = 4;
    let mut s = ClusterSim::new(c);
    let port_of = |s: &ClusterSim, g: usize| s.topo.primary_port(s.topo.gpu_of_rank(RankId(g)));
    // Phases: baseline → RNIC0 down → +RNIC2 down → +RNIC4 down → all up.
    let p0 = port_of(&s, 0);
    let p2 = port_of(&s, 2);
    let p4 = port_of(&s, 4);
    let phase_len = SimTime::ms(600);
    s.inject_port_down(p0, phase_len);
    s.inject_port_down(p2, SimTime::ns(phase_len.as_ns() * 2));
    s.inject_port_down(p4, SimTime::ns(phase_len.as_ns() * 3));
    for p in [p0, p2, p4] {
        s.inject_port_up(p, SimTime::ns(phase_len.as_ns() * 4));
    }
    // Continuous AllReduce traffic: submit ops back to back until past
    // phase 5.
    let mut results: Vec<(f64, f64)> = Vec::new(); // (t_end_s, busbw)
    let horizon = SimTime::ns(phase_len.as_ns() * 5);
    while s.now() < horizon {
        let id = s.submit(CollKind::AllReduce, ByteSize::mb(64).0);
        if !s.run_until_op(id, 400_000_000) {
            break;
        }
        let nranks = s.topo.num_ranks();
        let op = &s.ops[id.0];
        if let (Some(end), Some(bw)) = (op.finished_at, op.busbw_gbps(nranks)) {
            results.push((end.as_secs_f64(), bw));
        }
    }
    let mut t = Table::new(vec!["phase", "window (s)", "avg busbw Gbps", "paper Gbps"]);
    let paper = ["450", "350", "190", "190", "450"];
    for ph in 0..5 {
        let lo = ph as f64 * 0.6;
        let hi = lo + 0.6;
        let in_phase: Vec<f64> = results
            .iter()
            .filter(|(t, _)| *t > lo && *t <= hi)
            .map(|(_, b)| *b)
            .collect();
        let avg = if in_phase.is_empty() {
            0.0
        } else {
            in_phase.iter().sum::<f64>() / in_phase.len() as f64
        };
        t.row(vec![
            format!("{ph}"),
            format!("{lo:.1}–{hi:.1}"),
            format!("{avg:.0}"),
            paper[ph].to_string(),
        ]);
    }
    let mut out = String::from(
        "Fig 18 — AllReduce bandwidth under progressive port failures\n\
         (phase 0: healthy; 1: RNIC0 down; 2: +RNIC2; 3: +RNIC4; 4: all up)\n\n",
    );
    out.push_str(&t.render());
    let _ = writeln!(
        out,
        "\nfailovers={} failbacks={} — shape check: each failure degrades but\n\
         never stops the collective; full recovery in phase 4.",
        s.stats.failovers, s.stats.failbacks
    );
    out
}

/// Everything the §Fault domains fabric preset measures (shared by the
/// `fabric` experiment and `vccl bench fabric`).
#[derive(Debug, Clone)]
pub struct FabricRun {
    /// Connections whose primary path crossed the trunk when it died.
    pub affected: usize,
    /// Plane failovers observed (must equal `affected` for completeness 1).
    pub migrated: u64,
    pub failbacks: u64,
    pub lost_ops: u64,
    /// Aggregate goodput of the 4-stream batch per phase.
    pub baseline_gbps: f64,
    pub degraded_gbps: f64,
    pub recovered_gbps: f64,
    pub retry_window_ms: f64,
    /// The leaf switch owning the killed trunk (RCA ground truth).
    pub switch: usize,
    pub rca_attributed: usize,
    pub rca_precision: f64,
}

impl FabricRun {
    /// Plane-failover completeness: migrated / affected.
    pub fn completeness(&self) -> f64 {
        if self.affected == 0 { 0.0 } else { self.migrated as f64 / self.affected as f64 }
    }
}

/// §Fault domains dual-plane preset: 4 nodes, 4 rail-aligned P2P streams —
/// the node-0→1 and node-2→3 rail-0 streams share one leaf and therefore
/// one plane-0 trunk; the rail-1 streams are the unaffected control. Kill
/// that single trunk with every NIC port still up (path death ≠ port
/// death), re-run the batch, heal, re-run. The whole run is flight-recorded
/// so RCA is graded on the same evidence an operator would have.
pub fn fabric_run(cfg: &Config) -> FabricRun {
    let mut c = experiments::transport_cfg(cfg, "vccl", 4, 1);
    c.topo.dual_port_nics = true;
    // Short retry window (as bench_failover) so the stall phase is ~8 ms of
    // simulated time instead of the paper's ~7.5 s.
    c.net.ib_timeout_exp = 10;
    c.net.ib_retry_cnt = 2;
    c.net.qp_warmup_ns = 100_000_000;
    c.trace.enabled = true;
    c.trace.ring_capacity = c.trace.ring_capacity.max(1 << 20);
    c.trace.snapshot_window_ns =
        c.trace.snapshot_window_ns.max(c.net.retry_window_ns() + 2_000_000_000);
    let sink = TraceSink::new(c.trace.ring_capacity, c.trace.snapshot_window_ns);
    c.trace.sink = Some(sink.clone());
    let retry_window_ms = c.net.retry_window_ns() as f64 / 1e6;
    let mut s = ClusterSim::new(c);
    let streams = [(0usize, 8usize), (16, 24), (1, 9), (17, 25)];
    let bytes = ByteSize::mb(64).0;
    let batch = |s: &mut ClusterSim| -> f64 {
        let t0 = s.now().as_ns();
        let ids: Vec<_> = streams
            .iter()
            .map(|&(a, b)| s.submit_p2p(RankId(a), RankId(b), bytes))
            .collect();
        for id in ids {
            assert!(s.run_until_op(id, 400_000_000), "fabric stream must complete");
        }
        (streams.len() as u64 * bytes * 8) as f64 / (s.now().as_ns() - t0) as f64
    };
    let baseline_gbps = batch(&mut s);

    let trunk = s.topo.fabric.trunk_up(0, 0);
    let switch = s.topo.fabric.switch_of_link(trunk).expect("trunks belong to a leaf");
    let down_at = s.now() + SimTime::ms(1);
    s.inject_trunk_down(trunk, down_at);
    s.run_until(down_at + SimTime::ms(1));
    // Path-death perception: the ports never flapped, so "affected" is a
    // path property — every conn whose primary route transits the trunk.
    let affected = s
        .conns
        .iter()
        .filter(|cn| cn.primary.is_some_and(|qp| !s.rdma.qp_path_up(qp, &s.topo.fabric)))
        .count();
    let degraded_gbps = batch(&mut s);
    let migrated = s.stats.failovers;

    // Heal; failback waits on the proactively-reset primary's warm-up.
    s.inject_trunk_up(trunk, s.now() + SimTime::ms(1));
    s.run_to_idle(400_000_000);
    let failbacks = s.stats.failbacks;
    let recovered_gbps = batch(&mut s);

    // Grade RCA on the run's own flight-recorder ring: every confident
    // switch-level attribution must name the leaf that owns the trunk.
    let g = rca::build(&sink.records(), RcaTopo::from_config(&s.cfg));
    let report = rca::analyze(&g, &s.cfg.rca, None);
    let grade = rca::grade_switches(&report, &[InjectedSwitchFault { switch, at: down_at }]);
    FabricRun {
        affected,
        migrated,
        failbacks,
        lost_ops: s.stats.hung_ops,
        baseline_gbps,
        degraded_gbps,
        recovered_gbps,
        retry_window_ms,
        switch,
        rca_attributed: grade.attributed,
        rca_precision: grade.precision,
    }
}

/// The `fabric` experiment: render [`fabric_run`] as a phase table.
pub fn fabric_failover(cfg: &Config) -> String {
    let r = fabric_run(cfg);
    let mut t = Table::new(vec!["phase", "aggregate Gbps", "note"]);
    t.row(vec![
        "baseline".into(),
        format!("{:.0}", r.baseline_gbps),
        "4 streams, dual-plane fabric healthy".into(),
    ]);
    t.row(vec![
        "trunk down".into(),
        format!("{:.0}", r.degraded_gbps),
        format!(
            "{} affected conns ride the retry window (≈{:.1} ms), then migrate planes",
            r.affected, r.retry_window_ms
        ),
    ]);
    t.row(vec![
        "healed".into(),
        format!("{:.0}", r.recovered_gbps),
        "failback returns traffic to the primary plane".into(),
    ]);
    let mut out = String::from(
        "Fabric fault domains — one plane-0 trunk dies with every NIC port\n\
         still up (path death ≠ port death, §Fault domains)\n\n",
    );
    out.push_str(&t.render());
    let _ = writeln!(
        out,
        "\naffected={} migrated={} completeness={:.2} failbacks={} lost_ops={}",
        r.affected,
        r.migrated,
        r.completeness(),
        r.failbacks,
        r.lost_ops
    );
    let _ = writeln!(
        out,
        "rca: {} switch-level attribution(s) to leaf {} — precision {:.2}",
        r.rca_attributed, r.switch, r.rca_precision
    );
    out
}

/// Everything the §Elastic node-crash preset measures (shared by the
/// `elastic` experiment and `vccl bench elastic`).
#[derive(Debug, Clone)]
pub struct ElasticRun {
    /// Ring shrinks observed (must be exactly 1 for the single crash).
    pub shrinks: u64,
    /// Deferred re-entries after the node returned (must also be 1).
    pub rejoins: u64,
    /// (op, channel) ring steps aborted by the shrink and re-run.
    pub steps_requeued: u64,
    pub lost_ops: u64,
    /// Crash → interrupted collective completion on the shrunk ring (ms).
    pub recovery_ms: f64,
    /// 256MB AllReduce algbw per phase: full ring (crash-free twin),
    /// shrunk N−1 ring, rejoined full ring.
    pub baseline_gbps: f64,
    pub degraded_gbps: f64,
    pub recovered_gbps: f64,
    /// Ring membership after the rejoin vs the full communicator.
    pub rejoin_ranks: usize,
    pub full_ranks: usize,
    /// Rail-disjoint pipeline P2P timers identical to the crash-free twin.
    pub noncrossing_identical: bool,
    /// The crashed node (RCA ground truth).
    pub node: usize,
    pub rca_attributed: usize,
    pub rca_precision: f64,
}

impl ElasticRun {
    /// Rejoin completeness: ranks back in the ring over the full set.
    pub fn rejoin_completeness(&self) -> f64 {
        if self.full_ranks == 0 {
            0.0
        } else {
            self.rejoin_ranks as f64 / self.full_ranks as f64
        }
    }
}

/// §Elastic preset: 3 nodes, a monitored 2-channel AllReduce (whose ring
/// channels stripe rails 0/1 and cross every node) plus two pipeline P2P
/// streams on rails 4/5 between the two survivors. Node 2 crashes
/// mid-collective: the crossing channels are aborted and requeued on the
/// shrunk 2-node ring, the P2P streams — link-disjoint from every crossing
/// channel (per-rail uplinks AND per-rail trunk pairs) — must not shift by
/// a nanosecond, and the node's return re-expands the ring behind QP
/// warm-up. A crash-free twin run provides the baseline goodput and the
/// bit-identity reference. The crash run is flight-recorded so RCA is
/// graded on the same evidence an operator would have.
pub fn elastic_run(cfg: &Config) -> ElasticRun {
    let mk = || {
        let mut c = experiments::transport_cfg(cfg, "vccl", 3, 2);
        c.vccl.monitor = true;
        // Short retry window + warm-up (as `fabric_run`) so the whole
        // crash → rejoin arc fits in under a second of simulated time.
        c.net.ib_timeout_exp = 10;
        c.net.ib_retry_cnt = 2;
        c.net.qp_warmup_ns = 100_000_000;
        c
    };
    // 256MB so the collective is still mid-flight at the 2ms crash (64MB
    // drains in ~1.3ms at line rate — see `bench_failover`'s sizing note).
    let ar_bytes = ByteSize::mb(256).0;
    let p2p_bytes = ByteSize::mb(256).0;
    // Rails 4/5, node 0 → node 1: these never touch the victim node or
    // the AllReduce's rail-0/1 links (channels stripe rails — see
    // `crate::topology::build_rings`), so the crash may not move them.
    let streams = [(RankId(4), RankId(12)), (RankId(5), RankId(13))];
    // Start/finish plus the per-channel roll-up of each P2P stream as one
    // comparable signature; the Debug rendering carries every timer ns.
    let p2p_sig = |s: &ClusterSim, ids: &[OpId]| -> Vec<String> {
        ids.iter()
            .map(|id| {
                let o = &s.ops[id.0];
                format!("{:?} {:?} {:?}", o.started_at, o.finished_at, o.chan_rollup)
            })
            .collect()
    };

    // Crash-free twin: baseline goodput + the bit-identity reference.
    let (ref_sig, baseline_gbps) = {
        let mut s = ClusterSim::new(mk());
        let ar = s.submit(CollKind::AllReduce, ar_bytes);
        let ids: Vec<_> =
            streams.iter().map(|&(a, b)| s.submit_p2p(a, b, p2p_bytes)).collect();
        assert!(s.run_until_op(ar, 400_000_000), "twin allreduce must complete");
        for &id in &ids {
            assert!(s.run_until_op(id, 400_000_000), "twin stream must complete");
        }
        (p2p_sig(&s, &ids), s.ops[ar.0].algbw_gbps().expect("twin allreduce done"))
    };

    // Crash run, flight-recorded end to end.
    let mut c = mk();
    c.trace.enabled = true;
    c.trace.ring_capacity = c.trace.ring_capacity.max(1 << 20);
    c.trace.snapshot_window_ns = c.trace.snapshot_window_ns.max(2_000_000_000);
    let sink = TraceSink::new(c.trace.ring_capacity, c.trace.snapshot_window_ns);
    c.trace.sink = Some(sink.clone());
    let mut s = ClusterSim::new(c);
    let node = 2usize;
    let down_at = SimTime::ms(2);
    let up_at = SimTime::ms(400);
    s.inject_node_down(node, down_at);
    s.inject_node_up(node, up_at);
    let ar = s.submit(CollKind::AllReduce, ar_bytes);
    let ids: Vec<_> = streams.iter().map(|&(a, b)| s.submit_p2p(a, b, p2p_bytes)).collect();
    assert!(s.run_until_op(ar, 400_000_000), "elastic allreduce must complete");
    for &id in &ids {
        assert!(s.run_until_op(id, 400_000_000), "elastic stream must complete");
    }
    let recovery_ms = s.ops[ar.0].finished_at.expect("done").since(down_at).as_ms_f64();
    let steps_requeued = s.stats.ops_requeued;
    let shrinks = s.stats.elastic_shrinks;

    // N−1 goodput: the same AllReduce on the shrunk two-node ring.
    let d = s.submit(CollKind::AllReduce, ar_bytes);
    assert!(s.run_until_op(d, 400_000_000), "degraded allreduce must complete");
    let degraded_gbps = s.ops[d.0].algbw_gbps().expect("degraded allreduce done");
    assert!(s.now() < up_at, "degraded phase must finish before the node returns");

    // Rejoin: run past the node's return and its QP warm-up, then measure
    // the full ring again.
    s.run_until(up_at + SimTime::ms(150));
    s.run_to_idle(400_000_000);
    let rejoin_ranks = s.rings[0].order.len();
    let full_ranks = s.topo.num_ranks();
    let r = s.submit(CollKind::AllReduce, ar_bytes);
    assert!(s.run_until_op(r, 400_000_000), "recovered allreduce must complete");
    let recovered_gbps = s.ops[r.0].algbw_gbps().expect("recovered allreduce done");

    let noncrossing_identical = p2p_sig(&s, &ids) == ref_sig;

    // Grade RCA on the crash run's own flight recorder: every confident
    // host-level attribution must name the crashed node.
    let g = rca::build(&sink.records(), RcaTopo::from_config(&s.cfg));
    let report = rca::analyze(&g, &s.cfg.rca, None);
    let grade = rca::grade_nodes(&report, &[InjectedNodeFault { node, at: down_at }]);
    ElasticRun {
        shrinks,
        rejoins: s.stats.elastic_rejoins,
        steps_requeued,
        lost_ops: s.stats.hung_ops,
        recovery_ms,
        baseline_gbps,
        degraded_gbps,
        recovered_gbps,
        rejoin_ranks,
        full_ranks,
        noncrossing_identical,
        node,
        rca_attributed: grade.attributed,
        rca_precision: grade.precision,
    }
}

/// The `elastic` experiment: render [`elastic_run`] as a phase table.
pub fn elastic_recovery(cfg: &Config) -> String {
    let r = elastic_run(cfg);
    let mut t = Table::new(vec!["phase", "AllReduce algbw (Gbps)", "note"]);
    t.row(vec![
        "baseline".into(),
        format!("{:.0}", r.baseline_gbps),
        "full 3-node ring (crash-free twin)".into(),
    ]);
    t.row(vec![
        "shrunk (N−1)".into(),
        format!("{:.0}", r.degraded_gbps),
        format!(
            "{} step(s) requeued; interrupted op done {:.1} ms after the crash",
            r.steps_requeued, r.recovery_ms
        ),
    ]);
    t.row(vec![
        "rejoined".into(),
        format!("{:.0}", r.recovered_gbps),
        format!("{}/{} ranks back in the ring", r.rejoin_ranks, r.full_ranks),
    ]);
    let mut out = String::from(
        "Elastic node crash — node 2 dies mid-AllReduce, the ring shrinks\n\
         without draining the world, and the node rejoins behind QP warm-up\n\
         (§Elastic)\n\n",
    );
    out.push_str(&t.render());
    let _ = writeln!(
        out,
        "\nshrinks={} rejoins={} steps_requeued={} lost_ops={} rejoin_completeness={:.2}",
        r.shrinks,
        r.rejoins,
        r.steps_requeued,
        r.lost_ops,
        r.rejoin_completeness()
    );
    let _ = writeln!(
        out,
        "non-crossing pipeline P2P bit-identical to the crash-free twin: {}",
        r.noncrossing_identical
    );
    let _ = writeln!(
        out,
        "rca: {} host-level attribution(s) to host {} — precision {:.2}",
        r.rca_attributed, r.node, r.rca_precision
    );
    out
}

/// Ablation: the intentional retry window (≈ half of flaps recover within
/// seconds) vs immediate failover.
pub fn retrywin_ablation(cfg: &Config) -> String {
    // Short flap (2s): with the paper's window the flap rides out with NO
    // failover; with a hair-trigger window every flap churns QPs.
    let run = |timeout_exp: u32, retry: u32| -> (u64, u64, bool) {
        let mut c = cfg.clone();
        c.net.ib_timeout_exp = timeout_exp;
        c.net.ib_retry_cnt = retry;
        c.net.qp_warmup_ns = 300_000_000;
        c.vccl.channels = 1;
        let mut s = ClusterSim::new(c);
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(100));
        s.inject_port_up(port, SimTime::ms(2_100)); // 2s flap
        // 16GB so the transfer (~340ms at line rate) is mid-flight when the
        // flap hits; anything that drains before t=100ms measures nothing.
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::gb(16).0);
        s.run_to_idle(400_000_000);
        let op = &s.ops[id.0];
        (op.finished_at.map(|t| t.as_ns()).unwrap_or(0), s.stats.failovers, op.is_done())
    };
    // Paper window ≈7.5s  vs  hair-trigger ≈50ms.
    let (t_window, fo_window, done_w) = run(18, 7);
    let (t_fast, fo_fast, done_f) = run(10, 3);
    let mut t = Table::new(vec!["policy", "retry window", "failovers", "completion (s)"]);
    t.row(vec![
        "paper (TIMEOUT=18,RETRY=7)".into(),
        "≈7.5s".into(),
        fo_window.to_string(),
        format!("{:.2} done={}", t_window as f64 / 1e9, done_w),
    ]);
    t.row(vec![
        "hair-trigger (TIMEOUT=10,RETRY=3)".into(),
        "≈25ms".into(),
        fo_fast.to_string(),
        format!("{:.2} done={}", t_fast as f64 / 1e9, done_f),
    ]);
    let mut out = String::from(
        "Ablation — retaining the hardware retry window (§3.3):\n\
         short flaps (≈half of failures) recover inside the window with ZERO\n\
         QP churn; a hair-trigger window fails over on every flap, paying\n\
         state migration + a proactive primary reset each time. The paper\n\
         keeps TIMEOUT=18/RETRY=7 because flap-riding is free.\n\n",
    );
    out.push_str(&t.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_link_failures_dominate() {
        let r = fig2_failure_stats(&Config::paper_defaults());
        assert!(r.contains("optical module"));
    }

    #[test]
    fn fig14_vccl_saves_most() {
        let r = fig14_idle_gpu_time(&Config::paper_defaults());
        assert!(r.contains("VCCL fault tolerance"));
    }

    #[test]
    fn retrywin_shows_failover_difference() {
        let r = retrywin_ablation(&Config::paper_defaults());
        assert!(r.contains("hair-trigger"));
    }

    /// §Fault domains acceptance: one trunk down on the dual-plane preset
    /// loses zero collectives, migrates 100 % of the affected conns exactly
    /// once each, fails every one back, and post-failback goodput returns
    /// to the baseline. RCA pins the blame on the owning leaf.
    #[test]
    fn fabric_trunk_down_migrates_all_affected_and_recovers() {
        let r = fabric_run(&Config::paper_defaults());
        assert_eq!(r.affected, 2, "both rail-0 streams share the dead trunk");
        assert_eq!(r.migrated as usize, r.affected, "every affected conn fails over once");
        assert_eq!(r.completeness(), 1.0);
        assert_eq!(r.failbacks, r.migrated);
        assert_eq!(r.lost_ops, 0, "a dual-plane fabric loses nothing to one trunk");
        assert!(
            r.degraded_gbps < r.baseline_gbps * 0.8,
            "the retry window must be visible: {} vs {}",
            r.degraded_gbps,
            r.baseline_gbps
        );
        assert!(
            r.recovered_gbps >= r.baseline_gbps * 0.99,
            "post-failback goodput must return to baseline: {} vs {}",
            r.recovered_gbps,
            r.baseline_gbps
        );
        assert!(r.rca_attributed >= 1, "the trunk outage must be walkable");
        assert!(r.rca_precision >= 0.9, "precision {}", r.rca_precision);
    }

    /// §Elastic acceptance: one node crash mid-collective loses zero ops,
    /// shrinks exactly once and rejoins exactly once, leaves the
    /// rail-disjoint pipeline P2P bit-identical to the crash-free twin,
    /// re-expands to the full ring, and returns goodput to baseline. RCA
    /// pins the blame on the crashed host.
    #[test]
    fn elastic_node_crash_shrinks_rejoins_and_recovers() {
        let r = elastic_run(&Config::paper_defaults());
        assert_eq!(r.shrinks, 1, "exactly one shrink per crash");
        assert_eq!(r.rejoins, 1, "exactly one rejoin per recovery");
        assert!(r.steps_requeued >= 1, "the mid-flight collective must requeue");
        assert_eq!(r.lost_ops, 0, "an elastic shrink loses nothing");
        assert!(
            r.noncrossing_identical,
            "rail-disjoint P2P must not shift by a nanosecond"
        );
        assert_eq!(r.rejoin_completeness(), 1.0, "all ranks return to the ring");
        assert!(
            r.degraded_gbps > 0.0 && r.degraded_gbps < r.baseline_gbps * 1.5,
            "the shrunk ring still moves bytes: {} vs {}",
            r.degraded_gbps,
            r.baseline_gbps
        );
        assert!(
            r.recovered_gbps >= r.baseline_gbps * 0.99,
            "post-rejoin goodput must return to baseline: {} vs {}",
            r.recovered_gbps,
            r.baseline_gbps
        );
        assert!(r.rca_attributed >= 1, "the crash must be walkable");
        assert!(r.rca_precision >= 0.9, "precision {}", r.rca_precision);
    }
}
