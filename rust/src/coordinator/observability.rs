//! Observability experiments: Fig 15, Fig 16, Fig 19 and Table 5.

use std::fmt::Write as _;

use crate::config::Config;
use crate::metrics::Table;
use crate::monitor::{MonitorSet, MsgRecord, Verdict, WindowEstimator};
use crate::sim::SimTime;
use crate::topology::RankId;
use crate::util::{ByteSize, Rng};

/// Synthesize a WR/WC stream for one port: `segments` of (message count,
/// effective Gbps, backlog bytes). Returns the verdict tally.
fn drive_case(
    mon: &mut MonitorSet,
    port: usize,
    segments: &[(usize, f64, u64)],
) -> (usize, usize, usize) {
    let msg = ByteSize::mb(1).0;
    let mut t = 0u64;
    let (mut healthy, mut net, mut non) = (0, 0, 0);
    for &(count, gbps, backlog) in segments {
        let dur = (msg as f64 / (gbps * 0.125)) as u64;
        for _ in 0..count {
            let posted = SimTime::ns(t);
            let completed = SimTime::ns(t + dur);
            match mon.on_wc(port, posted, completed, msg, backlog) {
                Some(Verdict::Healthy) | None => healthy += 1,
                Some(Verdict::NetworkAnomaly) => net += 1,
                Some(Verdict::NonNetwork) => non += 1,
            }
            t += dur;
        }
    }
    (healthy, net, non)
}

/// Fig 15: the four-case straggler-pinpointing study.
pub fn fig15_pinpointing(cfg: &Config) -> String {
    let mk = || MonitorSet::new(&cfg.vccl);
    let steady = 4 * ByteSize::mb(1).0;
    let mut t = Table::new(vec!["case", "healthy", "network-anomaly", "non-network", "expected"]);

    // Case 1: normal CC task — steady 390Gbps, steady backlog.
    let mut m = mk();
    let r = drive_case(&mut m, 0, &[(200, 390.0, steady)]);
    t.row(vec!["1 normal".into(), r.0.to_string(), r.1.to_string(), r.2.to_string(),
               "all healthy".into()]);
    let c1_ok = r.1 == 0 && r.2 == 0;

    // Case 2: manual termination — bandwidth tails off as the NIC buffer
    // drains to zero.
    let mut m = mk();
    let r = drive_case(&mut m, 0, &[(150, 390.0, steady), (20, 60.0, 0)]);
    t.row(vec!["2 termination".into(), r.0.to_string(), r.1.to_string(), r.2.to_string(),
               "no anomaly (buffer exhaustion)".into()]);
    let c2_ok = r.1 == 0;

    // Case 3: network interference (small-packet perftest) — bandwidth
    // halves AND un-sent data piles up on the NIC.
    let mut m = mk();
    let r = drive_case(&mut m, 0, &[(150, 390.0, steady), (50, 120.0, steady * 6)]);
    t.row(vec!["3 net interference".into(), r.0.to_string(), r.1.to_string(), r.2.to_string(),
               "NETWORK anomaly".into()]);
    let c3_ok = r.1 >= 30;

    // Case 4: GPU interference (gpu-burn) — bandwidth collapses but the
    // NIC is starved (compute cannot feed it): NOT the network.
    let mut m = mk();
    let r = drive_case(&mut m, 0, &[(150, 390.0, steady), (50, 110.0, steady / 8)]);
    t.row(vec!["4 gpu interference".into(), r.0.to_string(), r.1.to_string(), r.2.to_string(),
               "non-network (no false positive)".into()]);
    let c4_ok = r.1 == 0 && r.2 >= 30;

    let mut out = String::from("Fig 15 — network-straggler pinpointing across four cases\n\n");
    out.push_str(&t.render());
    let _ = writeln!(
        out,
        "\ncase checks: normal={c1_ok} termination={c2_ok} net-interference={c3_ok} \
         gpu-interference={c4_ok}"
    );
    out
}

/// Fig 16: runtime diagnosis percentage as platform components integrate.
pub fn fig16_diagnosis_ramp(cfg: &Config) -> String {
    let mut rng = Rng::new(cfg.seed ^ 0xF16);
    // Issue categories and the month their collector lands (VCCL's NIC-level
    // μs monitor is the final piece).
    let components: &[(&str, usize, f64)] = &[
        ("hardware counters / dcgmi", 0, 0.35),
        ("host metrics / prometheus", 1, 0.20),
        ("app-level tracing", 2, 0.18),
        ("dependency tracing", 4, 0.12),
        ("VCCL μs network monitor", 6, 0.15),
    ];
    let mut t = Table::new(vec!["month", "runtime diagnosis %"]);
    for month in 0..9 {
        let mut covered: f64 = components
            .iter()
            .filter(|(_, m, _)| *m <= month)
            .map(|(_, _, share)| share)
            .sum();
        covered += rng.uniform(-0.015, 0.015);
        t.row(vec![month.to_string(), format!("{:.1}", covered.min(1.0) * 100.0)]);
    }
    let mut out = String::from(
        "Fig 16 — runtime diagnosis percentage: integrating VCCL's network\n\
         straggler pinpointing completes the full-stack platform (→ ~100%).\n\n",
    );
    out.push_str(&t.render());
    out
}

/// Fig 19 / Appendix H: window-size sweep under a disturbance at 100μs.
pub fn fig19_window_sweep(_cfg: &Config) -> String {
    let msg = ByteSize::kb(256).0;
    // Ground truth: 400 Gbps until 100μs, then converges to 200 Gbps.
    let synth = |w: usize| -> (f64, f64, u64) {
        let mut est = WindowEstimator::new(w);
        let mut rng = Rng::new(42);
        let mut t = 0u64;
        let mut pre = Vec::new();
        let mut post = Vec::new();
        let mut detect_at = None;
        while t < 300_000 {
            let base = if t < 100_000 { 400.0 } else { 200.0 };
            // Per-message noise: queuing interleave (the thing windows
            // amortize) — heavy multiplicative jitter.
            let eff = base * rng.jitter(0.35);
            let dur = (msg as f64 / (eff * 0.125)) as u64;
            if let Some(s) = est.push(MsgRecord {
                posted_at: SimTime::ns(t),
                completed_at: SimTime::ns(t + dur),
                bytes: msg,
            }) {
                if t < 100_000 {
                    pre.push(s.gbps);
                } else {
                    post.push(s.gbps);
                    if detect_at.is_none() && s.gbps < 300.0 {
                        detect_at = Some(t - 100_000);
                    }
                }
            }
            t += dur;
        }
        let cv = |xs: &[f64]| {
            if xs.len() < 2 {
                return 0.0;
            }
            let m = xs.iter().sum::<f64>() / xs.len() as f64;
            let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
            v.sqrt() / m
        };
        (cv(&pre), cv(&post), detect_at.unwrap_or(u64::MAX))
    };
    let mut t = Table::new(vec![
        "window", "fluctuation CV (pre)", "CV (post)", "detection delay (μs)",
    ]);
    for w in [1usize, 8, 32] {
        let (pre, post, d) = synth(w);
        t.row(vec![
            if w == 1 { "1 (per-message)".into() } else { w.to_string() },
            format!("{pre:.3}"),
            format!("{post:.3}"),
            if d == u64::MAX { "missed".into() } else { format!("{:.0}", d as f64 / 1e3) },
        ]);
    }
    let mut out = String::from(
        "Fig 19 — monitor fidelity vs window size (disturbance at 100μs:\n\
         400→200 Gbps): W=1 is noisy, W=32 over-smooths and reacts late,\n\
         W=8 balances accuracy and sensitivity (the Table 3 default).\n\n",
    );
    out.push_str(&t.render());
    out
}

/// Table 5: online monitor overhead (CPU + memory).
pub fn table5_monitor_overhead(cfg: &Config) -> String {
    use crate::ccl::ClusterSim;
    let run = |monitor: bool| -> (f64, f64, usize) {
        let mut c = cfg.clone();
        c.vccl.monitor = monitor;
        c.vccl.channels = 2;
        let mut s = ClusterSim::new(c);
        let _ = s.run_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        let elapsed = s.now().as_ns().max(1) as f64;
        let proxy: u64 = s.stats.proxy_cpu_ns.iter().sum();
        let mon = s.monitor.as_ref().map(|m| m.cpu_overhead_ns()).unwrap_or(0);
        let mem = s.monitor.as_ref().map(|m| m.memory_bytes()).unwrap_or(0);
        (((proxy + mon) as f64 / elapsed) * 100.0, (mon as f64 / elapsed) * 100.0, mem)
    };
    let (cpu_off, _, _) = run(false);
    let (cpu_on, mon_share, mem) = run(true);
    let mut t = Table::new(vec!["scheme", "CPU util %", "monitor memory"]);
    t.row(vec!["w/o monitor".into(), format!("{cpu_off:.2}"), "0".into()]);
    t.row(vec!["w/  monitor".into(), format!("{cpu_on:.2}"), format!("{} B", mem)]);
    let mut out = String::from("Table 5 — system overhead of the online monitor\n\n");
    out.push_str(&t.render());
    let _ = writeln!(
        out,
        "\nmonitor adds {:.2}% CPU (paper: 9.32%→21.1% on a full host) and\n\
         negligible memory (paper: 1.7%→2.1%).",
        cpu_on - cpu_off
    );
    let _ = mon_share;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig15_cases_classified_correctly() {
        let r = fig15_pinpointing(&Config::paper_defaults());
        assert!(r.contains("normal=true"), "{r}");
        assert!(r.contains("termination=true"), "{r}");
        assert!(r.contains("net-interference=true"), "{r}");
        assert!(r.contains("gpu-interference=true"), "{r}");
    }

    #[test]
    fn fig19_w8_between_w1_and_w32() {
        let r = fig19_window_sweep(&Config::paper_defaults());
        assert!(r.contains("per-message"));
    }

    #[test]
    fn fig16_reaches_full_coverage() {
        let r = fig16_diagnosis_ramp(&Config::paper_defaults());
        assert!(r.contains("100.0") || r.contains("99."));
    }
}
