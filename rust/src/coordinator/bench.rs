//! `vccl bench` — the measurement loop.
//!
//! Runs the paper's headline experiments end to end on the deterministic
//! simulator and writes machine-readable `BENCH_<suite>.json` files (see
//! [`crate::metrics::BenchReport`]) so the repo's performance trajectory is
//! tracked from real, reproducible runs:
//!
//! | file                  | reproduces                                      |
//! |-----------------------|-------------------------------------------------|
//! | `BENCH_p2p.json`      | Fig 10 P2P bandwidth/latency + Table 1 SM util   |
//! | `BENCH_failover.json` | §3.3 recovery: failover gap, Fig 13b hang check  |
//! | `BENCH_monitor.json`  | Fig 19 window sweep + Table 5 monitor overhead   |
//! | `BENCH_train.json`    | Fig 11 1F1B training throughput per transport    |
//! | `BENCH_simcore.json`  | §Perf L3 allocator work per network change       |
//! | `BENCH_fabric.json`   | §Fault domains trunk-down plane failover + RCA   |
//! | `BENCH_elastic.json`  | §Elastic node-crash ring shrink/rejoin + RCA     |
//!
//! Everything is simulated time, so the numbers are bit-stable across runs
//! and machines (same config + seed ⇒ same JSON), which is what makes them
//! usable as a regression trajectory.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::ccl::{ClusterSim, CollKind};
use crate::config::Config;
use crate::metrics::BenchReport;
use crate::monitor::{MsgRecord, WindowEstimator};
use crate::pipeline::{PipelineCfg, PipelineSim};
use crate::sim::SimTime;
use crate::topology::RankId;
use crate::util::{ByteSize, Rng};

use super::experiments;

/// Bench-run options.
#[derive(Debug, Clone, Default)]
pub struct BenchOpts {
    /// Smaller sizes / fewer points — used by tests and smoke runs.
    pub quick: bool,
    /// Run only the named suite (`vccl bench fabric`); None = all suites.
    pub suite: Option<String>,
}

/// The suite registry: `vccl bench <name>` accepts any first column.
const SUITES: &[(&str, fn(&Config, &BenchOpts) -> BenchReport)] = &[
    ("p2p", bench_p2p),
    ("failover", bench_failover),
    ("monitor", bench_monitor),
    ("train", bench_train),
    ("simcore", bench_simcore),
    ("fabric", bench_fabric),
    ("elastic", bench_elastic),
];

/// Run the selected suites and write `BENCH_*.json` into `out_dir`.
/// Returns the written paths.
pub fn run_bench(cfg: &Config, out_dir: &Path, opts: &BenchOpts) -> Result<Vec<PathBuf>> {
    if let Some(want) = opts.suite.as_deref() {
        if !SUITES.iter().any(|(n, _)| *n == want) {
            let names: Vec<&str> = SUITES.iter().map(|(n, _)| *n).collect();
            return Err(anyhow!("unknown bench suite {want:?} (one of: {})", names.join(", ")));
        }
    }
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let mut paths = Vec::new();
    for (name, suite) in SUITES {
        if opts.suite.as_deref().is_some_and(|w| w != *name) {
            continue;
        }
        let rep = suite(cfg, opts);
        assert!(!rep.metrics.is_empty(), "bench {} produced no metrics", rep.bench);
        let path = out_dir.join(format!("BENCH_{}.json", rep.bench));
        std::fs::write(&path, rep.to_json())
            .with_context(|| format!("writing {}", path.display()))?;
        paths.push(path);
    }
    Ok(paths)
}

/// A fresh simulator for one transport, via the shared
/// [`experiments::transport_cfg`] normalization. `fair_zero_copy` grants
/// the kernel baseline zero-copy as Fig 10's comparison does ("we
/// explicitly implement the zero-copy mechanism for the NCCL baseline");
/// Table-1-style resource rows use the true NCCL defaults instead.
fn sim_for(cfg: &Config, transport: &str, fair_zero_copy: bool) -> ClusterSim {
    let mut c = experiments::transport_cfg(cfg, transport, 2, 2);
    if transport == "kernel" && fair_zero_copy {
        c.vccl.zero_copy = true;
    }
    ClusterSim::new(c)
}

/// Fig 10 (+ Table 1 companion): P2P throughput/latency and SM residency.
pub fn bench_p2p(cfg: &Config, opts: &BenchOpts) -> BenchReport {
    let mut r = BenchReport::new("p2p", "Fig 10 P2P bandwidth/latency + Table 1 SM utilization");
    let sizes: &[u64] = if opts.quick {
        &[1 << 20, 64 << 20]
    } else {
        &[64 << 10, 1 << 20, 8 << 20, 64 << 20, 256 << 20]
    };
    for (scope, dst) in [("inter", RankId(8)), ("intra", RankId(1))] {
        for transport in ["vccl", "kernel"] {
            for &size in sizes {
                let mut s = sim_for(cfg, transport, true);
                let (t, op) = s.run_p2p(RankId(0), dst, size);
                let bw = op.algbw_gbps().unwrap_or(0.0);
                let label = size_label(size);
                r.push(format!("p2p.{scope}.{transport}.{label}.algbw_gbps"), bw, "gbps");
                r.push(format!("p2p.{scope}.{transport}.{label}.latency_us"), t.as_us_f64(), "us");
            }
        }
    }
    // SM residency of one large inter-node P2P per transport (Table 1/4's
    // point: VCCL holds zero SMs and launches zero communication kernels).
    let size: u64 = if opts.quick { 64 << 20 } else { 256 << 20 };
    for transport in ["vccl", "ncclx", "kernel"] {
        let mut s = sim_for(cfg, transport, false);
        let _ = s.run_p2p(RankId(0), RankId(8), size);
        let now = s.now();
        let util = s.gpus[0].compute.comm_sm_utilization(now) * 100.0;
        r.push(format!("p2p.sm_utilization.{transport}"), util, "percent");
        r.push(
            format!("p2p.kernel_launches.{transport}"),
            s.stats.comm_kernel_launches as f64,
            "count",
        );
    }
    r
}

/// §3.3: failover recovery time on a permanent port failure, and the
/// Fig 13b contrast (NCCL hangs, VCCL completes on the backup QP).
pub fn bench_failover(cfg: &Config, opts: &BenchOpts) -> BenchReport {
    let mut r = BenchReport::new(
        "failover",
        "§3.3 recovery time (Fig 13a shape) + Fig 13b hang-vs-ride-through",
    );
    // 256MB regardless of `quick`: anything smaller completes before the
    // 2ms port-down fires (64MB drains in ~1.3ms at 388Gbps) and the suite
    // would measure nothing. 256 chunks is cheap either way.
    let _ = opts;
    let bytes: u64 = 256 << 20;
    // Shrink the hardware retry window (×2^10 instead of ×2^18) so the
    // bench finishes in bounded sim time; the *ratio* of gap to window is
    // what the paper's Fig 13a narrates.
    let mk = |transport: &str| {
        let mut c = experiments::transport_cfg(cfg, transport, 2, 1);
        c.net.ib_timeout_exp = 10;
        c.net.ib_retry_cnt = 2;
        c.net.qp_warmup_ns = 100_000_000;
        c
    };
    let down_at = SimTime::ms(2);

    // Baseline: same transfer, no failure.
    let mut s = ClusterSim::new(mk("vccl"));
    let (t_base, _) = s.run_p2p(RankId(0), RankId(8), bytes);
    r.push("failover.baseline_completion_ms", t_base.as_ms_f64(), "ms");

    // VCCL: port down at 2ms, never restored — complete on the backup QP.
    let mut s = ClusterSim::new(mk("vccl"));
    let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
    s.inject_port_down(port, down_at);
    let id = s.submit_p2p(RankId(0), RankId(8), bytes);
    s.run_to_idle(100_000_000);
    let completed = s.ops[id.0].is_done();
    let finished_ms = s.ops[id.0].finished_at.map(|t| t.as_ms_f64()).unwrap_or(0.0);
    r.push("failover.vccl.completed", completed as u64 as f64, "bool");
    r.push("failover.vccl.completion_ms", finished_ms, "ms");
    r.push("failover.vccl.failovers", s.stats.failovers as f64, "count");
    // Recovery gap: port-down → first chunk completion on the backup port.
    // Exact even under the §Perf L4 windowed aggregation: the backup port
    // is silent before failover, so its first completion is stored exactly.
    if let Some(bp) = s.conns.iter().find_map(|c| c.backup_port) {
        let ord = s.topo.fabric.port_ordinal(bp);
        if let Some(t) = s.stats.port_traffic.first_completion_at_or_after(ord, down_at.as_ns())
        {
            r.push(
                "failover.vccl.recovery_gap_ms",
                (t - down_at.as_ns()) as f64 / 1e6,
                "ms",
            );
        }
    }
    r.push(
        "failover.retry_window_ms",
        s.cfg.net.retry_window_ns() as f64 / 1e6,
        "ms",
    );

    // NCCL baseline on the identical failure: the op hangs (Fig 13b).
    let mut n = ClusterSim::new(mk("kernel"));
    let port = n.topo.primary_port(n.topo.gpu_of_rank(RankId(0)));
    n.inject_port_down(port, down_at);
    let idn = n.submit_p2p(RankId(0), RankId(8), bytes);
    n.run_to_idle(100_000_000);
    r.push("failover.nccl.hung", n.ops[idn.0].failed as u64 as f64, "bool");
    r
}

/// §Perf L3 + L4: simulator-core work per change, from the deterministic
/// [`crate::net::AllocStats`] and [`crate::net::RdmaStats`] counters (pure
/// functions of simulated activity, so the JSON stays bit-stable across
/// machines). Wall-clock throughput — which is machine-dependent — lives in
/// `benches/flownet.rs` and `benches/rdma.rs`, which also enforce the ≥10×
/// visit-reduction acceptance gates against the reference algorithms.
pub fn bench_simcore(cfg: &Config, opts: &BenchOpts) -> BenchReport {
    let mut r = BenchReport::new(
        "simcore",
        "§Perf L3/L4 simulator core: allocator flow-visits + RDMA QP-visits per change",
    );
    let nodes = if opts.quick { 4 } else { 16 };
    let mut c = experiments::transport_cfg(cfg, "vccl", nodes, 1);
    c.vccl.monitor = false;
    let mut s = ClusterSim::new(c);
    let id = s.submit(CollKind::AllReduce, 8 << 20);
    s.run_to_idle(400_000_000);
    assert!(s.ops[id.0].is_done(), "simcore allreduce must complete");
    let a = s.rdma.flows.alloc_stats();
    r.push("simcore.nodes", nodes as f64, "count");
    r.push("simcore.events_dispatched", s.engine.dispatched() as f64, "count");
    r.push("simcore.alloc.changes", a.changes as f64, "count");
    r.push("simcore.alloc.flow_visits", a.flow_visits as f64, "count");
    r.push("simcore.alloc.global_floor_visits", a.global_floor as f64, "count");
    r.push(
        "simcore.alloc.visit_reduction_x",
        a.global_floor as f64 / a.flow_visits.max(1) as f64,
        "ratio",
    );
    r.push("simcore.alloc.max_component_flows", a.max_component as f64, "count");

    // §Perf L5 (`simcore.mem.*`): transfer-slab accounting on the same
    // AllReduce — the witnesses that bookkeeping is O(active transfers).
    // All counters are deterministic and mode-independent (retaining a
    // finished record never makes it live), so they are safe to track in
    // the BENCH trajectory.
    let m = s.xfers.mem_stats();
    r.push("simcore.mem.xfers_created", m.created as f64, "count");
    r.push("simcore.mem.xfers_retired", m.retired as f64, "count");
    r.push("simcore.mem.xfers_live_end", m.live as f64, "count");
    r.push("simcore.mem.xfers_peak_live", m.high_water as f64, "count");
    r.push(
        "simcore.mem.recycle_ratio_x",
        m.created as f64 / m.high_water.max(1) as f64,
        "ratio",
    );

    // §Perf L5 memory gate numbers at the gate's own scale: a scale64
    // (512-rank) ring AllReduce — the workload `benches/xfer_slab.rs`
    // enforces the ≥100× created-to-peak ratio on. Skipped in quick mode
    // (~0.5M transfers is a release-bench workload, not a smoke one).
    if !opts.quick {
        let mut s = ClusterSim::new(Config::scale64());
        let id = s.submit(CollKind::AllReduce, 32 << 20);
        s.run_to_idle(400_000_000);
        assert!(s.ops[id.0].is_done(), "scale64 allreduce must complete");
        let m = s.xfers.mem_stats();
        r.push("simcore.mem64.xfers_created", m.created as f64, "count");
        r.push("simcore.mem64.xfers_peak_live", m.high_water as f64, "count");
        r.push(
            "simcore.mem64.recycle_ratio_x",
            m.created as f64 / m.high_water.max(1) as f64,
            "ratio",
        );
    }

    // §Perf L6 (`simcore.engine.*`): scheduler throughput and the
    // fast-forward tier's elision split. The twin run drives the IDENTICAL
    // AllReduce with the tier on and asserts the trajectory did not move —
    // the bench doubles as a cheap equivalence smoke on every CI run. The
    // split counters are deterministic; `events_per_sec` is this report's
    // one wall-clock metric (a raw engine churn microbench — the CI gate
    // asserts a generous floor, `benches/simcore.rs` enforces the tighter
    // per-workload gates).
    {
        let mut c = experiments::transport_cfg(cfg, "vccl", nodes, 1);
        c.vccl.monitor = false;
        c.engine.fast_forward = true;
        let mut f = ClusterSim::new(c);
        let fid = f.submit(CollKind::AllReduce, 8 << 20);
        f.run_to_idle(400_000_000);
        assert!(f.ops[fid.0].is_done(), "fast-forward twin must complete");
        assert_eq!(
            f.ops[fid.0].finished_at, s.ops[id.0].finished_at,
            "fast-forward twin diverged from the evented run"
        );
        assert_eq!(
            f.events_processed(),
            s.engine.dispatched(),
            "fast-forward twin must do the same total event work"
        );
        let ff = f.ff_stats();
        let es = f.engine.stats();
        let total = f.events_processed();
        r.push("simcore.engine.events_total", total as f64, "count");
        r.push("simcore.engine.ff_windows", ff.windows as f64, "count");
        r.push("simcore.engine.ff_elided", ff.elided as f64, "count");
        r.push("simcore.engine.ff_local_dispatched", ff.local_dispatched as f64, "count");
        r.push(
            "simcore.engine.ff_share",
            ff.local_dispatched as f64 / total.max(1) as f64,
            "ratio",
        );
        r.push("simcore.engine.window_sorts", es.window_sorts as f64, "count");
        r.push("simcore.engine.window_jumps", es.window_jumps as f64, "count");
        r.push("simcore.engine.overflow_pulls", es.overflow_pulls as f64, "count");
    }
    {
        // Raw calendar-queue churn: schedule+pop a mixed near/far pattern
        // (hot bucket traffic, same-time bursts, occasional overflow-day
        // hops) and report dispatched events per wall-clock second.
        const N: u64 = if cfg!(debug_assertions) { 1 << 18 } else { 1 << 21 };
        let mut e: crate::sim::Engine<u64> = crate::sim::Engine::new();
        let t0 = std::time::Instant::now();
        for i in 0..N {
            let far = if i % 64 == 0 { 8_000_000 } else { 0 };
            let at = e.now() + crate::sim::SimTime::ns(1 + (i % 7) * 777 + far);
            e.schedule_at(at, i);
            if i % 2 == 0 {
                let _ = e.pop();
            }
        }
        while e.pop().is_some() {}
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        r.push("simcore.engine.events_per_sec", N as f64 / secs, "events/s");
    }

    // §Perf L4 (`bench_rdma` suite): RDMA hot-path accounting work on a
    // monitored flap-churn workload — every successful WC reads the
    // per-port backlog (§3.4 condition ii) and every flap walks the
    // port→QP index. The flaps heal inside the retry window ("about half
    // of flaps recover within seconds" — §3.3) so all transfers complete.
    let mut c = experiments::transport_cfg(cfg, "vccl", nodes, 1);
    c.net.ib_timeout_exp = 10;
    c.net.ib_retry_cnt = 2;
    c.vccl.monitor = true;
    let mut s = ClusterSim::new(c);
    let mut ids = Vec::new();
    for pair in 0..nodes / 2 {
        let src = RankId(pair * 2 * 8);
        let dst = RankId((pair * 2 + 1) * 8);
        ids.push(s.submit_p2p(src, dst, 32 << 20));
    }
    for pair in 0..(nodes / 2).min(4) {
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(pair * 2 * 8)));
        let down = SimTime::us(200 + 150 * pair as u64);
        s.inject_port_down(port, down);
        s.inject_port_up(port, down + SimTime::ms(3));
    }
    s.run_to_idle(100_000_000);
    assert!(ids.iter().all(|id| s.ops[id.0].is_done()), "rdma churn transfers must complete");
    let w = s.rdma.rdma_stats();
    r.push("simcore.rdma.qps", s.rdma.num_qps() as f64, "count");
    r.push("simcore.rdma.backlog_reads", w.backlog_reads as f64, "count");
    r.push("simcore.rdma.backlog_qp_visits", w.backlog_qp_visits as f64, "count");
    r.push("simcore.rdma.backlog_scan_floor_visits", w.backlog_scan_floor as f64, "count");
    r.push("simcore.rdma.flap_events", w.flap_events as f64, "count");
    r.push("simcore.rdma.flap_qp_visits", w.flap_qp_visits as f64, "count");
    r.push("simcore.rdma.flap_scan_floor_visits", w.flap_scan_floor as f64, "count");
    r.push("simcore.rdma.visit_reduction_x", w.visit_reduction(), "ratio");
    r
}

/// §Fault domains: the dual-plane trunk-down → plane failover → failback
/// preset (see [`super::reliability::fabric_run`]) as machine-readable
/// gates: plane-failover completeness, zero lost ops, goodput recovery and
/// RCA trunk-to-switch attribution precision.
pub fn bench_fabric(cfg: &Config, opts: &BenchOpts) -> BenchReport {
    // One preset either way: the scenario is already smoke-sized.
    let _ = opts;
    let f = super::reliability::fabric_run(cfg);
    let mut r = BenchReport::new(
        "fabric",
        "§Fault domains: trunk-down plane failover, failback, RCA attribution",
    );
    r.push("fabric.affected_conns", f.affected as f64, "count")
        .push("fabric.migrated_conns", f.migrated as f64, "count")
        .push("fabric.completeness", f.completeness(), "ratio")
        .push("fabric.failbacks", f.failbacks as f64, "count")
        .push("fabric.lost_ops", f.lost_ops as f64, "count")
        .push("fabric.baseline_agg_gbps", f.baseline_gbps, "gbps")
        .push("fabric.degraded_agg_gbps", f.degraded_gbps, "gbps")
        .push("fabric.recovered_agg_gbps", f.recovered_gbps, "gbps")
        .push(
            "fabric.recovered_over_baseline",
            f.recovered_gbps / f.baseline_gbps.max(1e-9),
            "ratio",
        )
        .push("fabric.retry_window_ms", f.retry_window_ms, "ms")
        .push("fabric.rca.switch_attributions", f.rca_attributed as f64, "count")
        .push("fabric.rca.trunk_precision", f.rca_precision, "ratio");
    r
}

/// §Elastic: the node-crash shrink/rejoin preset (see
/// [`super::reliability::elastic_run`]) as machine-readable gates: zero
/// lost ops, exactly one shrink and one rejoin, full rejoin completeness,
/// non-crossing bit-identity, goodput recovery and RCA host attribution.
pub fn bench_elastic(cfg: &Config, opts: &BenchOpts) -> BenchReport {
    // One preset either way: the scenario is already smoke-sized.
    let _ = opts;
    let e = super::reliability::elastic_run(cfg);
    let mut r = BenchReport::new(
        "elastic",
        "§Elastic: node crash → ring shrink → rejoin, with RCA host attribution",
    );
    r.push("elastic.shrinks", e.shrinks as f64, "count")
        .push("elastic.rejoins", e.rejoins as f64, "count")
        .push("elastic.steps_requeued", e.steps_requeued as f64, "count")
        .push("elastic.lost_ops", e.lost_ops as f64, "count")
        .push("elastic.recovery_ms", e.recovery_ms, "ms")
        .push("elastic.baseline_algbw_gbps", e.baseline_gbps, "gbps")
        .push("elastic.degraded_algbw_gbps", e.degraded_gbps, "gbps")
        .push("elastic.recovered_algbw_gbps", e.recovered_gbps, "gbps")
        .push(
            "elastic.degraded_over_baseline",
            e.degraded_gbps / e.baseline_gbps.max(1e-9),
            "ratio",
        )
        .push(
            "elastic.recovered_over_baseline",
            e.recovered_gbps / e.baseline_gbps.max(1e-9),
            "ratio",
        )
        .push("elastic.rejoin_completeness", e.rejoin_completeness(), "ratio")
        .push(
            "elastic.noncrossing_identical",
            e.noncrossing_identical as u64 as f64,
            "bool",
        )
        .push("elastic.rca.node_attributions", e.rca_attributed as f64, "count")
        .push("elastic.rca.node_precision", e.rca_precision, "ratio");
    r
}

/// Integer size label for metric names (`64KB`, `1MB` — never `64.0MB`:
/// metric names are dotted paths, so no decimal point may appear).
fn size_label(bytes: u64) -> String {
    if bytes >= 1 << 20 && bytes % (1 << 20) == 0 {
        format!("{}MB", bytes >> 20)
    } else if bytes >= 1 << 10 && bytes % (1 << 10) == 0 {
        format!("{}KB", bytes >> 10)
    } else {
        format!("{bytes}B")
    }
}

/// Fig 19 / Table 3 window sweep + Table 5 monitor overhead.
pub fn bench_monitor(cfg: &Config, opts: &BenchOpts) -> BenchReport {
    let mut r = BenchReport::new(
        "monitor",
        "Fig 19 window-size sweep (Table 3 W=8) + Table 5 monitor overhead",
    );
    for w in [1usize, 8, 32] {
        let (cv_pre, cv_post, delay_us) = window_fidelity(w);
        r.push(format!("monitor.window{w}.cv_pre"), cv_pre, "ratio");
        r.push(format!("monitor.window{w}.cv_post"), cv_post, "ratio");
        r.push(format!("monitor.window{w}.detection_delay_us"), delay_us, "us");
    }
    // Overhead of the in-band monitor over a real simulated transfer. The
    // suite exists to measure the monitor, so force it on even when the
    // caller's config (env vars, --set) disabled it.
    let mut c = cfg.clone();
    c.vccl.monitor = true;
    c.vccl.channels = 2;
    let size: u64 = if opts.quick { 64 << 20 } else { 256 << 20 };
    let mut s = ClusterSim::new(c);
    let (t, _) = s.run_p2p(RankId(0), RankId(8), size);
    let mon = s.monitor.as_ref().expect("monitor forced on above");
    r.push("monitor.processed_wcs", mon.processed_wcs as f64, "count");
    r.push(
        "monitor.cpu_overhead_percent",
        mon.cpu_overhead_ns() as f64 / t.as_ns().max(1) as f64 * 100.0,
        "percent",
    );
    r.push("monitor.memory_bytes", mon.memory_bytes() as f64, "bytes");
    r
}

/// Synthetic 400→200 Gbps step at t=100μs with heavy per-message jitter
/// (the Fig 19 setup). Returns (CV before, CV after, detection delay μs;
/// −1 when the window never detects the step).
fn window_fidelity(window: usize) -> (f64, f64, f64) {
    let msg = ByteSize::kb(256).0;
    let mut est = WindowEstimator::new(window);
    let mut rng = Rng::new(42);
    let mut t = 0u64;
    let mut pre = Vec::new();
    let mut post = Vec::new();
    let mut detect_at = None;
    while t < 300_000 {
        let base = if t < 100_000 { 400.0 } else { 200.0 };
        let eff = base * rng.jitter(0.35);
        let dur = ((msg as f64 / (eff * 0.125)) as u64).max(1);
        if let Some(s) = est.push(MsgRecord {
            posted_at: SimTime::ns(t),
            completed_at: SimTime::ns(t + dur),
            bytes: msg,
        }) {
            if t < 100_000 {
                pre.push(s.gbps);
            } else {
                post.push(s.gbps);
                if detect_at.is_none() && s.gbps < 300.0 {
                    detect_at = Some(t - 100_000);
                }
            }
        }
        t += dur;
    }
    let cv = |xs: &[f64]| -> f64 {
        if xs.len() < 2 {
            return 0.0;
        }
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        v.sqrt() / m
    };
    (cv(&pre), cv(&post), detect_at.map(|d| d as f64 / 1e3).unwrap_or(-1.0))
}

/// Fig 11: one 1F1B iteration per transport at paper-shaped compute times.
pub fn bench_train(cfg: &Config, opts: &BenchOpts) -> BenchReport {
    let mut r = BenchReport::new("train", "Fig 11 1F1B training throughput per transport");
    let micro = if opts.quick { 4 } else { 8 };
    let mut iter_ns: Vec<(&str, f64)> = Vec::new();
    for transport in ["vccl", "ncclx", "kernel"] {
        let mut c = cfg.clone();
        c.set_key("vccl.transport", transport).expect("known transport");
        let mut pcfg = PipelineCfg::spread(&c, 4, micro);
        pcfg.fwd_ns = 6_000_000;
        pcfg.bwd_ns = 12_000_000;
        pcfg.msg_bytes = 128 << 20;
        // FLOPs consistent with ~55% MFU at full rate (as fig11 uses).
        pcfg.flops_per_micro_stage = pcfg.fwd_ns as f64 * 1e-9 * (989e12 * 0.55);
        let mut p = PipelineSim::new(ClusterSim::new(c), pcfg);
        let res = p.run_iteration();
        r.push(format!("train.{transport}.iter_ms"), res.iter_ns as f64 / 1e6, "ms");
        r.push(format!("train.{transport}.tflops_per_gpu"), res.tflops_per_gpu, "tflops");
        r.push(
            format!("train.{transport}.comm_sm_utilization_percent"),
            res.comm_sm_utilization * 100.0,
            "percent",
        );
        iter_ns.push((transport, res.iter_ns as f64));
    }
    let of = |name: &str| iter_ns.iter().find(|(n, _)| *n == name).map(|(_, v)| *v).unwrap_or(0.0);
    let (v, x, n) = (of("vccl"), of("ncclx"), of("kernel"));
    if v > 0.0 {
        r.push("train.vccl_vs_nccl_gain_percent", (n / v - 1.0) * 100.0, "percent");
        r.push("train.vccl_vs_ncclx_gain_percent", (x / v - 1.0) * 100.0, "percent");
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_labels_have_no_decimal_point() {
        assert_eq!(size_label(64 << 10), "64KB");
        assert_eq!(size_label(1 << 20), "1MB");
        assert_eq!(size_label(256 << 20), "256MB");
        assert_eq!(size_label(100), "100B");
        assert!(!size_label(64 << 20).contains('.'));
    }

    #[test]
    fn window_fidelity_orders_like_fig19() {
        let (pre1, _, _) = window_fidelity(1);
        let (pre8, _, d8) = window_fidelity(8);
        let (pre32, _, _) = window_fidelity(32);
        // Bigger windows smooth more.
        assert!(pre1 > pre8 && pre8 > pre32, "{pre1} {pre8} {pre32}");
        // W=8 still detects the step.
        assert!(d8 >= 0.0, "W=8 must detect the disturbance");
    }

    #[test]
    fn suites_emit_metrics_quickly() {
        let cfg = Config::paper_defaults();
        let opts = BenchOpts { quick: true, ..Default::default() };
        for rep in [bench_monitor(&cfg, &opts), bench_train(&cfg, &opts), bench_simcore(&cfg, &opts)]
        {
            assert!(!rep.metrics.is_empty(), "{} empty", rep.bench);
            assert!(rep.metrics.iter().all(|m| m.value.is_finite()));
        }
    }

    /// `vccl bench fabric` writes exactly BENCH_fabric.json, with the CI
    /// gate metrics present; an unknown suite is rejected up front.
    #[test]
    fn bench_suite_filter_selects_fabric_only() {
        let dir = std::env::temp_dir().join("vccl_bench_fabric_test");
        let _ = std::fs::remove_dir_all(&dir);
        let opts = BenchOpts { quick: true, suite: Some("fabric".into()) };
        let paths = run_bench(&Config::paper_defaults(), &dir, &opts).unwrap();
        assert_eq!(paths.len(), 1);
        assert!(paths[0].ends_with("BENCH_fabric.json"));
        let json = std::fs::read_to_string(&paths[0]).unwrap();
        for key in ["fabric.completeness", "fabric.lost_ops", "fabric.rca.trunk_precision"] {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        let bad = BenchOpts { quick: true, suite: Some("nope".into()) };
        assert!(run_bench(&Config::paper_defaults(), &dir, &bad).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `vccl bench elastic` writes exactly BENCH_elastic.json with the CI
    /// gate metrics present.
    #[test]
    fn bench_suite_filter_selects_elastic_only() {
        let dir = std::env::temp_dir().join("vccl_bench_elastic_test");
        let _ = std::fs::remove_dir_all(&dir);
        let opts = BenchOpts { quick: true, suite: Some("elastic".into()) };
        let paths = run_bench(&Config::paper_defaults(), &dir, &opts).unwrap();
        assert_eq!(paths.len(), 1);
        assert!(paths[0].ends_with("BENCH_elastic.json"));
        let json = std::fs::read_to_string(&paths[0]).unwrap();
        for key in
            ["elastic.lost_ops", "elastic.rejoin_completeness", "elastic.rca.node_precision"]
        {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// The incremental allocator and the O(1) RDMA accounting must beat
    /// their scan floors even on the quick 4-node workload (the 64-node
    /// gates live in benches/flownet.rs and benches/rdma.rs).
    #[test]
    fn simcore_reports_visit_reduction() {
        let rep = bench_simcore(&Config::paper_defaults(), &BenchOpts { quick: true, ..Default::default() });
        let get = |name: &str| {
            rep.metrics
                .iter()
                .find(|m| m.name == name)
                .unwrap_or_else(|| panic!("missing {name}"))
                .value
        };
        assert!(get("simcore.alloc.changes") > 1000.0);
        assert!(
            get("simcore.alloc.visit_reduction_x") > 2.0,
            "even 4 nodes must show a component-scoping win: {}x",
            get("simcore.alloc.visit_reduction_x")
        );
        // §Perf L5: the transfer slab recycles — live slots at quiescence
        // are zero and the created-to-peak ratio shows the reuse win even
        // on the quick 4-node AllReduce (the ≥100× gate lives at 64 nodes
        // in benches/xfer_slab.rs).
        assert!(get("simcore.mem.xfers_created") > 1000.0);
        assert_eq!(get("simcore.mem.xfers_live_end"), 0.0);
        assert!(
            get("simcore.mem.recycle_ratio_x") > 10.0,
            "transfer recycling must bound live slots: {}x",
            get("simcore.mem.recycle_ratio_x")
        );
        // §Perf L6: the engine block reports the fast-forward split (the
        // twin-run equality is asserted inside bench_simcore itself) and a
        // non-degenerate wall-clock throughput.
        assert!(get("simcore.engine.events_total") > 1000.0);
        assert!(get("simcore.engine.ff_windows") > 0.0, "the tier must engage");
        assert!(get("simcore.engine.ff_local_dispatched") > 0.0);
        assert!(get("simcore.engine.events_per_sec") > 0.0);
        // §Perf L4: the monitored churn workload exercises both hot paths.
        assert!(get("simcore.rdma.backlog_reads") > 50.0);
        assert!(get("simcore.rdma.flap_events") >= 4.0);
        assert!(
            get("simcore.rdma.visit_reduction_x") > 2.0,
            "even 4 QPs must show the counter/index win: {}x",
            get("simcore.rdma.visit_reduction_x")
        );
    }
}
