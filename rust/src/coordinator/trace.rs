//! `vccl trace <experiment-id>` — run any experiment with the flight
//! recorder on and export what it saw.
//!
//! The driver installs one shared [`TraceSink`] into the config, so every
//! `ClusterSim` the experiment builds records into the same bounded ring,
//! then writes a Chrome trace-event JSON (load in `chrome://tracing` or
//! Perfetto) and renders the fixed-width incident timeline. Example:
//! `vccl trace fig13a` shows the full port-flap → stall → pointer-migration
//! → resume causal chain of the §3.3 failover.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::Config;
use crate::trace::chrome::{self, ChromeMeta};
use crate::trace::{diff, timeline, Incident, TraceRecord, TraceSink};

/// Ring floor for traced experiment runs: big enough to hold every event a
/// full `fig13a` timeline emits (~300 k instants plus one `AllocPass` per
/// network change since the allocator got trace-spanned), so the causal
/// chain is never evicted mid-run. `--set trace.ring_capacity=N` can only
/// raise it.
const TRACE_CMD_RING_FLOOR: usize = 1 << 20;

/// Everything one traced run produced.
#[derive(Debug)]
pub struct TraceRun {
    /// The experiment's normal report text.
    pub report: String,
    /// Where the Chrome trace JSON was written.
    pub json_path: PathBuf,
    /// Ring contents at the end of the run (oldest first).
    pub records: Vec<TraceRecord>,
    /// Frozen anomaly snapshots.
    pub incidents: Vec<Incident>,
    /// Events evicted from the bounded ring during the run.
    pub dropped: u64,
    /// Human-readable key-event timeline + incident tables.
    pub summary: String,
}

/// Run experiment `id` with tracing forced on; write the Chrome trace to
/// `out` (default `traces/<id>.json`).
pub fn run_traced(id: &str, cfg: &Config, out: Option<&Path>) -> Result<TraceRun> {
    let mut cfg = cfg.clone();
    cfg.trace.enabled = true;
    cfg.trace.ring_capacity = cfg.trace.ring_capacity.max(TRACE_CMD_RING_FLOOR);
    // A failover incident must reach back past the stall that caused it,
    // and the stall lasts the hardware retry window (≈7.5 s at the paper's
    // TIMEOUT=18/RETRY=7) — floor the snapshot window accordingly so the
    // PortDown → FlowStalled prefix of the chain is inside every snapshot.
    cfg.trace.snapshot_window_ns = cfg
        .trace
        .snapshot_window_ns
        .max(cfg.net.retry_window_ns().saturating_add(2_000_000_000));
    let sink = TraceSink::new(cfg.trace.ring_capacity, cfg.trace.snapshot_window_ns);
    cfg.trace.sink = Some(sink.clone());

    let report = super::run_experiment(id, &cfg)?;

    let records = sink.records();
    let incidents = sink.incidents();
    let dropped = sink.dropped();
    let ports_per_nic = if cfg.topo.dual_port_nics { 2 } else { 1 };
    let meta = ChromeMeta { ports_per_node: cfg.topo.nics_per_node * ports_per_nic };
    let json = chrome::export(&records, &meta);

    let json_path = out.map(Path::to_path_buf).unwrap_or_else(|| {
        PathBuf::from("traces").join(format!("{id}.json"))
    });
    if let Some(dir) = json_path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(&json_path, &json)
        .with_context(|| format!("writing {}", json_path.display()))?;

    let mut summary = if records.is_empty() {
        // Synthetic experiments (fig2, fig14, fig16, ...) build no traced
        // simulation; the empty trace is still a valid Chrome JSON.
        format!("experiment {id} built no traced simulation — empty trace\n")
    } else {
        timeline::key_event_timeline(&records)
    };
    for inc in &incidents {
        summary.push('\n');
        summary.push_str(&timeline::incident_table(inc));
    }
    Ok(TraceRun { report, json_path, records, incidents, dropped, summary })
}

/// `vccl trace <id> --diff` — run the experiment twice, each into a fresh
/// sink, and report the event-set delta plus the per-component `AllocPass`
/// histogram comparison. On a deterministic simulator the two runs must be
/// identical; any divergence (first differing record, per-kind count skew,
/// allocator churn) is rendered for inspection. Returns the rendered diff
/// and whether the runs matched.
pub fn run_traced_diff(id: &str, cfg: &Config) -> Result<(String, bool)> {
    let run = |label: &str| -> Result<(Vec<TraceRecord>, Vec<Incident>)> {
        let mut c = cfg.clone();
        c.trace.enabled = true;
        c.trace.ring_capacity = c.trace.ring_capacity.max(TRACE_CMD_RING_FLOOR);
        c.trace.snapshot_window_ns = c
            .trace
            .snapshot_window_ns
            .max(c.net.retry_window_ns().saturating_add(2_000_000_000));
        let sink = TraceSink::new(c.trace.ring_capacity, c.trace.snapshot_window_ns);
        c.trace.sink = Some(sink.clone());
        super::run_experiment(id, &c).with_context(|| format!("{label} run of {id}"))?;
        Ok((sink.records(), sink.incidents()))
    };
    let (ra, ia) = run("first")?;
    let (rb, ib) = run("second")?;
    let d = diff::diff_records(&ra, &rb);
    let mut out = diff::render(&d, "run A", "run B");
    out.push('\n');
    out.push_str(&diff::render_incidents(&ia, &ib, "run A", "run B"));
    Ok((out, d.identical()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::chrome::json_lint;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("vccl_trace_test_{}_{name}", std::process::id()))
    }

    /// A cheap, sim-backed experiment traces end to end: events recorded,
    /// valid Chrome JSON written, timeline rendered.
    #[test]
    fn table5_runs_traced_with_valid_json() {
        let path = tmp("table5.json");
        let run = run_traced("table5", &Config::paper_defaults(), Some(path.as_path())).unwrap();
        assert!(!run.records.is_empty(), "table5 drives a ClusterSim");
        assert!(!run.report.trim().is_empty());
        let json = std::fs::read_to_string(&run.json_path).unwrap();
        json_lint(&json).unwrap();
        assert!(json.contains("\"traceEvents\""));
        let _ = std::fs::remove_file(&path);
    }

    /// Synthetic experiments trace to an empty-but-valid JSON, not an error.
    #[test]
    fn synthetic_experiment_traces_empty() {
        let path = tmp("fig2.json");
        let run = run_traced("fig2", &Config::paper_defaults(), Some(path.as_path())).unwrap();
        assert!(run.records.is_empty());
        assert!(run.summary.contains("no traced simulation"));
        json_lint(&std::fs::read_to_string(&run.json_path).unwrap()).unwrap();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unknown_experiment_is_a_clean_error() {
        assert!(run_traced("not-an-id", &Config::paper_defaults(), None).is_err());
        assert!(run_traced_diff("not-an-id", &Config::paper_defaults()).is_err());
    }

    /// The determinism contract behind `--diff`: two traced runs of the
    /// same experiment at the same seed are event-for-event identical.
    #[test]
    fn traced_diff_of_deterministic_experiment_is_identical() {
        let (text, identical) = run_traced_diff("table5", &Config::paper_defaults()).unwrap();
        assert!(identical, "{text}");
        assert!(text.contains("IDENTICAL"), "{text}");
    }
}
