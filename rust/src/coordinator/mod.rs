//! The leader/coordinator: CLI entry points and the experiment harness
//! that regenerates every table and figure of the paper (see DESIGN.md's
//! experiment index).
//!
//! `vccl exp <id>` runs one experiment and prints its report (also written
//! to `reports/<id>.txt`); `vccl exp all` runs the full set. `vccl bench`
//! runs the headline experiments and emits machine-readable
//! `BENCH_*.json` (see [`bench`]). `vccl train` is the real-compute
//! training entry point (PJRT over the AOT artifacts).

pub mod bench;
pub mod experiments;
pub mod reliability;
pub mod observability;
pub mod rca;
pub mod soak;
pub mod trace;

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use crate::config::Config;

/// Parsed command line (hand-rolled: the offline build has no clap).
#[derive(Debug)]
pub enum Command {
    /// `vccl exp <id> [--set k=v ...]`
    Exp { id: String },
    /// `vccl trace <id> [--out file] [--diff]` — run an experiment with the
    /// flight recorder on; export Chrome trace JSON + incident timeline.
    /// `--diff` runs it twice and prints the event-set delta instead (a
    /// determinism check: expect "identical").
    Trace { id: String, out: Option<PathBuf>, diff: bool },
    /// `vccl rca <id> [--symptom s] [--out file]` — run a fault-injection
    /// scenario, diagnose it from the flight recorder alone, and grade the
    /// diagnosis against the injected ground truth (see [`rca`]).
    Rca { id: String, symptom: Option<String>, out: Option<PathBuf> },
    /// `vccl bench [suite] [--out-dir d] [--quick]` — emit `BENCH_*.json`
    /// (all suites, or just the named one, e.g. `vccl bench fabric`).
    Bench { out_dir: PathBuf, quick: bool, suite: Option<String> },
    /// `vccl soak [--sim-days F] [--quick] [--out-dir d] [--resume ckpt]
    /// [--stop-after-ckpts N]` — time-compressed MTBF fault soak with
    /// checkpoint/resume; emits `BENCH_soak.json` (see [`soak`]).
    Soak { out_dir: PathBuf, opts: soak::SoakOpts },
    /// `vccl train [--preset p] [--steps n] [--transport t] [--out csv]`
    Train { preset: String, steps: u64, out: Option<PathBuf> },
    /// `vccl info` — print resolved configuration.
    Info,
    Help,
}

/// Parse argv. Also applies `--config file` and repeated `--set k=v` onto
/// the returned Config (after env-var overrides).
pub fn parse_args(args: &[String]) -> Result<(Command, Config)> {
    let mut cfg = Config::load(None)?;
    let mut it = args.iter().peekable();
    let cmd = it.next().map(|s| s.as_str()).unwrap_or("help");
    let mut preset = "tiny".to_string();
    let mut steps = 50u64;
    let mut out = None;
    let mut out_dir = PathBuf::from(".");
    let mut quick = false;
    let mut resume = None;
    let mut stop_after_ckpts = None;
    let mut symptom = None;
    let mut diff = false;
    let mut exp_id = String::new();
    if cmd == "soak" {
        // The soak preset (single channel, tight retry window, dual-port
        // NICs — see `Config::soak_defaults`) is the baseline; env vars
        // still apply, and `--config`/`--set` below override further.
        cfg = Config::soak_defaults();
        crate::config::apply_env(&mut cfg, |k| std::env::var(k).ok());
    }
    if cmd == "exp" || cmd == "trace" || cmd == "rca" {
        exp_id = it
            .next()
            .ok_or_else(|| anyhow!("usage: vccl {cmd} <id> (try `vccl {cmd} list`)"))?
            .clone();
    }
    // `vccl bench [suite]` — an optional positional suite filter.
    let mut suite = None;
    if cmd == "bench" {
        if let Some(next) = it.peek() {
            if !next.starts_with("--") {
                suite = Some(it.next().expect("peeked").clone());
            }
        }
    }
    while let Some(flag) = it.next() {
        match flag.as_str() {
            "--config" => {
                let path = it.next().ok_or_else(|| anyhow!("--config needs a path"))?;
                cfg = Config::load(Some(path))?;
            }
            "--set" => {
                let kv = it.next().ok_or_else(|| anyhow!("--set needs k=v"))?;
                let (k, v) =
                    kv.split_once('=').ok_or_else(|| anyhow!("--set expects key=value"))?;
                cfg.set_key(k, v)?;
            }
            "--preset" => preset = it.next().ok_or_else(|| anyhow!("--preset needs a name"))?.clone(),
            "--steps" => {
                steps = it
                    .next()
                    .ok_or_else(|| anyhow!("--steps needs a number"))?
                    .parse()
                    .map_err(|e| anyhow!("--steps: {e}"))?;
            }
            "--out" => out = Some(PathBuf::from(it.next().ok_or_else(|| anyhow!("--out path"))?)),
            "--out-dir" => {
                out_dir = PathBuf::from(it.next().ok_or_else(|| anyhow!("--out-dir path"))?);
            }
            "--quick" => quick = true,
            "--diff" => diff = true,
            "--symptom" => {
                symptom = Some(it.next().ok_or_else(|| anyhow!("--symptom needs a value"))?.clone());
            }
            "--sim-days" => {
                let d = it.next().ok_or_else(|| anyhow!("--sim-days needs a number"))?;
                cfg.set_key("soak.sim_days", d)?;
            }
            "--resume" => {
                resume =
                    Some(PathBuf::from(it.next().ok_or_else(|| anyhow!("--resume needs a path"))?));
            }
            "--stop-after-ckpts" => {
                stop_after_ckpts = Some(
                    it.next()
                        .ok_or_else(|| anyhow!("--stop-after-ckpts needs a number"))?
                        .parse()
                        .map_err(|e| anyhow!("--stop-after-ckpts: {e}"))?,
                );
            }
            "--transport" => {
                let t = it.next().ok_or_else(|| anyhow!("--transport needs a value"))?;
                cfg.set_key("vccl.transport", t)?;
            }
            other => return Err(anyhow!("unknown flag {other:?}")),
        }
    }
    let command = match cmd {
        "exp" => Command::Exp { id: exp_id },
        "trace" => Command::Trace { id: exp_id, out, diff },
        "rca" => Command::Rca { id: exp_id, symptom, out },
        "bench" => Command::Bench { out_dir, quick, suite },
        "soak" => Command::Soak {
            out_dir,
            opts: soak::SoakOpts { quick, resume, stop_after_ckpts },
        },
        "train" => Command::Train { preset, steps, out },
        "info" => Command::Info,
        _ => Command::Help,
    };
    Ok((command, cfg))
}

/// All experiment ids, in paper order.
pub const EXPERIMENTS: &[(&str, &str)] = &[
    ("table1", "SM utilization of P2P workloads (Appendix A)"),
    ("fig2", "failure-type statistics over 10 months"),
    ("fig10", "inter/intra-node P2P bandwidth & latency, VCCL vs NCCL"),
    ("fig11", "training TFLOPS: NCCL vs NCCLX-like vs VCCL, strong scaling"),
    ("fig13a", "SendRecv bandwidth timeline under a port down/up"),
    ("fig13b", "training TFLOPS under link failure: NCCL hangs, VCCL recovers"),
    ("fig14", "failure-induced idle GPU time: single/dual-plane/VCCL"),
    ("fig15", "straggler pinpointing across 4 cases"),
    ("fig16", "runtime diagnosis percentage ramp"),
    ("table4", "kernel invocation, SM and CPU consumption (w/ Fig 17)"),
    ("table5", "online monitor overhead"),
    ("fig18", "AllReduce resilience under multi-port failures (Appendix G)"),
    ("fig19", "monitor window-size sweep (Appendix H)"),
    ("fig21", "memory footprint: eager NCCL vs VCCL dynamic pool (Appendix J)"),
    ("appc", "PP message-size analysis (Appendix C)"),
    ("scaling", "§5 gain-decay model I=(Tn−Tv)/(Tv+α)"),
    ("hostfunc", "Fig 5 ablation: hostFunc ordering deadlock"),
    ("retrywin", "ablation: retry window before failover vs immediate"),
    ("scale64", "64-node (512-GPU) allreduce + failover sweep (§Perf L3)"),
    ("scale256", "256-node (2048-GPU) monitored allreduce + multi-failure sweep (§Perf L4)"),
    ("scale512", "512-node (4096-GPU) monitored allreduce + failover sweep (§Perf L5)"),
    ("scale4k", "4096-node rail-slice monitored allreduce + failover sweep (§Perf L6)"),
    ("fabric", "§Fault domains: trunk-down → backup-plane failover → failback"),
    ("elastic", "§Elastic: node crash → ring shrink → rejoin without draining the world"),
];

/// Run one experiment by id; returns the report text.
pub fn run_experiment(id: &str, cfg: &Config) -> Result<String> {
    let report = match id {
        "table1" => experiments::table1_sm_utilization(cfg),
        "fig2" => reliability::fig2_failure_stats(cfg),
        "fig10" => experiments::fig10_p2p_perf(cfg),
        "fig11" => experiments::fig11_training_throughput(cfg),
        "fig13a" => reliability::fig13a_failover_timeline(cfg),
        "fig13b" => reliability::fig13b_training_under_failure(cfg),
        "fig14" => reliability::fig14_idle_gpu_time(cfg),
        "fig15" => observability::fig15_pinpointing(cfg),
        "fig16" => observability::fig16_diagnosis_ramp(cfg),
        "table4" => experiments::table4_resource_consumption(cfg),
        "table5" => observability::table5_monitor_overhead(cfg),
        "fig18" => reliability::fig18_multiport_stress(cfg),
        "fig19" => observability::fig19_window_sweep(cfg),
        "fig21" => experiments::fig21_memory_footprint(cfg),
        "appc" => experiments::appc_message_sizes(cfg),
        "scaling" => experiments::scaling_gain_decay(cfg),
        "hostfunc" => experiments::hostfunc_ablation(cfg),
        "retrywin" => reliability::retrywin_ablation(cfg),
        "scale64" => experiments::scale64_cluster(cfg),
        "scale256" => experiments::scale256_cluster(cfg),
        "scale512" => experiments::scale512_cluster(cfg),
        "scale4k" => experiments::scale4k_cluster(cfg),
        "fabric" => reliability::fabric_failover(cfg),
        "elastic" => reliability::elastic_recovery(cfg),
        "list" => {
            let mut out = String::new();
            for (id, desc) in EXPERIMENTS {
                out.push_str(&format!("{id:10} {desc}\n"));
            }
            return Ok(out);
        }
        "all" => {
            let mut out = String::new();
            for (id, _) in EXPERIMENTS {
                out.push_str(&format!("\n================ {id} ================\n"));
                out.push_str(&run_experiment(id, cfg)?);
            }
            return Ok(out);
        }
        other => return Err(anyhow!("unknown experiment {other:?} (try `vccl exp list`)")),
    };
    // Persist alongside stdout so reports/ accumulates the full set.
    let dir = std::path::Path::new("reports");
    if std::fs::create_dir_all(dir).is_ok() {
        let _ = std::fs::write(dir.join(format!("{id}.txt")), &report);
    }
    Ok(report)
}

pub fn help_text() -> String {
    let mut s = String::from(
        "vccl — VCCL reproduction coordinator\n\n\
         USAGE:\n\
         \x20 vccl exp <id|list|all> [--set k=v]...   regenerate a paper table/figure\n\
         \x20 vccl trace <id> [--out FILE] [--diff]    run an experiment with the flight\n\
         \x20                                          recorder on; write Chrome trace JSON\n\
         \x20                                          (chrome://tracing / Perfetto) and print\n\
         \x20                                          the incident timeline; --diff runs it\n\
         \x20                                          twice and prints the event-set delta\n\
         \x20 vccl rca <id|list|all> [--symptom S] [--out FILE]\n\
         \x20                                          run a fault-injection scenario\n\
         \x20                                          (fig15|fig16|fig18|scale64|soak), diagnose it\n\
         \x20                                          from the flight recorder, grade against\n\
         \x20                                          the injected ground truth; --out writes\n\
         \x20                                          BENCH_rca.json\n\
         \x20 vccl bench [SUITE] [--out-dir DIR] [--quick]\n\
         \x20                                          run the headline experiments and write\n\
         \x20                                          BENCH_{p2p,failover,monitor,train,simcore,fabric,elastic}.json\n\
         \x20                                          (SUITE restricts to one, e.g. `vccl bench elastic`)\n\
         \x20 vccl soak [--sim-days F] [--quick] [--out-dir DIR]\n\
         \x20           [--resume soak.ckpt] [--stop-after-ckpts N]\n\
         \x20                                          time-compressed MTBF fault soak with\n\
         \x20                                          checkpoint/resume; writes BENCH_soak.json\n\
         \x20 vccl train [--preset tiny|e2e] [--steps N] [--transport vccl|nccl|ncclx]\n\
         \x20           [--out loss.csv]               real PJRT training run\n\
         \x20 vccl info                                print resolved config\n\n\
         EXPERIMENTS:\n",
    );
    for (id, desc) in EXPERIMENTS {
        s.push_str(&format!("  {id:10} {desc}\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parse_exp() {
        let (cmd, _) = parse_args(&argv("exp fig10")).unwrap();
        assert!(matches!(cmd, Command::Exp { id } if id == "fig10"));
    }

    #[test]
    fn parse_train_flags() {
        let (cmd, cfg) =
            parse_args(&argv("train --preset e2e --steps 7 --transport nccl")).unwrap();
        match cmd {
            Command::Train { preset, steps, .. } => {
                assert_eq!(preset, "e2e");
                assert_eq!(steps, 7);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(cfg.vccl.transport, crate::config::Transport::Kernel);
    }

    #[test]
    fn parse_trace() {
        let (cmd, _) = parse_args(&argv("trace fig13a")).unwrap();
        match cmd {
            Command::Trace { id, out, diff } => {
                assert_eq!(id, "fig13a");
                assert!(out.is_none());
                assert!(!diff);
            }
            other => panic!("{other:?}"),
        }
        let (cmd, cfg) =
            parse_args(&argv("trace fig13a --out /tmp/t.json --set trace.ring_capacity=4096"))
                .unwrap();
        match cmd {
            Command::Trace { id, out, diff } => {
                assert_eq!(id, "fig13a");
                assert_eq!(out, Some(std::path::PathBuf::from("/tmp/t.json")));
                assert!(!diff);
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(cfg.trace.ring_capacity, 4096);
        assert!(parse_args(&argv("trace")).is_err(), "trace needs an id");
        let (cmd, _) = parse_args(&argv("trace fig13a --diff")).unwrap();
        assert!(matches!(cmd, Command::Trace { diff: true, .. }));
    }

    #[test]
    fn parse_rca() {
        let (cmd, _) = parse_args(&argv("rca fig15")).unwrap();
        match cmd {
            Command::Rca { id, symptom, out } => {
                assert_eq!(id, "fig15");
                assert!(symptom.is_none() && out.is_none());
            }
            other => panic!("{other:?}"),
        }
        let (cmd, cfg) = parse_args(&argv(
            "rca all --symptom failover --out /tmp/BENCH_rca.json --set rca.max_candidates=5",
        ))
        .unwrap();
        match cmd {
            Command::Rca { id, symptom, out } => {
                assert_eq!(id, "all");
                assert_eq!(symptom.as_deref(), Some("failover"));
                assert_eq!(out, Some(std::path::PathBuf::from("/tmp/BENCH_rca.json")));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(cfg.rca.max_candidates, 5);
        assert!(parse_args(&argv("rca")).is_err(), "rca needs an id");
        assert!(parse_args(&argv("rca fig15 --symptom")).is_err());
    }

    #[test]
    fn parse_bench() {
        let (cmd, _) = parse_args(&argv("bench")).unwrap();
        match cmd {
            Command::Bench { out_dir, quick, suite } => {
                assert_eq!(out_dir, std::path::PathBuf::from("."));
                assert!(!quick);
                assert!(suite.is_none());
            }
            other => panic!("{other:?}"),
        }
        let (cmd, _) = parse_args(&argv("bench --out-dir /tmp/b --quick")).unwrap();
        match cmd {
            Command::Bench { out_dir, quick, suite } => {
                assert_eq!(out_dir, std::path::PathBuf::from("/tmp/b"));
                assert!(quick);
                assert!(suite.is_none());
            }
            other => panic!("{other:?}"),
        }
        // Positional suite filter: `vccl bench fabric --quick`.
        let (cmd, _) = parse_args(&argv("bench fabric --quick --out-dir /tmp/f")).unwrap();
        match cmd {
            Command::Bench { out_dir, quick, suite } => {
                assert_eq!(out_dir, std::path::PathBuf::from("/tmp/f"));
                assert!(quick);
                assert_eq!(suite.as_deref(), Some("fabric"));
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn parse_soak() {
        let (cmd, cfg) = parse_args(&argv("soak")).unwrap();
        match cmd {
            Command::Soak { out_dir, opts } => {
                assert_eq!(out_dir, std::path::PathBuf::from("."));
                assert!(!opts.quick && opts.resume.is_none() && opts.stop_after_ckpts.is_none());
            }
            other => panic!("{other:?}"),
        }
        // The soak command starts from the soak preset...
        assert!(cfg.topo.dual_port_nics);
        assert_eq!(cfg.vccl.channels, 1);
        // ...but `bench` etc. do not.
        let (_, cfg) = parse_args(&argv("bench")).unwrap();
        assert!(!cfg.topo.dual_port_nics);

        let (cmd, cfg) = parse_args(&argv(
            "soak --quick --sim-days 0.5 --out-dir /tmp/s --resume /tmp/s/soak.ckpt \
             --stop-after-ckpts 2 --set soak.mtbf_hours=2",
        ))
        .unwrap();
        match cmd {
            Command::Soak { out_dir, opts } => {
                assert_eq!(out_dir, std::path::PathBuf::from("/tmp/s"));
                assert!(opts.quick);
                assert_eq!(opts.resume, Some(std::path::PathBuf::from("/tmp/s/soak.ckpt")));
                assert_eq!(opts.stop_after_ckpts, Some(2));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(cfg.soak.sim_days, 0.5);
        assert_eq!(cfg.soak.mtbf_hours, 2.0);
        assert!(parse_args(&argv("soak --stop-after-ckpts nope")).is_err());
    }

    #[test]
    fn parse_set_overrides() {
        let (_, cfg) = parse_args(&argv("exp fig10 --set net.link_gbps=200")).unwrap();
        assert_eq!(cfg.net.link_gbps, 200.0);
    }

    #[test]
    fn unknown_flag_rejected() {
        assert!(parse_args(&argv("exp fig10 --bogus")).is_err());
    }

    #[test]
    fn experiment_list_nonempty() {
        let cfg = Config::paper_defaults();
        let listing = run_experiment("list", &cfg).unwrap();
        assert!(listing.contains("fig18"));
        assert!(EXPERIMENTS.len() >= 18);
    }
}
