//! `vccl soak` — the time-compressed soak entry point (§Soak).
//!
//! Drives a [`crate::soak::SoakHarness`] over the configured number of
//! simulated days, persisting a `soak.ckpt` checkpoint every
//! `soak.checkpoint_every` bursts and `BENCH_soak.json` at the end. A run
//! killed mid-soak (crash, CI timeout, `--stop-after-ckpts`) resumes with
//! `--resume soak.ckpt` and produces the **byte-identical** final report —
//! the CI smoke job diffs exactly that.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::config::Config;
use crate::soak::SoakHarness;

/// Soak-run options (parsed from the `vccl soak` command line).
#[derive(Debug, Clone, Default)]
pub struct SoakOpts {
    /// Tiny deterministic slice for CI: ~12 bursts, MTBF of ~2 bursts,
    /// checkpoint every 5. Same code path as a full soak.
    pub quick: bool,
    /// Resume from a `soak.ckpt` written by a previous (interrupted) run.
    pub resume: Option<PathBuf>,
    /// Abort right after the N-th checkpoint is written — CI uses this to
    /// simulate a mid-soak kill deterministically.
    pub stop_after_ckpts: Option<u64>,
}

/// Apply the `--quick` time compression onto a config.
pub fn quick_cfg(mut cfg: Config) -> Config {
    // 12 bursts of 60 simulated seconds; MTBF 108 s ≈ 1.8 bursts so the
    // slice sees several faults of both kinds.
    cfg.soak.sim_days = 12.0 * 60.0 / 86_400.0;
    cfg.soak.mtbf_hours = 0.03;
    cfg.soak.checkpoint_every = 5;
    cfg
}

/// Apply `soak.preset` onto a config: "burst" keeps the 2-node soak
/// cluster; "scale64" widens it to the 64-node scaling topology (the soak
/// baseline already carries scale64's shortened failure time constants,
/// so the widening is the only delta — monitor and dual-port NICs stay).
pub fn preset_cfg(mut cfg: Config) -> Config {
    if cfg.soak.preset == "scale64" {
        cfg.topo.num_nodes = 64;
    }
    cfg
}

/// Run (or resume) a soak; write `soak.ckpt` checkpoints and the final
/// `BENCH_soak.json` into `out_dir`. Returns the human-readable summary.
pub fn run_soak(cfg: &Config, out_dir: &Path, opts: &SoakOpts) -> Result<String> {
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating {}", out_dir.display()))?;
    let cfg = if opts.quick { quick_cfg(cfg.clone()) } else { cfg.clone() };
    let cfg = preset_cfg(cfg);
    let ckpt_path = out_dir.join("soak.ckpt");

    let mut h = match &opts.resume {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading checkpoint {}", path.display()))?;
            let h = SoakHarness::restore(cfg, &text).map_err(|e| anyhow!("resume: {e}"))?;
            eprintln!("soak: resumed at burst {} from {}", h.burst_index(), path.display());
            h
        }
        None => SoakHarness::new(cfg),
    };

    let written = h.run(opts.stop_after_ckpts, &mut |burst, text| {
        // Write-then-rename so a kill mid-write never corrupts the
        // resumable checkpoint.
        let tmp = ckpt_path.with_extension("ckpt.tmp");
        if std::fs::write(&tmp, text).and_then(|_| std::fs::rename(&tmp, &ckpt_path)).is_ok() {
            eprintln!("soak: checkpoint at burst {burst} -> {}", ckpt_path.display());
        }
    });

    if h.hung() {
        return Err(anyhow!(
            "soak: an op failed to complete by burst {} — simulated fault tolerance \
             did not recover (this is a finding, not an I/O error)",
            h.burst_index()
        ));
    }

    let report = h.report();
    let stopped_early = !h.done();
    if stopped_early {
        // Killed on request after the N-th checkpoint: the resumable state
        // is on disk; the final report belongs to the resumed run.
        return Ok(format!(
            "soak: stopped after {written} checkpoint(s) at burst {}/{} (resume with \
             --resume {})",
            h.burst_index(),
            h.params.bursts_total,
            ckpt_path.display()
        ));
    }

    let bench_path = out_dir.join("BENCH_soak.json");
    std::fs::write(&bench_path, report.to_bench().to_json())
        .with_context(|| format!("writing {}", bench_path.display()))?;

    Ok(format!(
        "soak: {} bursts / {:.0} simulated s — availability {:.4}, \
         {} flaps ({} failovers, {} failbacks), {} degrades \
         (precision {:.3}, recall {:.3}), goodput {:.2} GB -> {}",
        report.bursts,
        report.sim_seconds,
        report.availability,
        report.flaps_injected,
        report.failovers,
        report.failbacks,
        report.degrades_injected,
        report.precision(),
        report.recall(),
        report.goodput_bytes as f64 / 1e9,
        bench_path.display()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("vccl_soak_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// The CI smoke contract end to end: an uninterrupted quick soak and a
    /// kill-after-first-checkpoint + resume produce byte-identical
    /// BENCH_soak.json files.
    #[test]
    fn quick_soak_kill_resume_matches_uninterrupted() {
        let cfg = Config::soak_defaults();
        let opts = SoakOpts { quick: true, ..Default::default() };

        let ref_dir = tmpdir("ref");
        let summary = run_soak(&cfg, &ref_dir, &opts).unwrap();
        assert!(summary.contains("availability"), "{summary}");
        let reference = std::fs::read_to_string(ref_dir.join("BENCH_soak.json")).unwrap();

        let dir = tmpdir("resume");
        let killed = run_soak(
            &cfg,
            &dir,
            &SoakOpts { quick: true, stop_after_ckpts: Some(1), ..Default::default() },
        )
        .unwrap();
        assert!(killed.contains("stopped after 1 checkpoint"), "{killed}");
        assert!(dir.join("soak.ckpt").exists());
        assert!(!dir.join("BENCH_soak.json").exists(), "no report from a killed run");

        let resumed = run_soak(
            &cfg,
            &dir,
            &SoakOpts { quick: true, resume: Some(dir.join("soak.ckpt")), ..Default::default() },
        )
        .unwrap();
        assert!(resumed.contains("availability"), "{resumed}");
        let final_json = std::fs::read_to_string(dir.join("BENCH_soak.json")).unwrap();
        assert_eq!(final_json, reference, "resume must be bit-identical to uninterrupted");

        let _ = std::fs::remove_dir_all(&ref_dir);
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// `soak.preset=scale64` widens the soak cluster to the 64-node
    /// scaling topology without touching the rest of the soak baseline;
    /// the default "burst" preset leaves the config alone.
    #[test]
    fn scale64_preset_widens_the_cluster() {
        let mut cfg = Config::soak_defaults();
        cfg.set_key("soak.preset", "scale64").unwrap();
        let c = preset_cfg(cfg);
        assert_eq!(c.topo.num_nodes, 64);
        assert!(c.topo.dual_port_nics, "soak keeps dual-port NICs at scale");
        assert_eq!(c.vccl.channels, 1);
        let base = Config::soak_defaults();
        let c2 = preset_cfg(base.clone());
        assert_eq!(c2.topo.num_nodes, base.topo.num_nodes, "burst preset is a no-op");
    }

    #[test]
    fn resume_from_garbage_is_an_error() {
        let dir = tmpdir("garbage");
        std::fs::create_dir_all(&dir).unwrap();
        let bad = dir.join("soak.ckpt");
        std::fs::write(&bad, "not a checkpoint").unwrap();
        let err = run_soak(
            &Config::soak_defaults(),
            &dir,
            &SoakOpts { quick: true, resume: Some(bad), ..Default::default() },
        )
        .unwrap_err();
        assert!(format!("{err}").contains("resume"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
