//! `vccl rca <scenario>` — ground-truth-scored causal diagnosis.
//!
//! Each scenario here drives a real `ClusterSim` with the flight recorder
//! on, injects faults whose identity and time it keeps as ground truth,
//! then hands the ring to the [`crate::rca`] engine and grades the result:
//! per-scenario precision, recall and time-to-attribution, emitted as
//! `BENCH_rca.json` rows and asserted in tests and CI.
//!
//! | id        | shape                                                        |
//! |-----------|--------------------------------------------------------------|
//! | `fig15`   | 4 sequential single-victim port flaps mid-transfer           |
//! |           | (the pinpointing setting: one fault, one answer)             |
//! | `fig16`   | 6 single-victim rounds with a ramped fault→traffic gap —     |
//! |           | time-to-attribution ramps with symptom availability          |
//! | `fig18`   | progressive multi-victim sweep (3 staggered flaps + a 4th    |
//! |           | fault captured mid-retry-window, leaving a hung op)          |
//! | `scale64` | 64-node multi-victim: 2 flaps + 1 capacity degrade, with     |
//! |           | the monitor on so the degrade is diagnosed via its verdicts  |
//! | `soak`    | a traced MTBF soak (flaps + degrades + switch outages);      |
//! |           | ground truth is the harness's own fault tape — ports graded  |
//! |           | with [`rca::grade`], leaf outages with                       |
//! |           | [`rca::grade_switches`]                                      |
//!
//! Victims are always the *sender-side* primary ports of rail-aligned
//! P2P streams, so the injected port demonstrably carries the traffic the
//! symptoms come from — ground truth without guesswork. The soak scenario
//! extends that to switch-class faults: its tape records the leaf id, and
//! the stall's uplink walks Flow→Link→Switch into the outage window.

use std::fmt::Write as _;

use anyhow::{anyhow, Result};

use crate::ccl::{ClusterSim, CollKind, Event};
use crate::config::Config;
use crate::metrics::{BenchReport, Table};
use crate::rca::{self, InjectedFault, InjectedNodeFault, InjectedSwitchFault, RcaTopo};
use crate::sim::SimTime;
use crate::soak::{SoakHarness, SoakParams, TapeKind};
use crate::topology::RankId;
use crate::trace::{Incident, TraceRecord, TraceSink};
use crate::util::ByteSize;

/// All scenario ids, in report order.
pub const SCENARIOS: &[(&str, &str)] = &[
    ("fig15", "single-victim pinpointing: 4 sequential port flaps"),
    ("fig16", "diagnosis ramp: fault→traffic gap grows per round"),
    ("fig18", "progressive multi-victim sweep with a hung op"),
    ("scale64", "64-node multi-victim: flaps + monitored degrade"),
    ("nodes", "mid-flight node crash: symptoms walk up to the dead host"),
    ("soak", "traced MTBF soak graded against its own fault tape"),
];

/// One executed scenario: the trace it recorded plus its ground truth.
/// Port-class faults (flaps, NIC degrades) land in `injected`;
/// switch-class faults (leaf outages) in `injected_switches`; node
/// crashes (§Elastic) in `injected_nodes`.
#[derive(Debug)]
pub struct Scenario {
    pub name: &'static str,
    pub records: Vec<TraceRecord>,
    pub incidents: Vec<Incident>,
    pub injected: Vec<InjectedFault>,
    pub injected_switches: Vec<InjectedSwitchFault>,
    pub injected_nodes: Vec<InjectedNodeFault>,
    pub topo: RcaTopo,
}

/// Force tracing on (same floors as `vccl trace`): ring big enough that
/// the causal chain is never evicted, snapshot window spanning the retry
/// window so incidents reach back past the stall that caused them.
fn traced(base: &Config) -> (Config, TraceSink) {
    let mut c = base.clone();
    c.trace.enabled = true;
    c.trace.ring_capacity = c.trace.ring_capacity.max(1 << 20);
    c.trace.snapshot_window_ns = c
        .trace
        .snapshot_window_ns
        .max(c.net.retry_window_ns().saturating_add(2_000_000_000));
    let sink = TraceSink::new(c.trace.ring_capacity, c.trace.snapshot_window_ns);
    c.trace.sink = Some(sink.clone());
    (c, sink)
}

/// Short-retry variant (mirrors the reliability experiments' `fast`):
/// ~50 ms retry window so each failover fits in a scenario round.
fn fast(cfg: &Config) -> Config {
    let mut c = cfg.clone();
    c.net.ib_timeout_exp = 12;
    c.net.ib_retry_cnt = 3;
    c.net.qp_warmup_ns = 400_000_000;
    c
}

fn collect(
    name: &'static str,
    cfg: &Config,
    sink: &TraceSink,
    injected: Vec<InjectedFault>,
) -> Scenario {
    Scenario {
        name,
        records: sink.records(),
        incidents: sink.incidents(),
        injected,
        injected_switches: Vec::new(),
        injected_nodes: Vec::new(),
        topo: RcaTopo::from_config(cfg),
    }
}

/// fig15 — single-victim pinpointing. Four rounds; round `v` runs a
/// rail-aligned P2P from rank `2v` and flaps that rank's primary port
/// 2 ms into the transfer. Symptoms appear the instant the flow stalls,
/// so time-to-attribution is near zero.
pub fn fig15_scenario(cfg: &Config) -> Scenario {
    let mut base = fast(cfg);
    base.vccl.channels = 2;
    let (c, sink) = traced(&base);
    let window = c.net.retry_window_ns();
    let mut s = ClusterSim::new(c);
    let mut injected = Vec::new();
    for v in 0..4usize {
        let src = RankId(2 * v);
        let dst = RankId(2 * v + 8);
        let port = s.topo.primary_port(s.topo.gpu_of_rank(src));
        let down = s.now() + SimTime::ms(2);
        let up = down + SimTime::ns(window * 2);
        s.inject_port_down(port, down);
        s.inject_port_up(port, up);
        injected.push(InjectedFault { port: s.topo.fabric.port_ordinal(port), at: down });
        // 256 MB with the flap 2 ms in: provably mid-flight (the fig13a
        // reliability template uses the same shape).
        let id = s.submit_p2p(src, dst, ByteSize::mb(256).0);
        assert!(s.run_until_op(id, 400_000_000), "fig15 round {v} must complete");
        s.run_to_idle(400_000_000); // drain port-up, warmup, failback
    }
    collect("fig15", &s.cfg, &sink, injected)
}

/// fig16 — the diagnosis ramp. Six rounds; round `r` downs rank `r`'s
/// port while the network is *idle*, waits `10·(r+1)` ms, then submits
/// traffic across it. The first walkable symptom (the retry window armed
/// at post time) appears only when traffic hits the dead port, so
/// time-to-attribution ramps with the gap — the scenario that shows tta
/// measures symptom availability, not analysis speed.
pub fn fig16_scenario(cfg: &Config) -> Scenario {
    let mut base = fast(cfg);
    base.vccl.channels = 2;
    let (c, sink) = traced(&base);
    let window = c.net.retry_window_ns();
    let mut s = ClusterSim::new(c);
    let mut injected = Vec::new();
    for r in 0..6usize {
        let src = RankId(r);
        let dst = RankId(r + 8);
        let port = s.topo.primary_port(s.topo.gpu_of_rank(src));
        let down = s.now() + SimTime::ms(1);
        let gap = SimTime::ms(10 * (r as u64 + 1));
        s.inject_port_down(port, down);
        injected.push(InjectedFault { port: s.topo.fabric.port_ordinal(port), at: down });
        // A redundant re-down at the gap end is the clock that carries the
        // idle simulation forward (the event queue is otherwise empty).
        s.inject_port_down(port, down + gap);
        s.run_until(down + gap);
        let id = s.submit_p2p(src, dst, ByteSize::mb(64).0);
        s.inject_port_up(port, s.now() + SimTime::ns(window * 2));
        assert!(s.run_until_op(id, 400_000_000), "fig16 round {r} must complete");
        s.run_to_idle(400_000_000);
    }
    collect("fig16", &s.cfg, &sink, injected)
}

/// fig18 — progressive multi-victim sweep. Three concurrent rail-aligned
/// streams lose their sender ports at 50/100/150 ms; a fourth stream
/// starts at ~200 ms and loses its port at 210 ms. The trace is captured
/// at 230 ms — inside the fourth retry window — so the fourth op is still
/// open: the hung-op symptom (and the incidents' live-transfer snapshots)
/// point at in-flight work, and its walk must name the freshest victim.
pub fn fig18_scenario(cfg: &Config) -> Scenario {
    let mut base = fast(cfg);
    base.vccl.channels = 2;
    let (c, sink) = traced(&base);
    let mut s = ClusterSim::new(c);
    let port_of = |s: &ClusterSim, g: usize| s.topo.primary_port(s.topo.gpu_of_rank(RankId(g)));
    let mut injected = Vec::new();
    // Streams sized so none can complete before its port dies (sim cost is
    // bounded by the 230 ms capture horizon, not the declared size).
    for (i, src) in [0usize, 2, 4].into_iter().enumerate() {
        let _ = s.submit_p2p(RankId(src), RankId(src + 8), ByteSize::gb(16).0);
        let port = port_of(&s, src);
        let down = SimTime::ms(50 * (i as u64 + 1));
        s.inject_port_down(port, down);
        injected.push(InjectedFault { port: s.topo.fabric.port_ordinal(port), at: down });
    }
    s.run_until(SimTime::ms(200));
    let _ = s.submit_p2p(RankId(6), RankId(14), ByteSize::gb(4).0);
    let p6 = port_of(&s, 6);
    let down = SimTime::ms(210);
    s.inject_port_down(p6, down);
    injected.push(InjectedFault { port: s.topo.fabric.port_ordinal(p6), at: down });
    // Capture mid-retry-window: op 3 is hung by construction.
    s.run_until(SimTime::ms(230));
    collect("fig18", &s.cfg, &sink, injected)
}

/// scale64 — multi-victim at 64 nodes (512 GPUs), monitor on. A small
/// healthy AllReduce first (op/step structure at scale), then three
/// concurrent cross-node streams: two lose their sender ports, the third
/// has its port's uplink degraded 8× — that victim is only diagnosable
/// through the monitor's `network-anomaly` verdicts, closing the
/// §3.4 → rca loop.
pub fn scale64_scenario(cfg: &Config) -> Scenario {
    let mut base = fast(&Config::scale64());
    base.seed = cfg.seed;
    base.vccl.monitor = true;
    let (c, sink) = traced(&base);
    let window = c.net.retry_window_ns();
    let mut s = ClusterSim::new(c);
    // Healthy collective baseline across all 512 ranks.
    let id = s.submit(CollKind::AllReduce, ByteSize::mb(1).0);
    s.run_to_idle(400_000_000);
    assert!(s.ops[id.0].is_done(), "scale64 baseline allreduce must complete");
    // Multi-victim phase: cross-node streams from three different nodes.
    let streams = [(0usize, 8usize), (64, 72), (128, 136)];
    let t0 = s.now();
    let mut ops = Vec::new();
    for (src, dst) in streams {
        ops.push(s.submit_p2p(RankId(src), RankId(dst), ByteSize::gb(1).0));
    }
    let mut injected = Vec::new();
    // Victims 1+2: port flaps on the first two senders.
    for (i, (src, _)) in streams.iter().take(2).enumerate() {
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(*src)));
        let down = t0 + SimTime::ms(2 + 2 * i as u64);
        s.inject_port_down(port, down);
        s.inject_port_up(port, down + SimTime::ns(window * 4));
        injected.push(InjectedFault { port: s.topo.fabric.port_ordinal(port), at: down });
    }
    // Victim 3: capacity degrade on the third sender's uplink (§3.4 —
    // the port still moves traffic, so only the monitor sees it).
    let deg_port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(streams[2].0)));
    let deg_link = s.topo.fabric.port_tx(deg_port);
    s.run_until(t0 + SimTime::ms(2));
    let deg_at = s.now();
    let orig = s.rdma.flows.link_capacity_bpns(deg_link);
    for t in s.rdma.flows.set_link_capacity(deg_link, orig / 8.0, deg_at) {
        s.engine.schedule_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
    }
    injected.push(InjectedFault { port: s.topo.fabric.port_ordinal(deg_port), at: deg_at });
    // Let the anomaly phase play out, then heal and drain.
    s.run_until(t0 + SimTime::ms(80));
    let heal = s.now();
    for t in s.rdma.flows.set_link_capacity(deg_link, orig, heal) {
        s.engine.schedule_at(t.at, Event::Flow { flow: t.flow, gen: t.gen });
    }
    for op in ops {
        assert!(s.run_until_op(op, 400_000_000), "scale64 stream must complete");
    }
    s.run_to_idle(400_000_000);
    collect("scale64", &s.cfg, &sink, injected)
}

/// soak — the `vccl rca` pass over a soak run. Drives a short traced MTBF
/// soak with flaps, NIC degrades and leaf-switch outages all weighted on,
/// then grades the diagnosis against the harness's own ground-truth fault
/// tape (the tape is the soak's injection log — no side-channel bookkeeping
/// here). Trunk *degrades* are left out on purpose: a slow-but-alive trunk
/// never stalls a flow, so its only symptom is the victim port's monitor
/// verdict — port-level evidence the soak's in-band grading already scores.
/// Switch-level attribution of hard trunk deaths is graded by the `fabric`
/// bench instead, where the trunk actually goes down.
pub fn soak_scenario(cfg: &Config) -> Scenario {
    let mut base = Config::soak_defaults();
    base.seed = cfg.seed;
    let (c, sink) = traced(&base);
    let mut p = SoakParams::from_config(&c);
    p.bursts_total = 5;
    p.mtbf_ns = 20_000_000_000; // ~3 arrivals per 60 s burst
    p.mttr_ns = 30_000_000_000;
    p.flap_weight = 1;
    p.degrade_weight = 1;
    p.trunk_weight = 0;
    p.switch_weight = 1;
    let mut h = SoakHarness::with_params(c, p);
    while !h.done() {
        h.run_burst();
    }
    assert!(!h.hung(), "the soak scenario must stay live");
    let mut injected = Vec::new();
    let mut injected_switches = Vec::new();
    let mut injected_nodes = Vec::new();
    for e in h.fault_tape() {
        match e.kind {
            TapeKind::Flap | TapeKind::Degrade => {
                injected.push(InjectedFault { port: e.id, at: SimTime::ns(e.at_ns) });
            }
            TapeKind::TrunkDegrade | TapeKind::SwitchDown => {
                injected_switches
                    .push(InjectedSwitchFault { switch: e.id, at: SimTime::ns(e.at_ns) });
            }
            TapeKind::NodeCrash => {
                injected_nodes
                    .push(InjectedNodeFault { node: e.id, at: SimTime::ns(e.at_ns) });
            }
        }
    }
    Scenario {
        name: "soak",
        records: sink.records(),
        incidents: sink.incidents(),
        injected,
        injected_switches,
        injected_nodes,
        topo: RcaTopo::from_config(&h.sim.cfg),
    }
}

/// nodes — the §Elastic diagnosis loop. A 256 MB AllReduce is mid-flight
/// when node 1 crashes outright: every one of its NIC ports dies with no
/// per-port PortDown, the elastic layer shrinks the ring and requeues the
/// interrupted channel, and the collective completes on the survivors.
/// The symptoms (stalls on the victim's uplinks, the errored QPs) must
/// walk Port→Host into the node-down window — graded with
/// [`rca::grade_nodes`].
pub fn nodes_scenario(cfg: &Config) -> Scenario {
    let base = fast(cfg);
    let (c, sink) = traced(&base);
    let mut s = ClusterSim::new(c);
    let down = SimTime::ms(2);
    s.inject_node_down(1, down);
    s.inject_node_up(1, SimTime::ms(800));
    let id = s.submit(CollKind::AllReduce, ByteSize::mb(256).0);
    assert!(s.run_until_op(id, 400_000_000), "the shrunk collective must complete");
    s.run_to_idle(400_000_000); // drain recovery, rejoin, warmups
    assert_eq!(s.stats.elastic_shrinks, 1, "the crash must shrink the ring");
    assert_eq!(s.stats.elastic_rejoins, 1, "the heal must rejoin the ring");
    let mut sc = collect("nodes", &s.cfg, &sink, Vec::new());
    sc.injected_nodes = vec![InjectedNodeFault { node: 1, at: down }];
    sc
}

/// Run one scenario by id.
pub fn run_scenario(id: &str, cfg: &Config) -> Result<Scenario> {
    match id {
        "fig15" => Ok(fig15_scenario(cfg)),
        "fig16" => Ok(fig16_scenario(cfg)),
        "fig18" => Ok(fig18_scenario(cfg)),
        "scale64" => Ok(scale64_scenario(cfg)),
        "nodes" => Ok(nodes_scenario(cfg)),
        "soak" => Ok(soak_scenario(cfg)),
        other => Err(anyhow!("unknown rca scenario {other:?} (try `vccl rca list`)")),
    }
}

/// Analysis + grading of one executed scenario, rendered. Switch- and
/// node-level grades are present only for scenarios whose ground truth
/// includes faults of that class.
#[derive(Debug)]
pub struct Diagnosis {
    pub text: String,
    pub grade: rca::Grade,
    pub switch_grade: Option<rca::Grade>,
    pub node_grade: Option<rca::Grade>,
    /// Multi-fault disambiguation over every injected victim, all classes.
    pub disambiguation: rca::Disambiguation,
}

pub fn diagnose(sc: &Scenario, cfg: &Config, symptom: Option<&str>) -> Diagnosis {
    let g = rca::build(&sc.records, sc.topo);
    let report = rca::analyze(&g, &cfg.rca, symptom);
    let grade = rca::grade(&report, &sc.injected);
    let mut out = rca::render_report(&report, sc.name);
    out.push_str(&rca::render_grade(&grade, sc.name));
    let switch_grade = (!sc.injected_switches.is_empty()).then(|| {
        let sg = rca::grade_switches(&report, &sc.injected_switches);
        let _ = writeln!(
            out,
            "\nground truth (switch-level) — {}: {} injected switch(es), \
             {} attribution(s), precision {:.2}, recall {:.2}",
            sc.name, sg.injected, sg.attributed, sg.precision, sg.recall,
        );
        sg
    });
    let node_grade = (!sc.injected_nodes.is_empty()).then(|| {
        let ng = rca::grade_nodes(&report, &sc.injected_nodes);
        let _ = writeln!(
            out,
            "\nground truth (node-level) — {}: {} crashed node(s), \
             {} attribution(s), precision {:.2}, recall {:.2}",
            sc.name, ng.injected, ng.attributed, ng.precision, ng.recall,
        );
        ng
    });
    // Disambiguation: every victim, regardless of class, competes for
    // every symptom — the score says whether symptoms name their OWN.
    let mut victims: Vec<rca::Node> =
        sc.injected.iter().map(|f| rca::Node::Port(f.port)).collect();
    victims.extend(sc.injected_switches.iter().map(|f| rca::Node::Switch(f.switch)));
    victims.extend(sc.injected_nodes.iter().map(|f| rca::Node::Host(f.node)));
    let disambiguation = rca::disambiguate(&report, &victims);
    if disambiguation.scored + disambiguation.ambiguous > 0 {
        let _ = writeln!(
            out,
            "\ndisambiguation — {}: {}/{} symptom(s) named their own victim \
             ({} ambiguous), score {:.2}",
            sc.name,
            disambiguation.correct,
            disambiguation.scored,
            disambiguation.ambiguous,
            disambiguation.score,
        );
    }
    // Incident join (no string parsing): the triggering verdict/failover
    // port plus the live in-flight transfers frozen with each snapshot —
    // the operator's view of what a hung op was actually waiting on.
    if !sc.incidents.is_empty() {
        let mut t =
            Table::new(vec!["incident", "trigger", "port", "in flight", "sample transfers"]);
        for inc in &sc.incidents {
            let sample = inc
                .live_xfers
                .iter()
                .take(3)
                .map(|x| format!("xfer {} (op {} {}/{})", x.seq, x.op, x.chunks_done, x.chunks_total))
                .collect::<Vec<_>>()
                .join(", ");
            t.row(vec![
                inc.name.clone(),
                inc.trigger.kind().to_string(),
                inc.port().map_or_else(|| "-".to_string(), |p| p.to_string()),
                inc.live_total.to_string(),
                if sample.is_empty() { "-".to_string() } else { sample },
            ]);
        }
        let _ = writeln!(out, "\nincidents ({}):\n", sc.incidents.len());
        out.push_str(&t.render());
    }
    Diagnosis { text: out, grade, switch_grade, node_grade, disambiguation }
}

/// The `vccl rca <id>` entry point: run the scenario set, diagnose, grade,
/// and emit the `BENCH_rca.json` rows.
pub fn run_rca(id: &str, cfg: &Config, symptom: Option<&str>) -> Result<(String, BenchReport)> {
    let ids: Vec<&str> = match id {
        "all" => SCENARIOS.iter().map(|(n, _)| *n).collect(),
        "list" => {
            let mut out = String::new();
            for (n, d) in SCENARIOS {
                let _ = writeln!(out, "{n:10} {d}");
            }
            return Ok((out, BenchReport::new("rca", "Fig 15/16/18 + scale64 + nodes + soak diagnosis")));
        }
        one => vec![one],
    };
    let mut out = String::new();
    let mut bench = BenchReport::new("rca", "Fig 15/16/18 + scale64 + nodes + soak diagnosis");
    for (i, sid) in ids.iter().enumerate() {
        let sc = run_scenario(sid, cfg)?;
        let d = diagnose(&sc, cfg, symptom);
        let grade = &d.grade;
        if i > 0 {
            out.push('\n');
        }
        let _ = writeln!(out, "================ rca {sid} ================");
        out.push_str(&d.text);
        bench
            .push(format!("rca.{sid}.injected"), grade.injected as f64, "count")
            .push(format!("rca.{sid}.attributed"), grade.attributed as f64, "count")
            .push(format!("rca.{sid}.correct"), grade.correct as f64, "count")
            .push(format!("rca.{sid}.recalled"), grade.recalled as f64, "count")
            .push(format!("rca.{sid}.precision"), grade.precision, "ratio")
            .push(format!("rca.{sid}.recall"), grade.recall, "ratio")
            .push(format!("rca.{sid}.tta_mean_ms"), grade.mean_tta_ms(), "ms");
        for (port, d) in &grade.tta_ns {
            bench.push(
                format!("rca.{sid}.tta_port{port}_ms"),
                *d as f64 / 1e6,
                "ms",
            );
        }
        // Switch-class ground truth (the soak tape's leaf outages) gets its
        // own BENCH rows so CI can gate fabric attribution separately.
        if let Some(sg) = &d.switch_grade {
            bench
                .push(format!("rca.{sid}.switch_injected"), sg.injected as f64, "count")
                .push(format!("rca.{sid}.switch_attributed"), sg.attributed as f64, "count")
                .push(format!("rca.{sid}.switch_precision"), sg.precision, "ratio")
                .push(format!("rca.{sid}.switch_recall"), sg.recall, "ratio");
        }
        // Node-class ground truth (§Elastic): crashed-host attribution.
        if let Some(ng) = &d.node_grade {
            bench
                .push(format!("rca.{sid}.node_injected"), ng.injected as f64, "count")
                .push(format!("rca.{sid}.node_attributed"), ng.attributed as f64, "count")
                .push(format!("rca.{sid}.node_precision"), ng.precision, "ratio")
                .push(format!("rca.{sid}.node_recall"), ng.recall, "ratio");
        }
        // The disambiguation satellite: did each symptom name its OWN
        // victim (scored only where exactly one victim was reachable)?
        bench
            .push(format!("rca.{sid}.disambiguation"), d.disambiguation.score, "ratio")
            .push(
                format!("rca.{sid}.disambiguation_scored"),
                d.disambiguation.scored as f64,
                "count",
            )
            .push(
                format!("rca.{sid}.disambiguation_ambiguous"),
                d.disambiguation.ambiguous as f64,
                "count",
            );
    }
    Ok((out, bench))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// fig16's ground truth: time-to-attribution ramps with the injected
    /// fault→traffic gap (10·(r+1) ms per round). Also exercises the
    /// `--symptom` filter on the same trace. (The fig15 hard gates and
    /// bit-identity live in tests/integration.rs.)
    #[test]
    fn fig16_tta_ramps_with_symptom_availability() {
        let cfg = Config::paper_defaults();
        let sc = fig16_scenario(&cfg);
        let d = diagnose(&sc, &cfg, None);
        let (text, grade) = (&d.text, &d.grade);
        assert!(d.switch_grade.is_none(), "fig16 injects no switch-class faults");
        assert!(d.node_grade.is_none(), "fig16 injects no node-class faults");
        assert!(grade.recall >= 0.9, "recall {}\n{text}", grade.recall);
        assert!(grade.precision >= 0.9, "precision {}\n{text}", grade.precision);
        // Ports 0..6 were downed in round order; tta_ns is sorted by port.
        assert_eq!(grade.tta_ns.len(), 6);
        for (r, (port, d)) in grade.tta_ns.iter().enumerate() {
            assert_eq!(*port, r);
            let gap_ms = 10.0 * (r as f64 + 1.0);
            let tta_ms = *d as f64 / 1e6;
            assert!(
                (tta_ms - gap_ms).abs() < 5.0,
                "round {r}: tta {tta_ms} ms vs gap {gap_ms} ms\n{text}"
            );
        }
        let only = diagnose(&sc, &cfg, Some("qp-retry")).text;
        assert!(text.len() > only.len());
        assert!(only.contains("qp-retry"), "{only}");
        assert!(!only.contains("qp-error"), "{only}");
    }

    /// The soak satellite: `vccl rca soak` grades the diagnosis against the
    /// harness's own fault tape. Soft gates as the other multi-victim
    /// scenarios use — nothing may be mis-attributed at either level, and
    /// most victims must be recalled.
    #[test]
    fn soak_scenario_grades_against_the_fault_tape() {
        let cfg = Config::paper_defaults();
        let sc = soak_scenario(&cfg);
        assert!(
            !sc.injected.is_empty() && !sc.injected_switches.is_empty(),
            "5 bursts at 20 s MTBF must land both port- and switch-class faults \
             ({} ports, {} switches)",
            sc.injected.len(),
            sc.injected_switches.len()
        );
        let d = diagnose(&sc, &cfg, None);
        let (text, grade) = (&d.text, &d.grade);
        let sg = d.switch_grade.as_ref().expect("the soak tape carries switch faults");
        assert!(grade.precision >= 0.9, "port precision {}\n{text}", grade.precision);
        assert!(grade.recall >= 0.6, "port recall {}\n{text}", grade.recall);
        // Switch attributions only arise inside an outage's fault window,
        // so every one must name an injected leaf.
        assert!(sg.precision >= 0.9, "switch precision {}\n{text}", sg.precision);
        assert!(sg.recall >= 0.5, "switch recall {}\n{text}", sg.recall);
        assert!(text.contains("ground truth (switch-level) — soak"), "{text}");
    }

    /// §Elastic: the nodes scenario crashes a server mid-collective; the
    /// diagnosis must attribute confidently to the dead host (never to a
    /// port — no per-port PortDown is ever recorded), and the
    /// disambiguation score over the single victim must be perfect.
    #[test]
    fn nodes_scenario_attributes_to_the_dead_host() {
        let cfg = Config::paper_defaults();
        let sc = nodes_scenario(&cfg);
        assert_eq!(sc.injected_nodes.len(), 1);
        assert!(sc.injected.is_empty() && sc.injected_switches.is_empty());
        let d = diagnose(&sc, &cfg, None);
        let ng = d.node_grade.as_ref().expect("node ground truth must be graded");
        assert_eq!(ng.injected, 1);
        assert!(ng.attributed >= 1, "some symptom must walk to the host\n{}", d.text);
        assert!(ng.precision >= 0.9, "node precision {}\n{}", ng.precision, d.text);
        assert_eq!(ng.recall, 1.0, "the crashed host must be recalled\n{}", d.text);
        assert!(d.disambiguation.score >= 0.99, "{:?}\n{}", d.disambiguation, d.text);
        assert!(d.text.contains("ground truth (node-level) — nodes"), "{}", d.text);
        assert!(d.text.contains("host 1"), "{}", d.text);
    }

    #[test]
    fn scenario_ids_resolve() {
        let cfg = Config::paper_defaults();
        assert!(run_scenario("nope", &cfg).is_err());
        let (listing, _) = run_rca("list", &cfg).unwrap();
        for (n, _) in SCENARIOS {
            assert!(listing.contains(n), "{listing}");
        }
    }

    /// Randomized single-fault sweep (the ISSUE's property test): for a
    /// random victim, size and fault time, every confidently attributed
    /// symptom names the injected port, and the victim is always recalled.
    #[test]
    fn property_random_single_fault_always_attributes_to_victim() {
        use crate::util::Rng;
        let mut rng = Rng::new(0x5CC1_0AC4);
        let cases: u64 = if cfg!(debug_assertions) { 3 } else { 9 };
        for case in 0..cases {
            let mut cfg = Config::paper_defaults();
            cfg.seed = 0x5CC1 ^ case;
            let mut base = fast(&cfg);
            base.vccl.channels = 2;
            let (c, sink) = traced(&base);
            let window = c.net.retry_window_ns();
            let mut s = ClusterSim::new(c);
            let src = rng.below(8) as usize;
            let dst = src + 8;
            // ≥256 MB with the flap ≤1.5 ms in: mid-flight even at full
            // dual-channel line rate (≈150 MB moved by then).
            let bytes = ByteSize::mb(256 + rng.below(256)).0;
            let down = SimTime::us(500 + rng.below(1000));
            let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(src)));
            let ordinal = s.topo.fabric.port_ordinal(port);
            s.inject_port_down(port, down);
            s.inject_port_up(port, down + SimTime::ns(window * 2));
            let id = s.submit_p2p(RankId(src), RankId(dst), bytes);
            assert!(s.run_until_op(id, 400_000_000), "case {case} must complete");
            s.run_to_idle(400_000_000);
            let sc = collect("prop", &s.cfg, &sink, vec![InjectedFault { port: ordinal, at: down }]);
            let g = rca::build(&sc.records, sc.topo);
            let report = rca::analyze(&g, &cfg.rca, None);
            for a in &report.attributions {
                if let Some(p) = a.attributed_port() {
                    assert_eq!(
                        p, ordinal,
                        "case {case}: {:?} attributed to port {p}, victim {ordinal}",
                        a.symptom
                    );
                }
            }
            let grade = rca::grade(&report, &sc.injected);
            assert_eq!(grade.recall, 1.0, "case {case} (src {src}, down {down:?})");
        }
    }
}
