//! Efficiency experiments: Table 1, Fig 10, Fig 11, Table 4/Fig 17,
//! Fig 21, Appendix C, the §5 scaling model, the Fig 5 ablation and the
//! `scale64` (§Perf L3), `scale256` (§Perf L4) and `scale512` (§Perf L5)
//! cluster-scale sweeps.

use std::fmt::Write as _;

use crate::ccl::{ClusterSim, CollKind};
use crate::config::{Config, StreamOrdering};
use crate::metrics::Table;
use crate::pipeline::{dp_overhead_ns, relative_gain, PipelineCfg, PipelineSim};
use crate::sim::SimTime;
use crate::topology::RankId;
use crate::util::ByteSize;

/// Normalize a config for one transport: baselines drop VCCL-only features
/// (the kernel baseline additionally loses zero-copy and the lazy pool —
/// NCCL defaults). Shared by the experiment harness and `coordinator::bench`
/// so "the kernel baseline" means the same thing in reports and BENCH JSON.
pub(crate) fn transport_cfg(
    cfg: &Config,
    transport: &str,
    nodes: usize,
    channels: usize,
) -> Config {
    let mut c = cfg.clone();
    c.set_key("vccl.transport", transport).expect("known transport");
    if transport != "smfree" && transport != "vccl" {
        c.vccl.fault_tolerance = false;
        c.vccl.monitor = false;
        if transport == "kernel" {
            c.vccl.zero_copy = false;
            c.vccl.lazy_mempool = false;
        }
    }
    c.topo.num_nodes = nodes;
    c.vccl.channels = channels;
    c
}

fn fresh(cfg: &Config, transport: &str, nodes: usize, channels: usize) -> ClusterSim {
    ClusterSim::new(transport_cfg(cfg, transport, nodes, channels))
}

/// Table 1 / Appendix A: SM utilization of reduction-free workloads under
/// the kernel (NCCL) transport.
pub fn table1_sm_utilization(cfg: &Config) -> String {
    let mut t = Table::new(vec!["workload", "default SMs", "comm SM util (%)", "paper (%)"]);
    // Intra-host P2P: 32 SMs by default.
    {
        let mut s = fresh(cfg, "kernel", 1, 2);
        let _ = s.run_p2p(RankId(0), RankId(1), ByteSize::mb(256).0);
        let now = s.now();
        let u = s.gpus[0].compute.comm_sm_utilization(now) * 100.0;
        t.row(vec!["intra-host P2P".into(), "32".into(), format!("{u:.1}"), "13.2".into()]);
    }
    // Inter-host P2P: 2 SMs.
    {
        let mut s = fresh(cfg, "kernel", 2, 2);
        let _ = s.run_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        let now = s.now();
        let u = s.gpus[0].compute.comm_sm_utilization(now) * 100.0;
        t.row(vec!["inter-host P2P".into(), "2".into(), format!("{u:.1}"), "1.8".into()]);
    }
    // 8-rank alltoall (single node, 28 SMs default per the paper).
    {
        let mut s = fresh(cfg, "kernel", 1, 2);
        let _ = s.run_collective(CollKind::AllToAll, ByteSize::mb(64).0);
        let now = s.now();
        let u: f64 = (0..8)
            .map(|g| s.gpus[g].compute.comm_sm_utilization(now))
            .sum::<f64>()
            / 8.0
            * 100.0;
        t.row(vec!["8-rank alltoall".into(), "28".into(), format!("{u:.1}"), "13.1".into()]);
    }
    // 16-rank alltoall (two nodes, 4 SMs default).
    {
        let mut s = fresh(cfg, "kernel", 2, 2);
        let _ = s.run_collective(CollKind::AllToAll, ByteSize::mb(64).0);
        let now = s.now();
        let u: f64 = (0..16)
            .map(|g| s.gpus[g].compute.comm_sm_utilization(now))
            .sum::<f64>()
            / 16.0
            * 100.0;
        t.row(vec!["16-rank alltoall".into(), "4".into(), format!("{u:.1}"), "2.3".into()]);
    }
    let mut out = String::from("Table 1 — NCCL SM utilization of P2P workloads\n");
    out.push_str(&t.render());
    out.push_str(
        "\nShape check: intra-host P2P and single-node alltoall occupy an order of\n\
         magnitude more SM than the inter-host variants; VCCL's SM-free transport\n\
         reports 0% for all four (see table4).\n",
    );
    out
}

/// Fig 10: P2P bandwidth & latency, VCCL vs NCCL, inter- and intra-node.
pub fn fig10_p2p_perf(cfg: &Config) -> String {
    let sizes: &[u64] = &[
        ByteSize::kb(16).0,
        ByteSize::kb(256).0,
        ByteSize::mb(1).0,
        ByteSize::mb(8).0,
        ByteSize::mb(64).0,
        ByteSize::mb(256).0,
    ];
    let mut out = String::from("Fig 10 — P2P bandwidth and latency (VCCL vs NCCL)\n\n");
    for (label, nodes, dst) in [("inter-node", 2usize, RankId(8)), ("intra-node", 1, RankId(1))] {
        let mut t = Table::new(vec![
            "size", "VCCL GB/s", "NCCL GB/s", "VCCL lat", "NCCL lat", "lat Δ%",
        ]);
        let mut small_deltas = Vec::new();
        for &size in sizes {
            let mut v = fresh(cfg, "vccl", nodes, 2);
            let (tv, opv) = v.run_p2p(RankId(0), dst, size);
            // Fair comparison (§4.1): the NCCL baseline gets zero-copy too.
            let mut n = fresh(cfg, "kernel", nodes, 2);
            n.cfg.vccl.zero_copy = true;
            let (tn, opn) = n.run_p2p(RankId(0), dst, size);
            let d = (1.0 - tv.as_ns() as f64 / tn.as_ns() as f64) * 100.0;
            if size <= ByteSize::mb(1).0 {
                small_deltas.push(d);
            }
            t.row(vec![
                ByteSize(size).to_string(),
                format!("{:.1}", opv.algbw_gbps().unwrap() / 8.0),
                format!("{:.1}", opn.algbw_gbps().unwrap() / 8.0),
                format!("{tv}"),
                format!("{tn}"),
                format!("{d:+.1}"),
            ]);
        }
        let _ = writeln!(out, "{label}:");
        out.push_str(&t.render());
        let avg = small_deltas.iter().sum::<f64>() / small_deltas.len() as f64;
        let _ = writeln!(
            out,
            "small-message (≤1MB) latency reduction, VCCL vs NCCL: {avg:+.1}% \
             (paper inter-node: −18.9% avg; paper intra-node: VCCL *worse* on \
             small messages — copy-engine setup)\n"
        );
    }
    out
}

/// Fig 11: end-to-end training throughput across transports and scales.
pub fn fig11_training_throughput(cfg: &Config) -> String {
    // Two model scales ("177B"/"314B"-shaped per-stage compute) × two
    // cluster sizes. Compute times are per-microbatch per-stage at TP=2.
    let scales = [
        ("GPT-2 177B-shape", 6_000_000u64, 12_000_000u64, 128u64 << 20),
        ("GPT-2 314B-shape", 10_000_000, 20_000_000, 160 << 20),
    ];
    let clusters = [2usize, 4];
    let mut out = String::from("Fig 11 — training TFLOPS (1F1B, PP=4)\n\n");
    let mut t = Table::new(vec![
        "model", "nodes", "NCCL TF", "NCCLX TF", "VCCL TF", "VCCL vs NCCL", "VCCL vs NCCLX",
    ]);
    let mut gains = Vec::new();
    for (name, fwd, bwd, msg) in scales {
        for &nodes in &clusters {
            let run = |transport: &str| {
                let mut c = cfg.clone();
                c.set_key("vccl.transport", transport).unwrap();
                c.topo.num_nodes = nodes;
                let mut pcfg = PipelineCfg::spread(&c, 4, 8);
                pcfg.fwd_ns = fwd;
                pcfg.bwd_ns = bwd;
                pcfg.msg_bytes = msg;
                // FLOPs consistent with ~55% MFU at full rate.
                pcfg.flops_per_micro_stage = fwd as f64 * 1e-9 * (989e12 * 0.55);
                let mut p = PipelineSim::new(ClusterSim::new(c), pcfg);
                p.run_iteration()
            };
            let rn = run("kernel");
            let rx = run("ncclx");
            let rv = run("vccl");
            let g_n = (rn.iter_ns as f64 / rv.iter_ns as f64 - 1.0) * 100.0;
            let g_x = (rx.iter_ns as f64 / rv.iter_ns as f64 - 1.0) * 100.0;
            gains.push(g_n);
            t.row(vec![
                name.to_string(),
                nodes.to_string(),
                format!("{:.0}", rn.tflops_per_gpu),
                format!("{:.0}", rx.tflops_per_gpu),
                format!("{:.0}", rv.tflops_per_gpu),
                format!("+{g_n:.2}%"),
                format!("+{g_x:.2}%"),
            ]);
        }
    }
    out.push_str(&t.render());
    let avg = gains.iter().sum::<f64>() / gains.len() as f64;
    let max = gains.iter().cloned().fold(0.0f64, f64::max);
    let _ = writeln!(
        out,
        "\nVCCL vs NCCL: avg {avg:+.2}%, max {max:+.2}% (paper: avg +4.00%, max +5.28%).\n\
         NCCLX-like sits between them (paper: up to 1.73% below VCCL) — even one\n\
         SM measurably hurts."
    );
    out
}

/// Table 4 + Fig 17: kernel invocation, SM and CPU consumption.
pub fn table4_resource_consumption(cfg: &Config) -> String {
    let mut out = String::from("Table 4 / Fig 17 — resource consumption (64MB inter-node P2P)\n\n");
    let mut t = Table::new(vec![
        "transport", "comm kernel launches", "SM util %", "proxy CPU ms", "CE ops",
    ]);
    for tr in ["kernel", "ncclx", "vccl"] {
        let mut s = fresh(cfg, tr, 2, 2);
        let _ = s.run_p2p(RankId(0), RankId(8), ByteSize::mb(64).0);
        let now = s.now();
        let u = s.gpus[0].compute.comm_sm_utilization(now) * 100.0;
        let cpu_ms: f64 = s.stats.proxy_cpu_ns.iter().sum::<u64>() as f64 / 1e6;
        t.row(vec![
            tr.to_string(),
            s.stats.comm_kernel_launches.to_string(),
            format!("{u:.2}"),
            format!("{cpu_ms:.3}"),
            s.stats.ce_ops.to_string(),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(
        "\nVCCL launches ZERO communication kernels (Table 4) at the cost of ~2%\n\
         more proxy CPU (Fig 17) and copy-engine usage.\n",
    );
    out
}

/// Fig 21 / Appendix J: memory footprint, eager NCCL vs VCCL dynamic pool.
pub fn fig21_memory_footprint(cfg: &Config) -> String {
    use crate::ccl::{AllocPolicy, MemPool};
    // Four model-shaped communicator usage patterns: (name, peers in the
    // communicator, channels, peers actually exercised).
    let shapes = [
        ("GPT-2 32B (dense)", 15usize, 16usize, 4usize),
        ("GPT-2 70B (dense)", 15, 16, 4),
        ("Qwen3-30B-A3B (MoE)", 31, 32, 10),
        ("Qwen3-235B-A22B (MoE)", 63, 32, 14),
    ];
    let buf = cfg.vccl.chunk_bytes * 8;
    let mut t = Table::new(vec!["model", "NCCL GB", "VCCL GB", "reduction %"]);
    for (name, peers, channels, used) in shapes {
        let mut nccl = MemPool::new(AllocPolicy::Eager, false, buf);
        nccl.on_init(peers, channels);
        let mut vccl = MemPool::new(AllocPolicy::LazyPool, true, buf);
        vccl.on_init(peers, channels);
        for p in 0..used {
            for c in 0..channels {
                vccl.on_first_use(p, c);
            }
        }
        // Fig 21 reports TOTAL model HBM; CCL buffers are a slice of it.
        // Model-other HBM (weights/optimizer/activations) for the shape:
        let other: u64 = 60 << 30;
        let n_total = nccl.peak_bytes() + other;
        let v_total = vccl.peak_bytes() + other;
        let red = (1.0 - v_total as f64 / n_total as f64) * 100.0;
        t.row(vec![
            name.to_string(),
            format!("{:.1}", n_total as f64 / (1u64 << 30) as f64),
            format!("{:.1}", v_total as f64 / (1u64 << 30) as f64),
            format!("{red:.1}"),
        ]);
    }
    let mut out = String::from("Fig 21 — HBM footprint (paper: up to 26.7% reduction)\n\n");
    out.push_str(&t.render());
    out
}

/// Appendix C: PP boundary-message size analysis.
pub fn appc_message_sizes(_cfg: &Config) -> String {
    let mut t = Table::new(vec!["B", "L", "H", "precision", "S_PP"]);
    for (b, l, h, p) in [(1u64, 2048u64, 8192u64, 2u64), (4, 2048, 8192, 2), (2, 4096, 12288, 2)] {
        let s = b * l * h * p;
        t.row(vec![
            b.to_string(),
            l.to_string(),
            h.to_string(),
            format!("{}B", p),
            ByteSize(s).to_string(),
        ]);
    }
    let mut out = String::from(
        "Appendix C — S_PP = B × L × H × p: PP transfers routinely exceed 32MB,\n\
         so VCCL's higher small-message intra-node latency is irrelevant in PP.\n\n",
    );
    out.push_str(&t.render());
    out
}

/// §5 scaling model: gain decay with DP width.
pub fn scaling_gain_decay(cfg: &Config) -> String {
    // Measure Tn/Tv once from the pipeline sim, then sweep α analytically.
    let run = |transport: &str| {
        let mut c = cfg.clone();
        c.set_key("vccl.transport", transport).unwrap();
        let mut pcfg = PipelineCfg::spread(&c, 4, 8);
        pcfg.fwd_ns = 6_000_000;
        pcfg.bwd_ns = 12_000_000;
        pcfg.msg_bytes = 128 << 20;
        let mut p = PipelineSim::new(ClusterSim::new(c), pcfg);
        p.run_iteration().iter_ns
    };
    let tn = run("kernel");
    let tv = run("vccl");
    let grad_bytes = 4u64 << 30;
    let mut t = Table::new(vec!["DP width", "alpha (ms)", "I (relative gain %)"]);
    for dp in [2usize, 4, 8, 16, 32, 64] {
        let a = dp_overhead_ns(dp, grad_bytes, cfg.net.link_gbps, cfg.net.hop_latency_ns);
        let i = relative_gain(tn, tv, a) * 100.0;
        t.row(vec![dp.to_string(), format!("{:.1}", a as f64 / 1e6), format!("{i:.2}")]);
    }
    let mut out = String::from(
        "§5 — I = (Tn − Tv)/(Tv + α): the relative gain decays as DP-group\n\
         AllReduce overhead α grows with cluster size, while absolute GPU-time\n\
         savings keep growing with GPU count.\n\n",
    );
    let _ = writeln!(out, "measured Tn = {:.1} ms, Tv = {:.1} ms\n", tn as f64 / 1e6, tv as f64 / 1e6);
    out.push_str(&t.render());
    out
}

/// scale64: a 64-node (512-GPU) ring AllReduce plus a failover sweep on
/// the same fabric — the cluster-scale regime the paper's reliability and
/// observability results live in. Unlocked by the §Perf L3 incremental
/// allocator: the global reference re-rates every live flow on each of the
/// ~10⁶ network changes this workload generates, which made 64 nodes
/// intractable in wall-clock; the component-scoped allocator touches only
/// the handful of flows sharing links with the mutated one.
pub fn scale64_cluster(cfg: &Config) -> String {
    let mut base = Config::scale64();
    base.seed = cfg.seed;
    let mut out = String::from(
        "scale64 — 64-node (512-GPU) AllReduce + failover sweep (§Perf L3)\n\n",
    );

    // Part 1: ring allreduce across all 512 ranks, with allocator work
    // counters (the same numbers BENCH_simcore.json tracks).
    let mut s = ClusterSim::new(base.clone());
    let nranks = s.topo.num_ranks();
    let id = s.submit(CollKind::AllReduce, ByteSize::mb(32).0);
    s.run_to_idle(400_000_000);
    let op = &s.ops[id.0];
    assert!(op.is_done(), "scale64 allreduce must complete");
    let t = op.finished_at.unwrap().since(op.started_at);
    let busbw = op.busbw_gbps(nranks).unwrap_or(0.0);
    let a = s.rdma.flows.alloc_stats();
    let reduction = a.global_floor as f64 / a.flow_visits.max(1) as f64;
    let mut t1 = Table::new(vec!["metric", "value"]);
    t1.row(vec!["ranks".to_string(), nranks.to_string()]);
    t1.row(vec!["AllReduce 32MB completion".into(), format!("{t}")]);
    t1.row(vec!["busbw (Gbps)".into(), format!("{busbw:.0}")]);
    t1.row(vec!["events dispatched".into(), s.engine.dispatched().to_string()]);
    t1.row(vec!["network changes (alloc passes)".into(), a.changes.to_string()]);
    t1.row(vec!["flow visits (incremental)".into(), a.flow_visits.to_string()]);
    t1.row(vec!["flow visits (global-allocator floor)".into(), a.global_floor.to_string()]);
    t1.row(vec!["visit reduction".into(), format!("{reduction:.1}x")]);
    t1.row(vec!["largest component (flows)".into(), a.max_component.to_string()]);
    out.push_str(&t1.render());
    let _ = writeln!(
        out,
        "\nRail-aligned rings keep components tiny (max {} flows across {} \
         changes), which is exactly why component-scoped water-filling wins \
         ≥10x here (acceptance gate enforced by benches/flownet.rs).",
        a.max_component, a.changes
    );

    // Part 2: failover sweep on the same 64-node fabric — the primary port
    // of rank 0 dies at three points inside a 256MB transfer and is never
    // restored; VCCL must ride through on the backup QP every time.
    let mut t2 = Table::new(vec!["down at (ms)", "completed", "failovers", "completion (ms)"]);
    for down_ms in [1u64, 2, 4] {
        let mut s = ClusterSim::new(base.clone());
        let port = s.topo.primary_port(s.topo.gpu_of_rank(RankId(0)));
        s.inject_port_down(port, SimTime::ms(down_ms));
        let id = s.submit_p2p(RankId(0), RankId(8), ByteSize::mb(256).0);
        s.run_to_idle(100_000_000);
        let op = &s.ops[id.0];
        assert!(op.is_done() && !op.failed, "scale64 failover at {down_ms}ms must recover");
        t2.row(vec![
            down_ms.to_string(),
            "yes".to_string(),
            s.stats.failovers.to_string(),
            op.finished_at.map(|t| format!("{:.1}", t.as_ms_f64())).unwrap_or_else(|| "—".into()),
        ]);
    }
    out.push_str("\nfailover sweep (port down mid-256MB P2P, never restored):\n");
    out.push_str(&t2.render());
    out
}

/// scale256: a 256-node (2048-GPU) ring AllReduce — with the §3.4 in-band
/// monitor ON — plus a multi-failure failover sweep on the same fabric.
/// The regime papers like *Collective Communication for 100k+ GPUs*
/// (arXiv:2510.20171) and *Mycroft* (arXiv:2509.03018) treat as the
/// interesting one. Unlocked by §Perf L4: the monitor reads the per-port
/// remaining-to-send backlog on every WC and the failover machinery walks
/// the flapped port's QPs — both were O(QPs) scans that made monitored
/// 256-node runs intractable, and are now a counter lookup and a reverse-
/// index walk (`RdmaNet`, DESIGN.md "§Perf L4"). The heaviest experiment
/// in the catalogue (~8.4M transfers); release-only in the test sweep.
pub fn scale256_cluster(cfg: &Config) -> String {
    let mut base = Config::scale256();
    base.seed = cfg.seed;
    let mut out = String::from(
        "scale256 — 256-node (2048-GPU) monitored AllReduce + multi-failure sweep (§Perf L4)\n\n",
    );
    // Part 1 runs in its own fn so the ~8.4M transfer records drop before
    // part 2 builds its simulation.
    out.push_str(&scale256_allreduce(&base));

    // Part 2: multi-failure sweep — three primary ports on three different
    // nodes die at staggered times inside concurrent 256MB transfers and
    // are never restored; every transfer must ride through on its backup
    // QP (fig18's progressive-failure shape at cluster scale).
    let mut s = ClusterSim::new(base.clone());
    let victims = [(RankId(0), 1u64), (RankId(512), 2), (RankId(1024), 4)];
    let mut ids = Vec::new();
    for &(rank, down_ms) in &victims {
        let port = s.topo.primary_port(s.topo.gpu_of_rank(rank));
        s.inject_port_down(port, SimTime::ms(down_ms));
        ids.push((rank, down_ms, s.submit_p2p(rank, RankId(rank.0 + 8), ByteSize::mb(256).0)));
    }
    s.run_to_idle(200_000_000);
    let mut t2 = Table::new(vec!["victim", "down at (ms)", "completed", "completion (ms)"]);
    for (rank, down_ms, id) in ids {
        let op = &s.ops[id.0];
        assert!(op.is_done() && !op.failed, "scale256 failover for {rank} must recover");
        t2.row(vec![
            rank.to_string(),
            down_ms.to_string(),
            "yes".into(),
            op.finished_at.map(|t| format!("{:.1}", t.as_ms_f64())).unwrap_or_else(|| "—".into()),
        ]);
    }
    let rf = s.rdma.rdma_stats();
    out.push_str("\nmulti-failure sweep (3 ports down mid-256MB P2P, never restored):\n");
    out.push_str(&t2.render());
    let _ = writeln!(
        out,
        "\nfailovers={} probe_deaths={}; each flap visited {} QP(s) total via the \
         port→QP index where the old scan would have walked {} — \
         RDMA hot paths stay O(changed), not O(cluster).",
        s.stats.failovers, s.stats.probe_dead, rf.flap_qp_visits, rf.flap_scan_floor
    );
    assert_eq!(s.stats.failovers, 3, "every victim fails over exactly once");
    out
}

/// scale256 part 1: the monitored 2048-rank ring AllReduce, as its own fn
/// so the ~8.4M transfer records drop before the failover sweep runs.
fn scale256_allreduce(base: &Config) -> String {
    let mut s = ClusterSim::new(base.clone());
    let nranks = s.topo.num_ranks();
    let id = s.submit(CollKind::AllReduce, ByteSize::mb(16).0);
    s.run_to_idle(600_000_000);
    let mut out = String::new();
    let op = &s.ops[id.0];
    assert!(op.is_done(), "scale256 allreduce must complete");
    let t = op.finished_at.unwrap().since(op.started_at);
    let busbw = op.busbw_gbps(nranks).unwrap_or(0.0);
    let a = s.rdma.flows.alloc_stats();
    let r = s.rdma.rdma_stats();
    let mon = s.monitor.as_ref().expect("scale256 keeps the monitor on");
    let mut t1 = Table::new(vec!["metric", "value"]);
    t1.row(vec!["ranks".to_string(), nranks.to_string()]);
    t1.row(vec!["AllReduce 16MB completion".into(), format!("{t}")]);
    t1.row(vec!["busbw (Gbps)".into(), format!("{busbw:.0}")]);
    t1.row(vec!["events dispatched".into(), s.engine.dispatched().to_string()]);
    t1.row(vec!["monitor WCs processed".into(), mon.processed_wcs.to_string()]);
    t1.row(vec!["backlog reads (1 QP visit each)".into(), r.backlog_reads.to_string()]);
    t1.row(vec![
        "backlog QP visits: counter vs scan".into(),
        format!("{} vs {}", r.backlog_qp_visits, r.backlog_scan_floor),
    ]);
    t1.row(vec![
        "QP-visit reduction (§Perf L4 gate ≥10x)".into(),
        format!("{:.0}x", r.visit_reduction()),
    ]);
    t1.row(vec!["alloc passes (§Perf L3)".into(), a.changes.to_string()]);
    t1.row(vec![
        "alloc flow-visit reduction".into(),
        format!("{:.1}x", a.global_floor as f64 / a.flow_visits.max(1) as f64),
    ]);
    t1.row(vec![
        "port-traffic stats memory (bytes)".into(),
        s.stats.port_traffic.memory_bytes().to_string(),
    ]);
    out.push_str(&t1.render());
    let _ = writeln!(
        out,
        "\nThe monitor stays ON at 2048 GPUs because its per-WC backlog read \
         is one counter lookup ({} reads, {} visits) instead of an all-QP \
         scan ({} visits) — the §Perf L4 point. Per-port completion stats \
         are window-bucketed, so their memory tracks elapsed windows, not \
         the {} chunks transferred.",
        r.backlog_reads, r.backlog_qp_visits, r.backlog_scan_floor, mon.processed_wcs
    );
    out
}

/// scale512: a 512-node (4096-GPU) ring AllReduce — monitor ON — plus a
/// multi-failure failover sweep. The proof the §Perf L5 ceiling moved:
/// the AllReduce creates ~33.5M chunked transfers, and before transfer
/// recycling every record stayed resident forever (ROADMAP named memory
/// as the 256-node ceiling — ~8.4M records, gigabytes, per scale256
/// AllReduce; 512 nodes OOMed before anything else broke). With the
/// recycling slab, peak live transfer records track the ~4k active ring
/// hops — the experiment asserts the ≥100× created-to-peak ratio the
/// memory-regression gate (`benches/xfer_slab.rs`) enforces at 64 nodes.
/// Heaviest experiment in the catalogue; release-only in the test sweep.
pub fn scale512_cluster(cfg: &Config) -> String {
    let mut base = Config::scale512();
    base.seed = cfg.seed;
    let mut out = String::from(
        "scale512 — 512-node (4096-GPU) monitored AllReduce + multi-failure sweep (§Perf L5)\n\n",
    );
    // Part 1 in its own fn so its simulation drops before part 2 builds.
    out.push_str(&scale512_allreduce(&base));

    // Part 2: multi-failure sweep — three primary ports on three different
    // nodes die at staggered times inside concurrent 256MB transfers and
    // are never restored; every transfer must ride through on its backup.
    let mut s = ClusterSim::new(base.clone());
    let victims = [(RankId(0), 1u64), (RankId(1024), 2), (RankId(2048), 4)];
    let mut ids = Vec::new();
    for &(rank, down_ms) in &victims {
        let port = s.topo.primary_port(s.topo.gpu_of_rank(rank));
        s.inject_port_down(port, SimTime::ms(down_ms));
        ids.push((rank, down_ms, s.submit_p2p(rank, RankId(rank.0 + 8), ByteSize::mb(256).0)));
    }
    s.run_to_idle(200_000_000);
    let mut t2 = Table::new(vec!["victim", "down at (ms)", "completed", "completion (ms)"]);
    for (rank, down_ms, id) in ids {
        let op = &s.ops[id.0];
        assert!(op.is_done() && !op.failed, "scale512 failover for {rank} must recover");
        t2.row(vec![
            rank.to_string(),
            down_ms.to_string(),
            "yes".into(),
            op.finished_at.map(|t| format!("{:.1}", t.as_ms_f64())).unwrap_or_else(|| "—".into()),
        ]);
    }
    out.push_str("\nmulti-failure sweep (3 ports down mid-256MB P2P, never restored):\n");
    out.push_str(&t2.render());
    let m = s.xfers.mem_stats();
    let _ = writeln!(
        out,
        "\nfailovers={} — and the sweep's transfer records recycle too: \
         {} created, peak {} live.",
        s.stats.failovers, m.created, m.high_water
    );
    assert_eq!(s.stats.failovers, 3, "every victim fails over exactly once");
    out
}

/// scale512 part 1: the monitored 4096-rank ring AllReduce with the
/// §Perf L5 memory evidence, as its own fn so the simulation (and its
/// bounded slab) drops before the failover sweep runs.
fn scale512_allreduce(base: &Config) -> String {
    let mut s = ClusterSim::new(base.clone());
    let nranks = s.topo.num_ranks();
    let id = s.submit(CollKind::AllReduce, ByteSize::mb(16).0);
    s.run_to_idle(2_500_000_000);
    let mut out = String::new();
    let op = &s.ops[id.0];
    assert!(op.is_done(), "scale512 allreduce must complete");
    let t = op.finished_at.unwrap().since(op.started_at);
    let busbw = op.busbw_gbps(nranks).unwrap_or(0.0);
    let r = s.rdma.rdma_stats();
    let m = s.xfers.mem_stats();
    let recycle_ratio = m.created as f64 / m.high_water.max(1) as f64;
    let mon = s.monitor.as_ref().expect("scale512 keeps the monitor on");
    let rollup_bytes: u64 =
        s.ops[id.0].chan_rollup.iter().map(|c| c.bytes).sum();
    let mut t1 = Table::new(vec!["metric", "value"]);
    t1.row(vec!["ranks".to_string(), nranks.to_string()]);
    t1.row(vec!["AllReduce 16MB completion".into(), format!("{t}")]);
    t1.row(vec!["busbw (Gbps)".into(), format!("{busbw:.0}")]);
    t1.row(vec!["events dispatched".into(), s.engine.dispatched().to_string()]);
    t1.row(vec!["monitor WCs processed".into(), mon.processed_wcs.to_string()]);
    t1.row(vec!["QP-visit reduction (§Perf L4)".into(), format!("{:.0}x", r.visit_reduction())]);
    t1.row(vec!["transfers created".into(), m.created.to_string()]);
    t1.row(vec!["peak live transfer slots".into(), m.high_water.to_string()]);
    t1.row(vec!["live at end".into(), m.live.to_string()]);
    t1.row(vec![
        "created / peak-live (§Perf L5 gate ≥100x)".into(),
        format!("{recycle_ratio:.0}x"),
    ]);
    t1.row(vec!["roll-up payload bytes".into(), rollup_bytes.to_string()]);
    out.push_str(&t1.render());
    let _ = writeln!(
        out,
        "\nTransfer bookkeeping is O(active): {} transfers were created but \
         at most {} records were ever live — completed slots recycle through \
         the §Perf L5 slab, and per-op figures live in the roll-ups \
         (here {} B across {} channel(s)). Before L5 the retained records \
         were the 512-node OOM.",
        m.created,
        m.high_water,
        rollup_bytes,
        s.ops[id.0].chan_rollup.len()
    );
    assert!(
        recycle_ratio >= 100.0,
        "§Perf L5 memory gate missed at scale512: {recycle_ratio:.1}x < 100x"
    );
    assert_eq!(m.live, 0, "every transfer must retire at quiescence");
    out
}

/// scale4k: a 4096-node rail-slice ring AllReduce — monitor ON, §Perf L6
/// calendar queue + fast-forward tier engaged — plus a multi-failure
/// failover sweep. The proof the scheduler ceiling moved: at 4096 nodes
/// every ring hop is inter-node, so the transfer count matches scale512's
/// full-rail sweep while the ring is 8× longer, and the engine pushes
/// hundreds of millions of events. The fast-forward tier dispatches the
/// steady-state chunk/flow chatter locally (windows between global-queue
/// events), and the calendar queue keeps the rest O(1) — the experiment
/// prints the elision split and asserts the tier actually engaged.
/// Heaviest experiment in the catalogue; release-only in the test sweep.
pub fn scale4k_cluster(cfg: &Config) -> String {
    let mut base = Config::scale4k();
    base.seed = cfg.seed;
    let mut out = String::from(
        "scale4k — 4096-node rail-slice monitored AllReduce + multi-failure sweep (§Perf L6)\n\n",
    );
    out.push_str(&scale4k_allreduce(&base));

    // Part 2: multi-failure sweep across the ring — three primary ports on
    // three widely separated nodes die at staggered times inside concurrent
    // 256MB transfers and are never restored; every transfer must ride
    // through on its backup (dual-port NICs: the other port of the same
    // NIC — the scale4k rail-slice has one NIC per node).
    let mut s = ClusterSim::new(base.clone());
    let victims = [(RankId(0), 1u64), (RankId(1365), 2), (RankId(2730), 4)];
    let mut ids = Vec::new();
    for &(rank, down_ms) in &victims {
        let port = s.topo.primary_port(s.topo.gpu_of_rank(rank));
        s.inject_port_down(port, SimTime::ms(down_ms));
        ids.push((rank, down_ms, s.submit_p2p(rank, RankId(rank.0 + 8), ByteSize::mb(256).0)));
    }
    s.run_to_idle(200_000_000);
    let mut t2 = Table::new(vec!["victim", "down at (ms)", "completed", "completion (ms)"]);
    for (rank, down_ms, id) in ids {
        let op = &s.ops[id.0];
        assert!(op.is_done() && !op.failed, "scale4k failover for {rank} must recover");
        t2.row(vec![
            rank.to_string(),
            down_ms.to_string(),
            "yes".into(),
            op.finished_at.map(|t| format!("{:.1}", t.as_ms_f64())).unwrap_or_else(|| "—".into()),
        ]);
    }
    out.push_str("\nmulti-failure sweep (3 ports down mid-256MB P2P, never restored):\n");
    out.push_str(&t2.render());
    let ff = s.ff_stats();
    let _ = writeln!(
        out,
        "\nfailovers={} — fault events serialize through the global queue \
         (they bound every fast-forward window), yet {} of {} events still \
         dispatched locally.",
        s.stats.failovers,
        ff.local_dispatched,
        s.events_processed()
    );
    assert_eq!(s.stats.failovers, 3, "every victim fails over exactly once");
    out
}

/// scale4k part 1: the monitored 4096-rank rail-slice AllReduce with the
/// §Perf L6 scheduler evidence, as its own fn so the simulation drops
/// before the failover sweep runs.
fn scale4k_allreduce(base: &Config) -> String {
    let mut s = ClusterSim::new(base.clone());
    let nranks = s.topo.num_ranks();
    let id = s.submit(CollKind::AllReduce, ByteSize::mb(16).0);
    s.run_to_idle(2_500_000_000);
    let mut out = String::new();
    let op = &s.ops[id.0];
    assert!(op.is_done(), "scale4k allreduce must complete");
    let t = op.finished_at.unwrap().since(op.started_at);
    let busbw = op.busbw_gbps(nranks).unwrap_or(0.0);
    let m = s.xfers.mem_stats();
    let es = s.engine.stats();
    let ff = s.ff_stats();
    let total = s.events_processed();
    let elided_pct = 100.0 * ff.local_dispatched as f64 / total.max(1) as f64;
    let mon = s.monitor.as_ref().expect("scale4k keeps the monitor on");
    let mut t1 = Table::new(vec!["metric", "value"]);
    t1.row(vec!["ranks (1 GPU/node rail slice)".to_string(), nranks.to_string()]);
    t1.row(vec!["AllReduce 16MB completion".into(), format!("{t}")]);
    t1.row(vec!["busbw (Gbps)".into(), format!("{busbw:.0}")]);
    t1.row(vec!["events processed".into(), total.to_string()]);
    t1.row(vec!["  via global queue".into(), es.dispatched.to_string()]);
    t1.row(vec!["  fast-forwarded locally".into(), ff.local_dispatched.to_string()]);
    t1.row(vec!["fast-forward share".into(), format!("{elided_pct:.1}%")]);
    t1.row(vec!["fast-forward windows".into(), ff.windows.to_string()]);
    t1.row(vec!["calendar window sorts".into(), es.window_sorts.to_string()]);
    t1.row(vec!["calendar idle jumps".into(), es.window_jumps.to_string()]);
    t1.row(vec!["monitor WCs processed".into(), mon.processed_wcs.to_string()]);
    t1.row(vec!["transfers created".into(), m.created.to_string()]);
    t1.row(vec!["peak live transfer slots".into(), m.high_water.to_string()]);
    out.push_str(&t1.render());
    let _ = writeln!(
        out,
        "\nThe 512-node wall was the scheduler: every chunk/flow/WC event \
         round-tripped a global binary heap. At 4096 nodes the §Perf L6 \
         calendar queue buckets the global queue and the fast-forward tier \
         dispatched {:.1}% of events locally, without touching the physics — \
         the randomized equivalence tests pin both trajectories to the \
         reference heap bit for bit.",
        elided_pct
    );
    assert!(ff.windows > 0, "the fast-forward tier must engage at scale4k");
    assert!(
        ff.local_dispatched > 0,
        "fast-forward must dispatch events locally at scale4k: {ff:?}"
    );
    assert_eq!(m.live, 0, "every transfer must retire at quiescence");
    out
}

/// Fig 5 ablation: hostFunc ordering deadlock vs writeValue.
pub fn hostfunc_ablation(cfg: &Config) -> String {
    let run = |ordering: StreamOrdering| {
        let mut c = cfg.clone();
        c.vccl.ordering = ordering;
        let pcfg = PipelineCfg::spread(&c, 4, 8);
        let mut p = PipelineSim::new(ClusterSim::new(c), pcfg);
        p.run_iteration()
    };
    let hf = run(StreamOrdering::HostFunc);
    let wv = run(StreamOrdering::WriteValue);
    let mut out = String::from("Fig 5 ablation — stream-ordering primitive\n\n");
    let mut t = Table::new(vec!["ordering", "outcome", "iter (ms)"]);
    t.row(vec![
        "cudaLaunchHostFunc".into(),
        if hf.deadlocked { "DEADLOCK (Fig 5)".to_string() } else { "ok".into() },
        if hf.deadlocked { "—".into() } else { format!("{:.1}", hf.iter_ns as f64 / 1e6) },
    ]);
    t.row(vec![
        "cuStreamWriteValue/WaitValue".into(),
        if wv.deadlocked { "DEADLOCK".to_string() } else { "ok".into() },
        format!("{:.1}", wv.iter_ns as f64 / 1e6),
    ]);
    out.push_str(&t.render());
    out.push_str(
        "\nhostFunc serializes callbacks from independent streams on one host\n\
         thread: the bidirectional 1F1B exchange deadlocks. Stream memory ops\n\
         are stream-native and order without a shared thread (§3.2-3).\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_reports_shape() {
        let r = table1_sm_utilization(&Config::paper_defaults());
        assert!(r.contains("intra-host P2P") && r.contains("16-rank alltoall"));
    }

    #[test]
    fn appc_exceeds_32mb() {
        let r = appc_message_sizes(&Config::paper_defaults());
        assert!(r.contains("32.0MB") || r.contains("MB"));
    }

    #[test]
    fn hostfunc_ablation_detects_deadlock() {
        let r = hostfunc_ablation(&Config::paper_defaults());
        assert!(r.contains("DEADLOCK"));
    }

    #[test]
    fn scaling_table_monotone() {
        let r = scaling_gain_decay(&Config::paper_defaults());
        assert!(r.contains("DP width"));
    }
}
