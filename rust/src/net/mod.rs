//! Network substrate: fluid-flow bandwidth model + RDMA verbs simulation.
//!
//! Two halves:
//!
//! - [`flow`] — a progress-based fluid model: every in-flight transfer is a
//!   *flow* over a path of links; link bandwidth is divided max-min fairly
//!   among the flows crossing it, and each flow's completion time is
//!   re-derived whenever the flow set or link state changes. Incast (the
//!   many-to-one pattern behind Fig 18's congestion collapse) degrades the
//!   effective goodput of a receive port shared by several flows, modelling
//!   PFC backpressure.
//!
//! - [`rdma`] — the verbs narrow waist the paper builds on (§3.4): QPs with
//!   the RESET→INIT→RTR→RTS→ERROR state machine, Work Requests that become
//!   flows, Work Completions with success/retry-exceeded status, the
//!   IB_TIMEOUT/IB_RETRY_CNT retransmission window, and the hardware warm-up
//!   period after a QP reset that §3.3 masks by overlapping with failover.

pub mod flow;
pub mod rdma;

pub use flow::{AllocStats, FlowId, FlowMeta, FlowNet, FlowTimer};
pub use rdma::{
    CompletionStatus, NetOutput, Qp, QpId, QpState, RdmaNet, RdmaStats, WorkCompletion, WrId,
};
