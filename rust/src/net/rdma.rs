//! RDMA verbs simulation: QPs, WRs, WCs, retry-timeout semantics.
//!
//! This is the "narrow waist" (§3.4) the whole paper stands on. The model
//! keeps exactly the behaviours VCCL's mechanisms depend on:
//!
//! - **QP state machine** RESET→INIT→RTR→RTS→ERROR. A link failure drives
//!   affected QPs to ERROR after the hardware retransmission window
//!   (IB_TIMEOUT/IB_RETRY_CNT), surfacing a `RetryExceeded` WC — the paper's
//!   Fig 7(a) failure-perception trigger.
//! - **WR → flow → WC** with post/completion timestamps, feeding the
//!   O(μs) monitor (§3.4).
//! - **Warm-up**: a freshly transitioned QP needs `qp_warmup_ns` before the
//!   hardware serves at full rate (§3.3 recovery); VCCL masks it by
//!   resetting proactively during failover. Modelled as a transfer-start
//!   gate: WRs posted while cold are released when warm.
//!
//! The layer is engine-agnostic: every mutating call returns a [`NetOutput`]
//! of timers the owner must schedule and WCs to deliver.

use std::collections::HashMap;

use super::flow::{FlowId, FlowMeta, FlowNet, FlowTimer};
use crate::config::NetConfig;
use crate::sim::SimTime;
use crate::topology::{Fabric, Path, PortId};
use crate::trace::{TraceEvent, Tracer};

/// Queue-pair identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QpId(pub u64);

/// Work-request identifier (caller-assigned, unique per QP).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WrId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QpState {
    Reset,
    Init,
    Rtr,
    Rts,
    Error,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionStatus {
    Success,
    /// `IBV_WC_RETRY_EXC_ERR`: the hardware exhausted
    /// IB_RETRY_CNT × timeout without an ACK.
    RetryExceeded,
    /// WR flushed because the QP entered the error state.
    WrFlushed,
}

/// A work completion, timestamped for the monitor.
#[derive(Debug, Clone, Copy)]
pub struct WorkCompletion {
    pub qp: QpId,
    pub wr: WrId,
    pub status: CompletionStatus,
    pub bytes: u64,
    pub posted_at: SimTime,
    pub completed_at: SimTime,
}

/// What a mutating call asks the owner to do.
#[derive(Debug, Default)]
pub struct NetOutput {
    /// (Re)schedule flow-completion checks.
    pub timers: Vec<FlowTimer>,
    /// Deliver these completions to their CQs.
    pub wcs: Vec<WorkCompletion>,
    /// Schedule a retry-deadline check: `on_retry_deadline(qp, epoch)` at t.
    pub retry_deadlines: Vec<(QpId, u32, SimTime)>,
    /// Schedule a warm-up release: `on_warm(qp)` at t.
    pub warmups: Vec<(QpId, SimTime)>,
}

impl NetOutput {
    fn merge(&mut self, other: NetOutput) {
        self.timers.extend(other.timers);
        self.wcs.extend(other.wcs);
        self.retry_deadlines.extend(other.retry_deadlines);
        self.warmups.extend(other.warmups);
    }
}

#[derive(Debug)]
struct Wr {
    wr: WrId,
    bytes: u64,
    posted_at: SimTime,
    flow: Option<FlowId>, // None while queued behind a cold QP
    /// Extra caller-supplied fixed latency (receiver-side delivery copies
    /// etc.), folded into the flow's tail.
    extra_tail_ns: u64,
}

/// One simulated queue pair (send side; the receive side is implicit —
/// completion is delivered to both endpoints by the owner).
#[derive(Debug)]
pub struct Qp {
    pub id: QpId,
    pub src: PortId,
    pub dst: PortId,
    pub state: QpState,
    path: Path,
    /// Dense ordinal of `src` (trace labelling; avoids threading the
    /// fabric through every hot-path call).
    src_ordinal: usize,
    /// Warm until: WRs posted before this fire at reduced readiness.
    warm_at: SimTime,
    /// Monotonic epoch; bumped whenever retry context changes so stale
    /// deadline events are ignored.
    epoch: u32,
    /// Deadline of the running retransmission window (None = healthy).
    retrying_since: Option<SimTime>,
    outstanding: Vec<Wr>,
    next_wr_seq: u64,
}

impl Qp {
    pub fn outstanding_wrs(&self) -> usize {
        self.outstanding.len()
    }
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// The RDMA network: QPs over a [`FlowNet`].
pub struct RdmaNet {
    pub flows: FlowNet,
    cfg: NetConfig,
    qps: HashMap<QpId, Qp>,
    next_qp: u64,
    flow_owner: HashMap<FlowId, (QpId, WrId)>,
    /// Flight recorder (disabled by default; install via `set_tracer`).
    tracer: Tracer,
}

impl RdmaNet {
    pub fn new(fabric: &Fabric, cfg: NetConfig) -> Self {
        let flows = FlowNet::from_fabric(fabric, cfg.wire_efficiency, cfg.incast_penalty);
        RdmaNet {
            flows,
            cfg,
            qps: HashMap::new(),
            next_qp: 0,
            flow_owner: HashMap::new(),
            tracer: Tracer::disabled(),
        }
    }

    /// Install a flight-recorder handle on this layer AND the fluid-flow
    /// layer beneath it.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.flows.set_tracer(tracer.clone());
        self.tracer = tracer;
    }

    pub fn cfg(&self) -> &NetConfig {
        &self.cfg
    }

    /// Create a QP between two ports and drive it straight to RTS (the
    /// bootstrap connection phase; metadata caching makes later resets
    /// cheap — §3.3 "recovery of normal QPs").
    pub fn create_qp(&mut self, fabric: &Fabric, src: PortId, dst: PortId) -> QpId {
        let id = QpId(self.next_qp);
        self.next_qp += 1;
        let path = fabric.path_inter(src, dst);
        let src_ordinal = fabric.port_ordinal(src);
        self.qps.insert(
            id,
            Qp {
                id,
                src,
                dst,
                state: QpState::Rts,
                path,
                src_ordinal,
                warm_at: SimTime::ZERO,
                epoch: 0,
                retrying_since: None,
                outstanding: Vec::new(),
                next_wr_seq: 0,
            },
        );
        id
    }

    pub fn qp_state(&self, qp: QpId) -> QpState {
        self.qps[&qp].state
    }

    pub fn qp_src(&self, qp: QpId) -> PortId {
        self.qps[&qp].src
    }

    pub fn qp_dst(&self, qp: QpId) -> PortId {
        self.qps[&qp].dst
    }

    pub fn qp_outstanding(&self, qp: QpId) -> usize {
        self.qps[&qp].outstanding.len()
    }

    /// Is every link on this QP's path currently up? (The CTS re-probe of
    /// the §3.3 case-2 double check.)
    pub fn qp_path_up(&self, qp: QpId, fabric: &Fabric) -> bool {
        fabric.path_up(self.qps[&qp].path())
    }

    /// Total un-ACKed bytes on a port's QPs — the monitor's
    /// "remaining-to-send" (RTS) signal (§3.4 pinpointing condition ii).
    pub fn port_backlog_bytes(&self, port: PortId) -> u64 {
        self.qps
            .values()
            .filter(|q| q.src == port)
            .flat_map(|q| q.outstanding.iter())
            .map(|w| w.bytes)
            .sum()
    }

    /// Post a send WR. `extra_tail_ns` adds caller-level fixed latency to
    /// the completion (e.g. the receiver's chunk→app delivery copy in the
    /// staged NCCL transport). Returns the WrId plus scheduling work.
    pub fn post_send(
        &mut self,
        qp_id: QpId,
        bytes: u64,
        now: SimTime,
        extra_tail_ns: u64,
    ) -> (WrId, NetOutput) {
        let mut out = NetOutput::default();
        let (wr_id, start_at, tail, path) = {
            let qp = self.qps.get_mut(&qp_id).expect("post_send on unknown QP");
            let wr_id = WrId(qp.next_wr_seq);
            qp.next_wr_seq += 1;
            self.tracer.record(
                now,
                TraceEvent::WrPosted { qp: qp_id.0, port: qp.src_ordinal, bytes },
            );
            if qp.state != QpState::Rts {
                // Posting to a non-RTS QP flushes immediately.
                self.tracer.record(
                    now,
                    TraceEvent::WrCompleted {
                        qp: qp_id.0,
                        port: qp.src_ordinal,
                        bytes,
                        status: "flushed",
                    },
                );
                out.wcs.push(WorkCompletion {
                    qp: qp_id,
                    wr: wr_id,
                    status: CompletionStatus::WrFlushed,
                    bytes,
                    posted_at: now,
                    completed_at: now,
                });
                return (wr_id, out);
            }
            let start_at = now.max(qp.warm_at);
            let tail = self.cfg.nic_latency_ns
                + qp.path.hops as u64 * self.cfg.hop_latency_ns
                + extra_tail_ns;
            qp.outstanding.push(Wr {
                wr: wr_id,
                bytes,
                posted_at: now,
                flow: None,
                extra_tail_ns,
            });
            (wr_id, start_at, tail, qp.path.clone())
        };
        if start_at > now {
            // Cold QP: queue the WR; it is released by `on_warm`.
            out.warmups.push((qp_id, start_at));
        } else {
            let (flow, timers) =
                self.flows.start(now, path, bytes, tail, FlowMeta(0));
            self.flow_owner.insert(flow, (qp_id, wr_id));
            let qp = self.qps.get_mut(&qp_id).unwrap();
            qp.outstanding.last_mut().unwrap().flow = Some(flow);
            out.timers.extend(timers);
            // If the path is already dead the flow stalls immediately →
            // arm the retransmission window.
            out.merge(self.maybe_arm_retry(qp_id, now));
        }
        (wr_id, out)
    }

    /// Warm-up release: start flows for any queued WRs that were waiting.
    pub fn on_warm(&mut self, qp_id: QpId, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let Some(qp) = self.qps.get(&qp_id) else { return out };
        if qp.state != QpState::Rts || now < qp.warm_at {
            return out;
        }
        let pending: Vec<(WrId, u64, u64)> = qp
            .outstanding
            .iter()
            .filter(|w| w.flow.is_none())
            .map(|w| (w.wr, w.bytes, w.extra_tail_ns))
            .collect();
        let base_tail =
            self.cfg.nic_latency_ns + qp.path.hops as u64 * self.cfg.hop_latency_ns;
        let path = qp.path.clone();
        for (wr, bytes, extra) in pending {
            let (flow, timers) =
                self.flows.start(now, path.clone(), bytes, base_tail + extra, FlowMeta(0));
            self.flow_owner.insert(flow, (qp_id, wr));
            let q = self.qps.get_mut(&qp_id).unwrap();
            if let Some(w) = q.outstanding.iter_mut().find(|w| w.wr == wr) {
                w.flow = Some(flow);
            }
            out.timers.extend(timers);
        }
        out.merge(self.maybe_arm_retry(qp_id, now));
        out
    }

    /// A flow-completion timer fired.
    pub fn on_flow_timer(&mut self, flow: FlowId, gen: u32, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let (meta, timers) = self.flows.try_finish(flow, gen, now);
        out.timers.extend(timers);
        if meta.is_none() {
            return out;
        }
        let Some((qp_id, wr_id)) = self.flow_owner.remove(&flow) else { return out };
        if let Some(qp) = self.qps.get_mut(&qp_id) {
            if let Some(pos) = qp.outstanding.iter().position(|w| w.wr == wr_id) {
                let w = qp.outstanding.remove(pos);
                self.tracer.record(
                    now,
                    TraceEvent::WrCompleted {
                        qp: qp_id.0,
                        port: qp.src_ordinal,
                        bytes: w.bytes,
                        status: "success",
                    },
                );
                out.wcs.push(WorkCompletion {
                    qp: qp_id,
                    wr: wr_id,
                    status: CompletionStatus::Success,
                    bytes: w.bytes,
                    posted_at: w.posted_at,
                    completed_at: now,
                });
            }
        }
        // Successful progress resets the retransmission window.
        if self.qps.get(&qp_id).map_or(false, |q| q.retrying_since.is_some())
            && !self.qp_stalled(qp_id)
        {
            let qp = self.qps.get_mut(&qp_id).unwrap();
            qp.retrying_since = None;
            qp.epoch += 1;
        }
        out
    }

    fn qp_stalled(&self, qp_id: QpId) -> bool {
        let qp = &self.qps[&qp_id];
        qp.outstanding
            .iter()
            .filter_map(|w| w.flow)
            .any(|f| self.flows.is_stalled(f).unwrap_or(false))
    }

    /// Arm the hardware retransmission window if any outstanding flow is
    /// stalled and no window is already running.
    fn maybe_arm_retry(&mut self, qp_id: QpId, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        if !self.qp_stalled(qp_id) {
            return out;
        }
        let window = self.cfg.retry_window_ns();
        let qp = self.qps.get_mut(&qp_id).unwrap();
        if qp.retrying_since.is_none() {
            qp.retrying_since = Some(now);
            qp.epoch += 1;
            let deadline = now + SimTime::ns(window);
            self.tracer.record(
                now,
                TraceEvent::QpRetryArmed {
                    qp: qp_id.0,
                    port: qp.src_ordinal,
                    deadline_ns: deadline.as_ns(),
                },
            );
            out.retry_deadlines.push((qp_id, qp.epoch, deadline));
        }
        out
    }

    /// Retry-deadline event. If the QP is still stalled the hardware gives
    /// up: every outstanding WR completes with `RetryExceeded` and the QP
    /// enters the error state (Fig 7a).
    pub fn on_retry_deadline(&mut self, qp_id: QpId, epoch: u32, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let Some(qp) = self.qps.get(&qp_id) else { return out };
        if qp.epoch != epoch || qp.retrying_since.is_none() {
            return out; // stale — window was reset by progress or failover
        }
        if !self.qp_stalled(qp_id) {
            // Link recovered but no completion has fired yet — disarm.
            let qp = self.qps.get_mut(&qp_id).unwrap();
            qp.retrying_since = None;
            qp.epoch += 1;
            return out;
        }
        out.merge(self.force_error(qp_id, now));
        out
    }

    /// Drive a QP to the error state, flushing outstanding WRs. First WR
    /// reports `RetryExceeded` (the error the proxy perceives); the rest
    /// flush. Used both by the retry deadline and by explicit teardown.
    pub fn force_error(&mut self, qp_id: QpId, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let Some(qp) = self.qps.get_mut(&qp_id) else { return out };
        qp.state = QpState::Error;
        qp.retrying_since = None;
        qp.epoch += 1;
        let ordinal = qp.src_ordinal;
        self.tracer.record(now, TraceEvent::QpError { qp: qp_id.0, port: ordinal });
        let drained: Vec<Wr> = qp.outstanding.drain(..).collect();
        for (i, w) in drained.iter().enumerate() {
            if let Some(f) = w.flow {
                self.flow_owner.remove(&f);
                out.timers.extend(self.flows.kill(f, now));
            }
            let status = if i == 0 {
                CompletionStatus::RetryExceeded
            } else {
                CompletionStatus::WrFlushed
            };
            self.tracer.record(
                now,
                TraceEvent::WrCompleted {
                    qp: qp_id.0,
                    port: ordinal,
                    bytes: w.bytes,
                    status: if i == 0 { "retry-exceeded" } else { "flushed" },
                },
            );
            out.wcs.push(WorkCompletion {
                qp: qp_id,
                wr: w.wr,
                status,
                bytes: w.bytes,
                posted_at: w.posted_at,
                completed_at: now,
            });
        }
        out
    }

    /// Begin the RESET→INIT→RTR→RTS sequence on an errored QP. The state
    /// transition itself is fast; the hardware warm-up dominates (§3.3).
    /// VCCL calls this *immediately on failure perception* so the warm-up
    /// overlaps the failover period ("proactive reset").
    pub fn reset_to_rts(&mut self, qp_id: QpId, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let warmup = self.cfg.qp_warmup_ns;
        let Some(qp) = self.qps.get_mut(&qp_id) else { return out };
        qp.state = QpState::Rts;
        qp.retrying_since = None;
        qp.epoch += 1;
        qp.warm_at = now + SimTime::ns(warmup);
        self.tracer.record(
            now,
            TraceEvent::QpReset { qp: qp_id.0, port: qp.src_ordinal, warm_ns: warmup },
        );
        out.warmups.push((qp_id, qp.warm_at));
        out
    }

    /// Whether the QP's hardware is warm (full-rate) at `now`.
    pub fn is_warm(&self, qp_id: QpId, now: SimTime) -> bool {
        self.qps[&qp_id].warm_at <= now
    }

    /// Port state change: stalls / resumes flows; arms retry windows on
    /// every QP whose path crosses the port.
    pub fn set_port_up(
        &mut self,
        fabric: &Fabric,
        port: PortId,
        up: bool,
        now: SimTime,
    ) -> NetOutput {
        let mut out = NetOutput::default();
        // Both directions flap as one batch: a single component recompute
        // (and one generation bump per affected flow) instead of two.
        let links = fabric.port_links(port);
        out.timers.extend(self.flows.set_links_up(&links, up, now));
        // Sorted for determinism: retry windows armed here schedule engine
        // events, and HashMap order would leak into timestamp tie-breaks.
        let mut qp_ids: Vec<QpId> = self.qps.keys().copied().collect();
        qp_ids.sort_unstable();
        for qp_id in qp_ids {
            if self.qps[&qp_id].state != QpState::Rts {
                continue;
            }
            if !up {
                out.merge(self.maybe_arm_retry(qp_id, now));
            } else if !self.qp_stalled(qp_id) {
                // Recovered within the window: disarm quietly ("about half
                // of flaps recover within seconds" — §3.3).
                let qp = self.qps.get_mut(&qp_id).unwrap();
                if qp.retrying_since.is_some() {
                    qp.retrying_since = None;
                    qp.epoch += 1;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;
    use crate::topology::{NicId, NodeId};

    fn setup() -> (Fabric, RdmaNet) {
        let fabric = Fabric::build(&TopologyConfig { num_nodes: 2, ..Default::default() });
        // Shrink the retry window so tests run fast: 4.096us × 2^10 × 2 ≈ 8.4ms
        let cfg = NetConfig { ib_timeout_exp: 10, ib_retry_cnt: 2, ..Default::default() };
        let net = RdmaNet::new(&fabric, cfg);
        (fabric, net)
    }

    fn port(node: usize, nic: usize) -> PortId {
        PortId { nic: NicId { node: NodeId(node), local: nic }, port: 0 }
    }

    /// Mini event loop over NetOutput (timers + deadlines + warmups).
    struct Loop {
        wcs: Vec<WorkCompletion>,
        timers: Vec<FlowTimer>,
        deadlines: Vec<(QpId, u32, SimTime)>,
        warmups: Vec<(QpId, SimTime)>,
        now: SimTime,
    }

    impl Loop {
        fn new() -> Self {
            Loop {
                wcs: vec![],
                timers: vec![],
                deadlines: vec![],
                warmups: vec![],
                now: SimTime::ZERO,
            }
        }
        fn absorb(&mut self, out: NetOutput) {
            self.wcs.extend(out.wcs);
            self.timers.extend(out.timers);
            self.deadlines.extend(out.retry_deadlines);
            self.warmups.extend(out.warmups);
        }
        /// Run until no events remain or `until` reached.
        fn run(&mut self, net: &mut RdmaNet, until: SimTime) {
            loop {
                let tt = self.timers.iter().map(|t| t.at).min();
                let dt = self.deadlines.iter().map(|d| d.2).min();
                let wt = self.warmups.iter().map(|w| w.1).min();
                let next = [tt, dt, wt].into_iter().flatten().min();
                let Some(at) = next else { break };
                if at > until {
                    break;
                }
                self.now = at;
                if tt == Some(at) {
                    let i = self.timers.iter().position(|t| t.at == at).unwrap();
                    let t = self.timers.remove(i);
                    let out = net.on_flow_timer(t.flow, t.gen, at);
                    self.absorb(out);
                } else if dt == Some(at) {
                    let i = self.deadlines.iter().position(|d| d.2 == at).unwrap();
                    let d = self.deadlines.remove(i);
                    let out = net.on_retry_deadline(d.0, d.1, at);
                    self.absorb(out);
                } else {
                    let i = self.warmups.iter().position(|w| w.1 == at).unwrap();
                    let w = self.warmups.remove(i);
                    let out = net.on_warm(w.0, at);
                    self.absorb(out);
                }
            }
        }
    }

    #[test]
    fn wr_completes_with_success_and_timestamps() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        let (wr, out) = net.post_send(qp, 1 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        lp.run(&mut net, SimTime::s(1));
        assert_eq!(lp.wcs.len(), 1);
        let wc = lp.wcs[0];
        assert_eq!(wc.wr, wr);
        assert_eq!(wc.status, CompletionStatus::Success);
        assert_eq!(wc.posted_at, SimTime::ZERO);
        // ≈ 1MB / (400Gbps × 0.97) + 2500ns NIC + 2 hops × 1000ns
        let expect = (1048576.0 / (400.0 * 0.125 * 0.97)) + 2500.0 + 2000.0;
        assert!((wc.completed_at.as_ns() as f64 - expect).abs() < 50.0);
    }

    #[test]
    fn port_down_triggers_retry_exceeded_after_window() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        let (_, out) = net.post_send(qp, 64 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        // Kill the port at 100us, before completion.
        let out = net.set_port_up(&fabric, port(0, 0), false, SimTime::us(100));
        lp.absorb(out);
        lp.run(&mut net, SimTime::s(5));
        assert_eq!(lp.wcs.len(), 1);
        assert_eq!(lp.wcs[0].status, CompletionStatus::RetryExceeded);
        assert_eq!(net.qp_state(qp), QpState::Error);
        // Deadline = 100us + window (2 retries × 4.096us×2^10 ≈ 8.39ms)
        let window_ns = net.cfg().retry_window_ns();
        let expect = 100_000 + window_ns;
        assert_eq!(lp.wcs[0].completed_at.as_ns(), expect);
    }

    #[test]
    fn flap_within_window_recovers_silently() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        let (_, out) = net.post_send(qp, 8 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        let out = net.set_port_up(&fabric, port(0, 0), false, SimTime::us(50));
        lp.absorb(out);
        // Up again well inside the window.
        let out = net.set_port_up(&fabric, port(0, 0), true, SimTime::ms(2));
        lp.absorb(out);
        lp.run(&mut net, SimTime::s(5));
        assert_eq!(lp.wcs.len(), 1);
        assert_eq!(lp.wcs[0].status, CompletionStatus::Success);
        assert_eq!(net.qp_state(qp), QpState::Rts);
    }

    #[test]
    fn post_to_error_qp_flushes() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        net.force_error(qp, SimTime::ZERO);
        let (_, out) = net.post_send(qp, 1024, SimTime::us(1), 0);
        assert_eq!(out.wcs.len(), 1);
        assert_eq!(out.wcs[0].status, CompletionStatus::WrFlushed);
    }

    #[test]
    fn reset_to_rts_queues_until_warm() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        net.force_error(qp, SimTime::ZERO);
        let mut lp = Loop::new();
        let out = net.reset_to_rts(qp, SimTime::ZERO);
        lp.absorb(out);
        assert!(!net.is_warm(qp, SimTime::ZERO));
        // Post while cold: WR waits for the warm-up release.
        let (_, out) = net.post_send(qp, 1 << 20, SimTime::us(1), 0);
        lp.absorb(out);
        lp.run(&mut net, SimTime::s(5));
        assert_eq!(lp.wcs.len(), 1);
        assert_eq!(lp.wcs[0].status, CompletionStatus::Success);
        // Completed after warm-up (default 1.5s), not at ~21us.
        assert!(lp.wcs[0].completed_at >= SimTime::ns(net.cfg().qp_warmup_ns));
    }

    #[test]
    fn error_flushes_all_outstanding() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        for _ in 0..4 {
            let (_, out) = net.post_send(qp, 16 << 20, SimTime::ZERO, 0);
            lp.absorb(out);
        }
        assert_eq!(net.qp_outstanding(qp), 4);
        let out = net.force_error(qp, SimTime::us(10));
        lp.absorb(out);
        let statuses: Vec<_> = lp.wcs.iter().map(|w| w.status).collect();
        assert_eq!(statuses.len(), 4);
        assert_eq!(statuses[0], CompletionStatus::RetryExceeded);
        assert!(statuses[1..].iter().all(|s| *s == CompletionStatus::WrFlushed));
        assert_eq!(net.port_backlog_bytes(port(0, 0)), 0);
    }

    #[test]
    fn backlog_tracks_outstanding_bytes() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        let (_, out) = net.post_send(qp, 1 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        let (_, out) = net.post_send(qp, 2 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        assert_eq!(net.port_backlog_bytes(port(0, 0)), 3 << 20);
        lp.run(&mut net, SimTime::s(1));
        assert_eq!(net.port_backlog_bytes(port(0, 0)), 0);
        assert_eq!(net.qp_state(qp), QpState::Rts);
        assert_eq!(lp.wcs.len(), 2);
    }
}
