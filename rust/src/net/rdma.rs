//! RDMA verbs simulation: QPs, WRs, WCs, retry-timeout semantics.
//!
//! This is the "narrow waist" (§3.4) the whole paper stands on. The model
//! keeps exactly the behaviours VCCL's mechanisms depend on:
//!
//! - **QP state machine** RESET→INIT→RTR→RTS→ERROR. A link failure drives
//!   affected QPs to ERROR after the hardware retransmission window
//!   (IB_TIMEOUT/IB_RETRY_CNT), surfacing a `RetryExceeded` WC — the paper's
//!   Fig 7(a) failure-perception trigger.
//! - **WR → flow → WC** with post/completion timestamps, feeding the
//!   O(μs) monitor (§3.4).
//! - **Warm-up**: a freshly transitioned QP needs `qp_warmup_ns` before the
//!   hardware serves at full rate (§3.3 recovery); VCCL masks it by
//!   resetting proactively during failover. Modelled as a transfer-start
//!   gate: WRs posted while cold are released when warm.
//!
//! The layer is engine-agnostic: every mutating call returns a [`NetOutput`]
//! of timers the owner must schedule and WCs to deliver.
//!
//! # §Perf L4: O(1) hot-path accounting
//!
//! Two operations used to scan every QP in the net:
//!
//! - [`RdmaNet::port_backlog_bytes`] — the monitor's "remaining-to-send"
//!   signal, read once per successful WC (§3.4 condition ii) — summed all
//!   outstanding WRs of all QPs on the port. It is now a per-port running
//!   counter maintained on `post_send` / WC success / error flush, so every
//!   read is one hash lookup.
//! - [`RdmaNet::set_port_up`] — the failover trigger (§3.3) — armed/disarmed
//!   retry windows by iterating *every* QP on each flap. It now walks a
//!   persistent `link → crossing QPs` reverse index (built from each QP's
//!   path at creation; paths are immutable for a QP's lifetime, so the
//!   index is append-only) and visits only the QPs whose path actually
//!   crosses the flapped port. Skipped QPs provably contribute no output:
//!   between events, an RTS QP is armed **iff** it is stalled, and only a
//!   crossing QP's stall state can change on a flap.
//!
//! Both keep the scan-based implementations as reference paths under
//! `cfg(any(test, debug_assertions, feature = "ref-alloc"))`: debug builds
//! cross-check the counter and the index against the scans on every call,
//! and `RdmaNet::set_reference_mode` forces the scans so
//! `benches/rdma.rs` can measure the work ratio (≥10× fewer QP visits is
//! the acceptance gate, tracked by [`RdmaStats`] in `BENCH_simcore.json`).
//! Outputs are identical in both modes by contract — the sorted-iteration
//! determinism guarantee from the flight-recorder PR is unchanged because
//! the crossing set is iterated in the same sorted order as the full scan,
//! restricted to the QPs that produce output. See DESIGN.md "§Perf L4".

use std::collections::HashMap;

use super::flow::{FlowId, FlowMeta, FlowNet, FlowTimer};
use crate::config::NetConfig;
use crate::sim::SimTime;
use crate::topology::{Fabric, LinkId, Path, PortId};
use crate::trace::{TraceEvent, Tracer};
use crate::util::{CkptReader, CkptWriter};

/// Queue-pair identifier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct QpId(pub u64);

/// Work-request identifier (caller-assigned, unique per QP).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct WrId(pub u64);

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QpState {
    Reset,
    Init,
    Rtr,
    Rts,
    Error,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompletionStatus {
    Success,
    /// `IBV_WC_RETRY_EXC_ERR`: the hardware exhausted
    /// IB_RETRY_CNT × timeout without an ACK.
    RetryExceeded,
    /// WR flushed because the QP entered the error state.
    WrFlushed,
}

/// A work completion, timestamped for the monitor.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkCompletion {
    pub qp: QpId,
    pub wr: WrId,
    pub status: CompletionStatus,
    pub bytes: u64,
    pub posted_at: SimTime,
    pub completed_at: SimTime,
}

/// What a mutating call asks the owner to do.
#[derive(Debug, Default)]
pub struct NetOutput {
    /// (Re)schedule flow-completion checks.
    pub timers: Vec<FlowTimer>,
    /// Deliver these completions to their CQs.
    pub wcs: Vec<WorkCompletion>,
    /// Schedule a retry-deadline check: `on_retry_deadline(qp, epoch)` at t.
    pub retry_deadlines: Vec<(QpId, u32, SimTime)>,
    /// Schedule a warm-up release: `on_warm(qp)` at t.
    pub warmups: Vec<(QpId, SimTime)>,
}

impl NetOutput {
    fn merge(&mut self, other: NetOutput) {
        self.timers.extend(other.timers);
        self.wcs.extend(other.wcs);
        self.retry_deadlines.extend(other.retry_deadlines);
        self.warmups.extend(other.warmups);
    }
}

#[derive(Debug)]
struct Wr {
    wr: WrId,
    bytes: u64,
    posted_at: SimTime,
    flow: Option<FlowId>, // None while queued behind a cold QP
    /// Extra caller-supplied fixed latency (receiver-side delivery copies
    /// etc.), folded into the flow's tail.
    extra_tail_ns: u64,
}

/// One simulated queue pair (send side; the receive side is implicit —
/// completion is delivered to both endpoints by the owner).
#[derive(Debug)]
pub struct Qp {
    pub id: QpId,
    pub src: PortId,
    pub dst: PortId,
    pub state: QpState,
    path: Path,
    /// Dense ordinal of `src` (trace labelling; avoids threading the
    /// fabric through every hot-path call).
    src_ordinal: usize,
    /// Warm until: WRs posted before this fire at reduced readiness.
    warm_at: SimTime,
    /// Monotonic epoch; bumped whenever retry context changes so stale
    /// deadline events are ignored.
    epoch: u32,
    /// Deadline of the running retransmission window (None = healthy).
    retrying_since: Option<SimTime>,
    outstanding: Vec<Wr>,
    next_wr_seq: u64,
}

impl Qp {
    pub fn outstanding_wrs(&self) -> usize {
        self.outstanding.len()
    }
    pub fn path(&self) -> &Path {
        &self.path
    }
}

/// §Perf L4 instrumentation: how much work the RDMA hot paths do.
/// Deterministic (pure counters over simulated activity), so the numbers
/// are safe to emit into `BENCH_simcore.json` (the `simcore.rdma.*` suite).
#[derive(Debug, Default, Clone, Copy)]
pub struct RdmaStats {
    /// `port_backlog_bytes` reads (one per successful WC from the monitor).
    pub backlog_reads: u64,
    /// QPs examined by those reads: 1 per read incrementally; all QPs per
    /// read in reference mode.
    pub backlog_qp_visits: u64,
    /// What the pre-L4 scan would have examined: live QPs summed over reads.
    pub backlog_scan_floor: u64,
    /// `set_port_up` calls (one per port state change).
    pub flap_events: u64,
    /// QPs visited by those calls: the crossing set incrementally; every QP
    /// in the net in reference mode.
    pub flap_qp_visits: u64,
    /// What the pre-L4 scan would have examined: live QPs summed over flaps.
    pub flap_scan_floor: u64,
}

impl RdmaStats {
    /// Total QP visits vs what the scans would have cost (the ≥10× gate).
    pub fn visit_reduction(&self) -> f64 {
        (self.backlog_scan_floor + self.flap_scan_floor) as f64
            / (self.backlog_qp_visits + self.flap_qp_visits).max(1) as f64
    }
}

/// The RDMA network: QPs over a [`FlowNet`].
pub struct RdmaNet {
    pub flows: FlowNet,
    cfg: NetConfig,
    qps: HashMap<QpId, Qp>,
    next_qp: u64,
    flow_owner: HashMap<FlowId, (QpId, WrId)>,
    /// §Perf L4: per-source-port un-ACKed bytes, maintained incrementally
    /// (post adds, WC success / error flush subtract). The monitor's RTS
    /// signal is one lookup here instead of an all-QP scan.
    port_backlog: HashMap<PortId, u64>,
    /// §Perf L4: link → QPs whose path crosses it, kept sorted (QP ids are
    /// allocated monotonically and paths are immutable, so plain appends
    /// preserve the order). Indexed by dense `LinkId` like the flow layer's
    /// reverse index — `Fabric::port_links` documents the id stability.
    link_qps: Vec<Vec<QpId>>,
    stats: RdmaStats,
    /// Force the scan-based reference paths (work-ratio measurement in
    /// `benches/rdma.rs`; outputs are identical by contract).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    force_scan: bool,
    /// Flight recorder (disabled by default; install via `set_tracer`).
    tracer: Tracer,
}

impl RdmaNet {
    pub fn new(fabric: &Fabric, cfg: NetConfig) -> Self {
        let flows = FlowNet::from_fabric(fabric, cfg.wire_efficiency, cfg.incast_penalty);
        RdmaNet {
            flows,
            cfg,
            qps: HashMap::new(),
            next_qp: 0,
            flow_owner: HashMap::new(),
            port_backlog: HashMap::new(),
            link_qps: vec![Vec::new(); fabric.num_links()],
            stats: RdmaStats::default(),
            #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
            force_scan: false,
            tracer: Tracer::disabled(),
        }
    }

    /// §Perf L4 work counters (see [`RdmaStats`]).
    pub fn rdma_stats(&self) -> RdmaStats {
        self.stats
    }

    /// Number of live QPs (the scan cost the incremental paths avoid).
    pub fn num_qps(&self) -> usize {
        self.qps.len()
    }

    /// Live flow → (QP, WR) routing entries. Drains to zero when nothing
    /// is on the wire (§Perf L5: no map pins a completed transfer's work).
    pub fn flow_owner_count(&self) -> usize {
        self.flow_owner.len()
    }

    /// Answer hot-path queries with the scan-based reference algorithms
    /// instead of the counter/index. Outputs are identical by contract;
    /// only the work (and [`RdmaStats`]) differs.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn set_reference_mode(&mut self, on: bool) {
        self.force_scan = on;
    }

    /// Install a flight-recorder handle on this layer AND the fluid-flow
    /// layer beneath it.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.flows.set_tracer(tracer.clone());
        self.tracer = tracer;
    }

    pub fn cfg(&self) -> &NetConfig {
        &self.cfg
    }

    /// Serialize the durable RDMA state (§Soak checkpointing). Requires
    /// quiescence: no WR outstanding on any QP, no flow routed, every port
    /// backlog drained. The embedded [`FlowNet`] stream rides along.
    pub fn save(&self, w: &mut CkptWriter) {
        assert!(
            self.flow_owner.is_empty(),
            "RdmaNet checkpoint requires quiescence (flows still routed)"
        );
        assert!(
            self.port_backlog.values().all(|b| *b == 0),
            "RdmaNet checkpoint requires quiescence (port backlog nonzero)"
        );
        self.flows.save(w);
        w.u64("nextqp", self.next_qp);
        w.u64("breads", self.stats.backlog_reads);
        w.u64("bvisits", self.stats.backlog_qp_visits);
        w.u64("bfloor", self.stats.backlog_scan_floor);
        w.u64("fevents", self.stats.flap_events);
        w.u64("fvisits", self.stats.flap_qp_visits);
        w.u64("ffloor", self.stats.flap_scan_floor);
        let mut ids: Vec<QpId> = self.qps.keys().copied().collect();
        ids.sort_unstable_by_key(|id| id.0);
        w.usize("nqps", ids.len());
        for id in ids {
            let q = &self.qps[&id];
            assert!(
                q.outstanding.is_empty(),
                "RdmaNet checkpoint requires quiescence (WR outstanding on {id:?})"
            );
            w.u64("qp", id.0);
            w.u64(
                "st",
                match q.state {
                    QpState::Reset => 0,
                    QpState::Init => 1,
                    QpState::Rtr => 2,
                    QpState::Rts => 3,
                    QpState::Error => 4,
                },
            );
            w.u64("warm", q.warm_at.as_ns());
            w.u64("ep", u64::from(q.epoch));
            w.opt_u64("retry", q.retrying_since.map(|t| t.as_ns()));
            w.u64("wrseq", q.next_wr_seq);
        }
    }

    /// Restore onto a net whose QPs were already re-created by replaying
    /// connection bootstrap in the recorded order (same order ⇒ same ids,
    /// paths and reverse index). Patches each QP's mutable fields directly
    /// with no side effects — pending warm-up/retry events are restored by
    /// the engine checkpoint, not re-armed here.
    pub fn load(&mut self, r: &mut CkptReader) -> Result<(), String> {
        self.flows.load(r)?;
        let next_qp = r.u64("nextqp")?;
        if next_qp != self.next_qp {
            return Err(format!(
                "checkpoint has {next_qp} QPs created, replay produced {}",
                self.next_qp
            ));
        }
        self.stats.backlog_reads = r.u64("breads")?;
        self.stats.backlog_qp_visits = r.u64("bvisits")?;
        self.stats.backlog_scan_floor = r.u64("bfloor")?;
        self.stats.flap_events = r.u64("fevents")?;
        self.stats.flap_qp_visits = r.u64("fvisits")?;
        self.stats.flap_scan_floor = r.u64("ffloor")?;
        let n = r.usize("nqps")?;
        if n != self.qps.len() {
            return Err(format!("checkpoint has {n} QPs, replay produced {}", self.qps.len()));
        }
        for _ in 0..n {
            let id = QpId(r.u64("qp")?);
            let state = match r.u64("st")? {
                0 => QpState::Reset,
                1 => QpState::Init,
                2 => QpState::Rtr,
                3 => QpState::Rts,
                4 => QpState::Error,
                other => return Err(format!("bad QP state ordinal {other}")),
            };
            let warm_at = SimTime::ns(r.u64("warm")?);
            let epoch = u32::try_from(r.u64("ep")?).map_err(|_| "QP epoch overflow".to_string())?;
            let retrying_since = r.opt_u64("retry")?.map(SimTime::ns);
            let next_wr_seq = r.u64("wrseq")?;
            let q = self
                .qps
                .get_mut(&id)
                .ok_or_else(|| format!("checkpoint names {id:?} which replay did not create"))?;
            q.state = state;
            q.warm_at = warm_at;
            q.epoch = epoch;
            q.retrying_since = retrying_since;
            q.next_wr_seq = next_wr_seq;
        }
        Ok(())
    }

    /// Create a QP between two ports and drive it straight to RTS (the
    /// bootstrap connection phase; metadata caching makes later resets
    /// cheap — §3.3 "recovery of normal QPs").
    pub fn create_qp(&mut self, fabric: &Fabric, src: PortId, dst: PortId) -> QpId {
        let id = QpId(self.next_qp);
        self.next_qp += 1;
        let path = fabric.path_inter(src, dst);
        let src_ordinal = fabric.port_ordinal(src);
        // §Perf L4 reverse index: ids are monotone, so appends stay sorted.
        // A QP's path never changes after creation (failover activates a
        // *different* QP; reset keeps the path), so entries are permanent.
        for l in &path.links {
            debug_assert!(self.link_qps[l.0].last().map_or(true, |&q| q < id));
            self.link_qps[l.0].push(id);
        }
        self.qps.insert(
            id,
            Qp {
                id,
                src,
                dst,
                state: QpState::Rts,
                path,
                src_ordinal,
                warm_at: SimTime::ZERO,
                epoch: 0,
                retrying_since: None,
                outstanding: Vec::new(),
                next_wr_seq: 0,
            },
        );
        id
    }

    pub fn qp_state(&self, qp: QpId) -> QpState {
        self.qps[&qp].state
    }

    pub fn qp_src(&self, qp: QpId) -> PortId {
        self.qps[&qp].src
    }

    pub fn qp_dst(&self, qp: QpId) -> PortId {
        self.qps[&qp].dst
    }

    pub fn qp_outstanding(&self, qp: QpId) -> usize {
        self.qps[&qp].outstanding.len()
    }

    /// Is every link on this QP's path currently up? (The CTS re-probe of
    /// the §3.3 case-2 double check.)
    pub fn qp_path_up(&self, qp: QpId, fabric: &Fabric) -> bool {
        fabric.path_up(self.qps[&qp].path())
    }

    /// First dead link on this QP's path, if any — names the fault domain
    /// that killed the path even when both endpoint ports are still up.
    pub fn qp_first_dead_link(&self, qp: QpId, fabric: &Fabric) -> Option<LinkId> {
        fabric.first_dead_link(self.qps[&qp].path())
    }

    /// Total un-ACKed bytes on a port's QPs — the monitor's
    /// "remaining-to-send" (RTS) signal (§3.4 pinpointing condition ii).
    /// §Perf L4: one counter lookup, called once per successful WC; debug
    /// builds cross-check against `reference_port_backlog` (the retained
    /// scan) on every read.
    pub fn port_backlog_bytes(&mut self, port: PortId) -> u64 {
        self.stats.backlog_reads += 1;
        self.stats.backlog_scan_floor += self.qps.len() as u64;
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        if self.force_scan {
            self.stats.backlog_qp_visits += self.qps.len() as u64;
            return self.reference_port_backlog(port);
        }
        self.stats.backlog_qp_visits += 1;
        let bytes = self.port_backlog.get(&port).copied().unwrap_or(0);
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            bytes,
            self.reference_port_backlog(port),
            "backlog counter diverged from the all-QP scan for {port}"
        );
        bytes
    }

    /// The pre-§Perf-L4 backlog computation, kept verbatim as the reference
    /// the running counter is checked against (debug builds: every read).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn reference_port_backlog(&self, port: PortId) -> u64 {
        self.qps
            .values()
            .filter(|q| q.src == port)
            .flat_map(|q| q.outstanding.iter())
            .map(|w| w.bytes)
            .sum()
    }

    /// Sorted QPs whose path crosses any of `links`, read off the
    /// persistent reverse index (O(crossing QPs), not O(all QPs)).
    fn crossing_qps(&self, links: &[LinkId]) -> Vec<QpId> {
        let mut ids: Vec<QpId> = links
            .iter()
            .flat_map(|l| self.link_qps[l.0].iter().copied())
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// The pre-§Perf-L4 crossing-set computation (scan every QP's path),
    /// kept as the reference the index is checked against.
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn reference_crossing_qps(&self, links: &[LinkId]) -> Vec<QpId> {
        let mut ids: Vec<QpId> = self
            .qps
            .values()
            .filter(|q| q.path.links.iter().any(|l| links.contains(l)))
            .map(|q| q.id)
            .collect();
        ids.sort_unstable();
        ids
    }

    /// Post a send WR. `extra_tail_ns` adds caller-level fixed latency to
    /// the completion (e.g. the receiver's chunk→app delivery copy in the
    /// staged NCCL transport). Returns the WrId plus scheduling work.
    pub fn post_send(
        &mut self,
        qp_id: QpId,
        bytes: u64,
        now: SimTime,
        extra_tail_ns: u64,
    ) -> (WrId, NetOutput) {
        let mut out = NetOutput::default();
        let (wr_id, start_at, tail, path) = {
            let qp = self.qps.get_mut(&qp_id).expect("post_send on unknown QP");
            let wr_id = WrId(qp.next_wr_seq);
            qp.next_wr_seq += 1;
            self.tracer.record(
                now,
                TraceEvent::WrPosted { qp: qp_id.0, port: qp.src_ordinal, bytes },
            );
            if qp.state != QpState::Rts {
                // Posting to a non-RTS QP flushes immediately.
                self.tracer.record(
                    now,
                    TraceEvent::WrCompleted {
                        qp: qp_id.0,
                        port: qp.src_ordinal,
                        bytes,
                        status: "flushed",
                    },
                );
                out.wcs.push(WorkCompletion {
                    qp: qp_id,
                    wr: wr_id,
                    status: CompletionStatus::WrFlushed,
                    bytes,
                    posted_at: now,
                    completed_at: now,
                });
                return (wr_id, out);
            }
            let start_at = now.max(qp.warm_at);
            let tail = self.cfg.nic_latency_ns
                + qp.path.hops as u64 * self.cfg.hop_latency_ns
                + extra_tail_ns;
            qp.outstanding.push(Wr {
                wr: wr_id,
                bytes,
                posted_at: now,
                flow: None,
                extra_tail_ns,
            });
            // §Perf L4: the WR entered the outstanding set → count it.
            *self.port_backlog.entry(qp.src).or_insert(0) += bytes;
            (wr_id, start_at, tail, qp.path.clone())
        };
        if start_at > now {
            // Cold QP: queue the WR; it is released by `on_warm`.
            out.warmups.push((qp_id, start_at));
        } else {
            let (flow, timers) =
                self.flows.start(now, path, bytes, tail, FlowMeta(0));
            self.flow_owner.insert(flow, (qp_id, wr_id));
            let qp = self.qps.get_mut(&qp_id).unwrap();
            qp.outstanding.last_mut().unwrap().flow = Some(flow);
            out.timers.extend(timers);
            // If the path is already dead the flow stalls immediately →
            // arm the retransmission window.
            out.merge(self.maybe_arm_retry(qp_id, now));
        }
        (wr_id, out)
    }

    /// Warm-up release: start flows for any queued WRs that were waiting.
    pub fn on_warm(&mut self, qp_id: QpId, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let Some(qp) = self.qps.get(&qp_id) else { return out };
        if qp.state != QpState::Rts || now < qp.warm_at {
            return out;
        }
        let pending: Vec<(WrId, u64, u64)> = qp
            .outstanding
            .iter()
            .filter(|w| w.flow.is_none())
            .map(|w| (w.wr, w.bytes, w.extra_tail_ns))
            .collect();
        let base_tail =
            self.cfg.nic_latency_ns + qp.path.hops as u64 * self.cfg.hop_latency_ns;
        let path = qp.path.clone();
        for (wr, bytes, extra) in pending {
            let (flow, timers) =
                self.flows.start(now, path.clone(), bytes, base_tail + extra, FlowMeta(0));
            self.flow_owner.insert(flow, (qp_id, wr));
            let q = self.qps.get_mut(&qp_id).unwrap();
            if let Some(w) = q.outstanding.iter_mut().find(|w| w.wr == wr) {
                w.flow = Some(flow);
            }
            out.timers.extend(timers);
        }
        out.merge(self.maybe_arm_retry(qp_id, now));
        out
    }

    /// A flow-completion timer fired.
    pub fn on_flow_timer(&mut self, flow: FlowId, gen: u32, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let (meta, timers) = self.flows.try_finish(flow, gen, now);
        out.timers.extend(timers);
        if meta.is_none() {
            return out;
        }
        let Some((qp_id, wr_id)) = self.flow_owner.remove(&flow) else { return out };
        if let Some(qp) = self.qps.get_mut(&qp_id) {
            if let Some(pos) = qp.outstanding.iter().position(|w| w.wr == wr_id) {
                let w = qp.outstanding.remove(pos);
                // §Perf L4: the WR left the outstanding set → uncount it.
                let backlog = self
                    .port_backlog
                    .get_mut(&qp.src)
                    .expect("completed WR must have been counted");
                debug_assert!(*backlog >= w.bytes, "backlog underflow on {}", qp.src);
                *backlog = backlog.saturating_sub(w.bytes);
                self.tracer.record(
                    now,
                    TraceEvent::WrCompleted {
                        qp: qp_id.0,
                        port: qp.src_ordinal,
                        bytes: w.bytes,
                        status: "success",
                    },
                );
                out.wcs.push(WorkCompletion {
                    qp: qp_id,
                    wr: wr_id,
                    status: CompletionStatus::Success,
                    bytes: w.bytes,
                    posted_at: w.posted_at,
                    completed_at: now,
                });
            }
        }
        // Successful progress resets the retransmission window.
        if self.qps.get(&qp_id).map_or(false, |q| q.retrying_since.is_some())
            && !self.qp_stalled(qp_id)
        {
            let qp = self.qps.get_mut(&qp_id).unwrap();
            qp.retrying_since = None;
            qp.epoch += 1;
        }
        out
    }

    fn qp_stalled(&self, qp_id: QpId) -> bool {
        let qp = &self.qps[&qp_id];
        qp.outstanding
            .iter()
            .filter_map(|w| w.flow)
            .any(|f| self.flows.is_stalled(f).unwrap_or(false))
    }

    /// Arm the hardware retransmission window if any outstanding flow is
    /// stalled and no window is already running.
    fn maybe_arm_retry(&mut self, qp_id: QpId, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        if !self.qp_stalled(qp_id) {
            return out;
        }
        let window = self.cfg.retry_window_ns();
        let qp = self.qps.get_mut(&qp_id).unwrap();
        if qp.retrying_since.is_none() {
            qp.retrying_since = Some(now);
            qp.epoch += 1;
            let deadline = now + SimTime::ns(window);
            self.tracer.record(
                now,
                TraceEvent::QpRetryArmed {
                    qp: qp_id.0,
                    port: qp.src_ordinal,
                    deadline_ns: deadline.as_ns(),
                },
            );
            out.retry_deadlines.push((qp_id, qp.epoch, deadline));
        }
        out
    }

    /// Retry-deadline event. If the QP is still stalled the hardware gives
    /// up: every outstanding WR completes with `RetryExceeded` and the QP
    /// enters the error state (Fig 7a).
    pub fn on_retry_deadline(&mut self, qp_id: QpId, epoch: u32, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let Some(qp) = self.qps.get(&qp_id) else { return out };
        if qp.epoch != epoch || qp.retrying_since.is_none() {
            return out; // stale — window was reset by progress or failover
        }
        if !self.qp_stalled(qp_id) {
            // Link recovered but no completion has fired yet — disarm.
            let qp = self.qps.get_mut(&qp_id).unwrap();
            qp.retrying_since = None;
            qp.epoch += 1;
            return out;
        }
        out.merge(self.force_error(qp_id, now));
        out
    }

    /// Drive a QP to the error state, flushing outstanding WRs. First WR
    /// reports `RetryExceeded` (the error the proxy perceives); the rest
    /// flush. Used both by the retry deadline and by explicit teardown.
    pub fn force_error(&mut self, qp_id: QpId, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let Some(qp) = self.qps.get_mut(&qp_id) else { return out };
        qp.state = QpState::Error;
        qp.retrying_since = None;
        qp.epoch += 1;
        let ordinal = qp.src_ordinal;
        self.tracer.record(now, TraceEvent::QpError { qp: qp_id.0, port: ordinal });
        let drained: Vec<Wr> = qp.outstanding.drain(..).collect();
        // §Perf L4: every flushed WR leaves the outstanding set at once —
        // this is what drops the failed primary port's backlog to zero on
        // pointer-migration rollback.
        if !drained.is_empty() {
            let flushed: u64 = drained.iter().map(|w| w.bytes).sum();
            let backlog = self
                .port_backlog
                .get_mut(&qp.src)
                .expect("flushed WRs must have been counted");
            debug_assert!(*backlog >= flushed, "backlog underflow on {}", qp.src);
            *backlog = backlog.saturating_sub(flushed);
        }
        for (i, w) in drained.iter().enumerate() {
            if let Some(f) = w.flow {
                self.flow_owner.remove(&f);
                out.timers.extend(self.flows.kill(f, now));
            }
            let status = if i == 0 {
                CompletionStatus::RetryExceeded
            } else {
                CompletionStatus::WrFlushed
            };
            self.tracer.record(
                now,
                TraceEvent::WrCompleted {
                    qp: qp_id.0,
                    port: ordinal,
                    bytes: w.bytes,
                    status: if i == 0 { "retry-exceeded" } else { "flushed" },
                },
            );
            out.wcs.push(WorkCompletion {
                qp: qp_id,
                wr: w.wr,
                status,
                bytes: w.bytes,
                posted_at: w.posted_at,
                completed_at: now,
            });
        }
        out
    }

    /// Begin the RESET→INIT→RTR→RTS sequence on an errored QP. The state
    /// transition itself is fast; the hardware warm-up dominates (§3.3).
    /// VCCL calls this *immediately on failure perception* so the warm-up
    /// overlaps the failover period ("proactive reset").
    pub fn reset_to_rts(&mut self, qp_id: QpId, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        let warmup = self.cfg.qp_warmup_ns;
        let Some(qp) = self.qps.get_mut(&qp_id) else { return out };
        qp.state = QpState::Rts;
        qp.retrying_since = None;
        qp.epoch += 1;
        qp.warm_at = now + SimTime::ns(warmup);
        self.tracer.record(
            now,
            TraceEvent::QpReset { qp: qp_id.0, port: qp.src_ordinal, warm_ns: warmup },
        );
        out.warmups.push((qp_id, qp.warm_at));
        out
    }

    /// Whether the QP's hardware is warm (full-rate) at `now`.
    pub fn is_warm(&self, qp_id: QpId, now: SimTime) -> bool {
        self.qps[&qp_id].warm_at <= now
    }

    /// Port state change: stalls / resumes flows; arms retry windows on
    /// every QP whose path crosses the port.
    ///
    /// §Perf L4: the QPs to touch come from the `link → QPs` reverse index
    /// instead of an all-QP scan. This is output-equivalent: between
    /// events, an RTS QP is armed **iff** it is stalled (arming happens at
    /// every stall source: post, warm-up release, flap; disarming at every
    /// unstall source: WC progress, deadline check, flap recovery), and
    /// only a QP crossing the flapped port can change stall state here —
    /// so every skipped QP would have been a no-op in the old loop.
    pub fn set_port_up(
        &mut self,
        fabric: &Fabric,
        port: PortId,
        up: bool,
        now: SimTime,
    ) -> NetOutput {
        // Both directions flap as one batch: a single component recompute
        // (and one generation bump per affected flow) instead of two.
        self.set_links_up(&fabric.port_links(port), up, now)
    }

    /// Link-level state change (§Fault domains): the trunk/switch analog of
    /// [`RdmaNet::set_port_up`]. A downed trunk stalls crossing flows and
    /// arms the retry window on every QP whose *path* transits the link —
    /// even though neither endpoint port flapped, which is exactly the
    /// path-death class port-centric perception misses.
    pub fn set_links_up(&mut self, links: &[LinkId], up: bool, now: SimTime) -> NetOutput {
        let mut out = NetOutput::default();
        out.timers.extend(self.flows.set_links_up(links, up, now));
        self.stats.flap_events += 1;
        self.stats.flap_scan_floor += self.qps.len() as u64;
        // Sorted for determinism: retry windows armed here schedule engine
        // events, and HashMap order would leak into timestamp tie-breaks.
        // The crossing set is already sorted (index invariant), so the
        // iteration order matches the old sorted full scan restricted to
        // the QPs that produce output.
        let qp_ids = self.affected_qps(links);
        for qp_id in qp_ids {
            self.stats.flap_qp_visits += 1;
            if self.qps[&qp_id].state != QpState::Rts {
                continue;
            }
            if !up {
                out.merge(self.maybe_arm_retry(qp_id, now));
            } else if !self.qp_stalled(qp_id) {
                // Recovered within the window: disarm quietly ("about half
                // of flaps recover within seconds" — §3.3).
                let qp = self.qps.get_mut(&qp_id).unwrap();
                if qp.retrying_since.is_some() {
                    qp.retrying_since = None;
                    qp.epoch += 1;
                }
            }
        }
        out
    }

    /// The QPs a flap of `links` must visit: the sorted crossing set from
    /// the reverse index (reference mode: every QP, like the old scan).
    fn affected_qps(&self, links: &[LinkId]) -> Vec<QpId> {
        #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
        if self.force_scan {
            let mut ids: Vec<QpId> = self.qps.keys().copied().collect();
            ids.sort_unstable();
            return ids;
        }
        let ids = self.crossing_qps(links);
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            ids,
            self.reference_crossing_qps(links),
            "port→QP index diverged from the per-path scan"
        );
        ids
    }

    /// The index-derived crossing set (release-build equivalence tests;
    /// debug builds cross-check it on every flap anyway).
    #[cfg(any(test, debug_assertions, feature = "ref-alloc"))]
    pub fn indexed_crossing_qps(&self, links: &[LinkId]) -> Vec<QpId> {
        self.crossing_qps(links)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TopologyConfig;
    use crate::topology::{NicId, NodeId};

    fn setup() -> (Fabric, RdmaNet) {
        let fabric = Fabric::build(&TopologyConfig { num_nodes: 2, ..Default::default() });
        // Shrink the retry window so tests run fast: 4.096us × 2^10 × 2 ≈ 8.4ms
        let cfg = NetConfig { ib_timeout_exp: 10, ib_retry_cnt: 2, ..Default::default() };
        let net = RdmaNet::new(&fabric, cfg);
        (fabric, net)
    }

    fn port(node: usize, nic: usize) -> PortId {
        PortId { nic: NicId { node: NodeId(node), local: nic }, port: 0 }
    }

    /// Mini event loop over NetOutput (timers + deadlines + warmups).
    struct Loop {
        wcs: Vec<WorkCompletion>,
        timers: Vec<FlowTimer>,
        deadlines: Vec<(QpId, u32, SimTime)>,
        warmups: Vec<(QpId, SimTime)>,
        now: SimTime,
    }

    impl Loop {
        fn new() -> Self {
            Loop {
                wcs: vec![],
                timers: vec![],
                deadlines: vec![],
                warmups: vec![],
                now: SimTime::ZERO,
            }
        }
        fn absorb(&mut self, out: NetOutput) {
            self.wcs.extend(out.wcs);
            self.timers.extend(out.timers);
            self.deadlines.extend(out.retry_deadlines);
            self.warmups.extend(out.warmups);
        }
        /// Run until no events remain or `until` reached.
        fn run(&mut self, net: &mut RdmaNet, until: SimTime) {
            loop {
                let tt = self.timers.iter().map(|t| t.at).min();
                let dt = self.deadlines.iter().map(|d| d.2).min();
                let wt = self.warmups.iter().map(|w| w.1).min();
                let next = [tt, dt, wt].into_iter().flatten().min();
                let Some(at) = next else { break };
                if at > until {
                    break;
                }
                self.now = at;
                if tt == Some(at) {
                    let i = self.timers.iter().position(|t| t.at == at).unwrap();
                    let t = self.timers.remove(i);
                    let out = net.on_flow_timer(t.flow, t.gen, at);
                    self.absorb(out);
                } else if dt == Some(at) {
                    let i = self.deadlines.iter().position(|d| d.2 == at).unwrap();
                    let d = self.deadlines.remove(i);
                    let out = net.on_retry_deadline(d.0, d.1, at);
                    self.absorb(out);
                } else {
                    let i = self.warmups.iter().position(|w| w.1 == at).unwrap();
                    let w = self.warmups.remove(i);
                    let out = net.on_warm(w.0, at);
                    self.absorb(out);
                }
            }
        }
    }

    #[test]
    fn wr_completes_with_success_and_timestamps() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        let (wr, out) = net.post_send(qp, 1 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        lp.run(&mut net, SimTime::s(1));
        assert_eq!(lp.wcs.len(), 1);
        let wc = lp.wcs[0];
        assert_eq!(wc.wr, wr);
        assert_eq!(wc.status, CompletionStatus::Success);
        assert_eq!(wc.posted_at, SimTime::ZERO);
        // ≈ 1MB / (400Gbps × 0.97) + 2500ns NIC + 2 hops × 1000ns
        let expect = (1048576.0 / (400.0 * 0.125 * 0.97)) + 2500.0 + 2000.0;
        assert!((wc.completed_at.as_ns() as f64 - expect).abs() < 50.0);
    }

    #[test]
    fn port_down_triggers_retry_exceeded_after_window() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        let (_, out) = net.post_send(qp, 64 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        // Kill the port at 100us, before completion.
        let out = net.set_port_up(&fabric, port(0, 0), false, SimTime::us(100));
        lp.absorb(out);
        lp.run(&mut net, SimTime::s(5));
        assert_eq!(lp.wcs.len(), 1);
        assert_eq!(lp.wcs[0].status, CompletionStatus::RetryExceeded);
        assert_eq!(net.qp_state(qp), QpState::Error);
        // Deadline = 100us + window (2 retries × 4.096us×2^10 ≈ 8.39ms)
        let window_ns = net.cfg().retry_window_ns();
        let expect = 100_000 + window_ns;
        assert_eq!(lp.wcs[0].completed_at.as_ns(), expect);
    }

    #[test]
    fn trunk_down_arms_retry_on_crossing_qps_only() {
        let (mut fabric, mut net) = setup();
        // Cross-rail QP transits trunk_up(0,0); the aligned QP rides its
        // own rail's trunk pair (rail 1) and must be untouched.
        let crossing = net.create_qp(&fabric, port(0, 0), port(1, 5));
        let aligned = net.create_qp(&fabric, port(0, 1), port(1, 1));
        let mut lp = Loop::new();
        let (_, out) = net.post_send(crossing, 64 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        let (_, out) = net.post_send(aligned, 8 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        // Kill the trunk at 100us: neither endpoint port flaps, yet the
        // crossing QP's path is dead and its retry window must arm.
        let t = fabric.trunk_up(0, 0);
        fabric.set_link_up(t, false);
        let out = net.set_links_up(&[t], false, SimTime::us(100));
        lp.absorb(out);
        assert!(!net.qp_path_up(crossing, &fabric), "path-death perceived");
        assert!(net.qp_path_up(aligned, &fabric));
        lp.run(&mut net, SimTime::s(5));
        let by_qp = |q: QpId| lp.wcs.iter().find(|w| w.qp == q).unwrap();
        assert_eq!(by_qp(crossing).status, CompletionStatus::RetryExceeded);
        assert_eq!(
            by_qp(crossing).completed_at.as_ns(),
            100_000 + net.cfg().retry_window_ns()
        );
        assert_eq!(by_qp(aligned).status, CompletionStatus::Success);
        assert_eq!(net.qp_state(crossing), QpState::Error);
        assert_eq!(net.qp_state(aligned), QpState::Rts);
    }

    #[test]
    fn trunk_flap_within_window_recovers_silently() {
        let (mut fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 5));
        let mut lp = Loop::new();
        let (_, out) = net.post_send(qp, 8 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        let t = fabric.trunk_down(5, 0);
        fabric.set_link_up(t, false);
        lp.absorb(net.set_links_up(&[t], false, SimTime::us(50)));
        fabric.set_link_up(t, true);
        lp.absorb(net.set_links_up(&[t], true, SimTime::ms(2)));
        lp.run(&mut net, SimTime::s(5));
        assert_eq!(lp.wcs.len(), 1);
        assert_eq!(lp.wcs[0].status, CompletionStatus::Success);
        assert_eq!(net.qp_state(qp), QpState::Rts);
    }

    #[test]
    fn flap_within_window_recovers_silently() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        let (_, out) = net.post_send(qp, 8 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        let out = net.set_port_up(&fabric, port(0, 0), false, SimTime::us(50));
        lp.absorb(out);
        // Up again well inside the window.
        let out = net.set_port_up(&fabric, port(0, 0), true, SimTime::ms(2));
        lp.absorb(out);
        lp.run(&mut net, SimTime::s(5));
        assert_eq!(lp.wcs.len(), 1);
        assert_eq!(lp.wcs[0].status, CompletionStatus::Success);
        assert_eq!(net.qp_state(qp), QpState::Rts);
    }

    #[test]
    fn post_to_error_qp_flushes() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        net.force_error(qp, SimTime::ZERO);
        let (_, out) = net.post_send(qp, 1024, SimTime::us(1), 0);
        assert_eq!(out.wcs.len(), 1);
        assert_eq!(out.wcs[0].status, CompletionStatus::WrFlushed);
    }

    #[test]
    fn reset_to_rts_queues_until_warm() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        net.force_error(qp, SimTime::ZERO);
        let mut lp = Loop::new();
        let out = net.reset_to_rts(qp, SimTime::ZERO);
        lp.absorb(out);
        assert!(!net.is_warm(qp, SimTime::ZERO));
        // Post while cold: WR waits for the warm-up release.
        let (_, out) = net.post_send(qp, 1 << 20, SimTime::us(1), 0);
        lp.absorb(out);
        lp.run(&mut net, SimTime::s(5));
        assert_eq!(lp.wcs.len(), 1);
        assert_eq!(lp.wcs[0].status, CompletionStatus::Success);
        // Completed after warm-up (default 1.5s), not at ~21us.
        assert!(lp.wcs[0].completed_at >= SimTime::ns(net.cfg().qp_warmup_ns));
    }

    #[test]
    fn error_flushes_all_outstanding() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        for _ in 0..4 {
            let (_, out) = net.post_send(qp, 16 << 20, SimTime::ZERO, 0);
            lp.absorb(out);
        }
        assert_eq!(net.qp_outstanding(qp), 4);
        let out = net.force_error(qp, SimTime::us(10));
        lp.absorb(out);
        let statuses: Vec<_> = lp.wcs.iter().map(|w| w.status).collect();
        assert_eq!(statuses.len(), 4);
        assert_eq!(statuses[0], CompletionStatus::RetryExceeded);
        assert!(statuses[1..].iter().all(|s| *s == CompletionStatus::WrFlushed));
        assert_eq!(net.port_backlog_bytes(port(0, 0)), 0);
    }

    /// §Perf L4: a flap visits only the QPs whose path crosses the flapped
    /// port (the reverse index), never the whole net.
    #[test]
    fn flap_visits_only_crossing_qps() {
        let (fabric, mut net) = setup();
        // One rail-aligned QP per NIC pair: 8 QPs, disjoint 2-link paths.
        let qps: Vec<QpId> =
            (0..8).map(|nic| net.create_qp(&fabric, port(0, nic), port(1, nic))).collect();
        let mut lp = Loop::new();
        for &qp in &qps {
            let (_, out) = net.post_send(qp, 1 << 20, SimTime::ZERO, 0);
            lp.absorb(out);
        }
        let before = net.rdma_stats();
        let out = net.set_port_up(&fabric, port(0, 3), false, SimTime::us(10));
        lp.absorb(out);
        let s = net.rdma_stats();
        assert_eq!(s.flap_events - before.flap_events, 1);
        assert_eq!(s.flap_qp_visits - before.flap_qp_visits, 1, "only QP 3 crosses the port");
        assert_eq!(s.flap_scan_floor - before.flap_scan_floor, 8, "the old scan touched all 8");
        // And the flap still armed exactly the crossing QP's retry window.
        assert_eq!(lp.deadlines.len(), 1);
        assert_eq!(lp.deadlines[0].0, qps[3]);
    }

    /// §Perf L4: every backlog read costs one QP-visit, not one per QP.
    #[test]
    fn backlog_reads_are_constant_work() {
        let (fabric, mut net) = setup();
        for nic in 0..4 {
            let qp = net.create_qp(&fabric, port(0, nic), port(1, nic));
            let _ = net.post_send(qp, 1 << 20, SimTime::ZERO, 0);
        }
        let before = net.rdma_stats();
        for nic in 0..4 {
            assert_eq!(net.port_backlog_bytes(port(0, nic)), 1 << 20);
        }
        let s = net.rdma_stats();
        assert_eq!(s.backlog_reads - before.backlog_reads, 4);
        assert_eq!(s.backlog_qp_visits - before.backlog_qp_visits, 4, "one visit per read");
        assert_eq!(s.backlog_scan_floor - before.backlog_scan_floor, 16, "scan floor: 4 QPs × 4");
    }

    /// §Perf L4 acceptance: ~1k seeded random post / complete / flush /
    /// flap / error+reset (failover) operations, with the incremental net's
    /// outputs asserted identical to a reference-mode mirror at every step,
    /// and the running backlog counter and port→QP index asserted
    /// bit-identical to the reference scans throughout. (Debug builds
    /// additionally cross-check both inside every call.)
    #[test]
    fn randomized_equivalence_with_reference_scans() {
        use crate::util::Rng;
        let fabric =
            Fabric::build(&crate::config::TopologyConfig { num_nodes: 4, ..Default::default() });
        // Short windows so errors and warm-ups actually cycle in-sweep.
        let cfg = NetConfig {
            ib_timeout_exp: 10,
            ib_retry_cnt: 2,
            qp_warmup_ns: 2_000_000,
            ..Default::default()
        };
        let mut inc = RdmaNet::new(&fabric, cfg.clone());
        let mut refn = RdmaNet::new(&fabric, cfg);
        refn.set_reference_mode(true);

        let all_ports: Vec<PortId> =
            (0..4).flat_map(|n| (0..8).map(move |nic| port(n, nic))).collect();
        let mut qps: Vec<QpId> = Vec::new();
        // Seed QPs: rail-aligned ring (node n → n+1, same nic) — 32 QPs
        // whose 2-link paths overlap pairwise on every port.
        for n in 0..4 {
            for nic in 0..8 {
                let (s, d) = (port(n, nic), port((n + 1) % 4, nic));
                let a = inc.create_qp(&fabric, s, d);
                let b = refn.create_qp(&fabric, s, d);
                assert_eq!(a, b);
                qps.push(a);
            }
        }
        let assert_out = |step: usize, a: &NetOutput, b: &NetOutput| {
            assert_eq!(a.timers, b.timers, "step {step}: timers diverged");
            assert_eq!(a.wcs, b.wcs, "step {step}: WCs diverged");
            assert_eq!(a.retry_deadlines, b.retry_deadlines, "step {step}: deadlines diverged");
            assert_eq!(a.warmups, b.warmups, "step {step}: warm-ups diverged");
        };

        let mut rng = Rng::new(0x9D4A_11);
        let mut now = SimTime::ZERO;
        let mut timers: Vec<FlowTimer> = Vec::new();
        let mut deadlines: Vec<(QpId, u32, SimTime)> = Vec::new();
        let mut warmups: Vec<(QpId, SimTime)> = Vec::new();
        let mut down: Vec<PortId> = Vec::new();
        let ops = if cfg!(debug_assertions) { 400 } else { 1000 };
        for step in 0..ops {
            now = now + SimTime::ns(rng.range(1, 50_000));
            let (a, b) = match rng.below(10) {
                // 0-4: fire the earliest pending net event on both nets.
                0..=4 if !(timers.is_empty() && deadlines.is_empty() && warmups.is_empty()) => {
                    let tt = timers.iter().map(|t| t.at).min();
                    let dt = deadlines.iter().map(|d| d.2).min();
                    let wt = warmups.iter().map(|w| w.1).min();
                    let at = [tt, dt, wt].into_iter().flatten().min().unwrap();
                    now = now.max(at);
                    if tt == Some(at) {
                        let i = timers.iter().position(|t| t.at == at).unwrap();
                        let t = timers.remove(i);
                        (inc.on_flow_timer(t.flow, t.gen, now),
                         refn.on_flow_timer(t.flow, t.gen, now))
                    } else if dt == Some(at) {
                        let i = deadlines.iter().position(|d| d.2 == at).unwrap();
                        let d = deadlines.remove(i);
                        (inc.on_retry_deadline(d.0, d.1, now),
                         refn.on_retry_deadline(d.0, d.1, now))
                    } else {
                        let i = warmups.iter().position(|w| w.1 == at).unwrap();
                        let w = warmups.remove(i);
                        (inc.on_warm(w.0, now), refn.on_warm(w.0, now))
                    }
                }
                // 5-6 (plus 0-4 when idle): post a send on a random QP.
                0..=6 => {
                    let qp = qps[rng.below(qps.len() as u64) as usize];
                    let bytes = rng.range(64 << 10, 4 << 20);
                    let tail = rng.range(0, 5_000);
                    let (wa, oa) = inc.post_send(qp, bytes, now, tail);
                    let (wb, ob) = refn.post_send(qp, bytes, now, tail);
                    assert_eq!(wa, wb, "step {step}: WR ids diverged");
                    (oa, ob)
                }
                // 7: failover churn — error a random QP, proactively reset.
                7 => {
                    let qp = qps[rng.below(qps.len() as u64) as usize];
                    let oa = inc.force_error(qp, now);
                    let ob = refn.force_error(qp, now);
                    assert_out(step, &oa, &ob);
                    (merge2(oa, inc.reset_to_rts(qp, now)),
                     merge2(ob, refn.reset_to_rts(qp, now)))
                }
                // 8-9: flap a port (batched tx+rx, like the cluster layer).
                _ => {
                    if !down.is_empty() && rng.chance(0.6) {
                        let p = down.remove(rng.below(down.len() as u64) as usize);
                        (inc.set_port_up(&fabric, p, true, now),
                         refn.set_port_up(&fabric, p, true, now))
                    } else {
                        let p = all_ports[rng.below(all_ports.len() as u64) as usize];
                        if down.contains(&p) {
                            continue;
                        }
                        down.push(p);
                        (inc.set_port_up(&fabric, p, false, now),
                         refn.set_port_up(&fabric, p, false, now))
                    }
                }
            };
            assert_out(step, &a, &b);
            timers.extend(a.timers);
            deadlines.extend(a.retry_deadlines);
            warmups.extend(a.warmups);
            // The running counter and the reverse index must match the
            // reference scans bit-for-bit at every step, on every port.
            for &p in &all_ports {
                let scanned = inc.reference_port_backlog(p);
                assert_eq!(
                    inc.port_backlog_bytes(p), scanned,
                    "step {step}: backlog counter diverged on {p}"
                );
                assert_eq!(
                    refn.port_backlog_bytes(p), scanned,
                    "step {step}: reference-mode backlog diverged on {p}"
                );
                let links = fabric.port_links(p);
                assert_eq!(
                    inc.indexed_crossing_qps(&links),
                    inc.reference_crossing_qps(&links),
                    "step {step}: port→QP index diverged on {p}"
                );
            }
        }
        // The sweep must have exercised the incremental paths — and done
        // far less work than the reference scans.
        let (si, sr) = (inc.rdma_stats(), refn.rdma_stats());
        assert!(si.flap_events > 20, "flap_events={}", si.flap_events);
        assert!(si.backlog_reads > 1_000);
        assert!(si.visit_reduction() > 8.0, "reduction={:.1}", si.visit_reduction());
        assert!(
            si.backlog_qp_visits + si.flap_qp_visits < sr.backlog_qp_visits + sr.flap_qp_visits,
            "incremental must do less work than the reference"
        );
    }

    fn merge2(mut a: NetOutput, b: NetOutput) -> NetOutput {
        a.merge(b);
        a
    }

    #[test]
    fn backlog_tracks_outstanding_bytes() {
        let (fabric, mut net) = setup();
        let qp = net.create_qp(&fabric, port(0, 0), port(1, 0));
        let mut lp = Loop::new();
        let (_, out) = net.post_send(qp, 1 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        let (_, out) = net.post_send(qp, 2 << 20, SimTime::ZERO, 0);
        lp.absorb(out);
        assert_eq!(net.port_backlog_bytes(port(0, 0)), 3 << 20);
        lp.run(&mut net, SimTime::s(1));
        assert_eq!(net.port_backlog_bytes(port(0, 0)), 0);
        assert_eq!(net.qp_state(qp), QpState::Rts);
        assert_eq!(lp.wcs.len(), 2);
    }
}
